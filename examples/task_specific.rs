//! Task-specific fine-tuning (the paper's second scenario, §4.2): adapt a
//! quantized model to the arithmetic word-problem task and compare all
//! three QAF methods — LoRA (16-bit adapters, unmerged serving), QA-LoRA
//! (zero-factor merge) and LoTA-QAF (in-grid ternary merge).
//!
//! Run with: `cargo run --release --example task_specific`
//! Env knobs: LOTA_TASK (arith|sql|datatotext), LOTA_FT_STEPS (60),
//! LOTA_EVAL_N (24), LOTA_BITS (4).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::{run_cell, ExperimentContext};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let task = std::env::var("LOTA_TASK").unwrap_or_else(|_| "arith".into());
    let steps = env_usize("LOTA_FT_STEPS", 60);
    let eval_n = env_usize("LOTA_EVAL_N", 24);
    let bits = env_usize("LOTA_BITS", 4) as u32;

    let ctx = ExperimentContext::build(Path::new("artifacts"), "tiny", 150, 1)?;
    println!("== task-specific fine-tuning: {task} at {bits}-bit, {steps} steps ==");

    let mut t = Table::new(&[
        "method", "serving", "exact match %", "token acc %", "train s", "merge err",
    ]);
    for method in [Method::Lora, Method::QaLora, Method::LotaQaf] {
        let exp = ExperimentConfig {
            method,
            n_bits: bits,
            steps,
            lr: 5e-4,
            task: task.clone(),
            omega_frac: if task == "datatotext" { 0.875 } else { 0.75 },
            ..Default::default()
        };
        let cell = run_cell(&ctx, &exp, eval_n)?;
        t.row(&[
            method.as_str().to_string(),
            match method {
                Method::Lora => format!("{bits}-bit + 16-bit adapter"),
                _ => format!("{bits}-bit merged"),
            },
            format!("{:.2}", cell.exact_match.unwrap_or(0.0)),
            format!("{:.2}", cell.token_acc.unwrap_or(0.0)),
            format!("{:.1}", cell.report.wall_secs),
            format!("{:.1e}", cell.merge_err),
        ]);
    }
    t.print();
    println!("(LoTA/QA-LoRA rows serve pure low-bit; LoRA pays the adapter matmuls)");
    Ok(())
}
