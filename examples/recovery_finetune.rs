//! Performance-recovery fine-tuning (the paper's first scenario, §4.2):
//! pretrain → GPTQ-quantize (watch the MMLU-like score fall, hardest at
//! 2-bit) → recovery-finetune with LoTA-QAF on Alpaca-like generic data →
//! watch the score come back.
//!
//! Run with: `cargo run --release --example recovery_finetune`
//! Env knobs: LOTA_PRETRAIN_STEPS (default 150), LOTA_FT_STEPS (40),
//! LOTA_EVAL_N (32), LOTA_BITS (2).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::{run_cell, ExperimentContext};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let pretrain_steps = env_usize("LOTA_PRETRAIN_STEPS", 150);
    let ft_steps = env_usize("LOTA_FT_STEPS", 40);
    let eval_n = env_usize("LOTA_EVAL_N", 32);
    let bits = env_usize("LOTA_BITS", 2) as u32;

    let ctx = ExperimentContext::build(Path::new("artifacts"), "tiny", pretrain_steps, 1)?;
    println!("== performance recovery at {bits}-bit ==");

    // 16-bit reference and the quantized (pre-recovery) score
    let fp_scores = ctx.mmlu_fp(eval_n)?;
    let q = ctx.quantized(bits)?;
    let q_scores = ctx.mmlu_merged(&q, eval_n)?;

    // recovery fine-tune with LoTA-QAF
    let exp = ExperimentConfig {
        method: Method::LotaQaf,
        n_bits: bits,
        steps: ft_steps,
        task: "recovery".into(),
        omega_frac: 0.75,
        sigma_init: 0.05,
        ..Default::default()
    };
    let cell = run_cell(&ctx, &exp, eval_n)?;
    let recovered = cell.mmlu.expect("recovery cell scores mmlu");

    let mut t = Table::new(&["stage", "facts", "math", "social", "seq", "avg"]);
    for (name, s) in [
        ("16-bit base", &fp_scores),
        (&format!("GPTQ {bits}-bit"), &q_scores),
        ("  + LoTA-QAF recovery", &recovered),
    ] {
        let mut row = vec![name.to_string()];
        row.extend(s.per_subject.iter().map(|v| format!("{v:.1}")));
        row.push(format!("{:.1}", s.average));
        t.row(&row);
    }
    t.print();
    println!(
        "loss {:.3} -> {:.3} over {} t-SignSGD steps; merge error {:.1e} (lossless)",
        cell.report.losses.first().unwrap_or(&f32::NAN),
        cell.report.losses.last().unwrap_or(&f32::NAN),
        cell.report.steps,
        cell.merge_err,
    );
    if recovered.average >= q_scores.average {
        println!("OK — recovery fine-tuning improved the quantized model");
    } else {
        println!(
            "NOTE: no recovery at this scale ({:.1} -> {:.1}); raise LOTA_FT_STEPS",
            q_scores.average, recovered.average
        );
    }
    Ok(())
}
