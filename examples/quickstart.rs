//! Quickstart: the LoTA-QAF core loop in one page of API surface.
//!
//! Builds a tiny quantized model, fine-tunes ternary adapters with
//! t-SignSGD for a handful of steps, merges them **losslessly** into the
//! 4-bit grid, and verifies the merged model reproduces the adapter
//! model's logits.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first)

use std::path::Path;

use lota_qaf::config::{preset, step_batch, ExperimentConfig, Method};
use lota_qaf::coordinator::{finetune, merge_into_store, run_forward, TrainOptions};
use lota_qaf::model;
use lota_qaf::quant::rtn_quantize;
use lota_qaf::runtime::Runtime;
use lota_qaf::tensor::{Rng, Tensor};

fn main() -> anyhow::Result<()> {
    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg = preset("tiny")?;
    println!("model: {} ({} params)", cfg.name, cfg.n_params());

    // 1. a quantized base model (RTN for speed; see recovery_finetune.rs
    //    for the full GPTQ pipeline)
    let mut rng = Rng::new(42);
    let fp = model::init_fp(&cfg, &mut rng);
    let mut store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))?;

    // 2. ternary adapters (paper §3.2 init) + a short t-SignSGD run
    model::init_adapters(&cfg, Method::LotaQaf, &mut rng, &mut store);
    let exp = ExperimentConfig {
        method: Method::LotaQaf,
        n_bits: 4,
        steps: 15,
        task: "recovery".into(),
        ..Default::default()
    };
    let report = finetune(&rt, &cfg, &exp, &mut store, &TrainOptions::default())?;
    println!(
        "fine-tuned {} steps: loss {:.3} -> {:.3}",
        report.steps,
        report.losses.first().unwrap(),
        report.losses.last().unwrap()
    );

    // 3. logits through the live-adapter path...
    let b = step_batch(&cfg.name);
    let mut trng = Rng::new(7);
    let tokens = Tensor::new(
        &[b, cfg.seq_len],
        (0..b * cfg.seq_len).map(|_| trng.below(cfg.vocab) as f32).collect(),
    );
    let exe_lota = rt.load("fwd_lota_tiny_w4")?;
    let before = run_forward(&rt, &exe_lota, &store, &tokens, Some(exp.omega(cfg.rank)))?;

    // 4. ...merge losslessly and compare through the merged low-bit path
    let err = merge_into_store(&cfg, &exp, &mut store)?;
    let exe_merged = rt.load("fwd_merged_tiny")?;
    let after = run_forward(&rt, &exe_merged, &store, &tokens, None)?;
    println!(
        "merge: requant error {err:.1e}, max logit diff {:.2e} (f32 noise only)",
        before.max_abs_diff(&after)
    );
    assert_eq!(err, 0.0, "LoTA merge is lossless by construction");
    assert!(before.max_abs_diff(&after) < 2e-4);
    println!("OK — ternary adaptation merged into the 4-bit grid with zero loss");
    Ok(())
}
