//! END-TO-END VALIDATION (DESIGN.md §6): the full system on a real
//! workload, proving all layers compose —
//!
//!   pretrain (in-graph AdamW through PJRT)
//!     → GPTQ quantization (Rust Hessians from captured activations)
//!       → LoTA-QAF recovery fine-tuning (t-SignSGD, loss curve logged)
//!         → **lossless merge** (bit-exact grid check)
//!           → task-specific fine-tuning (arith)
//!             → batched serving of the merged low-bit model
//!
//! Defaults run the `small` (~3.2M param) config in a few minutes on one
//! CPU core; set LOTA_MODEL=medium (~14M) or raise step counts for a
//! longer run. The run log for EXPERIMENTS.md §E2E came from this binary.
//!
//! Run with: `cargo run --release --example e2e_pipeline`

use std::path::Path;

use lota_qaf::config::{Backend, ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::{max_new_for, ExperimentContext};
use lota_qaf::coordinator::{
    exact_match_eval, finetune, merge_into_store, token_accuracy, TrainOptions,
};
use lota_qaf::data::tasks;
use lota_qaf::model;
use lota_qaf::serve::{serve_batch, ServeOptions, ServePath};
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

fn fmt_curve(losses: &[f32]) -> String {
    // compact loss curve: every ~10th point
    let stride = (losses.len() / 12).max(1);
    losses
        .iter()
        .step_by(stride)
        .map(|l| format!("{l:.2}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() -> anyhow::Result<()> {
    let model_name = env_str("LOTA_MODEL", "small");
    let pretrain_steps = env_usize("LOTA_PRETRAIN_STEPS", 300);
    let recovery_steps = env_usize("LOTA_RECOVERY_STEPS", 120);
    let task_steps = env_usize("LOTA_TASK_STEPS", 150);
    let bits = env_usize("LOTA_BITS", 4) as u32;
    let eval_n = env_usize("LOTA_EVAL_N", 32);

    println!("=== LoTA-QAF end-to-end pipeline: {model_name} at {bits}-bit ===\n");

    // -- stage 1+2: pretrain + GPTQ-calibrate (cached in checkpoints/) --
    let t0 = std::time::Instant::now();
    let ctx = ExperimentContext::build(Path::new("artifacts"), &model_name, pretrain_steps, 3)?;
    println!(
        "[1] base model: {} params, pretrained {pretrain_steps} steps ({:.0}s)",
        ctx.cfg.n_params(),
        t0.elapsed().as_secs_f64()
    );
    let fp_mmlu = ctx.mmlu_fp(eval_n)?;
    println!("    16-bit MMLU-like avg: {:.2}%", fp_mmlu.average);

    let quant = ctx.quantized(bits)?;
    let q_mmlu = ctx.mmlu_merged(&quant, eval_n)?;
    println!("[2] GPTQ {bits}-bit MMLU-like avg: {:.2}%", q_mmlu.average);

    // -- stage 3: recovery fine-tuning with LoTA-QAF --
    let mut store = quant.clone();
    let mut rng = Rng::new(77);
    model::init_adapters(&ctx.cfg, Method::LotaQaf, &mut rng, &mut store);
    let exp = ExperimentConfig {
        model: model_name.clone(),
        method: Method::LotaQaf,
        n_bits: bits,
        steps: recovery_steps,
        task: "recovery".into(),
        ..Default::default()
    };
    let report = finetune(&ctx.rt, &ctx.cfg, &exp, &mut store, &TrainOptions::default())?;
    println!(
        "[3] recovery fine-tune {recovery_steps} t-SignSGD steps ({:.0}s)\n    loss curve: {}",
        report.wall_secs,
        fmt_curve(&report.losses)
    );

    // -- stage 4: lossless merge + verification --
    let merge_err = merge_into_store(&ctx.cfg, &exp, &mut store)?;
    assert_eq!(merge_err, 0.0);
    let rec_mmlu = ctx.mmlu_merged(&store, eval_n)?;
    println!(
        "[4] lossless merge (requant error {merge_err:.1}); recovered MMLU-like avg: {:.2}% \
         (was {:.2}% quantized, {:.2}% fp)",
        rec_mmlu.average, q_mmlu.average, fp_mmlu.average
    );

    // -- stage 5: task-specific fine-tuning on arith --
    let mut task_store = quant;
    model::init_adapters(&ctx.cfg, Method::LotaQaf, &mut rng, &mut task_store);
    let exp_task = ExperimentConfig {
        task: "arith".into(),
        steps: task_steps,
        lr: 5e-4,
        ..exp.clone()
    };
    let report = finetune(&ctx.rt, &ctx.cfg, &exp_task, &mut task_store, &TrainOptions::default())?;
    merge_into_store(&ctx.cfg, &exp_task, &mut task_store)?;
    let gen = tasks::task_by_name("arith")?;
    let test = gen.test_set(eval_n);
    let exe = ctx.rt.load(&format!("fwd_merged_{model_name}"))?;
    let em = exact_match_eval(
        &ctx.rt, &exe, &task_store, &ctx.cfg, &test, max_new_for("arith"), None,
    )?;
    let ta = token_accuracy(&ctx.rt, &exe, &task_store, &ctx.cfg, &test, None)?;
    println!(
        "[5] task fine-tune (arith, {task_steps} steps, {:.0}s): exact match {em:.2}%, \
         token acc {ta:.2}%",
        report.wall_secs
    );

    // -- stage 6: serve the merged model --
    let mut prng = Rng::new(55);
    let prompts: Vec<String> = (0..16)
        .map(|_| gen.sample(&mut prng, tasks::Split::Test).prompt)
        .collect();
    let opts = ServeOptions::new(ServePath::Merged, 6);
    let rep = serve_batch(Some(&ctx.rt), &ctx.cfg, &task_store, &opts, &prompts)?;
    println!(
        "[6] served {} merged-path requests [pjrt]: {:.1} tok/s, p50 {:.3}s, p95 {:.3}s",
        rep.requests, rep.tokens_per_sec, rep.latency.p50, rep.latency.p95
    );
    // same checkpoint through the native packed-integer engine — no
    // artifacts, no buckets, any batch size
    let nopts = ServeOptions::new(ServePath::Merged, 6).backend(Backend::Native).bits(bits);
    let nrep = serve_batch(None, &ctx.cfg, &task_store, &nopts, &prompts)?;
    println!(
        "[6] served {} merged-path requests [native]: {:.1} tok/s, p50 {:.3}s, p95 {:.3}s",
        nrep.requests, nrep.tokens_per_sec, nrep.latency.p50, nrep.latency.p95
    );

    let stats = ctx.rt.stats();
    println!(
        "\nruntime: {} artifact compilations ({:.1}s), {} executions ({:.1}s)",
        stats.compilations, stats.compile_secs, stats.executions, stats.execute_secs
    );
    println!("=== e2e pipeline complete ===");
    Ok(())
}
