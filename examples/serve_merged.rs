//! Serving-efficiency demo (paper §4.3, Fig. 4 "Efficiency Analysis"):
//! serve the same request stream through
//!   (a) the merged low-bit path (LoTA-QAF after its lossless merge), and
//!   (b) the quant + 16-bit-adapter path (LoRA, unmergeable without loss),
//! on **both** serving backends — the fixed-bucket PJRT artifacts and the
//! native packed-integer engine — and report throughput + latency.
//!
//! Run with: `cargo run --release --example serve_merged`
//! Env knobs: LOTA_REQUESTS (24), LOTA_MAX_NEW (8), LOTA_BITS (4),
//! LOTA_BACKEND (both|pjrt|native).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{preset, Backend, Method};
use lota_qaf::model;
use lota_qaf::quant::{pack::deployed_bytes, rtn_quantize};
use lota_qaf::runtime::Runtime;
use lota_qaf::serve::{serve_batch, ServeOptions, ServePath};
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n = env_usize("LOTA_REQUESTS", 24);
    let max_new = env_usize("LOTA_MAX_NEW", 8);
    let bits = env_usize("LOTA_BITS", 4) as u32;
    let backend_sel = std::env::var("LOTA_BACKEND").unwrap_or_else(|_| "both".into());
    let backends = Backend::parse_selection(&backend_sel)?;

    // the native engine serves without artifacts; only load PJRT if asked
    let rt = if backends.contains(&Backend::Pjrt) {
        Some(Runtime::new(Path::new("artifacts"))?)
    } else {
        None
    };
    let cfg = preset("tiny")?;
    let mut rng = Rng::new(9);
    let fp = model::init_fp(&cfg, &mut rng);

    // merged path: quantized weights only
    let merged =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, bits)))?;
    // lora path: same base + fp adapters riding along
    let mut lora = merged.clone();
    model::init_adapters(&cfg, Method::Lora, &mut rng, &mut lora);

    let gen = lota_qaf::data::task_by_name("arith")?;
    let mut prng = Rng::new(31);
    let prompts: Vec<String> = (0..n)
        .map(|_| gen.sample(&mut prng, lota_qaf::data::Split::Test).prompt)
        .collect();

    println!("serving {n} requests × {max_new} new tokens on {} ...", cfg.name);
    let mut t = Table::new(&["path", "backend", "tok/s", "req/s", "p50 s", "p95 s", "weights"]);
    let w_bytes: usize = cfg
        .slots()
        .iter()
        .map(|(_, din, dout)| deployed_bytes(*din, *dout, cfg.group_size, bits) * cfg.n_layers)
        .sum();
    let adapter_bytes: usize = cfg
        .slots()
        .iter()
        .map(|(_, din, dout)| (din * cfg.rank + cfg.rank * dout) * 4 * cfg.n_layers)
        .sum();
    let mut speedups = Vec::new();
    for &backend in &backends {
        let opts = |path| ServeOptions::new(path, max_new).backend(backend).bits(bits);
        let rep_merged = serve_batch(rt.as_ref(), &cfg, &merged, &opts(ServePath::Merged), &prompts)?;
        let rep_lora =
            serve_batch(rt.as_ref(), &cfg, &lora, &opts(ServePath::LoraAdapter), &prompts)?;
        for (name, rep, bytes) in [
            ("merged (LoTA/QA-LoRA)", &rep_merged, w_bytes),
            ("quant + 16-bit LoRA", &rep_lora, w_bytes + adapter_bytes),
        ] {
            t.row(&[
                name.to_string(),
                backend.as_str().to_string(),
                format!("{:.1}", rep.tokens_per_sec),
                format!("{:.2}", rep.requests_per_sec),
                format!("{:.3}", rep.latency.p50),
                format!("{:.3}", rep.latency.p95),
                format!("{:.1} KiB", bytes as f64 / 1024.0),
            ]);
        }
        speedups.push((backend, rep_merged.speedup_over(&rep_lora)));
    }
    t.print();
    for (backend, s) in speedups {
        println!(
            "merged-path speedup over LoRA path [{}]: {s:.2}x (paper reports 1.7–2.0x on A800)",
            backend.as_str()
        );
    }
    Ok(())
}
