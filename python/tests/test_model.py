"""L2 graph correctness: shapes, the lossless-merge invariant at the full
model level, optimizer-step behaviour, and method-specific semantics.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.configs import TINY as CFG, STEP_BATCH
from compile.golden import ref_rtn_quantize
from compile.kernels import ref

B = STEP_BATCH["tiny"]
T = CFG.seq_len


@pytest.fixture(scope="module")
def frozen():
    rng = np.random.default_rng(7)
    shapes = model.frozen_shapes(CFG, "lota")
    out = {}
    for n, s in shapes.items():
        if n.startswith("q_") and n.endswith("_int"):
            out[n] = jnp.array(rng.integers(0, 16, s).astype(np.float32))
        elif n.endswith("_s"):
            out[n] = jnp.array(rng.random(s).astype(np.float32) * 0.02 + 0.005)
        elif n.endswith("_z"):
            out[n] = jnp.array(rng.normal(size=s).astype(np.float32) * 0.02)
        elif n in ("ln1_w", "ln2_w", "lnf_w"):
            out[n] = jnp.ones(s, jnp.float32)
        elif n in ("ln1_b", "ln2_b", "lnf_b"):
            out[n] = jnp.zeros(s, jnp.float32)
        else:
            out[n] = jnp.array(rng.normal(size=s).astype(np.float32) * 0.05)
    return out


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(8)
    tokens = jnp.array(rng.integers(0, CFG.vocab, (B, T)).astype(np.float32))
    targets = jnp.array(rng.integers(0, CFG.vocab, (B, T)).astype(np.float32))
    mask = jnp.ones((B, T), jnp.float32)
    return tokens, targets, mask


def ternary_adapters(seed=9):
    rng = np.random.default_rng(seed)
    shapes = model.adapter_shapes(CFG, "lota")
    return {n: jnp.array(rng.integers(-1, 2, s).astype(np.float32))
            for n, s in shapes.items()}


def test_forward_shapes_all_methods(frozen, batch):
    tokens = batch[0]
    for method in ("merged", "lora", "qalora", "lota"):
        rng = np.random.default_rng(1)
        adap = {n: jnp.array(rng.normal(size=s).astype(np.float32) * 0.01)
                for n, s in model.adapter_shapes(CFG, method).items()}
        if method == "lota":
            adap = ternary_adapters()
        logits = model.forward({**frozen, **adap}, tokens, CFG, method,
                               omega=0.75 * CFG.rank, n_bits=4)
        assert logits.shape == (B, T, CFG.vocab)
        assert bool(jnp.isfinite(logits).all())


def test_lossless_merge_full_model(frozen, batch):
    """THE paper headline: merged-model logits ≡ adapter-applied logits.

    Merge every layer's every slot host-side with the *reference* map, build
    a 'merged' parameter set, and compare full-model logits against the
    lota forward with live adapters. They must agree to f32 round-off.
    """
    tokens = batch[0]
    adap = ternary_adapters()
    omega = 0.75 * CFG.rank

    merged = dict(frozen)
    for s in model.slot_dims(CFG):
        a = adap[f"ta_{s}_a"]
        b = adap[f"ta_{s}_b"]
        w_new, z_new = jax.vmap(
            lambda aa, bb, ww, ss, zz: ref.ternary_apply_ref(
                aa, bb, ww, ss, zz, omega, CFG.rank, 4)
        )(a, b, frozen[f"q_{s}_int"], frozen[f"q_{s}_s"], frozen[f"q_{s}_z"])
        merged[f"q_{s}_int"] = w_new
        merged[f"q_{s}_z"] = z_new

    logits_adapter = model.forward({**frozen, **adap}, tokens, CFG, "lota",
                                   omega=omega, n_bits=4)
    logits_merged = model.forward(merged, tokens, CFG, "merged")
    # The merged *representation* (integer grid + zero factors) is exact —
    # asserted bit-for-bit in test_kernels. At the logits level the two
    # executions are different XLA programs, so f32 GEMM reassociation
    # leaves ~1e-5 noise; anything beyond that would indicate a real
    # (lossy) merge. Compare with the LoRA requant merge below, whose
    # error is orders of magnitude larger.
    np.testing.assert_allclose(np.asarray(logits_adapter),
                               np.asarray(logits_merged),
                               rtol=5e-3, atol=1e-4)


def test_lora_merge_is_lossy(frozen, batch):
    """Counterpart: requantizing a LoRA update back onto the grid changes
    the logits (the accuracy-degradation challenge motivating the paper)."""
    tokens = batch[0]
    rng = np.random.default_rng(11)
    adap = {n: jnp.array(rng.normal(size=s).astype(np.float32) * 0.05)
            for n, s in model.adapter_shapes(CFG, "lora").items()}
    alpha = 2.0 * CFG.rank

    merged = dict(frozen)
    for s in model.slot_dims(CFG):
        w_new, _ = jax.vmap(
            lambda ww, ss, zz, aa, bb: ref.lora_merge_requant_ref(
                ww, ss, zz, aa, bb, alpha, CFG.rank, 4)
        )(frozen[f"q_{s}_int"], frozen[f"q_{s}_s"], frozen[f"q_{s}_z"],
          adap[f"lo_{s}_a"], adap[f"lo_{s}_b"])
        merged[f"q_{s}_int"] = w_new

    logits_adapter = model.forward({**frozen, **adap}, tokens, CFG, "lora")
    logits_merged = model.forward(merged, tokens, CFG, "merged")
    diff = float(jnp.abs(logits_adapter - logits_merged).max())
    assert diff > 1e-4, "requantized LoRA merge should NOT be lossless"


def test_qalora_merge_lossless(frozen, batch):
    """QA-LoRA's zero-factor merge is lossless too (but can only move zeros)."""
    tokens = batch[0]
    rng = np.random.default_rng(12)
    adap = {n: jnp.array(rng.normal(size=s).astype(np.float32) * 0.05)
            for n, s in model.adapter_shapes(CFG, "qalora").items()}
    alpha = 2.0 * CFG.rank

    merged = dict(frozen)
    for s in model.slot_dims(CFG):
        ab = jax.vmap(jnp.matmul)(adap[f"qa_{s}_a"], adap[f"qa_{s}_b"])
        merged[f"q_{s}_z"] = (frozen[f"q_{s}_z"]
                              + (alpha / CFG.rank) * ab / CFG.group_size)

    logits_adapter = model.forward({**frozen, **adap}, tokens, CFG, "qalora")
    logits_merged = model.forward(merged, tokens, CFG, "merged")
    np.testing.assert_allclose(np.asarray(logits_adapter),
                               np.asarray(logits_merged),
                               rtol=2e-4, atol=2e-4)


def test_lota_step_decreases_loss(frozen, batch):
    """A few t-SignSGD steps on a fixed batch must reduce the loss."""
    tokens, targets, mask = batch
    fn, fnames, anames, _, _ = model.make_step_fn(CFG, "lota", 4,
                                                  use_pallas=False)
    step = jax.jit(fn)
    adap = ternary_adapters()
    args_f = [frozen[n] for n in fnames]
    cur = {n: adap[n] for n in anames}
    losses = []
    for _ in range(8):
        out = step(*args_f, *[cur[n] for n in anames], tokens, targets, mask,
                   jnp.array([0.5 * CFG.rank]), jnp.array([0.05]))
        losses.append(float(out[0][0]))
        cur = {n: out[1 + i] for i, n in enumerate(anames)}
        for n in anames:  # stays ternary
            assert set(np.unique(np.asarray(cur[n]))).issubset({-1.0, 0.0, 1.0})
    assert losses[-1] < losses[0], f"no progress: {losses}"


def test_adamw_step_runs_and_improves(frozen, batch):
    tokens, targets, mask = batch
    for method in ("lora", "qalora"):
        fn, fnames, anames, _, _ = model.make_step_fn(CFG, method, 4)
        step = jax.jit(fn)
        rng = np.random.default_rng(13)
        shapes = model.adapter_shapes(CFG, method)
        cur = {}
        for n in anames:
            if n.endswith("_b"):
                cur[n] = jnp.zeros(shapes[n], jnp.float32)  # LoRA B=0 init
            else:
                cur[n] = jnp.array(rng.normal(size=shapes[n]).astype(np.float32)
                                   * 0.02)
        m = {n: jnp.zeros(shapes[n], jnp.float32) for n in anames}
        v = {n: jnp.zeros(shapes[n], jnp.float32) for n in anames}
        losses = []
        for t in range(1, 9):
            out = step(*[frozen[n] for n in fnames], *[cur[n] for n in anames],
                       *[m[n] for n in anames], *[v[n] for n in anames],
                       tokens, targets, mask,
                       jnp.array([5e-3]), jnp.array([float(t)]))
            losses.append(float(out[0][0]))
            k = len(anames)
            cur = {n: out[1 + i] for i, n in enumerate(anames)}
            m = {n: out[1 + k + i] for i, n in enumerate(anames)}
            v = {n: out[1 + 2 * k + i] for i, n in enumerate(anames)}
        assert losses[-1] < losses[0], f"{method}: no progress {losses}"


def test_pretrain_step_improves(batch):
    tokens, targets, mask = batch
    fn, names, _ = model.make_pretrain_fn(CFG)
    step = jax.jit(fn)
    rng = np.random.default_rng(14)
    shapes = model.frozen_shapes(CFG, "fp")
    p = {}
    for n, s in shapes.items():
        if n in ("ln1_w", "ln2_w", "lnf_w"):
            p[n] = jnp.ones(s, jnp.float32)
        elif n.endswith("_b"):
            p[n] = jnp.zeros(s, jnp.float32)
        else:
            p[n] = jnp.array(rng.normal(size=s).astype(np.float32) * 0.05)
    m = {n: jnp.zeros(shapes[n], jnp.float32) for n in names}
    v = {n: jnp.zeros(shapes[n], jnp.float32) for n in names}
    losses = []
    for t in range(1, 7):
        out = step(*[p[n] for n in names], *[m[n] for n in names],
                   *[v[n] for n in names], tokens, targets, mask,
                   jnp.array([1e-3]), jnp.array([float(t)]))
        losses.append(float(out[0][0]))
        k = len(names)
        p = {n: out[1 + i] for i, n in enumerate(names)}
        m = {n: out[1 + k + i] for i, n in enumerate(names)}
        v = {n: out[1 + 2 * k + i] for i, n in enumerate(names)}
    assert losses[-1] < losses[0]


def test_rtn_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(15)
    w = rng.normal(size=(64, 32)).astype(np.float32) * 0.1
    for nb in (2, 3, 4):
        w_int, sc, ze = ref_rtn_quantize(w, 16, nb)
        deq = np.asarray(ref.dequant_ref(jnp.array(w_int), jnp.array(sc),
                                         jnp.array(ze)))
        max_err = np.abs(deq - w).max()
        # RTN error is bounded by s/2 per group
        assert max_err <= sc.max() / 2 + 1e-6
        assert w_int.min() >= 0 and w_int.max() <= 2 ** nb - 1


def test_loss_mask_zeroes_padding(frozen, batch):
    tokens, targets, _ = batch
    adap = ternary_adapters()
    p = {**frozen, **adap}
    full = model.loss_fn(p, (tokens, targets, jnp.ones((B, T))), CFG, "lota",
                         0.75 * CFG.rank, 4)
    # masking out the second half must change the value (different average)
    half_mask = jnp.concatenate([jnp.ones((B, T // 2)),
                                 jnp.zeros((B, T // 2))], axis=1)
    half = model.loss_fn(p, (tokens, targets, half_mask), CFG, "lota",
                         0.75 * CFG.rank, 4)
    assert np.isfinite(float(full)) and np.isfinite(float(half))
    assert abs(float(full) - float(half)) > 1e-7
