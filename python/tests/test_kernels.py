"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes (and the key hyper-parameters ω / keep_frac /
n_bits); every comparison is exact or within one f32 ulp-ish tolerance —
the kernels are the same arithmetic in a different schedule.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quant_matmul import quant_matmul
from compile.kernels.ternary import ternary_apply, ternary_apply_fwd_pallas
from compile.kernels.tsign import tsign_update

SETTINGS = dict(max_examples=25, deadline=None)


def make_quant(rng, din, dout, g, n_bits):
    w_int = rng.integers(0, 2 ** n_bits, (din, dout)).astype(np.float32)
    scales = (rng.random((g, dout)).astype(np.float32) * 0.1 + 0.01)
    zeros = rng.normal(size=(g, dout)).astype(np.float32) * 0.1
    return w_int, scales, zeros


@st.composite
def qmm_case(draw):
    gs = draw(st.sampled_from([8, 16, 32]))
    g = draw(st.integers(1, 4))
    dout = draw(st.sampled_from([64, 128]))
    m = draw(st.sampled_from([1, 8, 16]))
    n_bits = draw(st.sampled_from([2, 3, 4]))
    seed = draw(st.integers(0, 2 ** 31))
    return gs, g, dout, m, n_bits, seed


@given(qmm_case())
@settings(**SETTINGS)
def test_quant_matmul_matches_ref(case):
    gs, g, dout, m, n_bits, seed = case
    din = g * gs
    rng = np.random.default_rng(seed)
    w_int, sc, ze = make_quant(rng, din, dout, g, n_bits)
    x = rng.normal(size=(m, din)).astype(np.float32)
    got = quant_matmul(jnp.array(x), jnp.array(w_int), jnp.array(sc),
                       jnp.array(ze), block_m=8, block_n=64)
    want = ref.quant_matmul_ref(jnp.array(x), jnp.array(w_int),
                                jnp.array(sc), jnp.array(ze))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


@st.composite
def ternary_case(draw):
    gs = draw(st.sampled_from([8, 16]))
    g = draw(st.integers(1, 4))
    dout = draw(st.sampled_from([64, 128]))
    r = draw(st.sampled_from([4, 8, 16]))
    n_bits = draw(st.sampled_from([2, 3, 4]))
    omega_frac = draw(st.sampled_from([0.5, 0.75, 0.875]))
    seed = draw(st.integers(0, 2 ** 31))
    return gs, g, dout, r, n_bits, omega_frac, seed


@given(ternary_case())
@settings(**SETTINGS)
def test_ternary_kernel_matches_ref(case):
    gs, g, dout, r, n_bits, omega_frac, seed = case
    din = g * gs
    rng = np.random.default_rng(seed)
    w_int, sc, ze = make_quant(rng, din, dout, g, n_bits)
    a = rng.integers(-1, 2, (din, r)).astype(np.float32)
    b = rng.integers(-1, 2, (r, dout)).astype(np.float32)
    omega = omega_frac * r
    w1, z1 = ternary_apply_fwd_pallas(
        jnp.array(a), jnp.array(b), jnp.array(w_int), jnp.array(sc),
        jnp.array(ze), jnp.float32(omega), r, n_bits)
    w2, z2 = ref.ternary_apply_ref(
        jnp.array(a), jnp.array(b), jnp.array(w_int), jnp.array(sc),
        jnp.array(ze), omega, r, n_bits)
    # integer grid must match EXACTLY (it is the lossless-merge payload)
    assert bool(jnp.all(w1 == w2))
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2),
                               rtol=1e-6, atol=1e-6)


@given(ternary_case())
@settings(**SETTINGS)
def test_ternary_output_stays_in_grid(case):
    gs, g, dout, r, n_bits, omega_frac, seed = case
    din = g * gs
    rng = np.random.default_rng(seed)
    w_int, sc, ze = make_quant(rng, din, dout, g, n_bits)
    a = rng.integers(-1, 2, (din, r)).astype(np.float32)
    b = rng.integers(-1, 2, (r, dout)).astype(np.float32)
    w1, _ = ref.ternary_apply_ref(
        jnp.array(a), jnp.array(b), jnp.array(w_int), jnp.array(sc),
        jnp.array(ze), omega_frac * r, r, n_bits)
    w1 = np.asarray(w1)
    assert w1.min() >= 0.0 and w1.max() <= 2 ** n_bits - 1
    assert np.all(w1 == np.rint(w1)), "grid values must stay integral"
    # adjustment is ternary: at most ±1 from the original grid
    assert np.abs(w1 - w_int).max() <= 1.0


@given(st.integers(0, 2 ** 31), st.sampled_from([0.02, 0.05, 0.095, 0.001]))
@settings(**SETTINGS)
def test_tsign_kernel_matches_ref(seed, keep):
    rng = np.random.default_rng(seed)
    rows, cols = 64, 8
    a = rng.integers(-1, 2, (rows, cols)).astype(np.float32)
    g = rng.normal(size=(rows, cols)).astype(np.float32) * 1e-3
    got = tsign_update(jnp.array(a), jnp.array(g), jnp.float32(keep))
    want = ref.tsign_update_ref(jnp.array(a), jnp.array(g), jnp.float32(keep))
    assert bool(jnp.all(got == want))


@given(st.integers(0, 2 ** 31))
@settings(**SETTINGS)
def test_tsign_update_is_ternary_and_selective(seed):
    rng = np.random.default_rng(seed)
    rows, cols = 128, 8
    a = rng.integers(-1, 2, (rows, cols)).astype(np.float32)
    g = rng.normal(size=(rows, cols)).astype(np.float32)
    keep = 0.05
    out = np.asarray(ref.tsign_update_ref(jnp.array(a), jnp.array(g),
                                          jnp.float32(keep)))
    assert set(np.unique(out)).issubset({-1.0, 0.0, 1.0})
    # roughly keep-fraction of entries move (clips can reduce the count)
    changed = (out != a).sum()
    assert changed <= int(np.ceil(keep * a.size)) + 1


def test_tsign_zero_grad_is_identity():
    a = jnp.array(np.random.default_rng(0).integers(-1, 2, (64, 8)),
                  jnp.float32)
    g = jnp.zeros((64, 8), jnp.float32)
    out = tsign_update(a, g, jnp.float32(0.05))
    assert bool(jnp.all(out == a))


def test_ternary_ste_gradients_nonzero():
    """The custom_vjp must deliver usable gradients to both adapters."""
    rng = np.random.default_rng(3)
    din, dout, g, r, nb = 32, 64, 4, 8, 4
    w_int, sc, ze = make_quant(rng, din, dout, g, nb)
    a = jnp.array(rng.integers(-1, 2, (din, r)), jnp.float32)
    b = jnp.array(rng.integers(-1, 2, (r, dout)), jnp.float32)

    def loss(a, b):
        w, z = ternary_apply(a, b, jnp.array(w_int), jnp.array(sc),
                             jnp.array(ze), jnp.float32(0.75 * r), r, nb, True)
        return jnp.sum(w ** 2) * 1e-3 + jnp.sum(z ** 2)

    ga, gb = jax.grad(loss, argnums=(0, 1))(a, b)
    assert bool(jnp.isfinite(ga).all() and jnp.isfinite(gb).all())
    assert float(jnp.abs(ga).max()) > 0.0
    assert float(jnp.abs(gb).max()) > 0.0


@given(st.integers(0, 2 ** 31), st.sampled_from([2, 3, 4]))
@settings(**SETTINGS)
def test_boundary_overflow_prevention(seed, n_bits):
    """Paper Fig. 3: boundary values (e.g. 0 and 2^N−1) must not over/underflow."""
    rng = np.random.default_rng(seed)
    din, dout, g, r = 16, 64, 2, 4
    # all-boundary grid: half at 0, half at max
    w_int = np.where(rng.random((din, dout)) < 0.5, 0.0,
                     float(2 ** n_bits - 1)).astype(np.float32)
    sc = np.full((g, dout), 0.05, np.float32)
    ze = np.zeros((g, dout), np.float32)
    # adapters that push hard in both directions
    a = rng.integers(-1, 2, (din, r)).astype(np.float32)
    b = rng.integers(-1, 2, (r, dout)).astype(np.float32)
    w1, _ = ternary_apply_fwd_pallas(
        jnp.array(a), jnp.array(b), jnp.array(w_int), jnp.array(sc),
        jnp.array(ze), jnp.float32(0.5 * r), r, n_bits)
    w1 = np.asarray(w1)
    assert w1.min() >= 0.0
    assert w1.max() <= 2 ** n_bits - 1
