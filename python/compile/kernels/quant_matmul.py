"""L1 Pallas kernel: fused group-wise dequantize + matmul.

The inference hot-spot of every QAF method here is ``y = x @ (s·W_int + z)``.
A naive implementation materialises the dequantized ``(Din, Dout)`` f32
matrix in HBM; the paper's GPU kernels (GPTQModel's TritonV2QuantLinear)
instead dequantize *tiles* in shared memory on the way into the MAC loop.

TPU/Pallas mapping (DESIGN.md §Hardware-Adaptation): the grid walks
``(M/bm, Dout/bn, Din/bk)`` with ``bk == group_size`` so each k-step brings
exactly one quantization group's ``(bk, bn)`` integer tile plus its
``(1, bn)`` scale/zero rows into VMEM, dequantizes on the VPU, and feeds the
MXU-shaped ``(bm, bk) @ (bk, bn)`` MAC — one HBM read per tile, no
full-size dequantized intermediate.

``interpret=True`` always: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the same schedule to plain HLO
(a while-loop over the grid), keeping numerics identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, w_ref, s_ref, z_ref, o_ref):
    """One (bm, bn) output tile accumulated over the k grid axis.

    k is the innermost grid dimension; the output block index map ignores k
    so the same VMEM tile stays resident while we accumulate.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w_tile = s_ref[...] * w_ref[...] + z_ref[...]  # dequant in-register
    o_ref[...] += jnp.dot(x_ref[...], w_tile, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def quant_matmul(x, w_int, scales, zeros, *, block_m=16, block_n=64):
    """``y = x @ dequant(w_int, scales, zeros)`` via the fused Pallas kernel.

    x: (M, Din) f32; w_int: (Din, Dout) f32-coded ints;
    scales/zeros: (G, Dout) with Din = G·gs. Block sizes must divide the
    corresponding dims; the k-block is pinned to the group size so the
    scale/zero index map is exact (one group per k-step).
    """
    m, din = x.shape
    dout = w_int.shape[1]
    g = scales.shape[0]
    gs = din // g
    bm = min(block_m, m)
    bn = min(block_n, dout)
    assert m % bm == 0 and dout % bn == 0 and din % gs == 0

    grid = (m // bm, dout // bn, g)
    return pl.pallas_call(
        _qmm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, dout), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, gs), lambda i, j, k: (i, k)),   # x tile
            pl.BlockSpec((gs, bn), lambda i, j, k: (k, j)),   # W_int tile
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),    # scale row
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),    # zero row
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=True,
    )(x, w_int, scales, zeros)
