"""L1 Pallas kernel: fused ternary adaptation (paper Eqs. 3–5, Appendix A).

The paper fuses, in one Triton kernel: the auxiliary-matrix tile
``ΔW = A_T @ B_T``, the ternary map ``Ŵ``, the boundary (overflow) masks and
the integer update. We reproduce that fusion for the TPU memory hierarchy:
the grid walks ``(G, Dout/bn)`` — one program per (quantization-group ×
output-tile) — so each program:

  1. loads the group's ``(gs, r)`` slice of A_T and ``(r, bn)`` slice of B_T
     into VMEM and forms the ``(gs, bn)`` ΔW tile on the MXU;
  2. applies the threshold ω on the VPU to get the ternary tile Ŵ;
  3. clips ``W_int + Ŵ`` against the grid bounds (the paper's boundary
     check — a free VPU clamp here, vs. packed boolean masks on GPU);
  4. row-reduces the offset tile ``W̃ = ΔW − ωŴ`` to the per-group partial
     sums that become the offset factor μ.

One HBM read of A/B/W_int, one write of W_int' and the μ row — the same
one-pass property the Triton kernel gets from shared memory.

The autodiff wrapper :func:`ternary_apply` attaches the straight-through
backward (see ``ref.ternary_ste_bwd_ref``); t-SignSGD consumes only the
sign and relative magnitude of these gradients.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ternary_kernel(a_ref, b_ref, w_ref, omega_ref, bound_ref,
                    w_out_ref, musum_ref):
    delta = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)
    omega = omega_ref[0]
    bound = bound_ref[0]
    w_hat = jnp.sign(delta) * (jnp.abs(delta) > omega).astype(jnp.float32)
    w_out_ref[...] = jnp.clip(w_ref[...] + w_hat, 0.0, bound)
    w_tilde = delta - omega * w_hat
    musum_ref[...] = jnp.sum(w_tilde, axis=0, keepdims=True)


def ternary_apply_fwd_pallas(a_t, b_t, w_int, scales, zeros, omega, rank,
                             n_bits, *, block_n=64):
    """Fused forward: returns ``(w_int', zeros')`` like ``ternary_apply_ref``."""
    din, r = a_t.shape
    dout = w_int.shape[1]
    g = scales.shape[0]
    gs = din // g
    bn = min(block_n, dout)
    assert dout % bn == 0

    omega_arr = jnp.full((1,), omega, jnp.float32)
    bound_arr = jnp.full((1,), float(2 ** n_bits - 1), jnp.float32)
    grid = (g, dout // bn)
    w_new, musum = pl.pallas_call(
        _ternary_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((din, dout), jnp.float32),
            jax.ShapeDtypeStruct((g, dout), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((gs, r), lambda i, j: (i, 0)),    # A_T group rows
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),    # B_T column tile
            pl.BlockSpec((gs, bn), lambda i, j: (i, j)),   # W_int tile
            pl.BlockSpec((1,), lambda i, j: (0,)),         # ω
            pl.BlockSpec((1,), lambda i, j: (0,)),         # grid bound
        ],
        out_specs=(
            pl.BlockSpec((gs, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (i, j)),
        ),
        interpret=True,
    )(a_t, b_t, w_int, omega_arr, bound_arr)
    zeros_new = zeros + scales * musum / (rank * gs)
    return w_new, zeros_new


# omega is a *traced* scalar (swept by the L3 harness without re-lowering),
# so it is a differentiable argument that receives a zero cotangent; only
# rank / n_bits / use_pallas are static.
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def ternary_apply(a_t, b_t, w_int, scales, zeros, omega, rank, n_bits,
                  use_pallas=True):
    """Differentiable ternary adaptation. Forward is discrete (exactly the
    merge map); backward is the straight-through surrogate. Gradients flow
    only to ``a_t``/``b_t`` — the base quantized tensors are frozen."""
    if use_pallas:
        return ternary_apply_fwd_pallas(a_t, b_t, w_int, scales, zeros,
                                        omega, rank, n_bits)
    return ref.ternary_apply_ref(a_t, b_t, w_int, scales, zeros,
                                 omega, rank, n_bits)


def _ternary_fwd(a_t, b_t, w_int, scales, zeros, omega, rank, n_bits,
                 use_pallas):
    out = ternary_apply(a_t, b_t, w_int, scales, zeros, omega, rank, n_bits,
                        use_pallas)
    return out, (a_t, b_t, w_int, scales, zeros, omega)


def _ternary_bwd(rank, n_bits, use_pallas, res, cts):
    a_t, b_t, w_int, scales, zeros, omega = res
    ct_w, ct_z = cts
    d_a, d_b = ref.ternary_ste_bwd_ref(a_t, b_t, w_int, scales, zeros,
                                       omega, rank, n_bits, ct_w, ct_z)
    zero = lambda x: jnp.zeros_like(x)
    return (d_a, d_b, zero(w_int), zero(scales), zero(zeros),
            jnp.zeros_like(omega))


ternary_apply.defvjp(_ternary_fwd, _ternary_bwd)
