"""Pure-jnp reference oracles for the Pallas kernels.

These are the *semantic ground truth* for the three L1 kernels:

* :func:`quant_matmul_ref` — group-wise asymmetric dequant + matmul
  (paper Eq. 2 applied inside the forward pass).
* :func:`ternary_apply_ref` — ternary adaptation: auxiliary matrix
  ``ΔW = A_T @ B_T``, ternary map ``Ŵ`` (Eq. 3), offset matrix/factor
  (Eq. 4) and the boundary-checked in-grid integer update used both during
  fine-tuning and for the lossless merge (Eq. 5).
* :func:`tsign_update_ref` — the t-SignSGD ternary update (Eq. 6).

pytest + hypothesis assert the Pallas kernels match these bit-for-bit (they
are the same f32 ops in a different schedule), and the Rust host-side
implementations are validated against golden vectors generated from this
file, so all three layers agree on the semantics.

Convention: quantized weights travel as *f32 tensors holding integer
values* (the PJRT CPU path has no native sub-byte dtypes, mirroring the
paper's bf16-simulated ternary adapters, Appendix A); ``scales``/``zeros``
are ``(G, Dout)`` with groups along the input dimension, ``Din = G * gs``.
"""

import jax.numpy as jnp


def dequant_ref(w_int, scales, zeros):
    """Dequantize group-quantized weights: ``W_q = s * W_int + z`` (Eq. 2).

    w_int: (Din, Dout) f32-coded integers; scales/zeros: (G, Dout).
    """
    din = w_int.shape[0]
    g = scales.shape[0]
    gs = din // g
    s = jnp.repeat(scales, gs, axis=0)
    z = jnp.repeat(zeros, gs, axis=0)
    return s * w_int + z


def quant_matmul_ref(x, w_int, scales, zeros):
    """``y = x @ dequant(W_int)`` — the quantized-linear forward."""
    return x @ dequant_ref(w_int, scales, zeros)


def ternary_map_ref(delta_w, omega):
    """Eq. 3: ``Ŵ = sign(ΔW) · 1[|ΔW| > ω]``."""
    return jnp.sign(delta_w) * (jnp.abs(delta_w) > omega).astype(delta_w.dtype)


def ternary_apply_ref(a_t, b_t, w_int, scales, zeros, omega, rank, n_bits):
    """Full ternary adaptation (Eqs. 3–5), per-group offset granularity.

    Returns ``(w_int', zeros')`` — the adjusted integer grid (boundary
    checked against ``[0, 2^N - 1]``) and the offset-absorbed zero factors.
    Used by the training forward *and* the merge: they are the same map,
    which is exactly why the merge is lossless.
    """
    din, dout = w_int.shape
    g = scales.shape[0]
    gs = din // g
    delta_w = a_t @ b_t                                  # (Din, Dout), ints in [-r, r]
    w_hat = ternary_map_ref(delta_w, omega)              # (Din, Dout) ∈ {-1,0,1}
    w_int_new = jnp.clip(w_int + w_hat, 0.0, float(2 ** n_bits - 1))
    w_tilde = delta_w - omega * w_hat                    # Eq. 4 offset matrix
    # Per-group mean (Eq. 4 at per-group granularity — matches the group-wise
    # quantizer; the paper notes μ "can be performed at different granularity").
    mu = w_tilde.reshape(g, gs, dout).sum(axis=1) / (rank * gs)
    zeros_new = zeros + scales * mu                      # Eq. 5: z' = z + s·μ
    return w_int_new, zeros_new


def ternary_ste_bwd_ref(a_t, b_t, w_int, scales, zeros, omega, rank, n_bits,
                        ct_w_int, ct_zeros):
    """Straight-through backward used by the custom_vjp (our interpretation;
    the paper trains ternary adapters with gradients but does not spell out
    the surrogate — DESIGN.md §3 documents this choice).

    Surrogates: ``dŴ/dΔW ≈ 1/r`` gated by the boundary (clip) mask, plus the
    exact linear part of the offset path with Ŵ treated via the same slope.
    """
    din, dout = w_int.shape
    g = scales.shape[0]
    gs = din // g
    delta_w = a_t @ b_t
    w_hat = ternary_map_ref(delta_w, omega)
    inside = (w_int + w_hat >= 0.0) & (w_int + w_hat <= float(2 ** n_bits - 1))
    d_from_wint = ct_w_int * inside.astype(ct_w_int.dtype) / rank
    # z' = z + s * sum_group(ΔW − ωŴ)/(r·gs): dΔW = s·ct_z·(1 − ω/r)/(r·gs)
    d_from_z = jnp.repeat(ct_zeros * scales, gs, axis=0) * (1.0 - omega / rank) / (rank * gs)
    d_delta = d_from_wint + d_from_z
    d_a = d_delta @ b_t.T
    d_b = a_t.T @ d_delta
    return d_a, d_b


def sigma_threshold_ref(grad, keep_frac, tau=1e-9):
    """Dynamic percentile threshold σ_t: keep the top ``keep_frac`` of |g|."""
    q = jnp.clip(1.0 - keep_frac, 0.0, 1.0)
    sigma = jnp.quantile(jnp.abs(grad).reshape(-1), q)
    return jnp.maximum(sigma, tau)


def tsign_update_ref(a_t, grad, keep_frac, tau=1e-9):
    """Eq. 6: ``A ← clip(A − sign(g)·1[|g| > max(τ, σ_t)], −1, 1)``."""
    thr = sigma_threshold_ref(grad, keep_frac, tau)
    upd = jnp.sign(grad) * (jnp.abs(grad) > thr).astype(grad.dtype)
    return jnp.clip(a_t - upd, -1.0, 1.0)


def qalora_pool_ref(x, group_size):
    """QA-LoRA input pooling: average x over each quantization group."""
    *lead, din = x.shape
    g = din // group_size
    return x.reshape(*lead, g, group_size).mean(axis=-1)


def lora_merge_requant_ref(w_int, scales, zeros, a, b, alpha, rank, n_bits):
    """The *lossy* LoRA merge the paper criticises: add the fp update to the
    dequantized weights and re-quantize onto the existing per-group grid.
    Returned alongside the exact fp result so tests can measure the
    reintroduced quantization error (challenge #2 in the paper's intro)."""
    w_fp = dequant_ref(w_int, scales, zeros) + (alpha / rank) * (a @ b)
    din = w_int.shape[0]
    g = scales.shape[0]
    gs = din // g
    s = jnp.repeat(scales, gs, axis=0)
    z = jnp.repeat(zeros, gs, axis=0)
    w_int_new = jnp.clip(jnp.round((w_fp - z) / s), 0.0, float(2 ** n_bits - 1))
    return w_int_new, w_fp
