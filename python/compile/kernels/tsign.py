"""L1 Pallas kernel: the t-SignSGD ternary update (paper Eq. 6).

The update is element-wise once the dynamic percentile threshold σ_t is
known: ``A ← clip(A − sign(g)·1[|g| > max(τ, σ_t)], −1, 1)``. σ_t is a
global order statistic (top-``keep_frac`` of |g|), which on TPU is a
sort/reduce best left to XLA's native ``sort`` — so the threshold is
computed with ``jnp.quantile`` and broadcast into the kernel, and the
Pallas kernel fuses the gate + sign step + clip over VMEM tiles.

This mirrors the paper's Appendix A split: the percentile is a framework
op; the hot element-wise path is the custom kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _tsign_kernel(a_ref, g_ref, thr_ref, o_ref):
    thr = thr_ref[0]
    g = g_ref[...]
    upd = jnp.sign(g) * (jnp.abs(g) > thr).astype(jnp.float32)
    o_ref[...] = jnp.clip(a_ref[...] - upd, -1.0, 1.0)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def tsign_update(a_t, grad, keep_frac, tau=1e-9, *, block_rows=64):
    """Apply one t-SignSGD step to a ternary adapter tensor.

    ``keep_frac`` is a traced scalar (the L3 Rust scheduler feeds the
    linearly-decaying 5% → 0.1% → 0.01% schedule per step).
    """
    thr = ref.sigma_threshold_ref(grad, keep_frac, tau)
    rows, cols = a_t.shape
    br = min(block_rows, rows)
    assert rows % br == 0
    thr_arr = jnp.reshape(thr, (1,))
    return pl.pallas_call(
        _tsign_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        interpret=True,
    )(a_t, grad, thr_arr)
