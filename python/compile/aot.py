"""AOT lowering: JAX graphs → HLO *text* artifacts + a JSON manifest.

Run once at build time (``make artifacts``); the Rust runtime loads the
text through ``HloModuleProto::from_text_file`` and never touches Python.

HLO **text** — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

The manifest (``artifacts/manifest.json``) records, for every artifact, the
exact ordered input names/shapes and output names/shapes, so the Rust
marshaller is driven by data rather than by a parallel hand-maintained
convention. A content hash of ``python/compile`` makes re-runs no-ops when
nothing changed.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import BITS, CONFIGS, SERVE_BUCKETS, STEP_BATCH
from .kernels.quant_matmul import quant_matmul
from .kernels.ternary import ternary_apply_fwd_pallas
from .kernels.tsign import tsign_update


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = []

    def lower(self, name, fn, inputs, outputs, meta):
        """Lower ``fn(*inputs)`` and record manifest entry.

        inputs: list of (name, shape); outputs: list of (name, shape).
        """
        specs = [_spec(s) for _, s in inputs]
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        entry = {
            "name": name,
            "file": fname,
            "inputs": [{"name": n, "shape": list(s)} for n, s in inputs],
            "outputs": [{"name": n, "shape": list(s)} for n, s in outputs],
        }
        entry.update(meta)
        self.manifest.append(entry)
        print(f"  lowered {name}: {len(inputs)} in / {len(outputs)} out, "
              f"{len(text) // 1024} KiB")

    def save_manifest(self, extra):
        data = {"artifacts": self.manifest}
        data.update(extra)
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(data, f, indent=1)


# ---------------------------------------------------------------------------
# Artifact definitions


def batch_shapes(b, t):
    return [("tokens", (b, t)), ("targets", (b, t)), ("mask", (b, t))]


def lower_kernels(bld: Builder):
    """Standalone Pallas-kernel artifacts: prove the L1 kernels lower into
    HLO the Rust PJRT client can execute (validated in rust/tests)."""
    din, dout, g, r = 64, 128, 4, 8
    gs = din // g
    m = 16

    bld.lower(
        "kernel_qmm", lambda x, w, s, z: (quant_matmul(x, w, s, z),),
        [("x", (m, din)), ("w_int", (din, dout)), ("scales", (g, dout)),
         ("zeros", (g, dout))],
        [("y", (m, dout))],
        {"kind": "kernel"},
    )
    bld.lower(
        "kernel_ternary",
        lambda a, b, w, s, z, om: ternary_apply_fwd_pallas(
            a, b, w, s, z, om.reshape(()), r, 4),
        [("a_t", (din, r)), ("b_t", (r, dout)), ("w_int", (din, dout)),
         ("scales", (g, dout)), ("zeros", (g, dout)), ("omega", (1,))],
        [("w_int_new", (din, dout)), ("zeros_new", (g, dout))],
        {"kind": "kernel"},
    )
    bld.lower(
        "kernel_tsign",
        lambda a, grad, kf: (tsign_update(a, grad, kf.reshape(())),),
        [("a_t", (din, r)), ("grad", (din, r)), ("keep_frac", (1,))],
        [("a_new", (din, r))],
        {"kind": "kernel"},
    )


def lower_config(bld: Builder, cfg_name: str, use_pallas: bool):
    cfg = CONFIGS[cfg_name]
    b = STEP_BATCH[cfg_name]
    t = cfg.seq_len

    froz = model.frozen_shapes(cfg, "lota")  # same frozen set for all QAF
    fnames = model.sorted_names(froz)
    fp_shapes = model.frozen_shapes(cfg, "fp")
    fpnames = model.sorted_names(fp_shapes)

    # --- pretraining step (full precision, AdamW) ---
    fn, names, outs = model.make_pretrain_fn(cfg)
    ins = ([(n, fp_shapes[n]) for n in names]
           + [(f"m_{n}", fp_shapes[n]) for n in names]
           + [(f"v_{n}", fp_shapes[n]) for n in names]
           + batch_shapes(b, t) + [("lr", (1,)), ("step", (1,))])
    outshapes = ([("loss", (1,))] + [(n, fp_shapes[n]) for n in names]
                 + [(f"m_{n}", fp_shapes[n]) for n in names]
                 + [(f"v_{n}", fp_shapes[n]) for n in names])
    bld.lower(f"pretrain_step_{cfg_name}", fn, ins, outshapes,
              {"kind": "pretrain_step", "cfg": cfg_name, "batch": b})

    # --- activation capture for GPTQ calibration ---
    afn, anames_, aouts = model.make_acts_fn(cfg)
    d, ff, L = cfg.d_model, cfg.d_ff, cfg.n_layers
    bld.lower(
        f"acts_fp_{cfg_name}", afn,
        [(n, fp_shapes[n]) for n in anames_] + [("tokens", (b, t))],
        [("xn1", (L, b, t, d)), ("attn_o", (L, b, t, d)),
         ("xn2", (L, b, t, d)), ("h_mid", (L, b, t, ff))],
        {"kind": "acts", "cfg": cfg_name, "batch": b},
    )

    # --- fp forward (16-bit baseline rows of Table 1) ---
    fwd, names, _ = model.make_fwd_fn(cfg, "fp", 4)
    bld.lower(
        f"fwd_fp_{cfg_name}", fwd,
        [(n, fp_shapes[n]) for n in names] + [("tokens", (b, t))],
        [("logits", (b, t, cfg.vocab))],
        {"kind": "fwd", "cfg": cfg_name, "method": "fp", "batch": b},
    )

    # --- QAF training steps ---
    for bits in BITS:
        fn, fn_f, fn_a, extra, outs = model.make_step_fn(cfg, "lota", bits,
                                                         use_pallas)
        adap = model.adapter_shapes(cfg, "lota")
        ins = ([(n, froz[n]) for n in fn_f] + [(n, adap[n]) for n in fn_a]
               + batch_shapes(b, t) + [("omega", (1,)), ("keep_frac", (1,))])
        outshapes = [("loss", (1,))] + [(n, adap[n]) for n in fn_a]
        bld.lower(f"step_lota_{cfg_name}_w{bits}", fn, ins, outshapes,
                  {"kind": "step", "cfg": cfg_name, "method": "lota",
                   "n_bits": bits, "batch": b})

    for method in ("lora", "qalora"):
        fn, fn_f, fn_a, extra, outs = model.make_step_fn(cfg, method, 4)
        adap = model.adapter_shapes(cfg, method)
        ins = ([(n, froz[n]) for n in fn_f] + [(n, adap[n]) for n in fn_a]
               + [(f"m_{n}", adap[n]) for n in fn_a]
               + [(f"v_{n}", adap[n]) for n in fn_a]
               + batch_shapes(b, t) + [("lr", (1,)), ("step", (1,))])
        outshapes = ([("loss", (1,))] + [(n, adap[n]) for n in fn_a]
                     + [(f"m_{n}", adap[n]) for n in fn_a]
                     + [(f"v_{n}", adap[n]) for n in fn_a])
        bld.lower(f"step_{method}_{cfg_name}", fn, ins, outshapes,
                  {"kind": "step", "cfg": cfg_name, "method": method,
                   "batch": b})

    # --- evaluation / serving forwards ---
    def lower_fwd(method, batch, suffix, n_bits=4):
        fwd, names, needs_omega = model.make_fwd_fn(cfg, method, n_bits,
                                                    use_pallas and method == "lota")
        adap = model.adapter_shapes(cfg, method)
        allsh = {**froz, **adap}
        ins = [(n, allsh[n]) for n in names]
        if needs_omega:
            ins += [("omega", (1,))]
        ins += [("tokens", (batch, t))]
        bld.lower(
            f"fwd_{method}_{cfg_name}{suffix}", fwd, ins,
            [("logits", (batch, t, cfg.vocab))],
            {"kind": "fwd", "cfg": cfg_name, "method": method,
             "batch": batch, "n_bits": n_bits},
        )

    for bits in BITS:
        lower_fwd("lota", b, f"_w{bits}", bits)
    for method in ("lora", "qalora", "merged"):
        lower_fwd(method, b, "")

    # serving buckets: merged (low-bit path) vs lora (quant + 16-bit path)
    for bucket in SERVE_BUCKETS[cfg_name]:
        if bucket == b:
            continue  # already lowered above for merged/lora
        lower_fwd("merged", bucket, f"_b{bucket}")
        lower_fwd("lora", bucket, f"_b{bucket}")


# ---------------------------------------------------------------------------
# Staleness


def input_hash() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(f.encode())
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small",
                    help="comma-separated model configs to lower")
    ap.add_argument("--pallas", action="store_true", default=True,
                    help="use the Pallas kernels inside the lota graphs")
    ap.add_argument("--no-pallas", dest="pallas", action="store_false")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    hash_path = os.path.join(args.out, ".input_hash")
    manifest_path = os.path.join(args.out, "manifest.json")
    cur = input_hash() + f"|cfgs={args.configs}|pallas={args.pallas}"
    if not args.force and os.path.exists(hash_path) and os.path.exists(manifest_path):
        with open(hash_path) as f:
            if f.read().strip() == cur:
                print("artifacts up to date (input hash match); skipping")
                return

    bld = Builder(args.out)
    print("lowering kernel artifacts ...")
    lower_kernels(bld)
    for cfg_name in args.configs.split(","):
        cfg_name = cfg_name.strip()
        if not cfg_name:
            continue
        print(f"lowering {cfg_name} graphs ...")
        lower_config(bld, cfg_name, args.pallas)

    from . import golden
    golden.generate(os.path.join(args.out, "golden"))

    bld.save_manifest({
        "configs": {n: vars(c) for n, c in CONFIGS.items()},
        "step_batch": STEP_BATCH,
        "serve_buckets": {k: list(v) for k, v in SERVE_BUCKETS.items()},
    })
    with open(hash_path, "w") as f:
        f.write(cur)
    print(f"wrote {len(bld.manifest)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
