"""L2: the JAX compute graphs — transformer fwd/bwd over group-quantized
weights with LoTA / LoRA / QA-LoRA adapters, plus full in-graph training
steps (t-SignSGD for LoTA, AdamW for the baselines and for pretraining).

Everything here is build-time only: ``aot.py`` lowers these functions once
to HLO text and the Rust coordinator executes them through PJRT. Parameters
cross the boundary as a flat, name-sorted list of f32 arrays; each artifact
ships a JSON manifest recording that order (``aot.py``), which the Rust
marshaller follows — nothing is positional by convention alone.

Model: GPT-style pre-norm decoder. The six per-block matrices
(wq/wk/wv/wo/w_up/w_down) are group-quantized and adapted; embeddings,
position table, layer norms and the LM head stay f32 and frozen during QAF.
Layer parameters are stacked on a leading ``L`` axis and the blocks run
under ``lax.scan`` so the lowered HLO stays compact at any depth.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.ternary import ternary_apply

# ---------------------------------------------------------------------------
# Parameter inventory


def slot_dims(cfg: ModelConfig):
    """The six quantized linear slots: name -> (Din, Dout)."""
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
        "w_up": (d, ff), "w_down": (ff, d),
    }


def fp_shared_shapes(cfg: ModelConfig):
    """Frozen f32 tensors shared by every method (sorted-name order)."""
    L, d, V, T = cfg.n_layers, cfg.d_model, cfg.vocab, cfg.seq_len
    return {
        "embed": (V, d),
        "head": (d, V),
        "ln1_b": (L, d), "ln1_w": (L, d),
        "ln2_b": (L, d), "ln2_w": (L, d),
        "lnf_b": (d,), "lnf_w": (d,),
        "pos": (T, d),
    }


def fp_weight_shapes(cfg: ModelConfig):
    """Full-precision per-slot weights (pretraining only)."""
    L = cfg.n_layers
    return {f"w_{s}": (L, din, dout) for s, (din, dout) in slot_dims(cfg).items()}


def quant_shapes(cfg: ModelConfig):
    """Quantized representation of each slot: ints + per-group scale/zero."""
    L, gs = cfg.n_layers, cfg.group_size
    out = {}
    for s, (din, dout) in slot_dims(cfg).items():
        g = din // gs
        out[f"q_{s}_int"] = (L, din, dout)
        out[f"q_{s}_s"] = (L, g, dout)
        out[f"q_{s}_z"] = (L, g, dout)
    return out


def adapter_shapes(cfg: ModelConfig, method: str):
    """Trainable adapter tensors for a method (empty for merged/fp)."""
    L, r, gs = cfg.n_layers, cfg.rank, cfg.group_size
    out = {}
    for s, (din, dout) in slot_dims(cfg).items():
        if method == "lota":
            out[f"ta_{s}_a"] = (L, din, r)
            out[f"ta_{s}_b"] = (L, r, dout)
        elif method == "lora":
            out[f"lo_{s}_a"] = (L, din, r)
            out[f"lo_{s}_b"] = (L, r, dout)
        elif method == "qalora":
            out[f"qa_{s}_a"] = (L, din // gs, r)
            out[f"qa_{s}_b"] = (L, r, dout)
    return out


def frozen_shapes(cfg: ModelConfig, method: str):
    """Non-trainable inputs for a QAF method's graphs."""
    if method == "fp":
        return {**fp_shared_shapes(cfg), **fp_weight_shapes(cfg)}
    return {**fp_shared_shapes(cfg), **quant_shapes(cfg)}


def sorted_names(shapes: dict) -> list:
    return sorted(shapes.keys())


# ---------------------------------------------------------------------------
# Transformer forward


def _layernorm(x, w, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * w + b


def _linear(x, layer, slot, cfg: ModelConfig, method: str, omega, use_pallas):
    """Method-dependent forward of one quantized linear.

    ``x``: (B, T, Din); ``layer``: the dict of this block's (unstacked)
    tensors produced by the scan body.
    """
    b, t, din = x.shape
    x2 = x.reshape(b * t, din)
    if method == "fp":
        y2 = x2 @ layer[f"w_{slot}"]
        return y2.reshape(b, t, -1)

    w_int = layer[f"q_{slot}_int"]
    sc = layer[f"q_{slot}_s"]
    ze = layer[f"q_{slot}_z"]

    if method == "lota":
        # In-grid ternary adjustment (Eqs. 3–5) — the same map as the merge,
        # so training-forward ≡ merged-forward bit-for-bit.
        omega_arr = jnp.asarray(omega, jnp.float32)
        w_int, ze = ternary_apply(
            layer[f"ta_{slot}_a"], layer[f"ta_{slot}_b"],
            w_int, sc, ze, omega_arr, cfg.rank, layer["__n_bits__"], use_pallas,
        )
        y2 = x2 @ ref.dequant_ref(w_int, sc, ze)
    elif method == "lora":
        y2 = x2 @ ref.dequant_ref(w_int, sc, ze)
        alpha = 2.0 * cfg.rank
        y2 = y2 + (alpha / cfg.rank) * (x2 @ layer[f"lo_{slot}_a"]) @ layer[f"lo_{slot}_b"]
    elif method == "qalora":
        y2 = x2 @ ref.dequant_ref(w_int, sc, ze)
        alpha = 2.0 * cfg.rank
        pooled = ref.qalora_pool_ref(x2, cfg.group_size)
        y2 = y2 + (alpha / cfg.rank) * (pooled @ layer[f"qa_{slot}_a"]) @ layer[f"qa_{slot}_b"]
    else:  # "merged" / plain GPTQ forward
        y2 = x2 @ ref.dequant_ref(w_int, sc, ze)
    return y2.reshape(b, t, -1)


def _block(x, layer, cfg: ModelConfig, method: str, omega, use_pallas):
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xn = _layernorm(x, layer["ln1_w"], layer["ln1_b"])
    q = _linear(xn, layer, "wq", cfg, method, omega, use_pallas)
    k = _linear(xn, layer, "wk", cfg, method, omega, use_pallas)
    v = _linear(xn, layer, "wv", cfg, method, omega, use_pallas)
    q = q.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
    mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    att = jnp.where(mask == 0.0, -1e30, att)
    att = jax.nn.softmax(att, axis=-1)
    o = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    x = x + _linear(o, layer, "wo", cfg, method, omega, use_pallas)
    xn = _layernorm(x, layer["ln2_w"], layer["ln2_b"])
    hmid = jax.nn.gelu(_linear(xn, layer, "w_up", cfg, method, omega, use_pallas))
    x = x + _linear(hmid, layer, "w_down", cfg, method, omega, use_pallas)
    return x


_PER_LAYER_PREFIXES = ("ln1_", "ln2_", "q_", "ta_", "lo_", "qa_", "w_")


def forward(params: dict, tokens_f32, cfg: ModelConfig, method: str,
            omega=0.0, n_bits=4, use_pallas=False):
    """Logits (B, T, V) for a batch of f32-coded token ids."""
    tokens = tokens_f32.astype(jnp.int32)
    b, t = tokens.shape
    x = params["embed"][tokens] + params["pos"][None, :t, :]

    stacked = {k: v for k, v in params.items()
               if k.startswith(_PER_LAYER_PREFIXES)}

    def body(carry, layer):
        layer = dict(layer)
        layer["__n_bits__"] = n_bits
        return _block(carry, layer, cfg, method, omega, use_pallas), None

    x, _ = jax.lax.scan(body, x, stacked)
    x = _layernorm(x, params["lnf_w"], params["lnf_b"])
    return x @ params["head"]


def loss_fn(params, batch, cfg, method, omega=0.0, n_bits=4, use_pallas=False):
    """Masked next-token cross-entropy. ``batch`` = (tokens, targets, mask),
    all f32-coded (B, T)."""
    tokens, targets, mask = batch
    logits = forward(params, tokens, cfg, method, omega, n_bits, use_pallas)
    tgt = targets.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Optimizers (in-graph)


def adamw_update(p, g, m, v, lr, step, beta1=0.9, beta2=0.999, eps=1e-8,
                 weight_decay=0.0):
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1 ** step)
    vhat = v / (1.0 - beta2 ** step)
    p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
    return p, m, v


def clip_global_norm(grads: dict, max_norm: float):
    """Paper setup: max gradient norm 0.3 for the AdamW baselines."""
    total = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(total, 1e-12))
    return {k: g * scale for k, g in grads.items()}


def tsign_update_stacked(a, g, keep_frac, tau=1e-9):
    """t-SignSGD (Eq. 6) on a layer-stacked adapter tensor: the percentile
    threshold σ_t is per (layer, adapter-matrix), matching the paper's
    per-matrix updates."""
    L = a.shape[0]
    absg = jnp.abs(g).reshape(L, -1)
    q = jnp.clip(1.0 - keep_frac, 0.0, 1.0)
    sigma = jnp.quantile(absg, q, axis=1)
    thr = jnp.maximum(sigma, tau).reshape((L,) + (1,) * (a.ndim - 1))
    upd = jnp.sign(g) * (jnp.abs(g) > thr).astype(g.dtype)
    return jnp.clip(a - upd, -1.0, 1.0)


# ---------------------------------------------------------------------------
# Lowered entry points (flat-argument functions; see aot.py manifests)


def make_fwd_fn(cfg: ModelConfig, method: str, n_bits: int, use_pallas=False):
    """fwd_{method}: frozen+adapters (sorted) + [omega?] + tokens → logits."""
    froz = frozen_shapes(cfg, method)
    adap = adapter_shapes(cfg, method)
    names = sorted_names({**froz, **adap})
    needs_omega = method == "lota"

    def fn(*args):
        arrs = list(args)
        params = {n: arrs[i] for i, n in enumerate(names)}
        rest = arrs[len(names):]
        if needs_omega:
            omega, tokens = rest
            omega = omega.reshape(())
        else:
            (tokens,) = rest
            omega = 0.0
        return (forward(params, tokens, cfg, method, omega, n_bits, use_pallas),)

    return fn, names, needs_omega


def make_step_fn(cfg: ModelConfig, method: str, n_bits: int, use_pallas=False):
    """step_{method}: one full training step (loss + backward + update).

    Flat inputs: frozen (sorted) + adapters (sorted) + opt-state + batch +
    hyper scalars. Outputs: (loss, *updated-adapters[, *updated-opt-state]).
    """
    froz = frozen_shapes(cfg, method)
    adap = adapter_shapes(cfg, method)
    fnames = sorted_names(froz)
    anames = sorted_names(adap)

    if method == "lota":
        def fn(*args):
            arrs = list(args)
            i = 0
            frozen = {n: arrs[i + j] for j, n in enumerate(fnames)}; i += len(fnames)
            adapters = {n: arrs[i + j] for j, n in enumerate(anames)}; i += len(anames)
            tokens, targets, mask, omega, keep_frac = arrs[i:i + 5]
            omega = omega.reshape(())
            keep_frac = keep_frac.reshape(())

            def loss_of(ad):
                return loss_fn({**frozen, **ad}, (tokens, targets, mask),
                               cfg, "lota", omega, n_bits, use_pallas)

            loss, grads = jax.value_and_grad(loss_of)(adapters)
            new = {n: tsign_update_stacked(adapters[n], grads[n], keep_frac)
                   for n in anames}
            return (loss.reshape(1),) + tuple(new[n] for n in anames)

        extra = ["tokens", "targets", "mask", "omega", "keep_frac"]
        outs = ["loss"] + anames
        return fn, fnames, anames, extra, outs

    # LoRA / QA-LoRA: AdamW on adapters (paper: paged AdamW, grad-norm 0.3).
    def fn(*args):
        arrs = list(args)
        i = 0
        frozen = {n: arrs[i + j] for j, n in enumerate(fnames)}; i += len(fnames)
        adapters = {n: arrs[i + j] for j, n in enumerate(anames)}; i += len(anames)
        m = {n: arrs[i + j] for j, n in enumerate(anames)}; i += len(anames)
        v = {n: arrs[i + j] for j, n in enumerate(anames)}; i += len(anames)
        tokens, targets, mask, lr, step = arrs[i:i + 5]
        lr = lr.reshape(())
        step = step.reshape(())

        def loss_of(ad):
            return loss_fn({**frozen, **ad}, (tokens, targets, mask),
                           cfg, method, 0.0, n_bits, use_pallas)

        loss, grads = jax.value_and_grad(loss_of)(adapters)
        grads = clip_global_norm(grads, 0.3)
        new_p, new_m, new_v = {}, {}, {}
        for n in anames:
            new_p[n], new_m[n], new_v[n] = adamw_update(
                adapters[n], grads[n], m[n], v[n], lr, step)
        out = (loss.reshape(1),)
        out += tuple(new_p[n] for n in anames)
        out += tuple(new_m[n] for n in anames)
        out += tuple(new_v[n] for n in anames)
        return out

    extra = ["tokens", "targets", "mask", "lr", "step"]
    outs = (["loss"] + anames + [f"m_{n}" for n in anames]
            + [f"v_{n}" for n in anames])
    return fn, fnames, anames, extra, outs


def make_acts_fn(cfg: ModelConfig):
    """acts_fp: capture the inputs of every quantized slot on the fp model.

    GPTQ needs per-layer calibration activations X to build its Hessians
    ``H = 2 X Xᵀ``. Returns, stacked over layers: ``xn1`` (input to
    wq/wk/wv), ``attn_o`` (input to wo), ``xn2`` (input to w_up) and
    ``h_mid`` (input to w_down), each (L, B, T, ·).
    """
    shapes = {**fp_shared_shapes(cfg), **fp_weight_shapes(cfg)}
    names = sorted_names(shapes)

    def fn(*args):
        arrs = list(args)
        params = {n: arrs[i] for i, n in enumerate(names)}
        tokens = arrs[len(names)].astype(jnp.int32)
        b, t = tokens.shape
        x = params["embed"][tokens] + params["pos"][None, :t, :]
        stacked = {k: v for k, v in params.items()
                   if k.startswith(_PER_LAYER_PREFIXES)}

        def body(carry, layer):
            layer = dict(layer)
            layer["__n_bits__"] = 4
            bb, tt, d = carry.shape
            h, hd = cfg.n_heads, cfg.head_dim
            xn1 = _layernorm(carry, layer["ln1_w"], layer["ln1_b"])
            q = _linear(xn1, layer, "wq", cfg, "fp", 0.0, False)
            k = _linear(xn1, layer, "wk", cfg, "fp", 0.0, False)
            v = _linear(xn1, layer, "wv", cfg, "fp", 0.0, False)
            q = q.reshape(bb, tt, h, hd).transpose(0, 2, 1, 3)
            k = k.reshape(bb, tt, h, hd).transpose(0, 2, 1, 3)
            v = v.reshape(bb, tt, h, hd).transpose(0, 2, 1, 3)
            att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
            mask = jnp.tril(jnp.ones((tt, tt), jnp.float32))
            att = jnp.where(mask == 0.0, -1e30, att)
            att = jax.nn.softmax(att, axis=-1)
            attn_o = (att @ v).transpose(0, 2, 1, 3).reshape(bb, tt, d)
            x2 = carry + _linear(attn_o, layer, "wo", cfg, "fp", 0.0, False)
            xn2 = _layernorm(x2, layer["ln2_w"], layer["ln2_b"])
            h_mid = jax.nn.gelu(_linear(xn2, layer, "w_up", cfg, "fp", 0.0, False))
            x3 = x2 + _linear(h_mid, layer, "w_down", cfg, "fp", 0.0, False)
            return x3, (xn1, attn_o, xn2, h_mid)

        _, caps = jax.lax.scan(body, x, stacked)
        return caps

    outs = ["xn1", "attn_o", "xn2", "h_mid"]
    return fn, names, outs


def make_pretrain_fn(cfg: ModelConfig):
    """pretrain_step: full-precision AdamW over every parameter (used to
    create the in-repo 'pretrained' base model that GPTQ then quantizes)."""
    shapes = {**fp_shared_shapes(cfg), **fp_weight_shapes(cfg)}
    names = sorted_names(shapes)

    def fn(*args):
        arrs = list(args)
        n = len(names)
        params = {nm: arrs[j] for j, nm in enumerate(names)}
        m = {nm: arrs[n + j] for j, nm in enumerate(names)}
        v = {nm: arrs[2 * n + j] for j, nm in enumerate(names)}
        tokens, targets, mask, lr, step = arrs[3 * n:3 * n + 5]
        lr = lr.reshape(())
        step = step.reshape(())

        def loss_of(p):
            return loss_fn(p, (tokens, targets, mask), cfg, "fp")

        loss, grads = jax.value_and_grad(loss_of)(params)
        grads = clip_global_norm(grads, 1.0)
        new_p, new_m, new_v = {}, {}, {}
        for nm in names:
            new_p[nm], new_m[nm], new_v[nm] = adamw_update(
                params[nm], grads[nm], m[nm], v[nm], lr, step)
        out = (loss.reshape(1),)
        out += tuple(new_p[nm] for nm in names)
        out += tuple(new_m[nm] for nm in names)
        out += tuple(new_v[nm] for nm in names)
        return out

    outs = (["loss"] + names + [f"m_{n}" for n in names]
            + [f"v_{n}" for n in names])
    return fn, names, outs
