"""Golden-vector generator: pins the Rust host-side math to the JAX graphs.

The lossless-merge property only holds system-wide if the Rust
implementations (quantizer, ternary merge, optimizer schedule) compute *the
same numbers* as the lowered HLO graphs. This module generates deterministic
input/output pairs from the python references into ``artifacts/golden/*.json``;
the Rust unit tests replay them (`rust/src/*/golden tests`).

Run automatically by ``aot.py`` (part of ``make artifacts``).
"""

import json
import os

import numpy as np

from .kernels import ref


def _rng():
    return np.random.default_rng(20250710)


def ref_rtn_quantize(w, group_size, n_bits):
    """Round-to-nearest group-wise asymmetric quantization (paper Eq. 2):
    per (group, out-column) ``s = (max−min)/(2^N−1)``, ``z = min``."""
    din, dout = w.shape
    g = din // group_size
    wg = w.reshape(g, group_size, dout)
    mx = wg.max(axis=1)
    mn = wg.min(axis=1)
    scales = (mx - mn) / float(2 ** n_bits - 1)
    scales = np.maximum(scales, 1e-8)
    zeros = mn
    w_int = np.rint((wg - zeros[:, None, :]) / scales[:, None, :])
    w_int = np.clip(w_int, 0, 2 ** n_bits - 1).reshape(din, dout)
    return w_int.astype(np.float32), scales.astype(np.float32), zeros.astype(np.float32)


def generate(out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    rng = _rng()
    din, dout, gs, r = 32, 48, 8, 4
    g = din // gs

    w = (rng.normal(size=(din, dout)) * 0.1).astype(np.float32)
    cases = {}

    # --- RTN quantization, all bit-widths ---
    for nb in (2, 3, 4):
        w_int, sc, ze = ref_rtn_quantize(w, gs, nb)
        cases[f"rtn_w{nb}"] = {
            "w": w.ravel().tolist(), "din": din, "dout": dout, "gs": gs,
            "n_bits": nb,
            "w_int": w_int.ravel().tolist(),
            "scales": sc.ravel().tolist(),
            "zeros": ze.ravel().tolist(),
        }

    # --- ternary adaptation / lossless merge ---
    w_int, sc, ze = ref_rtn_quantize(w, gs, 4)
    a = rng.integers(-1, 2, (din, r)).astype(np.float32)
    b = rng.integers(-1, 2, (r, dout)).astype(np.float32)
    omega = 0.75 * r
    w_new, z_new = ref.ternary_apply_ref(a, b, w_int, sc, ze, omega, r, 4)
    cases["ternary_apply"] = {
        "a": a.ravel().tolist(), "b": b.ravel().tolist(),
        "w_int": w_int.ravel().tolist(),
        "scales": sc.ravel().tolist(), "zeros": ze.ravel().tolist(),
        "din": din, "dout": dout, "gs": gs, "rank": r,
        "omega": omega, "n_bits": 4,
        "w_int_new": np.asarray(w_new).ravel().tolist(),
        "zeros_new": np.asarray(z_new).ravel().tolist(),
    }

    # --- t-SignSGD update ---
    grad = rng.normal(size=(din, r)).astype(np.float32) * 1e-3
    a_new = ref.tsign_update_ref(a, grad, np.float32(0.05))
    cases["tsign"] = {
        "a": a.ravel().tolist(), "grad": grad.ravel().tolist(),
        "rows": din, "cols": r, "keep_frac": 0.05,
        "a_new": np.asarray(a_new).ravel().tolist(),
    }

    # --- quantized matmul ---
    x = rng.normal(size=(8, din)).astype(np.float32)
    y = ref.quant_matmul_ref(x, w_int, sc, ze)
    cases["quant_matmul"] = {
        "x": x.ravel().tolist(), "m": 8,
        "w_int": w_int.ravel().tolist(),
        "scales": sc.ravel().tolist(), "zeros": ze.ravel().tolist(),
        "din": din, "dout": dout, "gs": gs,
        "y": np.asarray(y).ravel().tolist(),
    }

    # --- QA-LoRA pooling + zero-merge ---
    qa = rng.normal(size=(g, r)).astype(np.float32) * 0.1
    qb = rng.normal(size=(r, dout)).astype(np.float32) * 0.1
    alpha = 2.0 * r
    pooled = ref.qalora_pool_ref(x, gs)
    contrib = (alpha / r) * pooled @ qa @ qb
    z_merged = ze + (alpha / r) * (qa @ qb) / gs
    cases["qalora"] = {
        "x": x.ravel().tolist(), "m": 8, "din": din, "dout": dout,
        "gs": gs, "rank": r, "alpha": alpha,
        "a": qa.ravel().tolist(), "b": qb.ravel().tolist(),
        "zeros": ze.ravel().tolist(), "scales": sc.ravel().tolist(),
        "pooled": np.asarray(pooled).ravel().tolist(),
        "contrib": np.asarray(contrib).ravel().tolist(),
        "zeros_merged": np.asarray(z_merged).ravel().tolist(),
    }

    # --- lossy LoRA merge (requantization error demo) ---
    la = rng.normal(size=(din, r)).astype(np.float32) * 0.05
    lb = rng.normal(size=(r, dout)).astype(np.float32) * 0.05
    w_int_m, w_fp = ref.lora_merge_requant_ref(w_int, sc, ze, la, lb, alpha, r, 4)
    cases["lora_merge"] = {
        "w_int": w_int.ravel().tolist(), "scales": sc.ravel().tolist(),
        "zeros": ze.ravel().tolist(), "a": la.ravel().tolist(),
        "b": lb.ravel().tolist(), "din": din, "dout": dout, "gs": gs,
        "rank": r, "alpha": alpha, "n_bits": 4,
        "w_int_merged": np.asarray(w_int_m).ravel().tolist(),
        "w_fp": np.asarray(w_fp).ravel().tolist(),
    }

    for name, case in cases.items():
        with open(os.path.join(out_dir, f"{name}.json"), "w") as f:
            json.dump(case, f)
    print(f"wrote {len(cases)} golden cases to {out_dir}")


if __name__ == "__main__":
    generate("../artifacts/golden")
