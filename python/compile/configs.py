"""Model / lowering configurations shared by model.py, aot.py and the tests.

The Rust side mirrors these in ``rust/src/config/presets.rs``; the two MUST
stay in sync (the artifact staleness hash covers this file, so editing it
forces a re-lowering, and the Rust integration tests check shapes at load
time).
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    """GPT-style decoder configuration.

    All linear layers that get quantized+adapted are the six per-block
    matrices: wq, wk, wv, wo (d×d) and w_up (d×ff), w_down (ff×d).
    Embedding, positional table, layer norms and the LM head stay f32.
    """

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    group_size: int  # quantization group size along the input dimension
    rank: int        # adapter rank r

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def n_params(self) -> int:
        per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff
        embed = 2 * self.vocab * self.d_model + self.seq_len * self.d_model
        norms = (4 * self.n_layers + 2) * self.d_model
        return self.n_layers * per_layer + embed + norms


# NOTE: vocab matches rust/src/data/tokenizer.rs (char-level, 64 symbols).
VOCAB = 64

TINY = ModelConfig(
    name="tiny", vocab=VOCAB, d_model=64, n_layers=2, n_heads=4,
    d_ff=256, seq_len=128, group_size=16, rank=8,
)
SMALL = ModelConfig(
    name="small", vocab=VOCAB, d_model=256, n_layers=4, n_heads=4,
    d_ff=1024, seq_len=128, group_size=32, rank=16,
)
MEDIUM = ModelConfig(
    name="medium", vocab=VOCAB, d_model=384, n_layers=8, n_heads=6,
    d_ff=1536, seq_len=128, group_size=64, rank=16,
)

CONFIGS = {c.name: c for c in (TINY, SMALL, MEDIUM)}

# Training-step batch sizes (fixed shapes baked into the HLO artifacts).
STEP_BATCH = {"tiny": 8, "small": 4, "medium": 2}

# Serving forward-pass batch buckets per config: the L3 dynamic batcher
# routes requests to the smallest bucket that fits (see rust serve/).
SERVE_BUCKETS = {"tiny": (1, 8, 32), "small": (1, 4, 8), "medium": (1, 4)}

# Methods with a training-step artifact.
METHODS = ("lota", "lora", "qalora")

# Bit-widths exercised throughout (paper: 4/3/2-bit GPTQ).
BITS = (4, 3, 2)
