//! Native packed-integer inference engine — the second serving backend.
//!
//! The PJRT path executes AOT-lowered HLO at fixed batch buckets and
//! computes on f32-coded integers, which leaves the packed `u32`
//! deployment representation of `quant/pack.rs` unused at inference time.
//! This module is the deployment story the paper's §4.3 efficiency claim
//! actually makes: after the lossless merge the model *is* its low-bit
//! codes, and the engine computes directly on them —
//!
//! * [`packed::PackedLinear`] — column-packed `u32` grid + per-group
//!   scale/zero tables, built once from a [`crate::quant::QuantizedLinear`];
//! * [`gemm::matmul_packed`] — the fused group-dequant × matmul kernel:
//!   codes decoded in-register, affine factors applied per group, output
//!   columns fanned out over `std::thread::scope`, and no dense f32 weight
//!   matrix ever materialized;
//! * [`forward::Engine`] — the full transformer forward (embedding, layer
//!   norms, causal attention, GELU MLP, logits) mirroring the lowered
//!   graphs operation-for-operation, with an optional LoRA adapter path
//!   for the Fig. 4 baseline;
//! * [`decode::greedy_decode`] — recompute greedy decoding at **any**
//!   batch size, no bucket policy and no artifacts directory required.
//!
//! When to use which backend: the PJRT path is the reference executor —
//! it shares one lowered graph with training and is what the golden /
//! integration suites pin numerically; the native engine is for serving a
//! *merged* checkpoint where batch shapes are unpredictable, artifacts are
//! unavailable, or memory must stay at the packed footprint. The two are
//! interchangeable by construction: `tests/backend_parity.rs` holds their
//! logits together within f32 tolerance on the same checkpoint.

pub mod decode;
pub mod forward;
pub mod gemm;
pub mod packed;

pub use decode::{greedy_decode, Generation};
pub use forward::Engine;
pub use gemm::{matmul_packed, matmul_packed_with_threads};
pub use packed::PackedLinear;
