//! Native packed-integer inference engine — the second serving backend.
//!
//! The PJRT path executes AOT-lowered HLO at fixed batch buckets and
//! computes on f32-coded integers, which leaves the packed `u32`
//! deployment representation of `quant/pack.rs` unused at inference time.
//! This module is the deployment story the paper's §4.3 efficiency claim
//! actually makes: after the lossless merge the model *is* its low-bit
//! codes, and the engine computes directly on them —
//!
//! * [`packed::PackedLinear`] — column-packed `u32` grid + per-group
//!   scale/zero tables, built once from a [`crate::quant::QuantizedLinear`];
//! * [`gemm::matmul_packed`] — the fused group-dequant × matmul kernel:
//!   codes decoded in-register (a whole `u32` word at a time), affine
//!   factors applied per group, output columns fanned out over
//!   `std::thread::scope`, and no dense f32 weight matrix ever
//!   materialized. The inner loop is runtime-dispatched through
//!   [`simd`]: AVX2 when detected, a portable 8-lane fallback
//!   otherwise, and the scalar reference behind `--gemm-kernel scalar` /
//!   `LOTA_GEMM_KERNEL=scalar` — all three accumulate in the same fixed
//!   lane order, so kernel choice is bit-invisible in the outputs
//!   (`tests/gemm_simd.rs` pins it, and the CI perf gate keeps the
//!   SIMD path ≥ 1.5× the reference);
//! * [`forward::Engine`] — the full transformer forward (embedding, layer
//!   norms, causal attention, GELU MLP, logits) mirroring the lowered
//!   graphs operation-for-operation, with an optional LoRA adapter path
//!   for the Fig. 4 baseline;
//! * [`cache::KvCache`] + [`forward::Engine::forward_incremental`] — per
//!   request K/V buffers and the incremental forward that feeds only new
//!   token positions against them, making decode O(T) per generation
//!   instead of the recompute path's O(T²). Rows are reclaimable in
//!   place ([`cache::KvCache::reset_row`], O(1)): the continuous-batching
//!   scheduler (`crate::sched`) hands a finished request's row to the
//!   next waiting request without reallocating, and a reused row decodes
//!   bit-identically to a fresh cache. Storage comes in two layouts —
//!   contiguous per-row slabs (the reference) or **paged**
//!   ([`cache::KvCache::new_paged`]): fixed-size blocks from a shared
//!   [`blocks::BlockAllocator`] pool mapped through per-row page tables,
//!   so a row's footprint tracks its actual length and the same KV budget
//!   carries far more concurrent requests. The layouts are pinned
//!   bit-identical (`tests/kv_paged.rs`) — only the memory shape moves;
//! * [`decode::greedy_decode`] — greedy decoding at **any** batch size,
//!   no bucket policy and no artifacts directory required. KV-cached by
//!   default; [`decode::greedy_decode_with`] selects the full-prefix
//!   recompute reference, and both drop finished rows from the step
//!   batch. [`decode::DecodeStats`] reports what was actually fed. The
//!   cached path is built on two shared primitives — a padded batch
//!   prefill and a one-token step — that the scheduler drives directly,
//!   so one-shot and scheduled decoding cannot drift apart.
//!
//! When to use which backend: the PJRT path is the reference executor —
//! it shares one lowered graph with training and is what the golden /
//! integration suites pin numerically; the native engine is for serving a
//! *merged* checkpoint where batch shapes are unpredictable, artifacts are
//! unavailable, or memory must stay at the packed footprint. The two are
//! interchangeable by construction: `tests/backend_parity.rs` holds their
//! logits together within f32 tolerance on the same checkpoint, and the
//! engine's own cached/recompute pair is pinned **bit-identical** by
//! `tests/engine_parity.rs` — no artifacts needed.

pub mod blocks;
pub mod cache;
pub mod decode;
pub mod delta;
pub mod forward;
pub mod gemm;
pub mod packed;
pub mod simd;

pub use blocks::{BlockAllocator, BlockCounters};
pub use cache::KvCache;
pub use decode::{greedy_decode, greedy_decode_paged, greedy_decode_with, DecodeStats, Generation};
pub use delta::{PackedView, TernaryDelta};
pub use forward::Engine;
pub use gemm::{
    matmul_packed, matmul_packed_dispatch, matmul_packed_opts, matmul_packed_view,
    matmul_packed_with_threads,
};
pub use packed::PackedLinear;
pub use simd::{Dispatch as GemmDispatch, LANES as GEMM_LANES};
