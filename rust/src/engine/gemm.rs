//! The fused group-dequant × matmul kernel — the native engine's hot path.
//!
//! Computes `Y = X · (s ⊙ W_int + z)` directly from the column-packed
//! `u32` grid, without ever materializing the dense f32 weight matrix.
//! The affine factors distribute over the group sum:
//!
//! ```text
//! y[m,j] = Σ_g ( s[g,j] · Σ_{i∈g} x[m,i]·w_int[i,j]  +  z[g,j] · Σ_{i∈g} x[m,i] )
//! ```
//!
//! so the kernel needs only (a) the per-group integer dot products, decoded
//! in-register from one column-sized code buffer, and (b) the per-row group
//! sums of `X`, computed once and reused by every output column. Scale and
//! zero are applied per group in-register — the f32 weights never exist.
//!
//! Blocking/parallelism: output columns are split into contiguous chunks
//! and fanned out over `std::thread::scope` threads; each thread owns its
//! chunk's output block, so there is no sharing and no locking. The group
//! loop doubles as the cache block along the reduction dimension.
//!
//! # Kernel dispatch and the lane-ordered contract
//!
//! The per-group dot product runs through one of three kernels —
//! AVX2, a portable 8-lane fallback, or the scalar reference in
//! this file — selected at runtime by [`crate::engine::simd::resolve`]
//! (`auto|simd|scalar` via `ServeOptions::gemm_kernel`, the experiment
//! TOML, `lota serve --gemm-kernel`, or `LOTA_GEMM_KERNEL`). All three
//! accumulate in the **same fixed 8-lane order** (see the contract in
//! [`crate::engine::simd`]), so kernel choice never changes a bit of the
//! output: `tests/gemm_simd.rs` pins them `assert_eq!`-identical, which
//! is what lets every engine/sched/paged parity suite keep holding
//! bitwise whatever hardware runs it.
//!
//! **Do not "simplify" [`gemm_block_scalar`] or [`group_sums`] back to
//! sequential accumulation** — their lane structure *is* the contract the
//! vector kernels are pinned against, not a stylistic choice.

use crate::config::GemmKernel;
use crate::tensor::Tensor;

use super::delta::PackedView;
use super::packed::PackedLinear;
use super::simd::{self, Dispatch};

/// Work threshold (multiply-accumulates) below which threading costs more
/// than it saves — decode-sized calls stay on the caller's thread.
///
/// KV-cached decode steps feed one row per live request, so they land far
/// below this threshold; that is only safe because every output element's
/// accumulation order is independent of `M` and of the thread count — a
/// single-row call is bitwise identical to the matching row of a batched
/// call (pinned by `row_slices_match_batched_call_bitwise` below), which
/// is what lets the cached decode path promise bit-equal generations.
const PAR_THRESHOLD: usize = 1 << 20;

/// Fused packed GEMM: `x` is (M, Din), returns (M, Dout). Kernel and
/// thread count both auto-selected.
pub fn matmul_packed(x: &Tensor, w: &PackedLinear) -> Tensor {
    matmul_packed_dispatch(x, w, simd::resolve(GemmKernel::Auto), None)
}

/// [`matmul_packed`] with an explicit thread budget (bench / test knob).
pub fn matmul_packed_with_threads(x: &Tensor, w: &PackedLinear, threads: usize) -> Tensor {
    matmul_packed_dispatch(x, w, simd::resolve(GemmKernel::Auto), Some(threads))
}

/// [`matmul_packed`] with an explicit kernel request — what the serving
/// plumbing and the GEMM bench drive. `threads = None` auto-sizes.
pub fn matmul_packed_opts(
    x: &Tensor,
    w: &PackedLinear,
    kernel: GemmKernel,
    threads: Option<usize>,
) -> Tensor {
    matmul_packed_dispatch(x, w, simd::resolve(kernel), threads)
}

/// Run with an already-resolved [`Dispatch`] (the engine resolves once at
/// construction and reuses it every forward). Base weights only — the
/// adapter-aware entry is [`matmul_packed_view`].
pub fn matmul_packed_dispatch(
    x: &Tensor,
    w: &PackedLinear,
    dispatch: Dispatch,
    threads: Option<usize>,
) -> Tensor {
    matmul_packed_view(x, PackedView::base_only(w), dispatch, threads)
}

/// Innermost entry: fused packed GEMM over any weight surface — the bare
/// base or a base overlaid with one adapter's ternary delta
/// ([`PackedView`]). The view changes which codes and zeros the kernels
/// read, never the accumulation order, so every bitwise pin carries over.
pub fn matmul_packed_view(
    x: &Tensor,
    w: PackedView,
    dispatch: Dispatch,
    threads: Option<usize>,
) -> Tensor {
    let (m, din) = (x.rows(), x.cols());
    assert_eq!(din, w.din(), "packed matmul inner dims {din} vs {}", w.din());
    // Explicit invariant, checked once per call: the group decomposition
    // (and the `chunks_exact` in `group_sums`) silently drops a trailing
    // partial group if this ever breaks, which would corrupt outputs
    // instead of failing loud.
    assert_eq!(
        din % w.group_size(),
        0,
        "packed GEMM requires group_size ({}) to divide Din ({din}); \
         a trailing partial group would be silently dropped",
        w.group_size()
    );
    let dout = w.dout();
    let threads = match threads {
        Some(t) => t,
        None => {
            let work = m * din * dout;
            if work < PAR_THRESHOLD {
                1
            } else {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
        }
    };
    let xg = group_sums(x, w.group_size(), w.n_groups());

    let threads = threads.clamp(1, dout.max(1));
    if threads == 1 {
        let block = simd::run_block(dispatch, x, &xg, w, 0, dout);
        return Tensor::new(&[m, dout], block);
    }

    // Fan output-column chunks out over scoped threads; each returns its
    // own (M × chunk) block which the scatter below interleaves into the
    // row-major output.
    let chunk = dout.div_ceil(threads);
    let blocks: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        let mut j0 = 0;
        while j0 < dout {
            let j1 = (j0 + chunk).min(dout);
            let xg_ref = &xg;
            handles.push(
                scope.spawn(move || (j0, j1, simd::run_block(dispatch, x, xg_ref, w, j0, j1))),
            );
            j0 = j1;
        }
        handles.into_iter().map(|h| h.join().expect("gemm worker panicked")).collect()
    });

    let mut out = vec![0.0f32; m * dout];
    for (j0, j1, block) in blocks {
        let width = j1 - j0;
        for mi in 0..m {
            out[mi * dout + j0..mi * dout + j1]
                .copy_from_slice(&block[mi * width..(mi + 1) * width]);
        }
    }
    Tensor::new(&[m, dout], out)
}

/// Per-row group sums of the activations: `xg[m,g] = Σ_{i∈g} x[m,i]`.
///
/// Summed in the same 8-lane order as the dot-product kernels
/// ([`simd::lane_sum`]), so the activation side of `z[g,j] · Σ x` can
/// never diverge from the kernel's accumulation order. The caller
/// (`matmul_packed_dispatch`) has already asserted that `group_size`
/// divides Din, so `chunks_exact` covers every element.
fn group_sums(x: &Tensor, group_size: usize, n_groups: usize) -> Vec<f32> {
    let m = x.rows();
    let mut xg = vec![0.0f32; m * n_groups];
    for mi in 0..m {
        let xrow = x.row(mi);
        let grow = &mut xg[mi * n_groups..(mi + 1) * n_groups];
        for (g, chunk) in xrow.chunks_exact(group_size).enumerate() {
            grow[g] = simd::lane_sum(chunk);
        }
    }
    xg
}

/// The scalar reference kernel for output columns `[j0, j1)`: returns the
/// (M × width) block in chunk-local row-major order.
///
/// "Scalar" means no explicit vector code — the accumulation itself runs
/// in the contract's 8-lane order via [`simd::lane_dot`], which is what
/// makes this the *reference* the AVX2/portable kernels are bitwise-pinned
/// against rather than a merely-close baseline. Reachable in production
/// via `--gemm-kernel scalar` / `LOTA_GEMM_KERNEL=scalar`.
pub(crate) fn gemm_block_scalar(
    x: &Tensor,
    xg: &[f32],
    w: PackedView,
    j0: usize,
    j1: usize,
) -> Vec<f32> {
    let (m, din) = (x.rows(), x.cols());
    let gs = w.group_size();
    let g = w.n_groups();
    let dout = w.dout();
    let (scales, zeros) = (w.scales(), w.zeros());
    let width = j1 - j0;
    let mut out = vec![0.0f32; m * width];
    // one column of integer codes — the only decoded weight storage
    let mut codes = vec![0.0f32; din];
    // per-column scale/zero gathers, hoisted out of the m × g inner loops
    // (the strided `[gi * dout + j]` loads used to re-run per row)
    let mut sbuf = vec![0.0f32; g];
    let mut zbuf = vec![0.0f32; g];
    for j in j0..j1 {
        w.decode_col_into(j, &mut codes);
        for (gi, (s, z)) in sbuf.iter_mut().zip(zbuf.iter_mut()).enumerate() {
            *s = scales[gi * dout + j];
            *z = zeros[gi * dout + j];
        }
        for mi in 0..m {
            let xrow = x.row(mi);
            let xgrow = &xg[mi * g..(mi + 1) * g];
            let mut acc = 0.0f32;
            for gi in 0..g {
                let base = gi * gs;
                let dot = simd::lane_dot(&xrow[base..base + gs], &codes[base..base + gs]);
                acc += sbuf[gi] * dot + zbuf[gi] * xgrow[gi];
            }
            out[mi * width + (j - j0)] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::tensor::{linalg, Rng};

    fn setup(seed: u64, m: usize, din: usize, dout: usize, gs: usize, bits: u32) -> (Tensor, PackedLinear, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, bits);
        let x = Tensor::new(&[m, din], rng.normal_vec(m * din, 1.0));
        let dense = linalg::matmul(&x, &ql.dequantize());
        (x, PackedLinear::from_quantized(&ql).unwrap(), dense)
    }

    #[test]
    fn fused_matches_unpack_then_matmul() {
        for bits in [2u32, 3, 4] {
            for (m, din, dout, gs) in [(1, 32, 16, 8), (7, 64, 48, 16), (37, 96, 33, 32)] {
                let (x, pl, dense) = setup(bits as u64 + m as u64, m, din, dout, gs, bits);
                let fused = matmul_packed(&x, &pl);
                assert!(
                    fused.allclose(&dense, 1e-3, 1e-4),
                    "bits={bits} m={m} din={din} dout={dout}: max diff {}",
                    fused.max_abs_diff(&dense)
                );
            }
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let (x, pl, _) = setup(11, 13, 64, 50, 16, 4);
        let serial = matmul_packed_with_threads(&x, &pl, 1);
        for threads in [2usize, 3, 8, 64] {
            let par = matmul_packed_with_threads(&x, &pl, threads);
            // identical summation order per column ⇒ bitwise equality
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn kernels_agree_bitwise() {
        // the dispatch contract at unit scale; tests/gemm_simd.rs sweeps
        // it across bit widths, tails, and thread counts
        let (x, pl, _) = setup(31, 5, 64, 40, 16, 4);
        let scalar = matmul_packed_opts(&x, &pl, GemmKernel::Scalar, Some(1));
        let simd = matmul_packed_opts(&x, &pl, GemmKernel::Simd, Some(1));
        let auto = matmul_packed_opts(&x, &pl, GemmKernel::Auto, Some(1));
        assert_eq!(simd, scalar);
        assert_eq!(auto, scalar);
    }

    #[test]
    fn group_tail_is_lane_ordered_not_dropped() {
        // gs = 20 : two full 8-lanes plus a 4-element tail per group —
        // compare against a hand dequantized dense matmul to prove the
        // tail contributes
        let (x, pl, dense) = setup(41, 3, 40, 12, 20, 4);
        let y = matmul_packed(&x, &pl);
        assert!(y.allclose(&dense, 1e-3, 1e-4), "max diff {}", y.max_abs_diff(&dense));
    }

    #[test]
    fn row_slices_match_batched_call_bitwise() {
        // the incremental-decode contract: feeding any subset of rows
        // produces exactly the bits the full-batch call produces for them
        let (x, pl, _) = setup(21, 9, 64, 48, 16, 4);
        let full = matmul_packed(&x, &pl);
        let dout = pl.dout();
        for mi in 0..x.rows() {
            let one = Tensor::new(&[1, x.cols()], x.row(mi).to_vec());
            let y = matmul_packed(&one, &pl);
            assert_eq!(y.data(), &full.data()[mi * dout..(mi + 1) * dout], "row {mi}");
        }
    }

    #[test]
    fn zero_activations_hit_only_zero_terms() {
        let (_, pl, _) = setup(3, 1, 32, 8, 8, 4);
        let x = Tensor::zeros(&[4, 32]);
        let y = matmul_packed(&x, &pl);
        assert!(y.data().iter().all(|v| *v == 0.0));
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        let (_, pl, _) = setup(5, 2, 32, 8, 8, 4);
        let x = Tensor::zeros(&[2, 16]);
        matmul_packed(&x, &pl);
    }

    #[test]
    fn lane_width_is_the_documented_contract() {
        // the contract's width is load-bearing for every bitwise pin;
        // changing it is a breaking change to all recorded parity
        assert_eq!(simd::LANES, 8);
    }
}
