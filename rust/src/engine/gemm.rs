//! The fused group-dequant × matmul kernel — the native engine's hot path.
//!
//! Computes `Y = X · (s ⊙ W_int + z)` directly from the column-packed
//! `u32` grid, without ever materializing the dense f32 weight matrix.
//! The affine factors distribute over the group sum:
//!
//! ```text
//! y[m,j] = Σ_g ( s[g,j] · Σ_{i∈g} x[m,i]·w_int[i,j]  +  z[g,j] · Σ_{i∈g} x[m,i] )
//! ```
//!
//! so the kernel needs only (a) the per-group integer dot products, decoded
//! in-register from one column-sized code buffer, and (b) the per-row group
//! sums of `X`, computed once and reused by every output column. Scale and
//! zero are applied per group in-register — the f32 weights never exist.
//!
//! Blocking/parallelism: output columns are split into contiguous chunks
//! and fanned out over `std::thread::scope` threads; each thread owns its
//! chunk's output block, so there is no sharing and no locking. The group
//! loop doubles as the cache block along the reduction dimension.

use crate::tensor::Tensor;

use super::packed::PackedLinear;

/// Work threshold (multiply-accumulates) below which threading costs more
/// than it saves — decode-sized calls stay on the caller's thread.
///
/// KV-cached decode steps feed one row per live request, so they land far
/// below this threshold; that is only safe because every output element's
/// accumulation order is independent of `M` and of the thread count — a
/// single-row call is bitwise identical to the matching row of a batched
/// call (pinned by `row_slices_match_batched_call_bitwise` below), which
/// is what lets the cached decode path promise bit-equal generations.
const PAR_THRESHOLD: usize = 1 << 20;

/// Fused packed GEMM: `x` is (M, Din), returns (M, Dout).
pub fn matmul_packed(x: &Tensor, w: &PackedLinear) -> Tensor {
    let work = x.rows() * x.cols() * w.dout();
    let threads = if work < PAR_THRESHOLD {
        1
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    matmul_packed_with_threads(x, w, threads)
}

/// [`matmul_packed`] with an explicit thread budget (bench / test knob).
pub fn matmul_packed_with_threads(x: &Tensor, w: &PackedLinear, threads: usize) -> Tensor {
    let (m, din) = (x.rows(), x.cols());
    assert_eq!(din, w.din(), "packed matmul inner dims {din} vs {}", w.din());
    let dout = w.dout();
    let xg = group_sums(x, w.group_size, w.n_groups());

    let threads = threads.clamp(1, dout.max(1));
    if threads == 1 {
        let block = gemm_block(x, &xg, w, 0, dout);
        return Tensor::new(&[m, dout], block);
    }

    // Fan output-column chunks out over scoped threads; each returns its
    // own (M × chunk) block which the scatter below interleaves into the
    // row-major output.
    let chunk = dout.div_ceil(threads);
    let blocks: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        let mut j0 = 0;
        while j0 < dout {
            let j1 = (j0 + chunk).min(dout);
            let xg_ref = &xg;
            handles.push(scope.spawn(move || (j0, j1, gemm_block(x, xg_ref, w, j0, j1))));
            j0 = j1;
        }
        handles.into_iter().map(|h| h.join().expect("gemm worker panicked")).collect()
    });

    let mut out = vec![0.0f32; m * dout];
    for (j0, j1, block) in blocks {
        let width = j1 - j0;
        for mi in 0..m {
            out[mi * dout + j0..mi * dout + j1]
                .copy_from_slice(&block[mi * width..(mi + 1) * width]);
        }
    }
    Tensor::new(&[m, dout], out)
}

/// Per-row group sums of the activations: `xg[m,g] = Σ_{i∈g} x[m,i]`.
fn group_sums(x: &Tensor, group_size: usize, n_groups: usize) -> Vec<f32> {
    let m = x.rows();
    let mut xg = vec![0.0f32; m * n_groups];
    for mi in 0..m {
        let xrow = x.row(mi);
        let grow = &mut xg[mi * n_groups..(mi + 1) * n_groups];
        for (g, chunk) in xrow.chunks_exact(group_size).enumerate() {
            grow[g] = chunk.iter().sum();
        }
    }
    xg
}

/// Serial kernel for output columns `[j0, j1)`: returns the (M × width)
/// block in chunk-local row-major order.
fn gemm_block(x: &Tensor, xg: &[f32], w: &PackedLinear, j0: usize, j1: usize) -> Vec<f32> {
    let (m, din) = (x.rows(), x.cols());
    let gs = w.group_size;
    let g = w.n_groups();
    let dout = w.dout();
    let (scales, zeros) = (w.scales(), w.zeros());
    let width = j1 - j0;
    let mut out = vec![0.0f32; m * width];
    // one column of integer codes — the only decoded weight storage
    let mut codes = vec![0.0f32; din];
    for j in j0..j1 {
        w.decode_col_into(j, &mut codes);
        for mi in 0..m {
            let xrow = x.row(mi);
            let xgrow = &xg[mi * g..(mi + 1) * g];
            let mut acc = 0.0f32;
            for gi in 0..g {
                let s = scales[gi * dout + j];
                let z = zeros[gi * dout + j];
                let mut dot = 0.0f32;
                let base = gi * gs;
                for i in 0..gs {
                    dot += xrow[base + i] * codes[base + i];
                }
                acc += s * dot + z * xgrow[gi];
            }
            out[mi * width + (j - j0)] = acc;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::tensor::{linalg, Rng};

    fn setup(seed: u64, m: usize, din: usize, dout: usize, gs: usize, bits: u32) -> (Tensor, PackedLinear, Tensor) {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, bits);
        let x = Tensor::new(&[m, din], rng.normal_vec(m * din, 1.0));
        let dense = linalg::matmul(&x, &ql.dequantize());
        (x, PackedLinear::from_quantized(&ql).unwrap(), dense)
    }

    #[test]
    fn fused_matches_unpack_then_matmul() {
        for bits in [2u32, 3, 4] {
            for (m, din, dout, gs) in [(1, 32, 16, 8), (7, 64, 48, 16), (37, 96, 33, 32)] {
                let (x, pl, dense) = setup(bits as u64 + m as u64, m, din, dout, gs, bits);
                let fused = matmul_packed(&x, &pl);
                assert!(
                    fused.allclose(&dense, 1e-3, 1e-4),
                    "bits={bits} m={m} din={din} dout={dout}: max diff {}",
                    fused.max_abs_diff(&dense)
                );
            }
        }
    }

    #[test]
    fn threaded_matches_serial() {
        let (x, pl, _) = setup(11, 13, 64, 50, 16, 4);
        let serial = matmul_packed_with_threads(&x, &pl, 1);
        for threads in [2usize, 3, 8, 64] {
            let par = matmul_packed_with_threads(&x, &pl, threads);
            // identical summation order per column ⇒ bitwise equality
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn row_slices_match_batched_call_bitwise() {
        // the incremental-decode contract: feeding any subset of rows
        // produces exactly the bits the full-batch call produces for them
        let (x, pl, _) = setup(21, 9, 64, 48, 16, 4);
        let full = matmul_packed(&x, &pl);
        let dout = pl.dout();
        for mi in 0..x.rows() {
            let one = Tensor::new(&[1, x.cols()], x.row(mi).to_vec());
            let y = matmul_packed(&one, &pl);
            assert_eq!(y.data(), &full.data()[mi * dout..(mi + 1) * dout], "row {mi}");
        }
    }

    #[test]
    fn zero_activations_hit_only_zero_terms() {
        let (_, pl, _) = setup(3, 1, 32, 8, 8, 4);
        let x = Tensor::zeros(&[4, 32]);
        let y = matmul_packed(&x, &pl);
        assert!(y.data().iter().all(|v| *v == 0.0));
    }

    #[test]
    #[should_panic]
    fn inner_dim_mismatch_panics() {
        let (_, pl, _) = setup(5, 2, 32, 8, 8, 4);
        let x = Tensor::zeros(&[2, 16]);
        matmul_packed(&x, &pl);
    }
}
