//! The native transformer forward pass over packed weights.
//!
//! Mirrors `python/compile/model.py::forward` with `method="merged"`
//! operation-for-operation — pre-norm blocks, causal attention with the
//! `-1e30` mask convention, tanh-approximate GELU, layer norm with
//! `eps = 1e-5` — so its logits agree with the `fwd_merged_*` PJRT
//! artifacts up to f32 summation order (the parity golden test in
//! `tests/backend_parity.rs` pins this). The six quantized linears run
//! through the fused packed GEMM; nothing here ever holds a dense f32
//! weight matrix for them.
//!
//! The LoRA serving path (quantized base **plus** f32 adapter matmuls on
//! every token — the baseline LoTA is compared against in Fig. 4) is
//! supported by attaching the `lo_{slot}_a/_b` tensors with
//! [`Engine::attach_lora`].
//!
//! Two entry points share every kernel:
//!
//! * [`Engine::forward`] — the full (B, T) forward, attention recomputed
//!   over the whole prefix. The reference path.
//! * [`Engine::forward_incremental`] — feeds only *new* token positions,
//!   appending their keys/values to a [`KvCache`] and attending against
//!   the stored prefix. Because every kernel here accumulates per row in
//!   a fixed order, the incremental path is **bit-identical** to the full
//!   forward at the same positions — `tests/engine_parity.rs` pins this
//!   with `assert_eq`, not a tolerance.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::adapter::lota::TernaryAdapter;
use crate::config::{GemmKernel, ModelConfig};
use crate::model::{self, ParamStore, SLOTS};
use crate::obs::profiler::{KernelProf, PhaseKind, Profiler, STEP_TID};
use crate::tensor::{linalg, Tensor};

use super::cache::KvCache;
use super::delta::{PackedView, TernaryDelta};
use super::gemm::matmul_packed_view;
use super::packed::PackedLinear;
use super::simd;

/// Slot indices within [`Layer::slots`], in [`SLOTS`] order.
const WQ: usize = 0;
const WK: usize = 1;
const WV: usize = 2;
const WO: usize = 3;
const W_UP: usize = 4;
const W_DOWN: usize = 5;

/// One transformer block's serving-time parameters.
struct Layer {
    ln1_w: Vec<f32>,
    ln1_b: Vec<f32>,
    ln2_w: Vec<f32>,
    ln2_b: Vec<f32>,
    /// packed quantized linears in [`SLOTS`] order
    slots: Vec<PackedLinear>,
    /// optional f32 LoRA factors `(A, B)` per slot, same order
    lora: Option<Vec<(Tensor, Tensor)>>,
    /// registered ternary adapters: `adapters[a][slot]` is adapter id
    /// `a + 1`'s grid delta for this layer (id 0 is the bare base)
    adapters: Vec<Vec<TernaryDelta>>,
}

/// The native inference engine: a merged quantized checkpoint held in
/// deployment form, executable at **any** batch size with no AOT artifact.
pub struct Engine {
    cfg: ModelConfig,
    pub n_bits: u32,
    embed: Tensor,
    pos: Tensor,
    head: Tensor,
    lnf_w: Vec<f32>,
    lnf_b: Vec<f32>,
    layers: Vec<Layer>,
    /// resolved packed-GEMM kernel, fixed at construction (or via
    /// [`Engine::set_gemm_kernel`]) so the hot path never re-detects —
    /// all choices are bit-identical, this is purely a speed/debug knob
    gemm: simd::Dispatch,
    /// names of registered ternary adapter sets, in registration order;
    /// adapter id `i + 1` is `adapter_names[i]`, id 0 the bare base
    adapter_names: Vec<String>,
}

impl Engine {
    /// Build from a quantized [`ParamStore`] (the `q_{slot}_int|_s|_z`
    /// layout every coordinator path produces).
    pub fn from_store(cfg: &ModelConfig, store: &ParamStore, n_bits: u32) -> Result<Engine> {
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let mut slots = Vec::with_capacity(SLOTS.len());
            for slot in SLOTS {
                let ql = model::quant_layer(cfg, store, slot, li, n_bits)?;
                slots.push(PackedLinear::from_quantized(&ql)?);
            }
            layers.push(Layer {
                ln1_w: store.get("ln1_w")?.row(li).to_vec(),
                ln1_b: store.get("ln1_b")?.row(li).to_vec(),
                ln2_w: store.get("ln2_w")?.row(li).to_vec(),
                ln2_b: store.get("ln2_b")?.row(li).to_vec(),
                slots,
                lora: None,
                adapters: Vec::new(),
            });
        }
        Ok(Engine {
            cfg: cfg.clone(),
            n_bits,
            embed: store.get("embed")?.clone(),
            pos: store.get("pos")?.clone(),
            head: store.get("head")?.clone(),
            lnf_w: store.get("lnf_w")?.data().to_vec(),
            lnf_b: store.get("lnf_b")?.data().to_vec(),
            layers,
            gemm: simd::resolve(GemmKernel::Auto),
            adapter_names: Vec::new(),
        })
    }

    /// Re-resolve the packed-GEMM kernel for this engine (`auto` honors
    /// `LOTA_GEMM_KERNEL`, then hardware detection). Outputs are
    /// bit-identical across kernels — this selects instructions, not
    /// results.
    pub fn set_gemm_kernel(&mut self, kernel: GemmKernel) {
        self.gemm = simd::resolve(kernel);
    }

    /// Which kernel this engine's forwards actually run
    /// (`avx2` / `portable` / `scalar`) — surfaced in serving reports
    /// and the bench JSON.
    pub fn gemm_kernel_label(&self) -> &'static str {
        self.gemm.label()
    }

    /// Build from a merged checkpoint on disk. `n_bits` falls back to the
    /// checkpoint's `__n_bits__` hint when not given.
    pub fn from_checkpoint(
        cfg: &ModelConfig,
        path: &std::path::Path,
        n_bits: Option<u32>,
    ) -> Result<Engine> {
        let store = model::checkpoint::load(path)?;
        let Some(bits) = n_bits.or_else(|| model::checkpoint::n_bits_hint(&store)) else {
            bail!("{path:?} carries no __n_bits__ hint — pass n_bits explicitly");
        };
        Engine::from_store(cfg, &store, bits)
    }

    /// Attach the 16-bit LoRA adapters (`lo_{slot}_a/_b`) so the forward
    /// runs the quantized base **plus** the adapter matmuls — the
    /// unmergeable baseline path of the Fig. 4 comparison.
    pub fn attach_lora(&mut self, store: &ParamStore) -> Result<()> {
        if !self.adapter_names.is_empty() {
            bail!("cannot attach LoRA to an engine serving ternary adapters");
        }
        for (li, layer) in self.layers.iter_mut().enumerate() {
            let mut mats = Vec::with_capacity(SLOTS.len());
            for slot in SLOTS {
                let a = store.get(&format!("lo_{slot}_a"))?.layer(li);
                let b = store.get(&format!("lo_{slot}_b"))?.layer(li);
                mats.push((a, b));
            }
            layer.lora = Some(mats);
        }
        Ok(())
    }

    /// Register one named ternary adapter set (the `ta_{slot}_a/_b`
    /// layer-stacked layout every LoTA training path produces) against
    /// this engine's packed base, returning its adapter id (≥ 1; id 0 is
    /// always the bare base). The adapter is merged losslessly per
    /// (layer, slot) via [`crate::adapter::lota::lota_merge`] and stored
    /// as in-kernel [`TernaryDelta`]s — requests tagged with the returned
    /// id decode bit-identically to serving the merged checkpoint alone.
    ///
    /// `omega` is the ternarization threshold the adapter was trained
    /// with (`omega_frac · rank`); a wrong value changes which grid moves
    /// survive, so it must match training.
    pub fn register_adapter(
        &mut self,
        name: &str,
        store: &ParamStore,
        omega: f32,
    ) -> Result<u32> {
        if self.has_lora() {
            bail!("cannot register ternary adapters on an engine serving LoRA");
        }
        if name.is_empty() || name == "base" {
            bail!("adapter name {name:?} is reserved");
        }
        if self.adapter_names.iter().any(|n| n == name) {
            bail!("adapter {name:?} already registered");
        }
        for (li, layer) in self.layers.iter_mut().enumerate() {
            let mut deltas = Vec::with_capacity(SLOTS.len());
            for (si, slot) in SLOTS.iter().enumerate() {
                let a = store.get(&format!("ta_{slot}_a"))?.layer(li);
                let b = store.get(&format!("ta_{slot}_b"))?.layer(li);
                let ta = TernaryAdapter::from_parts(a, b)?;
                deltas.push(TernaryDelta::from_adapter(&layer.slots[si], &ta, omega)?);
            }
            layer.adapters.push(deltas);
        }
        self.adapter_names.push(name.to_string());
        Ok(self.adapter_names.len() as u32)
    }

    /// Number of registered adapter sets (excluding the implicit base).
    /// Valid request tags are `0..=adapter_count()`.
    pub fn adapter_count(&self) -> usize {
        self.adapter_names.len()
    }

    /// Human-readable name for an adapter id (`"base"` for 0) — what the
    /// per-adapter serving stats are keyed by.
    pub fn adapter_label(&self, id: u32) -> &str {
        match id {
            0 => "base",
            i => &self.adapter_names[(i - 1) as usize],
        }
    }

    /// Registered adapter names, in id order (id = index + 1).
    pub fn adapter_names(&self) -> &[String] {
        &self.adapter_names
    }

    /// Bytes held resident by all registered adapter deltas.
    pub fn adapter_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                l.adapters
                    .iter()
                    .flat_map(|set| set.iter().map(|d| d.deployed_bytes()))
                    .sum::<usize>()
            })
            .sum()
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn has_lora(&self) -> bool {
        self.layers.first().is_some_and(|l| l.lora.is_some())
    }

    /// Total bytes of packed grids + affine tables across all layers —
    /// the deployment footprint this engine actually holds.
    pub fn deployed_weight_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.slots.iter().map(|p| p.deployed_bytes()).sum::<usize>())
            .sum()
    }

    /// Logits (B, T, V) for f32-coded token ids (B, T). `t` may be any
    /// length up to `seq_len` — fixed-shape buckets do not exist here.
    pub fn forward(&self, tokens: &Tensor) -> Result<Tensor> {
        let cfg = &self.cfg;
        if tokens.shape().len() != 2 {
            bail!("engine forward wants (B, T) tokens, got {:?}", tokens.shape());
        }
        let (b, t) = (tokens.shape()[0], tokens.shape()[1]);
        if t == 0 || t > cfg.seq_len {
            bail!("sequence length {t} outside 1..={}", cfg.seq_len);
        }
        let d = cfg.d_model;

        // embedding + position table
        let mut x = vec![0.0f32; b * t * d];
        for bi in 0..b {
            for ti in 0..t {
                let id = tokens.data()[bi * t + ti];
                if id < 0.0 || id.fract() != 0.0 || id as usize >= cfg.vocab {
                    bail!("token {id} at ({bi},{ti}) outside vocab {}", cfg.vocab);
                }
                let row = &mut x[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                let erow = self.embed.row(id as usize);
                let prow = self.pos.row(ti);
                for k in 0..d {
                    row[k] = erow[k] + prow[k];
                }
            }
        }
        let mut x = Tensor::new(&[b * t, d], x);

        for layer in &self.layers {
            x = self.block(&x, layer, b, t, &[])?;
        }
        let x = layernorm(&x, &self.lnf_w, &self.lnf_b);
        let logits = linalg::matmul(&x, &self.head);
        Ok(logits.reshape(&[b, t, cfg.vocab]))
    }

    /// A fresh [`KvCache`] sized for `batch` concurrent requests at this
    /// engine's full context length.
    pub fn new_cache(&self, batch: usize) -> KvCache {
        self.new_cache_for(batch, self.cfg.seq_len)
    }

    /// A fresh [`KvCache`] sized for a known decode horizon (prompt +
    /// generation positions, clamped to the context length) — a short
    /// generation on a long-context model allocates only what it can
    /// actually write.
    pub fn new_cache_for(&self, batch: usize, horizon: usize) -> KvCache {
        let capacity = horizon.clamp(1, self.cfg.seq_len);
        KvCache::new(self.layers.len(), batch, capacity, self.cfg.d_model)
    }

    /// A fresh **paged** [`KvCache`]: `batch` rows that draw fixed-size
    /// blocks of `block_size` token positions from a shared pool of
    /// `pool_blocks` as they grow, instead of reserving `horizon`
    /// positions each up front. Decoding through it is bit-identical to
    /// the contiguous layout — only the memory shape differs.
    pub fn new_cache_paged(
        &self,
        batch: usize,
        horizon: usize,
        block_size: usize,
        pool_blocks: usize,
    ) -> Result<KvCache> {
        let capacity = horizon.clamp(1, self.cfg.seq_len);
        KvCache::new_paged(
            self.layers.len(),
            batch,
            capacity,
            self.cfg.d_model,
            block_size,
            pool_blocks,
        )
    }

    /// Bytes one cached request row costs across all layers (K + V) —
    /// what the serving layer's batch cap is computed from.
    pub fn cache_row_bytes(&self) -> usize {
        KvCache::row_bytes(self.layers.len(), self.cfg.seq_len, self.cfg.d_model)
    }

    /// Bytes one paged KV block of `block_size` token positions costs
    /// across all layers (K + V) — what the paged scheduler's pool is
    /// sized from.
    pub fn kv_block_bytes(&self, block_size: usize) -> usize {
        KvCache::block_bytes(self.layers.len(), block_size, self.cfg.d_model)
    }

    /// Incremental forward: logits (R, T_new, V) for `t_new` **new** token
    /// positions per row, appended after each row's cached prefix.
    ///
    /// `tokens` is (R, T_new) with `R == rows.len()`; `rows[i]` names the
    /// cache row the i-th input row extends, so finished requests drop out
    /// of the step batch without disturbing the others. Rows must be
    /// strictly increasing (each cache row extended at most once per
    /// call). New keys/values land in `cache` and the live lengths
    /// advance by `t_new` — prefill a prompt by passing it whole (or in
    /// chunks), then step one token at a time.
    pub fn forward_incremental(
        &self,
        tokens: &Tensor,
        cache: &mut KvCache,
        rows: &[usize],
    ) -> Result<Tensor> {
        self.forward_incremental_tagged(tokens, cache, rows, &[])
    }

    /// [`Engine::forward_incremental`] with a per-request adapter tag:
    /// `adapters[i]` selects the weight surface request row `i` runs
    /// through (0 = bare base, `k ≥ 1` = the k-th registered ternary
    /// adapter). An empty slice means all-base. Rows with different tags
    /// may share one call — every kernel is per-row independent, so each
    /// row's logits bit-equal a solo call under its own adapter
    /// (`tests/adapters.rs` pins the end-to-end claim).
    pub fn forward_incremental_tagged(
        &self,
        tokens: &Tensor,
        cache: &mut KvCache,
        rows: &[usize],
        adapters: &[u32],
    ) -> Result<Tensor> {
        self.forward_incremental_profiled(tokens, cache, rows, adapters, None)
    }

    /// [`Engine::forward_incremental_tagged`] with an optional
    /// [`Profiler`] marking kernel-phase boundaries as the forward runs.
    /// `None` is the production default and costs one never-taken branch
    /// per phase; `Some` is pinned bitwise invisible on outputs
    /// (`tests/obs.rs`) — the profiler only reads clocks between phases
    /// (and forces profiled GEMMs single-threaded, which never changes
    /// bits). Phase boundaries land on the caller's open profiler window;
    /// the scheduler opens/closes that window with the same `Instant`s it
    /// stamps `StepReport` forward wall-times from, so the per-layer
    /// segments tile those wall-times exactly.
    pub fn forward_incremental_profiled(
        &self,
        tokens: &Tensor,
        cache: &mut KvCache,
        rows: &[usize],
        adapters: &[u32],
        prof: Option<&Profiler>,
    ) -> Result<Tensor> {
        let cfg = &self.cfg;
        if tokens.shape().len() != 2 {
            bail!("incremental forward wants (R, T_new) tokens, got {:?}", tokens.shape());
        }
        let (r, t_new) = (tokens.shape()[0], tokens.shape()[1]);
        if r == 0 || t_new == 0 {
            bail!("incremental forward wants at least one row and one new position");
        }
        if r != rows.len() {
            bail!("{r} token rows for {} cache rows", rows.len());
        }
        if !adapters.is_empty() && adapters.len() != rows.len() {
            bail!("{} adapter tags for {} rows", adapters.len(), rows.len());
        }
        if let Some(&bad) = adapters.iter().find(|&&a| a as usize > self.adapter_names.len()) {
            bail!("adapter id {bad} outside registered range 0..={}", self.adapter_names.len());
        }
        cache.check(self.layers.len(), cfg.d_model, cfg.seq_len)?;
        for w in rows.windows(2) {
            if w[0] >= w[1] {
                bail!("cache rows must be strictly increasing, got {rows:?}");
            }
        }
        if let Some(&last) = rows.last() {
            if last >= cache.batch() {
                bail!("cache row {last} outside batch {}", cache.batch());
            }
        }
        for &row in rows {
            if cache.pos_len(row) + t_new > cache.capacity() {
                bail!(
                    "row {row}: {} cached + {t_new} new positions exceed cache capacity {}",
                    cache.pos_len(row),
                    cache.capacity()
                );
            }
        }
        let d = cfg.d_model;
        // absolute position of each row's first new token — fixed for the
        // whole call; cache lengths advance only after the last layer
        let bases: Vec<usize> = rows.iter().map(|&row| cache.pos_len(row)).collect();

        // embedding + position table, offset per row by its cached prefix
        let mut x = vec![0.0f32; r * t_new * d];
        for (i, &base) in bases.iter().enumerate() {
            for ti in 0..t_new {
                let id = tokens.data()[i * t_new + ti];
                if id < 0.0 || id.fract() != 0.0 || id as usize >= cfg.vocab {
                    bail!("token {id} at ({i},{ti}) outside vocab {}", cfg.vocab);
                }
                let row = &mut x[(i * t_new + ti) * d..(i * t_new + ti + 1) * d];
                let erow = self.embed.row(id as usize);
                let prow = self.pos.row(base + ti);
                for k in 0..d {
                    row[k] = erow[k] + prow[k];
                }
            }
        }
        let mut x = Tensor::new(&[r * t_new, d], x);
        // validation + embedding lookup belong to no layer — step scope
        if let Some(p) = prof {
            p.mark(STEP_TID, PhaseKind::Other, Instant::now());
        }

        // paged layout: grab any blocks the new positions need now that
        // every input is validated — a dry pool fails clean with the page
        // tables rolled back and nothing written (no-op when contiguous).
        // Timed into the cache's alloc-wall accumulator so the tracer can
        // attribute step time to block allocation; the contiguous layout
        // skips even the clock reads.
        if cache.is_paged() {
            let t_alloc = std::time::Instant::now();
            cache.ensure_blocks(rows, t_new)?;
            cache.note_alloc_wall(t_alloc.elapsed().as_secs_f64());
        } else {
            cache.ensure_blocks(rows, t_new)?;
        }
        // layout-resolved addressing, identical for every layer: where
        // each new position's K/V row lands, and the storage runs backing
        // each request's prefix + new positions in logical order
        let mut dsts: Vec<usize> = Vec::with_capacity(r * t_new);
        let mut segs: Vec<Vec<(usize, usize, usize)>> = Vec::with_capacity(r);
        for (i, &row) in rows.iter().enumerate() {
            for ti in 0..t_new {
                dsts.push(cache.pos_base(row, bases[i] + ti));
            }
            segs.push(cache.segments(row, bases[i] + t_new));
        }
        // block allocation + page-table address resolution — KV paging
        // work at step scope, before any layer runs
        if let Some(p) = prof {
            p.mark(STEP_TID, PhaseKind::KvPage, Instant::now());
        }

        // expand per-request tags to activation rows (row i owns
        // activation rows i·t_new .. (i+1)·t_new); all-base collapses to
        // the empty tag slice so the pre-adapter fast path stays intact
        let tags: Vec<u32> = if adapters.iter().all(|&a| a == 0) {
            Vec::new()
        } else {
            adapters.iter().flat_map(|&a| std::iter::repeat(a).take(t_new)).collect()
        };

        for (li, layer) in self.layers.iter().enumerate() {
            x = self
                .block_incremental(&x, layer, li, cache, &bases, t_new, &dsts, &segs, &tags, prof)?;
        }
        let x = layernorm(&x, &self.lnf_w, &self.lnf_b);
        let logits = linalg::matmul(&x, &self.head);
        cache.advance(rows, t_new);
        // final layernorm + vocab head + cache advance — step scope; the
        // gap from here to the scheduler's window close (argmax, picks)
        // lands in the same (STEP_TID, other) bucket at end_window
        if let Some(p) = prof {
            p.mark(STEP_TID, PhaseKind::Other, Instant::now());
        }
        Ok(logits.reshape(&[r, t_new, cfg.vocab]))
    }

    /// One transformer block over new positions only: same kernels and
    /// accumulation order as [`Engine::block`], but K/V for the prefix come
    /// from the cache instead of being recomputed. Storage is addressed
    /// through `dsts` (slab offset of each new position's K/V row) and
    /// `segs` (per request, the storage runs backing its prefix + new
    /// positions in logical order) — one run for a contiguous cache, one
    /// per block for a paged one. Positions are visited in the same
    /// logical order either way, so the layouts are bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn block_incremental(
        &self,
        x: &Tensor,
        layer: &Layer,
        li: usize,
        cache: &mut KvCache,
        bases: &[usize],
        t_new: usize,
        dsts: &[usize],
        segs: &[Vec<(usize, usize, usize)>],
        tags: &[u32],
        prof: Option<&Profiler>,
    ) -> Result<Tensor> {
        let cfg = &self.cfg;
        let (d, h, hd) = (cfg.d_model, cfg.n_heads, cfg.head_dim());
        let r = bases.len();
        let cap = cache.capacity();
        let kprof = prof.map(|p| p.kernel());
        let tid = li as u64;

        let xn = layernorm(x, &layer.ln1_w, &layer.ln1_b);
        let q = self.linear(&xn, layer, WQ, tags, kprof);
        let k = self.linear(&xn, layer, WK, tags, kprof);
        let v = self.linear(&xn, layer, WV, tags, kprof);
        // ln1 + the three projections; the profiler splits out the
        // in-kernel dequant/overlay ns accumulated since the last mark
        if let Some(p) = prof {
            p.mark(tid, PhaseKind::GemmQkv, Instant::now());
        }

        // append phase: the new K/V rows join the cached prefix — these are
        // exactly the values the full forward computes at these positions
        {
            let (ck, cv) = cache.layer_mut(li);
            for i in 0..r {
                for ti in 0..t_new {
                    let src = (i * t_new + ti) * d;
                    let dst = dsts[i * t_new + ti];
                    ck[dst..dst + d].copy_from_slice(&k.data()[src..src + d]);
                    cv[dst..dst + d].copy_from_slice(&v.data()[src..src + d]);
                }
            }
        }
        // K/V rows landing in their (possibly paged) cache slots
        if let Some(p) = prof {
            p.mark(tid, PhaseKind::KvPage, Instant::now());
        }

        // attention: each new position attends over the cached prefix plus
        // the new positions written above, gathered run by run through the
        // page table — identical summation order to the full forward's
        // causal loop
        let (ck, cv) = cache.layer(li);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; r * t_new * d];
        let mut scores = vec![0.0f32; cap];
        for i in 0..r {
            for hi in 0..h {
                let off = hi * hd;
                for ti in 0..t_new {
                    let qrow =
                        &q.data()[(i * t_new + ti) * d + off..(i * t_new + ti) * d + off + hd];
                    let t_abs = bases[i] + ti;
                    let mut maxv = f32::NEG_INFINITY;
                    for &(pos0, n, base) in &segs[i] {
                        if pos0 > t_abs {
                            break;
                        }
                        let take = n.min(t_abs + 1 - pos0);
                        for jj in 0..take {
                            let krow = &ck[base + jj * d + off..base + jj * d + off + hd];
                            let mut dot = 0.0f32;
                            for e in 0..hd {
                                dot += qrow[e] * krow[e];
                            }
                            let s = dot * scale;
                            scores[pos0 + jj] = s;
                            maxv = maxv.max(s);
                        }
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut().take(t_abs + 1) {
                        *s = (*s - maxv).exp();
                        denom += *s;
                    }
                    let orow =
                        &mut attn[(i * t_new + ti) * d + off..(i * t_new + ti) * d + off + hd];
                    for &(pos0, n, base) in &segs[i] {
                        if pos0 > t_abs {
                            break;
                        }
                        let take = n.min(t_abs + 1 - pos0);
                        for jj in 0..take {
                            let w = scores[pos0 + jj] / denom;
                            let vrow = &cv[base + jj * d + off..base + jj * d + off + hd];
                            for e in 0..hd {
                                orow[e] += w * vrow[e];
                            }
                        }
                    }
                }
            }
        }
        let attn = Tensor::new(&[r * t_new, d], attn);
        // the score/softmax/AXPY loops over the gathered prefix
        if let Some(p) = prof {
            p.mark(tid, PhaseKind::Attention, Instant::now());
        }
        let x = x.add(&self.linear(&attn, layer, WO, tags, kprof));
        // output projection + residual add
        if let Some(p) = prof {
            p.mark(tid, PhaseKind::GemmO, Instant::now());
        }

        let xn = layernorm(&x, &layer.ln2_w, &layer.ln2_b);
        let hmid = self.linear(&xn, layer, W_UP, tags, kprof).map(gelu_tanh);
        let out = x.add(&self.linear(&hmid, layer, W_DOWN, tags, kprof));
        // ln2 + up-projection + GELU + down-projection + residual
        if let Some(p) = prof {
            p.mark(tid, PhaseKind::GemmMlp, Instant::now());
        }
        Ok(out)
    }

    /// The weight surface activation rows tagged `tag` read in this
    /// (layer, slot): the bare base for 0, base + that adapter's ternary
    /// delta otherwise.
    fn slot_view<'a>(&self, layer: &'a Layer, slot: usize, tag: u32) -> PackedView<'a> {
        let base = &layer.slots[slot];
        match tag {
            0 => PackedView::base_only(base),
            t => PackedView::with_delta(base, &layer.adapters[(t - 1) as usize][slot]),
        }
    }

    /// One quantized linear, with the optional LoRA contribution
    /// (`α/r = 2`, matching the graphs) riding on top. `tags` gives each
    /// activation row's adapter id (empty = all base): a uniform batch
    /// runs one fused GEMM through that adapter's [`PackedView`]; a mixed
    /// batch is partitioned by adapter — gather rows, one GEMM per
    /// adapter present, scatter back. Per-row kernel independence
    /// (`row_slices_match_batched_call_bitwise` in `gemm.rs`) makes the
    /// partition bit-invisible: every row gets exactly the bits a
    /// solo call under its adapter would produce.
    ///
    /// `kprof` (profiled forwards only) attaches in-kernel sub-phase
    /// timing to the GEMM's weight view and forces it single-threaded so
    /// the timed sub-intervals stay disjoint — bitwise free either way.
    fn linear(
        &self,
        x: &Tensor,
        layer: &Layer,
        slot: usize,
        tags: &[u32],
        kprof: Option<&KernelProf>,
    ) -> Tensor {
        let mut y = self.linear_quant(x, layer, slot, tags, kprof);
        if let Some(lora) = &layer.lora {
            let (a, b) = &lora[slot];
            let contrib = linalg::matmul(&linalg::matmul(x, a), b).scale(2.0);
            y = y.add(&contrib);
        }
        y
    }

    fn linear_quant(
        &self,
        x: &Tensor,
        layer: &Layer,
        slot: usize,
        tags: &[u32],
        kprof: Option<&KernelProf>,
    ) -> Tensor {
        // profiled runs pin the column-chunk thread count to 1: thread
        // choice never changes output bits (gemm.rs pins it), and the
        // KernelProf sub-intervals must not overlap in wall time
        let threads = if kprof.is_some() { Some(1) } else { None };
        let first = tags.first().copied().unwrap_or(0);
        if tags.iter().all(|&t| t == first) {
            let view = self.slot_view(layer, slot, first).with_prof(kprof);
            return matmul_packed_view(x, view, self.gemm, threads);
        }
        debug_assert_eq!(tags.len(), x.rows());
        let (m, din) = (x.rows(), x.cols());
        let dout = layer.slots[slot].dout();
        let mut out = vec![0.0f32; m * dout];
        let mut present: Vec<u32> = tags.to_vec();
        present.sort_unstable();
        present.dedup();
        for tag in present {
            let picked: Vec<usize> = (0..m).filter(|&i| tags[i] == tag).collect();
            let mut sub = vec![0.0f32; picked.len() * din];
            for (k, &i) in picked.iter().enumerate() {
                sub[k * din..(k + 1) * din].copy_from_slice(x.row(i));
            }
            let sub = Tensor::new(&[picked.len(), din], sub);
            let view = self.slot_view(layer, slot, tag).with_prof(kprof);
            let y = matmul_packed_view(&sub, view, self.gemm, threads);
            for (k, &i) in picked.iter().enumerate() {
                out[i * dout..(i + 1) * dout].copy_from_slice(&y.data()[k * dout..(k + 1) * dout]);
            }
        }
        Tensor::new(&[m, dout], out)
    }

    fn block(&self, x: &Tensor, layer: &Layer, b: usize, t: usize, tags: &[u32]) -> Result<Tensor> {
        let cfg = &self.cfg;
        let (d, h, hd) = (cfg.d_model, cfg.n_heads, cfg.head_dim());

        let xn = layernorm(x, &layer.ln1_w, &layer.ln1_b);
        let q = self.linear(&xn, layer, WQ, tags, None);
        let k = self.linear(&xn, layer, WK, tags, None);
        let v = self.linear(&xn, layer, WV, tags, None);

        // causal multi-head attention over the (B·T, D) activations
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; b * t * d];
        let mut scores = vec![0.0f32; t];
        for bi in 0..b {
            for hi in 0..h {
                let off = hi * hd;
                for ti in 0..t {
                    let qrow = &q.data()[(bi * t + ti) * d + off..(bi * t + ti) * d + off + hd];
                    // causal mask: softmax over positions 0..=ti only —
                    // numerically identical to the graphs' -1e30 fill,
                    // whose masked terms underflow to exactly 0 in f32
                    let mut maxv = f32::NEG_INFINITY;
                    for (tj, s) in scores.iter_mut().enumerate().take(ti + 1) {
                        let krow =
                            &k.data()[(bi * t + tj) * d + off..(bi * t + tj) * d + off + hd];
                        let mut dot = 0.0f32;
                        for e in 0..hd {
                            dot += qrow[e] * krow[e];
                        }
                        *s = dot * scale;
                        maxv = maxv.max(*s);
                    }
                    let mut denom = 0.0f32;
                    for s in scores.iter_mut().take(ti + 1) {
                        *s = (*s - maxv).exp();
                        denom += *s;
                    }
                    let orow = &mut attn[(bi * t + ti) * d + off..(bi * t + ti) * d + off + hd];
                    for (tj, s) in scores.iter().enumerate().take(ti + 1) {
                        let w = s / denom;
                        let vrow =
                            &v.data()[(bi * t + tj) * d + off..(bi * t + tj) * d + off + hd];
                        for e in 0..hd {
                            orow[e] += w * vrow[e];
                        }
                    }
                }
            }
        }
        let attn = Tensor::new(&[b * t, d], attn);
        let x = x.add(&self.linear(&attn, layer, WO, tags, None));

        let xn = layernorm(&x, &layer.ln2_w, &layer.ln2_b);
        let hmid = self.linear(&xn, layer, W_UP, tags, None).map(gelu_tanh);
        Ok(x.add(&self.linear(&hmid, layer, W_DOWN, tags, None)))
    }
}

/// Layer norm over the last axis, `eps = 1e-5` (matches `_layernorm` in
/// the graphs).
pub(crate) fn layernorm(x: &Tensor, w: &[f32], b: &[f32]) -> Tensor {
    let d = w.len();
    let m = x.len() / d;
    let mut out = vec![0.0f32; x.len()];
    for mi in 0..m {
        let row = &x.data()[mi * d..(mi + 1) * d];
        let mu = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        let orow = &mut out[mi * d..(mi + 1) * d];
        for k in 0..d {
            orow[k] = (row[k] - mu) * inv * w[k] + b[k];
        }
    }
    Tensor::new(&[m, d], out)
}

/// Tanh-approximate GELU — `jax.nn.gelu`'s default, which the lowered
/// graphs bake in.
pub(crate) fn gelu_tanh(v: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/π)
    0.5 * v * (1.0 + (C * (v + 0.044_715 * v * v * v)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn tiny_engine(seed: u64) -> (ModelConfig, ParamStore, Engine) {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        let engine = Engine::from_store(&cfg, &store, 4).unwrap();
        (cfg, store, engine)
    }

    fn rand_tokens(cfg: &ModelConfig, b: usize, t: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(&[b, t], (0..b * t).map(|_| rng.below(cfg.vocab) as f32).collect())
    }

    /// Dense reference: same math with dequantized f32 matrices via
    /// `linalg::matmul` — the unpack-then-matmul path the engine replaces.
    fn dense_forward(cfg: &ModelConfig, store: &ParamStore, tokens: &Tensor) -> Tensor {
        let (b, t, d) = (tokens.shape()[0], tokens.shape()[1], cfg.d_model);
        let embed = store.get("embed").unwrap();
        let pos = store.get("pos").unwrap();
        let mut x = vec![0.0f32; b * t * d];
        for bi in 0..b {
            for ti in 0..t {
                let id = tokens.data()[bi * t + ti] as usize;
                for k in 0..d {
                    x[(bi * t + ti) * d + k] = embed.row(id)[k] + pos.row(ti)[k];
                }
            }
        }
        let mut x = Tensor::new(&[b * t, d], x);
        for li in 0..cfg.n_layers {
            let dense: Vec<Tensor> = SLOTS
                .iter()
                .map(|s| model::quant_layer(cfg, store, s, li, 4).unwrap().dequantize())
                .collect();
            let lin = |inp: &Tensor, slot: usize| linalg::matmul(inp, &dense[slot]);
            let xn = layernorm(&x, store.get("ln1_w").unwrap().row(li), store.get("ln1_b").unwrap().row(li));
            let q = lin(&xn, WQ);
            let k = lin(&xn, WK);
            let v = lin(&xn, WV);
            let (h, hd) = (cfg.n_heads, cfg.head_dim());
            let scale = 1.0 / (hd as f32).sqrt();
            let mut attn = vec![0.0f32; b * t * d];
            for bi in 0..b {
                for hi in 0..h {
                    let off = hi * hd;
                    for ti in 0..t {
                        let mut sc = vec![0.0f32; ti + 1];
                        let mut maxv = f32::NEG_INFINITY;
                        for (tj, s) in sc.iter_mut().enumerate() {
                            let mut dot = 0.0f32;
                            for e in 0..hd {
                                dot += q.at2(bi * t + ti, off + e) * k.at2(bi * t + tj, off + e);
                            }
                            *s = dot * scale;
                            maxv = maxv.max(*s);
                        }
                        let denom: f32 = sc.iter_mut().map(|s| { *s = (*s - maxv).exp(); *s }).sum();
                        for (tj, s) in sc.iter().enumerate() {
                            for e in 0..hd {
                                attn[(bi * t + ti) * d + off + e] +=
                                    s / denom * v.at2(bi * t + tj, off + e);
                            }
                        }
                    }
                }
            }
            let attn = Tensor::new(&[b * t, d], attn);
            x = x.add(&lin(&attn, WO));
            let xn = layernorm(&x, store.get("ln2_w").unwrap().row(li), store.get("ln2_b").unwrap().row(li));
            let hmid = lin(&xn, W_UP).map(gelu_tanh);
            x = x.add(&lin(&hmid, W_DOWN));
        }
        let x = layernorm(&x, store.get("lnf_w").unwrap().data(), store.get("lnf_b").unwrap().data());
        linalg::matmul(&x, store.get("head").unwrap()).reshape(&[b, t, cfg.vocab])
    }

    #[test]
    fn fused_forward_matches_dense_reference() {
        let (cfg, store, engine) = tiny_engine(1);
        for (b, t) in [(1usize, 5usize), (3, 17), (5, 64)] {
            let tokens = rand_tokens(&cfg, b, t, 7 + b as u64);
            let got = engine.forward(&tokens).unwrap();
            let want = dense_forward(&cfg, &store, &tokens);
            assert_eq!(got.shape(), &[b, t, cfg.vocab]);
            assert!(
                got.allclose(&want, 1e-3, 1e-3),
                "b={b} t={t}: max diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn arbitrary_batch_sizes_accepted() {
        let (cfg, _, engine) = tiny_engine(2);
        for b in [1usize, 3, 5, 11] {
            let logits = engine.forward(&rand_tokens(&cfg, b, 9, b as u64)).unwrap();
            assert_eq!(logits.shape(), &[b, 9, cfg.vocab]);
            assert!(logits.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn forward_is_deterministic() {
        let (cfg, _, engine) = tiny_engine(3);
        let tokens = rand_tokens(&cfg, 2, 12, 9);
        assert_eq!(engine.forward(&tokens).unwrap(), engine.forward(&tokens).unwrap());
    }

    #[test]
    fn lora_path_changes_logits() {
        let (cfg, store, mut engine) = tiny_engine(4);
        let mut with_adapters = store.clone();
        let mut rng = Rng::new(5);
        model::init_adapters(&cfg, crate::config::Method::Lora, &mut rng, &mut with_adapters);
        // force a non-trivial B so the adapter actually contributes
        for slot in SLOTS {
            let name = format!("lo_{slot}_b");
            let t = with_adapters.get_mut(&name).unwrap();
            for v in t.data_mut() {
                *v = 0.01;
            }
        }
        let tokens = rand_tokens(&cfg, 2, 8, 6);
        let merged_logits = engine.forward(&tokens).unwrap();
        engine.attach_lora(&with_adapters).unwrap();
        assert!(engine.has_lora());
        let lora_logits = engine.forward(&tokens).unwrap();
        assert!(merged_logits.max_abs_diff(&lora_logits) > 1e-4);
    }

    #[test]
    fn rejects_bad_tokens_and_shapes() {
        let (cfg, _, engine) = tiny_engine(6);
        assert!(engine.forward(&Tensor::zeros(&[4])).is_err());
        assert!(engine.forward(&Tensor::zeros(&[1, cfg.seq_len + 1])).is_err());
        let bad = Tensor::full(&[1, 4], cfg.vocab as f32);
        assert!(engine.forward(&bad).is_err());
    }

    #[test]
    fn incremental_forward_is_bitwise_identical_to_full() {
        let (cfg, _, engine) = tiny_engine(10);
        let (b, t) = (3usize, 20usize);
        let tokens = rand_tokens(&cfg, b, t, 21);
        let full = engine.forward(&tokens).unwrap();

        // prefill 13 positions in one call, then one token at a time
        let mut cache = engine.new_cache(b);
        let rows: Vec<usize> = (0..b).collect();
        let split = 13usize;
        let mut prefix = vec![0.0f32; b * split];
        for bi in 0..b {
            prefix[bi * split..(bi + 1) * split]
                .copy_from_slice(&tokens.data()[bi * t..bi * t + split]);
        }
        let got = engine
            .forward_incremental(&Tensor::new(&[b, split], prefix), &mut cache, &rows)
            .unwrap();
        assert_eq!(got.shape(), &[b, split, cfg.vocab]);
        let v = cfg.vocab;
        for bi in 0..b {
            for ti in 0..split {
                assert_eq!(
                    &got.data()[(bi * split + ti) * v..(bi * split + ti + 1) * v],
                    &full.data()[(bi * t + ti) * v..(bi * t + ti + 1) * v],
                    "prefill logits differ at ({bi},{ti})"
                );
            }
        }
        for ti in split..t {
            let step: Vec<f32> = (0..b).map(|bi| tokens.data()[bi * t + ti]).collect();
            let got = engine
                .forward_incremental(&Tensor::new(&[b, 1], step), &mut cache, &rows)
                .unwrap();
            for bi in 0..b {
                assert_eq!(
                    &got.data()[bi * v..(bi + 1) * v],
                    &full.data()[(bi * t + ti) * v..(bi * t + ti + 1) * v],
                    "step logits differ at ({bi},{ti})"
                );
            }
        }
        assert_eq!(cache.pos_len(0), t);
    }

    #[test]
    fn incremental_forward_with_lora_matches_full() {
        let (cfg, store, mut engine) = tiny_engine(11);
        let mut with_adapters = store.clone();
        let mut rng = Rng::new(12);
        model::init_adapters(&cfg, crate::config::Method::Lora, &mut rng, &mut with_adapters);
        for slot in SLOTS {
            let t = with_adapters.get_mut(&format!("lo_{slot}_b")).unwrap();
            for v in t.data_mut() {
                *v = 0.01;
            }
        }
        engine.attach_lora(&with_adapters).unwrap();
        let tokens = rand_tokens(&cfg, 2, 9, 13);
        let full = engine.forward(&tokens).unwrap();
        let mut cache = engine.new_cache(2);
        let mut got = Vec::new();
        for ti in 0..9 {
            let step: Vec<f32> = (0..2).map(|bi| tokens.data()[bi * 9 + ti]).collect();
            got.push(
                engine
                    .forward_incremental(&Tensor::new(&[2, 1], step), &mut cache, &[0, 1])
                    .unwrap(),
            );
        }
        let v = cfg.vocab;
        for (ti, g) in got.iter().enumerate() {
            for bi in 0..2 {
                assert_eq!(
                    &g.data()[bi * v..(bi + 1) * v],
                    &full.data()[(bi * 9 + ti) * v..(bi * 9 + ti + 1) * v]
                );
            }
        }
    }

    #[test]
    fn reused_slot_is_bit_identical_to_fresh_cache() {
        // the scheduler reclaims a finished request's cache row with
        // reset_row instead of reallocating: a replay on the dirty,
        // reused row must reproduce the fresh-cache logits bit for bit
        let (cfg, _, engine) = tiny_engine(15);
        let a = rand_tokens(&cfg, 1, 10, 16);
        let other = rand_tokens(&cfg, 1, 14, 17);
        let mut fresh = engine.new_cache(1);
        let want = engine.forward_incremental(&a, &mut fresh, &[0]).unwrap();
        let mut cache = engine.new_cache(1);
        engine.forward_incremental(&other, &mut cache, &[0]).unwrap();
        assert_eq!(cache.pos_len(0), 14);
        cache.reset_row(0);
        assert_eq!(cache.pos_len(0), 0);
        let got = engine.forward_incremental(&a, &mut cache, &[0]).unwrap();
        assert_eq!(got, want, "reused slot diverged from a fresh cache");
        assert_eq!(cache.pos_len(0), 10);
    }

    #[test]
    fn paged_incremental_is_bitwise_identical_to_contiguous() {
        // the paged layout changes where K/V rows live, not what they
        // hold: chunked prefill + stepping through a paged cache must
        // reproduce the contiguous cache's logits bit for bit, for block
        // sizes that divide the sequence and ones that don't
        let (cfg, _, engine) = tiny_engine(20);
        let (b, t) = (3usize, 21usize);
        let tokens = rand_tokens(&cfg, b, t, 22);
        let v = cfg.vocab;
        let mut contiguous = engine.new_cache(b);
        let rows: Vec<usize> = (0..b).collect();
        let mut want = Vec::new();
        for ti in 0..t {
            let step: Vec<f32> = (0..b).map(|bi| tokens.data()[bi * t + ti]).collect();
            want.push(
                engine
                    .forward_incremental(&Tensor::new(&[b, 1], step), &mut contiguous, &rows)
                    .unwrap(),
            );
        }
        for bs in [1usize, 5, 16] {
            let pool = b * cfg.seq_len.div_ceil(bs);
            let mut cache = engine.new_cache_paged(b, cfg.seq_len, bs, pool).unwrap();
            // prefill 8 positions in one chunk, then one token at a time —
            // chunks cross block boundaries for every bs here
            let split = 8usize;
            let mut prefix = vec![0.0f32; b * split];
            for bi in 0..b {
                prefix[bi * split..(bi + 1) * split]
                    .copy_from_slice(&tokens.data()[bi * t..bi * t + split]);
            }
            let got = engine
                .forward_incremental(&Tensor::new(&[b, split], prefix), &mut cache, &rows)
                .unwrap();
            for bi in 0..b {
                for ti in 0..split {
                    assert_eq!(
                        &got.data()[(bi * split + ti) * v..(bi * split + ti + 1) * v],
                        &want[ti].data()[bi * v..(bi + 1) * v],
                        "bs={bs}: paged prefill diverged at ({bi},{ti})"
                    );
                }
            }
            for ti in split..t {
                let step: Vec<f32> = (0..b).map(|bi| tokens.data()[bi * t + ti]).collect();
                let got = engine
                    .forward_incremental(&Tensor::new(&[b, 1], step), &mut cache, &rows)
                    .unwrap();
                assert_eq!(got, want[ti], "bs={bs}: paged step {ti} diverged");
            }
            assert_eq!(cache.pos_len(0), t);
            // every row holds exactly the blocks its length needs
            for bi in 0..b {
                assert_eq!(cache.row_block_ids(bi).len(), t.div_ceil(bs));
            }
        }
    }

    #[test]
    fn paged_pool_exhaustion_fails_before_writing() {
        let (cfg, _, engine) = tiny_engine(21);
        // one block of 4 positions total: a 5-token prefill cannot fit
        let mut cache = engine.new_cache_paged(1, cfg.seq_len, 4, 1).unwrap();
        let tokens = rand_tokens(&cfg, 1, 5, 23);
        assert!(engine.forward_incremental(&tokens, &mut cache, &[0]).is_err());
        assert_eq!(cache.pos_len(0), 0, "failed forward advanced the row");
        assert_eq!(cache.free_blocks(), Some(1), "failed forward leaked blocks");
        // a fitting prefill still works afterwards
        let short = rand_tokens(&cfg, 1, 3, 24);
        engine.forward_incremental(&short, &mut cache, &[0]).unwrap();
        assert_eq!(cache.pos_len(0), 3);
    }

    #[test]
    fn reused_paged_row_is_bit_identical_to_fresh_cache() {
        // reset_row hands a paged row's blocks back to the pool; a new
        // request on the reused row may land on different physical blocks
        // and must still decode bit-identically
        let (cfg, _, engine) = tiny_engine(22);
        let a = rand_tokens(&cfg, 1, 10, 25);
        let other = rand_tokens(&cfg, 1, 14, 26);
        let mut fresh = engine.new_cache_paged(1, cfg.seq_len, 4, 8).unwrap();
        let want = engine.forward_incremental(&a, &mut fresh, &[0]).unwrap();
        let mut cache = engine.new_cache_paged(1, cfg.seq_len, 4, 8).unwrap();
        engine.forward_incremental(&other, &mut cache, &[0]).unwrap();
        cache.reset_row(0);
        assert_eq!(cache.free_blocks(), Some(8));
        let got = engine.forward_incremental(&a, &mut cache, &[0]).unwrap();
        assert_eq!(got, want, "reused paged row diverged from a fresh cache");
    }

    #[test]
    fn incremental_rejects_bad_rows_and_overflow() {
        let (cfg, _, engine) = tiny_engine(12);
        let mut cache = engine.new_cache(2);
        let tok = Tensor::new(&[1, 1], vec![5.0]);
        // row outside the cache batch
        assert!(engine.forward_incremental(&tok, &mut cache, &[2]).is_err());
        // rows not strictly increasing
        let two = Tensor::new(&[2, 1], vec![5.0, 6.0]);
        assert!(engine.forward_incremental(&two, &mut cache, &[1, 0]).is_err());
        assert!(engine.forward_incremental(&two, &mut cache, &[1, 1]).is_err());
        // row/token count mismatch
        assert!(engine.forward_incremental(&two, &mut cache, &[0]).is_err());
        // cache built for a different shape
        let mut wrong = super::KvCache::new(1, 2, cfg.seq_len, cfg.d_model);
        assert!(engine.forward_incremental(&tok, &mut wrong, &[0]).is_err());
        // overflowing the context
        let mut cache = engine.new_cache(1);
        let long = Tensor::new(&[1, cfg.seq_len], vec![5.0; cfg.seq_len]);
        engine.forward_incremental(&long, &mut cache, &[0]).unwrap();
        assert!(engine.forward_incremental(&tok, &mut cache, &[0]).is_err());
    }

    #[test]
    fn incremental_skips_finished_rows_independently() {
        // rows evolve independently: stepping a subset leaves the others'
        // cached state untouched and still bit-identical to the full pass
        let (cfg, _, engine) = tiny_engine(14);
        let t = 8usize;
        let tokens = rand_tokens(&cfg, 3, t, 15);
        let full = engine.forward(&tokens).unwrap();
        let mut cache = engine.new_cache(3);
        // prefill rows 0..3 to t-1, then step only rows 0 and 2
        let mut prefix = vec![0.0f32; 3 * (t - 1)];
        for bi in 0..3 {
            prefix[bi * (t - 1)..(bi + 1) * (t - 1)]
                .copy_from_slice(&tokens.data()[bi * t..bi * t + t - 1]);
        }
        engine
            .forward_incremental(&Tensor::new(&[3, t - 1], prefix), &mut cache, &[0, 1, 2])
            .unwrap();
        let step: Vec<f32> = [0usize, 2]
            .iter()
            .map(|bi| tokens.data()[bi * t + t - 1])
            .collect();
        let got = engine
            .forward_incremental(&Tensor::new(&[2, 1], step), &mut cache, &[0, 2])
            .unwrap();
        let v = cfg.vocab;
        for (i, bi) in [0usize, 2].into_iter().enumerate() {
            assert_eq!(
                &got.data()[i * v..(i + 1) * v],
                &full.data()[(bi * t + t - 1) * v..(bi * t + t) * v],
                "row {bi} diverged when stepped in a partial batch"
            );
        }
        assert_eq!(cache.pos_len(0), t);
        assert_eq!(cache.pos_len(1), t - 1);
        assert_eq!(cache.pos_len(2), t);
    }

    #[test]
    fn profiled_incremental_forward_is_bitwise_identical_and_tiles() {
        use crate::obs::profiler::ForwardPhase;
        let (cfg, _, engine) = tiny_engine(30);
        let tokens = rand_tokens(&cfg, 2, 6, 31);
        let mut plain_cache = engine.new_cache(2);
        let want = engine.forward_incremental(&tokens, &mut plain_cache, &[0, 1]).unwrap();

        let prof = Profiler::new();
        let mut cache = engine.new_cache(2);
        prof.begin_window(ForwardPhase::Prefill, 0, Instant::now());
        let got = engine
            .forward_incremental_profiled(&tokens, &mut cache, &[0, 1], &[], Some(&prof))
            .unwrap();
        prof.end_window(Instant::now());
        // the profiler only reads clocks — logits are bit-identical
        assert_eq!(got, want);

        let ws = prof.windows();
        assert_eq!(ws.len(), 1);
        let w = &ws[0];
        // integer-duration segments tile the window exactly, and every
        // layer contributed each of its phase kinds
        assert_eq!(w.segments.values().sum::<std::time::Duration>(), w.total);
        for li in 0..cfg.n_layers {
            for kind in [
                PhaseKind::GemmQkv,
                PhaseKind::KvPage,
                PhaseKind::Attention,
                PhaseKind::GemmO,
                PhaseKind::GemmMlp,
            ] {
                assert!(
                    w.segments.contains_key(&(li as u64, kind)),
                    "layer {li} missing {kind:?}"
                );
            }
        }
        assert!(w.segments.contains_key(&(STEP_TID, PhaseKind::Other)));
        assert!(w.segments.contains_key(&(STEP_TID, PhaseKind::KvPage)));
    }

    #[test]
    fn deployed_bytes_far_below_f32() {
        let (cfg, _, engine) = tiny_engine(8);
        let f32_bytes: usize = cfg
            .slots()
            .iter()
            .map(|(_, din, dout)| din * dout * 4 * cfg.n_layers)
            .sum();
        // 4-bit grid ≈ f32/8 and the tiny preset's dense gs=16 tables add
        // another f32/8 — well under a third of the fp32 footprint
        assert!(engine.deployed_weight_bytes() < f32_bytes / 3);
    }
}
