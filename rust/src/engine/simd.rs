//! SIMD-accelerated packed-GEMM inner kernels, runtime-dispatched —
//! and the **lane-ordered accumulation contract** that keeps every one of
//! them bit-identical to the scalar reference.
//!
//! # The contract
//!
//! Floating-point addition is not associative, so "vectorize the dot
//! product" normally means "change the bits of the output". This repo's
//! parity discipline (cached vs recompute, paged vs contiguous, scheduled
//! vs one-shot — all pinned with `assert_eq!`, see `tests/engine_parity.rs`)
//! only survives a SIMD kernel if the scalar reference and the vector
//! kernels agree on an **exact** accumulation order. That order is:
//!
//! For a group of `gs` elements at offset `base`, with `LANES = 8`:
//!
//! 1. eight lane accumulators start at `0.0`;
//! 2. for each full 8-wide chunk `k`, lane `l` absorbs element
//!    `base + 8k + l` as a plain multiply **then** add —
//!    `lane[l] += x * c`, two IEEE roundings (`vmulps` + `vaddps`
//!    lane-wise). Deliberately *not* `f32::mul_add`: baseline x86-64
//!    carries no FMA instruction, so a fused contract would lower the
//!    scalar reference and any non-AVX2 build to one `fmaf` libcall per
//!    element — wrecking the fallback's throughput and inflating the
//!    perf gate's "SIMD vs scalar" ratio with call overhead;
//! 3. the tail (`gs % 8` elements) lands in lanes `0..gs % 8` the same
//!    way (an AVX2 masked load feeds `0.0` into the disabled lanes, and
//!    `lane + 0.0·0.0 = lane` bit-for-bit — a lane accumulator can
//!    never be `-0.0`, since round-to-nearest zero-sums produce `+0.0`
//!    and the lanes start there);
//! 4. lanes reduce in the fixed tree
//!    `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))` — the order an
//!    `extractf128 / movehl / shuffle` horizontal reduction performs.
//!
//! [`lane_dot`] is the executable statement of that contract: plain
//! scalar Rust, no intrinsics, no `unsafe`. The scalar reference kernel
//! in [`super::gemm`] accumulates through it (its lane-array inner loop
//! is exactly the shape autovectorizers eat, which is all a separate
//! "portable" kernel could be — so the Portable dispatch runs the same
//! body and only the AVX2 kernel is a distinct translation, instruction
//! for instruction). Change the contract in one place and both kernels
//! plus `tests/gemm_simd.rs` will tell you.
//!
//! # Dispatch
//!
//! [`resolve`] maps a requested [`GemmKernel`] (`auto|simd|scalar` — from
//! `ServeOptions`, the experiment TOML, `lota serve --gemm-kernel`, or
//! the `LOTA_GEMM_KERNEL` env var) to a concrete [`Dispatch`]:
//!
//! * `Avx2` — AVX2 intrinsics, 8 lanes per step, selected when
//!   `is_x86_feature_detected!` confirms the feature;
//! * `Portable` — the lane-array path on any architecture: same body as
//!   the reference (the contract loop is already the shape optimizers
//!   auto-vectorize), kept as a distinct dispatch so "best vector path"
//!   and "forced reference" stay separately addressable;
//! * `Scalar` — the reference kernel in `gemm.rs`, reachable via
//!   `--gemm-kernel scalar` / `LOTA_GEMM_KERNEL=scalar` so CI exercises
//!   the non-SIMD path on every PR.
//!
//! Because all three obey the contract, dispatch is a pure performance
//! choice: `assert_eq!` holds across kernels, thread counts, and batch
//! shapes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::config::GemmKernel;
use crate::tensor::Tensor;

use super::delta::PackedView;

/// Fixed vector width of the accumulation contract. Everything —
/// including the scalar reference — accumulates in 8 lanes, whatever the
/// hardware underneath.
pub const LANES: usize = 8;

/// A resolved kernel choice: which code path [`run_block`] executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dispatch {
    /// AVX2 intrinsics (x86-64 with the feature detected)
    Avx2,
    /// lane-array contract loop on any architecture (shares the
    /// reference body — the loop shape is what autovectorizers want)
    Portable,
    /// the reference kernel in `gemm.rs`, forced (never auto-selected)
    Scalar,
}

impl Dispatch {
    /// Short name surfaced in `ThroughputReport` / bench JSON.
    pub fn label(&self) -> &'static str {
        match self {
            Dispatch::Avx2 => "avx2",
            Dispatch::Portable => "portable",
            Dispatch::Scalar => "scalar",
        }
    }

    /// True for the vectorized paths (everything but the scalar reference).
    pub fn is_simd(&self) -> bool {
        !matches!(self, Dispatch::Scalar)
    }
}

/// Best vector kernel this host supports.
fn detect() -> Dispatch {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return Dispatch::Avx2;
        }
    }
    Dispatch::Portable
}

/// `LOTA_GEMM_KERNEL` env override, parsed once per process. An invalid
/// value is ignored (with a warning) rather than crashing serving.
fn env_override() -> Option<GemmKernel> {
    static ENV: OnceLock<Option<GemmKernel>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("LOTA_GEMM_KERNEL") {
        Ok(v) => match GemmKernel::parse(&v) {
            Ok(k) => Some(k),
            Err(_) => {
                log::warn!("ignoring invalid LOTA_GEMM_KERNEL='{v}' (auto|simd|scalar)");
                None
            }
        },
        Err(_) => None,
    })
}

/// Resolve a requested kernel to the path that will actually run.
///
/// An explicit `simd`/`scalar` request wins outright; `auto` defers to
/// `LOTA_GEMM_KERNEL` if set (the CI scalar-fallback leg), else hardware
/// detection. `simd` on hardware without AVX2 degrades to the portable
/// lane path — same bits, still autovectorizable.
pub fn resolve(requested: GemmKernel) -> Dispatch {
    match requested {
        GemmKernel::Scalar => Dispatch::Scalar,
        GemmKernel::Simd => detect(),
        GemmKernel::Auto => match env_override() {
            Some(GemmKernel::Scalar) => Dispatch::Scalar,
            Some(GemmKernel::Simd) => detect(),
            Some(GemmKernel::Auto) | None => detect(),
        },
    }
}

/// Blocks executed by a SIMD path (AVX2 or portable) since process start.
/// `tests/gemm_simd.rs` uses this to prove a forced-`scalar` override
/// really bypasses the vector kernels rather than merely matching their
/// bits (which it would anyway, by the contract).
static SIMD_BLOCKS: AtomicUsize = AtomicUsize::new(0);

/// Monotonic count of SIMD block-kernel invocations (test observability).
pub fn simd_blocks_run() -> usize {
    SIMD_BLOCKS.load(Ordering::Relaxed)
}

/// The contract's dot product: `Σ x[i]·c[i]` over equal-length slices in
/// lane order. This is the *definition* the AVX2 kernel implements —
/// scalar Rust, safe, plain multiply-then-add per element (see the
/// module docs for why the contract is deliberately unfused).
#[inline]
pub fn lane_dot(x: &[f32], c: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), c.len());
    let mut lanes = [0.0f32; LANES];
    let full = x.len() / LANES * LANES;
    let mut k = 0;
    while k < full {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x[k + l] * c[k + l];
        }
        k += LANES;
    }
    for (l, lane) in lanes.iter_mut().enumerate().take(x.len() - full) {
        *lane += x[full + l] * c[full + l];
    }
    reduce_lanes(lanes)
}

/// The contract's plain sum (used by the activation group-sums): same
/// lane assignment and reduction tree as [`lane_dot`], additions only.
#[inline]
pub fn lane_sum(x: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let full = x.len() / LANES * LANES;
    let mut k = 0;
    while k < full {
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += x[k + l];
        }
        k += LANES;
    }
    for (l, lane) in lanes.iter_mut().enumerate().take(x.len() - full) {
        *lane += x[full + l];
    }
    reduce_lanes(lanes)
}

/// The fixed horizontal reduction: `((l0+l4)+(l2+l6)) + ((l1+l5)+(l3+l7))`
/// — the add order of an `extractf128` + `movehl` + `shuffle` tree.
#[inline]
pub fn reduce_lanes(l: [f32; LANES]) -> f32 {
    let s0 = l[0] + l[4];
    let s1 = l[1] + l[5];
    let s2 = l[2] + l[6];
    let s3 = l[3] + l[7];
    (s0 + s2) + (s1 + s3)
}

/// Run the block kernel the dispatch selects over output columns
/// `[j0, j1)`. All three paths return bit-identical results; only the
/// instructions differ.
pub(crate) fn run_block(
    dispatch: Dispatch,
    x: &Tensor,
    xg: &[f32],
    w: PackedView,
    j0: usize,
    j1: usize,
) -> Vec<f32> {
    match dispatch {
        Dispatch::Scalar => super::gemm::gemm_block_scalar(x, xg, w, j0, j1),
        // the portable vector path *is* the reference body — its lane
        // loop is already the autovectorizable shape, and a duplicated
        // copy would only be a place for the contract to silently fork.
        // What distinguishes this arm is dispatch semantics (it counts
        // as a SIMD path and is what `simd` degrades to without AVX2).
        Dispatch::Portable => {
            SIMD_BLOCKS.fetch_add(1, Ordering::Relaxed);
            super::gemm::gemm_block_scalar(x, xg, w, j0, j1)
        }
        Dispatch::Avx2 => {
            SIMD_BLOCKS.fetch_add(1, Ordering::Relaxed);
            // SAFETY: `resolve` only hands out Avx2 after
            // `is_x86_feature_detected!` confirmed it (and the non-x86
            // stub below is plain safe code).
            unsafe { gemm_block_avx2(x, xg, w, j0, j1) }
        }
    }
}

/// Per-tail-length masks for `_mm256_maskload_ps`: index `r` enables
/// lanes `0..r` (sign bit set = load, clear = zero). `r = 0` is unused —
/// full groups never take the tail load.
#[cfg(target_arch = "x86_64")]
const TAIL_MASKS: [[i32; 8]; 8] = [
    [0, 0, 0, 0, 0, 0, 0, 0],
    [-1, 0, 0, 0, 0, 0, 0, 0],
    [-1, -1, 0, 0, 0, 0, 0, 0],
    [-1, -1, -1, 0, 0, 0, 0, 0],
    [-1, -1, -1, -1, 0, 0, 0, 0],
    [-1, -1, -1, -1, -1, 0, 0, 0],
    [-1, -1, -1, -1, -1, -1, 0, 0],
    [-1, -1, -1, -1, -1, -1, -1, 0],
];

/// AVX2 kernel: the contract, instruction for instruction. Unaligned
/// 8-wide loads of activations and decoded codes, `vmulps` + `vaddps`
/// into the lane accumulator (unfused, matching the contract's two
/// roundings), a masked load for the group tail, and the
/// `extractf128`/`movehl`/`shuffle` reduction whose add order
/// [`reduce_lanes`] mirrors.
///
/// # Safety
/// Caller must have verified `avx2` via `is_x86_feature_detected!`
/// (as [`resolve`] does).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn gemm_block_avx2(
    x: &Tensor,
    xg: &[f32],
    w: PackedView,
    j0: usize,
    j1: usize,
) -> Vec<f32> {
    use std::arch::x86_64::*;

    let (m, din) = (x.rows(), x.cols());
    let gs = w.group_size();
    let g = w.n_groups();
    let dout = w.dout();
    let (scales, zeros) = (w.scales(), w.zeros());
    let width = j1 - j0;
    let full = gs / LANES * LANES;
    let tail = gs - full;
    let tail_mask = _mm256_loadu_si256(TAIL_MASKS[tail].as_ptr() as *const __m256i);
    let mut out = vec![0.0f32; m * width];
    let mut codes = vec![0.0f32; din];
    let mut sbuf = vec![0.0f32; g];
    let mut zbuf = vec![0.0f32; g];
    for j in j0..j1 {
        w.decode_col_into(j, &mut codes);
        for (gi, (s, z)) in sbuf.iter_mut().zip(zbuf.iter_mut()).enumerate() {
            *s = scales[gi * dout + j];
            *z = zeros[gi * dout + j];
        }
        let cptr = codes.as_ptr();
        for mi in 0..m {
            let xrow = x.row(mi);
            let xptr = xrow.as_ptr();
            let xgrow = &xg[mi * g..(mi + 1) * g];
            let mut acc = 0.0f32;
            for gi in 0..g {
                let base = gi * gs;
                let mut lanes = _mm256_setzero_ps();
                let mut k = 0;
                while k < full {
                    let xv = _mm256_loadu_ps(xptr.add(base + k));
                    let cv = _mm256_loadu_ps(cptr.add(base + k));
                    lanes = _mm256_add_ps(lanes, _mm256_mul_ps(xv, cv));
                    k += LANES;
                }
                if tail != 0 {
                    // masked lanes load +0.0 on both sides: adding the
                    // +0.0 product leaves those accumulators untouched
                    // bit-for-bit (a lane can never hold -0.0 — see the
                    // module docs), matching the scalar contract's
                    // "tail goes into lanes 0..tail"
                    let xv = _mm256_maskload_ps(xptr.add(base + full), tail_mask);
                    let cv = _mm256_maskload_ps(cptr.add(base + full), tail_mask);
                    lanes = _mm256_add_ps(lanes, _mm256_mul_ps(xv, cv));
                }
                // horizontal reduction in the contract's tree order
                let lo = _mm256_castps256_ps128(lanes);
                let hi = _mm256_extractf128_ps(lanes, 1);
                let s = _mm_add_ps(lo, hi); // [l0+l4, l1+l5, l2+l6, l3+l7]
                let t = _mm_add_ps(s, _mm_movehl_ps(s, s)); // [s0+s2, s1+s3, ..]
                let d = _mm_add_ss(t, _mm_shuffle_ps(t, t, 0b01)); // t0 + t1
                let dot = _mm_cvtss_f32(d);
                acc += sbuf[gi] * dot + zbuf[gi] * xgrow[gi];
            }
            out[mi * width + (j - j0)] = acc;
        }
    }
    out
}

/// Off x86-64 the Avx2 dispatch is unreachable by construction
/// ([`detect`] never returns it there) — degrade to the reference body
/// rather than fail to compile or invoke UB.
#[cfg(not(target_arch = "x86_64"))]
unsafe fn gemm_block_avx2(
    x: &Tensor,
    xg: &[f32],
    w: PackedView,
    j0: usize,
    j1: usize,
) -> Vec<f32> {
    super::gemm::gemm_block_scalar(x, xg, w, j0, j1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lane_dot_matches_naive_within_tolerance_and_is_exact_on_integers() {
        // tolerance against the naive order (the orders differ)...
        let x: Vec<f32> = (0..37).map(|i| (i as f32 * 0.37).sin()).collect();
        let c: Vec<f32> = (0..37).map(|i| (i % 5) as f32).collect();
        let naive: f32 = x.iter().zip(&c).map(|(a, b)| a * b).sum();
        let laned = lane_dot(&x, &c);
        assert!((laned - naive).abs() < 1e-4, "{laned} vs {naive}");
        // ...but exact where every partial sum is representable
        let xi: Vec<f32> = (0..19).map(|i| (i % 7) as f32).collect();
        let ci: Vec<f32> = (0..19).map(|i| (i % 3) as f32).collect();
        let exact: f32 = xi.iter().zip(&ci).map(|(a, b)| a * b).sum();
        assert_eq!(lane_dot(&xi, &ci), exact);
    }

    #[test]
    fn lane_sum_handles_all_tail_lengths() {
        for n in 0..=24usize {
            let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
            let expect = (n * n.saturating_sub(1) / 2) as f32;
            assert_eq!(lane_sum(&x), expect, "n={n}");
        }
    }

    #[test]
    fn reduce_tree_is_the_documented_order() {
        // distinguishable values: any other association changes the bits
        let l = [1e8f32, 1.0, -1e8, 3.0, 5.0, 7.0, 11.0, 13.0];
        let expect = ((l[0] + l[4]) + (l[2] + l[6])) + ((l[1] + l[5]) + (l[3] + l[7]));
        assert_eq!(reduce_lanes(l), expect);
    }

    #[test]
    fn resolve_honors_explicit_requests() {
        assert_eq!(resolve(GemmKernel::Scalar), Dispatch::Scalar);
        let simd = resolve(GemmKernel::Simd);
        assert!(simd.is_simd(), "explicit simd may degrade to portable, never scalar");
        assert_ne!(simd.label(), "scalar");
    }

    #[test]
    fn dispatch_labels_are_stable() {
        assert_eq!(Dispatch::Avx2.label(), "avx2");
        assert_eq!(Dispatch::Portable.label(), "portable");
        assert_eq!(Dispatch::Scalar.label(), "scalar");
        assert!(Dispatch::Avx2.is_simd() && Dispatch::Portable.is_simd());
        assert!(!Dispatch::Scalar.is_simd());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn tail_masks_enable_exactly_the_first_r_lanes() {
        for (r, mask) in TAIL_MASKS.iter().enumerate() {
            for (l, v) in mask.iter().enumerate() {
                assert_eq!(*v == -1, l < r, "r={r} lane={l}");
            }
        }
    }
}
