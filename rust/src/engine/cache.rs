//! Per-request K/V buffers for incremental decoding.
//!
//! Recompute decoding re-runs attention over the whole prefix for every
//! generated token — O(T²) per request. [`KvCache`] is what makes decoding
//! linear: each layer keeps the already-computed key/value rows for every
//! request, so a decode step feeds only the *new* token positions and
//! attends against the stored prefix. The buffers hold exactly what the
//! full forward would have recomputed, bitwise — the engine writes the
//! same fused-GEMM outputs it would otherwise throw away — which is why
//! the cached and recompute paths can be pinned to identical logits.
//!
//! Layout: one `(batch, capacity, d_model)` f32 slab per layer for keys
//! and one for values, heads interleaved along `d_model` exactly as the
//! forward's attention reads them. `len[row]` tracks how many positions of
//! each request are live; positions past `len` are scratch (padded prefill
//! writes there and [`KvCache::truncate_row`] reclaims them) and are never
//! read before being overwritten.

use anyhow::{bail, Result};

/// Per-layer, per-request key/value buffers plus the live-position cursor
/// for each request row. Built with [`super::Engine::new_cache`]; advanced
/// by [`super::Engine::forward_incremental`].
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    batch: usize,
    /// maximum positions per row (the engine sizes this to `seq_len`)
    capacity: usize,
    d_model: usize,
    /// per-layer (batch, capacity, d_model) key rows
    k: Vec<Vec<f32>>,
    /// per-layer (batch, capacity, d_model) value rows
    v: Vec<Vec<f32>>,
    /// live cached positions per request row
    len: Vec<usize>,
}

impl KvCache {
    pub fn new(n_layers: usize, batch: usize, capacity: usize, d_model: usize) -> KvCache {
        let slab = batch * capacity * d_model;
        KvCache {
            n_layers,
            batch,
            capacity,
            d_model,
            k: (0..n_layers).map(|_| vec![0.0f32; slab]).collect(),
            v: (0..n_layers).map(|_| vec![0.0f32; slab]).collect(),
            len: vec![0; batch],
        }
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Live cached positions for request `row`.
    pub fn pos_len(&self, row: usize) -> usize {
        self.len[row]
    }

    /// Total bytes the K/V slabs hold across all layers.
    pub fn bytes(&self) -> usize {
        2 * self.n_layers * self.batch * self.capacity * self.d_model * 4
    }

    /// Bytes one request row costs across all layers (K + V) — what batch
    /// caps are computed from.
    pub fn row_bytes(n_layers: usize, capacity: usize, d_model: usize) -> usize {
        2 * n_layers * capacity * d_model * 4
    }

    /// Reclaim `row` for a brand-new request: drop every live position.
    /// The slab is *not* cleared — positions past `len` are scratch that a
    /// forward always writes before reading — so reuse costs O(1) instead
    /// of reallocating the whole cache, and a decode on a reused row is
    /// bit-identical to one on a fresh cache (pinned by the reuse
    /// regression in `engine::decode` and `tests/engine_parity.rs`). This
    /// is what lets the scheduler hand a finished request's slot to the
    /// next waiting request mid-generation.
    pub fn reset_row(&mut self, row: usize) {
        assert!(row < self.batch, "reset_row: row {row} outside batch {}", self.batch);
        self.len[row] = 0;
    }

    /// Shrink `row` back to `new_len` live positions. Used after a padded
    /// batch prefill (ragged prompts all advance by the padded length; the
    /// pad tail becomes scratch again) and by benches to re-time a step at
    /// a fixed prefix. Growing through this is a bug — positions can only
    /// be *written* by a forward.
    pub fn truncate_row(&mut self, row: usize, new_len: usize) {
        assert!(
            new_len <= self.len[row],
            "truncate_row can only shrink: row {row} has {} live positions, asked for {new_len}",
            self.len[row]
        );
        self.len[row] = new_len;
    }

    /// Advance the live length of each row in `rows` by `t_new` — called
    /// once per incremental forward, after every layer has written its new
    /// K/V rows against the *old* lengths.
    pub(crate) fn advance(&mut self, rows: &[usize], t_new: usize) {
        for &row in rows {
            self.len[row] += t_new;
            debug_assert!(self.len[row] <= self.capacity);
        }
    }

    /// The full K and V slabs for layer `li`.
    pub(crate) fn layer(&self, li: usize) -> (&[f32], &[f32]) {
        (&self.k[li], &self.v[li])
    }

    /// Mutable K and V slabs for layer `li` (the forward's append phase).
    pub(crate) fn layer_mut(&mut self, li: usize) -> (&mut [f32], &mut [f32]) {
        (&mut self.k[li], &mut self.v[li])
    }

    /// Refuse caches built for a different model shape. Capacity may be
    /// anything up to the engine's context length — a decode that knows
    /// its horizon (prompt + max_new) allocates only that much.
    pub(crate) fn check(&self, n_layers: usize, d_model: usize, max_capacity: usize) -> Result<()> {
        if self.n_layers != n_layers || self.d_model != d_model || self.capacity > max_capacity {
            bail!(
                "cache shape ({}, cap {}, d {}) does not fit engine ({n_layers}, cap ≤{max_capacity}, d {d_model})",
                self.n_layers,
                self.capacity,
                self.d_model
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_advance_and_truncate() {
        let mut c = KvCache::new(2, 3, 16, 8);
        assert_eq!(c.batch(), 3);
        assert_eq!(c.capacity(), 16);
        c.advance(&[0, 2], 5);
        assert_eq!(c.pos_len(0), 5);
        assert_eq!(c.pos_len(1), 0);
        assert_eq!(c.pos_len(2), 5);
        c.truncate_row(2, 3);
        assert_eq!(c.pos_len(2), 3);
        c.advance(&[2], 1);
        assert_eq!(c.pos_len(2), 4);
    }

    #[test]
    fn reset_reclaims_single_rows() {
        let mut c = KvCache::new(2, 3, 16, 8);
        c.advance(&[0, 1, 2], 7);
        c.reset_row(1);
        assert_eq!(c.pos_len(0), 7);
        assert_eq!(c.pos_len(1), 0);
        assert_eq!(c.pos_len(2), 7);
        // the reclaimed row advances again from zero, others undisturbed
        c.advance(&[1], 3);
        assert_eq!(c.pos_len(1), 3);
        assert_eq!(c.pos_len(0), 7);
    }

    #[test]
    #[should_panic]
    fn reset_row_bounds_checked() {
        let mut c = KvCache::new(1, 2, 8, 4);
        c.reset_row(2);
    }

    #[test]
    #[should_panic]
    fn truncate_cannot_grow() {
        let mut c = KvCache::new(1, 1, 8, 4);
        c.truncate_row(0, 1);
    }

    #[test]
    fn byte_accounting() {
        let c = KvCache::new(2, 3, 16, 8);
        assert_eq!(c.bytes(), 2 * 2 * 3 * 16 * 8 * 4);
        assert_eq!(KvCache::row_bytes(2, 16, 8), c.bytes() / 3);
    }

    #[test]
    fn shape_check_rejects_mismatches() {
        let c = KvCache::new(2, 1, 16, 8);
        assert!(c.check(2, 8, 16).is_ok());
        // shorter-than-context caches are fine (bounded-horizon decode)…
        assert!(c.check(2, 8, 32).is_ok());
        // …but wrong layer count, width, or an over-long cache are not
        assert!(c.check(3, 8, 16).is_err());
        assert!(c.check(2, 4, 16).is_err());
        assert!(c.check(2, 8, 8).is_err());
    }
}
