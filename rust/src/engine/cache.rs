//! Per-request K/V buffers for incremental decoding.
//!
//! Recompute decoding re-runs attention over the whole prefix for every
//! generated token — O(T²) per request. [`KvCache`] is what makes decoding
//! linear: each layer keeps the already-computed key/value rows for every
//! request, so a decode step feeds only the *new* token positions and
//! attends against the stored prefix. The buffers hold exactly what the
//! full forward would have recomputed, bitwise — the engine writes the
//! same fused-GEMM outputs it would otherwise throw away — which is why
//! the cached and recompute paths can be pinned to identical logits.
//!
//! Two storage layouts sit behind one addressing API:
//!
//! * **Contiguous** ([`KvCache::new`]) — one `(batch, capacity, d_model)`
//!   f32 slab per layer for keys and one for values; position `p` of row
//!   `r` lives at `(r·capacity + p)·d`. Simple, but every row pays for
//!   `capacity` positions whether it uses them or not. The reference
//!   layout the paged one is pinned bit-identical against.
//! * **Paged** ([`KvCache::new_paged`]) — the slabs are sliced into
//!   fixed-size blocks of `block_size` token positions drawn from a
//!   shared [`BlockAllocator`] pool; each row holds a page table mapping
//!   logical block index → physical block id, grown on demand as the
//!   forward appends positions. A short request holds blocks for its
//!   *actual* length, so the same memory budget carries far more
//!   concurrent rows. [`KvCache::reset_row`] / [`KvCache::truncate_row`]
//!   release blocks straight back to the pool.
//!
//! Either way, `len[row]` tracks how many positions of each request are
//! live; positions past `len` are scratch (padded prefill writes there
//! and [`KvCache::truncate_row`] reclaims them) and are never read
//! before being overwritten. The layouts address the same values in the
//! same iteration order, so which one backs a decode is unobservable in
//! the logits — `tests/kv_paged.rs` and `tests/engine_parity.rs` pin
//! that with `assert_eq!`, not a tolerance.

use anyhow::{bail, Result};

use super::blocks::{BlockAllocator, BlockCounters};

/// The paged layout's bookkeeping: the shared pool plus one page table
/// per request row.
#[derive(Clone, Debug)]
struct Paged {
    /// token positions per block
    block_size: usize,
    alloc: BlockAllocator,
    /// per-row physical block ids, in logical order (`tables[row][i]`
    /// backs positions `i·block_size .. (i+1)·block_size`)
    tables: Vec<Vec<usize>>,
}

/// Per-layer, per-request key/value buffers plus the live-position cursor
/// for each request row. Built with [`super::Engine::new_cache`] (or
/// [`super::Engine::new_cache_paged`]); advanced by
/// [`super::Engine::forward_incremental`].
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    batch: usize,
    /// maximum positions per row (the engine sizes this to `seq_len`)
    capacity: usize,
    d_model: usize,
    /// per-layer key slabs: `(batch, capacity, d_model)` contiguous, or
    /// `(pool_blocks, block_size, d_model)` paged
    k: Vec<Vec<f32>>,
    /// per-layer value slabs, same geometry as `k`
    v: Vec<Vec<f32>>,
    /// live cached positions per request row
    len: Vec<usize>,
    /// block pool + page tables; None selects the contiguous layout
    paged: Option<Paged>,
    /// cumulative wall time the forward spent growing page tables
    /// ([`KvCache::ensure_blocks`]), seconds — observability only, always
    /// 0.0 for the contiguous layout
    alloc_wall_secs: f64,
}

impl KvCache {
    /// A contiguous cache: every row owns `capacity` positions up front.
    pub fn new(n_layers: usize, batch: usize, capacity: usize, d_model: usize) -> KvCache {
        let slab = batch * capacity * d_model;
        KvCache {
            n_layers,
            batch,
            capacity,
            d_model,
            k: (0..n_layers).map(|_| vec![0.0f32; slab]).collect(),
            v: (0..n_layers).map(|_| vec![0.0f32; slab]).collect(),
            len: vec![0; batch],
            paged: None,
            alloc_wall_secs: 0.0,
        }
    }

    /// A paged cache: rows draw blocks of `block_size` positions from a
    /// shared pool of `pool_blocks` as they grow. `capacity` stays the
    /// per-row *logical* ceiling (positions a row may ever hold); the
    /// pool bounds how many positions all rows hold *together*.
    pub fn new_paged(
        n_layers: usize,
        batch: usize,
        capacity: usize,
        d_model: usize,
        block_size: usize,
        pool_blocks: usize,
    ) -> Result<KvCache> {
        if block_size == 0 {
            bail!("kv block size must be at least 1 token");
        }
        if pool_blocks == 0 {
            bail!("kv block pool must hold at least 1 block");
        }
        let slab = pool_blocks * block_size * d_model;
        Ok(KvCache {
            n_layers,
            batch,
            capacity,
            d_model,
            k: (0..n_layers).map(|_| vec![0.0f32; slab]).collect(),
            v: (0..n_layers).map(|_| vec![0.0f32; slab]).collect(),
            len: vec![0; batch],
            paged: Some(Paged {
                block_size,
                alloc: BlockAllocator::new(pool_blocks),
                tables: vec![Vec::new(); batch],
            }),
            alloc_wall_secs: 0.0,
        })
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Live cached positions for request `row`.
    pub fn pos_len(&self, row: usize) -> usize {
        self.len[row]
    }

    pub fn is_paged(&self) -> bool {
        self.paged.is_some()
    }

    /// Token positions per block (None for the contiguous layout).
    pub fn block_size(&self) -> Option<usize> {
        self.paged.as_ref().map(|p| p.block_size)
    }

    /// Free blocks left in the pool (None for the contiguous layout).
    pub fn free_blocks(&self) -> Option<usize> {
        self.paged.as_ref().map(|p| p.alloc.free_blocks())
    }

    /// Pool size in blocks (None for the contiguous layout).
    pub fn total_blocks(&self) -> Option<usize> {
        self.paged.as_ref().map(|p| p.alloc.total_blocks())
    }

    /// Cumulative pool traffic counters (None for the contiguous layout).
    pub fn block_counters(&self) -> Option<BlockCounters> {
        self.paged.as_ref().map(|p| p.alloc.counters())
    }

    /// Add `secs` of block-allocation wall time (the forward times its
    /// [`KvCache::ensure_blocks`] call when the layout is paged).
    pub(crate) fn note_alloc_wall(&mut self, secs: f64) {
        self.alloc_wall_secs += secs;
    }

    /// Cumulative wall time spent growing page tables, milliseconds.
    pub fn alloc_wall_ms(&self) -> f64 {
        self.alloc_wall_secs * 1e3
    }

    /// The physical block ids backing `row`, in logical order (empty for
    /// the contiguous layout). Exposed so the property harness can check
    /// page tables never alias across rows.
    pub fn row_block_ids(&self, row: usize) -> &[usize] {
        match &self.paged {
            Some(p) => &p.tables[row],
            None => &[],
        }
    }

    /// Total bytes the K/V slabs hold across all layers.
    pub fn bytes(&self) -> usize {
        let positions = match &self.paged {
            Some(p) => p.alloc.total_blocks() * p.block_size,
            None => self.batch * self.capacity,
        };
        2 * self.n_layers * positions * self.d_model * 4
    }

    /// Bytes one full-capacity request row costs across all layers
    /// (K + V) in the contiguous layout — what contiguous batch caps are
    /// computed from.
    pub fn row_bytes(n_layers: usize, capacity: usize, d_model: usize) -> usize {
        2 * n_layers * capacity * d_model * 4
    }

    /// Bytes one paged block costs across all layers (K + V) — what the
    /// paged scheduler's pool is sized from.
    pub fn block_bytes(n_layers: usize, block_size: usize, d_model: usize) -> usize {
        2 * n_layers * block_size * d_model * 4
    }

    /// Reclaim `row` for a brand-new request: drop every live position.
    /// The slab is *not* cleared — positions past `len` are scratch that a
    /// forward always writes before reading — so reuse costs O(1) in the
    /// contiguous layout and O(blocks held) in the paged one (every block
    /// goes back to the pool), and a decode on a reused row is
    /// bit-identical to one on a fresh cache (pinned by the reuse
    /// regression in `engine::decode` and `tests/engine_parity.rs`). This
    /// is what lets the scheduler hand a finished request's slot to the
    /// next waiting request mid-generation.
    pub fn reset_row(&mut self, row: usize) {
        assert!(row < self.batch, "reset_row: row {row} outside batch {}", self.batch);
        if let Some(p) = &mut self.paged {
            for id in p.tables[row].drain(..) {
                p.alloc.release(id);
            }
        }
        self.len[row] = 0;
    }

    /// Shrink `row` back to `new_len` live positions. Used after a padded
    /// batch prefill (ragged prompts all advance by the padded length; the
    /// pad tail becomes scratch again) and by benches to re-time a step at
    /// a fixed prefix. In the paged layout, blocks past the last one still
    /// covering a live position go straight back to the pool. Growing
    /// through this is a bug — positions can only be *written* by a
    /// forward.
    pub fn truncate_row(&mut self, row: usize, new_len: usize) {
        assert!(
            new_len <= self.len[row],
            "truncate_row can only shrink: row {row} has {} live positions, asked for {new_len}",
            self.len[row]
        );
        if let Some(p) = &mut self.paged {
            let keep = new_len.div_ceil(p.block_size);
            for id in p.tables[row].drain(keep..) {
                p.alloc.release(id);
            }
        }
        self.len[row] = new_len;
    }

    /// Grow `row` by `n` positions through the public surface: allocate
    /// any blocks the paged layout needs, then advance the live cursor.
    /// This is the entry point for the allocator property harness
    /// (`tests/kv_paged.rs`), which drives alloc/extend/truncate/reset
    /// sequences without an engine — the forward itself uses the internal
    /// [`KvCache::ensure_blocks`]/[`KvCache::advance`] pair because K/V
    /// must be written between the two.
    pub fn grow_row(&mut self, row: usize, n: usize) -> Result<()> {
        if row >= self.batch {
            bail!("grow_row: row {row} outside batch {}", self.batch);
        }
        if self.len[row] + n > self.capacity {
            bail!(
                "grow_row: {} live + {n} new positions exceed capacity {}",
                self.len[row],
                self.capacity
            );
        }
        self.ensure_blocks(&[row], n)?;
        self.advance(&[row], n);
        Ok(())
    }

    /// Make sure every row in `rows` has blocks covering `t_new` more
    /// positions past its live length. No-op for the contiguous layout.
    /// On pool exhaustion the blocks granted by *this call* are returned
    /// and an error surfaces — page tables are never left half-grown.
    pub(crate) fn ensure_blocks(&mut self, rows: &[usize], t_new: usize) -> Result<()> {
        let Some(p) = &mut self.paged else {
            return Ok(());
        };
        let mut granted: Vec<(usize, usize)> = Vec::new(); // (row, count)
        for &row in rows {
            let needed = (self.len[row] + t_new).div_ceil(p.block_size);
            let mut added = 0usize;
            while p.tables[row].len() < needed {
                match p.alloc.alloc() {
                    Some(id) => {
                        p.tables[row].push(id);
                        added += 1;
                    }
                    None => {
                        // roll back: this row's partial grant, then every
                        // earlier row's
                        for _ in 0..added {
                            let id = p.tables[row].pop().expect("just pushed");
                            p.alloc.release(id);
                        }
                        for &(r, n) in granted.iter().rev() {
                            for _ in 0..n {
                                let id = p.tables[r].pop().expect("granted this call");
                                p.alloc.release(id);
                            }
                        }
                        bail!(
                            "kv block pool exhausted: row {row} needs {needed} blocks, \
                             pool of {} has none free",
                            p.alloc.total_blocks()
                        );
                    }
                }
            }
            if added > 0 {
                granted.push((row, added));
            }
        }
        Ok(())
    }

    /// Advance the live length of each row in `rows` by `t_new` — called
    /// once per incremental forward, after every layer has written its new
    /// K/V rows against the *old* lengths.
    pub(crate) fn advance(&mut self, rows: &[usize], t_new: usize) {
        for &row in rows {
            self.len[row] += t_new;
            debug_assert!(self.len[row] <= self.capacity);
            if let Some(p) = &self.paged {
                debug_assert!(p.tables[row].len() * p.block_size >= self.len[row]);
            }
        }
    }

    /// Slab offset of position `pos` in `row` — the layout-resolving
    /// address every K/V read and write goes through. For paged caches the
    /// position's block must already be allocated ([`KvCache::ensure_blocks`]).
    pub(crate) fn pos_base(&self, row: usize, pos: usize) -> usize {
        match &self.paged {
            Some(p) => {
                let table = &p.tables[row];
                (table[pos / p.block_size] * p.block_size + pos % p.block_size) * self.d_model
            }
            None => (row * self.capacity + pos) * self.d_model,
        }
    }

    /// The storage runs backing positions `0..n_pos` of `row`, in logical
    /// order: `(first position, run length, slab offset of the run)`.
    /// Contiguous rows are one run; paged rows are one per block. The
    /// attention loop walks these instead of assuming contiguity — same
    /// positions in the same order either way, which is what keeps the
    /// two layouts bit-identical.
    pub(crate) fn segments(&self, row: usize, n_pos: usize) -> Vec<(usize, usize, usize)> {
        if n_pos == 0 {
            return Vec::new();
        }
        match &self.paged {
            Some(p) => {
                let bs = p.block_size;
                let table = &p.tables[row];
                (0..n_pos.div_ceil(bs))
                    .map(|bi| {
                        let pos0 = bi * bs;
                        (pos0, bs.min(n_pos - pos0), table[bi] * bs * self.d_model)
                    })
                    .collect()
            }
            None => vec![(0, n_pos, row * self.capacity * self.d_model)],
        }
    }

    /// The full K and V slabs for layer `li`.
    pub(crate) fn layer(&self, li: usize) -> (&[f32], &[f32]) {
        (&self.k[li], &self.v[li])
    }

    /// Mutable K and V slabs for layer `li` (the forward's append phase).
    pub(crate) fn layer_mut(&mut self, li: usize) -> (&mut [f32], &mut [f32]) {
        (&mut self.k[li], &mut self.v[li])
    }

    /// Refuse caches built for a different model shape. Capacity may be
    /// anything up to the engine's context length — a decode that knows
    /// its horizon (prompt + max_new) allocates only that much.
    pub(crate) fn check(&self, n_layers: usize, d_model: usize, max_capacity: usize) -> Result<()> {
        if self.n_layers != n_layers || self.d_model != d_model || self.capacity > max_capacity {
            bail!(
                "cache shape ({}, cap {}, d {}) does not fit engine ({n_layers}, cap ≤{max_capacity}, d {d_model})",
                self.n_layers,
                self.capacity,
                self.d_model
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lengths_advance_and_truncate() {
        let mut c = KvCache::new(2, 3, 16, 8);
        assert_eq!(c.batch(), 3);
        assert_eq!(c.capacity(), 16);
        assert!(!c.is_paged());
        assert_eq!(c.block_size(), None);
        assert_eq!(c.free_blocks(), None);
        assert!(c.row_block_ids(0).is_empty());
        c.advance(&[0, 2], 5);
        assert_eq!(c.pos_len(0), 5);
        assert_eq!(c.pos_len(1), 0);
        assert_eq!(c.pos_len(2), 5);
        c.truncate_row(2, 3);
        assert_eq!(c.pos_len(2), 3);
        c.advance(&[2], 1);
        assert_eq!(c.pos_len(2), 4);
    }

    #[test]
    fn reset_reclaims_single_rows() {
        let mut c = KvCache::new(2, 3, 16, 8);
        c.advance(&[0, 1, 2], 7);
        c.reset_row(1);
        assert_eq!(c.pos_len(0), 7);
        assert_eq!(c.pos_len(1), 0);
        assert_eq!(c.pos_len(2), 7);
        // the reclaimed row advances again from zero, others undisturbed
        c.advance(&[1], 3);
        assert_eq!(c.pos_len(1), 3);
        assert_eq!(c.pos_len(0), 7);
    }

    #[test]
    #[should_panic]
    fn reset_row_bounds_checked() {
        let mut c = KvCache::new(1, 2, 8, 4);
        c.reset_row(2);
    }

    #[test]
    #[should_panic]
    fn truncate_cannot_grow() {
        let mut c = KvCache::new(1, 1, 8, 4);
        c.truncate_row(0, 1);
    }

    #[test]
    fn byte_accounting() {
        let c = KvCache::new(2, 3, 16, 8);
        assert_eq!(c.bytes(), 2 * 2 * 3 * 16 * 8 * 4);
        assert_eq!(KvCache::row_bytes(2, 16, 8), c.bytes() / 3);
        // paged: the pool, not batch × capacity, is what's held
        let p = KvCache::new_paged(2, 3, 16, 8, 4, 6).unwrap();
        assert_eq!(p.bytes(), 2 * 2 * 6 * 4 * 8 * 4);
        assert_eq!(KvCache::block_bytes(2, 4, 8), p.bytes() / 6);
    }

    #[test]
    fn shape_check_rejects_mismatches() {
        let c = KvCache::new(2, 1, 16, 8);
        assert!(c.check(2, 8, 16).is_ok());
        // shorter-than-context caches are fine (bounded-horizon decode)…
        assert!(c.check(2, 8, 32).is_ok());
        // …but wrong layer count, width, or an over-long cache are not
        assert!(c.check(3, 8, 16).is_err());
        assert!(c.check(2, 4, 16).is_err());
        assert!(c.check(2, 8, 8).is_err());
        // the paged layout carries the same logical shape
        let p = KvCache::new_paged(2, 1, 16, 8, 4, 2).unwrap();
        assert!(p.check(2, 8, 16).is_ok());
        assert!(p.check(3, 8, 16).is_err());
    }

    #[test]
    fn paged_rows_grow_block_by_block() {
        let mut c = KvCache::new_paged(1, 2, 32, 4, 4, 8).unwrap();
        assert!(c.is_paged());
        assert_eq!(c.block_size(), Some(4));
        assert_eq!((c.free_blocks(), c.total_blocks()), (Some(8), Some(8)));
        c.grow_row(0, 3).unwrap(); // 3 positions → 1 block
        assert_eq!(c.row_block_ids(0).len(), 1);
        assert_eq!(c.free_blocks(), Some(7));
        c.grow_row(0, 1).unwrap(); // fills the block exactly — no new alloc
        assert_eq!(c.row_block_ids(0).len(), 1);
        assert_eq!(c.free_blocks(), Some(7));
        c.grow_row(0, 1).unwrap(); // crosses the boundary → second block
        assert_eq!(c.row_block_ids(0).len(), 2);
        assert_eq!(c.free_blocks(), Some(6));
        // rows never share blocks
        c.grow_row(1, 9).unwrap(); // 9 positions → 3 blocks
        assert_eq!(c.row_block_ids(1).len(), 3);
        let mut all: Vec<usize> = c.row_block_ids(0).to_vec();
        all.extend_from_slice(c.row_block_ids(1));
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "rows alias a physical block");
        assert_eq!(c.free_blocks(), Some(3));
    }

    #[test]
    fn paged_truncate_releases_at_block_boundaries() {
        let mut c = KvCache::new_paged(1, 1, 32, 4, 4, 8).unwrap();
        c.grow_row(0, 10).unwrap(); // 10 positions → 3 blocks
        assert_eq!(c.row_block_ids(0).len(), 3);
        assert_eq!(c.free_blocks(), Some(5));
        // mid-block: 9 positions still span 3 blocks, nothing freed
        c.truncate_row(0, 9);
        assert_eq!(c.row_block_ids(0).len(), 3);
        assert_eq!(c.free_blocks(), Some(5));
        // to exactly two blocks' worth: the third goes back
        c.truncate_row(0, 8);
        assert_eq!(c.row_block_ids(0).len(), 2);
        assert_eq!(c.free_blocks(), Some(6));
        // mid-block inside the second block: second block still live
        c.truncate_row(0, 5);
        assert_eq!(c.row_block_ids(0).len(), 2);
        assert_eq!(c.free_blocks(), Some(6));
        // to exactly one block
        c.truncate_row(0, 4);
        assert_eq!(c.row_block_ids(0).len(), 1);
        assert_eq!(c.free_blocks(), Some(7));
        // to zero: everything back, row reusable
        c.truncate_row(0, 0);
        assert!(c.row_block_ids(0).is_empty());
        assert_eq!(c.free_blocks(), Some(8));
        c.grow_row(0, 4).unwrap();
        assert_eq!(c.free_blocks(), Some(7));
    }

    #[test]
    fn paged_reset_returns_exactly_the_rows_blocks() {
        let mut c = KvCache::new_paged(2, 3, 64, 4, 8, 12).unwrap();
        c.grow_row(0, 17).unwrap(); // 3 blocks
        c.grow_row(1, 8).unwrap(); // 1 block
        c.grow_row(2, 9).unwrap(); // 2 blocks
        assert_eq!(c.free_blocks(), Some(6));
        let held = c.row_block_ids(1).len();
        let free_before = c.free_blocks().unwrap();
        c.reset_row(1);
        assert_eq!(c.free_blocks(), Some(free_before + held));
        assert_eq!(c.pos_len(1), 0);
        assert!(c.row_block_ids(1).is_empty());
        // the other rows' tables are untouched
        assert_eq!(c.row_block_ids(0).len(), 3);
        assert_eq!(c.row_block_ids(2).len(), 2);
    }

    #[test]
    fn paged_exhaustion_fails_clean_and_rolls_back() {
        let mut c = KvCache::new_paged(1, 2, 64, 4, 4, 3).unwrap();
        c.grow_row(0, 8).unwrap(); // 2 of 3 blocks
        assert_eq!(c.free_blocks(), Some(1));
        // needs 2 more blocks, pool has 1: refuse, release the partial grant
        assert!(c.grow_row(1, 7).is_err());
        assert_eq!(c.free_blocks(), Some(1), "failed grow leaked blocks");
        assert!(c.row_block_ids(1).is_empty(), "failed grow left a half-grown table");
        assert_eq!(c.pos_len(1), 0);
        // a fitting request still succeeds afterwards
        c.grow_row(1, 3).unwrap();
        assert_eq!(c.free_blocks(), Some(0));
    }

    #[test]
    fn grow_row_respects_logical_capacity() {
        // plenty of pool, but the per-row ceiling still binds
        let mut c = KvCache::new_paged(1, 1, 8, 4, 4, 16).unwrap();
        assert!(c.grow_row(0, 9).is_err());
        c.grow_row(0, 8).unwrap();
        assert!(c.grow_row(0, 1).is_err());
        // contiguous rows enforce the same ceiling
        let mut c = KvCache::new(1, 1, 8, 4);
        assert!(c.grow_row(0, 9).is_err());
        c.grow_row(0, 8).unwrap();
        assert!(c.grow_row(0, 1).is_err());
    }

    #[test]
    fn invalid_paged_shapes_are_refused() {
        assert!(KvCache::new_paged(1, 1, 8, 4, 0, 4).is_err());
        assert!(KvCache::new_paged(1, 1, 8, 4, 4, 0).is_err());
    }

    #[test]
    fn addressing_matches_layouts() {
        // contiguous: row-major positions
        let c = KvCache::new(1, 2, 8, 4);
        assert_eq!(c.pos_base(0, 0), 0);
        assert_eq!(c.pos_base(0, 3), 12);
        assert_eq!(c.pos_base(1, 0), 32);
        assert_eq!(c.segments(0, 5), vec![(0, 5, 0)]);
        assert_eq!(c.segments(1, 2), vec![(0, 2, 32)]);
        assert!(c.segments(0, 0).is_empty());
        // paged: through the page table
        let mut p = KvCache::new_paged(1, 2, 16, 4, 4, 4).unwrap();
        p.grow_row(1, 6).unwrap(); // row 1 grabs blocks first (ids 0, 1)
        p.grow_row(0, 2).unwrap(); // row 0 gets id 2
        assert_eq!(p.row_block_ids(1), &[0, 1]);
        assert_eq!(p.row_block_ids(0), &[2]);
        assert_eq!(p.pos_base(1, 0), 0);
        assert_eq!(p.pos_base(1, 5), (4 + 1) * 4);
        assert_eq!(p.pos_base(0, 1), (2 * 4 + 1) * 4);
        assert_eq!(p.segments(1, 6), vec![(0, 4, 0), (4, 2, 4 * 4)]);
        assert_eq!(p.segments(0, 2), vec![(0, 2, 2 * 4 * 4)]);
    }
}
