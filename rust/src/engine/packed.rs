//! Column-packed weight storage for the native engine.
//!
//! [`PackedLinear`] is the serving twin of [`QuantizedLinear`]: the same
//! (Din, Dout) integer grid and per-group affine tables, but with the codes
//! bit-packed into `u32` words **per output column** instead of stored as
//! f32. Column-major packing is what the fused GEMM wants: one column's
//! codes are a single contiguous word run, decoded group-by-group while the
//! activations stream past, and every column starts word-aligned so the
//! kernel never straddles a column boundary.
//!
//! The per-column alignment costs at most `Dout · 3` bytes over the dense
//! `ceil(Din·Dout·bits/32)` stream that [`crate::quant::pack`] (and the
//! paper's footprint numbers) use — negligible against the tables.

use anyhow::{bail, Result};

use crate::quant::{pack_ints, packed_len_u32, QuantizedLinear};
use crate::tensor::Tensor;

/// One quantized linear layer in deployment form: column-packed `u32`
/// codes plus (G, Dout) f32 scale/zero tables.
#[derive(Clone, Debug)]
pub struct PackedLinear {
    pub n_bits: u32,
    pub group_size: usize,
    din: usize,
    dout: usize,
    /// words per packed column: `ceil(din·bits / 32)`
    words_per_col: usize,
    /// column-major packed codes; column `j` is
    /// `words[j·words_per_col .. (j+1)·words_per_col]`
    words: Vec<u32>,
    /// (G, Dout) row-major scale factors
    scales: Vec<f32>,
    /// (G, Dout) row-major zero factors
    zeros: Vec<f32>,
}

impl PackedLinear {
    /// Pack a validated [`QuantizedLinear`] into deployment form.
    pub fn from_quantized(ql: &QuantizedLinear) -> Result<PackedLinear> {
        ql.validate()?;
        let (din, dout) = (ql.din(), ql.dout());
        let wpc = packed_len_u32(din, ql.n_bits);
        let mut words = vec![0u32; wpc * dout];
        let mut col = vec![0.0f32; din];
        for j in 0..dout {
            for (i, c) in col.iter_mut().enumerate() {
                *c = ql.w_int.at2(i, j);
            }
            let packed = pack_ints(&col, ql.n_bits)?;
            words[j * wpc..j * wpc + packed.len()].copy_from_slice(&packed);
        }
        Ok(PackedLinear {
            n_bits: ql.n_bits,
            group_size: ql.group_size,
            din,
            dout,
            words_per_col: wpc,
            words,
            scales: ql.scales.data().to_vec(),
            zeros: ql.zeros.data().to_vec(),
        })
    }

    pub fn din(&self) -> usize {
        self.din
    }

    pub fn dout(&self) -> usize {
        self.dout
    }

    pub fn n_groups(&self) -> usize {
        self.din / self.group_size
    }

    /// (G, Dout) row-major scale table.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// (G, Dout) row-major zero table.
    pub fn zeros(&self) -> &[f32] {
        &self.zeros
    }

    /// Actual bytes held by this packed layer (grid words + affine tables)
    /// — the number the serving memory accounting reports.
    pub fn deployed_bytes(&self) -> usize {
        (self.words.len() + self.scales.len() + self.zeros.len()) * 4
    }

    /// Decode column `j`'s integer codes into `out` (length `din`), as f32
    /// values. This is the only unpacking the engine ever does: a single
    /// column-sized working buffer, never the full weight matrix.
    ///
    /// Fed straight into the GEMM inner loop, so it unpacks a whole `u32`
    /// word at a time (8 codes for 4-bit, 16 for 2-bit, a streamed bit
    /// buffer for 3-bit) instead of recomputing a bit cursor per element.
    /// The per-element cursor survives as [`Self::decode_col_reference`],
    /// the reference the fast paths are pinned against in the tests below.
    #[inline]
    pub fn decode_col_into(&self, j: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.din);
        let col = &self.words[j * self.words_per_col..(j + 1) * self.words_per_col];
        match self.n_bits {
            2 => decode_col_w2(col, out),
            4 => decode_col_w4(col, out),
            3 => decode_col_w3(col, out),
            bits => decode_col_bitwise(col, out, bits),
        }
    }

    /// Reference column decode: the original per-element bit cursor.
    /// Tests / diagnostics only — the hot path takes the word-at-a-time
    /// lanes above, which must produce identical codes.
    pub fn decode_col_reference(&self, j: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.din);
        let col = &self.words[j * self.words_per_col..(j + 1) * self.words_per_col];
        decode_col_bitwise(col, out, self.n_bits);
    }

    /// Reconstruct the f32-coded integer grid (tests / diagnostics only —
    /// the hot path never calls this).
    pub fn unpack_grid(&self) -> Tensor {
        let mut grid = vec![0.0f32; self.din * self.dout];
        let mut col = vec![0.0f32; self.din];
        for j in 0..self.dout {
            self.decode_col_into(j, &mut col);
            for i in 0..self.din {
                grid[i * self.dout + j] = col[i];
            }
        }
        Tensor::new(&[self.din, self.dout], grid)
    }

    /// Reconstruct the dense f32 weight matrix (tests / diagnostics only).
    pub fn dequantize(&self) -> Tensor {
        let scales = Tensor::new(&[self.n_groups(), self.dout], self.scales.clone());
        let zeros = Tensor::new(&[self.n_groups(), self.dout], self.zeros.clone());
        crate::quant::dequant(&self.unpack_grid(), &scales, &zeros, self.group_size)
    }

    /// Round-trip back into the f32-coded representation the merge and the
    /// PJRT artifacts consume.
    pub fn to_quantized(&self) -> Result<QuantizedLinear> {
        if self.din % self.group_size != 0 {
            bail!("group size {} does not divide Din {}", self.group_size, self.din);
        }
        Ok(QuantizedLinear {
            n_bits: self.n_bits,
            group_size: self.group_size,
            w_int: self.unpack_grid(),
            scales: Tensor::new(&[self.n_groups(), self.dout], self.scales.clone()),
            zeros: Tensor::new(&[self.n_groups(), self.dout], self.zeros.clone()),
        })
    }
}

/// 2-bit fast path: 16 codes per word, shifted out low-to-high (the
/// little-endian-within-word layout `quant::pack_ints` writes).
fn decode_col_w2(col: &[u32], out: &mut [f32]) {
    let mut chunks = out.chunks_exact_mut(16);
    let mut wi = 0;
    for chunk in &mut chunks {
        let word = col[wi];
        wi += 1;
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = ((word >> (2 * k)) & 0x3) as f32;
        }
    }
    let rem = chunks.into_remainder();
    if !rem.is_empty() {
        let word = col[wi];
        for (k, slot) in rem.iter_mut().enumerate() {
            *slot = ((word >> (2 * k)) & 0x3) as f32;
        }
    }
}

/// 4-bit fast path: 8 codes per word.
fn decode_col_w4(col: &[u32], out: &mut [f32]) {
    let mut chunks = out.chunks_exact_mut(8);
    let mut wi = 0;
    for chunk in &mut chunks {
        let word = col[wi];
        wi += 1;
        for (k, slot) in chunk.iter_mut().enumerate() {
            *slot = ((word >> (4 * k)) & 0xF) as f32;
        }
    }
    let rem = chunks.into_remainder();
    if !rem.is_empty() {
        let word = col[wi];
        for (k, slot) in rem.iter_mut().enumerate() {
            *slot = ((word >> (4 * k)) & 0xF) as f32;
        }
    }
}

/// 3-bit fast path: codes straddle word boundaries, so stream words
/// through a u64 bit buffer — one shift/mask per code, one word load per
/// 32 bits, no per-element cursor arithmetic.
fn decode_col_w3(col: &[u32], out: &mut [f32]) {
    let mut buf: u64 = 0;
    let mut have: u32 = 0;
    let mut wi = 0;
    for slot in out.iter_mut() {
        if have < 3 {
            buf |= (col[wi] as u64) << have;
            wi += 1;
            have += 32;
        }
        *slot = (buf & 0x7) as f32;
        buf >>= 3;
        have -= 3;
    }
}

/// Generic per-element bit cursor — the reference implementation (and the
/// fallback for any width without a fast path).
fn decode_col_bitwise(col: &[u32], out: &mut [f32], n_bits: u32) {
    let bits = n_bits as usize;
    let mask = (1u64 << bits) - 1;
    let mut bitpos = 0usize;
    for slot in out.iter_mut() {
        let word = bitpos / 32;
        let off = bitpos % 32;
        let mut code = (col[word] as u64) >> off;
        if off + bits > 32 {
            code |= (col[word + 1] as u64) << (32 - off);
        }
        *slot = (code & mask) as f32;
        bitpos += bits;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn sample(seed: u64, din: usize, dout: usize, gs: usize, bits: u32) -> QuantizedLinear {
        let mut rng = Rng::new(seed);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        rtn_quantize(&w, gs, bits)
    }

    #[test]
    fn roundtrip_all_bit_widths() {
        for bits in [2u32, 3, 4] {
            let ql = sample(bits as u64, 48, 20, 8, bits);
            let pl = PackedLinear::from_quantized(&ql).unwrap();
            assert_eq!(pl.unpack_grid(), ql.w_int, "{bits}-bit grid");
            let back = pl.to_quantized().unwrap();
            assert_eq!(back.w_int, ql.w_int);
            assert_eq!(back.scales, ql.scales);
            assert_eq!(back.zeros, ql.zeros);
        }
    }

    #[test]
    fn dequantize_matches_dense_path() {
        let ql = sample(7, 64, 24, 16, 4);
        let pl = PackedLinear::from_quantized(&ql).unwrap();
        assert!(pl.dequantize().allclose(&ql.dequantize(), 1e-6, 1e-7));
    }

    #[test]
    fn three_bit_columns_stay_word_aligned() {
        // Din=11 × 3 bits = 33 bits/column → 2 words/column, straddling
        // inside the column but never across columns.
        let mut rng = Rng::new(9);
        let w = Tensor::new(&[11, 5], rng.normal_vec(55, 0.1));
        // group_size must divide din for validate(); use a hand grid
        let ql = QuantizedLinear {
            n_bits: 3,
            group_size: 11,
            w_int: w.map(|v| ((v.abs() * 40.0) as u32 % 8) as f32),
            scales: Tensor::full(&[1, 5], 0.1),
            zeros: Tensor::zeros(&[1, 5]),
        };
        let pl = PackedLinear::from_quantized(&ql).unwrap();
        assert_eq!(pl.words_per_col, 2);
        assert_eq!(pl.unpack_grid(), ql.w_int);
    }

    #[test]
    fn word_decode_matches_bitwise_reference() {
        // din deliberately not a multiple of the codes-per-word counts
        // (16 for 2-bit, 8 for 4-bit) so the remainder paths run, and
        // odd group sizes so 3-bit codes straddle words mid-column
        for bits in [2u32, 3, 4] {
            for (din, dout, gs) in [(44, 7, 11), (52, 5, 13), (64, 9, 16)] {
                let ql = sample(bits as u64 * 100 + din as u64, din, dout, gs, bits);
                let pl = PackedLinear::from_quantized(&ql).unwrap();
                let mut fast = vec![0.0f32; din];
                let mut reference = vec![0.0f32; din];
                for j in 0..dout {
                    pl.decode_col_into(j, &mut fast);
                    pl.decode_col_reference(j, &mut reference);
                    assert_eq!(fast, reference, "bits={bits} din={din} col={j}");
                }
            }
        }
    }

    #[test]
    fn deployed_bytes_tracks_bit_width() {
        let b4 = PackedLinear::from_quantized(&sample(1, 256, 64, 32, 4)).unwrap();
        let b2 = PackedLinear::from_quantized(&sample(1, 256, 64, 32, 2)).unwrap();
        assert!(b2.deployed_bytes() < b4.deployed_bytes());
        // and far below the f32 matrix
        assert!(b4.deployed_bytes() < 256 * 64 * 4 / 4);
    }
}
