//! The KV block pool: a free-list allocator over fixed-size cache blocks.
//!
//! Paged KV caching ([`super::KvCache`] built with
//! [`super::KvCache::new_paged`]) slices the K/V slabs into blocks of
//! `kv_block_size` token positions and hands them out on demand, so a
//! request's cache footprint grows with its *actual* length instead of
//! reserving a full-context row up front. [`BlockAllocator`] is the pool
//! behind that: a plain LIFO free list over physical block ids, O(1)
//! alloc and release, no compaction (blocks are position-addressed
//! through per-row page tables, so fragmentation cannot exist).
//!
//! Internal invariants are enforced eagerly — a double release or an
//! out-of-range id panics instead of corrupting the free list — and the
//! external ones (no block owned by two rows, free + live == pool size)
//! are pinned by the property harness in `tests/kv_paged.rs`.

/// Cumulative allocator traffic — what the observability layer
/// ([`crate::obs`]) snapshots as counters each scheduler step. All
/// fields are monotone over the pool's lifetime (releases never
/// decrement `allocs`), so consecutive snapshots difference cleanly.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockCounters {
    /// blocks ever granted
    pub allocs: u64,
    /// blocks ever returned
    pub frees: u64,
    /// most blocks simultaneously granted out
    pub peak_in_use: usize,
}

/// A fixed pool of KV blocks with a LIFO free list.
#[derive(Clone, Debug)]
pub struct BlockAllocator {
    /// free physical block ids, popped from the back
    free: Vec<usize>,
    /// `is_free[id]` — double-release / double-grant detection
    is_free: Vec<bool>,
    total: usize,
    counters: BlockCounters,
}

impl BlockAllocator {
    /// A pool of `total` blocks, all free. Ids are `0..total`.
    pub fn new(total: usize) -> BlockAllocator {
        BlockAllocator {
            // LIFO over descending ids so the first alloc hands out id 0
            free: (0..total).rev().collect(),
            is_free: vec![true; total],
            total,
            counters: BlockCounters::default(),
        }
    }

    /// Take one block from the pool, or `None` when it has run dry. The
    /// caller owns the id until it releases it.
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        debug_assert!(self.is_free[id]);
        self.is_free[id] = false;
        self.counters.allocs += 1;
        self.counters.peak_in_use = self.counters.peak_in_use.max(self.in_use());
        Some(id)
    }

    /// Return `id` to the pool. Panics on ids the pool never granted —
    /// an out-of-range id or a double release is page-table corruption,
    /// not a recoverable condition.
    pub fn release(&mut self, id: usize) {
        assert!(id < self.total, "release of block {id} outside pool of {}", self.total);
        assert!(!self.is_free[id], "double release of block {id}");
        self.is_free[id] = true;
        self.free.push(id);
        self.counters.frees += 1;
    }

    /// Cumulative traffic counters (see [`BlockCounters`]).
    pub fn counters(&self) -> BlockCounters {
        self.counters
    }

    /// Blocks currently available.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently granted out.
    pub fn in_use(&self) -> usize {
        self.total - self.free.len()
    }

    /// Pool size (free + in use, always).
    pub fn total_blocks(&self) -> usize {
        self.total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocates_every_block_exactly_once() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.total_blocks(), 4);
        let mut got = Vec::new();
        while let Some(id) = a.alloc() {
            got.push(id);
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(a.free_blocks(), 0);
        assert_eq!(a.in_use(), 4);
        assert_eq!(a.alloc(), None);
    }

    #[test]
    fn release_recycles() {
        let mut a = BlockAllocator::new(2);
        let x = a.alloc().unwrap();
        let y = a.alloc().unwrap();
        assert_eq!(a.alloc(), None);
        a.release(x);
        assert_eq!(a.free_blocks(), 1);
        let z = a.alloc().unwrap();
        assert_eq!(z, x, "LIFO free list should hand the released block back");
        a.release(y);
        a.release(z);
        assert_eq!(a.free_blocks(), 2);
        assert_eq!(a.in_use(), 0);
    }

    #[test]
    #[should_panic]
    fn double_release_panics() {
        let mut a = BlockAllocator::new(2);
        let x = a.alloc().unwrap();
        a.release(x);
        a.release(x);
    }

    #[test]
    #[should_panic]
    fn out_of_range_release_panics() {
        let mut a = BlockAllocator::new(2);
        a.release(5);
    }

    #[test]
    fn empty_pool_is_legal_but_dry() {
        let mut a = BlockAllocator::new(0);
        assert_eq!(a.alloc(), None);
        assert_eq!(a.total_blocks(), 0);
        assert_eq!(a.counters(), BlockCounters::default(), "a dry alloc is not traffic");
    }

    #[test]
    fn counters_accumulate_and_peak_holds() {
        let mut a = BlockAllocator::new(3);
        let x = a.alloc().unwrap();
        let y = a.alloc().unwrap();
        assert_eq!(a.counters(), BlockCounters { allocs: 2, frees: 0, peak_in_use: 2 });
        a.release(x);
        // the peak survives the release; frees tick up
        assert_eq!(a.counters(), BlockCounters { allocs: 2, frees: 1, peak_in_use: 2 });
        let z = a.alloc().unwrap();
        a.release(y);
        a.release(z);
        let c = a.counters();
        assert_eq!((c.allocs, c.frees), (3, 3));
        assert_eq!(c.peak_in_use, 2, "in-use never exceeded 2");
        // exhaustion attempts don't count as allocs
        let mut b = BlockAllocator::new(1);
        b.alloc().unwrap();
        assert_eq!(b.alloc(), None);
        assert_eq!(b.counters().allocs, 1);
    }
}
