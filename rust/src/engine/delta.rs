//! In-kernel ternary adapter deltas on the packed grid — the multi-adapter
//! serving representation.
//!
//! LoTA's merge (paper Eqs. 3–5, [`crate::adapter::lota::lota_merge`])
//! moves every integer code by at most ±1 and rewrites only the per-group
//! zero table; scales are untouched. That makes an adapter representable
//! *against* a shared packed base as:
//!
//! * a column-major 2-bit grid of `(merged_code − base_code) + 1 ∈ {0,1,2}`
//!   — [`TernaryDelta`], one per (layer, slot);
//! * the merged zero table, carried verbatim.
//!
//! [`PackedView`] overlays a delta on a [`PackedLinear`] and exposes the
//! exact weight surface the GEMM kernels consume (`decode_col_into`,
//! `scales`, `zeros`, dims). Applying the delta is an exact f32 operation:
//! base codes are small non-negative integers, and adding `-1.0`, `0.0`,
//! or `+1.0` to such a value is a single exactly-representable step. A
//! delta-applied column therefore decodes **bit-identically** to decoding
//! the adapter's individually merged checkpoint — which, with the
//! lane-ordered accumulation contract ([`super::simd`]) fixing every
//! downstream add, is what makes mixed-adapter batches bit-equal to
//! solo-merged serving (`tests/adapters.rs` pins it).
//!
//! The deltas are built *through* `lota_merge` itself
//! ([`TernaryDelta::from_adapter`] merges, then diffs), so there is one
//! implementation of the merge math in the repo and serving cannot drift
//! from it.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::adapter::lota::{lota_merge, TernaryAdapter};
use crate::obs::profiler::KernelProf;
use crate::quant::QuantizedLinear;

use super::packed::PackedLinear;

/// Codes per packed `u32` word (2 bits each).
const CODES_PER_WORD: usize = 16;

/// The 2-bit pattern of an all-zero-delta word: every field holds
/// `0 + 1 = 1` (`0b01` repeated). Columns are mostly identity — ternary
/// adapters are sparse and ω prunes further — so [`TernaryDelta::apply_col`]
/// skips whole words that match this pattern.
const IDENTITY_WORD: u32 = 0x5555_5555;

/// One (layer, slot) ternary adapter in serving form: the grid moves
/// against a shared [`PackedLinear`] base, packed 2 bits per element, plus
/// the merged zero table.
#[derive(Clone, Debug)]
pub struct TernaryDelta {
    din: usize,
    dout: usize,
    group_size: usize,
    /// words per packed column: `ceil(din / 16)`
    words_per_col: usize,
    /// column-major packed `(delta + 1)` codes; column `j` is
    /// `words[j·words_per_col .. (j+1)·words_per_col]`
    words: Vec<u32>,
    /// (G, Dout) row-major merged zero table (replaces the base's)
    zeros: Vec<f32>,
    /// grid entries actually moved (|delta| = 1) — diagnostics
    adjustments: usize,
}

impl TernaryDelta {
    /// Build the serving delta for `adapter` against `base`: run the
    /// lossless merge on the base's integer grid, then record what moved.
    /// This is the only constructor serving uses — the merge math lives in
    /// [`lota_merge`] alone.
    pub fn from_adapter(
        base: &PackedLinear,
        adapter: &TernaryAdapter,
        omega: f32,
    ) -> Result<TernaryDelta> {
        if adapter.a.rows() != base.din() || adapter.b.cols() != base.dout() {
            bail!(
                "adapter shape ({}, r, {}) does not fit base ({}, {})",
                adapter.a.rows(),
                adapter.b.cols(),
                base.din(),
                base.dout()
            );
        }
        let merged = lota_merge(&base.to_quantized()?, adapter, omega);
        TernaryDelta::from_merged(base, &merged)
    }

    /// Diff an already-merged checkpoint layer against its base. Validates
    /// the lossless-merge contract: same shape/grouping/bit width,
    /// bit-identical scales, and every grid move in {-1, 0, +1}.
    pub fn from_merged(base: &PackedLinear, merged: &QuantizedLinear) -> Result<TernaryDelta> {
        if merged.din() != base.din()
            || merged.dout() != base.dout()
            || merged.group_size != base.group_size
            || merged.n_bits != base.n_bits
        {
            bail!(
                "merged layer ({}, {}, gs {}, {} bits) does not match base ({}, {}, gs {}, {} bits)",
                merged.din(),
                merged.dout(),
                merged.group_size,
                merged.n_bits,
                base.din(),
                base.dout(),
                base.group_size,
                base.n_bits
            );
        }
        if merged.scales.data() != base.scales() {
            bail!("merged scales differ from base — not a lossless ternary merge");
        }
        let (din, dout) = (base.din(), base.dout());
        let wpc = din.div_ceil(CODES_PER_WORD);
        let mut words = vec![0u32; wpc * dout];
        let mut col = vec![0.0f32; din];
        let mut adjustments = 0usize;
        for j in 0..dout {
            base.decode_col_into(j, &mut col);
            for (i, &basecode) in col.iter().enumerate() {
                let d = merged.w_int.at2(i, j) - basecode;
                if d != -1.0 && d != 0.0 && d != 1.0 {
                    bail!("grid move {d} at ({i},{j}) outside ternary range");
                }
                if d != 0.0 {
                    adjustments += 1;
                }
                let code = (d + 1.0) as u32;
                words[j * wpc + i / CODES_PER_WORD] |= code << (2 * (i % CODES_PER_WORD));
            }
        }
        Ok(TernaryDelta {
            din,
            dout,
            group_size: base.group_size,
            words_per_col: wpc,
            words,
            zeros: merged.zeros.data().to_vec(),
            adjustments,
        })
    }

    pub fn din(&self) -> usize {
        self.din
    }

    pub fn dout(&self) -> usize {
        self.dout
    }

    pub fn group_size(&self) -> usize {
        self.group_size
    }

    /// (G, Dout) row-major merged zero table.
    pub fn zeros(&self) -> &[f32] {
        &self.zeros
    }

    /// Grid entries this delta moves (serving diagnostics — the paper's
    /// "adjustment budget" at deployment).
    pub fn adjustments(&self) -> usize {
        self.adjustments
    }

    /// Bytes this delta holds resident (packed grid + zero table) — the
    /// per-adapter serving footprint, far below a merged checkpoint copy.
    pub fn deployed_bytes(&self) -> usize {
        (self.words.len() + self.zeros.len()) * 4
    }

    /// Apply column `j`'s grid moves to already-decoded base codes:
    /// `out[i] += delta[i,j]`. Each add is `±1.0` or skipped, so the
    /// result bit-equals decoding the merged checkpoint's column.
    #[inline]
    pub fn apply_col(&self, j: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.din);
        let col = &self.words[j * self.words_per_col..(j + 1) * self.words_per_col];
        for (chunk, &word) in out.chunks_mut(CODES_PER_WORD).zip(col) {
            let ident = IDENTITY_WORD >> (2 * (CODES_PER_WORD - chunk.len()));
            if word == ident {
                continue; // whole word unmoved — the common sparse case
            }
            for (k, slot) in chunk.iter_mut().enumerate() {
                *slot += ((word >> (2 * k)) & 0x3) as f32 - 1.0;
            }
        }
    }
}

/// The weight surface a GEMM kernel reads: a packed base, optionally
/// overlaid with one adapter's [`TernaryDelta`]. `Copy` — a few
/// word-sized refs — so the column-chunk threads share it freely.
///
/// The kernels consume weights *only* through this surface (column
/// decode + affine tables + dims); the delta changes input values, never
/// the accumulation order, so the lane-ordered contract is untouched.
/// An attached [`KernelProf`] times the two fused sub-kernels (base
/// decode, delta overlay) into relaxed atomic accumulators — it observes
/// values-in-flight timing only, never the values, so attaching one
/// cannot move a bit of output.
#[derive(Clone, Copy)]
pub struct PackedView<'a> {
    base: &'a PackedLinear,
    delta: Option<&'a TernaryDelta>,
    prof: Option<&'a KernelProf>,
}

impl<'a> PackedView<'a> {
    /// The base weights alone — what every pre-adapter call site wraps.
    pub fn base_only(base: &'a PackedLinear) -> PackedView<'a> {
        PackedView { base, delta: None, prof: None }
    }

    /// Base plus one adapter's grid moves and zero table.
    pub fn with_delta(base: &'a PackedLinear, delta: &'a TernaryDelta) -> PackedView<'a> {
        debug_assert_eq!(base.din(), delta.din());
        debug_assert_eq!(base.dout(), delta.dout());
        debug_assert_eq!(base.group_size, delta.group_size());
        PackedView { base, delta: Some(delta), prof: None }
    }

    /// Attach (or detach) in-kernel sub-phase timing. Profiled GEMM
    /// calls run single-threaded so the accumulated nanoseconds are
    /// disjoint sub-intervals of the enclosing profiler segment.
    pub fn with_prof(mut self, prof: Option<&'a KernelProf>) -> PackedView<'a> {
        self.prof = prof;
        self
    }

    pub fn din(&self) -> usize {
        self.base.din()
    }

    pub fn dout(&self) -> usize {
        self.base.dout()
    }

    pub fn group_size(&self) -> usize {
        self.base.group_size
    }

    pub fn n_groups(&self) -> usize {
        self.base.n_groups()
    }

    /// Scale table — always the base's: the lossless merge never moves it.
    pub fn scales(&self) -> &'a [f32] {
        self.base.scales()
    }

    /// Zero table — the adapter's merged table when a delta is overlaid.
    pub fn zeros(&self) -> &'a [f32] {
        match self.delta {
            Some(d) => d.zeros(),
            None => self.base.zeros(),
        }
    }

    /// Decode column `j` through the overlay: base codes, then the exact
    /// ±1 grid moves. Bit-equals the merged checkpoint's column decode.
    /// With a [`KernelProf`] attached, each sub-kernel is clocked into
    /// its accumulator; the unprofiled branch reads no clock at all.
    #[inline]
    pub fn decode_col_into(&self, j: usize, out: &mut [f32]) {
        match self.prof {
            None => {
                self.base.decode_col_into(j, out);
                if let Some(d) = self.delta {
                    d.apply_col(j, out);
                }
            }
            Some(p) => {
                let t = Instant::now();
                self.base.decode_col_into(j, out);
                p.add_dequant_ns(t.elapsed().as_nanos() as u64);
                if let Some(d) = self.delta {
                    let t = Instant::now();
                    d.apply_col(j, out);
                    p.add_overlay_ns(t.elapsed().as_nanos() as u64);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::tensor::{Rng, Tensor};

    fn setup(seed: u64, bits: u32) -> (PackedLinear, TernaryAdapter) {
        let mut rng = Rng::new(seed);
        let (din, dout, gs, r) = (48, 20, 8, 4);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, bits);
        let mut ta = TernaryAdapter::init(din, dout, r, &mut rng);
        let bd: Vec<f32> = (0..r * dout).map(|_| (rng.below(3) as f32) - 1.0).collect();
        ta.b = Tensor::new(&[r, dout], bd);
        (PackedLinear::from_quantized(&ql).unwrap(), ta)
    }

    #[test]
    fn view_decode_bit_equals_merged_checkpoint_all_bit_widths() {
        // the in-kernel losslessness claim at its root: overlay decode vs
        // packing the merged checkpoint and decoding that
        for bits in [2u32, 3, 4] {
            let (base, ta) = setup(bits as u64, bits);
            let omega = 0.5 * ta.rank as f32;
            let merged = lota_merge(&base.to_quantized().unwrap(), &ta, omega);
            let merged_packed = PackedLinear::from_quantized(&merged).unwrap();
            let delta = TernaryDelta::from_adapter(&base, &ta, omega).unwrap();
            let view = PackedView::with_delta(&base, &delta);
            assert_eq!(view.zeros(), merged_packed.zeros(), "bits={bits} zeros");
            assert_eq!(view.scales(), merged_packed.scales(), "bits={bits} scales");
            let mut got = vec![0.0f32; base.din()];
            let mut want = vec![0.0f32; base.din()];
            for j in 0..base.dout() {
                view.decode_col_into(j, &mut got);
                merged_packed.decode_col_into(j, &mut want);
                assert_eq!(got, want, "bits={bits} col {j}");
            }
            assert!(delta.adjustments() > 0, "bits={bits}: test adapter moved nothing");
        }
    }

    #[test]
    fn identity_adapter_is_a_no_op_overlay() {
        let (base, _) = setup(9, 4);
        let mut rng = Rng::new(10);
        // fresh init has B = 0 ⇒ ΔW = 0 ⇒ identity merge
        let ta = TernaryAdapter::init(base.din(), base.dout(), 4, &mut rng);
        let delta = TernaryDelta::from_adapter(&base, &ta, 2.0).unwrap();
        assert_eq!(delta.adjustments(), 0);
        assert_eq!(delta.zeros(), base.zeros());
        let view = PackedView::with_delta(&base, &delta);
        let mut got = vec![0.0f32; base.din()];
        let mut want = vec![0.0f32; base.din()];
        for j in 0..base.dout() {
            view.decode_col_into(j, &mut got);
            base.decode_col_into(j, &mut want);
            assert_eq!(got, want, "col {j}");
        }
    }

    #[test]
    fn tail_words_apply_their_partial_codes() {
        // din = 20: one full 16-code word plus a 4-code tail per column
        let mut rng = Rng::new(11);
        let (din, dout, gs, r) = (20, 6, 4, 4);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let base = PackedLinear::from_quantized(&rtn_quantize(&w, gs, 4)).unwrap();
        let mut ta = TernaryAdapter::init(din, dout, r, &mut rng);
        let bd: Vec<f32> = (0..r * dout).map(|_| (rng.below(3) as f32) - 1.0).collect();
        ta.b = Tensor::new(&[r, dout], bd);
        let omega = 0.25 * r as f32;
        let merged = lota_merge(&base.to_quantized().unwrap(), &ta, omega);
        let delta = TernaryDelta::from_merged(&base, &merged).unwrap();
        let view = PackedView::with_delta(&base, &delta);
        let mut got = vec![0.0f32; din];
        for j in 0..dout {
            view.decode_col_into(j, &mut got);
            for i in 0..din {
                assert_eq!(got[i], merged.w_int.at2(i, j), "({i},{j})");
            }
        }
    }

    #[test]
    fn rejects_shape_and_scale_drift() {
        let (base, ta) = setup(13, 4);
        // wrong-shaped adapter
        let mut rng = Rng::new(14);
        let small = TernaryAdapter::init(16, 8, 2, &mut rng);
        assert!(TernaryDelta::from_adapter(&base, &small, 1.0).is_err());
        // a "merged" layer whose scales moved is not a lossless merge
        let mut merged = lota_merge(&base.to_quantized().unwrap(), &ta, 2.0);
        merged.scales.data_mut()[0] += 1.0;
        assert!(TernaryDelta::from_merged(&base, &merged).is_err());
        // and one whose grid moved more than ±1
        let mut merged = base.to_quantized().unwrap();
        merged.w_int.data_mut()[0] += 2.0;
        assert!(TernaryDelta::from_merged(&base, &merged).is_err());
    }

    #[test]
    fn profiled_view_decodes_bit_identically() {
        // attaching a KernelProf times the fused sub-kernels but must not
        // move a single bit of the decoded column
        let (base, ta) = setup(17, 4);
        let delta = TernaryDelta::from_adapter(&base, &ta, 2.0).unwrap();
        let kp = KernelProf::default();
        let plain = PackedView::with_delta(&base, &delta);
        let profiled = PackedView::with_delta(&base, &delta).with_prof(Some(&kp));
        let mut a = vec![0.0f32; base.din()];
        let mut b = vec![0.0f32; base.din()];
        for _ in 0..50 {
            for j in 0..base.dout() {
                plain.decode_col_into(j, &mut a);
                profiled.decode_col_into(j, &mut b);
                assert_eq!(a, b, "col {j}");
            }
        }
        let (dq, ov) = kp.snapshot_ns();
        assert!(dq > 0, "1000 timed decodes accumulated no dequant time");
        assert!(ov > 0, "overlaid decodes accumulated no overlay time");
        // an un-profiled view leaves the accumulators untouched
        let kp2 = KernelProf::default();
        let detached = profiled.with_prof(None);
        detached.decode_col_into(0, &mut a);
        assert_eq!(kp2.snapshot_ns(), (0, 0));
    }

    #[test]
    fn delta_footprint_is_far_below_a_merged_copy() {
        let (base, ta) = setup(15, 4);
        let delta = TernaryDelta::from_adapter(&base, &ta, 2.0).unwrap();
        // 2-bit grid + one zero table vs 4-bit grid + two tables
        assert!(delta.deployed_bytes() < base.deployed_bytes());
    }
}
