//! Greedy decoding on the native engine — any batch size, no buckets.
//!
//! Semantics mirror `coordinator::eval::greedy_decode` (BOS + prompt + SEP
//! framing, recompute decoding, EOS / seq-len stopping, last-max argmax
//! tie-breaking) so backend comparisons are apples-to-apples. The one
//! deliberate difference: because nothing here has a fixed shape, each
//! forward runs at the *current* sequence length — the live prefix plus
//! generated tokens — instead of padding every request to `seq_len`.
//! Causal attention makes the trailing pad rows inert, so the logits at
//! each cursor are unchanged; the engine just skips computing them.

use anyhow::{bail, Result};

use crate::data::tokenizer::{self, BOS, EOS, SEP};
use crate::tensor::Tensor;

use super::forward::Engine;

/// One finished generation: the decoded text plus the number of tokens
/// actually generated — the honest unit behind tokens/s (a final forward
/// that argmaxes EOS generates nothing and is not counted).
#[derive(Clone, Debug)]
pub struct Generation {
    pub text: String,
    pub tokens: usize,
}

/// Greedy-decode completions for `prompts` in a single batch of exactly
/// `prompts.len()` rows.
pub fn greedy_decode(engine: &Engine, prompts: &[String], max_new: usize) -> Result<Vec<Generation>> {
    if prompts.is_empty() {
        return Ok(Vec::new());
    }
    let cfg = engine.config();
    let b = prompts.len();
    let t_cap = cfg.seq_len;

    // rows hold f32-coded ids, grown as generation proceeds
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(b);
    let mut cursor = vec![0usize; b];
    for (ri, p) in prompts.iter().enumerate() {
        let mut ids = vec![BOS];
        ids.extend(tokenizer::encode(&p.replace('\n', " ")));
        ids.push(SEP);
        if ids.len() + max_new > t_cap {
            bail!("prompt+generation ({}) exceeds seq_len {t_cap}", ids.len() + max_new);
        }
        cursor[ri] = ids.len() - 1;
        rows.push(ids.into_iter().map(|id| id as f32).collect());
    }

    let mut done = vec![false; b];
    let mut generated: Vec<Vec<u32>> = vec![Vec::new(); b];
    for _ in 0..max_new {
        if done.iter().all(|d| *d) {
            break;
        }
        // forward only the live prefix: positions 0..=max cursor
        let t_cur = cursor.iter().max().copied().unwrap_or(0) + 1;
        let mut tokens = vec![0.0f32; b * t_cur];
        for (ri, row) in rows.iter().enumerate() {
            let n = row.len().min(t_cur);
            tokens[ri * t_cur..ri * t_cur + n].copy_from_slice(&row[..n]);
        }
        let logits = engine.forward(&Tensor::new(&[b, t_cur], tokens))?;
        let v = cfg.vocab;
        for ri in 0..b {
            if done[ri] {
                continue;
            }
            let off = (ri * t_cur + cursor[ri]) * v;
            let lrow = &logits.data()[off..off + v];
            let next = lrow
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                .map(|(i, _)| i as u32)
                .unwrap();
            if next == EOS || cursor[ri] + 1 >= t_cap {
                done[ri] = true;
                continue;
            }
            cursor[ri] += 1;
            rows[ri].push(next as f32);
            generated[ri].push(next);
        }
    }

    Ok(generated
        .into_iter()
        .map(|g| Generation { text: tokenizer::decode(&g), tokens: g.len() })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::model;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn tiny_engine(seed: u64) -> Engine {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        Engine::from_store(&cfg, &store, 4).unwrap()
    }

    #[test]
    fn decodes_any_batch_size() {
        let engine = tiny_engine(1);
        for n in [1usize, 3, 5, 13] {
            let prompts: Vec<String> = (0..n).map(|i| format!("{i} + {i} =")).collect();
            let gens = greedy_decode(&engine, &prompts, 4).unwrap();
            assert_eq!(gens.len(), n);
            for g in &gens {
                assert!(g.tokens <= 4);
                // decode() filters specials, so chars never exceed steps
                assert!(g.text.chars().count() <= g.tokens);
            }
        }
    }

    #[test]
    fn token_counts_are_decode_steps() {
        let engine = tiny_engine(2);
        let gens = greedy_decode(&engine, &["1 + 2 =".to_string()], 6).unwrap();
        assert_eq!(gens.len(), 1);
        assert!(gens[0].tokens <= 6);
    }

    #[test]
    fn batch_composition_does_not_change_outputs() {
        // row independence: a prompt decodes identically alone and in a
        // mixed batch — the property buckets used to guarantee by shape
        let engine = tiny_engine(3);
        let prompts: Vec<String> =
            ["2 + 2 =", "9 - 4 =", "1 * 3 ="].iter().map(|s| s.to_string()).collect();
        let together = greedy_decode(&engine, &prompts, 5).unwrap();
        for (i, p) in prompts.iter().enumerate() {
            let alone = greedy_decode(&engine, std::slice::from_ref(p), 5).unwrap();
            assert_eq!(alone[0].text, together[i].text, "prompt {i}");
            assert_eq!(alone[0].tokens, together[i].tokens);
        }
    }

    #[test]
    fn empty_and_oversized_inputs() {
        let engine = tiny_engine(4);
        assert!(greedy_decode(&engine, &[], 4).unwrap().is_empty());
        let long = "1 + 2 = ".repeat(32);
        assert!(greedy_decode(&engine, &[long], 8).is_err());
    }
}
