//! Greedy decoding on the native engine — any batch size, no buckets.
//!
//! Semantics mirror `coordinator::eval::greedy_decode` (BOS + prompt + SEP
//! framing, EOS / seq-len stopping, last-max argmax tie-breaking) so
//! backend comparisons are apples-to-apples. Two execution strategies sit
//! behind the same semantics, selected by [`DecodeMode`]:
//!
//! * **Cached** (the default) — prefill every prompt once through
//!   [`Engine::forward_incremental`], then step one token per live row
//!   against the per-layer [`super::KvCache`]. Attention work per
//!   generated token is O(T) in prefix length and the GEMMs see one row
//!   per request, so a whole generation costs O(T) instead of the
//!   recompute path's O(T²).
//! * **Recompute** — re-run the full live prefix through
//!   [`Engine::forward`] every step. Kept alive as the reference
//!   implementation: `tests/engine_parity.rs` pins the two modes to
//!   bit-identical generations.
//!
//! Both paths drop finished rows from the step batch — a request that hit
//! EOS stops consuming forward compute instead of padding the batch until
//! the slowest request finishes. [`DecodeStats`] records what was actually
//! fed so tests and benches can assert on the savings rather than trust
//! the claim.

use anyhow::{bail, Result};

use crate::config::{DecodeMode, ModelConfig};
use crate::data::tokenizer::{self, BOS, EOS, PAD, SEP};
use crate::obs::profiler::Profiler;
use crate::tensor::Tensor;

use super::cache::KvCache;
use super::forward::Engine;

/// One finished generation: the decoded text plus the number of tokens
/// actually generated — the honest unit behind tokens/s (a final forward
/// that argmaxes EOS generates nothing and is not counted).
#[derive(Clone, Debug)]
pub struct Generation {
    pub text: String,
    pub tokens: usize,
}

/// What a decode actually fed through the engine. The cached path's
/// advantage is visible here, not asserted: recompute feeds the whole live
/// prefix every step (`forwarded_positions` ~ B·T²/2), the cached path
/// feeds each position once (~ B·T).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DecodeStats {
    /// engine forward invocations (the prefill plus one per decode step)
    pub forwards: usize,
    /// request rows fed across those invocations — finished rows leave
    /// the step batch, so this undershoots `batch × forwards` whenever
    /// requests finish at different times
    pub forwarded_rows: usize,
    /// (row × position) pairs fed — proportional to GEMM work, the
    /// O(T²)-vs-O(T) witness the benches report
    pub forwarded_positions: usize,
}

impl DecodeStats {
    /// Fold another decode's accounting into this one (batch aggregation).
    pub fn absorb(&mut self, other: &DecodeStats) {
        self.forwards += other.forwards;
        self.forwarded_rows += other.forwarded_rows;
        self.forwarded_positions += other.forwarded_positions;
    }
}

/// Greedy-decode completions for `prompts` in a single batch of exactly
/// `prompts.len()` rows, with the default KV-cached strategy.
pub fn greedy_decode(
    engine: &Engine,
    prompts: &[String],
    max_new: usize,
) -> Result<Vec<Generation>> {
    Ok(greedy_decode_with(engine, prompts, max_new, DecodeMode::Cached)?.0)
}

/// [`greedy_decode`] with an explicit [`DecodeMode`], returning the decode
/// accounting alongside the generations.
pub fn greedy_decode_with(
    engine: &Engine,
    prompts: &[String],
    max_new: usize,
    mode: DecodeMode,
) -> Result<(Vec<Generation>, DecodeStats)> {
    if prompts.is_empty() {
        return Ok((Vec::new(), DecodeStats::default()));
    }
    match mode {
        DecodeMode::Cached => decode_cached(engine, prompts, max_new),
        DecodeMode::Recompute => decode_recompute(engine, prompts, max_new),
    }
}

/// BOS + prompt + SEP framing for one prompt: the f32-coded row and its
/// cursor (the position whose logits pick the next token). Shared by the
/// one-shot strategies and the scheduler's per-request admission.
pub(crate) fn frame_prompt(
    cfg: &ModelConfig,
    prompt: &str,
    max_new: usize,
) -> Result<(Vec<f32>, usize)> {
    let t_cap = cfg.seq_len;
    let mut ids = vec![BOS];
    ids.extend(tokenizer::encode(&prompt.replace('\n', " ")));
    ids.push(SEP);
    if ids.len() + max_new > t_cap {
        bail!("prompt+generation ({}) exceeds seq_len {t_cap}", ids.len() + max_new);
    }
    let cursor = ids.len() - 1;
    Ok((ids.into_iter().map(|id| id as f32).collect(), cursor))
}

/// [`frame_prompt`] over a batch.
fn frame(
    cfg: &ModelConfig,
    prompts: &[String],
    max_new: usize,
) -> Result<(Vec<Vec<f32>>, Vec<usize>)> {
    let mut rows = Vec::with_capacity(prompts.len());
    let mut cursor = vec![0usize; prompts.len()];
    for (ri, p) in prompts.iter().enumerate() {
        let (row, cur) = frame_prompt(cfg, p, max_new)?;
        cursor[ri] = cur;
        rows.push(row);
    }
    Ok((rows, cursor))
}

/// Last-max argmax over one vocab row (ties resolve to the higher id,
/// matching the PJRT decoder).
pub(crate) fn argmax(lrow: &[f32]) -> u32 {
    lrow.iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
        .map(|(i, _)| i as u32)
        .unwrap()
}

/// Apply one picked token to a row's state; returns whether the row
/// finished (EOS or context cap — nothing appended in either case).
pub(crate) fn step_row(
    next: u32,
    t_cap: usize,
    row: &mut Vec<f32>,
    cursor: &mut usize,
    generated: &mut Vec<u32>,
) -> bool {
    if next == EOS || *cursor + 1 >= t_cap {
        return true;
    }
    *cursor += 1;
    row.push(next as f32);
    generated.push(next);
    false
}

fn finish(generated: Vec<Vec<u32>>) -> Vec<Generation> {
    generated
        .into_iter()
        .map(|g| Generation { text: tokenizer::decode(&g), tokens: g.len() })
        .collect()
}

/// Prefill a set of cache rows with their framed prompts in **one**
/// padded, batched incremental forward, and pick each row's first token.
///
/// `rows[i]` is the (strictly increasing) cache row that `frames[i]`
/// extends; every named row must be empty (fresh or
/// [`KvCache::reset_row`]). Ragged frames are padded to the longest and
/// truncated back to their true length afterwards, so the next token
/// overwrites the pad scratch — trailing pads are causally inert, which
/// is why a prefill's picks do not depend on what else shares the batch.
///
/// This is the single cached prefill implementation: the one-shot
/// [`greedy_decode`] calls it with the whole batch at once, the
/// continuous-batching scheduler (`crate::sched`) with whatever it
/// admitted this step — bit-identical picks either way.
#[allow(clippy::too_many_arguments)]
pub(crate) fn prefill_rows(
    engine: &Engine,
    cache: &mut KvCache,
    rows: &[usize],
    frames: &[Vec<f32>],
    adapters: &[u32],
    stats: &mut DecodeStats,
    prof: Option<&Profiler>,
) -> Result<Vec<u32>> {
    debug_assert_eq!(rows.len(), frames.len());
    let v = engine.config().vocab;
    let r = rows.len();
    let t0 = frames.iter().map(Vec::len).max().unwrap();
    let mut tokens = vec![PAD as f32; r * t0];
    for (i, f) in frames.iter().enumerate() {
        tokens[i * t0..i * t0 + f.len()].copy_from_slice(f);
    }
    let logits = engine.forward_incremental_profiled(
        &Tensor::new(&[r, t0], tokens),
        cache,
        rows,
        adapters,
        prof,
    )?;
    stats.forwards += 1;
    stats.forwarded_rows += r;
    stats.forwarded_positions += r * t0;
    let mut picks = Vec::with_capacity(r);
    for (i, f) in frames.iter().enumerate() {
        cache.truncate_row(rows[i], f.len());
        let off = (i * t0 + f.len() - 1) * v;
        picks.push(argmax(&logits.data()[off..off + v]));
    }
    Ok(picks)
}

/// One single-token decode step for `rows` (strictly increasing cache
/// rows), feeding `last[i]` — each row's newest token — and picking the
/// next via argmax. The shared step kernel of the one-shot cached decode
/// and the scheduler's iteration loop.
pub(crate) fn decode_step_rows(
    engine: &Engine,
    cache: &mut KvCache,
    rows: &[usize],
    last: &[f32],
    adapters: &[u32],
    stats: &mut DecodeStats,
    prof: Option<&Profiler>,
) -> Result<Vec<u32>> {
    debug_assert_eq!(rows.len(), last.len());
    let v = engine.config().vocab;
    let r = rows.len();
    let logits = engine.forward_incremental_profiled(
        &Tensor::new(&[r, 1], last.to_vec()),
        cache,
        rows,
        adapters,
        prof,
    )?;
    stats.forwards += 1;
    stats.forwarded_rows += r;
    stats.forwarded_positions += r;
    Ok((0..r).map(|i| argmax(&logits.data()[i * v..(i + 1) * v])).collect())
}

/// One-shot greedy decoding over a **paged** KV cache: same prompts, same
/// kernels, same picks as the cached default — only the cache's memory
/// shape differs (K/V live in `block_size`-token blocks from a pool sized
/// to the batch's horizon instead of per-row contiguous slabs). Pinned
/// bit-identical to [`greedy_decode`] in `tests/engine_parity.rs` and
/// `tests/kv_paged.rs`.
pub fn greedy_decode_paged(
    engine: &Engine,
    prompts: &[String],
    max_new: usize,
    block_size: usize,
) -> Result<(Vec<Generation>, DecodeStats)> {
    if prompts.is_empty() {
        return Ok((Vec::new(), DecodeStats::default()));
    }
    decode_cached_layout(engine, prompts, max_new, Some(block_size))
}

/// The KV-cached strategy: prefill once, then one token per live row per
/// step. The cache is created per batch and reused across every step of
/// that batch's generation. Built entirely on [`prefill_rows`] and
/// [`decode_step_rows`] — the same primitives the scheduler drives — so
/// the one-shot and scheduled paths cannot drift apart.
fn decode_cached(
    engine: &Engine,
    prompts: &[String],
    max_new: usize,
) -> Result<(Vec<Generation>, DecodeStats)> {
    decode_cached_layout(engine, prompts, max_new, None)
}

/// [`decode_cached`] over either cache layout: `block_size` selects paged
/// storage, `None` the contiguous reference.
fn decode_cached_layout(
    engine: &Engine,
    prompts: &[String],
    max_new: usize,
    block_size: Option<usize>,
) -> Result<(Vec<Generation>, DecodeStats)> {
    let cfg = engine.config();
    let b = prompts.len();
    let t_cap = cfg.seq_len;
    let (mut rows, mut cursor) = frame(cfg, prompts, max_new)?;
    let mut done = vec![false; b];
    let mut generated: Vec<Vec<u32>> = vec![Vec::new(); b];
    let mut stats = DecodeStats::default();
    if max_new == 0 {
        return Ok((finish(generated), stats));
    }

    // prefill: all prompts in one batched incremental forward. The cache
    // is sized to this batch's horizon, not the full context: no position
    // past t0 + max_new can ever be written. A paged pool of
    // b × ⌈horizon/bs⌉ blocks covers even the padded-prefill transient,
    // where every row briefly holds blocks for the longest frame.
    let t0 = rows.iter().map(Vec::len).max().unwrap();
    let horizon = (t0 + max_new).min(t_cap);
    let mut cache = match block_size {
        Some(bs) => engine.new_cache_paged(b, horizon, bs, b * horizon.div_ceil(bs))?,
        None => engine.new_cache_for(b, t0 + max_new),
    };
    let all: Vec<usize> = (0..b).collect();
    let picks = prefill_rows(engine, &mut cache, &all, &rows, &[], &mut stats, None)?;
    for (ri, next) in picks.into_iter().enumerate() {
        done[ri] = step_row(next, t_cap, &mut rows[ri], &mut cursor[ri], &mut generated[ri]);
    }

    // steps 2..=max_new: feed only the newest token of each live row; its
    // K/V join the cache, attention runs against the stored prefix
    for _ in 1..max_new {
        let active: Vec<usize> = (0..b).filter(|ri| !done[*ri]).collect();
        if active.is_empty() {
            break;
        }
        let step: Vec<f32> = active.iter().map(|ri| *rows[*ri].last().unwrap()).collect();
        let picks = decode_step_rows(engine, &mut cache, &active, &step, &[], &mut stats, None)?;
        for (i, &ri) in active.iter().enumerate() {
            done[ri] =
                step_row(picks[i], t_cap, &mut rows[ri], &mut cursor[ri], &mut generated[ri]);
        }
    }
    Ok((finish(generated), stats))
}

/// The reference strategy: every step re-runs the full live prefix of
/// every unfinished row. Finished rows leave the step batch (they used to
/// pad it until the whole batch drained).
fn decode_recompute(
    engine: &Engine,
    prompts: &[String],
    max_new: usize,
) -> Result<(Vec<Generation>, DecodeStats)> {
    let cfg = engine.config();
    let b = prompts.len();
    let t_cap = cfg.seq_len;
    let v = cfg.vocab;
    let (mut rows, mut cursor) = frame(cfg, prompts, max_new)?;
    let mut done = vec![false; b];
    let mut generated: Vec<Vec<u32>> = vec![Vec::new(); b];
    let mut stats = DecodeStats::default();
    for _ in 0..max_new {
        let active: Vec<usize> = (0..b).filter(|ri| !done[*ri]).collect();
        if active.is_empty() {
            break;
        }
        // forward only the live rows, padded to the longest live prefix;
        // causal attention keeps the trailing pads inert
        let t_cur = active.iter().map(|ri| cursor[*ri]).max().unwrap() + 1;
        let mut tokens = vec![PAD as f32; active.len() * t_cur];
        for (i, &ri) in active.iter().enumerate() {
            let n = rows[ri].len().min(t_cur);
            tokens[i * t_cur..i * t_cur + n].copy_from_slice(&rows[ri][..n]);
        }
        let logits = engine.forward(&Tensor::new(&[active.len(), t_cur], tokens))?;
        stats.forwards += 1;
        stats.forwarded_rows += active.len();
        stats.forwarded_positions += active.len() * t_cur;
        for (i, &ri) in active.iter().enumerate() {
            let off = (i * t_cur + cursor[ri]) * v;
            let next = argmax(&logits.data()[off..off + v]);
            done[ri] = step_row(next, t_cap, &mut rows[ri], &mut cursor[ri], &mut generated[ri]);
        }
    }
    Ok((finish(generated), stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::model;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn tiny_engine(seed: u64) -> Engine {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        Engine::from_store(&cfg, &store, 4).unwrap()
    }

    #[test]
    fn decodes_any_batch_size() {
        let engine = tiny_engine(1);
        for n in [1usize, 3, 5, 13] {
            let prompts: Vec<String> = (0..n).map(|i| format!("{i} + {i} =")).collect();
            let gens = greedy_decode(&engine, &prompts, 4).unwrap();
            assert_eq!(gens.len(), n);
            for g in &gens {
                assert!(g.tokens <= 4);
                // decode() filters specials, so chars never exceed steps
                assert!(g.text.chars().count() <= g.tokens);
            }
        }
    }

    #[test]
    fn token_counts_are_decode_steps() {
        let engine = tiny_engine(2);
        let gens = greedy_decode(&engine, &["1 + 2 =".to_string()], 6).unwrap();
        assert_eq!(gens.len(), 1);
        assert!(gens[0].tokens <= 6);
    }

    #[test]
    fn batch_composition_does_not_change_outputs() {
        // row independence: a prompt decodes identically alone and in a
        // mixed batch — cache rows never interact
        let engine = tiny_engine(3);
        let prompts: Vec<String> =
            ["2 + 2 =", "9 - 4 =", "1 * 3 ="].iter().map(|s| s.to_string()).collect();
        let together = greedy_decode(&engine, &prompts, 5).unwrap();
        for (i, p) in prompts.iter().enumerate() {
            let alone = greedy_decode(&engine, std::slice::from_ref(p), 5).unwrap();
            assert_eq!(alone[0].text, together[i].text, "prompt {i}");
            assert_eq!(alone[0].tokens, together[i].tokens);
        }
    }

    #[test]
    fn cached_and_recompute_agree() {
        let engine = tiny_engine(5);
        let prompts: Vec<String> = (0..4).map(|i| format!("{i} + 2 =")).collect();
        let (cached, cs) =
            greedy_decode_with(&engine, &prompts, 6, DecodeMode::Cached).unwrap();
        let (recomp, rs) =
            greedy_decode_with(&engine, &prompts, 6, DecodeMode::Recompute).unwrap();
        for (c, r) in cached.iter().zip(&recomp) {
            assert_eq!(c.text, r.text);
            assert_eq!(c.tokens, r.tokens);
        }
        // identical generations, very different work: the cached path feeds
        // each prompt position once, recompute feeds the prefix every step.
        // (Equality is possible only in the degenerate single-forward case
        // where every row EOSes immediately.)
        assert_eq!(cs.forwards, rs.forwards);
        assert!(cs.forwarded_positions <= rs.forwarded_positions);
        if rs.forwards > 1 {
            assert!(
                cs.forwarded_positions < rs.forwarded_positions,
                "cached fed {} positions, recompute {}",
                cs.forwarded_positions,
                rs.forwarded_positions
            );
        }
    }

    #[test]
    fn paged_decode_matches_contiguous_exactly() {
        let engine = tiny_engine(7);
        let prompts: Vec<String> = (0..5).map(|i| format!("{i} + {} =", (i * 3) % 10)).collect();
        let (want, ws) = greedy_decode_with(&engine, &prompts, 6, DecodeMode::Cached).unwrap();
        for bs in [1usize, 3, 16, 64] {
            let (got, gs) = greedy_decode_paged(&engine, &prompts, 6, bs).unwrap();
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.text, w.text, "bs={bs}");
                assert_eq!(g.tokens, w.tokens, "bs={bs}");
            }
            // same forwards, same rows, same positions — the layout is
            // invisible to the work accounting too
            assert_eq!(gs, ws, "bs={bs}");
        }
        // empty batch and zero budget behave like the contiguous path
        assert!(greedy_decode_paged(&engine, &[], 4, 16).unwrap().0.is_empty());
        let (gens, stats) = greedy_decode_paged(&engine, &prompts, 0, 16).unwrap();
        assert_eq!(gens.len(), 5);
        assert!(gens.iter().all(|g| g.tokens == 0));
        assert_eq!(stats, DecodeStats::default());
        // invalid block size fails loud
        assert!(greedy_decode_paged(&engine, &prompts, 4, 0).is_err());
    }

    #[test]
    fn zero_max_new_generates_nothing() {
        let engine = tiny_engine(6);
        for mode in [DecodeMode::Cached, DecodeMode::Recompute] {
            let (gens, stats) =
                greedy_decode_with(&engine, &["1 + 1 =".to_string()], 0, mode).unwrap();
            assert_eq!(gens.len(), 1);
            assert_eq!(gens[0].tokens, 0);
            assert_eq!(stats, DecodeStats::default(), "{mode:?} ran a forward for nothing");
        }
    }

    #[test]
    fn empty_and_oversized_inputs() {
        let engine = tiny_engine(4);
        assert!(greedy_decode(&engine, &[], 4).unwrap().is_empty());
        let long = "1 + 2 = ".repeat(32);
        assert!(greedy_decode(&engine, &[long.clone()], 8).is_err());
        let (gens, stats) =
            greedy_decode_with(&engine, &[], 4, DecodeMode::Recompute).unwrap();
        assert!(gens.is_empty());
        assert_eq!(stats, DecodeStats::default());
        assert!(greedy_decode_with(&engine, &[long], 8, DecodeMode::Recompute).is_err());
    }

    #[test]
    fn stats_absorb_sums_fields() {
        let mut a = DecodeStats { forwards: 1, forwarded_rows: 2, forwarded_positions: 30 };
        a.absorb(&DecodeStats { forwards: 2, forwarded_rows: 3, forwarded_positions: 7 });
        assert_eq!(a, DecodeStats { forwards: 3, forwarded_rows: 5, forwarded_positions: 37 });
    }
}
