//! Host-side linear algebra: blocked matmul and the Cholesky machinery GPTQ
//! needs for its damped inverse-Hessian (Frantar et al., 2022, §3).

use super::Tensor;

/// `C = A @ B` with a k-blocked inner loop (cache-friendly enough for the
/// quantizer-sized matrices that run on the host).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    // i-k-j loop order: streams B rows, accumulates into the C row.
    for i in 0..m {
        let crow = &mut out[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = ad[i * k + kk];
            if aik == 0.0 {
                continue; // ternary/sparse operands hit this a lot
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    Tensor::new(&[m, n], out)
}

/// `C = Aᵀ @ A` (the Hessian accumulation `2 X Xᵀ` uses this shape).
pub fn matmul_tt(a: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let mut out = vec![0.0f32; k * k];
    let ad = a.data();
    for r in 0..m {
        let row = &ad[r * k..(r + 1) * k];
        for i in 0..k {
            let v = row[i];
            if v == 0.0 {
                continue;
            }
            let orow = &mut out[i * k..(i + 1) * k];
            for j in i..k {
                orow[j] += v * row[j];
            }
        }
    }
    // mirror the upper triangle
    for i in 0..k {
        for j in 0..i {
            out[i * k + j] = out[j * k + i];
        }
    }
    Tensor::new(&[k, k], out)
}

/// Cholesky factorization `H = L Lᵀ` (lower). Returns `None` if H is not
/// positive definite (caller re-damps, as GPTQ does).
pub fn cholesky(h: &Tensor) -> Option<Tensor> {
    let n = h.rows();
    assert_eq!(n, h.cols());
    let mut l = vec![0.0f64; n * n];
    let hd = h.data();
    for i in 0..n {
        for j in 0..=i {
            let mut s = hd[i * n + j] as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(Tensor::new(&[n, n], l.into_iter().map(|v| v as f32).collect()))
}

/// Inverse of H via its Cholesky factor, returned as the **upper** Cholesky
/// factor `U` of `H⁻¹ = Uᵀ U`... more precisely GPTQ wants
/// `Cholesky(H⁻¹)ᵀ` — the upper-triangular factor whose diagonal entries
/// `U[j,j]` scale the per-column quantization error. Computed as:
/// `H = L Lᵀ  ⇒  H⁻¹ = L⁻ᵀ L⁻¹`, then a Cholesky of `H⁻¹` in upper form.
pub fn cholesky_inverse_upper(h: &Tensor) -> Option<Tensor> {
    let n = h.rows();
    let l = cholesky(h)?;
    // Invert lower-triangular L by forward substitution: L · Linv = I.
    let ld = l.data();
    let mut linv = vec![0.0f64; n * n];
    for col in 0..n {
        linv[col * n + col] = 1.0 / ld[col * n + col] as f64;
        for i in (col + 1)..n {
            let mut s = 0.0f64;
            for k in col..i {
                s += ld[i * n + k] as f64 * linv[k * n + col];
            }
            linv[i * n + col] = -s / ld[i * n + i] as f64;
        }
    }
    // Hinv = Linvᵀ · Linv  (upper-involved product)
    let mut hinv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f64;
            let kmin = i.max(j);
            for k in kmin..n {
                s += linv[k * n + i] * linv[k * n + j];
            }
            hinv[i * n + j] = s;
        }
    }
    // U = cholesky(Hinv)ᵀ — the `torch.linalg.cholesky(·, upper=True)`
    // convention GPTQ uses: Hinv = Uᵀ U with U upper-triangular, and row
    // U[i, i:] drives the error propagation from pivot i.
    let mut l2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = hinv[i * n + j];
            for k in 0..j {
                s -= l2[i * n + k] * l2[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l2[i * n + i] = s.sqrt();
            } else {
                l2[i * n + j] = s / l2[j * n + j];
            }
        }
    }
    let mut u = vec![0.0f32; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l2[i * n + j] as f32;
        }
    }
    Some(Tensor::new(&[n, n], u))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn matmul_tt_is_gram() {
        let a = Tensor::new(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let g = matmul_tt(&a);
        let gt = matmul(&a.transpose2(), &a);
        assert!(g.allclose(&gt, 1e-6, 1e-6));
    }

    #[test]
    fn cholesky_reconstructs() {
        // H = A Aᵀ + I is SPD
        let a = Tensor::new(&[3, 3], vec![1., 2., 0., 0.5, 1., 3., 2., 0., 1.]);
        let mut h = matmul(&a, &a.transpose2());
        for i in 0..3 {
            *h.at2_mut(i, i) += 1.0;
        }
        let l = cholesky(&h).unwrap();
        let rec = matmul(&l, &l.transpose2());
        assert!(rec.allclose(&h, 1e-4, 1e-4));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let h = Tensor::new(&[2, 2], vec![1., 2., 2., 1.]); // eigenvalues 3, -1
        assert!(cholesky(&h).is_none());
    }

    #[test]
    fn inverse_upper_factor_reconstructs_inverse() {
        let a = Tensor::new(&[3, 3], vec![2., 1., 0., 1., 3., 0.5, 0., 0.5, 1.5]);
        let mut h = matmul(&a, &a.transpose2());
        for i in 0..3 {
            *h.at2_mut(i, i) += 0.5;
        }
        let u = cholesky_inverse_upper(&h).unwrap();
        // Uᵀ U must equal H⁻¹, i.e. H · (Uᵀ U) = I
        let hinv = matmul(&u.transpose2(), &u);
        let id = matmul(&h, &hinv);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (id.at2(i, j) - want).abs() < 1e-3,
                    "H·Hinv[{i},{j}] = {}",
                    id.at2(i, j)
                );
            }
        }
        // and U is upper-triangular
        for i in 0..3 {
            for j in 0..i {
                assert_eq!(u.at2(i, j), 0.0);
            }
        }
    }
}
