//! Deterministic PRNG substrate (no `rand` crate offline): SplitMix64 for
//! seeding + xoshiro256** for the stream, Box–Muller normals, and the
//! Kaiming/ternary initializers the adapters need (paper §3.2: Kaiming
//! normal → ternarize at 0.75·mean|w|, Li et al. 2016).

/// xoshiro256** seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare: Option<f32>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-tensor / per-worker seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let mut u1 = self.uniform();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f32::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * std).collect()
    }

    /// Kaiming-normal std for a (fan_in, fan_out) linear: sqrt(2 / fan_in).
    pub fn kaiming_vec(&mut self, fan_in: usize, n: usize) -> Vec<f32> {
        let std = (2.0 / fan_in as f32).sqrt();
        self.normal_vec(n, std)
    }

    /// Paper §3.2 adapter init: Kaiming normal, then ternarize with
    /// threshold `0.75 · mean(|w|)` (Li et al., 2016 ternary networks).
    pub fn ternary_kaiming_vec(&mut self, fan_in: usize, n: usize) -> Vec<f32> {
        let w = self.kaiming_vec(fan_in, n);
        let mean_abs = w.iter().map(|v| v.abs()).sum::<f32>() / n.max(1) as f32;
        let thr = 0.75 * mean_abs;
        w.into_iter()
            .map(|v| {
                if v > thr {
                    1.0
                } else if v < -thr {
                    -1.0
                } else {
                    0.0
                }
            })
            .collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn ternary_init_values_and_sparsity() {
        let mut r = Rng::new(3);
        let w = r.ternary_kaiming_vec(64, 64 * 16);
        assert!(w.iter().all(|v| *v == -1.0 || *v == 0.0 || *v == 1.0));
        let nz = w.iter().filter(|v| **v != 0.0).count() as f32 / w.len() as f32;
        // 0.75·mean|w| threshold keeps roughly half the weights nonzero
        assert!(nz > 0.3 && nz < 0.7, "nonzero frac {nz}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(11);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let bv: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(av, bv);
    }
}
