//! Minimal dense-tensor substrate.
//!
//! The offline environment ships no ndarray/BLAS crates, so the host-side
//! numerics the coordinator needs — GPTQ's Hessian algebra, adapter merges,
//! evaluation metrics — run on this small row-major f32 tensor. The PJRT
//! artifacts do the model-scale compute; this module only has to be correct
//! and reasonably fast for quantizer/merge-sized matrices.

pub mod linalg;
pub mod rng;

pub use linalg::{cholesky_inverse_upper, matmul, matmul_tt};
pub use rng::Rng;

use std::fmt;

/// Dense row-major f32 tensor with up to 3 dimensions (enough for the
/// layer-stacked parameter tensors that cross the PJRT boundary).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn from_scalar(v: f32) -> Self {
        Tensor { shape: vec![1], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() needs a 2-D tensor");
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() needs a 2-D tensor");
        self.shape[1]
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn at2_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert_eq!(self.shape.len(), 2);
        &mut self.data[i * self.shape[1] + j]
    }

    /// Borrow row `i` of a 2-D tensor (last axis of any tensor).
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.shape[self.shape.len() - 1];
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.shape[self.shape.len() - 1];
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Slice the leading axis of a 3-D tensor into a 2-D copy.
    pub fn layer(&self, l: usize) -> Tensor {
        assert_eq!(self.shape.len(), 3);
        let (a, b) = (self.shape[1], self.shape[2]);
        let sz = a * b;
        Tensor::new(&[a, b], self.data[l * sz..(l + 1) * sz].to_vec())
    }

    /// Write a 2-D tensor into layer `l` of a 3-D tensor.
    pub fn set_layer(&mut self, l: usize, t: &Tensor) {
        assert_eq!(self.shape.len(), 3);
        let (a, b) = (self.shape[1], self.shape[2]);
        assert_eq!(t.shape(), &[a, b]);
        let sz = a * b;
        self.data[l * sz..(l + 1) * sz].copy_from_slice(t.data());
    }

    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.shape.len(), 2);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; r * c];
        for i in 0..r {
            for j in 0..c {
                out[j * r + i] = self.data[i * c + j];
            }
        }
        Tensor::new(&[c, r], out)
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Tensor {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn scale(self, s: f32) -> Tensor {
        self.map(|v| v * s)
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        if self.shape != other.shape {
            return false;
        }
        self.data
            .iter()
            .zip(&other.data)
            .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_index() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(0, 2), 3.0);
        assert_eq!(t.at2(1, 0), 4.0);
        assert_eq!(t.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
    }

    #[test]
    fn layer_slicing() {
        let mut t = Tensor::zeros(&[2, 2, 2]);
        let l1 = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        t.set_layer(1, &l1);
        assert_eq!(t.layer(1), l1);
        assert_eq!(t.layer(0), Tensor::zeros(&[2, 2]));
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(&[2], vec![1., -3.]);
        let b = Tensor::new(&[2], vec![0.5, 1.]);
        assert_eq!(a.add(&b).data(), &[1.5, -2.]);
        assert_eq!(a.sub(&b).data(), &[0.5, -4.]);
        assert_eq!(a.abs_max(), 3.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]);
        let b = Tensor::new(&[2], vec![1.0 + 1e-7, 2.0 - 1e-7]);
        assert!(a.allclose(&b, 1e-5, 1e-6));
        let c = Tensor::new(&[2], vec![1.1, 2.0]);
        assert!(!a.allclose(&c, 1e-5, 1e-6));
    }
}
