//! Hand-rolled benchmark harness (no criterion offline): warmup + timed
//! iterations with mean/p50/p95, plus the table printer every paper-figure
//! bench uses to emit its rows.

use std::time::Instant;

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub min_secs: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_secs > 0.0 {
            1.0 / self.mean_secs
        } else {
            0.0
        }
    }
}

/// Run `f` `warmup + iters` times, timing the last `iters`.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let pct = |q: f64| samples[((q * (n - 1) as f64).round() as usize).min(n - 1)];
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_secs: samples.iter().sum::<f64>() / n as f64,
        p50_secs: pct(0.5),
        p95_secs: pct(0.95),
        min_secs: samples[0],
    }
}

/// Markdown-ish table printer: fixed-width rows the bench binaries emit so
/// bench_output.txt diffs cleanly against EXPERIMENTS.md.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len().max(8)).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            println!("{s}");
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Format a float as a fixed-precision cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut calls = 0;
        let r = bench("x", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs >= 0.0);
        assert!(r.p50_secs <= r.p95_secs + 1e-12);
    }

    #[test]
    fn table_formats_rows() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3.5f64, &"x"]);
        assert_eq!(t.rows.len(), 2);
        t.print(); // should not panic
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
