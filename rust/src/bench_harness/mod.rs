//! Hand-rolled benchmark harness (no criterion offline): warmup + timed
//! iterations with mean/p50/p95, plus the table printer every paper-figure
//! bench uses to emit its rows — and [`JsonReport`], the machine-readable
//! twin of those tables (`BENCH_<name>.json`) that the CI perf gate
//! parses and future trajectory tracking reads.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::config::JsonWriter;

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p95_secs: f64,
    pub min_secs: f64,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        if self.mean_secs > 0.0 {
            1.0 / self.mean_secs
        } else {
            0.0
        }
    }
}

/// Run `f` `warmup + iters` times, timing the last `iters`.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let pct = |q: f64| samples[((q * (n - 1) as f64).round() as usize).min(n - 1)];
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_secs: samples.iter().sum::<f64>() / n as f64,
        p50_secs: pct(0.5),
        p95_secs: pct(0.95),
        min_secs: samples[0],
    }
}

/// Markdown-ish table printer: fixed-width rows the bench binaries emit so
/// bench_output.txt diffs cleanly against EXPERIMENTS.md.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            widths: headers.iter().map(|s| s.len().max(8)).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            println!("{s}");
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Format a float as a fixed-precision cell.
pub fn f(v: f64, prec: usize) -> String {
    format!("{v:.prec$}")
}

/// One metadata value in a [`JsonReport`] header.
enum MetaVal {
    Num(f64),
    Str(String),
    Bool(bool),
}

/// Machine-readable bench output, emitted alongside the markdown tables.
///
/// Schema (parsed by the CI `perf-gate` job and by trajectory tooling):
///
/// ```json
/// {
///   "bench": "<name>",
///   "meta": { "<key>": <num|str|bool>, ... },
///   "results": [
///     { "name": "...", "iters": N, "mean_secs": ..., "p50_secs": ...,
///       "p95_secs": ..., "min_secs": ... },
///     ...
///   ]
/// }
/// ```
///
/// Results keep insertion order; meta keys keep insertion order too (the
/// streaming writer never re-sorts), and re-setting a key appends rather
/// than replaces — set each key once.
pub struct JsonReport {
    bench: String,
    meta: Vec<(String, MetaVal)>,
    results: Vec<BenchResult>,
}

impl JsonReport {
    pub fn new(bench: &str) -> JsonReport {
        JsonReport { bench: bench.to_string(), meta: Vec::new(), results: Vec::new() }
    }

    /// Where a bench's JSON lands: `$LOTA_BENCH_JSON_DIR/BENCH_<name>.json`
    /// (or the current directory when the env var is unset — the repo
    /// root under `cargo bench`, which is where CI picks it up).
    pub fn default_path(bench: &str) -> PathBuf {
        let dir = std::env::var("LOTA_BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        PathBuf::from(dir).join(format!("BENCH_{bench}.json"))
    }

    pub fn meta_num(&mut self, key: &str, v: f64) -> &mut Self {
        self.meta.push((key.to_string(), MetaVal::Num(v)));
        self
    }

    pub fn meta_str(&mut self, key: &str, v: &str) -> &mut Self {
        self.meta.push((key.to_string(), MetaVal::Str(v.to_string())));
        self
    }

    pub fn meta_bool(&mut self, key: &str, v: bool) -> &mut Self {
        self.meta.push((key.to_string(), MetaVal::Bool(v)));
        self
    }

    /// Record one timing summary (called right after [`bench`]).
    pub fn push(&mut self, r: &BenchResult) -> &mut Self {
        self.results.push(r.clone());
        self
    }

    pub fn len(&self) -> usize {
        self.results.len()
    }

    pub fn is_empty(&self) -> bool {
        self.results.is_empty()
    }

    /// Serialize to the schema above.
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("bench").str(&self.bench);
        w.key("meta").begin_obj();
        for (k, v) in &self.meta {
            w.key(k);
            match v {
                MetaVal::Num(n) => w.num(*n),
                MetaVal::Str(s) => w.str(s),
                MetaVal::Bool(b) => w.bool(*b),
            };
        }
        w.end_obj();
        w.key("results").begin_arr();
        for r in &self.results {
            w.begin_obj();
            w.key("name").str(&r.name);
            w.key("iters").num(r.iters as f64);
            w.key("mean_secs").num(r.mean_secs);
            w.key("p50_secs").num(r.p50_secs);
            w.key("p95_secs").num(r.p95_secs);
            w.key("min_secs").num(r.min_secs);
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }

    /// Write the JSON to `path` (creating parent directories). Callers
    /// may write mid-run and again at the end — the file is replaced
    /// wholesale, so a bench that later fails still leaves the rows it
    /// completed.
    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iterations() {
        let mut calls = 0;
        let r = bench("x", 2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean_secs >= 0.0);
        assert!(r.p50_secs <= r.p95_secs + 1e-12);
    }

    #[test]
    fn table_formats_rows() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3.5f64, &"x"]);
        assert_eq!(t.rows.len(), 2);
        t.print(); // should not panic
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn json_report_roundtrips_through_the_parser() {
        use crate::config::Json;
        let r1 = bench("fast", 0, 3, || {});
        let r2 = bench("slow", 0, 3, || std::thread::sleep(std::time::Duration::from_micros(50)));
        let mut jr = JsonReport::new("unit");
        assert!(jr.is_empty());
        jr.meta_bool("quick", true);
        jr.meta_str("kernel", "avx2");
        jr.meta_num("speedup_min", 1.75);
        jr.push(&r1);
        jr.push(&r2);
        assert_eq!(jr.len(), 2);
        let parsed = Json::parse(&jr.to_json()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "unit");
        let meta = parsed.get("meta").unwrap();
        assert_eq!(meta.get("kernel").unwrap().as_str().unwrap(), "avx2");
        assert!((meta.get("speedup_min").unwrap().as_f64().unwrap() - 1.75).abs() < 1e-12);
        let results = parsed.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("name").unwrap().as_str().unwrap(), "fast");
        assert_eq!(results[1].get("iters").unwrap().as_usize().unwrap(), 3);
        for r in results {
            let mean = r.get("mean_secs").unwrap().as_f64().unwrap();
            let p50 = r.get("p50_secs").unwrap().as_f64().unwrap();
            let p95 = r.get("p95_secs").unwrap().as_f64().unwrap();
            assert!(mean >= 0.0 && p50 <= p95 + 1e-12);
        }
    }

    #[test]
    fn json_report_writes_where_told() {
        let dir = std::env::temp_dir().join(format!("lota_bench_json_{}", std::process::id()));
        let path = dir.join("BENCH_unit.json");
        let mut jr = JsonReport::new("unit");
        jr.push(&bench("x", 0, 1, || {}));
        jr.write(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"bench\":\"unit\""));
        // overwrite-in-place (the mid-run flush pattern) keeps it parseable
        jr.push(&bench("y", 0, 1, || {}));
        jr.write(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        crate::config::Json::parse(&body).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
