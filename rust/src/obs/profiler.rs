//! Engine hot-path profiler: scoped kernel-phase timings per (layer,
//! kind) and per forward window, zero-cost when off.
//!
//! PR 6 made the *scheduler* observable; below `prefill_forward` /
//! `decode_forward` the engine stayed a black box. [`Profiler`] opens it
//! under the same two rules every observer in this repo obeys:
//!
//! 1. **Inert when off.** The scheduler holds `Option<Profiler>`
//!    (default `None`) and the engine receives `Option<&Profiler>` —
//!    every emission site is one never-taken branch, nothing allocates,
//!    and attaching a profiler is pinned bitwise invisible on scheduler
//!    outputs (`tests/obs.rs`).
//! 2. **One clock.** The profiler never reads its own "window" clock:
//!    the scheduler opens each window with the *same* `Instant` it
//!    stamps `StepReport.prefill_ms` / `decode_ms` from, and the engine
//!    marks phase boundaries by cursor-marching — each mark attributes
//!    `at − cursor` (an integer-nanosecond `Duration`) to a phase and
//!    advances the cursor. Segment durations therefore tile the window
//!    **exactly**: their sum equals the window's `Duration`, so
//!    `1e3 · sum.as_secs_f64()` bit-equals the enclosing `StepReport`
//!    wall-time. No second timestamp source exists.
//!
//! Attribution inside a fused kernel needs one extra trick: dequant and
//! delta-overlay work is interleaved per column *inside* the packed
//! GEMM, so no cursor mark can separate them. [`KernelProf`] carries two
//! relaxed `AtomicU64` nanosecond accumulators that
//! `PackedView::decode_col_into` feeds when profiled; at each mark the
//! profiler diffs the accumulators against its last snapshot and splits
//! the elapsed segment into gemm / dequant / delta_overlay parts (the
//! sub-parts are true sub-intervals — profiled GEMMs run single-threaded,
//! which is bitwise safe because thread count never changes output bits).
//!
//! Surfaces:
//! * Perfetto tracks — attach a [`RecordingTracer`] sink
//!   ([`Profiler::with_sink`], ideally the same tracer the scheduler
//!   writes to so one `t0` governs everything) and every segment becomes
//!   a `B`/`E` span pair on pid 3 (`Track::Engine(layer)`), nested
//!   inside the scheduler's forward spans by construction.
//! * [`MetricsRegistry`] — [`Profiler::fill_registry`] folds all windows
//!   into `lota_engine_phase_ms_total{layer="…",kind="…"}` counters
//!   (`lota serve --profile-out`).
//! * [`Profiler::windows`] — the raw per-window profiles, what the
//!   reconciliation tests assert on.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::obs::registry::MetricsRegistry;
use crate::obs::tracer::{RecordingTracer, Tracer, Track};

/// Reserved [`Track::Engine`] tid for step-scope phases that belong to
/// no single layer (embedding + validation, block allocation, the final
/// layernorm + head matmul, the post-forward tail). Far above any real
/// layer count, and exactly representable as f64 in the Chrome export.
pub const STEP_TID: u64 = 1 << 20;

/// What a profiled segment of engine time was spent on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PhaseKind {
    /// the Q/K/V projections (plus the ln1 they read), per layer
    GemmQkv,
    /// the attention-output projection WO (plus the residual add)
    GemmO,
    /// the MLP pair W_up · gelu · W_down (plus ln2)
    GemmMlp,
    /// the attention score/softmax/AXPY loops
    Attention,
    /// packed-code column decode inside the GEMM ([`KernelProf`])
    Dequant,
    /// ternary-delta overlay application inside the GEMM ([`KernelProf`])
    DeltaOverlay,
    /// KV traffic: appending K/V rows to the cache; on [`STEP_TID`],
    /// paged block allocation (`ensure_blocks`)
    KvPage,
    /// everything else in the window: embedding/validation, final
    /// layernorm + head matmul, and the post-forward tail up to the
    /// scheduler's window end (argmax, `apply_pick`, …)
    Other,
}

impl PhaseKind {
    /// Stable label used for span names and metric `kind` label values.
    pub fn label(self) -> &'static str {
        match self {
            PhaseKind::GemmQkv => "qkv_gemm",
            PhaseKind::GemmO => "o_gemm",
            PhaseKind::GemmMlp => "mlp_gemm",
            PhaseKind::Attention => "attention",
            PhaseKind::Dequant => "dequant",
            PhaseKind::DeltaOverlay => "delta_overlay",
            PhaseKind::KvPage => "kv_page",
            PhaseKind::Other => "other",
        }
    }
}

/// Which scheduler forward a window encloses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ForwardPhase {
    Prefill,
    Decode,
}

impl ForwardPhase {
    pub fn label(self) -> &'static str {
        match self {
            ForwardPhase::Prefill => "prefill",
            ForwardPhase::Decode => "decode",
        }
    }
}

/// Nanosecond accumulators fed from *inside* the fused GEMM kernel
/// (column decode / delta overlay), where cursor marks cannot reach.
/// Atomics keep `PackedView` `Copy + Send` for the threaded GEMM path —
/// though profiled GEMMs force one thread so the accumulated intervals
/// stay disjoint sub-intervals of the enclosing segment.
#[derive(Debug, Default)]
pub struct KernelProf {
    dequant_ns: AtomicU64,
    overlay_ns: AtomicU64,
}

impl KernelProf {
    pub fn add_dequant_ns(&self, ns: u64) {
        self.dequant_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn add_overlay_ns(&self, ns: u64) {
        self.overlay_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Cumulative (dequant, overlay) nanoseconds since construction.
    pub fn snapshot_ns(&self) -> (u64, u64) {
        (self.dequant_ns.load(Ordering::Relaxed), self.overlay_ns.load(Ordering::Relaxed))
    }
}

/// One closed forward window: its exact wall `Duration` and the phase
/// segments that tile it. `segments.values().sum() == total` holds by
/// construction (integer-nanosecond arithmetic, no float rounding).
#[derive(Clone, Debug)]
pub struct WindowProfile {
    pub phase: ForwardPhase,
    /// scheduler step number the window belongs to
    pub step: u64,
    pub total: Duration,
    /// (tid, kind) → time; tid is a layer index or [`STEP_TID`]
    pub segments: BTreeMap<(u64, PhaseKind), Duration>,
}

#[derive(Debug)]
struct Window {
    phase: ForwardPhase,
    step: u64,
    start: Instant,
    cursor: Instant,
    dq_snap: u64,
    ov_snap: u64,
    segments: BTreeMap<(u64, PhaseKind), Duration>,
}

#[derive(Debug, Default)]
struct ProfBuf {
    window: Option<Window>,
    windows: Vec<WindowProfile>,
    sink: Option<RecordingTracer>,
}

/// The engine profiler handle: clonable, single-threaded, shared between
/// the scheduler (opens/closes windows) and the engine (marks phases) —
/// the same `Rc<RefCell<…>>` idiom as [`RecordingTracer`].
#[derive(Clone, Debug)]
pub struct Profiler {
    buf: Rc<RefCell<ProfBuf>>,
    kernel: Rc<KernelProf>,
}

impl Default for Profiler {
    fn default() -> Profiler {
        Profiler::new()
    }
}

impl Profiler {
    pub fn new() -> Profiler {
        Profiler {
            buf: Rc::new(RefCell::new(ProfBuf::default())),
            kernel: Rc::new(KernelProf::default()),
        }
    }

    /// Also emit every segment as a `B`/`E` span pair on
    /// [`Track::Engine`] into `sink`. Pass the *same* `RecordingTracer`
    /// the scheduler traces into: the engine spans then share its `t0`
    /// and nest exactly inside `prefill_forward` / `decode_forward`.
    pub fn with_sink(self, sink: RecordingTracer) -> Profiler {
        self.buf.borrow_mut().sink = Some(sink);
        self
    }

    /// The in-kernel accumulator views (`PackedView`) feed. Borrowed per
    /// forward by the engine's profiled GEMM calls.
    pub fn kernel(&self) -> &KernelProf {
        &self.kernel
    }

    /// Open a forward window at `at` — the scheduler calls this with the
    /// exact `Instant` it stamps the matching `StepReport` phase start
    /// from. The cursor starts at `at`.
    pub fn begin_window(&self, phase: ForwardPhase, step: u64, at: Instant) {
        let (dq, ov) = self.kernel.snapshot_ns();
        let mut b = self.buf.borrow_mut();
        debug_assert!(b.window.is_none(), "profiler window already open");
        b.window = Some(Window {
            phase,
            step,
            start: at,
            cursor: at,
            dq_snap: dq,
            ov_snap: ov,
            segments: BTreeMap::new(),
        });
    }

    /// Attribute the time since the last mark (or the window start) to
    /// `(tid, kind)` and advance the cursor to `at`. Dequant/overlay
    /// nanoseconds accumulated in [`KernelProf`] since the last mark are
    /// split out into their own kinds under the same tid; the three
    /// parts tile the elapsed segment exactly. No-op outside a window.
    pub fn mark(&self, tid: u64, kind: PhaseKind, at: Instant) {
        let (dq_now, ov_now) = self.kernel.snapshot_ns();
        let mut b = self.buf.borrow_mut();
        let ProfBuf { window, sink, .. } = &mut *b;
        let Some(win) = window.as_mut() else { return };
        let elapsed = at.checked_duration_since(win.cursor).unwrap_or(Duration::ZERO);
        // the in-kernel intervals are true sub-intervals of `elapsed`
        // (single-threaded profiled GEMMs); the clamp keeps the split
        // tiling `elapsed` exactly even under clock pathology
        let dq = Duration::from_nanos(dq_now - win.dq_snap).min(elapsed);
        let ov = Duration::from_nanos(ov_now - win.ov_snap).min(elapsed - dq);
        let main = elapsed - dq - ov;
        let span_start = win.cursor;
        win.cursor = at;
        win.dq_snap = dq_now;
        win.ov_snap = ov_now;
        *win.segments.entry((tid, kind)).or_default() += main;
        if dq > Duration::ZERO {
            *win.segments.entry((tid, PhaseKind::Dequant)).or_default() += dq;
        }
        if ov > Duration::ZERO {
            *win.segments.entry((tid, PhaseKind::DeltaOverlay)).or_default() += ov;
        }
        if let Some(tr) = sink.as_mut() {
            // one span for the whole segment; the fused sub-kernel parts
            // ride as counters (they interleave per column, so spans
            // would be thousands of slivers)
            tr.begin(Track::Engine(tid), kind.label(), span_start);
            tr.end(Track::Engine(tid), kind.label(), at);
            if dq > Duration::ZERO {
                tr.counter(Track::Engine(tid), "dequant_ms", 1e3 * dq.as_secs_f64(), at);
            }
            if ov > Duration::ZERO {
                tr.counter(Track::Engine(tid), "delta_overlay_ms", 1e3 * ov.as_secs_f64(), at);
            }
        }
    }

    /// Close the window at `at` — again the scheduler's own `Instant`
    /// (the one `StepReport.prefill_ms`/`decode_ms` is computed from).
    /// The trailing gap since the last mark lands in
    /// `(STEP_TID, Other)`, so the segments tile `[start, at]` exactly.
    pub fn end_window(&self, at: Instant) {
        self.mark(STEP_TID, PhaseKind::Other, at);
        let mut b = self.buf.borrow_mut();
        let Some(win) = b.window.take() else { return };
        let total = at.checked_duration_since(win.start).unwrap_or(Duration::ZERO);
        debug_assert_eq!(
            total,
            win.segments.values().sum::<Duration>(),
            "profiler segments failed to tile the window"
        );
        b.windows.push(WindowProfile {
            phase: win.phase,
            step: win.step,
            total,
            segments: win.segments,
        });
    }

    /// All closed windows so far, in order.
    pub fn windows(&self) -> Vec<WindowProfile> {
        self.buf.borrow().windows.clone()
    }

    /// Fold every closed window into `reg` as labeled counters:
    /// `lota_engine_phase_ms_total{layer="<i>|step",kind="<label>"}`
    /// plus window counts and total forward wall-time per phase
    /// (`lota_engine_{prefill,decode}_forward_ms_total`,
    /// `lota_engine_{prefill,decode}_windows_total`).
    pub fn fill_registry(&self, reg: &mut MetricsRegistry) {
        let b = self.buf.borrow();
        let mut totals: BTreeMap<(u64, PhaseKind), Duration> = BTreeMap::new();
        let mut windows = [0u64; 2];
        let mut wall = [Duration::ZERO; 2];
        for w in &b.windows {
            for (k, d) in &w.segments {
                *totals.entry(*k).or_default() += *d;
            }
            let i = match w.phase {
                ForwardPhase::Prefill => 0,
                ForwardPhase::Decode => 1,
            };
            windows[i] += 1;
            wall[i] += w.total;
        }
        for ((tid, kind), d) in totals {
            let layer =
                if tid == STEP_TID { "step".to_string() } else { tid.to_string() };
            reg.inc(
                &format!(
                    "lota_engine_phase_ms_total{{layer=\"{layer}\",kind=\"{}\"}}",
                    kind.label()
                ),
                1e3 * d.as_secs_f64(),
            );
        }
        reg.inc("lota_engine_prefill_windows_total", windows[0] as f64);
        reg.inc("lota_engine_decode_windows_total", windows[1] as f64);
        reg.inc("lota_engine_prefill_forward_ms_total", 1e3 * wall[0].as_secs_f64());
        reg.inc("lota_engine_decode_forward_ms_total", 1e3 * wall[1].as_secs_f64());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(n: u64) -> Duration {
        Duration::from_millis(n)
    }

    #[test]
    fn segments_tile_the_window_exactly() {
        let t0 = Instant::now();
        let p = Profiler::new();
        p.begin_window(ForwardPhase::Prefill, 3, t0);
        p.mark(0, PhaseKind::GemmQkv, t0 + ms(2));
        p.mark(0, PhaseKind::Attention, t0 + ms(5));
        p.mark(1, PhaseKind::GemmMlp, t0 + ms(6));
        p.end_window(t0 + ms(8));
        let ws = p.windows();
        assert_eq!(ws.len(), 1);
        let w = &ws[0];
        assert_eq!(w.phase, ForwardPhase::Prefill);
        assert_eq!(w.step, 3);
        assert_eq!(w.total, ms(8));
        assert_eq!(w.segments[&(0, PhaseKind::GemmQkv)], ms(2));
        assert_eq!(w.segments[&(0, PhaseKind::Attention)], ms(3));
        assert_eq!(w.segments[&(1, PhaseKind::GemmMlp)], ms(1));
        // the trailing gap lands in (STEP_TID, Other)
        assert_eq!(w.segments[&(STEP_TID, PhaseKind::Other)], ms(2));
        // the exactness claim itself: integer-duration tiling
        assert_eq!(w.segments.values().sum::<Duration>(), w.total);
    }

    #[test]
    fn kernel_accumulators_split_out_of_the_enclosing_mark() {
        let t0 = Instant::now();
        let p = Profiler::new();
        p.begin_window(ForwardPhase::Decode, 0, t0);
        p.kernel().add_dequant_ns(1_000_000); // 1 ms of column decode
        p.kernel().add_overlay_ns(500_000); // 0.5 ms of delta overlay
        p.mark(2, PhaseKind::GemmQkv, t0 + ms(4));
        p.end_window(t0 + ms(4));
        let w = &p.windows()[0];
        assert_eq!(w.segments[&(2, PhaseKind::Dequant)], ms(1));
        assert_eq!(w.segments[&(2, PhaseKind::DeltaOverlay)], Duration::from_micros(500));
        // gemm gets the remainder: 4 − 1 − 0.5 ms
        assert_eq!(w.segments[&(2, PhaseKind::GemmQkv)], Duration::from_micros(2500));
        assert_eq!(w.segments.values().sum::<Duration>(), w.total);
    }

    #[test]
    fn marks_outside_a_window_are_ignored() {
        let p = Profiler::new();
        p.mark(0, PhaseKind::Attention, Instant::now());
        p.end_window(Instant::now());
        assert!(p.windows().is_empty());
    }

    #[test]
    fn sink_receives_nested_engine_spans_on_the_shared_clock() {
        let tr = RecordingTracer::new();
        let p = Profiler::new().with_sink(tr.clone());
        let t0 = Instant::now();
        p.begin_window(ForwardPhase::Prefill, 0, t0);
        p.kernel().add_dequant_ns(100_000);
        p.mark(0, PhaseKind::GemmQkv, t0 + ms(1));
        p.end_window(t0 + ms(2));
        let ev = tr.events();
        // qkv B/E + dequant counter + trailing other B/E
        assert_eq!(ev.len(), 5);
        assert_eq!(ev[0].track, Track::Engine(0));
        assert_eq!(ev[0].name, "qkv_gemm");
        assert!(matches!(ev[2].kind, crate::obs::tracer::EventKind::Counter(v) if v > 0.0));
        assert_eq!(ev[3].track, Track::Engine(STEP_TID));
        assert_eq!(ev[3].name, "other");
        // span timestamps are monotone within the window
        assert!(ev[0].ts_us <= ev[1].ts_us && ev[1].ts_us <= ev[4].ts_us);
    }

    #[test]
    fn registry_fold_produces_labeled_engine_keys() {
        let t0 = Instant::now();
        let p = Profiler::new();
        p.begin_window(ForwardPhase::Prefill, 0, t0);
        p.mark(1, PhaseKind::GemmQkv, t0 + ms(2));
        p.end_window(t0 + ms(2));
        p.begin_window(ForwardPhase::Decode, 1, t0 + ms(3));
        p.mark(1, PhaseKind::GemmQkv, t0 + ms(4));
        p.mark(STEP_TID, PhaseKind::KvPage, t0 + ms(5));
        p.end_window(t0 + ms(5));
        let mut reg = MetricsRegistry::new();
        p.fill_registry(&mut reg);
        let qkv = reg
            .counter("lota_engine_phase_ms_total{layer=\"1\",kind=\"qkv_gemm\"}")
            .unwrap();
        assert!((qkv - 3.0).abs() < 1e-9, "qkv ms {qkv}");
        assert_eq!(
            reg.counter("lota_engine_phase_ms_total{layer=\"step\",kind=\"kv_page\"}"),
            Some(1.0)
        );
        assert_eq!(reg.counter("lota_engine_prefill_windows_total"), Some(1.0));
        assert_eq!(reg.counter("lota_engine_decode_windows_total"), Some(1.0));
        assert_eq!(reg.counter("lota_engine_prefill_forward_ms_total"), Some(2.0));
        assert_eq!(reg.counter("lota_engine_decode_forward_ms_total"), Some(2.0));
    }
}
