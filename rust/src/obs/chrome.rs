//! Chrome-trace-event JSON export of a recorded run.
//!
//! Output follows the Trace Event Format's "JSON object" flavor —
//! `{"traceEvents": [...], "displayTimeUnit": "ms"}` — which both
//! Perfetto (<https://ui.perfetto.dev>, drag-and-drop the file) and the
//! legacy `chrome://tracing` UI load directly. Mapping:
//!
//! * [`Track::Scheduler`] → pid 1 / tid 0, process name `scheduler`:
//!   the per-step phase spans (`step`, `admission`, `prefill_forward`,
//!   `decode_forward`, `kv_release`) and counter tracks.
//! * [`Track::Request`]`(id)` → pid 2 / tid = id, process name
//!   `requests`, thread name `req <id>`: that request's lifecycle chain
//!   (`request` enclosing `queued`, `prefill`, `decode_step`…).
//! * [`Track::Engine`]`(tid)` → pid 3 / tid = layer index, process name
//!   `engine`, thread name `layer <tid>` (the reserved
//!   [`crate::obs::profiler::STEP_TID`] row is `step scope`): the
//!   profiler's per-layer kernel-phase spans, nested strictly inside the
//!   scheduler's `prefill_forward`/`decode_forward` spans because both
//!   are stamped from the same `Instant`s. The pid-3 metadata is emitted
//!   only when engine events exist, so unprofiled traces are unchanged.
//! * [`EventKind::Begin`]/[`EventKind::End`] → `ph: "B"` / `"E"`
//!   duration events, [`EventKind::Counter`] → `ph: "C"` with
//!   `args.value`; timestamps (`ts`) are microseconds from the
//!   recording tracer's construction.
//! * [`crate::obs::Tracer::meta`] facts (e.g. `gemm_kernel`) land in a top-level
//!   `"meta"` object — viewers ignore unknown top-level keys, while the
//!   CI trace-smoke check and tests read them back.
//!
//! Written with the crate's own streaming [`JsonWriter`] (the offline
//! build has no serde), and parseable back with [`crate::config::Json`],
//! which is how the golden tests validate a written file.

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::JsonWriter;
use crate::obs::tracer::{EventKind, RecordingTracer, Track};

/// (pid, tid) for a track, per the module-doc mapping.
fn track_ids(track: Track) -> (f64, f64) {
    match track {
        Track::Scheduler => (1.0, 0.0),
        Track::Request(id) => (2.0, id as f64),
        Track::Engine(layer) => (3.0, layer as f64),
    }
}

fn event_common(w: &mut JsonWriter, ph: &str, track: Track, name: &str, ts_us: f64) {
    let (pid, tid) = track_ids(track);
    w.begin_obj()
        .key("ph")
        .str(ph)
        .key("pid")
        .num(pid)
        .key("tid")
        .num(tid)
        .key("name")
        .str(name)
        .key("ts")
        .num(ts_us)
        .key("cat")
        .str(match track {
            Track::Scheduler => "sched",
            Track::Request(_) => "request",
            Track::Engine(_) => "engine",
        });
}

fn metadata_event(w: &mut JsonWriter, pid: f64, tid: f64, kind: &str, value: &str) {
    w.begin_obj()
        .key("ph")
        .str("M")
        .key("pid")
        .num(pid)
        .key("tid")
        .num(tid)
        .key("name")
        .str(kind)
        .key("args")
        .begin_obj()
        .key("name")
        .str(value)
        .end_obj()
        .end_obj();
}

/// Render a recorded run as a Chrome-trace JSON string.
pub fn chrome_trace_json(rec: &RecordingTracer) -> String {
    let events = rec.events();
    let mut w = JsonWriter::new();
    w.begin_obj().key("displayTimeUnit").str("ms");

    w.key("traceEvents").begin_arr();
    // name the tracks first so viewers label them even for empty runs
    metadata_event(&mut w, 1.0, 0.0, "process_name", "scheduler");
    metadata_event(&mut w, 1.0, 0.0, "thread_name", "steps");
    metadata_event(&mut w, 2.0, 0.0, "process_name", "requests");
    let mut req_ids: Vec<u64> = Vec::new();
    let mut engine_tids: Vec<u64> = Vec::new();
    for e in &events {
        match e.track {
            Track::Request(id) => req_ids.push(id),
            Track::Engine(tid) => engine_tids.push(tid),
            Track::Scheduler => {}
        }
    }
    req_ids.sort_unstable();
    req_ids.dedup();
    for id in req_ids {
        metadata_event(&mut w, 2.0, id as f64, "thread_name", &format!("req {id}"));
    }
    // the engine process exists only when a profiler actually emitted —
    // unprofiled traces keep their exact historical event counts
    engine_tids.sort_unstable();
    engine_tids.dedup();
    if !engine_tids.is_empty() {
        metadata_event(&mut w, 3.0, 0.0, "process_name", "engine");
        for tid in engine_tids {
            let label = if tid == crate::obs::profiler::STEP_TID {
                "step scope".to_string()
            } else {
                format!("layer {tid}")
            };
            metadata_event(&mut w, 3.0, tid as f64, "thread_name", &label);
        }
    }
    for e in &events {
        match e.kind {
            EventKind::Begin => {
                event_common(&mut w, "B", e.track, e.name, e.ts_us);
                w.end_obj();
            }
            EventKind::End => {
                event_common(&mut w, "E", e.track, e.name, e.ts_us);
                w.end_obj();
            }
            EventKind::Counter(v) => {
                event_common(&mut w, "C", e.track, e.name, e.ts_us);
                w.key("args").begin_obj().key("value").num(v).end_obj().end_obj();
            }
        }
    }
    w.end_arr();

    w.key("meta").begin_obj();
    for (k, v) in rec.meta_entries() {
        w.key(k).str(&v);
    }
    // buffer health: how many events the cap discarded (0 = trustworthy
    // trace; > 0 = the timeline has holes and should be re-run capped up)
    w.key("dropped_events").num(rec.dropped_events() as f64);
    w.end_obj();

    w.end_obj();
    w.finish()
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(path: &Path, rec: &RecordingTracer) -> Result<()> {
    fs::write(path, chrome_trace_json(rec))
        .with_context(|| format!("writing trace to {}", path.display()))
}

#[cfg(test)]
mod tests {
    use std::time::Instant;

    use super::*;
    use crate::config::Json;
    use crate::obs::tracer::Tracer;

    fn sample_trace() -> RecordingTracer {
        let mut tr = RecordingTracer::new();
        let t = Instant::now();
        tr.meta("gemm_kernel", "scalar");
        tr.begin(Track::Request(0), "request", t);
        tr.begin(Track::Request(0), "queued", t);
        tr.begin(Track::Scheduler, "step", t);
        tr.counter(Track::Scheduler, "queue_depth", 1.0, t);
        tr.end(Track::Request(0), "queued", t);
        tr.end(Track::Scheduler, "step", t);
        tr.end(Track::Request(0), "request", t);
        tr
    }

    #[test]
    fn exported_json_parses_and_keeps_every_event() {
        let tr = sample_trace();
        let doc = Json::parse(&chrome_trace_json(&tr)).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // 7 recorded events + 3 fixed metadata + 1 per-request thread name
        assert_eq!(events.len(), tr.len() + 4);
        assert_eq!(doc.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
        let meta = doc.get("meta").unwrap();
        assert_eq!(meta.get("gemm_kernel").unwrap().as_str().unwrap(), "scalar");
    }

    #[test]
    fn begin_end_counter_phases_round_trip() {
        let doc = Json::parse(&chrome_trace_json(&sample_trace())).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let phs: Vec<String> = events
            .iter()
            .map(|e| e.get("ph").unwrap().as_str().unwrap().to_string())
            .collect();
        assert_eq!(phs.iter().filter(|p| *p == "M").count(), 4);
        assert_eq!(phs.iter().filter(|p| *p == "B").count(), 3);
        assert_eq!(phs.iter().filter(|p| *p == "E").count(), 3);
        assert_eq!(phs.iter().filter(|p| *p == "C").count(), 1);
        // counters carry args.value; request events land on pid 2 with
        // tid = request id, scheduler events on pid 1
        for e in events {
            match e.get("ph").unwrap().as_str().unwrap() {
                "C" => {
                    assert_eq!(e.get("args").unwrap().get("value").unwrap().as_f64().unwrap(), 1.0);
                    assert_eq!(e.get("pid").unwrap().as_f64().unwrap(), 1.0);
                }
                "B" | "E" => {
                    let pid = e.get("pid").unwrap().as_f64().unwrap();
                    let cat = e.get("cat").unwrap().as_str().unwrap();
                    assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
                    match e.get("name").unwrap().as_str().unwrap() {
                        "step" => assert_eq!((pid, cat), (1.0, "sched")),
                        _ => {
                            assert_eq!((pid, cat), (2.0, "request"));
                            assert_eq!(e.get("tid").unwrap().as_f64().unwrap(), 0.0);
                        }
                    }
                }
                "M" => {}
                other => panic!("unexpected phase {other:?}"),
            }
        }
    }

    #[test]
    fn engine_tracks_land_on_pid_3_with_layer_thread_names() {
        let mut tr = RecordingTracer::new();
        let t = Instant::now();
        tr.begin(Track::Engine(1), "qkv_gemm", t);
        tr.end(Track::Engine(1), "qkv_gemm", t);
        tr.begin(Track::Engine(crate::obs::profiler::STEP_TID), "other", t);
        tr.end(Track::Engine(crate::obs::profiler::STEP_TID), "other", t);
        let doc = Json::parse(&chrome_trace_json(&tr)).unwrap();
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut names = Vec::new();
        for e in events {
            if e.get("ph").unwrap().as_str().unwrap() == "M" {
                if e.get("pid").unwrap().as_f64().unwrap() == 3.0 {
                    names.push(e.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string());
                }
            } else {
                assert_eq!(e.get("pid").unwrap().as_f64().unwrap(), 3.0);
                assert_eq!(e.get("cat").unwrap().as_str().unwrap(), "engine");
            }
        }
        assert!(names.contains(&"engine".to_string()));
        assert!(names.contains(&"layer 1".to_string()));
        assert!(names.contains(&"step scope".to_string()));
    }

    #[test]
    fn dropped_events_surface_in_meta() {
        let mut tr = RecordingTracer::with_cap(1);
        let t = Instant::now();
        tr.begin(Track::Scheduler, "step", t);
        tr.end(Track::Scheduler, "step", t);
        let doc = Json::parse(&chrome_trace_json(&tr)).unwrap();
        let meta = doc.get("meta").unwrap();
        assert_eq!(meta.get("dropped_events").unwrap().as_f64().unwrap(), 1.0);
        // an uncapped sample trace reports zero drops
        let doc = Json::parse(&chrome_trace_json(&sample_trace())).unwrap();
        assert_eq!(doc.get("meta").unwrap().get("dropped_events").unwrap().as_f64().unwrap(), 0.0);
    }

    #[test]
    fn write_round_trips_through_a_file() {
        let dir = std::env::temp_dir().join("lota_obs_chrome_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        write_chrome_trace(&path, &sample_trace()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(!doc.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
