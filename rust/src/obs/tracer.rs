//! The event-sink trait the serving path emits into, and its two
//! implementations: discard everything ([`NoopTracer`]) or buffer
//! everything ([`RecordingTracer`]).
//!
//! Design constraints, in order:
//!
//! 1. **Inert when disabled.** The scheduler stores
//!    `Option<Box<dyn Tracer>>` defaulting to `None`; every emission
//!    site is one `if let` branch, and no event struct is even built on
//!    the disabled path. Attaching a [`NoopTracer`] must be
//!    indistinguishable (bitwise, on scheduler outputs) from attaching
//!    nothing — pinned in `tests/obs.rs`.
//! 2. **Timestamps are the scheduler's own `Instant`s.** Emission sites
//!    pass the *same* `Instant` the scheduler uses for its
//!    `SchedStats` histograms (arrival, admission `now`, pick `now`,
//!    release `now`), so span durations in a trace reconcile exactly
//!    with the TTFT / inter-token stats for the same run instead of
//!    being a second, slightly-off clock.
//! 3. **Static names.** Span and counter names are `&'static str` so
//!    recording a span costs a Vec push, not a format/allocation.

use std::cell::RefCell;
use std::rc::Rc;
use std::time::Instant;

/// Where an event belongs in the trace: the scheduler's own step/phase
/// timeline, or one request's lifecycle timeline. The Chrome exporter
/// maps these to (pid, tid) pairs so each request gets its own row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Track {
    /// per-step phases and counters (one shared timeline)
    Scheduler,
    /// one request's queued → prefill → decode_step… → finished chain,
    /// keyed by the id `Scheduler::submit` returned
    Request(u64),
    /// one engine layer's kernel-phase timeline (profiler spans), keyed
    /// by layer index; step-level phases (embedding, head, block alloc)
    /// ride the layer-count tid
    Engine(u64),
}

/// What an event is: a span opening, a span closing, or a counter
/// sample (Chrome phases `B` / `E` / `C`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    Begin,
    End,
    Counter(f64),
}

/// One recorded event. `ts_us` is microseconds since the recording
/// tracer's construction (its `t0`), matching Chrome's `ts` convention.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub track: Track,
    pub kind: EventKind,
    pub name: &'static str,
    pub ts_us: f64,
}

/// Event sink the scheduler and serving layer emit into.
///
/// Implementations must not panic and must not observe or mutate
/// anything that feeds back into scheduling — a tracer is a write-only
/// window. `begin`/`end` pairs nest per track (the exporter and tests
/// treat each track as a span stack).
pub trait Tracer {
    /// Open span `name` on `track` at time `at`.
    fn begin(&mut self, track: Track, name: &'static str, at: Instant);
    /// Close the innermost open span named `name` on `track`.
    fn end(&mut self, track: Track, name: &'static str, at: Instant);
    /// Sample counter `name` (its own timeline per name) at `value`.
    fn counter(&mut self, track: Track, name: &'static str, value: f64, at: Instant);
    /// Attach a run-level string fact (e.g. the resolved GEMM kernel).
    fn meta(&mut self, _key: &'static str, _value: &str) {}
}

/// Discards every event. Exists so "tracing enabled but pointed
/// nowhere" can be tested against "tracing absent" — the two must be
/// bitwise identical on scheduler outputs.
#[derive(Clone, Copy, Debug, Default)]
pub struct NoopTracer;

impl Tracer for NoopTracer {
    fn begin(&mut self, _track: Track, _name: &'static str, _at: Instant) {}
    fn end(&mut self, _track: Track, _name: &'static str, _at: Instant) {}
    fn counter(&mut self, _track: Track, _name: &'static str, _value: f64, _at: Instant) {}
}

/// Default event-buffer cap: generous (a soak at ~10 events per step
/// takes days to hit it) but finite, so a long open-loop run can't grow
/// memory without bound.
pub const DEFAULT_EVENT_CAP: usize = 1 << 20;

#[derive(Debug)]
struct TraceBuffer {
    /// all timestamps are offsets from here
    t0: Instant,
    events: Vec<TraceEvent>,
    /// run-level string facts, in emission order
    meta: Vec<(&'static str, String)>,
    /// maximum buffered events; pushes past this are counted, not stored
    cap: usize,
    /// events discarded at the cap — surfaced in the Chrome export meta
    dropped: u64,
}

/// Buffers events in memory behind a shared, clonable handle.
///
/// The scheduler takes a boxed clone (`with_tracer(Box::new(rec.clone()))`)
/// while the caller keeps `rec` to export from afterwards — the same
/// `Rc<RefCell<…>>` idiom the `TokenSink` tests use. Single-threaded by
/// construction, like the scheduler itself.
#[derive(Clone, Debug)]
pub struct RecordingTracer {
    buf: Rc<RefCell<TraceBuffer>>,
}

impl Default for RecordingTracer {
    fn default() -> RecordingTracer {
        RecordingTracer::new()
    }
}

impl RecordingTracer {
    /// An empty buffer whose `t0` (the trace's time origin) is *now*.
    /// Construct the tracer before submitting work so every emitted
    /// `Instant` lands at a non-negative offset.
    pub fn new() -> RecordingTracer {
        RecordingTracer::with_cap(DEFAULT_EVENT_CAP)
    }

    /// [`RecordingTracer::new`] with an explicit event-buffer cap. Once
    /// `cap` events are buffered, further pushes are dropped and counted
    /// ([`RecordingTracer::dropped_events`]) instead of growing memory —
    /// meta facts are unaffected.
    pub fn with_cap(cap: usize) -> RecordingTracer {
        RecordingTracer {
            buf: Rc::new(RefCell::new(TraceBuffer {
                t0: Instant::now(),
                events: Vec::new(),
                meta: Vec::new(),
                cap,
                dropped: 0,
            })),
        }
    }

    fn ts_us(&self, at: Instant) -> f64 {
        // `at` can only precede t0 if the caller constructed the tracer
        // after stamping work; clamp rather than panic on that misuse
        let buf = self.buf.borrow();
        match at.checked_duration_since(buf.t0) {
            Some(d) => d.as_secs_f64() * 1e6,
            None => 0.0,
        }
    }

    fn push(&self, track: Track, kind: EventKind, name: &'static str, at: Instant) {
        let ts_us = self.ts_us(at);
        let mut buf = self.buf.borrow_mut();
        if buf.events.len() >= buf.cap {
            buf.dropped += 1;
            return;
        }
        buf.events.push(TraceEvent { track, kind, name, ts_us });
    }

    /// Snapshot of all events recorded so far, in emission order.
    pub fn events(&self) -> Vec<TraceEvent> {
        self.buf.borrow().events.clone()
    }

    /// Run-level string facts recorded via [`Tracer::meta`].
    pub fn meta_entries(&self) -> Vec<(&'static str, String)> {
        self.buf.borrow().meta.clone()
    }

    pub fn len(&self) -> usize {
        self.buf.borrow().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.borrow().events.is_empty()
    }

    /// Events discarded because the buffer hit its cap (0 in healthy
    /// runs). The Chrome exporter surfaces this in the top-level meta.
    pub fn dropped_events(&self) -> u64 {
        self.buf.borrow().dropped
    }
}

impl Tracer for RecordingTracer {
    fn begin(&mut self, track: Track, name: &'static str, at: Instant) {
        self.push(track, EventKind::Begin, name, at);
    }

    fn end(&mut self, track: Track, name: &'static str, at: Instant) {
        self.push(track, EventKind::End, name, at);
    }

    fn counter(&mut self, track: Track, name: &'static str, value: f64, at: Instant) {
        self.push(track, EventKind::Counter(value), name, at);
    }

    fn meta(&mut self, key: &'static str, value: &str) {
        self.buf.borrow_mut().meta.push((key, value.to_string()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recording_preserves_order_and_monotone_offsets() {
        let mut tr = RecordingTracer::new();
        let a = Instant::now();
        tr.begin(Track::Scheduler, "step", a);
        tr.counter(Track::Scheduler, "queue_depth", 3.0, a);
        let b = Instant::now();
        tr.end(Track::Scheduler, "step", b);
        let ev = tr.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].kind, EventKind::Begin);
        assert_eq!(ev[1].kind, EventKind::Counter(3.0));
        assert_eq!(ev[2].kind, EventKind::End);
        assert_eq!(ev[0].name, "step");
        assert!(ev[0].ts_us >= 0.0);
        // same Instant → same offset; later Instant → ≥ offset
        assert_eq!(ev[0].ts_us, ev[1].ts_us);
        assert!(ev[2].ts_us >= ev[0].ts_us);
    }

    #[test]
    fn clones_share_one_buffer() {
        let mut a = RecordingTracer::new();
        let b = a.clone();
        a.begin(Track::Request(4), "request", Instant::now());
        a.meta("gemm_kernel", "scalar");
        assert_eq!(b.len(), 1);
        assert_eq!(b.events()[0].track, Track::Request(4));
        assert_eq!(b.meta_entries(), vec![("gemm_kernel", "scalar".to_string())]);
    }

    #[test]
    fn instants_before_t0_clamp_to_zero() {
        let before = Instant::now();
        let mut tr = RecordingTracer::new();
        tr.begin(Track::Scheduler, "step", before);
        assert_eq!(tr.events()[0].ts_us, 0.0);
    }

    #[test]
    fn capped_buffer_drops_and_counts_instead_of_growing() {
        let mut tr = RecordingTracer::with_cap(3);
        let t = Instant::now();
        for _ in 0..5 {
            tr.begin(Track::Scheduler, "step", t);
        }
        assert_eq!(tr.len(), 3);
        assert_eq!(tr.dropped_events(), 2);
        // meta facts are not subject to the event cap
        tr.meta("gemm_kernel", "scalar");
        assert_eq!(tr.meta_entries().len(), 1);
        // the default construction is generously capped, drops nothing
        let mut fresh = RecordingTracer::new();
        fresh.begin(Track::Scheduler, "step", t);
        assert_eq!(fresh.dropped_events(), 0);
    }

    #[test]
    fn noop_tracer_records_nothing_and_is_zero_sized() {
        let mut t = NoopTracer;
        t.begin(Track::Scheduler, "step", Instant::now());
        t.end(Track::Scheduler, "step", Instant::now());
        t.counter(Track::Scheduler, "queue_depth", 1.0, Instant::now());
        t.meta("k", "v");
        assert_eq!(std::mem::size_of::<NoopTracer>(), 0);
    }
}
