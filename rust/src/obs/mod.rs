//! Serving-path observability: tracing spans + a metrics registry.
//!
//! PRs 1–5 built the fast serving path (native packed-integer engine,
//! KV-cached decode, continuous batching, paged KV, SIMD GEMM) but left
//! only aggregate `ThroughputReport` numbers as a window into it. This
//! module adds the per-request view: *where did this request's time go,
//! step by step* — without perturbing the path it observes.
//!
//! Three pieces:
//!
//! * [`Tracer`] — the event-sink trait the scheduler emits lifecycle and
//!   phase spans into. [`NoopTracer`] discards everything;
//!   [`RecordingTracer`] buffers events (shared, clonable handle) for
//!   export. The scheduler holds `Option<Box<dyn Tracer>>` defaulting to
//!   None, so the disabled path costs one branch per emission site and
//!   allocates nothing per step; all bitwise parity pins hold with
//!   tracing on or off, since instrumentation only observes
//!   (`tests/obs.rs`).
//! * [`write_chrome_trace`] / [`chrome_trace_json`] — export a recorded
//!   run as Chrome-trace-event JSON, loadable in Perfetto
//!   (<https://ui.perfetto.dev>) or `chrome://tracing`. Requests map to
//!   one track each (pid `requests`, tid = request id) carrying their
//!   `request { queued, prefill, decode_step… }` span chain; the
//!   scheduler's per-step phases (`step { admission, prefill_forward,
//!   decode_forward, kv_release }`) and counters (queue depth,
//!   occupancy, KV pool traffic) live on a second track.
//! * [`MetricsRegistry`] — counters / gauges / histograms (reusing
//!   [`crate::serve::Histogram`]) snapshotted from a
//!   [`crate::serve::ThroughputReport`] and written as Prometheus-style
//!   text or JSON (`lota serve --metrics-out`).
//! * [`Profiler`] — the engine hot-path profiler. Where the tracer stops
//!   at the scheduler's `prefill_forward` / `decode_forward` spans, the
//!   profiler opens the engine below them: per-(layer, kind) kernel
//!   phase timings (qkv/o/mlp GEMM, attention, dequant, delta overlay,
//!   KV paging) that tile each forward window *exactly* — same
//!   `Option`-gated, single-clock discipline, same bitwise-invisibility
//!   pin. Surfaces as pid-3 Perfetto tracks ([`Track::Engine`]) and as
//!   `lota_engine_*` registry keys (`lota serve --profile-out`).
//!
//! Span and metric naming, the trace schema, and how the exported
//! timings reconcile with `SchedStats` are documented in
//! `docs/observability.md`.

pub mod chrome;
pub mod profiler;
pub mod registry;
pub mod tracer;

pub use chrome::{chrome_trace_json, write_chrome_trace};
pub use profiler::{ForwardPhase, KernelProf, PhaseKind, Profiler, WindowProfile, STEP_TID};
pub use registry::MetricsRegistry;
pub use tracer::{EventKind, NoopTracer, RecordingTracer, TraceEvent, Tracer, Track};
