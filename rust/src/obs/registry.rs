//! Machine-readable metrics snapshot of a serving run.
//!
//! A [`MetricsRegistry`] is the flat counters/gauges/histograms view of
//! a [`ThroughputReport`] — the shape scrapers and dashboards want,
//! versus the nested report struct the code wants. It renders two ways:
//!
//! * [`MetricsRegistry::to_prometheus`] — Prometheus text exposition
//!   (`# TYPE` headers, real cumulative `histogram` types: `_bucket`
//!   series over a fixed log-spaced millisecond `le` ladder plus
//!   `_sum`/`_count`, so TTFT/inter-token histograms scrape and
//!   aggregate correctly instead of posing as summaries).
//! * [`MetricsRegistry::to_json`] — one JSON object with `counters` /
//!   `gauges` / `histograms` / `info` sections, each histogram
//!   summarized as count/mean/min/p50/p95/p99/max.
//!
//! [`MetricsRegistry::write`] picks the format from the path extension
//! (`.json` → JSON, anything else → Prometheus text), which is what
//! `lota serve --metrics-out` calls. All metric names carry the `lota_`
//! prefix; the full key list is tabulated in `docs/observability.md`.
//!
//! Histograms reuse [`crate::serve::Histogram`] (exact percentiles, no
//! binning), and every value is finite by construction — the report's
//! ratio accessors return 0.0 instead of NaN on empty runs precisely so
//! this snapshot never emits `null`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::JsonWriter;
use crate::serve::{Histogram, ThroughputReport};

/// Upper bounds (`le` labels) of the Prometheus histogram buckets: a
/// fixed ×2 log-spaced millisecond ladder from 0.25 ms to ~4 s, plus the
/// implicit `+Inf`. Fixed (not data-derived) so series from different
/// runs aggregate; values in other units (ratios in [0, 1], depths) land
/// in the low buckets, which still orders them correctly.
pub const BUCKET_BOUNDS_MS: [f64; 15] = [
    0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0,
];

/// Escape a label *value* for the Prometheus text exposition format:
/// backslash, double quote, and line feed must be written as `\\`, `\"`
/// and `\n` inside the quoted value. Adapter names come from user TOML,
/// so a name like `fr"evil` would otherwise emit unparseable text.
pub fn escape_label(value: &str) -> String {
    let mut out = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Counters, gauges, histograms, and string facts, keyed by metric name.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
    info: BTreeMap<String, String>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `delta` to counter `name` (created at 0).
    pub fn inc(&mut self, name: &str, delta: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += delta;
    }

    /// Set gauge `name` to `value`.
    pub fn set_gauge(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    /// Record one sample into histogram `name`.
    pub fn observe(&mut self, name: &str, value: f64) {
        self.histograms.entry(name.to_string()).or_default().record(value);
    }

    /// Attach a string fact (rendered as a `lota_info` label / `info`
    /// JSON entry).
    pub fn set_info(&mut self, key: &str, value: &str) {
        self.info.insert(key.to_string(), value.to_string());
    }

    pub fn counter(&self, name: &str) -> Option<f64> {
        self.counters.get(name).copied()
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Flatten a serving report into the registry. Scheduler-only
    /// sections (TTFT, queue depth, …) appear only when the run actually
    /// went through `crate::sched`.
    pub fn from_report(report: &ThroughputReport) -> MetricsRegistry {
        let mut r = MetricsRegistry::new();
        r.inc("lota_requests_total", report.requests as f64);
        r.inc("lota_generated_tokens_total", report.tokens as f64);
        r.inc("lota_decode_forwards_total", report.decode.forwards as f64);
        r.inc("lota_decode_rows_total", report.decode.forwarded_rows as f64);
        r.inc("lota_decode_positions_total", report.decode.forwarded_positions as f64);
        r.set_gauge("lota_wall_seconds", report.wall_secs);
        r.set_gauge("lota_tokens_per_sec", report.tokens_per_sec);
        r.set_gauge("lota_requests_per_sec", report.requests_per_sec);
        r.set_gauge("lota_positions_per_token", report.positions_per_token());
        // the report only keeps the latency summary, not raw samples;
        // expose it as gauges instead of a lossy fake histogram
        r.set_gauge("lota_request_latency_secs_mean", report.latency.mean);
        r.set_gauge("lota_request_latency_secs_p50", report.latency.p50);
        r.set_gauge("lota_request_latency_secs_p95", report.latency.p95);
        r.set_gauge("lota_request_latency_secs_p99", report.latency.p99);
        r.set_gauge("lota_request_latency_secs_max", report.latency.max);
        if let Some(k) = report.gemm_kernel {
            r.set_info("gemm_kernel", k);
        }
        if let Some(sched) = &report.sched {
            r.inc("lota_sched_steps_total", sched.steps as f64);
            r.inc("lota_admission_denied_total", sched.admission_denied as f64);
            // overload-control counters, emitted only when the run
            // actually shed or rejected — snapshots from runs without
            // deadlines or a bounded queue keep their exact key set
            if sched.shed_at_submit > 0 {
                r.inc(
                    "lota_shed_total{reason=\"deadline_at_submit\"}",
                    sched.shed_at_submit as f64,
                );
            }
            if sched.shed_in_queue > 0 {
                r.inc("lota_shed_total{reason=\"deadline_in_queue\"}", sched.shed_in_queue as f64);
            }
            if sched.queue_rejected > 0 {
                r.inc("lota_queue_rejected_total", sched.queue_rejected as f64);
            }
            r.set_gauge("lota_peak_active_requests", sched.peak_active as f64);
            r.observe_all("lota_ttft_ms", &sched.ttft_ms);
            r.observe_all("lota_inter_token_ms", &sched.inter_token_ms);
            r.observe_all("lota_queue_wait_ms", &sched.queue_wait_ms);
            // empty unless requests crossed the worker-thread command
            // channel — in-process runs keep their exact key set
            r.observe_all("lota_handoff_ms", &sched.handoff_ms);
            r.observe_all("lota_queue_depth", &sched.queue_depth);
            r.observe_all("lota_batch_occupancy", &sched.batch_occupancy);
            r.observe_all("lota_block_util", &sched.block_util);
            // per-adapter serving usage, labeled Prometheus-style; absent
            // entirely when the run never tagged a request (pre-adapter
            // snapshots keep their exact key set)
            for (label, usage) in &sched.adapter_usage {
                let label = escape_label(label);
                r.inc(
                    &format!("lota_adapter_requests_total{{adapter=\"{label}\"}}"),
                    usage.requests as f64,
                );
                r.inc(
                    &format!("lota_adapter_tokens_total{{adapter=\"{label}\"}}"),
                    usage.tokens as f64,
                );
            }
        }
        r
    }

    /// Merge a whole histogram under `name` (empty histograms are
    /// skipped — absent means "this run never measured that").
    pub fn observe_all(&mut self, name: &str, h: &Histogram) {
        if h.is_empty() {
            return;
        }
        self.histograms.entry(name.to_string()).or_default().merge(h);
    }

    /// Prometheus text exposition format.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        // keys may carry a `{label="…"}` suffix (per-adapter counters);
        // the TYPE header names the bare metric, once per run of equal
        // bare names (BTreeMap order keeps labeled variants adjacent)
        let mut last_type: &str = "";
        for (name, v) in &self.counters {
            let base = name.split('{').next().unwrap_or(name);
            if base != last_type {
                writeln!(out, "# TYPE {base} counter").unwrap();
                last_type = base;
            }
            writeln!(out, "{name} {v}").unwrap();
        }
        for (name, v) in &self.gauges {
            writeln!(out, "# TYPE {name} gauge").unwrap();
            writeln!(out, "{name} {v}").unwrap();
        }
        for (name, h) in &self.histograms {
            // cumulative le-bucket form — each bucket counts samples ≤
            // its bound, +Inf counts everything, and _sum is the exact
            // sample sum (not mean·count, which reintroduces rounding)
            writeln!(out, "# TYPE {name} histogram").unwrap();
            let samples = h.samples();
            // retained samples may be a capped reservoir of a longer
            // stream; scale the cumulative counts to the true count so
            // the buckets stay consistent with `_count`/`+Inf` (scale is
            // exactly 1 below the cap — counts unchanged)
            let scale =
                if samples.is_empty() { 0.0 } else { h.len() as f64 / samples.len() as f64 };
            for le in BUCKET_BOUNDS_MS {
                let cum = samples.iter().filter(|&&v| v <= le).count() as f64 * scale;
                writeln!(out, "{name}_bucket{{le=\"{le}\"}} {}", cum.round()).unwrap();
            }
            writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.len()).unwrap();
            writeln!(out, "{name}_sum {}", h.sum()).unwrap();
            writeln!(out, "{name}_count {}", h.len()).unwrap();
        }
        if !self.info.is_empty() {
            let labels: Vec<String> =
                self.info.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
            writeln!(out, "# TYPE lota_info gauge").unwrap();
            writeln!(out, "lota_info{{{}}} 1", labels.join(",")).unwrap();
        }
        out
    }

    /// One JSON object: `{"counters": …, "gauges": …, "histograms": …,
    /// "info": …}`, histograms summarized.
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("counters").begin_obj();
        for (name, v) in &self.counters {
            w.key(name).num(*v);
        }
        w.end_obj();
        w.key("gauges").begin_obj();
        for (name, v) in &self.gauges {
            w.key(name).num(*v);
        }
        w.end_obj();
        w.key("histograms").begin_obj();
        for (name, h) in &self.histograms {
            let s = h.stats();
            w.key(name)
                .begin_obj()
                .key("count")
                .num(h.len() as f64)
                .key("mean")
                .num(s.mean)
                .key("min")
                .num(h.min())
                .key("p50")
                .num(s.p50)
                .key("p95")
                .num(s.p95)
                .key("p99")
                .num(s.p99)
                .key("max")
                .num(s.max)
                .end_obj();
        }
        w.end_obj();
        w.key("info").begin_obj();
        for (k, v) in &self.info {
            w.key(k).str(v);
        }
        w.end_obj();
        w.end_obj();
        w.finish()
    }

    /// Write the snapshot to `path`: JSON when the extension is `.json`,
    /// Prometheus text otherwise.
    pub fn write(&self, path: &Path) -> Result<()> {
        let body = match path.extension().and_then(|e| e.to_str()) {
            Some("json") => self.to_json(),
            _ => self.to_prometheus(),
        };
        fs::write(path, body)
            .with_context(|| format!("writing metrics snapshot to {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Json;
    use crate::engine::DecodeStats;
    use crate::serve::{AdapterUsage, SchedStats};

    fn sample_report() -> ThroughputReport {
        let mut sched = SchedStats::default();
        for v in [10.0, 20.0, 30.0] {
            sched.ttft_ms.record(v);
        }
        sched.inter_token_ms.record(5.0);
        sched.queue_wait_ms.record(2.0);
        sched.queue_depth.record(1.0);
        sched.batch_occupancy.record(0.5);
        sched.admission_denied = 2;
        sched.shed_at_submit = 1;
        sched.shed_in_queue = 2;
        sched.queue_rejected = 4;
        sched.peak_active = 3;
        sched.steps = 9;
        sched.adapter_usage.insert("base".to_string(), AdapterUsage { requests: 3, tokens: 9 });
        sched.adapter_usage.insert("fr".to_string(), AdapterUsage { requests: 1, tokens: 3 });
        let mut r = ThroughputReport::default();
        r.requests = 4;
        r.tokens = 12;
        r.wall_secs = 2.0;
        r.tokens_per_sec = 6.0;
        r.requests_per_sec = 2.0;
        r.decode = DecodeStats { forwards: 7, forwarded_rows: 14, forwarded_positions: 28 };
        r.with_sched(sched).with_gemm_kernel(Some("scalar"))
    }

    #[test]
    fn report_flattens_into_lota_keys() {
        let reg = MetricsRegistry::from_report(&sample_report());
        assert_eq!(reg.counter("lota_requests_total"), Some(4.0));
        assert_eq!(reg.counter("lota_generated_tokens_total"), Some(12.0));
        assert_eq!(reg.counter("lota_sched_steps_total"), Some(9.0));
        assert_eq!(reg.counter("lota_admission_denied_total"), Some(2.0));
        assert_eq!(reg.gauge("lota_tokens_per_sec"), Some(6.0));
        assert_eq!(reg.gauge("lota_peak_active_requests"), Some(3.0));
        // positions/token = 28 / 12
        assert!((reg.gauge("lota_positions_per_token").unwrap() - 28.0 / 12.0).abs() < 1e-12);
        assert_eq!(reg.histogram("lota_ttft_ms").unwrap().len(), 3);
        // empty histograms stay absent rather than appearing as zeros
        assert!(reg.histogram("lota_block_util").is_none());
    }

    #[test]
    fn overload_counters_are_labeled_and_zero_free() {
        let reg = MetricsRegistry::from_report(&sample_report());
        assert_eq!(reg.counter("lota_shed_total{reason=\"deadline_at_submit\"}"), Some(1.0));
        assert_eq!(reg.counter("lota_shed_total{reason=\"deadline_in_queue\"}"), Some(2.0));
        assert_eq!(reg.counter("lota_queue_rejected_total"), Some(4.0));
        let text = reg.to_prometheus();
        // the two shed reasons share one bare metric and one TYPE header
        assert_eq!(text.matches("# TYPE lota_shed_total counter").count(), 1);
        assert!(text.contains("lota_shed_total{reason=\"deadline_at_submit\"} 1"));
        assert!(text.contains("lota_shed_total{reason=\"deadline_in_queue\"} 2"));
        assert!(text.contains("lota_queue_rejected_total 4"));
        // a run that never shed or rejected emits none of these keys
        let mut calm = sample_report();
        let sched = calm.sched.as_mut().unwrap();
        sched.shed_at_submit = 0;
        sched.shed_in_queue = 0;
        sched.queue_rejected = 0;
        let reg = MetricsRegistry::from_report(&calm);
        assert_eq!(reg.counter("lota_shed_total{reason=\"deadline_at_submit\"}"), None);
        assert_eq!(reg.counter("lota_shed_total{reason=\"deadline_in_queue\"}"), None);
        assert_eq!(reg.counter("lota_queue_rejected_total"), None);
    }

    #[test]
    fn per_adapter_usage_flattens_into_labeled_counters() {
        let reg = MetricsRegistry::from_report(&sample_report());
        assert_eq!(reg.counter("lota_adapter_requests_total{adapter=\"base\"}"), Some(3.0));
        assert_eq!(reg.counter("lota_adapter_tokens_total{adapter=\"fr\"}"), Some(3.0));
        let text = reg.to_prometheus();
        // one TYPE header per bare metric, however many adapters
        assert_eq!(text.matches("# TYPE lota_adapter_requests_total counter").count(), 1);
        assert!(text.contains("lota_adapter_requests_total{adapter=\"base\"} 3"));
        assert!(text.contains("lota_adapter_requests_total{adapter=\"fr\"} 1"));
        assert!(text.contains("lota_adapter_tokens_total{adapter=\"base\"} 9"));
        // untagged runs carry no adapter keys at all
        let bare = MetricsRegistry::from_report(&ThroughputReport::default());
        assert_eq!(bare.counter("lota_adapter_requests_total{adapter=\"base\"}"), None);
    }

    #[test]
    fn one_shot_reports_skip_sched_sections() {
        let reg = MetricsRegistry::from_report(&ThroughputReport::default());
        assert_eq!(reg.counter("lota_requests_total"), Some(0.0));
        assert_eq!(reg.counter("lota_sched_steps_total"), None);
        assert!(reg.histogram("lota_ttft_ms").is_none());
        // and every emitted value is finite
        let doc = Json::parse(&reg.to_json()).unwrap();
        for section in ["counters", "gauges"] {
            if let Json::Obj(m) = doc.get(section).unwrap() {
                for (k, v) in m {
                    assert!(v.as_f64().unwrap().is_finite(), "{section}.{k} not finite");
                }
            } else {
                panic!("{section} is not an object");
            }
        }
    }

    #[test]
    fn json_snapshot_round_trips() {
        let reg = MetricsRegistry::from_report(&sample_report());
        let doc = Json::parse(&reg.to_json()).unwrap();
        let counters = doc.get("counters").unwrap();
        assert_eq!(counters.get("lota_requests_total").unwrap().as_f64().unwrap(), 4.0);
        let ttft = doc.get("histograms").unwrap().get("lota_ttft_ms").unwrap();
        assert_eq!(ttft.get("count").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(ttft.get("p50").unwrap().as_f64().unwrap(), 20.0);
        assert_eq!(ttft.get("min").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(ttft.get("max").unwrap().as_f64().unwrap(), 30.0);
        assert_eq!(doc.get("info").unwrap().get("gemm_kernel").unwrap().as_str().unwrap(), "scalar");
    }

    #[test]
    fn prometheus_text_has_types_buckets_and_info() {
        let text = MetricsRegistry::from_report(&sample_report()).to_prometheus();
        assert!(text.contains("# TYPE lota_requests_total counter"));
        assert!(text.contains("lota_requests_total 4"));
        // real cumulative histogram: samples 10/20/30 ms against the
        // fixed ladder — nothing ≤ 8, one ≤ 16, all three ≤ 32 and up
        assert!(text.contains("# TYPE lota_ttft_ms histogram"));
        assert!(text.contains("lota_ttft_ms_bucket{le=\"8\"} 0"));
        assert!(text.contains("lota_ttft_ms_bucket{le=\"16\"} 1"));
        assert!(text.contains("lota_ttft_ms_bucket{le=\"32\"} 3"));
        assert!(text.contains("lota_ttft_ms_bucket{le=\"4096\"} 3"));
        assert!(text.contains("lota_ttft_ms_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("lota_ttft_ms_sum 60"));
        assert!(text.contains("lota_ttft_ms_count 3"));
        // no summary-style quantile lines remain
        assert!(!text.contains("quantile="));
        assert!(text.contains("lota_info{gemm_kernel=\"scalar\"} 1"));
        // every non-comment line is "name[{labels}] value"
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
            assert!(parts.next().is_some(), "no metric name in {line:?}");
        }
    }

    #[test]
    fn hostile_labels_escape_and_round_trip() {
        // the three characters the exposition format requires escaping
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
        // an adapter named with all three still emits parseable text
        let mut report = sample_report();
        let sched = report.sched.as_mut().unwrap();
        sched.adapter_usage.clear();
        sched
            .adapter_usage
            .insert("ev\"il\\ad\napter".to_string(), AdapterUsage { requests: 2, tokens: 5 });
        let mut reg = MetricsRegistry::from_report(&report);
        reg.set_info("hostile", "va\\lue\nhere");
        let text = reg.to_prometheus();
        assert!(text
            .contains("lota_adapter_requests_total{adapter=\"ev\\\"il\\\\ad\\napter\"} 2"));
        assert!(text.contains("lota_adapter_tokens_total{adapter=\"ev\\\"il\\\\ad\\napter\"} 5"));
        assert!(text.contains("va\\\\lue\\nhere"));
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            // exactly one physical line per sample: "name[{labels}] value"
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
            let name = parts.next().expect("no metric name");
            // quoted label values never leak an unescaped quote: quotes
            // inside {…} are either delimiters or preceded by a backslash
            if let Some(labels) = name.split_once('{').map(|(_, l)| l) {
                let inner = labels.strip_suffix('}').expect("unterminated label set");
                let bytes = inner.as_bytes();
                let mut in_value = false;
                let mut i = 0;
                while i < bytes.len() {
                    match bytes[i] {
                        b'\\' if in_value => i += 1, // skip the escaped char
                        b'"' => in_value = !in_value,
                        _ => {}
                    }
                    i += 1;
                }
                assert!(!in_value, "unbalanced quotes in {line:?}");
            }
        }
        // and the JSON rendering stays parseable too (JsonWriter escapes)
        assert!(Json::parse(&reg.to_json()).is_ok());
    }

    #[test]
    fn write_picks_format_from_extension() {
        let dir = std::env::temp_dir().join("lota_obs_registry_test");
        std::fs::create_dir_all(&dir).unwrap();
        let reg = MetricsRegistry::from_report(&sample_report());
        let json_path = dir.join("metrics.json");
        let prom_path = dir.join("metrics.prom");
        reg.write(&json_path).unwrap();
        reg.write(&prom_path).unwrap();
        assert!(Json::parse(&std::fs::read_to_string(&json_path).unwrap()).is_ok());
        let prom = std::fs::read_to_string(&prom_path).unwrap();
        assert!(prom.starts_with("# TYPE"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
