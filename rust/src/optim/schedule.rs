//! Hyper-parameter schedules fed into the step artifacts each iteration.

/// The paper's dynamic percentile schedule for t-SignSGD (§4.1): the kept
/// top-fraction starts at `init` (default 5%), decays linearly to `mid`
/// (0.1%) over the first 80% of training, then stays at `final_` (0.01%)
/// for the last 20%.
#[derive(Clone, Debug)]
pub struct SigmaSchedule {
    pub init: f32,
    pub mid: f32,
    pub final_: f32,
    /// fraction of training covered by the linear decay
    pub decay_until: f32,
}

impl Default for SigmaSchedule {
    fn default() -> Self {
        SigmaSchedule { init: 0.05, mid: 0.001, final_: 0.0001, decay_until: 0.8 }
    }
}

impl SigmaSchedule {
    pub fn with_init(init: f32) -> Self {
        SigmaSchedule { init, ..Default::default() }
    }

    /// keep-fraction at step `t` of `total`.
    pub fn keep_frac(&self, t: usize, total: usize) -> f32 {
        if total == 0 {
            return self.init;
        }
        let progress = t as f32 / total as f32;
        if progress >= self.decay_until {
            self.final_
        } else {
            let p = progress / self.decay_until;
            self.init + (self.mid - self.init) * p
        }
    }
}

/// Learning-rate schedule for the AdamW paths (constant or cosine decay —
/// the paper uses constant rates; cosine is exposed for the extension
/// benches).
#[derive(Clone, Debug)]
pub enum LrSchedule {
    Constant(f32),
    Cosine { base: f32, min: f32 },
}

impl LrSchedule {
    pub fn at(&self, t: usize, total: usize) -> f32 {
        match self {
            LrSchedule::Constant(lr) => *lr,
            LrSchedule::Cosine { base, min } => {
                if total == 0 {
                    return *base;
                }
                let p = (t as f32 / total as f32).clamp(0.0, 1.0);
                min + 0.5 * (base - min) * (1.0 + (std::f32::consts::PI * p).cos())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sigma_schedule_endpoints() {
        let s = SigmaSchedule::default();
        assert!((s.keep_frac(0, 100) - 0.05).abs() < 1e-6);
        // just before the knee: close to mid
        let near = s.keep_frac(79, 100);
        assert!(near < 0.003 && near > 0.0005, "{near}");
        // after the knee: fixed final
        assert_eq!(s.keep_frac(80, 100), 0.0001);
        assert_eq!(s.keep_frac(99, 100), 0.0001);
    }

    #[test]
    fn sigma_schedule_is_monotone_nonincreasing() {
        let s = SigmaSchedule::default();
        let mut prev = f32::INFINITY;
        for t in 0..200 {
            let k = s.keep_frac(t, 200);
            assert!(k <= prev + 1e-9, "t={t}: {k} > {prev}");
            prev = k;
        }
    }

    #[test]
    fn cosine_lr_decays_to_min() {
        let s = LrSchedule::Cosine { base: 1e-3, min: 1e-5 };
        assert!((s.at(0, 100) - 1e-3).abs() < 1e-9);
        assert!((s.at(100, 100) - 1e-5).abs() < 1e-9);
        assert!(s.at(50, 100) < 1e-3 && s.at(50, 100) > 1e-5);
    }

    #[test]
    fn constant_lr_is_constant() {
        let s = LrSchedule::Constant(5e-4);
        assert_eq!(s.at(0, 10), s.at(9, 10));
    }
}
