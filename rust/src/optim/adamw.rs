//! Host-side AdamW reference (the baselines' optimizer; paper §4.1 uses a
//! paged AdamW with max grad-norm 0.3). The in-graph implementation lives
//! in `model.py::adamw_update`; this twin validates it and backs the
//! host-only unit tests.

use crate::tensor::Tensor;

/// First/second-moment state for one tensor.
#[derive(Clone, Debug)]
pub struct AdamWState {
    pub m: Tensor,
    pub v: Tensor,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl AdamWState {
    pub fn new(shape: &[usize]) -> Self {
        AdamWState {
            m: Tensor::zeros(shape),
            v: Tensor::zeros(shape),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }

    /// One AdamW step (bias-corrected); `t` is 1-based.
    pub fn update(&mut self, p: &mut Tensor, g: &Tensor, lr: f32, t: usize) {
        assert_eq!(p.shape(), g.shape());
        let b1 = self.beta1;
        let b2 = self.beta2;
        let bc1 = 1.0 - b1.powi(t as i32);
        let bc2 = 1.0 - b2.powi(t as i32);
        for i in 0..p.len() {
            let gi = g.data()[i];
            let m = b1 * self.m.data()[i] + (1.0 - b1) * gi;
            let v = b2 * self.v.data()[i] + (1.0 - b2) * gi * gi;
            self.m.data_mut()[i] = m;
            self.v.data_mut()[i] = v;
            let mhat = m / bc1;
            let vhat = v / bc2;
            let pi = &mut p.data_mut()[i];
            *pi -= lr * (mhat / (vhat.sqrt() + self.eps) + self.weight_decay * *pi);
        }
    }
}

/// Global-norm gradient clipping (paper: max-norm 0.3 for the baselines).
pub fn clip_global_norm(grads: &mut [Tensor], max_norm: f32) -> f32 {
    let total: f32 = grads
        .iter()
        .map(|g| g.data().iter().map(|v| v * v).sum::<f32>())
        .sum::<f32>()
        .sqrt();
    if total > max_norm && total > 0.0 {
        let s = max_norm / total;
        for g in grads.iter_mut() {
            for v in g.data_mut() {
                *v *= s;
            }
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn adamw_minimizes_quadratic() {
        // minimize f(x) = ||x - c||^2 — AdamW should converge near c
        let c = Tensor::new(&[4], vec![1.0, -2.0, 0.5, 3.0]);
        let mut x = Tensor::zeros(&[4]);
        let mut st = AdamWState::new(&[4]);
        for t in 1..=500 {
            let g = x.sub(&c).scale(2.0);
            st.update(&mut x, &g, 0.05, t);
        }
        assert!(x.max_abs_diff(&c) < 0.05, "{:?}", x.data());
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut x = Tensor::new(&[1], vec![10.0]);
        let mut st = AdamWState::new(&[1]);
        st.weight_decay = 0.1;
        let g = Tensor::zeros(&[1]);
        for t in 1..=10 {
            st.update(&mut x, &g, 0.1, t);
        }
        assert!(x.data()[0] < 10.0);
    }

    #[test]
    fn clip_rescales_to_max_norm() {
        let mut rng = Rng::new(1);
        let mut gs = vec![
            Tensor::new(&[8], rng.normal_vec(8, 10.0)),
            Tensor::new(&[8], rng.normal_vec(8, 10.0)),
        ];
        let before = clip_global_norm(&mut gs, 0.3);
        assert!(before > 0.3);
        let after: f32 = gs
            .iter()
            .map(|g| g.data().iter().map(|v| v * v).sum::<f32>())
            .sum::<f32>()
            .sqrt();
        assert!((after - 0.3).abs() < 1e-4);
    }

    #[test]
    fn clip_noop_below_threshold() {
        let mut gs = vec![Tensor::new(&[2], vec![0.01, 0.01])];
        let orig = gs[0].clone();
        clip_global_norm(&mut gs, 0.3);
        assert_eq!(gs[0], orig);
    }
}
