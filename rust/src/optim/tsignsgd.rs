//! Host-side reference of the t-SignSGD update (paper Eq. 6), used for
//! golden validation against the HLO/Pallas implementation and by the
//! host-only unit/property tests.
//!
//! `A ← clip(A − sign(g)·1[|g| > max(τ, σ_t)], −1, 1)` where σ_t is the
//! (1 − keep_frac) quantile of |g| — i.e. only the top keep_frac of
//! gradient magnitudes fire an update.

use crate::tensor::Tensor;

pub const TAU: f32 = 1e-9;

/// The dynamic percentile threshold σ_t over |g| (linear-interpolated
/// quantile, matching `jnp.quantile`'s default midpoint behaviour).
pub fn sigma_threshold(grad: &Tensor, keep_frac: f32) -> f32 {
    let mut mags: Vec<f32> = grad.data().iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = (1.0 - keep_frac).clamp(0.0, 1.0);
    let n = mags.len();
    if n == 0 {
        return TAU;
    }
    let pos = q as f64 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = (pos - lo as f64) as f32;
    let val = mags[lo] + (mags[hi] - mags[lo]) * frac;
    val.max(TAU)
}

/// One t-SignSGD step on a ternary tensor. Returns the updated tensor and
/// the number of entries that moved.
pub fn tsign_update_host(a: &Tensor, grad: &Tensor, keep_frac: f32) -> (Tensor, usize) {
    assert_eq!(a.shape(), grad.shape());
    let thr = sigma_threshold(grad, keep_frac);
    let mut out = a.clone();
    let mut moved = 0;
    for (v, g) in out.data_mut().iter_mut().zip(grad.data()) {
        if g.abs() > thr {
            let next = (*v - g.signum()).clamp(-1.0, 1.0);
            if next != *v {
                moved += 1;
            }
            *v = next;
        }
    }
    (out, moved)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn ternary_tensor(rng: &mut Rng, n: usize) -> Tensor {
        Tensor::new(&[n], (0..n).map(|_| rng.below(3) as f32 - 1.0).collect())
    }

    #[test]
    fn threshold_selects_top_fraction() {
        let g = Tensor::new(&[100], (1..=100).map(|i| i as f32).collect());
        let thr = sigma_threshold(&g, 0.05);
        // top 5% of 1..=100 are {96..100}; the q=0.95 midpoint sits near 95–96
        let kept = g.data().iter().filter(|v| v.abs() > thr).count();
        assert!(kept >= 4 && kept <= 6, "kept {kept}, thr {thr}");
    }

    #[test]
    fn update_is_sign_descent() {
        let a = Tensor::new(&[4], vec![0.0, 1.0, -1.0, 0.0]);
        let g = Tensor::new(&[4], vec![3.0, -4.0, 5.0, -0.1]);
        // keep 75%: threshold lands between |−0.1| and |3|, so the last
        // entry is below σ and the first three fire.
        let (out, moved) = tsign_update_host(&a, &g, 0.75);
        // sign descent with clipping: 0−1=−1; 1+1 clips to 1; −1−1 clips
        // to −1; below-threshold entry untouched.
        assert_eq!(out.data(), &[-1.0, 1.0, -1.0, 0.0]);
        assert_eq!(moved, 1); // only the first entry actually changed value
    }

    #[test]
    fn clip_keeps_ternary_domain() {
        let mut rng = Rng::new(10);
        for _ in 0..20 {
            let n = rng.range(16, 256);
            let a = ternary_tensor(&mut rng, n);
            let g = Tensor::new(&[n], rng.normal_vec(n, 1.0));
            let (out, _) = tsign_update_host(&a, &g, 0.2);
            assert!(out.data().iter().all(|v| [-1.0, 0.0, 1.0].contains(v)));
        }
    }

    #[test]
    fn selectivity_bounds_moved_entries() {
        let mut rng = Rng::new(11);
        let n = 10_000;
        let a = ternary_tensor(&mut rng, n);
        let g = Tensor::new(&[n], rng.normal_vec(n, 1.0));
        let keep = 0.05;
        let (_, moved) = tsign_update_host(&a, &g, keep);
        // moved <= selected (clips at ±1 can suppress movement)
        assert!(moved as f32 <= keep * n as f32 * 1.2 + 2.0, "moved {moved}");
        assert!(moved > 0);
    }

    #[test]
    fn tiny_gradients_never_fire() {
        let a = Tensor::new(&[4], vec![0.0; 4]);
        let g = Tensor::new(&[4], vec![1e-12, -1e-12, 1e-13, 0.0]);
        // even keeping 100%, the τ floor suppresses sub-1e-9 gradients
        let (out, moved) = tsign_update_host(&a, &g, 1.0);
        assert_eq!(moved, 0);
        assert_eq!(out.data(), a.data());
    }
}
