//! Optimizers: t-SignSGD (the paper's contribution, §3.3) and AdamW (the
//! baselines' optimizer and the pretrainer's).
//!
//! The *updates* execute inside the HLO step artifacts; this module owns
//! the schedules the Rust coordinator feeds them per step (the σ_t
//! percentile schedule, learning-rate schedules) and host-side reference
//! implementations used for golden validation and unit tests.

pub mod adamw;
pub mod schedule;
pub mod tsignsgd;

pub use adamw::AdamWState;
pub use schedule::{LrSchedule, SigmaSchedule};
pub use tsignsgd::{sigma_threshold, tsign_update_host};
