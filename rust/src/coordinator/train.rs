//! QAF fine-tuning loops + the lossless merge.
//!
//! One generic driver handles all three methods; the differences live in
//! which step artifact runs and which scalars feed it:
//! * LoTA — `step_lota_{cfg}_w{bits}` with (ω, keep_frac) from the σ_t
//!   schedule; no optimizer state (t-SignSGD is stateless).
//! * LoRA / QA-LoRA — `step_{method}_{cfg}` with (lr, step) and AdamW
//!   moment stores round-tripping through the artifact.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::adapter::{lota_merge, LoraAdapter, QaLoraAdapter, TernaryAdapter};
use crate::config::{step_batch, ExperimentConfig, Method, ModelConfig};
use crate::data::{corpus, sft_batch, tasks, Example, Split};
use crate::model::{self, ParamStore, SLOTS};
use crate::optim::SigmaSchedule;
use crate::runtime::Runtime;
use crate::tensor::{Rng, Tensor};

/// Extra knobs the benches tweak on top of an [`ExperimentConfig`].
#[derive(Clone, Debug)]
pub struct TrainOptions {
    /// record the loss every step (convergence analysis, Fig. 4d)
    pub record_losses: bool,
    /// validate ternary invariants after every step (slower; on in tests)
    pub paranoid: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { record_losses: true, paranoid: false }
    }
}

/// Outcome of a fine-tuning run.
#[derive(Clone, Debug)]
pub struct FinetuneReport {
    pub losses: Vec<f32>,
    pub wall_secs: f64,
    /// peak auxiliary state elements (adapters + optimizer moments) — the
    /// Fig. 6 memory-overhead metric
    pub aux_state_elems: usize,
    pub steps: usize,
}

fn sample_task_example(task: &str, rng: &mut Rng) -> Result<Example> {
    if task == "recovery" {
        let (prompt, completion) = corpus::sample_recovery_example(rng);
        Ok(Example { prompt, completion })
    } else {
        let gen = tasks::task_by_name(task)?;
        Ok(gen.sample(rng, Split::Train))
    }
}

/// Fine-tune `store` (quantized base + freshly-initialized adapters) in
/// place. Returns the loss curve and resource accounting.
pub fn finetune(
    rt: &Runtime,
    cfg: &ModelConfig,
    exp: &ExperimentConfig,
    store: &mut ParamStore,
    opts: &TrainOptions,
) -> Result<FinetuneReport> {
    let method = exp.method;
    if !method.trains() {
        bail!("method {:?} has no training step", method);
    }
    let artifact = match method {
        Method::LotaQaf => format!("step_lota_{}_w{}", cfg.name, exp.n_bits),
        m => format!("step_{}_{}", m.as_str(), cfg.name),
    };
    let exe = rt.load(&artifact)?;
    let b = step_batch(&cfg.name);
    let mut data_rng = Rng::new(exp.seed ^ 0xF17E);

    // optimizer state for the AdamW methods
    let adapter_names = model::adapter_names(method);
    let (mut opt_m, mut opt_v) = if matches!(method, Method::Lora | Method::QaLora) {
        let mut m = ParamStore::new();
        let mut v = ParamStore::new();
        for n in &adapter_names {
            let shape = store.get(n)?.shape().to_vec();
            m.insert(n, Tensor::zeros(&shape));
            v.insert(n, Tensor::zeros(&shape));
        }
        (Some(m), Some(v))
    } else {
        (None, None)
    };

    let adapter_elems: usize = adapter_names
        .iter()
        .map(|n| store.get(n).map(|t| t.len()).unwrap_or(0))
        .sum();
    let aux_state_elems = adapter_elems
        + opt_m.as_ref().map(|s| s.n_elems()).unwrap_or(0)
        + opt_v.as_ref().map(|s| s.n_elems()).unwrap_or(0);

    let sigma = SigmaSchedule::with_init(exp.sigma_init);
    let omega = exp.omega(cfg.rank);
    let t0 = Instant::now();
    let mut losses = Vec::new();

    for t in 1..=exp.steps {
        let examples: Vec<Example> = (0..b)
            .map(|_| sample_task_example(&exp.task, &mut data_rng))
            .collect::<Result<_>>()?;
        let batch = sft_batch(&examples, b, cfg.seq_len);

        let mut scalars = BTreeMap::new();
        match method {
            Method::LotaQaf => {
                scalars.insert("omega".to_string(), Tensor::from_scalar(omega));
                scalars.insert(
                    "keep_frac".to_string(),
                    Tensor::from_scalar(sigma.keep_frac(t - 1, exp.steps)),
                );
            }
            _ => {
                scalars.insert("lr".to_string(), Tensor::from_scalar(exp.lr));
                scalars.insert("step".to_string(), Tensor::from_scalar(t as f32));
            }
        }

        let loss = super::run_step(
            rt,
            &exe,
            store,
            opt_m.as_mut(),
            opt_v.as_mut(),
            &batch,
            &scalars,
        )?;
        if opts.record_losses {
            losses.push(loss);
        }
        if opts.paranoid && method == Method::LotaQaf {
            for n in &adapter_names {
                let t = store.get(n)?;
                if let Some(bad) =
                    t.data().iter().find(|v| **v != -1.0 && **v != 0.0 && **v != 1.0)
                {
                    bail!("adapter {n} left ternary domain: {bad}");
                }
            }
        }
        if t % 25 == 0 || t == 1 {
            log::info!(
                "finetune[{}/{}/{}b] step {t}/{} loss {loss:.4}",
                cfg.name,
                method.as_str(),
                exp.n_bits,
                exp.steps
            );
        }
    }

    Ok(FinetuneReport {
        losses,
        wall_secs: t0.elapsed().as_secs_f64(),
        aux_state_elems,
        steps: exp.steps,
    })
}

/// Merge trained adapters into the quantized store (consuming the adapter
/// tensors), producing a plain "merged" model the low-bit serving path
/// runs. LoTA and QA-LoRA merge losslessly; LoRA re-quantizes (lossy) —
/// the returned f32 is the max requantization error across slots
/// (always 0 for the lossless methods).
pub fn merge_into_store(
    cfg: &ModelConfig,
    exp: &ExperimentConfig,
    store: &mut ParamStore,
) -> Result<f32> {
    let mut max_err = 0.0f32;
    let omega = exp.omega(cfg.rank);
    for li in 0..cfg.n_layers {
        for slot in SLOTS {
            let ql = model::quant_layer(cfg, store, slot, li, exp.n_bits)?;
            let merged = match exp.method {
                Method::LotaQaf => {
                    let a = store.get(&format!("ta_{slot}_a"))?.layer(li);
                    let b = store.get(&format!("ta_{slot}_b"))?.layer(li);
                    let ta = TernaryAdapter::from_parts(a, b)?;
                    lota_merge(&ql, &ta, omega)
                }
                Method::QaLora => {
                    let a = store.get(&format!("qa_{slot}_a"))?.layer(li);
                    let b = store.get(&format!("qa_{slot}_b"))?.layer(li);
                    let ad = QaLoraAdapter {
                        a,
                        b,
                        rank: cfg.rank,
                        group_size: cfg.group_size,
                        alpha: 2.0 * cfg.rank as f32,
                    };
                    ad.merge_zeros(&ql)
                }
                Method::Lora => {
                    let a = store.get(&format!("lo_{slot}_a"))?.layer(li);
                    let b = store.get(&format!("lo_{slot}_b"))?.layer(li);
                    let ad = LoraAdapter { a, b, rank: cfg.rank, alpha: 2.0 * cfg.rank as f32 };
                    let (m, err) = crate::adapter::lora::merge_requantize(&ql, &ad);
                    max_err = max_err.max(err);
                    m
                }
                Method::GptqOnly => ql.clone(),
            };
            merged.validate()?;
            model::set_quant_layer(store, slot, li, &merged)?;
        }
    }
    // drop adapter tensors: the merged model is adapter-free
    for n in model::adapter_names(exp.method) {
        store.remove(&n);
    }
    Ok(max_err)
}
