//! Pipeline stages: pretraining, GPTQ calibration and model quantization —
//! the steps that produce the "pretrained-then-quantized" base model every
//! QAF experiment starts from.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{step_batch, ModelConfig};
use crate::data::{corpus, lm_batch};
use crate::model::{self, ParamStore};
use crate::quant::{accumulate_hessian, gptq_quantize, rtn_quantize, GptqConfig};
use crate::runtime::Runtime;
use crate::tensor::{Rng, Tensor};

/// End-to-end pipeline context: a config + runtime + seed.
pub struct Pipeline<'a> {
    pub cfg: ModelConfig,
    pub rt: &'a Runtime,
    pub seed: u64,
}

/// Pretrain a full-precision base model on the synthetic corpus with the
/// in-graph AdamW step. Returns the fp store and the loss curve.
pub fn pretrain(
    rt: &Runtime,
    cfg: &ModelConfig,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(ParamStore, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    let mut store = model::init_fp(cfg, &mut rng);
    let mut opt_m = ParamStore::new();
    let mut opt_v = ParamStore::new();
    for name in model::fp_names() {
        let shape = store.get(&name)?.shape().to_vec();
        opt_m.insert(&name, Tensor::zeros(&shape));
        opt_v.insert(&name, Tensor::zeros(&shape));
    }
    let exe = rt.load(&format!("pretrain_step_{}", cfg.name))?;
    let b = step_batch(&cfg.name);
    let mut data_rng = rng.fork(0xDA7A);
    let mut losses = Vec::with_capacity(steps);
    for t in 1..=steps {
        let docs: Vec<String> = (0..b).map(|_| corpus::sample_document(&mut data_rng)).collect();
        let batch = lm_batch(&docs, b, cfg.seq_len);
        let mut scalars = BTreeMap::new();
        scalars.insert("lr".to_string(), Tensor::from_scalar(lr));
        scalars.insert("step".to_string(), Tensor::from_scalar(t as f32));
        let loss = super::run_step(
            rt,
            &exe,
            &mut store,
            Some(&mut opt_m),
            Some(&mut opt_v),
            &batch,
            &scalars,
        )?;
        losses.push(loss);
        if t % 20 == 0 || t == 1 {
            log::info!("pretrain[{}] step {t}/{steps} loss {loss:.4}", cfg.name);
        }
    }
    Ok((store, losses))
}

/// Per-(slot, layer) Hessian accumulators for GPTQ calibration.
pub type HessianMap = BTreeMap<(String, usize), Tensor>;

/// Run the activation-capture artifact over `n_batches` calibration batches
/// and accumulate `XᵀX` Hessians for every quantized slot of every layer.
/// (Stands in for the paper's 1024 C4 samples; see DESIGN.md §2.)
pub fn calibrate_hessians(
    rt: &Runtime,
    cfg: &ModelConfig,
    fp: &ParamStore,
    n_batches: usize,
    seed: u64,
) -> Result<HessianMap> {
    let exe = rt.load(&format!("acts_fp_{}", cfg.name))?;
    let b = step_batch(&cfg.name);
    let (d, ff, l, t) = (cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.seq_len);
    let mut rng = Rng::new(seed ^ 0xCA11B);

    let mut hs: HessianMap = BTreeMap::new();
    for (slot, din, _) in cfg.slots() {
        for li in 0..l {
            hs.insert((slot.to_string(), li), Tensor::zeros(&[din, din]));
        }
    }

    // capture outputs: xn1 (wq/wk/wv input), attn_o (wo), xn2 (w_up),
    // h_mid (w_down); each (L, B, T, ·)
    let slot_of_capture: [(&str, Vec<&str>, usize); 4] = [
        ("xn1", vec!["wq", "wk", "wv"], d),
        ("attn_o", vec!["wo"], d),
        ("xn2", vec!["w_up"], d),
        ("h_mid", vec!["w_down"], ff),
    ];

    for _ in 0..n_batches {
        let docs: Vec<String> = (0..b).map(|_| corpus::sample_document(&mut rng)).collect();
        let batch = lm_batch(&docs, b, t);
        let tokens = Tensor::new(&[b, t], batch.tokens.clone());
        let mut scalars = BTreeMap::new();
        scalars.insert("tokens".to_string(), tokens);
        let mut batch_buf = Vec::new();
        let inputs =
            super::resolve_inputs(&exe, fp, None, None, None, &scalars, &mut batch_buf)?;
        let caps = rt.execute(&exe, &inputs)?;
        for (ci, (cap_name, slots, dim)) in slot_of_capture.iter().enumerate() {
            let cap = &caps[ci];
            let expect = [l, b, t, *dim];
            if cap.shape() != expect {
                bail!("capture {cap_name} shape {:?} != {:?}", cap.shape(), expect);
            }
            for li in 0..l {
                // (B*T, dim) activation matrix for this layer
                let rows = b * t;
                let off = li * rows * dim;
                let x = Tensor::new(&[rows, *dim], cap.data()[off..off + rows * dim].to_vec());
                for slot in slots {
                    let h = hs.get_mut(&(slot.to_string(), li)).unwrap();
                    accumulate_hessian(h, &x);
                }
            }
        }
    }
    Ok(hs)
}

/// Quantize a pretrained fp store with GPTQ (or RTN when `hessians` is
/// `None` — the ablation baseline).
pub fn quantize_model(
    cfg: &ModelConfig,
    fp: &ParamStore,
    n_bits: u32,
    hessians: Option<&HessianMap>,
) -> Result<ParamStore> {
    model::quantize_store(cfg, fp, |slot, layer, w| match hessians {
        Some(hs) => {
            let h = hs
                .get(&(slot.to_string(), layer))
                .with_context(|| format!("no hessian for {slot}/{layer}"))?;
            gptq_quantize(w, h, &GptqConfig::new(n_bits, cfg.group_size))
        }
        None => Ok(rtn_quantize(w, cfg.group_size, n_bits)),
    })
}

impl<'a> Pipeline<'a> {
    pub fn new(rt: &'a Runtime, cfg: ModelConfig, seed: u64) -> Self {
        Pipeline { cfg, rt, seed }
    }

    /// Produce (or load from cache) the pretrained fp base model.
    pub fn base_model(&self, steps: usize, cache_dir: Option<&Path>) -> Result<ParamStore> {
        if let Some(dir) = cache_dir {
            let path = dir.join(format!("base_{}_{steps}.ckpt", self.cfg.name));
            if path.exists() {
                log::info!("loading cached base model {path:?}");
                return model::checkpoint::load(&path);
            }
            std::fs::create_dir_all(dir)?;
            let (store, losses) = pretrain(self.rt, &self.cfg, steps, 1e-3, self.seed)?;
            log::info!(
                "pretrained {}: loss {:.3} -> {:.3}",
                self.cfg.name,
                losses.first().copied().unwrap_or(f32::NAN),
                losses.last().copied().unwrap_or(f32::NAN)
            );
            model::checkpoint::save(&store, &path, None)?;
            Ok(store)
        } else {
            Ok(pretrain(self.rt, &self.cfg, steps, 1e-3, self.seed)?.0)
        }
    }

    /// GPTQ-quantize the base model at a bit-width (with Hessian reuse).
    pub fn quantized(
        &self,
        fp: &ParamStore,
        n_bits: u32,
        hessians: &HessianMap,
    ) -> Result<ParamStore> {
        quantize_model(&self.cfg, fp, n_bits, Some(hessians))
    }
}
