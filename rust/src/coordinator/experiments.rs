//! Reusable experiment drivers behind Table 1 and Figures 1/4/5/6 — shared
//! by the `lota` CLI and the `cargo bench` regenerators so the numbers in
//! EXPERIMENTS.md come from exactly one code path.
//!
//! The flow mirrors the paper's §4.1 setup at simulator scale: pretrain a
//! base model once, GPTQ-calibrate once, then for every (bits × method ×
//! task) cell: quantize → init adapters → fine-tune → merge (lossless for
//! LoTA/QA-LoRA, requantize for LoRA is *not* done — the paper's
//! GPTQ+LoRA rows serve unmerged at 4+16 bit, and so do we) → evaluate.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::{preset, ExperimentConfig, Method, ModelConfig};
use crate::coordinator::pipeline::{calibrate_hessians, pretrain, quantize_model, HessianMap};
use crate::coordinator::train::{finetune, merge_into_store, FinetuneReport, TrainOptions};
use crate::coordinator::{eval, run_forward};
use crate::data::mmlu_like::{self, MmluScores};
use crate::data::{tasks, Example};
use crate::model::{self, checkpoint, ParamStore};
use crate::runtime::Runtime;
use crate::tensor::{Rng, Tensor};

/// Per-task decode budget (chars ≈ tokens for the char tokenizer).
pub fn max_new_for(task: &str) -> usize {
    match task {
        "arith" => 6,
        "sql" => 48,
        "datatotext" => 56,
        _ => 16,
    }
}

/// Shared context: pretrained base + calibration Hessians, built once.
pub struct ExperimentContext {
    pub cfg: ModelConfig,
    pub rt: Runtime,
    pub fp: ParamStore,
    pub hessians: HessianMap,
    pub seed: u64,
}

impl ExperimentContext {
    /// Build (or reload from `checkpoints/`) the shared base state.
    pub fn build(
        artifacts: &Path,
        model_name: &str,
        pretrain_steps: usize,
        seed: u64,
    ) -> Result<ExperimentContext> {
        let cfg = preset(model_name)?;
        let rt = Runtime::new(artifacts)?;
        let cache = Path::new("checkpoints");
        std::fs::create_dir_all(cache).ok();
        let base_path = cache.join(format!("base_{model_name}_{pretrain_steps}.ckpt"));
        let fp = if base_path.exists() {
            log::info!("reusing cached base model {base_path:?}");
            checkpoint::load(&base_path)?
        } else {
            let (fp, losses) = pretrain(&rt, &cfg, pretrain_steps, 1e-3, seed)?;
            log::info!(
                "pretrained {model_name}: loss {:.3} -> {:.3}",
                losses.first().unwrap_or(&f32::NAN),
                losses.last().unwrap_or(&f32::NAN)
            );
            checkpoint::save(&fp, &base_path, None)?;
            fp
        };
        let hessians = calibrate_hessians(&rt, &cfg, &fp, 6, seed)?;
        Ok(ExperimentContext { cfg, rt, fp, hessians, seed })
    }

    /// Quantize the base at a bit-width (GPTQ with the shared Hessians).
    pub fn quantized(&self, n_bits: u32) -> Result<ParamStore> {
        quantize_model(&self.cfg, &self.fp, n_bits, Some(&self.hessians))
    }

    /// MMLU-like scores of the *fp* model (the 16-bit reference row).
    pub fn mmlu_fp(&self, eval_n: usize) -> Result<MmluScores> {
        let exe = self.rt.load(&format!("fwd_fp_{}", self.cfg.name))?;
        let qs = mmlu_like::generate_suite(eval_n / 4, 0xE7A1);
        eval::mmlu_eval(&self.rt, &exe, &self.fp, &self.cfg, &qs, None)
    }

    /// MMLU-like scores of a (merged / gptq-only) quantized store.
    pub fn mmlu_merged(&self, store: &ParamStore, eval_n: usize) -> Result<MmluScores> {
        let exe = self.rt.load(&format!("fwd_merged_{}", self.cfg.name))?;
        let qs = mmlu_like::generate_suite(eval_n / 4, 0xE7A1);
        eval::mmlu_eval(&self.rt, &exe, store, &self.cfg, &qs, None)
    }

    /// MMLU-like scores through the unmerged LoRA path (4+16-bit serving).
    pub fn mmlu_lora(&self, store: &ParamStore, eval_n: usize) -> Result<MmluScores> {
        let exe = self.rt.load(&format!("fwd_lora_{}", self.cfg.name))?;
        let qs = mmlu_like::generate_suite(eval_n / 4, 0xE7A1);
        eval::mmlu_eval(&self.rt, &exe, store, &self.cfg, &qs, None)
    }
}

/// Result of one fine-tuning cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub mmlu: Option<MmluScores>,
    pub exact_match: Option<f32>,
    pub token_acc: Option<f32>,
    pub report: FinetuneReport,
    pub merge_err: f32,
}

/// Run one (method × bits × task) fine-tuning cell end to end.
///
/// `task == "recovery"` evaluates on the MMLU-like suite; other tasks get
/// exact-match + token accuracy on their held-out test set. LoRA cells are
/// evaluated through the unmerged 4+16 path (as in the paper); the
/// lossless methods are evaluated after their merge.
pub fn run_cell(
    ctx: &ExperimentContext,
    exp: &ExperimentConfig,
    eval_n: usize,
) -> Result<CellResult> {
    let cfg = &ctx.cfg;
    let mut store = ctx.quantized(exp.n_bits)?;
    let mut rng = Rng::new(exp.seed ^ 0xCE11);
    model::init_adapters(cfg, exp.method, &mut rng, &mut store);
    let report = if exp.method.trains() {
        finetune(&ctx.rt, cfg, exp, &mut store, &TrainOptions::default())?
    } else {
        FinetuneReport { losses: vec![], wall_secs: 0.0, aux_state_elems: 0, steps: 0 }
    };

    // merge (except LoRA, which serves unmerged like the paper's rows)
    let merge_err = if exp.method.trains() && exp.method != Method::Lora {
        merge_into_store(cfg, exp, &mut store)?
    } else {
        0.0
    };

    let (fwd_name, omega) = match exp.method {
        Method::Lora => (format!("fwd_lora_{}", cfg.name), None),
        _ => (format!("fwd_merged_{}", cfg.name), None),
    };
    let exe = ctx.rt.load(&fwd_name)?;

    let mut cell = CellResult {
        mmlu: None,
        exact_match: None,
        token_acc: None,
        report,
        merge_err,
    };
    if exp.task == "recovery" {
        let qs = mmlu_like::generate_suite(eval_n / 4, 0xE7A1);
        cell.mmlu = Some(eval::mmlu_eval(&ctx.rt, &exe, &store, cfg, &qs, omega)?);
    } else {
        let gen = tasks::task_by_name(&exp.task)?;
        let test: Vec<Example> = gen.test_set(eval_n);
        cell.exact_match = Some(eval::exact_match_eval(
            &ctx.rt,
            &exe,
            &store,
            cfg,
            &test,
            max_new_for(&exp.task),
            omega,
        )?);
        cell.token_acc = Some(eval::token_accuracy(&ctx.rt, &exe, &store, cfg, &test, omega)?);
    }
    Ok(cell)
}

/// One Table-1 row: method at a bit-width across MMLU + the three tasks.
#[derive(Clone, Debug)]
pub struct TableRow {
    pub method: String,
    pub bits: String,
    pub mmlu: Option<MmluScores>,
    /// task -> (exact match %, token accuracy %)
    pub tasks: BTreeMap<String, (f32, f32)>,
}

/// Regenerate Table 1 (and thereby Fig. 1's series) for one model size.
pub fn run_table1(
    ctx: &ExperimentContext,
    steps: usize,
    eval_n: usize,
    bits_list: &[u32],
    task_list: &[&str],
) -> Result<Vec<TableRow>> {
    let mut rows = Vec::new();

    // 16-bit reference row
    rows.push(TableRow {
        method: format!("{}-fp", ctx.cfg.name),
        bits: "16".into(),
        mmlu: Some(ctx.mmlu_fp(eval_n)?),
        tasks: BTreeMap::new(),
    });

    for &bits in bits_list {
        // GPTQ-only row
        let q = ctx.quantized(bits)?;
        rows.push(TableRow {
            method: "GPTQ".into(),
            bits: bits.to_string(),
            mmlu: Some(ctx.mmlu_merged(&q, eval_n)?),
            tasks: BTreeMap::new(),
        });

        for method in [Method::Lora, Method::QaLora, Method::LotaQaf] {
            let mut row = TableRow {
                method: match method {
                    Method::Lora => "GPTQ+LoRA".into(),
                    Method::QaLora => "QA-LoRA".into(),
                    Method::LotaQaf => "LoTA-QAF".into(),
                    Method::GptqOnly => unreachable!(),
                },
                bits: if method == Method::Lora {
                    format!("{bits}+16")
                } else {
                    bits.to_string()
                },
                mmlu: None,
                tasks: BTreeMap::new(),
            };
            // performance recovery
            let exp = ExperimentConfig {
                model: ctx.cfg.name.clone(),
                method,
                n_bits: bits,
                steps,
                // paper: recovery uses a lower lr than task-specific
                lr: 1e-4,
                sigma_init: 0.05,
                omega_frac: 0.75,
                task: "recovery".into(),
                seed: ctx.seed,
                ..Default::default()
            };
            let cell = run_cell(ctx, &exp, eval_n).context("recovery cell")?;
            row.mmlu = cell.mmlu;

            // task-specific
            for task in task_list {
                let exp = ExperimentConfig {
                    task: task.to_string(),
                    lr: 5e-4,
                    omega_frac: if *task == "datatotext" { 0.875 } else { 0.75 },
                    ..exp.clone()
                };
                // decode-based task evals are ~10× costlier per example
                // than likelihood scoring; use a smaller held-out slice
                let task_eval = (eval_n / 4).clamp(16, 48);
                let cell = run_cell(ctx, &exp, task_eval)
                    .with_context(|| format!("cell {}/{bits}/{task}", method.as_str()))?;
                row.tasks.insert(
                    task.to_string(),
                    (cell.exact_match.unwrap_or(0.0), cell.token_acc.unwrap_or(0.0)),
                );
            }
            rows.push(row);
        }
    }
    Ok(rows)
}

/// Pretty-print Table-1 rows in the paper's layout.
pub fn print_table1(rows: &[TableRow], task_list: &[&str]) {
    let mut headers = vec!["Method", "#Bit", "Hums.", "STEM", "Social", "Other", "MMLU-Avg"];
    let mut task_headers = Vec::new();
    for t in task_list {
        task_headers.push(format!("{t}-EM"));
        task_headers.push(format!("{t}-TokAcc"));
    }
    headers.extend(task_headers.iter().map(|s| s.as_str()));
    let mut table = crate::bench_harness::Table::new(&headers);
    for row in rows {
        let mut cells = vec![row.method.clone(), row.bits.clone()];
        match &row.mmlu {
            Some(m) => {
                for v in m.per_subject {
                    cells.push(format!("{v:.2}"));
                }
                cells.push(format!("{:.2}", m.average));
            }
            None => cells.extend(std::iter::repeat("-".to_string()).take(5)),
        }
        for t in task_list {
            match row.tasks.get(*t) {
                Some((em, ta)) => {
                    cells.push(format!("{em:.2}"));
                    cells.push(format!("{ta:.2}"));
                }
                None => {
                    cells.push("-".into());
                    cells.push("-".into());
                }
            }
        }
        table.row(&cells);
    }
    table.print();
}

/// The unmerged-LoTA forward (used by hyper-parameter sweeps that evaluate
/// *without* merging to keep the adapters live).
pub fn fwd_lota_logits(
    ctx: &ExperimentContext,
    store: &ParamStore,
    bits: u32,
    tokens: &Tensor,
    omega: f32,
) -> Result<Tensor> {
    let exe = ctx.rt.load(&format!("fwd_lota_{}_w{bits}", ctx.cfg.name))?;
    run_forward(&ctx.rt, &exe, store, tokens, Some(omega))
}
