//! Evaluation harnesses: the MMLU-like suite (lm-eval-style option
//! likelihood scoring), exact-match + token-level task accuracy over the
//! HALO-style test sets, and masked perplexity.

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::data::mmlu_like::{self, MmluScores, Question, N_OPTIONS};
use crate::data::tokenizer::{self, BOS, EOS, SEP};
use crate::data::{encode_example, Example};
use crate::model::ParamStore;
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;

fn spec_batch(exe: &Executable) -> Result<usize> {
    exe.spec
        .batch
        .ok_or_else(|| anyhow::anyhow!("fwd artifact '{}' has no batch size", exe.spec.name))
}

/// Length-normalized log-likelihood of `cont_ids` appended after `ctx_ids`,
/// from a logits tensor row.
fn seq_logprob(logits: &Tensor, row: usize, t: usize, v: usize, ids: &[u32], start: usize) -> f32 {
    // predicts ids[p+1] at position p
    let mut total = 0.0f64;
    let mut n = 0usize;
    for p in (start.max(1) - 1)..(ids.len() - 1) {
        let off = (row * t + p) * v;
        let lrow = &logits.data()[off..off + v];
        let maxv = lrow.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = maxv + lrow.iter().map(|x| (x - maxv).exp()).sum::<f32>().ln();
        total += (lrow[ids[p + 1] as usize] - lse) as f64;
        n += 1;
    }
    (total / n.max(1) as f64) as f32
}

/// Score the MMLU-like suite. Each question costs `N_OPTIONS` rows: the
/// option text is appended to the context and scored by mean token
/// log-likelihood (lm-eval's normalized protocol); argmax answers.
pub fn mmlu_eval(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    cfg: &ModelConfig,
    questions: &[Question],
    omega: Option<f32>,
) -> Result<MmluScores> {
    let b = spec_batch(exe)?;
    if b < N_OPTIONS {
        bail!("fwd batch {b} cannot hold {N_OPTIONS} option rows");
    }
    let per_chunk = b / N_OPTIONS;
    let t = cfg.seq_len;
    let v = cfg.vocab;

    let mut results = Vec::with_capacity(questions.len());
    for chunk in questions.chunks(per_chunk) {
        let mut tokens = vec![0.0f32; b * t];
        let mut meta: Vec<(Vec<u32>, usize)> = Vec::new(); // (ids, cont_start)
        for (qi, q) in chunk.iter().enumerate() {
            let ctx = tokenizer::encode(&q.context);
            for (oi, opt) in q.options.iter().enumerate() {
                let mut ids = vec![BOS];
                ids.extend(&ctx);
                let start = ids.len();
                ids.extend(tokenizer::encode(opt));
                if ids.len() > t {
                    bail!("mmlu sequence too long: {} > {t}", ids.len());
                }
                let row = qi * N_OPTIONS + oi;
                for (pos, id) in ids.iter().enumerate() {
                    tokens[row * t + pos] = *id as f32;
                }
                meta.push((ids, start));
            }
        }
        let logits =
            super::run_forward(rt, exe, store, &Tensor::new(&[b, t], tokens), omega)?;
        for (qi, q) in chunk.iter().enumerate() {
            let mut best = (f32::NEG_INFINITY, 0usize);
            for oi in 0..N_OPTIONS {
                let (ids, start) = &meta[qi * N_OPTIONS + oi];
                let lp = seq_logprob(&logits, qi * N_OPTIONS + oi, t, v, ids, *start);
                if lp > best.0 {
                    best = (lp, oi);
                }
            }
            results.push((q.subject, best.1 == q.answer));
        }
    }
    Ok(mmlu_like::aggregate(&results))
}

/// Teacher-forced token accuracy (%) on completion positions — the smooth
/// companion to exact match (one forward per batch, no decoding).
pub fn token_accuracy(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    cfg: &ModelConfig,
    test_set: &[Example],
    omega: Option<f32>,
) -> Result<f32> {
    let b = spec_batch(exe)?;
    let t = cfg.seq_len;
    let v = cfg.vocab;
    let mut correct = 0usize;
    let mut total = 0usize;
    for chunk in test_set.chunks(b) {
        let batch = crate::data::sft_batch(chunk, b, t);
        let logits = super::run_forward(
            rt,
            exe,
            store,
            &Tensor::new(&[b, t], batch.tokens.clone()),
            omega,
        )?;
        for i in 0..chunk.len() * t {
            if batch.mask[i] == 0.0 {
                continue;
            }
            let lrow = &logits.data()[i * v..(i + 1) * v];
            let argmax = lrow
                .iter()
                .enumerate()
                .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                .map(|(k, _)| k)
                .unwrap();
            total += 1;
            if argmax == batch.targets[i] as usize {
                correct += 1;
            }
        }
    }
    Ok(100.0 * correct as f32 / total.max(1) as f32)
}

/// Greedy-decode completions for a batch of prompts with a fixed-shape
/// forward artifact (recompute decoding: one forward per generated token,
/// shared by all serving paths so path comparisons stay fair).
pub fn greedy_decode(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    cfg: &ModelConfig,
    prompts: &[String],
    max_new: usize,
    omega: Option<f32>,
) -> Result<Vec<String>> {
    Ok(greedy_decode_counted(rt, exe, store, cfg, prompts, max_new, omega)?
        .into_iter()
        .map(|(text, _)| text)
        .collect())
}

/// [`greedy_decode`] that also reports how many tokens each row actually
/// generated — the unit the serving throughput metric counts.
pub fn greedy_decode_counted(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    cfg: &ModelConfig,
    prompts: &[String],
    max_new: usize,
    omega: Option<f32>,
) -> Result<Vec<(String, usize)>> {
    let b = spec_batch(exe)?;
    let t = cfg.seq_len;
    let v = cfg.vocab;
    let mut outputs = Vec::with_capacity(prompts.len());

    for chunk in prompts.chunks(b) {
        let mut tokens = vec![0.0f32; b * t];
        let mut cursor = vec![0usize; chunk.len()];
        for (row, p) in chunk.iter().enumerate() {
            let mut ids = vec![BOS];
            ids.extend(tokenizer::encode(&p.replace('\n', " ")));
            ids.push(SEP);
            if ids.len() + max_new > t {
                bail!("prompt+generation ({}) exceeds seq_len {t}", ids.len() + max_new);
            }
            for (pos, id) in ids.iter().enumerate() {
                tokens[row * t + pos] = *id as f32;
            }
            cursor[row] = ids.len() - 1; // position of the last prompt token
        }
        let mut done = vec![false; chunk.len()];
        let mut generated: Vec<Vec<u32>> = vec![Vec::new(); chunk.len()];
        for _ in 0..max_new {
            if done.iter().all(|d| *d) {
                break;
            }
            let logits = super::run_forward(
                rt,
                exe,
                store,
                &Tensor::new(&[b, t], tokens.clone()),
                omega,
            )?;
            for row in 0..chunk.len() {
                if done[row] {
                    continue;
                }
                let off = (row * t + cursor[row]) * v;
                let lrow = &logits.data()[off..off + v];
                let next = lrow
                    .iter()
                    .enumerate()
                    .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
                    .map(|(i, _)| i as u32)
                    .unwrap();
                if next == EOS || cursor[row] + 1 >= t {
                    done[row] = true;
                    continue;
                }
                cursor[row] += 1;
                tokens[row * t + cursor[row]] = next as f32;
                generated[row].push(next);
            }
        }
        for g in generated {
            outputs.push((tokenizer::decode(&g), g.len()));
        }
    }
    Ok(outputs)
}

/// Exact-match accuracy (%) of greedy decodes against reference
/// completions — the HALO-style task-specific metric of Table 1.
pub fn exact_match_eval(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    cfg: &ModelConfig,
    test_set: &[Example],
    max_new: usize,
    omega: Option<f32>,
) -> Result<f32> {
    let prompts: Vec<String> = test_set.iter().map(|e| e.prompt.clone()).collect();
    let decoded = greedy_decode(rt, exe, store, cfg, &prompts, max_new, omega)?;
    let correct = decoded
        .iter()
        .zip(test_set)
        .filter(|(got, want)| got.trim() == want.completion.trim())
        .count();
    Ok(100.0 * correct as f32 / test_set.len().max(1) as f32)
}

/// Masked perplexity of a forward artifact over an SFT batch.
pub fn perplexity(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    cfg: &ModelConfig,
    batch: &crate::data::Batch,
    omega: Option<f32>,
) -> Result<f32> {
    let logits = super::run_forward(
        rt,
        exe,
        store,
        &Tensor::new(&[batch.batch, batch.seq], batch.tokens.clone()),
        omega,
    )?;
    let v = cfg.vocab;
    let mut nll = 0.0f64;
    let mut count = 0.0f64;
    for i in 0..batch.batch * batch.seq {
        if batch.mask[i] == 0.0 {
            continue;
        }
        let row = &logits.data()[i * v..(i + 1) * v];
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = maxv + row.iter().map(|x| (x - maxv).exp()).sum::<f32>().ln();
        let tgt = batch.targets[i] as usize;
        nll += (lse - row[tgt]) as f64;
        count += 1.0;
    }
    Ok(((nll / count.max(1.0)).exp()) as f32)
}

// `encode_example` re-exported use keeps the SFT layout single-sourced.
#[allow(unused)]
fn _layout_contract(ex: &Example) -> (Vec<u32>, usize) {
    encode_example(ex)
}
