//! The training/evaluation coordinator — the L3 orchestration layer.
//!
//! Everything model-scale runs through the PJRT artifacts; this module owns
//! the loops around them: pretraining, GPTQ calibration + quantization,
//! QAF fine-tuning for every method (LoTA / LoRA / QA-LoRA), the lossless
//! merge, and the evaluation harnesses (MMLU-like suite + exact-match task
//! scoring + perplexity).
//!
//! All artifact I/O is **manifest-driven**: inputs are resolved by name
//! against the parameter store / optimizer state / batch / scalar
//! environment, so the Rust side can never silently desynchronize from the
//! lowered graphs.

pub mod eval;
pub mod experiments;
pub mod pipeline;
pub mod train;

pub use eval::{
    exact_match_eval, greedy_decode, greedy_decode_counted, mmlu_eval, perplexity, token_accuracy,
};
pub use experiments::{run_cell, run_table1, CellResult, ExperimentContext};
pub use pipeline::{calibrate_hessians, pretrain, quantize_model, Pipeline};
pub use train::{finetune, merge_into_store, FinetuneReport, TrainOptions};

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::data::Batch;
use crate::model::ParamStore;
use crate::runtime::{Executable, Runtime};
use crate::tensor::Tensor;

/// Resolve artifact-input names against the coordinator environment.
///
/// Priority: explicit scalars → batch fields → optimizer states (`m_`/`v_`
/// prefixes) → the parameter store. `batch_buf` is caller-owned storage for
/// tensors materialized from the batch.
pub fn resolve_inputs<'a>(
    exe: &Executable,
    store: &'a ParamStore,
    opt_m: Option<&'a ParamStore>,
    opt_v: Option<&'a ParamStore>,
    batch: Option<&Batch>,
    scalars: &'a BTreeMap<String, Tensor>,
    batch_buf: &'a mut Vec<(String, Tensor)>,
) -> Result<Vec<&'a Tensor>> {
    if let Some(b) = batch {
        batch_buf.push(("tokens".into(), Tensor::new(&[b.batch, b.seq], b.tokens.clone())));
        batch_buf.push(("targets".into(), Tensor::new(&[b.batch, b.seq], b.targets.clone())));
        batch_buf.push(("mask".into(), Tensor::new(&[b.batch, b.seq], b.mask.clone())));
    }
    let mut out = Vec::with_capacity(exe.spec.inputs.len());
    for io in &exe.spec.inputs {
        let name = io.name.as_str();
        let t: &Tensor = if let Some(t) = scalars.get(name) {
            t
        } else if let Some((_, t)) = batch_buf.iter().find(|(n, _)| n == name) {
            t
        } else if let (Some(m), Some(rest)) = (opt_m, name.strip_prefix("m_")) {
            m.get(rest)?
        } else if let (Some(v), Some(rest)) = (opt_v, name.strip_prefix("v_")) {
            v.get(rest)?
        } else if store.contains(name) {
            store.get(name)?
        } else {
            bail!(
                "artifact {}: cannot resolve input '{}' from store/opt/batch/scalars",
                exe.spec.name,
                name
            );
        };
        if t.len() != io.n_elems() {
            bail!(
                "artifact {}: input '{}' size {} != manifest {:?}",
                exe.spec.name,
                name,
                t.len(),
                io.shape
            );
        }
        out.push(t);
    }
    Ok(out)
}

/// Execute a step-like artifact and write named outputs back into the
/// store / optimizer states. Returns the scalar `loss`.
pub fn run_step(
    rt: &Runtime,
    exe: &Executable,
    store: &mut ParamStore,
    mut opt_m: Option<&mut ParamStore>,
    mut opt_v: Option<&mut ParamStore>,
    batch: &Batch,
    scalars: &BTreeMap<String, Tensor>,
) -> Result<f32> {
    let mut batch_buf = Vec::new();
    let outputs = {
        let inputs = resolve_inputs(
            exe,
            store,
            opt_m.as_deref(),
            opt_v.as_deref(),
            Some(batch),
            scalars,
            &mut batch_buf,
        )?;
        rt.execute(exe, &inputs)?
    };
    let mut loss = f32::NAN;
    for (spec, tensor) in exe.spec.outputs.iter().zip(outputs) {
        let name = spec.name.as_str();
        if name == "loss" {
            loss = tensor.data()[0];
        } else if let Some(rest) = name.strip_prefix("m_") {
            if let Some(m) = opt_m.as_deref_mut() {
                m.insert(rest, tensor);
            }
        } else if let Some(rest) = name.strip_prefix("v_") {
            if let Some(v) = opt_v.as_deref_mut() {
                v.insert(rest, tensor);
            }
        } else {
            store.insert(name, tensor);
        }
    }
    if !loss.is_finite() {
        bail!("artifact {} produced non-finite loss {loss}", exe.spec.name);
    }
    Ok(loss)
}

/// Run a forward artifact on a token tensor (B, T), returning logits
/// (B, T, V). `omega` is required for unmerged-LoTA forwards.
pub fn run_forward(
    rt: &Runtime,
    exe: &Executable,
    store: &ParamStore,
    tokens: &Tensor,
    omega: Option<f32>,
) -> Result<Tensor> {
    let mut scalars = BTreeMap::new();
    if let Some(w) = omega {
        scalars.insert("omega".to_string(), Tensor::from_scalar(w));
    }
    scalars.insert("tokens".to_string(), tokens.clone());
    let mut batch_buf = Vec::new();
    let inputs = resolve_inputs(exe, store, None, None, None, &scalars, &mut batch_buf)?;
    let mut out = rt.execute(exe, &inputs)?;
    Ok(out.remove(0))
}
