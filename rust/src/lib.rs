//! # LoTA-QAF: Lossless Ternary Adaptation for Quantization-Aware Fine-Tuning
//!
//! A full-stack reproduction of the NeurIPS 2025 paper *"LoTA-QAF: Lossless
//! Ternary Adaptation for Quantization-Aware Fine-Tuning"* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 1 (Pallas, build-time Python)** — fused ternary-adaptation
//!   kernels (`python/compile/kernels/`): quantized matmul with in-grid
//!   ternary adjustment, the ternary threshold/merge map, and the t-SignSGD
//!   percentile update. Checked against pure-jnp oracles (`ref.py`).
//! * **Layer 2 (JAX, build-time Python)** — the transformer forward/backward
//!   graph over group-quantized weights with LoTA / LoRA / QA-LoRA adapters,
//!   plus full training-step graphs, all AOT-lowered to HLO text by
//!   `python/compile/aot.py`.
//! * **Layer 3 (Rust, this crate)** — everything at runtime: GPTQ/RTN
//!   quantizers, bit-packing, adapter state + lossless merge, the t-SignSGD
//!   schedule, synthetic task corpora, the training coordinator, the batched
//!   inference server, and the benchmark harness that regenerates every
//!   table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` lowers the graphs
//! once, and the `lota` binary loads `artifacts/*.hlo.txt` through PJRT.

pub mod adapter;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
