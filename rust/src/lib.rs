//! # LoTA-QAF: Lossless Ternary Adaptation for Quantization-Aware Fine-Tuning
//!
//! A full-stack reproduction of the NeurIPS 2025 paper *"LoTA-QAF: Lossless
//! Ternary Adaptation for Quantization-Aware Fine-Tuning"* as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 1 (Pallas, build-time Python)** — fused ternary-adaptation
//!   kernels (`python/compile/kernels/`): quantized matmul with in-grid
//!   ternary adjustment, the ternary threshold/merge map, and the t-SignSGD
//!   percentile update. Checked against pure-jnp oracles (`ref.py`).
//! * **Layer 2 (JAX, build-time Python)** — the transformer forward/backward
//!   graph over group-quantized weights with LoTA / LoRA / QA-LoRA adapters,
//!   plus full training-step graphs, all AOT-lowered to HLO text by
//!   `python/compile/aot.py`.
//! * **Layer 3 (Rust, this crate)** — everything at runtime: GPTQ/RTN
//!   quantizers, bit-packing, adapter state + lossless merge, the t-SignSGD
//!   schedule, synthetic task corpora, the training coordinator, the batched
//!   inference server, and the benchmark harness that regenerates every
//!   table and figure of the paper.
//!
//! Python never runs on the request path: `make artifacts` lowers the graphs
//! once, and the `lota` binary loads `artifacts/*.hlo.txt` through PJRT.
//!
//! ## Serving backends
//!
//! Two executors sit behind the [`serve::ServeBackend`] trait:
//!
//! * **PJRT** ([`serve::PjrtBackend`]) — the AOT artifacts, compiled at
//!   fixed batch buckets. The reference executor: training and inference
//!   share one lowered graph, so this is what the golden and integration
//!   suites pin numerically. Requires the `artifacts/` directory.
//! * **Native** ([`serve::NativeBackend`], built on [`engine`]) — a
//!   pure-Rust engine that computes straight off the bit-packed `u32` grid
//!   with a fused group-dequant × matmul kernel. Any batch size, no
//!   artifacts, weights held at the deployed (packed) footprint — the
//!   serving shape the paper's §4.3 efficiency claim describes. Decoding
//!   is KV-cached by default ([`engine::KvCache`]): prompts prefill once
//!   and each generated token costs O(T) attention work instead of the
//!   full-prefix recompute's O(T²); the recompute path survives behind
//!   [`config::DecodeMode`] as the reference the cache is pinned
//!   bit-identical against (`tests/engine_parity.rs`, artifact-free).
//!
//! Use PJRT when artifacts exist and numbers must match training
//! bit-for-bit; use the native engine to serve merged checkpoints under
//! unpredictable batch shapes or without an artifacts directory. The
//! parity golden test (`tests/backend_parity.rs`) holds the two backends'
//! logits together on the same checkpoint.
//!
//! On top of the native engine, the continuous-batching scheduler
//! ([`sched`], served through [`serve::ScheduledBackend`] / `lota serve
//! --sched true`) turns the engine into a request-level server: requests
//! arrive over time, are admitted into KV-cache slots under a memory
//! budget, decode one token per iteration, and hand their slots to
//! waiting requests the moment they finish — with TTFT / queue-wait /
//! occupancy metrics and streaming token sinks. Scheduled greedy output
//! stays bit-identical to the one-shot cached decode.
//!
//! The serving path is observable end to end through [`obs`]: the
//! scheduler emits per-request lifecycle spans and per-step phase spans
//! into an [`obs::Tracer`] (`--trace-out` exports them as a
//! Perfetto-loadable Chrome trace), and [`obs::MetricsRegistry`]
//! snapshots a run's [`serve::ThroughputReport`] to Prometheus text or
//! JSON (`--metrics-out`). Tracing is opt-in and provably inert when
//! disabled — the parity pins above hold with it on or off.

pub mod adapter;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod model;
pub mod obs;
pub mod optim;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod tensor;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
