//! Batched inference serving — the efficiency half of the paper's claims
//! (§4.3, Fig. 4): after the lossless merge, LoTA serves with *only* the
//! low-bit weights, while the LoRA path must run the quantized base **plus**
//! the f32 adapter matmuls on every token. This module provides:
//!
//! * a [`DynamicBatcher`] that queues requests and routes them to the
//!   smallest compiled batch bucket that fits (fixed-shape executables, the
//!   standard AOT-serving pattern);
//! * a [`Server`] worker loop that drains the queue, runs greedy decode
//!   through the chosen forward artifact, and records per-request latency
//!   and aggregate throughput;
//! * [`ThroughputReport`] aggregation used by `examples/serve_merged.rs`
//!   and the Fig. 4 efficiency bench.

pub mod batcher;
pub mod metrics;

pub use batcher::{BucketPolicy, DynamicBatcher, Request};
pub use metrics::{LatencyStats, ThroughputReport};

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::{Method, ModelConfig};
use crate::coordinator;
use crate::model::ParamStore;
use crate::runtime::Runtime;

/// Which serving path a server instance runs (the Fig. 4 comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePath {
    /// merged low-bit weights only (LoTA / QA-LoRA after merge)
    Merged,
    /// quantized base + fp adapter matmuls every forward (LoRA)
    LoraAdapter,
}

impl ServePath {
    pub fn artifact_prefix(&self) -> &'static str {
        match self {
            ServePath::Merged => "fwd_merged",
            ServePath::LoraAdapter => "fwd_lora",
        }
    }

    pub fn for_method(m: Method) -> ServePath {
        match m {
            Method::Lora => ServePath::LoraAdapter,
            _ => ServePath::Merged,
        }
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub latency_secs: f64,
    pub tokens_generated: usize,
}

/// Synchronous batched server: drains a request queue bucket-by-bucket.
pub struct Server<'a> {
    rt: &'a Runtime,
    cfg: ModelConfig,
    store: &'a ParamStore,
    path: ServePath,
    batcher: DynamicBatcher,
    /// compiled executables per bucket size
    exes: BTreeMap<usize, Arc<crate::runtime::Executable>>,
    pub max_new: usize,
}

impl<'a> Server<'a> {
    /// Discover the available buckets for this (config, path) from the
    /// manifest and compile them.
    pub fn new(
        rt: &'a Runtime,
        cfg: &ModelConfig,
        store: &'a ParamStore,
        path: ServePath,
        max_new: usize,
    ) -> Result<Server<'a>> {
        let prefix = path.artifact_prefix();
        let mut exes = BTreeMap::new();
        for spec in rt.manifest().of_kind("fwd") {
            if spec.cfg.as_deref() == Some(cfg.name.as_str())
                && spec.name.starts_with(prefix)
                && spec
                    .method
                    .as_deref()
                    .map(|m| prefix.ends_with(m))
                    .unwrap_or(false)
            {
                if let Some(b) = spec.batch {
                    exes.insert(b, rt.load(&spec.name)?);
                }
            }
        }
        if exes.is_empty() {
            bail!("no {prefix} artifacts for config {}", cfg.name);
        }
        let buckets: Vec<usize> = exes.keys().copied().collect();
        log::info!("server[{}/{prefix}] buckets {:?}", cfg.name, buckets);
        Ok(Server {
            rt,
            cfg: cfg.clone(),
            store,
            path,
            batcher: DynamicBatcher::new(BucketPolicy::new(buckets)?),
            exes,
            max_new,
        })
    }

    pub fn path(&self) -> ServePath {
        self.path
    }

    pub fn enqueue(&mut self, prompt: String) -> u64 {
        self.batcher.push(prompt)
    }

    /// Drain everything queued, returning responses (in completion order)
    /// plus the aggregate report.
    pub fn drain(&mut self) -> Result<(Vec<Response>, ThroughputReport)> {
        let t0 = Instant::now();
        let mut responses = Vec::new();
        let mut total_tokens = 0usize;
        while let Some((bucket, reqs)) = self.batcher.next_batch() {
            let exe = self
                .exes
                .get(&bucket)
                .ok_or_else(|| anyhow::anyhow!("no executable for bucket {bucket}"))?
                .clone();
            let prompts: Vec<String> = reqs.iter().map(|r| r.prompt.clone()).collect();
            let texts = coordinator::greedy_decode(
                self.rt,
                &exe,
                self.store,
                &self.cfg,
                &prompts,
                self.max_new,
                None,
            )?;
            let now = Instant::now();
            for (req, text) in reqs.into_iter().zip(texts) {
                // count generated tokens without re-encoding: decodes can
                // contain ids outside the writable alphabet (untrained or
                // heavily-quantized models emit unused vocab slots)
                let toks = text.chars().count();
                total_tokens += toks;
                responses.push(Response {
                    id: req.id,
                    latency_secs: now.duration_since(req.arrival).as_secs_f64(),
                    tokens_generated: toks,
                    text,
                });
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = ThroughputReport::from_responses(&responses, total_tokens, wall);
        Ok((responses, report))
    }
}

/// Fire-and-drain convenience used by benches: serve `prompts` and report.
pub fn serve_batch(
    rt: &Runtime,
    cfg: &ModelConfig,
    store: &ParamStore,
    path: ServePath,
    prompts: &[String],
    max_new: usize,
) -> Result<ThroughputReport> {
    let mut server = Server::new(rt, cfg, store, path, max_new)?;
    for p in prompts {
        server.enqueue(p.clone());
    }
    let (_, report) = server.drain()?;
    Ok(report)
}

/// Async wrapper: run the server on a worker thread, feeding it through a
/// channel (demonstrates the decoupled producer/consumer deployment shape).
pub fn serve_channel(
    rt: &Runtime,
    cfg: &ModelConfig,
    store: &ParamStore,
    path: ServePath,
    rx: mpsc::Receiver<String>,
    max_new: usize,
) -> Result<(Vec<Response>, ThroughputReport)> {
    let mut server = Server::new(rt, cfg, store, path, max_new)?;
    while let Ok(prompt) = rx.recv() {
        server.enqueue(prompt);
    }
    server.drain()
}
