//! Batched inference serving — the efficiency half of the paper's claims
//! (§4.3, Fig. 4): after the lossless merge, LoTA serves with *only* the
//! low-bit weights, while the LoRA path must run the quantized base **plus**
//! the f32 adapter matmuls on every token. This module provides:
//!
//! * a [`ServeBackend`] trait with two executors: [`PjrtBackend`] (the
//!   fixed-shape AOT artifacts, routed by batch bucket) and
//!   [`NativeBackend`] (the packed-integer engine of `crate::engine`,
//!   which accepts any batch size, needs no artifacts directory, and
//!   decodes KV-cached by default — `decode_mode` in [`ServeOptions`]
//!   selects the full-prefix recompute reference instead);
//! * a [`DynamicBatcher`] that queues requests and routes them to the
//!   smallest batch the chosen backend can run — compiled buckets for
//!   PJRT, the whole queue at once for the native engine;
//! * a [`Server`] worker loop that drains the queue through its backend
//!   and records per-request latency and aggregate throughput;
//! * a scheduled native path: [`ScheduledBackend`] (one-shot serving as a
//!   thin wrapper over the continuous-batching `crate::sched` scheduler,
//!   selected by `ServeOptions::sched` / the `[sched]` TOML table /
//!   `lota serve --sched true`) and [`serve_open_loop`] (timed arrivals
//!   admitted mid-batch — the request-level serving shape). Scheduled
//!   serving runs over a **paged** KV cache by default
//!   (`sched.kv_paged`): the KV budget buys a shared block pool and
//!   admission reserves each request's actual horizon, so mixed-length
//!   workloads sustain more concurrency at the same budget than the
//!   contiguous full-context-row reference (kept behind the flag,
//!   bit-identical tokens either way);
//! * multi-adapter serving ([`AdapterRegistry`], `docs/adapters.md`):
//!   named ternary adapter sets registered against one packed base
//!   (`[adapters]` TOML table / `lota serve --adapter`), requests tagged
//!   per adapter and mixed freely in each scheduled batch — bit-identical
//!   to serving each adapter's individually merged checkpoint alone
//!   (`tests/adapters.rs` pins it);
//! * an async streaming front end ([`listen`], `lota serve --listen`):
//!   the scheduler moved onto a dedicated worker thread
//!   ([`crate::sched::SchedWorker`]) behind an MPSC command channel, with
//!   a minimal hand-rolled HTTP/1.1 + SSE transport streaming each
//!   request's tokens as they are picked and draining in-flight rows on
//!   SIGTERM (`docs/serving.md` documents the wire protocol);
//! * [`ThroughputReport`] aggregation used by `examples/serve_merged.rs`
//!   and the Fig. 4 efficiency bench. Token throughput counts **generated
//!   tokens**, not decoded characters; scheduled runs additionally carry
//!   TTFT, queue-wait, queue-depth and batch-occupancy measurements
//!   ([`SchedStats`]).

pub mod adapters;
pub mod backend;
pub mod batcher;
pub mod listen;
pub mod metrics;

pub use adapters::{synthetic_adapter_store, AdapterRegistry, AdapterSpec};
pub use listen::{serve_listen, ListenServer};
pub use backend::{
    DecodeStats, Generation, NativeBackend, PjrtBackend, ScheduledBackend, ServeBackend,
};
pub use batcher::{BucketPolicy, DynamicBatcher, Request};
pub use metrics::{
    AdapterUsage, Histogram, LatencyStats, SchedStats, ThroughputReport, HISTOGRAM_CAP,
};

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{Backend, DecodeMode, GemmKernel, Method, ModelConfig, SchedConfig};
use crate::model::ParamStore;
use crate::runtime::Runtime;
use crate::sched::{LoadRequest, RequestSpec, SchedOptions, SchedResponse, Scheduler};

/// Which serving path a server instance runs (the Fig. 4 comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServePath {
    /// merged low-bit weights only (LoTA / QA-LoRA after merge)
    Merged,
    /// quantized base + fp adapter matmuls every forward (LoRA)
    LoraAdapter,
}

impl ServePath {
    pub fn artifact_prefix(&self) -> &'static str {
        match self {
            ServePath::Merged => "fwd_merged",
            ServePath::LoraAdapter => "fwd_lora",
        }
    }

    pub fn for_method(m: Method) -> ServePath {
        match m {
            Method::Lora => ServePath::LoraAdapter,
            _ => ServePath::Merged,
        }
    }
}

/// What to serve with: path, backend, and the knobs each backend needs.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub path: ServePath,
    pub backend: Backend,
    /// bit width of the packed grid (native backend only)
    pub n_bits: u32,
    pub max_new: usize,
    /// decode strategy (native backend only): KV-cached incremental steps
    /// or the full-prefix recompute reference
    pub decode: DecodeMode,
    /// packed-GEMM inner kernel (native backend only): auto-detected
    /// SIMD, forced SIMD, or the scalar reference — bit-identical either
    /// way, so this is a speed/debug knob, never a correctness one
    pub gemm_kernel: GemmKernel,
    /// route native serving through the continuous-batching scheduler
    /// (`crate::sched`); None serves one-shot
    pub sched: Option<SchedConfig>,
    /// write a Chrome-trace-event/Perfetto JSON of the serving run here
    /// (scheduled native serving only — one-shot paths have no spans to
    /// record); None disables tracing entirely
    pub trace_out: Option<PathBuf>,
    /// write the engine hot-path profile — `lota_engine_*` per-(layer,
    /// kind) phase counters folded over every profiled forward — here as
    /// a [`crate::obs::MetricsRegistry`] snapshot (`.json` or Prometheus
    /// text by extension; scheduled native serving only); None keeps the
    /// profiler detached and every forward on the unprofiled path
    pub profile_out: Option<PathBuf>,
    /// named ternary adapter sets to register before serving (native
    /// backend only, LoTA serve path; empty serves the bare base)
    pub adapters: AdapterRegistry,
    /// ternarization threshold fraction the adapters were trained with
    /// (omega = omega_frac · rank); irrelevant when `adapters` is empty
    pub omega_frac: f32,
}

impl ServeOptions {
    pub fn new(path: ServePath, max_new: usize) -> ServeOptions {
        ServeOptions {
            path,
            backend: Backend::Pjrt,
            n_bits: 4,
            max_new,
            decode: DecodeMode::Cached,
            gemm_kernel: GemmKernel::Auto,
            sched: None,
            trace_out: None,
            profile_out: None,
            adapters: AdapterRegistry::new(),
            omega_frac: 0.75,
        }
    }

    pub fn backend(mut self, backend: Backend) -> ServeOptions {
        self.backend = backend;
        self
    }

    pub fn bits(mut self, n_bits: u32) -> ServeOptions {
        self.n_bits = n_bits;
        self
    }

    pub fn decode_mode(mut self, decode: DecodeMode) -> ServeOptions {
        self.decode = decode;
        self
    }

    pub fn kernel(mut self, gemm_kernel: GemmKernel) -> ServeOptions {
        self.gemm_kernel = gemm_kernel;
        self
    }

    pub fn scheduled(mut self, sched: SchedConfig) -> ServeOptions {
        self.sched = Some(sched);
        self
    }

    pub fn trace_out(mut self, path: PathBuf) -> ServeOptions {
        self.trace_out = Some(path);
        self
    }

    pub fn profile_out(mut self, path: PathBuf) -> ServeOptions {
        self.profile_out = Some(path);
        self
    }

    pub fn with_adapters(mut self, adapters: AdapterRegistry) -> ServeOptions {
        self.adapters = adapters;
        self
    }

    pub fn omega_frac(mut self, omega_frac: f32) -> ServeOptions {
        self.omega_frac = omega_frac;
        self
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub text: String,
    pub latency_secs: f64,
    /// tokens this generation actually produced (the honest tokens/s unit
    /// — not characters, which under-count when ids decode to specials)
    pub tokens_decoded: usize,
}

/// Synchronous batched server: drains a request queue batch-by-batch
/// through its backend.
pub struct Server<'a> {
    backend: Box<dyn ServeBackend + 'a>,
    batcher: DynamicBatcher,
    pub max_new: usize,
}

impl<'a> Server<'a> {
    /// The original PJRT server: discover buckets from the manifest and
    /// compile them.
    pub fn new(
        rt: &'a Runtime,
        cfg: &ModelConfig,
        store: &'a ParamStore,
        path: ServePath,
        max_new: usize,
    ) -> Result<Server<'a>> {
        Ok(Server::with_backend(Box::new(PjrtBackend::new(rt, cfg, store, path)?), max_new))
    }

    /// A native-engine server: packs the store's grids, no runtime needed.
    pub fn native(
        cfg: &ModelConfig,
        store: &ParamStore,
        path: ServePath,
        n_bits: u32,
        mode: DecodeMode,
        max_new: usize,
    ) -> Result<Server<'a>> {
        let backend =
            NativeBackend::new(cfg, store, path, n_bits, GemmKernel::Auto)?.with_mode(mode);
        Ok(Server::with_backend(Box::new(backend), max_new))
    }

    /// Wrap an already-built backend.
    pub fn with_backend(backend: Box<dyn ServeBackend + 'a>, max_new: usize) -> Server<'a> {
        let batcher = DynamicBatcher::new(backend.bucket_policy());
        Server { backend, batcher, max_new }
    }

    /// Build the backend an options struct selects.
    pub fn from_options(
        rt: Option<&'a Runtime>,
        cfg: &ModelConfig,
        store: &'a ParamStore,
        opts: &ServeOptions,
    ) -> Result<Server<'a>> {
        match opts.backend {
            Backend::Pjrt => {
                if opts.sched.is_some() {
                    bail!("the scheduler runs on the native backend only (got pjrt)");
                }
                if !opts.adapters.is_empty() {
                    bail!("adapter registration runs on the native backend only (got pjrt)");
                }
                let Some(rt) = rt else {
                    bail!("pjrt backend needs a Runtime (artifacts dir)");
                };
                Server::new(rt, cfg, store, opts.path, opts.max_new)
            }
            Backend::Native => match &opts.sched {
                Some(sched) => {
                    if opts.decode == DecodeMode::Recompute {
                        bail!("the scheduler decodes KV-cached; drop decode=recompute");
                    }
                    let backend = ScheduledBackend::new(
                        cfg,
                        store,
                        opts.path,
                        opts.n_bits,
                        sched,
                        opts.gemm_kernel,
                    )?
                    .with_trace_out(opts.trace_out.clone())
                    .with_profile_out(opts.profile_out.clone())
                    .with_adapters(&opts.adapters, opts.omega_frac)?;
                    Ok(Server::with_backend(Box::new(backend), opts.max_new))
                }
                None => {
                    let backend =
                        NativeBackend::new(cfg, store, opts.path, opts.n_bits, opts.gemm_kernel)?
                            .with_mode(opts.decode)
                            .with_adapters(&opts.adapters, opts.omega_frac)?;
                    Ok(Server::with_backend(Box::new(backend), opts.max_new))
                }
            },
        }
    }

    pub fn backend_label(&self) -> &'static str {
        self.backend.label()
    }

    pub fn enqueue(&mut self, prompt: String) -> u64 {
        self.batcher.push(prompt)
    }

    /// Drain everything queued, returning responses (in completion order)
    /// plus the aggregate report. Each batch's KV cache lives for exactly
    /// that batch's decode — created at prefill, reused across all of its
    /// decode steps, dropped with the batch.
    pub fn drain(&mut self) -> Result<(Vec<Response>, ThroughputReport)> {
        let t0 = Instant::now();
        let mut responses = Vec::new();
        let mut total_tokens = 0usize;
        let mut decode_stats = DecodeStats::default();
        let mut sched_stats: Option<SchedStats> = None;
        while let Some((_bucket, reqs)) = self.batcher.next_batch() {
            let prompts: Vec<String> = reqs.iter().map(|r| r.prompt.clone()).collect();
            let (gens, stats) = self.backend.decode_with_stats(&prompts, self.max_new)?;
            decode_stats.absorb(&stats);
            if let Some(s) = self.backend.take_sched_stats() {
                match sched_stats.as_mut() {
                    Some(acc) => acc.absorb(&s),
                    None => sched_stats = Some(s),
                }
            }
            if gens.len() != reqs.len() {
                bail!("backend returned {} generations for {} requests", gens.len(), reqs.len());
            }
            let now = Instant::now();
            for (req, gen) in reqs.into_iter().zip(gens) {
                total_tokens += gen.tokens;
                responses.push(Response {
                    id: req.id,
                    latency_secs: now.duration_since(req.arrival).as_secs_f64(),
                    tokens_decoded: gen.tokens,
                    text: gen.text,
                });
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = ThroughputReport::from_responses(&responses, total_tokens, wall)
            .with_decode(decode_stats)
            .with_sched_opt(sched_stats)
            .with_gemm_kernel(self.backend.gemm_kernel());
        Ok((responses, report))
    }
}

/// Fire-and-drain convenience used by benches: serve `prompts` through the
/// backend `opts` selects and report. `rt` may be `None` for the native
/// backend — serving a merged checkpoint needs no artifacts at all.
pub fn serve_batch(
    rt: Option<&Runtime>,
    cfg: &ModelConfig,
    store: &ParamStore,
    opts: &ServeOptions,
    prompts: &[String],
) -> Result<ThroughputReport> {
    let mut server = Server::from_options(rt, cfg, store, opts)?;
    for p in prompts {
        server.enqueue(p.clone());
    }
    let (_, report) = server.drain()?;
    Ok(report)
}

/// Open-loop scheduled serving: requests from a timed workload (e.g.
/// [`crate::sched::generate_load`]'s Poisson arrivals) are submitted to a
/// continuous-batching [`Scheduler`] as their arrival times pass, and the
/// step loop runs until everything drains. This is the serving shape the
/// scheduler exists for — admission happens *during* decoding, so a
/// request arriving mid-batch starts prefilling at the next iteration
/// instead of waiting for the batch to finish.
///
/// Native backend only, scheduler required (`opts.sched` must be Some;
/// the scheduler decodes KV-cached, so `decode = recompute` is refused —
/// the same rules `Server::from_options` enforces). Per-request `max_new`
/// comes from the workload; `opts.max_new` is ignored here. Returns
/// per-request responses plus the aggregate report carrying the
/// scheduler's measurements.
///
/// All per-request timing (latency, TTFT, queue wait) is measured from
/// the request's **nominal arrival time**, not from the submit call: the
/// driver loop can only submit between decode steps, and silently
/// excluding that lag would flatter exactly the overloaded regime the
/// open loop exists to measure.
pub fn serve_open_loop(
    cfg: &ModelConfig,
    store: &ParamStore,
    opts: &ServeOptions,
    load: &[LoadRequest],
) -> Result<(Vec<SchedResponse>, ThroughputReport)> {
    if opts.backend != Backend::Native {
        bail!("open-loop scheduled serving runs on the native backend only");
    }
    if opts.decode == DecodeMode::Recompute {
        bail!("the scheduler decodes KV-cached; drop decode=recompute");
    }
    let Some(sched_cfg) = opts.sched.clone() else {
        bail!("open-loop serving needs a scheduler config (ServeOptions::scheduled)");
    };
    let mut engine = backend::build_engine(cfg, store, opts.path, opts.n_bits, opts.gemm_kernel)?;
    if !opts.adapters.is_empty() {
        opts.adapters.register_all(&mut engine, opts.omega_frac)?;
    }
    let engine = engine;
    let mut sched = Scheduler::new(&engine, &SchedOptions::from_config(&sched_cfg))?;
    // recorder constructed before any submit so every span lands at a
    // non-negative trace offset; we keep a handle, the scheduler gets a
    // boxed clone of the same buffer
    let trace = opts.trace_out.as_ref().map(|_| crate::obs::RecordingTracer::new());
    if let Some(rec) = &trace {
        sched = sched.with_tracer(Box::new(rec.clone()));
    }
    let profiler = opts.profile_out.as_ref().map(|_| {
        let p = crate::obs::Profiler::new();
        // share the tracer's recording (and so its clock) when both are
        // on: the engine spans nest inside the forward spans by
        // construction
        match &trace {
            Some(rec) => p.with_sink(rec.clone()),
            None => p,
        }
    });
    if let Some(p) = &profiler {
        sched = sched.with_profiler(p.clone());
    }

    let mut order: Vec<&LoadRequest> = load.iter().collect();
    order.sort_by(|a, b| a.arrival_secs.partial_cmp(&b.arrival_secs).unwrap());
    let t0 = Instant::now();
    let mut next = 0usize;
    // seconds between a request's nominal arrival and its actual submit
    // (the driver only runs between steps) — folded back into the
    // response timings below so clocks start at arrival
    let mut submit_lag: HashMap<u64, f64> = HashMap::new();
    let mut responses: Vec<SchedResponse> = Vec::new();
    while next < order.len() || !sched.is_idle() {
        // open loop: everything whose arrival time has passed gets
        // submitted, whatever the batch is currently doing
        let elapsed = t0.elapsed().as_secs_f64();
        while next < order.len() && order[next].arrival_secs <= elapsed {
            let r = &order[next];
            let mut spec = RequestSpec::new(r.prompt.as_str(), r.max_new)
                .adapter(r.adapter)
                .priority(r.priority);
            spec.deadline_ms = r.deadline_ms;
            let id = sched.submit(spec)?;
            submit_lag.insert(id, (elapsed - order[next].arrival_secs).max(0.0));
            next += 1;
        }
        if sched.is_idle() {
            // nothing in flight: sleep (briefly) toward the next arrival
            // instead of spinning the step loop empty
            if next < order.len() {
                let wait = order[next].arrival_secs - t0.elapsed().as_secs_f64();
                if wait > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(wait.min(0.02)));
                }
            }
            continue;
        }
        sched.step()?;
        responses.extend(sched.take_finished());
    }
    responses.extend(sched.take_finished());
    for r in &mut responses {
        let lag = submit_lag.get(&r.id).copied().unwrap_or(0.0);
        r.latency_secs += lag;
        r.queue_wait_secs += lag;
        if let Some(t) = r.ttft_secs.as_mut() {
            *t += lag;
        }
    }

    let wall = t0.elapsed().as_secs_f64();
    let tokens: usize = responses.iter().map(|r| r.tokens).sum();
    let shim: Vec<Response> = responses
        .iter()
        .map(|r| Response {
            id: r.id,
            text: r.text.clone(),
            latency_secs: r.latency_secs,
            tokens_decoded: r.tokens,
        })
        .collect();
    // per-request histograms rebuilt on the arrival clock; step-level
    // ones (queue depth, occupancy, inter-token) keep the scheduler's
    let mut stats = sched.sched_stats();
    stats.ttft_ms = Histogram::default();
    stats.queue_wait_ms = Histogram::default();
    for r in &responses {
        stats.queue_wait_ms.record(1e3 * r.queue_wait_secs);
        if let Some(t) = r.ttft_secs {
            stats.ttft_ms.record(1e3 * t);
        }
    }
    if let (Some(path), Some(rec)) = (&opts.trace_out, &trace) {
        crate::obs::write_chrome_trace(path, rec)?;
        log::info!("serving trace written to {}", path.display());
    }
    if let (Some(path), Some(p)) = (&opts.profile_out, &profiler) {
        let mut reg = crate::obs::MetricsRegistry::new();
        reg.set_info("gemm_kernel", engine.gemm_kernel_label());
        p.fill_registry(&mut reg);
        reg.write(path)?;
        log::info!("engine profile written to {}", path.display());
    }
    let report = ThroughputReport::from_responses(&shim, tokens, wall)
        .with_decode(sched.decode_stats())
        .with_sched(stats)
        .with_gemm_kernel(Some(engine.gemm_kernel_label()));
    Ok((responses, report))
}

/// Async wrapper: run the server on a worker thread, feeding it through a
/// channel (demonstrates the decoupled producer/consumer deployment shape).
pub fn serve_channel(
    rt: Option<&Runtime>,
    cfg: &ModelConfig,
    store: &ParamStore,
    opts: &ServeOptions,
    rx: mpsc::Receiver<String>,
) -> Result<(Vec<Response>, ThroughputReport)> {
    let mut server = Server::from_options(rt, cfg, store, opts)?;
    while let Ok(prompt) = rx.recv() {
        server.enqueue(prompt);
    }
    server.drain()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::model;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn tiny_store() -> (ModelConfig, ParamStore) {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(11);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        (cfg, store)
    }

    #[test]
    fn native_server_end_to_end_arbitrary_batch() {
        let (cfg, store) = tiny_store();
        // 7 requests: not a bucket size any artifact set would compile
        let opts = ServeOptions::new(ServePath::Merged, 3).backend(Backend::Native);
        let prompts: Vec<String> = (0..7).map(|i| format!("{i} + 2 =")).collect();
        let report = serve_batch(None, &cfg, &store, &opts, &prompts).unwrap();
        assert_eq!(report.requests, 7);
        // generated-token accounting: bounded by requests × max_new
        assert!(report.tokens <= 7 * 3);
        assert!(report.wall_secs > 0.0);
    }

    #[test]
    fn decode_modes_serve_identically_with_honest_accounting() {
        let (cfg, store) = tiny_store();
        let prompts: Vec<String> = (0..5).map(|i| format!("{i} + 4 =")).collect();
        let cached = ServeOptions::new(ServePath::Merged, 4).backend(Backend::Native);
        let recomp = ServeOptions::new(ServePath::Merged, 4)
            .backend(Backend::Native)
            .decode_mode(DecodeMode::Recompute);
        let rep_c = serve_batch(None, &cfg, &store, &cached, &prompts).unwrap();
        let rep_r = serve_batch(None, &cfg, &store, &recomp, &prompts).unwrap();
        assert_eq!(rep_c.tokens, rep_r.tokens, "decode modes generated different tokens");
        // both report what they fed; the cached path never feeds more, and
        // feeds strictly less whenever decoding went past the first step
        assert!(rep_c.decode.forwards > 0 && rep_r.decode.forwards > 0);
        assert!(rep_c.decode.forwarded_positions <= rep_r.decode.forwarded_positions);
        if rep_r.decode.forwards > 1 {
            assert!(rep_c.decode.forwarded_positions < rep_r.decode.forwarded_positions);
        }
    }

    #[test]
    fn scheduled_one_shot_serves_identically_to_native() {
        let (cfg, store) = tiny_store();
        let prompts: Vec<String> = (0..6).map(|i| format!("{i} + 3 =")).collect();
        let plain = ServeOptions::new(ServePath::Merged, 4).backend(Backend::Native);
        let sched = ServeOptions::new(ServePath::Merged, 4)
            .backend(Backend::Native)
            .scheduled(SchedConfig::default());
        let rep_p = serve_batch(None, &cfg, &store, &plain, &prompts).unwrap();
        let rep_s = serve_batch(None, &cfg, &store, &sched, &prompts).unwrap();
        assert_eq!(rep_p.tokens, rep_s.tokens, "scheduling changed the generations");
        assert_eq!(rep_p.requests, rep_s.requests);
        // only the scheduled drain carries scheduler measurements
        assert!(rep_s.sched.is_some(), "scheduled drain lost its measurements");
        assert!(rep_p.sched.is_none());
        assert_eq!(rep_s.sched.as_ref().unwrap().queue_wait_ms.len(), 6);
    }

    #[test]
    fn reports_surface_the_gemm_kernel() {
        let (cfg, store) = tiny_store();
        let prompts: Vec<String> = (0..2).map(|i| format!("{i} + 1 =")).collect();
        let auto = ServeOptions::new(ServePath::Merged, 2).backend(Backend::Native);
        let scalar = ServeOptions::new(ServePath::Merged, 2)
            .backend(Backend::Native)
            .kernel(GemmKernel::Scalar);
        let rep_a = serve_batch(None, &cfg, &store, &auto, &prompts).unwrap();
        let rep_s = serve_batch(None, &cfg, &store, &scalar, &prompts).unwrap();
        assert_eq!(rep_s.gemm_kernel, Some("scalar"));
        // auto resolves host-dependently; it must report *something*
        assert!(rep_a.gemm_kernel.is_some());
        // and the kernels cannot disagree on what they generate
        assert_eq!(rep_a.tokens, rep_s.tokens);
    }

    #[test]
    fn sched_on_pjrt_or_recompute_fails_loud() {
        let (cfg, store) = tiny_store();
        let on_pjrt = ServeOptions::new(ServePath::Merged, 2).scheduled(SchedConfig::default());
        assert!(serve_batch(None, &cfg, &store, &on_pjrt, &["1 + 1 =".into()]).is_err());
        let on_recompute = ServeOptions::new(ServePath::Merged, 2)
            .backend(Backend::Native)
            .decode_mode(DecodeMode::Recompute)
            .scheduled(SchedConfig::default());
        assert!(serve_batch(None, &cfg, &store, &on_recompute, &["1 + 1 =".into()]).is_err());
    }

    #[test]
    fn open_loop_serves_a_poisson_workload() {
        let (cfg, store) = tiny_store();
        // a fast workload so the test doesn't sleep its way through: 8
        // requests arriving within ~2 ms of each other on average
        let spec = crate::sched::LoadSpec {
            n_requests: 8,
            rate_per_sec: 500.0,
            seed: 3,
            task: "arith".into(),
            max_new_mix: vec![2, 5],
        };
        let load = crate::sched::generate_load(&spec).unwrap();
        let opts = ServeOptions::new(ServePath::Merged, 4)
            .backend(Backend::Native)
            .scheduled(SchedConfig { max_batch: 3, ..SchedConfig::default() });
        let (responses, report) = serve_open_loop(&cfg, &store, &opts, &load).unwrap();
        assert_eq!(responses.len(), 8);
        assert_eq!(report.requests, 8);
        assert!(report.tokens <= 8 * 5);
        let sched = report.sched.as_ref().unwrap();
        assert!(sched.steps > 0);
        // every request was admitted exactly once
        assert_eq!(sched.queue_wait_ms.len(), 8);
        // open-loop enforces the same rules as from_options: native
        // backend only, scheduler config required, no recompute
        let bad = ServeOptions::new(ServePath::Merged, 4);
        assert!(serve_open_loop(&cfg, &store, &bad, &load).is_err());
        let no_sched = ServeOptions::new(ServePath::Merged, 4).backend(Backend::Native);
        assert!(serve_open_loop(&cfg, &store, &no_sched, &load).is_err());
        let recompute = ServeOptions::new(ServePath::Merged, 4)
            .backend(Backend::Native)
            .decode_mode(DecodeMode::Recompute)
            .scheduled(SchedConfig::default());
        assert!(serve_open_loop(&cfg, &store, &recompute, &load).is_err());
    }

    #[test]
    fn pjrt_options_without_runtime_fail_loud() {
        let (cfg, store) = tiny_store();
        let opts = ServeOptions::new(ServePath::Merged, 2);
        assert!(serve_batch(None, &cfg, &store, &opts, &["1 + 1 =".into()]).is_err());
    }

    #[test]
    fn native_serve_channel_drains() {
        let (cfg, store) = tiny_store();
        let opts = ServeOptions::new(ServePath::Merged, 2).backend(Backend::Native);
        let (tx, rx) = mpsc::channel();
        for i in 0..4 {
            tx.send(format!("{i} + 0 =")).unwrap();
        }
        drop(tx);
        let (responses, report) = serve_channel(None, &cfg, &store, &opts, rx).unwrap();
        assert_eq!(responses.len(), 4);
        assert_eq!(report.requests, 4);
        // FIFO ids survive the drain
        let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
    }
}
