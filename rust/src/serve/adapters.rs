//! Multi-adapter serving registry — S-LoRA's deployment shape on ternary
//! adapters: one packed quantized base stays resident, N named ternary
//! adapter sets register against it, and every request is tagged with the
//! adapter it wants. The continuous-batching scheduler then mixes
//! requests for different adapters in the *same* decode step; the engine
//! applies each adapter's [`crate::engine::TernaryDelta`] in-kernel on
//! the packed grid, so the mixed batch is bit-identical, token for token,
//! to serving each adapter's individually merged checkpoint alone
//! (`tests/adapters.rs` pins it).
//!
//! A registry is a named list of adapter *sources*. Each source is either
//! a checkpoint path (a [`crate::model::checkpoint`] file carrying the
//! `ta_{slot}_a/_b` layer-stacked tensors every LoTA training run saves)
//! or the `synthetic:<seed>` sentinel, which fabricates a deterministic
//! random ternary adapter set in-process — the demo/bench/test form that
//! needs no training artifacts on disk.
//!
//! Registration order defines adapter ids: the first registered set is
//! id 1, the second id 2, … (id 0 is always the bare base). CLI order is
//! the `--adapter` list order; TOML order is the alphabetical key order
//! of the `[adapters]` table (the subset parser stores keys sorted).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::engine::Engine;
use crate::model::{checkpoint, ParamStore};
use crate::tensor::{Rng, Tensor};

/// Prefix marking an in-process fabricated adapter source: the remainder
/// is the u64 RNG seed, e.g. `synthetic:41`.
pub const SYNTHETIC_PREFIX: &str = "synthetic:";

/// One named adapter and where its tensors come from.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AdapterSpec {
    /// registry name — request tags, serving stats, and metric labels all
    /// key on it ("base" and "" are reserved for id 0)
    pub name: String,
    /// checkpoint path, or `synthetic:<seed>`
    pub source: String,
}

/// An ordered set of [`AdapterSpec`]s: what `lota serve` registers on the
/// engine before taking requests. Order is id order (index + 1).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct AdapterRegistry {
    specs: Vec<AdapterSpec>,
}

impl AdapterRegistry {
    pub fn new() -> AdapterRegistry {
        AdapterRegistry::default()
    }

    /// Append one adapter. Names must be unique and not reserved.
    pub fn push(&mut self, name: &str, source: &str) -> Result<()> {
        if name.is_empty() || name == "base" {
            bail!("adapter name {name:?} is reserved for the bare base");
        }
        if self.specs.iter().any(|s| s.name == name) {
            bail!("adapter {name:?} listed twice");
        }
        if source.is_empty() {
            bail!("adapter {name:?} has an empty source");
        }
        self.specs.push(AdapterSpec { name: name.to_string(), source: source.to_string() });
        Ok(())
    }

    /// Build from `(name, source)` pairs — the shape
    /// [`crate::config::ExperimentConfig`] parses out of an `[adapters]`
    /// TOML table.
    pub fn from_pairs(pairs: &[(String, String)]) -> Result<AdapterRegistry> {
        let mut reg = AdapterRegistry::new();
        for (name, source) in pairs {
            reg.push(name, source)?;
        }
        Ok(reg)
    }

    /// Parse the `--adapter` CLI form: `name=source[,name=source...]`.
    pub fn parse_cli(arg: &str) -> Result<AdapterRegistry> {
        let mut reg = AdapterRegistry::new();
        for part in arg.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((name, source)) = part.split_once('=') else {
                bail!("--adapter entry {part:?} is not name=source");
            };
            reg.push(name.trim(), source.trim())?;
        }
        if reg.is_empty() {
            bail!("--adapter {arg:?} names no adapters");
        }
        Ok(reg)
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn specs(&self) -> &[AdapterSpec] {
        &self.specs
    }

    /// Register every adapter on `engine`, in order (so spec index i
    /// becomes adapter id i + 1). `omega_frac` is the ternarization
    /// threshold fraction the adapters were trained with; the merge uses
    /// `omega = omega_frac · rank`, and a wrong value changes which grid
    /// moves survive — it must match training.
    pub fn register_all(&self, engine: &mut Engine, omega_frac: f32) -> Result<()> {
        if !(0.0..1.0).contains(&omega_frac) || omega_frac <= 0.0 {
            bail!("omega_frac must be in (0, 1), got {omega_frac}");
        }
        let cfg = engine.config().clone();
        let omega = omega_frac * cfg.rank as f32;
        for spec in &self.specs {
            let store = load_adapter_store(spec, &cfg)
                .with_context(|| format!("adapter {:?} (source {:?})", spec.name, spec.source))?;
            let id = engine.register_adapter(&spec.name, &store, omega)?;
            log::info!(
                "registered adapter {:?} as id {id} ({} delta bytes resident)",
                spec.name,
                engine.adapter_bytes()
            );
        }
        Ok(())
    }
}

/// Materialize one adapter's `ta_{slot}_a/_b` tensors: load the
/// checkpoint, or fabricate a deterministic random ternary set for
/// `synthetic:<seed>` sources.
pub fn load_adapter_store(spec: &AdapterSpec, cfg: &ModelConfig) -> Result<ParamStore> {
    if let Some(seed_str) = spec.source.strip_prefix(SYNTHETIC_PREFIX) {
        let seed: u64 = seed_str
            .trim()
            .parse()
            .with_context(|| format!("synthetic adapter seed {seed_str:?} is not a u64"))?;
        return Ok(synthetic_adapter_store(cfg, seed));
    }
    let store = checkpoint::load(Path::new(&spec.source))?;
    // fail here, with the adapter's name attached, rather than deep in
    // the per-layer merge loop
    for (slot, _, _) in cfg.slots() {
        for suffix in ["a", "b"] {
            let name = format!("ta_{slot}_{suffix}");
            if !store.contains(&name) {
                bail!(
                    "checkpoint {:?} has no {name} tensor — not a LoTA adapter checkpoint",
                    spec.source
                );
            }
        }
    }
    Ok(store)
}

/// A deterministic random ternary adapter set for `cfg`: every
/// `ta_{slot}_a/_b` entry filled with values drawn uniformly from
/// {−1, 0, +1}. Nontrivial by construction (unlike the B = 0 training
/// init, which merges to the identity), so synthetic adapters visibly
/// change generations — what the parity tests and demos need.
pub fn synthetic_adapter_store(cfg: &ModelConfig, seed: u64) -> ParamStore {
    let mut rng = Rng::new(seed);
    let mut store = ParamStore::new();
    let l = cfg.n_layers;
    let mut ternary_vec = |n: usize| -> Vec<f32> {
        (0..n).map(|_| (rng.below(3) as f32) - 1.0).collect()
    };
    for (slot, din, dout) in cfg.slots() {
        let a = Tensor::new(&[l, din, cfg.rank], ternary_vec(l * din * cfg.rank));
        let b = Tensor::new(&[l, cfg.rank, dout], ternary_vec(l * cfg.rank * dout));
        store.insert(&format!("ta_{slot}_a"), a);
        store.insert(&format!("ta_{slot}_b"), b);
    }
    store
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::model;
    use crate::quant::rtn_quantize;

    fn tiny_engine(seed: u64) -> (ModelConfig, Engine) {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        let engine = Engine::from_store(&cfg, &store, 4).unwrap();
        (cfg, engine)
    }

    #[test]
    fn cli_parsing_accepts_lists_and_rejects_garbage() {
        let reg = AdapterRegistry::parse_cli("fr=synthetic:3, de = synthetic:4").unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.specs()[0].name, "fr");
        assert_eq!(reg.specs()[0].source, "synthetic:3");
        assert_eq!(reg.specs()[1].name, "de");
        assert!(AdapterRegistry::parse_cli("").is_err());
        assert!(AdapterRegistry::parse_cli("no-equals-sign").is_err());
        assert!(AdapterRegistry::parse_cli("base=synthetic:1").is_err());
        assert!(AdapterRegistry::parse_cli("x=a.ckpt,x=b.ckpt").is_err());
        assert!(AdapterRegistry::parse_cli("x=").is_err());
    }

    #[test]
    fn pairs_build_in_order() {
        let pairs = vec![
            ("alpha".to_string(), "synthetic:1".to_string()),
            ("beta".to_string(), "synthetic:2".to_string()),
        ];
        let reg = AdapterRegistry::from_pairs(&pairs).unwrap();
        assert_eq!(reg.specs()[0].name, "alpha");
        assert_eq!(reg.specs()[1].name, "beta");
        // duplicates rejected through the same gate as the CLI
        let dup = vec![("a".to_string(), "x".to_string()), ("a".to_string(), "y".to_string())];
        assert!(AdapterRegistry::from_pairs(&dup).is_err());
    }

    #[test]
    fn synthetic_stores_are_ternary_deterministic_and_seed_sensitive() {
        let cfg = preset("tiny").unwrap();
        let s1 = synthetic_adapter_store(&cfg, 9);
        let s2 = synthetic_adapter_store(&cfg, 9);
        let s3 = synthetic_adapter_store(&cfg, 10);
        let a = s1.get("ta_wq_a").unwrap();
        assert_eq!(a.shape(), &[cfg.n_layers, cfg.d_model, cfg.rank]);
        assert!(a.data().iter().all(|v| *v == -1.0 || *v == 0.0 || *v == 1.0));
        assert_eq!(a, s2.get("ta_wq_a").unwrap());
        assert_ne!(a, s3.get("ta_wq_a").unwrap());
        let b = s1.get("ta_w_down_b").unwrap();
        assert_eq!(b.shape(), &[cfg.n_layers, cfg.rank, cfg.d_model]);
        // nontrivial: a B of all zeros would merge to the identity
        assert!(b.data().iter().any(|v| *v != 0.0));
    }

    #[test]
    fn register_all_assigns_ids_in_spec_order() {
        let (_cfg, mut engine) = tiny_engine(21);
        let reg = AdapterRegistry::parse_cli("fr=synthetic:5,de=synthetic:6").unwrap();
        reg.register_all(&mut engine, 0.75).unwrap();
        assert_eq!(engine.adapter_count(), 2);
        assert_eq!(engine.adapter_label(1), "fr");
        assert_eq!(engine.adapter_label(2), "de");
        assert!(engine.adapter_bytes() > 0);
        // re-registering the same names fails loudly
        assert!(reg.register_all(&mut engine, 0.75).is_err());
        // omega_frac outside (0, 1) is refused before any work
        let (_cfg2, mut engine2) = tiny_engine(22);
        assert!(reg.register_all(&mut engine2, 0.0).is_err());
        assert!(reg.register_all(&mut engine2, 1.0).is_err());
    }

    #[test]
    fn checkpoint_sources_roundtrip_and_bad_sources_fail_loud() {
        let (cfg, mut engine) = tiny_engine(23);
        let store = synthetic_adapter_store(&cfg, 7);
        let mut path = std::env::temp_dir();
        path.push(format!("lota_adapter_reg_test_{}.ckpt", std::process::id()));
        checkpoint::save(&store, &path, None).unwrap();
        let mut reg = AdapterRegistry::new();
        reg.push("disk", path.to_str().unwrap()).unwrap();
        reg.register_all(&mut engine, 0.75).unwrap();
        assert_eq!(engine.adapter_label(1), "disk");
        std::fs::remove_file(&path).ok();
        // missing file and malformed seeds surface as errors, not panics
        let mut missing = AdapterRegistry::new();
        missing.push("gone", "/nonexistent/adapter.ckpt").unwrap();
        assert!(missing.register_all(&mut engine, 0.75).is_err());
        let mut bad_seed = AdapterRegistry::new();
        bad_seed.push("bad", "synthetic:notanumber").unwrap();
        assert!(bad_seed.register_all(&mut engine, 0.75).is_err());
        // a non-adapter checkpoint is named in the error path, too
        let mut base_path = std::env::temp_dir();
        base_path.push(format!("lota_adapter_reg_base_{}.ckpt", std::process::id()));
        let mut rng = Rng::new(1);
        let fp = model::init_fp(&cfg, &mut rng);
        checkpoint::save(&fp, &base_path, None).unwrap();
        let mut not_adapter = AdapterRegistry::new();
        not_adapter.push("fp", base_path.to_str().unwrap()).unwrap();
        assert!(not_adapter.register_all(&mut engine, 0.75).is_err());
        std::fs::remove_file(&base_path).ok();
    }
}
