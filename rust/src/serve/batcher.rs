//! Dynamic batching with bucket routing.
//!
//! The forward artifacts are compiled at fixed batch sizes (the "buckets",
//! e.g. 1/8/32). The batcher accumulates requests and, on each drain step,
//! picks the *largest bucket it can fill* — falling back to the smallest
//! bucket that covers the stragglers (padding rows are tolerated but
//! wasted, so the policy prefers exact fills). Properties verified by the
//! hand-rolled property tests below:
//!
//! 1. every request is scheduled exactly once, in FIFO order;
//! 2. a batch never exceeds its bucket capacity;
//! 3. padding waste is bounded by the smallest bucket that fits the tail.

use std::time::Instant;

use anyhow::{bail, Result};

/// One queued generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub arrival: Instant,
}

/// The available batch buckets (sorted ascending), or the adaptive policy
/// for backends that run any batch size natively.
#[derive(Clone, Debug)]
pub struct BucketPolicy {
    buckets: Vec<usize>,
    adaptive: bool,
    /// adaptive-mode ceiling per drain step (`None` = whole queue) — the
    /// KV-cached native backend bounds per-batch cache memory with this
    cap: Option<usize>,
}

impl BucketPolicy {
    pub fn new(mut buckets: Vec<usize>) -> Result<BucketPolicy> {
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() || buckets[0] == 0 {
            bail!("bucket list must be non-empty with positive sizes");
        }
        Ok(BucketPolicy { buckets, adaptive: false, cap: None })
    }

    /// No fixed shapes: every drain step takes the whole queue as one
    /// batch (the native engine's mode — no padding, no re-queue).
    pub fn adaptive() -> BucketPolicy {
        BucketPolicy { buckets: Vec::new(), adaptive: true, cap: None }
    }

    /// Adaptive, but at most `cap` requests per drain step. The KV-cached
    /// decode path allocates per-request K/V buffers for the whole batch
    /// up front, so an unbounded queue drain would allocate unbounded
    /// cache memory; the cap turns one huge batch into several full ones.
    pub fn adaptive_capped(cap: usize) -> BucketPolicy {
        BucketPolicy { buckets: Vec::new(), adaptive: true, cap: Some(cap.max(1)) }
    }

    pub fn is_adaptive(&self) -> bool {
        self.adaptive
    }

    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Bucket to use for `queued` pending requests.
    ///
    /// Cost model: a bucket-`b` forward costs ∝ `b` regardless of fill, so
    /// padding wastes compute. Policy:
    /// 1. queue ≥ largest bucket → run the largest (max throughput);
    /// 2. else if some bucket covers the whole queue with ≤ 2× padding
    ///    overhead → run it (one invocation, bounded waste);
    /// 3. else run the largest *fully-filled* bucket and let the remainder
    ///    re-enter the policy (no waste now, waste bounded at the tail).
    pub fn pick(&self, queued: usize) -> Option<usize> {
        if queued == 0 {
            return None;
        }
        if self.adaptive {
            return Some(match self.cap {
                Some(cap) => queued.min(cap),
                None => queued,
            });
        }
        let largest = *self.buckets.last().unwrap();
        if queued >= largest {
            return Some(largest);
        }
        if let Some(b) = self
            .buckets
            .iter()
            .find(|b| **b >= queued && **b <= 2 * queued)
        {
            return Some(*b);
        }
        self.buckets
            .iter()
            .rev()
            .find(|b| queued >= **b)
            .or(self.buckets.first())
            .copied()
    }
}

/// FIFO queue + bucket policy.
#[derive(Debug)]
pub struct DynamicBatcher {
    policy: BucketPolicy,
    queue: std::collections::VecDeque<Request>,
    next_id: u64,
}

impl DynamicBatcher {
    pub fn new(policy: BucketPolicy) -> DynamicBatcher {
        DynamicBatcher { policy, queue: Default::default(), next_id: 0 }
    }

    pub fn push(&mut self, prompt: String) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(Request { id, prompt, arrival: Instant::now() });
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Pop the next batch: (bucket size, requests ≤ bucket).
    pub fn next_batch(&mut self) -> Option<(usize, Vec<Request>)> {
        let bucket = self.policy.pick(self.queue.len())?;
        let take = bucket.min(self.queue.len());
        let reqs: Vec<Request> = self.queue.drain(..take).collect();
        Some((bucket, reqs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn policy_prefers_exact_fills() {
        let p = BucketPolicy::new(vec![1, 8, 32]).unwrap();
        assert_eq!(p.pick(0), None);
        assert_eq!(p.pick(1), Some(1));
        assert_eq!(p.pick(7), Some(8)); // one invocation, ≤2× padding
        assert_eq!(p.pick(8), Some(8));
        assert_eq!(p.pick(9), Some(8)); // 32 would waste >2×: drain 8 first
        assert_eq!(p.pick(17), Some(32)); // 32 ≤ 2×17: one invocation
        assert_eq!(p.pick(40), Some(32)); // fill the big bucket first
        assert_eq!(p.pick(100), Some(32));
    }

    #[test]
    fn adaptive_policy_takes_the_whole_queue() {
        let p = BucketPolicy::adaptive();
        assert!(p.is_adaptive());
        assert_eq!(p.pick(0), None);
        for q in [1usize, 7, 33, 1000] {
            assert_eq!(p.pick(q), Some(q));
        }
        // one drain step, no padding, FIFO preserved
        let mut b = DynamicBatcher::new(BucketPolicy::adaptive());
        for i in 0..9 {
            b.push(format!("p{i}"));
        }
        let (bucket, reqs) = b.next_batch().unwrap();
        assert_eq!(bucket, 9);
        assert_eq!(reqs.len(), 9);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn capped_adaptive_bounds_each_drain_step() {
        let p = BucketPolicy::adaptive_capped(4);
        assert!(p.is_adaptive());
        assert_eq!(p.pick(0), None);
        assert_eq!(p.pick(3), Some(3));
        assert_eq!(p.pick(4), Some(4));
        assert_eq!(p.pick(1000), Some(4));
        // zero caps are nonsense — clamp to 1 so the queue still drains
        assert_eq!(BucketPolicy::adaptive_capped(0).pick(7), Some(1));

        // every request still scheduled exactly once, FIFO, ≤ cap per batch
        let mut b = DynamicBatcher::new(BucketPolicy::adaptive_capped(4));
        for i in 0..11 {
            b.push(format!("p{i}"));
        }
        let mut seen = Vec::new();
        while let Some((bucket, reqs)) = b.next_batch() {
            assert!(bucket <= 4);
            assert!(reqs.len() <= 4);
            for r in reqs {
                seen.push(r.id);
            }
        }
        assert_eq!(seen, (0..11).collect::<Vec<u64>>());
    }

    #[test]
    fn policy_rejects_empty_or_zero() {
        assert!(BucketPolicy::new(vec![]).is_err());
        assert!(BucketPolicy::new(vec![0, 4]).is_err());
    }

    #[test]
    fn batcher_is_fifo_and_complete() {
        let mut b = DynamicBatcher::new(BucketPolicy::new(vec![1, 4]).unwrap());
        for i in 0..10 {
            b.push(format!("p{i}"));
        }
        let mut seen = Vec::new();
        while let Some((bucket, reqs)) = b.next_batch() {
            assert!(reqs.len() <= bucket);
            for r in reqs {
                seen.push(r.id);
            }
        }
        assert_eq!(seen, (0..10).collect::<Vec<u64>>());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn property_all_scheduled_once_never_overflow() {
        // randomized property sweep over bucket sets and arrival counts
        let mut rng = Rng::new(2024);
        for _ in 0..100 {
            let mut buckets = vec![1usize];
            if rng.below(2) == 0 {
                buckets.push(rng.range(2, 9));
            }
            if rng.below(2) == 0 {
                buckets.push(rng.range(9, 40));
            }
            let n = rng.below(100);
            let mut b = DynamicBatcher::new(BucketPolicy::new(buckets.clone()).unwrap());
            for i in 0..n {
                b.push(format!("{i}"));
            }
            let mut total = 0;
            let mut wasted = 0;
            while let Some((bucket, reqs)) = b.next_batch() {
                assert!(reqs.len() <= bucket, "overflow: {} > {bucket}", reqs.len());
                wasted += bucket - reqs.len();
                total += reqs.len();
            }
            assert_eq!(total, n, "buckets {buckets:?}");
            // waste only on the final partial batch
            let max_waste = buckets.iter().copied().max().unwrap();
            assert!(wasted < max_waste, "wasted {wasted} with buckets {buckets:?}");
        }
    }
}
