//! Streaming HTTP front end for the scheduler worker — `lota serve
//! --listen <addr>`.
//!
//! The offline crate set has no HTTP stack, so this is a deliberately
//! small hand-rolled HTTP/1.1 server over `std::net`: enough protocol
//! for curl, python, and browsers to drive the async scheduler, and not
//! one line more. One accept thread hands each connection to a short-
//! lived handler thread holding a [`WorkerClient`] clone; all decode
//! compute stays on the single scheduler worker thread
//! ([`crate::sched::SchedWorker`]), so concurrent connections cost a
//! blocked thread each, never a second engine.
//!
//! Wire protocol (see `docs/serving.md` for the full reference):
//!
//! * `GET /healthz` → `200 ok` — liveness only, never touches the worker.
//! * `POST /generate` with JSON `{"prompt": "...", "max_new": 16,
//!   "adapter": 0, "priority": 0, "deadline_ms": 250}` (the last two
//!   optional — they default to class 0 / no deadline) →
//!   `text/event-stream`. The stream opens with a `start` event carrying
//!   the assigned request id, then one `token` event per generated token
//!   as the scheduler picks it, and closes with a `finish` event that is
//!   the full [`SchedResponse`] (reason — including `"shed"` for a
//!   deadline-dropped request — queue wait, TTFT, latency). Submit
//!   rejections are `400`. The two overload `503`s are distinct: a full
//!   bounded submit queue answers `{"error": ..., "retriable": true}`
//!   with a `Retry-After` header (back off and come back), a draining
//!   worker answers `{"error": ..., "retriable": false}` (this server is
//!   going away).
//! * `POST /cancel` with `{"id": N}` → `{"id": N, "cancelled": bool}`,
//!   false for unknown or already-finished ids (same contract as
//!   [`crate::sched::Scheduler::cancel`]).
//!
//! Event payloads are built by [`start_event_json`], [`token_event_json`]
//! and [`finish_event_json`] — public precisely so `tests/sched_worker.rs`
//! can pin the transport byte-for-byte against in-process
//! [`StreamEvent`] streams.
//!
//! Shutdown is the worker's drain protocol surfaced to the socket:
//! [`ListenServer::shutdown`] (SIGTERM/SIGINT in [`serve_listen`]) stops
//! accepting, joins the open connections — their requests finish
//! normally, streams included — then drains the worker and returns its
//! [`WorkerReport`].

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::{Backend, DecodeMode, Json, JsonWriter, ModelConfig};
use crate::model::ParamStore;
use crate::sched::{
    RequestSpec, SchedOptions, SchedResponse, SchedWorker, StreamEvent, SubmitError, WorkerClient,
    WorkerConfig,
};

use super::{backend, ServeOptions};

/// How long the accept loop sleeps between polls of a non-blocking
/// listener (also bounds shutdown latency).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Per-connection socket read timeout: a client that connects and never
/// sends a full request can delay shutdown by at most this long.
const READ_TIMEOUT: Duration = Duration::from_secs(10);

/// JSON payload of the stream-opening SSE event: the assigned request id,
/// so the client can `POST /cancel` mid-generation.
pub fn start_event_json(id: u64) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("event").str("start");
    w.key("id").num(id as f64);
    w.end_obj();
    w.finish()
}

/// JSON payload of one `token` SSE event. `piece` is the decoded text of
/// the token (the toy tokenizer is one char per token), so a client can
/// render the stream without a tokenizer of its own.
pub fn token_event_json(id: u64, token: u32) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("event").str("token");
    w.key("id").num(id as f64);
    w.key("token").num(token as f64);
    w.key("piece").str(&crate::data::tokenizer::decode(&[token]));
    w.end_obj();
    w.finish()
}

/// JSON payload of the final `finish` SSE event — the whole
/// [`SchedResponse`]. `ttft_secs` is omitted (not null) when nothing was
/// generated, matching the response struct's `Option`.
pub fn finish_event_json(resp: &SchedResponse) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("event").str("finish");
    w.key("id").num(resp.id as f64);
    w.key("adapter").num(resp.adapter as f64);
    w.key("text").str(&resp.text);
    w.key("tokens").num(resp.tokens as f64);
    w.key("reason").str(resp.reason.as_str());
    w.key("queue_wait_secs").num(resp.queue_wait_secs);
    if let Some(t) = resp.ttft_secs {
        w.key("ttft_secs").num(t);
    }
    w.key("latency_secs").num(resp.latency_secs);
    w.end_obj();
    w.finish()
}

/// A running async serving front end: scheduler worker + accept loop.
/// Tests drive it in-process (`start` → requests → `shutdown`); the CLI
/// wraps it in [`serve_listen`] with signal handling.
pub struct ListenServer {
    worker: Option<SchedWorker>,
    accept: Option<thread::JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
}

impl ListenServer {
    /// Build the engine, spawn the scheduler worker, bind `addr` (use
    /// port 0 to let the OS pick — [`ListenServer::local_addr`] reports
    /// the result), and start accepting.
    pub fn start(
        cfg: &ModelConfig,
        store: &ParamStore,
        opts: &ServeOptions,
        addr: &str,
    ) -> Result<ListenServer> {
        if opts.backend != Backend::Native {
            bail!("--listen serves through the scheduler, which runs on the native backend only");
        }
        if opts.decode == DecodeMode::Recompute {
            bail!("the scheduler decodes KV-cached; drop decode=recompute");
        }
        let Some(sched_cfg) = opts.sched.clone() else {
            bail!("--listen needs a scheduler config (--sched true or a [sched] table)");
        };
        let mut engine =
            backend::build_engine(cfg, store, opts.path, opts.n_bits, opts.gemm_kernel)?;
        if !opts.adapters.is_empty() {
            opts.adapters.register_all(&mut engine, opts.omega_frac)?;
        }
        let worker_cfg = WorkerConfig {
            trace_out: opts.trace_out.clone(),
            profile_out: opts.profile_out.clone(),
        };
        let worker =
            SchedWorker::spawn(engine, SchedOptions::from_config(&sched_cfg), worker_cfg)?;

        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("resolving the bound address")?;
        listener
            .set_nonblocking(true)
            .context("setting the listener non-blocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = stop.clone();
            let client = worker.client();
            thread::Builder::new()
                .name("lota-accept".to_string())
                .spawn(move || accept_loop(listener, client, stop))
                .context("spawning the accept thread")?
        };
        Ok(ListenServer { worker: Some(worker), accept: Some(accept), stop, addr: local })
    }

    /// The actually-bound address (resolves `:0` port requests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// A direct submit/cancel port bypassing HTTP — used by tests to
    /// compare in-process streams against the wire.
    pub fn client(&self) -> WorkerClient {
        self.worker.as_ref().expect("worker lives until shutdown").client()
    }

    /// Stop accepting, let open connections finish (their requests run to
    /// completion — streams deliver every token and the finish event),
    /// then drain the worker and return its report.
    pub fn shutdown(mut self) -> Result<crate::sched::WorkerReport> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            if accept.join().is_err() {
                bail!("the accept thread panicked");
            }
        }
        self.worker
            .take()
            .expect("shutdown consumes the only worker handle")
            .shutdown()
    }
}

impl Drop for ListenServer {
    fn drop(&mut self) {
        // best-effort cleanup when `shutdown` was skipped (e.g. a test
        // failed): stop the accept loop and drain the worker
        self.stop.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        // the worker's own Drop sends Shutdown and joins
        self.worker.take();
    }
}

fn accept_loop(listener: TcpListener, client: WorkerClient, stop: Arc<AtomicBool>) {
    let mut conns: Vec<thread::JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let client = client.clone();
                let handle = thread::Builder::new()
                    .name("lota-conn".to_string())
                    .spawn(move || {
                        if let Err(e) = handle_conn(stream, &client) {
                            log::debug!("connection {peer}: {e:#}");
                        }
                    });
                match handle {
                    Ok(h) => conns.push(h),
                    Err(e) => log::warn!("spawning a connection thread failed: {e}"),
                }
                // joining finished handlers keeps the vec from growing
                // with the total connection count on long runs
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => thread::sleep(ACCEPT_POLL),
            Err(e) => {
                log::warn!("accept failed: {e}");
                thread::sleep(ACCEPT_POLL);
            }
        }
    }
    // shutdown: requests already past accept complete normally (the
    // worker is still stepping until the drain that follows this join)
    for h in conns {
        let _ = h.join();
    }
}

/// Parse one HTTP/1.1 request: (method, path, body). Only what the three
/// routes need — no chunked encoding, no keep-alive (every response sends
/// `Connection: close`).
fn read_request(stream: &TcpStream) -> Result<(String, String, Vec<u8>)> {
    let mut reader = BufReader::new(stream.try_clone().context("cloning the stream handle")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("reading the request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or_default().to_string();
    let path = parts.next().unwrap_or_default().to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {line:?}");
    }
    let mut content_length = 0usize;
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).context("reading a header line")?;
        let header = header.trim_end();
        if header.is_empty() {
            break;
        }
        if let Some((name, value)) = header.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value.trim().parse().context("parsing Content-Length")?;
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).context("reading the request body")?;
    Ok((method, path, body))
}

fn write_response(stream: &mut TcpStream, status: &str, content_type: &str, body: &str) {
    write_response_headers(stream, status, content_type, &[], body);
}

/// [`write_response`] with extra response headers — each `(name, value)`
/// lands as its own `Name: value` line (the queue-full 503 carries
/// `Retry-After` this way).
fn write_response_headers(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    extra: &[(&str, String)],
    body: &str,
) {
    let extra: String = extra.iter().map(|(k, v)| format!("{k}: {v}\r\n")).collect();
    let _ = write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n{extra}Connection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.flush();
}

fn error_json(msg: &str) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("error").str(msg);
    w.end_obj();
    w.finish()
}

/// Overload-control error body: `retriable` tells the client whether
/// backing off and retrying *this* server can ever help (queue full:
/// yes; draining: no).
fn overload_json(msg: &str, retriable: bool) -> String {
    let mut w = JsonWriter::new();
    w.begin_obj();
    w.key("error").str(msg);
    w.key("retriable").bool(retriable);
    w.end_obj();
    w.finish()
}

fn handle_conn(mut stream: TcpStream, client: &WorkerClient) -> Result<()> {
    stream
        .set_read_timeout(Some(READ_TIMEOUT))
        .context("setting the read timeout")?;
    let (method, path, body) = read_request(&stream)?;
    match (method.as_str(), path.as_str()) {
        ("GET", "/healthz") => {
            write_response(&mut stream, "200 OK", "text/plain", "ok\n");
            Ok(())
        }
        ("POST", "/generate") => handle_generate(stream, client, &body),
        ("POST", "/cancel") => handle_cancel(stream, client, &body),
        _ => {
            write_response(
                &mut stream,
                "404 Not Found",
                "application/json",
                &error_json(&format!("no route {method} {path}")),
            );
            Ok(())
        }
    }
}

fn handle_generate(mut stream: TcpStream, client: &WorkerClient, body: &[u8]) -> Result<()> {
    let parsed: Result<RequestSpec> = (|| {
        let text = std::str::from_utf8(body).context("request body is not UTF-8")?;
        let json = Json::parse(text).context("parsing the request JSON")?;
        let prompt = json.get("prompt")?.as_str()?.to_string();
        let max_new = match json.opt("max_new") {
            Some(v) => v.as_usize()?,
            None => 16,
        };
        let mut spec = RequestSpec::new(prompt, max_new);
        if let Some(v) = json.opt("adapter") {
            spec = spec.adapter(v.as_usize()? as u32);
        }
        if let Some(v) = json.opt("priority") {
            let class = v.as_usize()?;
            if class > u8::MAX as usize {
                bail!("priority must fit a class index 0..=255 (got {class})");
            }
            spec = spec.priority(class as u8);
        }
        if let Some(v) = json.opt("deadline_ms") {
            spec = spec.deadline_ms(v.as_usize()? as u64);
        }
        Ok(spec)
    })();
    let spec = match parsed {
        Ok(spec) => spec,
        Err(e) => {
            write_response(
                &mut stream,
                "400 Bad Request",
                "application/json",
                &error_json(&format!("{e:#}")),
            );
            return Ok(());
        }
    };
    let (id, events) = match client.submit_streaming(spec) {
        Ok(sub) => sub,
        Err(e) => {
            // the typed refusal (if any) picks the wire shape: the two
            // overload 503s carry distinct bodies so clients can tell
            // "back off and retry" from "this server is going away"
            let msg = format!("{e:#}");
            match e.downcast_ref::<SubmitError>() {
                Some(SubmitError::QueueFull { retry_after_secs, .. }) => {
                    write_response_headers(
                        &mut stream,
                        "503 Service Unavailable",
                        "application/json",
                        &[("Retry-After", retry_after_secs.to_string())],
                        &overload_json(&msg, true),
                    );
                }
                Some(SubmitError::Draining) => {
                    write_response(
                        &mut stream,
                        "503 Service Unavailable",
                        "application/json",
                        &overload_json(&msg, false),
                    );
                }
                // a spec the scheduler refused — or a worker that is
                // gone entirely, which reads as draining to the client
                _ => {
                    let status = if msg.contains("shutting down") || msg.contains("gone") {
                        "503 Service Unavailable"
                    } else {
                        "400 Bad Request"
                    };
                    write_response(&mut stream, status, "application/json", &error_json(&msg));
                }
            }
            return Ok(());
        }
    };
    // SSE: close-delimited stream, one `data:` frame per event
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )
    .context("writing the stream header")?;
    write!(stream, "data: {}\n\n", start_event_json(id)).context("writing the start event")?;
    stream.flush().ok();
    // the loop ends when the worker sends Finish (router closes the
    // stream) or the worker goes away entirely (recv error)
    for event in events {
        let frame = match &event {
            StreamEvent::Token { id, token } => token_event_json(*id, *token),
            StreamEvent::Finish(resp) => finish_event_json(resp),
        };
        // a client that hung up mid-stream is not an error worth logging;
        // the scheduler finishes the request either way
        if write!(stream, "data: {frame}\n\n").is_err() {
            break;
        }
        stream.flush().ok();
        if matches!(event, StreamEvent::Finish(_)) {
            break;
        }
    }
    Ok(())
}

fn handle_cancel(mut stream: TcpStream, client: &WorkerClient, body: &[u8]) -> Result<()> {
    let id: Result<u64> = (|| {
        let text = std::str::from_utf8(body).context("request body is not UTF-8")?;
        let json = Json::parse(text).context("parsing the request JSON")?;
        Ok(json.get("id")?.as_usize()? as u64)
    })();
    let id = match id {
        Ok(id) => id,
        Err(e) => {
            write_response(
                &mut stream,
                "400 Bad Request",
                "application/json",
                &error_json(&format!("{e:#}")),
            );
            return Ok(());
        }
    };
    match client.cancel(id) {
        Ok(cancelled) => {
            let mut w = JsonWriter::new();
            w.begin_obj();
            w.key("id").num(id as f64);
            w.key("cancelled").bool(cancelled);
            w.end_obj();
            write_response(&mut stream, "200 OK", "application/json", &w.finish());
        }
        Err(e) => {
            write_response(
                &mut stream,
                "503 Service Unavailable",
                "application/json",
                &error_json(&format!("{e:#}")),
            );
        }
    }
    Ok(())
}

/// process-wide signal flag for the CLI entry (`lota serve --listen`)
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_signum: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {
    // no graceful-signal story off unix; the server runs until killed
}

/// The `lota serve --listen <addr>` entry: start the front end, print the
/// bound address, run until SIGTERM/SIGINT, then drain and return the
/// worker's report.
pub fn serve_listen(
    cfg: &ModelConfig,
    store: &ParamStore,
    opts: &ServeOptions,
    addr: &str,
) -> Result<crate::sched::WorkerReport> {
    let server = ListenServer::start(cfg, store, opts, addr)?;
    install_signal_handlers();
    // the smoke test scrapes this line for the resolved port, so it goes
    // to stdout (println! flushes on the newline), not the log
    println!("listening on http://{}", server.local_addr());
    while !SHUTDOWN.load(Ordering::SeqCst) {
        thread::sleep(Duration::from_millis(50));
    }
    log::info!("shutdown signal received; draining in-flight requests");
    server.shutdown()
}
