//! Serving metrics: per-request latency percentiles and aggregate token
//! throughput — the numbers behind the paper's Fig. 4 efficiency panel
//! (tokens/s by batch size, speedup of the merged path over LoRA's).
//!
//! "Tokens" throughout this module means **generated tokens**, taken
//! from each response's `tokens_decoded`. Earlier revisions counted
//! decoded characters, which silently diverges whenever an untrained or
//! heavily-quantized model emits special/unused vocab ids that the
//! detokenizer drops.
//!
//! Reports also carry the aggregate [`DecodeStats`] of what the backend
//! actually fed through the model — the number that separates KV-cached
//! decode (positions fed ~ tokens generated) from recompute (positions
//! fed ~ prefix × steps). Backends that don't track it leave it zeroed.

use std::collections::BTreeMap;

use crate::engine::DecodeStats;

use super::Response;

/// Latency distribution summary.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    /// tail percentile — heavy-traffic serving work is judged on p99,
    /// which p95 understates once queues form
    pub p99: f64,
    pub max: f64,
}

impl LatencyStats {
    /// Nearest-rank percentiles over an already-sorted slice. Tiny
    /// sample counts are well-defined, not interpolation artifacts
    /// (pinned in tests): n = 0 → all zeros; n = 1 → every percentile is
    /// the sample; n = 2 → p50/p95/p99 all land on the *larger* sample
    /// (`(q · 1).round()` is 1 for q ≥ 0.5, round-half-away-from-zero).
    pub fn from_sorted(sorted: &[f64]) -> LatencyStats {
        if sorted.is_empty() {
            return LatencyStats::default();
        }
        let n = sorted.len();
        let pct = |q: f64| sorted[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        LatencyStats {
            mean: sorted.iter().sum::<f64>() / n as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: sorted[n - 1],
        }
    }
}

/// How many raw samples a [`Histogram`] retains. Below this everything
/// is kept and every summary is exact; past it the retained set becomes
/// a uniform reservoir (Algorithm R), bounding memory for long-running
/// servers (`lota serve --listen` records per-token samples forever)
/// while count/sum/min/max — and therefore mean — stay exact.
pub const HISTOGRAM_CAP: usize = 4096;

/// A sample accumulator summarized on demand. Samples are kept raw up to
/// [`HISTOGRAM_CAP`] (the scheduler records at most a few per request or
/// per step, so short runs never hit it) and sorted only when a summary
/// is asked for — no binning error, exact percentiles via
/// [`LatencyStats::from_sorted`]. Past the cap, percentiles come from a
/// uniform reservoir of the stream while the scalar aggregates (count,
/// sum, mean, min, max) remain exact for every sample ever recorded.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// retained samples: everything below the cap, a reservoir above it
    samples: Vec<f64>,
    /// samples ever recorded (≥ `samples.len()`)
    count: usize,
    sum: f64,
    min: f64,
    max: f64,
    /// xorshift64 state for reservoir replacement — seeded to a fixed
    /// constant so runs are reproducible; never zero (xorshift fixpoint)
    rng_state: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            samples: Vec::new(),
            count: 0,
            sum: 0.0,
            min: 0.0,
            max: 0.0,
            rng_state: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.sum += v;
        self.push_sample(v);
    }

    /// Count + reservoir maintenance (Algorithm R): item number `count`
    /// replaces a uniformly chosen retained slot with probability
    /// cap/count, keeping the retained set a uniform sample of the
    /// stream.
    fn push_sample(&mut self, v: f64) {
        self.count += 1;
        if self.samples.len() < HISTOGRAM_CAP {
            self.samples.push(v);
        } else {
            let j = (self.next_u64() % self.count as u64) as usize;
            if j < HISTOGRAM_CAP {
                self.samples[j] = v;
            }
        }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.rng_state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng_state = x;
        x
    }

    /// Samples ever recorded (not the retained-sample count).
    pub fn len(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Percentile/mean/max summary of everything recorded so far. Exact
    /// below [`HISTOGRAM_CAP`]; above it the percentiles are reservoir
    /// estimates while mean and max stay exact.
    pub fn stats(&self) -> LatencyStats {
        if self.count == 0 {
            return LatencyStats::default();
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut s = LatencyStats::from_sorted(&sorted);
        // the exact aggregates always win over the reservoir's view
        s.mean = self.sum / self.count as f64;
        s.max = self.max;
        s
    }

    /// Smallest recorded sample, exact over the whole stream (0.0 when
    /// empty, matching the zeroed summaries of [`Histogram::stats`]).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.min
    }

    /// Sum of all recorded samples — the Prometheus `_sum` series.
    /// Exact over the whole stream, capped or not.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// The retained samples, in recording order (all of them below
    /// [`HISTOGRAM_CAP`], a uniform reservoir above). The metrics
    /// registry's Prometheus renderer walks these to build cumulative
    /// `le` bucket counts, scaled to [`Histogram::len`] when capped
    /// (the JSON form keeps using exact percentiles).
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Fold another histogram's samples into this one. Exact while the
    /// combined retained sets fit the cap (a plain append); above it the
    /// other side's retained samples feed this reservoir and dropped
    /// samples still count toward the exact aggregates.
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if self.count == 0 {
            self.min = other.min;
            self.max = other.max;
        } else {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
        self.sum += other.sum;
        for &v in &other.samples {
            self.push_sample(v);
        }
        // samples the other side had already dropped from its reservoir
        // still count toward count/mean
        self.count += other.count - other.samples.len();
    }
}

/// Requests and generated tokens attributed to one adapter over a
/// scheduled run — the per-tenant accounting of multi-adapter serving.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdapterUsage {
    /// completed (incl. cancelled) requests tagged with this adapter
    pub requests: usize,
    /// tokens generated for this adapter
    pub tokens: usize,
}

/// What the continuous-batching scheduler (`crate::sched`) measured about
/// a serving run, beyond raw decode work: request-level timing (TTFT,
/// inter-token gaps, queue wait) and step-level pressure (queue depth,
/// batch occupancy). One-shot backends leave this absent.
#[derive(Clone, Debug, Default)]
pub struct SchedStats {
    /// time-to-first-token per request, milliseconds (submit → first pick)
    pub ttft_ms: Histogram,
    /// gap between consecutive generated tokens of a request, milliseconds
    pub inter_token_ms: Histogram,
    /// submit → admission wait per request, milliseconds
    pub queue_wait_ms: Histogram,
    /// cross-thread command-channel handoff per request, milliseconds
    /// (channel entry → scheduler pickup); empty unless requests were
    /// submitted through `sched::SchedWorker` — in-process submits have
    /// no handoff to measure. This is the queue-transport overhead
    /// isolated from compute: TTFT minus handoff minus queue wait is
    /// pure prefill work
    pub handoff_ms: Histogram,
    /// waiting requests observed at each step (after admission)
    pub queue_depth: Histogram,
    /// fraction of decode slots busy at each step, in [0, 1]
    pub batch_occupancy: Histogram,
    /// fraction of the KV block pool in use at each step, in [0, 1]
    /// (paged caches only — contiguous runs leave it empty)
    pub block_util: Histogram,
    /// admissions denied because the block pool couldn't cover the
    /// candidate's prompt + decode horizon (the paged backpressure path:
    /// the request stays queued, nothing in flight is ever evicted)
    pub admission_denied: usize,
    /// requests shed at submit: the TTFT deadline was already blown when
    /// the request arrived, so it never entered the queue
    pub shed_at_submit: usize,
    /// requests shed from the wait queue: the TTFT deadline blew while
    /// waiting for a slot, so the request was dropped before prefill
    pub shed_in_queue: usize,
    /// submits the bounded worker queue rejected outright (the 503 +
    /// Retry-After path) — these never reached the scheduler's queue
    pub queue_rejected: usize,
    /// most requests simultaneously holding decode slots in any step —
    /// the concurrency headline the paged layout moves at a fixed budget
    pub peak_active: usize,
    /// scheduler iterations run
    pub steps: usize,
    /// per-adapter request/token accounting, keyed by adapter label
    /// ("base" for untagged requests); sorted keys keep reports stable
    pub adapter_usage: BTreeMap<String, AdapterUsage>,
}

impl SchedStats {
    /// Fold another run's measurements into this one (multi-batch
    /// aggregation in the `Server` drain).
    pub fn absorb(&mut self, other: &SchedStats) {
        self.ttft_ms.merge(&other.ttft_ms);
        self.inter_token_ms.merge(&other.inter_token_ms);
        self.queue_wait_ms.merge(&other.queue_wait_ms);
        self.handoff_ms.merge(&other.handoff_ms);
        self.queue_depth.merge(&other.queue_depth);
        self.batch_occupancy.merge(&other.batch_occupancy);
        self.block_util.merge(&other.block_util);
        self.admission_denied += other.admission_denied;
        self.shed_at_submit += other.shed_at_submit;
        self.shed_in_queue += other.shed_in_queue;
        self.queue_rejected += other.queue_rejected;
        self.peak_active = self.peak_active.max(other.peak_active);
        self.steps += other.steps;
        for (label, usage) in &other.adapter_usage {
            let mine = self.adapter_usage.entry(label.clone()).or_default();
            mine.requests += usage.requests;
            mine.tokens += usage.tokens;
        }
    }
}

/// Aggregate serving report.
#[derive(Clone, Debug, Default)]
pub struct ThroughputReport {
    pub requests: usize,
    /// total tokens generated across all responses
    pub tokens: usize,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub requests_per_sec: f64,
    pub latency: LatencyStats,
    /// aggregate decode-work accounting across all batches (zeroed when
    /// the backend doesn't report it)
    pub decode: DecodeStats,
    /// time-to-first-token p50, milliseconds (0.0 unless served through
    /// the scheduler — one-shot paths never observe a first token apart)
    pub ttft_ms_p50: f64,
    /// time-to-first-token p95, milliseconds
    pub ttft_ms_p95: f64,
    /// time-to-first-token p99, milliseconds
    pub ttft_ms_p99: f64,
    /// mean submit → admission wait, milliseconds
    pub queue_wait_ms: f64,
    /// full scheduler measurements when the run went through
    /// `crate::sched` (None for one-shot backends)
    pub sched: Option<SchedStats>,
    /// which packed-GEMM kernel the native engine ran
    /// (`avx2` / `portable` / `scalar`; None for PJRT serving) — keeps
    /// every reported throughput number attributable to the code path
    /// that produced it
    pub gemm_kernel: Option<&'static str>,
}

impl ThroughputReport {
    pub fn from_responses(responses: &[Response], tokens: usize, wall: f64) -> ThroughputReport {
        let mut lat: Vec<f64> = responses.iter().map(|r| r.latency_secs).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ThroughputReport {
            requests: responses.len(),
            tokens,
            wall_secs: wall,
            tokens_per_sec: if wall > 0.0 { tokens as f64 / wall } else { 0.0 },
            requests_per_sec: if wall > 0.0 { responses.len() as f64 / wall } else { 0.0 },
            latency: LatencyStats::from_sorted(&lat),
            decode: DecodeStats::default(),
            ttft_ms_p50: 0.0,
            ttft_ms_p95: 0.0,
            ttft_ms_p99: 0.0,
            queue_wait_ms: 0.0,
            sched: None,
            gemm_kernel: None,
        }
    }

    /// Attach the aggregate decode accounting (builder style).
    pub fn with_decode(mut self, decode: DecodeStats) -> ThroughputReport {
        self.decode = decode;
        self
    }

    /// Attach the scheduler's measurements (builder style), surfacing the
    /// headline TTFT percentiles and mean queue wait as scalar fields.
    pub fn with_sched(mut self, sched: SchedStats) -> ThroughputReport {
        let ttft = sched.ttft_ms.stats();
        self.ttft_ms_p50 = ttft.p50;
        self.ttft_ms_p95 = ttft.p95;
        self.ttft_ms_p99 = ttft.p99;
        self.queue_wait_ms = sched.queue_wait_ms.stats().mean;
        self.sched = Some(sched);
        self
    }

    /// [`ThroughputReport::with_sched`] for backends that may or may not
    /// have scheduled (the `Server` drain path).
    pub fn with_sched_opt(self, sched: Option<SchedStats>) -> ThroughputReport {
        match sched {
            Some(s) => self.with_sched(s),
            None => self,
        }
    }

    /// Attach the packed-GEMM kernel label (builder style; None for
    /// backends that don't run the native engine).
    pub fn with_gemm_kernel(mut self, kernel: Option<&'static str>) -> ThroughputReport {
        self.gemm_kernel = kernel;
        self
    }

    /// Positions the backend fed per token it generated — 1.0 is the
    /// cached-decode ideal (each token paid for once, ignoring prefill);
    /// recompute grows linearly with generation length. 0.0 when no
    /// tokens were generated: the ratio feeds the JSON metrics snapshot,
    /// where a NaN would serialize as `null` and poison downstream math.
    pub fn positions_per_token(&self) -> f64 {
        if self.tokens > 0 {
            self.decode.forwarded_positions as f64 / self.tokens as f64
        } else {
            0.0
        }
    }

    /// Speedup of `self` over `other` in token throughput. 0.0 when
    /// `other` has no throughput to compare against (same snapshot-safety
    /// rationale as [`ThroughputReport::positions_per_token`]).
    pub fn speedup_over(&self, other: &ThroughputReport) -> f64 {
        if other.tokens_per_sec > 0.0 {
            self.tokens_per_sec / other.tokens_per_sec
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn resp(id: u64, lat: f64, toks: usize) -> Response {
        Response {
            id,
            text: String::new(),
            latency_secs: lat,
            tokens_decoded: toks,
        }
    }

    #[test]
    fn latency_percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencyStats::from_sorted(&sorted);
        assert_eq!(s.p50, 51.0); // (0.5·99).round() = 50 → value 51
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0); // (0.99·99).round() = 98 → value 99
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        // small samples: p99 collapses toward max, never past it
        let three = LatencyStats::from_sorted(&[1.0, 2.0, 3.0]);
        assert_eq!(three.p99, 3.0);
        let one = LatencyStats::from_sorted(&[4.0]);
        assert_eq!((one.p50, one.p95, one.p99, one.max), (4.0, 4.0, 4.0, 4.0));
    }

    #[test]
    fn tiny_sample_percentiles_are_pinned() {
        // n = 0: all-zero summary, no NaN
        let zero = LatencyStats::from_sorted(&[]);
        assert_eq!((zero.mean, zero.p50, zero.p95, zero.p99, zero.max), (0.0, 0.0, 0.0, 0.0, 0.0));
        // n = 1: every percentile is the lone sample
        let one = LatencyStats::from_sorted(&[2.5]);
        assert_eq!((one.mean, one.p50, one.p95, one.p99, one.max), (2.5, 2.5, 2.5, 2.5, 2.5));
        // n = 2: nearest-rank rounds half away from zero, so p50 (and
        // p95/p99) all land on the LARGER sample — bench-report deltas
        // over two-sample smoke runs compare real samples, not
        // interpolation artifacts
        let two = LatencyStats::from_sorted(&[1.0, 9.0]);
        assert_eq!(two.mean, 5.0);
        assert_eq!((two.p50, two.p95, two.p99, two.max), (9.0, 9.0, 9.0, 9.0));
    }

    #[test]
    fn report_aggregates() {
        let responses: Vec<Response> =
            (0..10).map(|i| resp(i, 0.1 * (i + 1) as f64, 5)).collect();
        let r = ThroughputReport::from_responses(&responses, 50, 2.0);
        assert_eq!(r.requests, 10);
        assert_eq!(r.tokens_per_sec, 25.0);
        assert_eq!(r.requests_per_sec, 5.0);
    }

    #[test]
    fn decode_stats_ride_along() {
        let responses: Vec<Response> = (0..4).map(|i| resp(i, 0.1, 5)).collect();
        let stats = DecodeStats { forwards: 6, forwarded_rows: 20, forwarded_positions: 120 };
        let r = ThroughputReport::from_responses(&responses, 20, 1.0).with_decode(stats);
        assert_eq!(r.decode, stats);
        assert!((r.positions_per_token() - 6.0).abs() < 1e-9);
        // zeroed by default; an empty report yields 0.0, not NaN — the
        // ratio lands in the JSON metrics snapshot, which must stay
        // finite
        let empty = ThroughputReport::from_responses(&[], 0, 0.0);
        assert_eq!(empty.decode, DecodeStats::default());
        assert_eq!(empty.positions_per_token(), 0.0);
    }

    #[test]
    fn histogram_summaries() {
        let mut h = Histogram::default();
        assert!(h.is_empty());
        for v in [3.0, 1.0, 2.0] {
            h.record(v);
        }
        assert_eq!(h.len(), 3);
        let s = h.stats();
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
        assert!((s.mean - 2.0).abs() < 1e-9);
        // empty histogram summarizes to zeros, not NaN
        assert_eq!(Histogram::default().stats().p95, 0.0);
    }

    #[test]
    fn histogram_empty_single_and_all_equal() {
        // empty: summaries are zeros across the board, not NaN
        let empty = Histogram::default();
        assert!(empty.is_empty());
        assert_eq!(empty.len(), 0);
        let s = empty.stats();
        assert_eq!((s.mean, s.p50, s.p95, s.max), (0.0, 0.0, 0.0, 0.0));
        // single sample: every summary collapses to that sample
        let mut one = Histogram::default();
        one.record(7.5);
        assert_eq!(one.len(), 1);
        let s = one.stats();
        assert_eq!((s.mean, s.p50, s.p95, s.max), (7.5, 7.5, 7.5, 7.5));
        // all-equal samples: percentiles are exact, mean has no rounding
        let mut eq = Histogram::default();
        for _ in 0..17 {
            eq.record(3.0);
        }
        let s = eq.stats();
        assert_eq!((s.mean, s.p50, s.p95, s.max), (3.0, 3.0, 3.0, 3.0));
        // merging an empty histogram changes nothing; merging into an
        // empty one copies the samples
        let before = eq.stats();
        eq.merge(&Histogram::default());
        assert_eq!(eq.len(), 17);
        assert_eq!(eq.stats().p95, before.p95);
        let mut fresh = Histogram::default();
        fresh.merge(&one);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh.stats().max, 7.5);
    }

    #[test]
    fn sched_stats_absorb_folds_paging_counters() {
        let mut a = SchedStats::default();
        a.block_util.record(0.5);
        a.admission_denied = 2;
        a.shed_at_submit = 1;
        a.shed_in_queue = 2;
        a.queue_rejected = 3;
        a.peak_active = 3;
        a.steps = 10;
        let mut b = SchedStats::default();
        b.block_util.record(0.75);
        b.admission_denied = 1;
        b.shed_at_submit = 4;
        b.shed_in_queue = 5;
        b.queue_rejected = 6;
        b.peak_active = 7;
        b.steps = 4;
        a.absorb(&b);
        assert_eq!(a.block_util.len(), 2);
        assert_eq!(a.admission_denied, 3);
        assert_eq!(
            (a.shed_at_submit, a.shed_in_queue, a.queue_rejected),
            (5, 7, 9),
            "overload counters fold by sum"
        );
        assert_eq!(a.peak_active, 7, "peak concurrency folds by max, not sum");
        assert_eq!(a.steps, 14);
        // absorbing a lower peak does not shrink the fold
        let quiet = SchedStats { peak_active: 1, ..SchedStats::default() };
        a.absorb(&quiet);
        assert_eq!(a.peak_active, 7);
    }

    #[test]
    fn sched_stats_surface_in_report() {
        let mut sched = SchedStats::default();
        for v in [10.0, 20.0, 30.0] {
            sched.ttft_ms.record(v);
        }
        sched.queue_wait_ms.record(4.0);
        sched.queue_wait_ms.record(6.0);
        let r = ThroughputReport::from_responses(&[], 0, 1.0).with_sched(sched);
        assert_eq!(r.ttft_ms_p50, 20.0);
        assert_eq!(r.ttft_ms_p95, 30.0);
        assert_eq!(r.ttft_ms_p99, 30.0);
        assert!((r.queue_wait_ms - 5.0).abs() < 1e-9);
        assert!(r.sched.is_some());
        // one-shot paths leave the scalar fields zeroed
        let plain = ThroughputReport::from_responses(&[], 0, 1.0).with_sched_opt(None);
        assert_eq!(plain.ttft_ms_p50, 0.0);
        assert!(plain.sched.is_none());
    }

    #[test]
    fn gemm_kernel_rides_along() {
        let r = ThroughputReport::from_responses(&[], 0, 1.0);
        assert_eq!(r.gemm_kernel, None);
        let r = r.with_gemm_kernel(Some("avx2"));
        assert_eq!(r.gemm_kernel, Some("avx2"));
        let r = r.with_gemm_kernel(None);
        assert_eq!(r.gemm_kernel, None);
    }

    #[test]
    fn speedup_ratio() {
        let fast = ThroughputReport { tokens_per_sec: 20.0, ..Default::default() };
        let slow = ThroughputReport { tokens_per_sec: 10.0, ..Default::default() };
        assert_eq!(fast.speedup_over(&slow), 2.0);
        // zero and negative baselines yield 0.0, never NaN/inf
        let idle = ThroughputReport::default();
        assert_eq!(fast.speedup_over(&idle), 0.0);
        let broken = ThroughputReport { tokens_per_sec: -1.0, ..Default::default() };
        assert_eq!(fast.speedup_over(&broken), 0.0);
        assert_eq!(idle.speedup_over(&idle), 0.0);
    }

    #[test]
    fn histogram_min_tracks_smallest_sample() {
        let mut h = Histogram::default();
        assert_eq!(h.min(), 0.0, "empty histogram min is zero, like its stats");
        for v in [3.0, 0.5, 2.0] {
            h.record(v);
        }
        assert_eq!(h.min(), 0.5);
    }

    #[test]
    fn histogram_caps_retained_samples_with_exact_aggregates() {
        let n = 3 * HISTOGRAM_CAP;
        let mut h = Histogram::default();
        for i in 0..n {
            h.record(i as f64);
        }
        // memory is bounded, counting is not
        assert_eq!(h.len(), n);
        assert_eq!(h.samples().len(), HISTOGRAM_CAP);
        // scalar aggregates stay exact past the cap
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.sum(), (n * (n - 1) / 2) as f64);
        let s = h.stats();
        assert_eq!(s.max, (n - 1) as f64);
        assert!((s.mean - (n - 1) as f64 / 2.0).abs() < 1e-9);
        // the reservoir keeps percentiles honest: the true p50 of
        // 0..3·cap is ~1.5·cap, and a 4096-sample uniform reservoir
        // estimates a uniform stream's median to a few percent
        let p50_true = 1.5 * HISTOGRAM_CAP as f64;
        assert!((s.p50 - p50_true).abs() < 0.15 * n as f64, "p50 {} vs {}", s.p50, p50_true);
        // every retained sample really came from the stream
        assert!(h.samples().iter().all(|&v| v >= 0.0 && v < n as f64));
    }

    #[test]
    fn histogram_merge_is_exact_below_the_cap() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        for v in [1.0, 2.0] {
            a.record(v);
        }
        for v in [3.0, 0.5] {
            b.record(v);
        }
        a.merge(&b);
        assert_eq!(a.len(), 4);
        assert_eq!(a.samples(), &[1.0, 2.0, 3.0, 0.5]);
        assert_eq!(a.min(), 0.5);
        assert_eq!(a.sum(), 6.5);
        assert_eq!(a.stats().max, 3.0);
        // merging a capped histogram keeps aggregate accounting exact
        let mut big = Histogram::default();
        for i in 0..2 * HISTOGRAM_CAP {
            big.record(i as f64);
        }
        let mut acc = Histogram::default();
        acc.record(-5.0);
        acc.merge(&big);
        assert_eq!(acc.len(), 2 * HISTOGRAM_CAP + 1);
        assert_eq!(acc.min(), -5.0);
        assert_eq!(acc.stats().max, (2 * HISTOGRAM_CAP - 1) as f64);
        assert_eq!(acc.sum(), big.sum() - 5.0);
        assert_eq!(acc.samples().len(), HISTOGRAM_CAP);
    }

    #[test]
    fn empty_report_is_safe() {
        let r = ThroughputReport::from_responses(&[], 0, 0.0);
        assert_eq!(r.tokens_per_sec, 0.0);
        let _ = Instant::now(); // keep the import honest
    }
}
