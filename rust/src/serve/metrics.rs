//! Serving metrics: per-request latency percentiles and aggregate token
//! throughput — the numbers behind the paper's Fig. 4 efficiency panel
//! (tokens/s by batch size, speedup of the merged path over LoRA's).
//!
//! "Tokens" throughout this module means **generated tokens**, taken
//! from each response's `tokens_decoded`. Earlier revisions counted
//! decoded characters, which silently diverges whenever an untrained or
//! heavily-quantized model emits special/unused vocab ids that the
//! detokenizer drops.
//!
//! Reports also carry the aggregate [`DecodeStats`] of what the backend
//! actually fed through the model — the number that separates KV-cached
//! decode (positions fed ~ tokens generated) from recompute (positions
//! fed ~ prefix × steps). Backends that don't track it leave it zeroed.

use crate::engine::DecodeStats;

use super::Response;

/// Latency distribution summary.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_sorted(sorted: &[f64]) -> LatencyStats {
        if sorted.is_empty() {
            return LatencyStats::default();
        }
        let n = sorted.len();
        let pct = |q: f64| sorted[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        LatencyStats {
            mean: sorted.iter().sum::<f64>() / n as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            max: sorted[n - 1],
        }
    }
}

/// Aggregate serving report.
#[derive(Clone, Debug, Default)]
pub struct ThroughputReport {
    pub requests: usize,
    /// total tokens generated across all responses
    pub tokens: usize,
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
    pub requests_per_sec: f64,
    pub latency: LatencyStats,
    /// aggregate decode-work accounting across all batches (zeroed when
    /// the backend doesn't report it)
    pub decode: DecodeStats,
}

impl ThroughputReport {
    pub fn from_responses(responses: &[Response], tokens: usize, wall: f64) -> ThroughputReport {
        let mut lat: Vec<f64> = responses.iter().map(|r| r.latency_secs).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ThroughputReport {
            requests: responses.len(),
            tokens,
            wall_secs: wall,
            tokens_per_sec: if wall > 0.0 { tokens as f64 / wall } else { 0.0 },
            requests_per_sec: if wall > 0.0 { responses.len() as f64 / wall } else { 0.0 },
            latency: LatencyStats::from_sorted(&lat),
            decode: DecodeStats::default(),
        }
    }

    /// Attach the aggregate decode accounting (builder style).
    pub fn with_decode(mut self, decode: DecodeStats) -> ThroughputReport {
        self.decode = decode;
        self
    }

    /// Positions the backend fed per token it generated — 1.0 is the
    /// cached-decode ideal (each token paid for once, ignoring prefill);
    /// recompute grows linearly with generation length.
    pub fn positions_per_token(&self) -> f64 {
        if self.tokens > 0 {
            self.decode.forwarded_positions as f64 / self.tokens as f64
        } else {
            f64::NAN
        }
    }

    /// Speedup of `self` over `other` in token throughput.
    pub fn speedup_over(&self, other: &ThroughputReport) -> f64 {
        if other.tokens_per_sec > 0.0 {
            self.tokens_per_sec / other.tokens_per_sec
        } else {
            f64::NAN
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn resp(id: u64, lat: f64, toks: usize) -> Response {
        Response {
            id,
            text: String::new(),
            latency_secs: lat,
            tokens_decoded: toks,
        }
    }

    #[test]
    fn latency_percentiles() {
        let sorted: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = LatencyStats::from_sorted(&sorted);
        assert_eq!(s.p50, 51.0); // (0.5·99).round() = 50 → value 51
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn report_aggregates() {
        let responses: Vec<Response> =
            (0..10).map(|i| resp(i, 0.1 * (i + 1) as f64, 5)).collect();
        let r = ThroughputReport::from_responses(&responses, 50, 2.0);
        assert_eq!(r.requests, 10);
        assert_eq!(r.tokens_per_sec, 25.0);
        assert_eq!(r.requests_per_sec, 5.0);
    }

    #[test]
    fn decode_stats_ride_along() {
        let responses: Vec<Response> = (0..4).map(|i| resp(i, 0.1, 5)).collect();
        let stats = DecodeStats { forwards: 6, forwarded_rows: 20, forwarded_positions: 120 };
        let r = ThroughputReport::from_responses(&responses, 20, 1.0).with_decode(stats);
        assert_eq!(r.decode, stats);
        assert!((r.positions_per_token() - 6.0).abs() < 1e-9);
        // zeroed by default, NaN ratio on an empty report
        let empty = ThroughputReport::from_responses(&[], 0, 0.0);
        assert_eq!(empty.decode, DecodeStats::default());
        assert!(empty.positions_per_token().is_nan());
    }

    #[test]
    fn speedup_ratio() {
        let fast = ThroughputReport { tokens_per_sec: 20.0, ..Default::default() };
        let slow = ThroughputReport { tokens_per_sec: 10.0, ..Default::default() };
        assert_eq!(fast.speedup_over(&slow), 2.0);
    }

    #[test]
    fn empty_report_is_safe() {
        let r = ThroughputReport::from_responses(&[], 0, 0.0);
        assert_eq!(r.tokens_per_sec, 0.0);
        let _ = Instant::now(); // keep the import honest
    }
}
