//! The serving backend abstraction: one trait, two executors.
//!
//! [`PjrtBackend`] wraps the original path — fixed-shape AOT artifacts
//! compiled per batch bucket, executed through the PJRT CPU client.
//! [`NativeBackend`] wraps the packed-integer engine (`crate::engine`),
//! which computes directly on the merged low-bit weights and accepts any
//! batch size. The [`Server`](super::Server) drains its queue through
//! whichever backend it was built with; the parity golden test pins the
//! two to the same logits on the same checkpoint.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{DecodeMode, GemmKernel, ModelConfig, SchedConfig};
use crate::coordinator;
use crate::engine::{self, Engine};
use crate::model::ParamStore;
use crate::runtime::{Executable, Runtime};
use crate::sched::{RequestSpec, SchedOptions, Scheduler};

use super::batcher::BucketPolicy;
use super::metrics::SchedStats;
use super::ServePath;

pub use crate::engine::{DecodeStats, Generation};

/// Per-batch KV memory the cached native path may hold: the adaptive
/// batcher is capped at however many request rows fit in this budget.
const KV_CACHE_BUDGET_BYTES: usize = 1 << 30;

/// Build the native engine a serving path needs: packed grids from the
/// store, plus the f32 LoRA adapters when serving the unmerged-baseline
/// path. The single construction point for every native serving mode
/// (one-shot, scheduled, open-loop) — engine setup changes land here
/// once.
pub(crate) fn build_engine(
    cfg: &ModelConfig,
    store: &ParamStore,
    path: ServePath,
    n_bits: u32,
    kernel: GemmKernel,
) -> Result<Engine> {
    let mut engine = Engine::from_store(cfg, store, n_bits)?;
    engine.set_gemm_kernel(kernel);
    if path == ServePath::LoraAdapter {
        engine.attach_lora(store)?;
    }
    Ok(engine)
}

/// A serving executor: turns a batch of prompts into finished generations.
pub trait ServeBackend {
    /// Short name for logs and report tables.
    fn label(&self) -> &'static str;

    /// The batch sizes this backend can run: a fixed bucket set for
    /// compiled artifacts, or the adaptive policy when any size works.
    fn bucket_policy(&self) -> BucketPolicy;

    /// Greedy-decode one batch. Returns exactly `prompts.len()` entries,
    /// each carrying its generated-token count, plus the decode-work
    /// accounting (zeroed by backends that don't track it).
    fn decode_with_stats(
        &self,
        prompts: &[String],
        max_new: usize,
    ) -> Result<(Vec<Generation>, DecodeStats)>;

    /// [`ServeBackend::decode_with_stats`] without the accounting.
    fn decode(&self, prompts: &[String], max_new: usize) -> Result<Vec<Generation>> {
        Ok(self.decode_with_stats(prompts, max_new)?.0)
    }

    /// Scheduler measurements from the most recent decode, for backends
    /// that serve through `crate::sched`. Taking clears the slot so a
    /// `Server` drain reports each run exactly once; one-shot backends
    /// return None.
    fn take_sched_stats(&self) -> Option<SchedStats> {
        None
    }

    /// Which packed-GEMM kernel this backend's forwards run
    /// (`avx2` / `portable` / `scalar`) — surfaced in the drain's
    /// [`super::ThroughputReport`]. None for backends without the native
    /// engine (PJRT computes through its lowered graphs instead).
    fn gemm_kernel(&self) -> Option<&'static str> {
        None
    }
}

/// The AOT path: compiled `fwd_*` artifacts per batch bucket.
pub struct PjrtBackend<'a> {
    rt: &'a Runtime,
    cfg: ModelConfig,
    store: &'a ParamStore,
    /// compiled executables per bucket size
    exes: BTreeMap<usize, Arc<Executable>>,
}

impl<'a> PjrtBackend<'a> {
    /// Discover the available buckets for this (config, path) from the
    /// manifest and compile them.
    pub fn new(
        rt: &'a Runtime,
        cfg: &ModelConfig,
        store: &'a ParamStore,
        path: ServePath,
    ) -> Result<PjrtBackend<'a>> {
        let prefix = path.artifact_prefix();
        let mut exes = BTreeMap::new();
        for spec in rt.manifest().of_kind("fwd") {
            if spec.cfg.as_deref() == Some(cfg.name.as_str())
                && spec.name.starts_with(prefix)
                && spec
                    .method
                    .as_deref()
                    .map(|m| prefix.ends_with(m))
                    .unwrap_or(false)
            {
                if let Some(b) = spec.batch {
                    exes.insert(b, rt.load(&spec.name)?);
                }
            }
        }
        if exes.is_empty() {
            bail!("no {prefix} artifacts for config {}", cfg.name);
        }
        let buckets: Vec<usize> = exes.keys().copied().collect();
        log::info!("pjrt backend[{}/{prefix}] buckets {:?}", cfg.name, buckets);
        Ok(PjrtBackend { rt, cfg: cfg.clone(), store, exes })
    }
}

impl ServeBackend for PjrtBackend<'_> {
    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn bucket_policy(&self) -> BucketPolicy {
        BucketPolicy::new(self.exes.keys().copied().collect())
            .expect("non-empty bucket set by construction")
    }

    fn decode_with_stats(
        &self,
        prompts: &[String],
        max_new: usize,
    ) -> Result<(Vec<Generation>, DecodeStats)> {
        // smallest compiled bucket that holds the batch; the decoder chunks
        // by the executable's batch if the queue handed us more than that
        let n = prompts.len();
        let exe = self
            .exes
            .range(n..)
            .next()
            .or_else(|| self.exes.iter().next_back())
            .map(|(_, e)| e.clone())
            .expect("non-empty bucket set by construction");
        let decoded = coordinator::greedy_decode_counted(
            self.rt,
            &exe,
            self.store,
            &self.cfg,
            prompts,
            max_new,
            None,
        )?;
        let gens = decoded.into_iter().map(|(text, tokens)| Generation { text, tokens }).collect();
        // the AOT decoder doesn't track per-step feeding — zeroed stats
        Ok((gens, DecodeStats::default()))
    }
}

/// The native path: the packed-integer engine, no artifacts, no buckets.
/// Decodes KV-cached by default; [`NativeBackend::with_mode`] selects the
/// full-prefix recompute reference instead.
pub struct NativeBackend {
    engine: Engine,
    mode: DecodeMode,
}

impl NativeBackend {
    /// Build the engine from a quantized store. For the LoRA serving path
    /// the `lo_{slot}_a/_b` tensors are attached so every forward pays the
    /// adapter matmuls, mirroring the artifact pair of the Fig. 4 setup.
    pub fn new(
        cfg: &ModelConfig,
        store: &ParamStore,
        path: ServePath,
        n_bits: u32,
        kernel: GemmKernel,
    ) -> Result<NativeBackend> {
        let engine = build_engine(cfg, store, path, n_bits, kernel)?;
        log::info!(
            "native backend[{}] {}-bit, {} packed weight bytes{}, {} KiB KV per cached row, {} gemm",
            cfg.name,
            n_bits,
            engine.deployed_weight_bytes(),
            if engine.has_lora() { " + lora adapters" } else { "" },
            engine.cache_row_bytes() / 1024,
            engine.gemm_kernel_label()
        );
        Ok(NativeBackend { engine, mode: DecodeMode::Cached })
    }

    /// Select the decode strategy (builder style; cached is the default).
    pub fn with_mode(mut self, mode: DecodeMode) -> NativeBackend {
        self.mode = mode;
        self
    }

    /// Register a set of named ternary adapters against the packed base
    /// (builder style; an empty registry is a no-op). One-shot native
    /// decodes always serve the bare base — the registry matters for
    /// callers that borrow the engine and tag requests — but registering
    /// here keeps every serving mode constructible from one options
    /// struct.
    pub fn with_adapters(
        mut self,
        reg: &super::AdapterRegistry,
        omega_frac: f32,
    ) -> Result<NativeBackend> {
        if !reg.is_empty() {
            reg.register_all(&mut self.engine, omega_frac)?;
        }
        Ok(self)
    }

    pub fn mode(&self) -> DecodeMode {
        self.mode
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Rows the KV budget can cache at full context — the adaptive
    /// batcher's per-drain-step ceiling in cached mode.
    fn max_cached_rows(&self) -> usize {
        (KV_CACHE_BUDGET_BYTES / self.engine.cache_row_bytes().max(1)).max(1)
    }
}

impl ServeBackend for NativeBackend {
    fn label(&self) -> &'static str {
        "native"
    }

    fn bucket_policy(&self) -> BucketPolicy {
        // cached decode allocates K/V per request row up front, so bound
        // what one drain step may take; recompute holds no per-row state
        match self.mode {
            DecodeMode::Cached => BucketPolicy::adaptive_capped(self.max_cached_rows()),
            DecodeMode::Recompute => BucketPolicy::adaptive(),
        }
    }

    fn decode_with_stats(
        &self,
        prompts: &[String],
        max_new: usize,
    ) -> Result<(Vec<Generation>, DecodeStats)> {
        engine::greedy_decode_with(&self.engine, prompts, max_new, self.mode)
    }

    fn gemm_kernel(&self) -> Option<&'static str> {
        Some(self.engine.gemm_kernel_label())
    }
}

/// The scheduled native path: one-shot serving as a thin wrapper over the
/// continuous-batching scheduler — every prompt of a batch is submitted
/// at t = 0 and the scheduler runs to idle. Because the scheduler drives
/// the same cached prefill/step kernels, generations are bit-identical to
/// [`NativeBackend`]'s cached decode; what this buys over it is the
/// request-level machinery (admission under the KV budget, slot reuse,
/// TTFT/queue/occupancy accounting) exercised on every serve call, plus
/// honest scheduler metrics in the drain report.
pub struct ScheduledBackend {
    engine: Engine,
    opts: SchedOptions,
    /// measurements of the most recent decode, handed to the Server
    /// drain via [`ServeBackend::take_sched_stats`]
    last_sched: RefCell<Option<SchedStats>>,
    /// when set, every decode records a span timeline and writes it here
    /// as a Chrome-trace JSON (the last decode wins the file)
    trace_out: Option<std::path::PathBuf>,
    /// when set, every decode runs with the engine hot-path profiler
    /// attached and writes the folded `lota_engine_*` registry here
    /// (`.json` or Prometheus text by extension; last decode wins)
    profile_out: Option<std::path::PathBuf>,
}

impl ScheduledBackend {
    pub fn new(
        cfg: &ModelConfig,
        store: &ParamStore,
        path: ServePath,
        n_bits: u32,
        sched: &SchedConfig,
        kernel: GemmKernel,
    ) -> Result<ScheduledBackend> {
        let engine = build_engine(cfg, store, path, n_bits, kernel)?;
        let opts = SchedOptions::from_config(sched);
        log::info!(
            "scheduled backend[{}] {}-bit, max_batch {}, {} MiB KV budget, {} cache, {} gemm",
            cfg.name,
            n_bits,
            opts.max_batch,
            sched.kv_budget_mb,
            if opts.kv_paged {
                format!("paged ({}-token blocks)", opts.kv_block_size)
            } else {
                "contiguous".to_string()
            },
            engine.gemm_kernel_label()
        );
        Ok(ScheduledBackend {
            engine,
            opts,
            last_sched: RefCell::new(None),
            trace_out: None,
            profile_out: None,
        })
    }

    /// Record a span timeline per decode and write it to `path` as
    /// Chrome-trace JSON (builder style; `None` keeps tracing off).
    pub fn with_trace_out(mut self, path: Option<std::path::PathBuf>) -> ScheduledBackend {
        self.trace_out = path;
        self
    }

    /// Profile the engine hot path per decode and write the folded
    /// per-(layer, kind) registry to `path` (builder style; `None` keeps
    /// profiling off). When tracing is also on, the profiler shares the
    /// tracer's clock and its engine spans nest inside the scheduler's
    /// forward spans in the same Chrome export.
    pub fn with_profile_out(mut self, path: Option<std::path::PathBuf>) -> ScheduledBackend {
        self.profile_out = path;
        self
    }

    /// Register a set of named ternary adapters against the packed base
    /// (builder style; an empty registry is a no-op). Requests tagged with
    /// an adapter id mix freely with base requests in the same batch.
    pub fn with_adapters(
        mut self,
        reg: &super::AdapterRegistry,
        omega_frac: f32,
    ) -> Result<ScheduledBackend> {
        if !reg.is_empty() {
            reg.register_all(&mut self.engine, omega_frac)?;
        }
        Ok(self)
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl ServeBackend for ScheduledBackend {
    fn label(&self) -> &'static str {
        "native-sched"
    }

    fn bucket_policy(&self) -> BucketPolicy {
        // hand the scheduler the whole queue: admission under the KV
        // budget is *its* job, per step, not the batcher's per drain
        BucketPolicy::adaptive()
    }

    fn decode_with_stats(
        &self,
        prompts: &[String],
        max_new: usize,
    ) -> Result<(Vec<Generation>, DecodeStats)> {
        let mut sched = Scheduler::new(&self.engine, &self.opts)?;
        let trace = self.trace_out.as_ref().map(|_| crate::obs::RecordingTracer::new());
        if let Some(rec) = &trace {
            sched = sched.with_tracer(Box::new(rec.clone()));
        }
        let profiler = self.profile_out.as_ref().map(|_| {
            let p = crate::obs::Profiler::new();
            // when tracing too, the profiler emits its engine spans into
            // the same recording — one clock, so they nest inside the
            // scheduler's prefill_forward/decode_forward spans exactly
            match &trace {
                Some(rec) => p.with_sink(rec.clone()),
                None => p,
            }
        });
        if let Some(p) = &profiler {
            sched = sched.with_profiler(p.clone());
        }
        let mut ids = Vec::with_capacity(prompts.len());
        for p in prompts {
            ids.push(sched.submit(RequestSpec::new(p.as_str(), max_new))?);
        }
        sched.run_until_idle()?;
        if let (Some(path), Some(rec)) = (&self.trace_out, &trace) {
            crate::obs::write_chrome_trace(path, rec)?;
            log::info!("serving trace written to {}", path.display());
        }
        if let (Some(path), Some(p)) = (&self.profile_out, &profiler) {
            let mut reg = crate::obs::MetricsRegistry::new();
            reg.set_info("gemm_kernel", self.engine.gemm_kernel_label());
            p.fill_registry(&mut reg);
            reg.write(path)?;
            log::info!("engine profile written to {}", path.display());
        }
        let mut by_id: BTreeMap<u64, Generation> = sched
            .take_finished()
            .into_iter()
            .map(|r| (r.id, Generation { text: r.text, tokens: r.tokens }))
            .collect();
        let gens = ids
            .iter()
            .map(|id| {
                by_id
                    .remove(id)
                    .ok_or_else(|| anyhow::anyhow!("scheduler lost request {id}"))
            })
            .collect::<Result<Vec<Generation>>>()?;
        *self.last_sched.borrow_mut() = Some(sched.sched_stats());
        Ok((gens, sched.decode_stats()))
    }

    fn take_sched_stats(&self) -> Option<SchedStats> {
        self.last_sched.borrow_mut().take()
    }

    fn gemm_kernel(&self) -> Option<&'static str> {
        Some(self.engine.gemm_kernel_label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::model;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn tiny_store(seed: u64) -> (ModelConfig, ParamStore) {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        (cfg, store)
    }

    #[test]
    fn native_backend_serves_without_artifacts() {
        let (cfg, store) = tiny_store(1);
        let be =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto).unwrap();
        assert_eq!(be.label(), "native");
        let prompts: Vec<String> = (0..5).map(|i| format!("{i} + 1 =")).collect();
        let gens = be.decode(&prompts, 4).unwrap();
        assert_eq!(gens.len(), 5);
        assert!(gens.iter().all(|g| g.tokens <= 4));
    }

    #[test]
    fn native_lora_path_attaches_adapters() {
        let (cfg, mut store) = tiny_store(2);
        let mut rng = Rng::new(3);
        model::init_adapters(&cfg, crate::config::Method::Lora, &mut rng, &mut store);
        let be =
            NativeBackend::new(&cfg, &store, ServePath::LoraAdapter, 4, GemmKernel::Auto).unwrap();
        assert!(be.engine().has_lora());
        let merged =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto).unwrap();
        assert!(!merged.engine().has_lora());
    }

    #[test]
    fn native_policy_is_adaptive() {
        let (cfg, store) = tiny_store(4);
        let be =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto).unwrap();
        assert_eq!(be.bucket_policy().pick(17), Some(17));
        // tiny rows are ~128 KiB of K/V, so the 1 GiB budget caps far
        // above any test batch — but the cap exists
        assert_eq!(be.bucket_policy().pick(usize::MAX), Some(be.max_cached_rows()));
        // recompute mode holds no cache, so nothing to cap
        let be = be.with_mode(DecodeMode::Recompute);
        assert_eq!(be.bucket_policy().pick(usize::MAX), Some(usize::MAX));
    }

    #[test]
    fn scheduled_backend_matches_one_shot_native() {
        let (cfg, store) = tiny_store(6);
        let native =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto).unwrap();
        let sched =
            ScheduledBackend::new(
                &cfg,
                &store,
                ServePath::Merged,
                4,
                &SchedConfig::default(),
                GemmKernel::Auto,
            )
                .unwrap();
        assert_eq!(sched.label(), "native-sched");
        let prompts: Vec<String> = (0..5).map(|i| format!("{i} + 2 =")).collect();
        let (ng, ns) = native.decode_with_stats(&prompts, 5).unwrap();
        let (sg, ss) = sched.decode_with_stats(&prompts, 5).unwrap();
        for (n, s) in ng.iter().zip(&sg) {
            assert_eq!(n.text, s.text);
            assert_eq!(n.tokens, s.tokens);
        }
        // 5 prompts fit the 8-slot default batch, so even the decode-work
        // accounting is identical to the one-shot cached path
        assert_eq!(ns, ss);
        // scheduler measurements are taken exactly once per run
        assert!(sched.take_sched_stats().is_some());
        assert!(sched.take_sched_stats().is_none());
        assert!(native.take_sched_stats().is_none());
    }

    #[test]
    fn kernel_override_reaches_the_engine_and_the_generations_agree() {
        let (cfg, store) = tiny_store(7);
        let auto =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto).unwrap();
        let scalar =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Scalar).unwrap();
        assert_eq!(scalar.gemm_kernel(), Some("scalar"));
        // auto resolves to *some* kernel (which one depends on the host
        // and LOTA_GEMM_KERNEL — never assert a specific label here)
        assert!(auto.gemm_kernel().is_some());
        // kernels are bit-identical by contract, so generations agree
        let prompts: Vec<String> = (0..3).map(|i| format!("{i} + 1 =")).collect();
        let a = auto.decode(&prompts, 3).unwrap();
        let s = scalar.decode(&prompts, 3).unwrap();
        for (x, y) in a.iter().zip(&s) {
            assert_eq!(x.text, y.text);
            assert_eq!(x.tokens, y.tokens);
        }
        // the scheduled wrapper honors the same selection
        let sched = ScheduledBackend::new(
            &cfg,
            &store,
            ServePath::Merged,
            4,
            &SchedConfig::default(),
            GemmKernel::Scalar,
        )
        .unwrap();
        assert_eq!(sched.gemm_kernel(), Some("scalar"));
    }

    #[test]
    fn decode_modes_agree_and_report_work() {
        let (cfg, store) = tiny_store(5);
        let prompts: Vec<String> = (0..3).map(|i| format!("{i} + 3 =")).collect();
        let cached =
            NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto).unwrap();
        assert_eq!(cached.mode(), DecodeMode::Cached);
        let recomp = NativeBackend::new(&cfg, &store, ServePath::Merged, 4, GemmKernel::Auto)
            .unwrap()
            .with_mode(DecodeMode::Recompute);
        let (cg, cs) = cached.decode_with_stats(&prompts, 5).unwrap();
        let (rg, rs) = recomp.decode_with_stats(&prompts, 5).unwrap();
        for (c, r) in cg.iter().zip(&rg) {
            assert_eq!(c.text, r.text);
            assert_eq!(c.tokens, r.tokens);
        }
        assert!(cs.forwarded_positions <= rs.forwarded_positions);
        if rs.forwards > 1 {
            assert!(cs.forwarded_positions < rs.forwarded_positions);
        }
        assert!(cs.forwards > 0 && rs.forwards > 0);
    }
}
