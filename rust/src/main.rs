//! `lota` — the LoTA-QAF launcher.
//!
//! Subcommands drive the full life cycle against the AOT artifacts:
//!
//! ```text
//! lota pretrain  --model tiny --steps 200 --out checkpoints
//! lota quantize  --model tiny --bits 4 --base checkpoints/base_tiny_200.ckpt
//! lota finetune  --model tiny --bits 4 --method lota --task arith --steps 100
//! lota eval      --model tiny --ckpt <ckpt> --suite mmlu
//! lota serve     --model tiny --ckpt <ckpt> --path merged --backend native --requests 32
//! lota serve     --model tiny --ckpt <ckpt> --backend native --sched true --arrival-rate 64
//! lota serve     --model tiny --synthetic true --backend native --sched true \
//!                --adapter fr=synthetic:3,de=synthetic:4
//! lota config-check examples/serve_sched.toml
//! lota table1    --model tiny --steps 40      # regenerate the main table
//! lota info                                    # artifact + config summary
//! ```
//!
//! Argument parsing is hand-rolled (`--key value` pairs); the offline
//! crate set has no clap.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{preset, step_batch, ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::{print_table1, run_table1, ExperimentContext};
use lota_qaf::coordinator::{
    calibrate_hessians, exact_match_eval, finetune, merge_into_store, mmlu_eval, pretrain,
    quantize_model, token_accuracy, TrainOptions,
};
use lota_qaf::data::{mmlu_like, tasks};
use lota_qaf::model::{self, checkpoint};
use lota_qaf::runtime::Runtime;
use lota_qaf::sched::{generate_load, spread_adapters, LoadRequest, LoadSpec};
use lota_qaf::serve::{
    serve_batch, serve_listen, serve_open_loop, AdapterRegistry, ServeOptions, ServePath,
};
use lota_qaf::tensor::Rng;

/// `--key value` argument bag.
struct Args {
    map: BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut map = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let k = argv[i]
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got '{}'", argv[i]))?;
            if i + 1 >= argv.len() {
                bail!("flag --{k} needs a value");
            }
            map.insert(k.to_string(), argv[i + 1].clone());
            i += 2;
        }
        Ok(Args { map })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.map.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.map.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be an integer")),
            None => Ok(default),
        }
    }

    fn get_f32(&self, key: &str, default: f32) -> Result<f32> {
        match self.map.get(key) {
            Some(v) => v.parse().with_context(|| format!("--{key} must be a float")),
            None => Ok(default),
        }
    }

    fn opt(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, m: &log::Metadata) -> bool {
        m.level()
            <= match std::env::var("RUST_LOG").as_deref() {
                Ok("debug") => log::Level::Debug,
                Ok("warn") => log::Level::Warn,
                _ => log::Level::Info,
            }
    }
    fn log(&self, r: &log::Record) {
        if self.enabled(r.metadata()) {
            eprintln!("[{}] {}", r.level().as_str().to_lowercase(), r.args());
        }
    }
    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

fn main() -> Result<()> {
    let _ = log::set_logger(&LOGGER).map(|_| log::set_max_level(log::LevelFilter::Debug));
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    // config-check takes positional file paths, not --flag pairs
    if cmd == "config-check" {
        return cmd_config_check(&argv[1..]);
    }
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "quantize" => cmd_quantize(&args),
        "finetune" => cmd_finetune(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "bench-report" => cmd_bench_report(&args),
        "table1" => cmd_table1(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `lota help`"),
    }
}

fn print_usage() {
    println!(
        "lota — LoTA-QAF reproduction launcher

USAGE: lota <command> [--flag value]...

COMMANDS
  pretrain  --model tiny --steps 200 [--out checkpoints]
  quantize  --model tiny --bits 4 --base <ckpt> [--quantizer gptq|rtn] [--out <ckpt>]
  finetune  --model tiny --bits 4 --method lota|lora|qalora --task recovery|arith|sql|datatotext
            [--steps 100] [--omega-frac 0.75] [--sigma-init 0.05] [--lr 5e-4]
            [--base <ckpt>] [--out <ckpt>] [--merge true]
  eval      --model tiny --ckpt <ckpt> --suite mmlu|arith|sql|datatotext [--n 64]
  serve     --model tiny --ckpt <ckpt> [--path merged|lora] [--backend pjrt|native]
            [--decode cached|recompute] [--gemm-kernel auto|simd|scalar]
            [--bits 4] [--config <exp.toml>] [--synthetic true|false]
            [--requests 32] [--max-new 12]
            [--sched true|false] [--max-batch 8] [--kv-budget-mb 1024]
            [--kv-paged true|false] [--kv-block-size 16]
            [--priority-classes 1] [--submit-queue-cap 0]
            [--default-deadline-ms 0]
            [--arrival-rate <req/s>] [--load-seed 123]
            [--adapter name=<ckpt|synthetic:seed>[,name=...]] [--omega-frac 0.75]
            [--listen <addr:port>]
            [--trace-out <trace.json>] [--metrics-out <metrics.json|.prom>]
            [--profile-out <profile.json|.prom>]
            --sched routes the native backend through the continuous-batching
            scheduler (defaults from the [sched] TOML table; see
            examples/serve_sched.toml). With --arrival-rate the request
            stream arrives open-loop (Poisson) instead of all at t=0.
            --kv-paged (default true) serves over paged KV blocks — the
            budget admits by tokens actually cached, not full-context
            rows; false selects the contiguous reference layout.
            Overload control (all three also TOML keys in [sched]):
            --priority-classes N admits by request priority class 0..N
            (0 most urgent, FIFO within a class, starvation bounded by
            aging; 1 = plain FIFO, the default). --submit-queue-cap N
            bounds the worker submit queue — submits over a full queue
            are rejected (HTTP 503 + Retry-After) instead of queued
            (0 = unbounded). --default-deadline-ms N sheds any request
            still waiting for prefill N ms after arrival as reason
            \"shed\" (0 = no default deadline; per-request deadline_ms
            wins either way).
            --gemm-kernel picks the native engine's packed-GEMM inner
            loop: auto (detect AVX2, honoring LOTA_GEMM_KERNEL),
            simd (vector path), scalar (the reference) — outputs are
            bit-identical, only the speed differs.
            --synthetic true serves an in-process RTN-quantized random
            store (no --ckpt, no artifacts) — smoke runs and CI.
            --adapter registers named ternary adapter sets against the
            packed base (S-LoRA style; needs --sched true). Sources are
            LoTA adapter checkpoints or synthetic:<seed>. Requests are
            spread round-robin across the registered adapters, mixed
            freely in each batch, and served bit-identically to each adapter's
            individually merged checkpoint. The [adapters] TOML table
            (name = \"source\") is the config-file form; --omega-frac must
            match the threshold the adapters were trained with.
            --listen <addr:port> serves over the async HTTP/SSE front end
            instead of a fixed batch (needs --sched true): the scheduler
            runs on a dedicated worker thread, POST /generate streams
            tokens per request as server-sent events, POST /cancel stops
            a request mid-decode, and SIGTERM drains in-flight rows before
            exit. Port 0 binds an OS-assigned port; the resolved address
            is printed on startup. The TOML `listen` key is the
            config-file form (the flag wins). See docs/serving.md.
            --trace-out writes a Chrome-trace/Perfetto JSON span timeline
            of the scheduled run (needs --sched true; load the file at
            ui.perfetto.dev). --metrics-out snapshots the final report's
            metrics registry (.json → JSON, else Prometheus text).
            --profile-out attaches the engine hot-path profiler (needs
            --sched true) and writes the folded per-(layer, kind)
            lota_engine_* phase counters (.json → JSON, else Prometheus
            text); combined with --trace-out the engine spans appear as
            pid-3 tracks nested inside the forward spans. All three also
            honor the trace_out / metrics_out / profile_out TOML keys.
  table1    --model tiny [--steps 40] [--eval-n 32] [--pretrain-steps 150]
  bench-report --dir <bench-history> [--out <ledger.json>] [--gate-metric min_secs]
            [--max-regress 0.20] [--fail-on-regress true|false]
            reads a directory of historical BENCH_*.json bench reports —
            one subdirectory per run, lexicographic order = chronological —
            and emits a machine-readable trend ledger: per metric, the
            latest value, its delta vs the previous run, and its delta vs
            the best run on record. --fail-on-regress true exits nonzero
            when the gate metric of any case regressed past --max-regress
            against the previous run OR the best run on record, so slow
            staircase drift trips the gate too (the CI perf gate runs
            exactly that over its rolling history).
  config-check <exp.toml>...   # parse + validate experiment TOMLs, run nothing
  info      [--artifacts artifacts]

Artifacts come from `make artifacts`; all commands take --artifacts <dir>."
    );
}

// ---------------------------------------------------------------------------

/// Parse every given TOML file through [`ExperimentConfig`] (and its
/// `[adapters]` table through [`AdapterRegistry`]) without running
/// anything — the CI doc-sanity leg feeds every fenced TOML snippet in
/// `docs/` and `examples/` through this.
fn cmd_config_check(paths: &[String]) -> Result<()> {
    if paths.is_empty() {
        bail!("usage: lota config-check <exp.toml>...");
    }
    for p in paths {
        let text =
            std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?;
        let doc = lota_qaf::config::TomlDoc::parse(&text)
            .with_context(|| format!("parsing {p}"))?;
        let exp = ExperimentConfig::from_toml(&doc)
            .with_context(|| format!("validating {p}"))?;
        let reg = AdapterRegistry::from_pairs(&exp.adapters)
            .with_context(|| format!("validating [adapters] in {p}"))?;
        preset(&exp.model).with_context(|| format!("unknown model in {p}"))?;
        println!(
            "{p}: ok (model {}, method {}, {}-bit{}{})",
            exp.model,
            exp.method.as_str(),
            exp.n_bits,
            match exp.sched.as_ref() {
                // surface the overload knobs so a config review sees the
                // admission policy, not just "sched on"
                Some(s) => format!(
                    ", sched: {} classes, queue cap {}, default deadline {} ms",
                    s.priority_classes, s.submit_queue_cap, s.default_deadline_ms
                ),
                None => String::new(),
            },
            if reg.is_empty() {
                String::new()
            } else {
                format!(", {} adapters", reg.len())
            }
        );
    }
    Ok(())
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let model_name = args.get("model", "tiny");
    let cfg = preset(&model_name)?;
    let steps = args.get_usize("steps", 200)?;
    let out = PathBuf::from(args.get("out", "checkpoints"));
    let rt = Runtime::new(&artifacts_dir(args))?;
    let (store, losses) = pretrain(&rt, &cfg, steps, args.get_f32("lr", 1e-3)?, 20250710)?;
    std::fs::create_dir_all(&out)?;
    let path = out.join(format!("base_{model_name}_{steps}.ckpt"));
    checkpoint::save(&store, &path, None)?;
    println!(
        "pretrained {model_name} ({} params) for {steps} steps: loss {:.3} -> {:.3}; saved {path:?}",
        cfg.n_params(),
        losses.first().unwrap_or(&f32::NAN),
        losses.last().unwrap_or(&f32::NAN)
    );
    Ok(())
}

fn cmd_quantize(args: &Args) -> Result<()> {
    let model_name = args.get("model", "tiny");
    let cfg = preset(&model_name)?;
    let bits: u32 = args.get_usize("bits", 4)? as u32;
    let base = args
        .opt("base")
        .context("--base <ckpt> required (from `lota pretrain`)")?;
    let fp = checkpoint::load(Path::new(base))?;
    let rt = Runtime::new(&artifacts_dir(args))?;
    let quantizer = args.get("quantizer", "gptq");
    let q = match quantizer.as_str() {
        "gptq" => {
            let hs = calibrate_hessians(&rt, &cfg, &fp, args.get_usize("calib-batches", 8)?, 7)?;
            quantize_model(&cfg, &fp, bits, Some(&hs))?
        }
        "rtn" => quantize_model(&cfg, &fp, bits, None)?,
        other => bail!("unknown quantizer '{other}'"),
    };
    let out = PathBuf::from(args.get(
        "out",
        &format!("checkpoints/quant_{model_name}_{quantizer}_w{bits}.ckpt"),
    ));
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    checkpoint::save(&q, &out, Some(bits))?;
    println!("quantized {model_name} to {bits}-bit via {quantizer}; saved {out:?}");
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let model_name = args.get("model", "tiny");
    let cfg = preset(&model_name)?;
    let exp = ExperimentConfig {
        model: model_name.clone(),
        method: Method::parse(&args.get("method", "lota"))?,
        n_bits: args.get_usize("bits", 4)? as u32,
        omega_frac: args.get_f32("omega-frac", 0.75)?,
        sigma_init: args.get_f32("sigma-init", 0.05)?,
        steps: args.get_usize("steps", 100)?,
        lr: args.get_f32("lr", 5e-4)?,
        seed: args.get_usize("seed", 20250710)? as u64,
        task: args.get("task", "recovery"),
        artifacts_dir: artifacts_dir(args).to_string_lossy().into_owned(),
        ..ExperimentConfig::default()
    };
    let rt = Runtime::new(&artifacts_dir(args))?;

    let mut store = match args.opt("base") {
        Some(path) => checkpoint::load(Path::new(path))?,
        None => {
            log::info!("no --base given: pretraining + quantizing a fresh base");
            let (fp, _) = pretrain(&rt, &cfg, 150, 1e-3, exp.seed)?;
            let hs = calibrate_hessians(&rt, &cfg, &fp, 4, exp.seed)?;
            quantize_model(&cfg, &fp, exp.n_bits, Some(&hs))?
        }
    };
    let mut rng = Rng::new(exp.seed ^ 0xADA7);
    model::init_adapters(&cfg, exp.method, &mut rng, &mut store);
    let report = finetune(&rt, &cfg, &exp, &mut store, &TrainOptions::default())?;
    println!(
        "finetuned {model_name}/{}/{}-bit on {}: loss {:.3} -> {:.3} in {:.1}s ({} steps)",
        exp.method.as_str(),
        exp.n_bits,
        exp.task,
        report.losses.first().unwrap_or(&f32::NAN),
        report.losses.last().unwrap_or(&f32::NAN),
        report.wall_secs,
        report.steps
    );
    if args.get("merge", "true") == "true" {
        let err = merge_into_store(&cfg, &exp, &mut store)?;
        println!(
            "merged adapters (max requant error {err:.2e}{})",
            if err == 0.0 { " — lossless" } else { "" }
        );
    }
    let out = PathBuf::from(args.get(
        "out",
        &format!(
            "checkpoints/ft_{model_name}_{}_w{}_{}.ckpt",
            exp.method.as_str(),
            exp.n_bits,
            exp.task
        ),
    ));
    if let Some(dir) = out.parent() {
        std::fs::create_dir_all(dir)?;
    }
    checkpoint::save(&store, &out, Some(exp.n_bits))?;
    println!("saved {out:?}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let model_name = args.get("model", "tiny");
    let cfg = preset(&model_name)?;
    let store = checkpoint::load(Path::new(
        args.opt("ckpt").context("--ckpt <path> required")?,
    ))?;
    let rt = Runtime::new(&artifacts_dir(args))?;
    let suite = args.get("suite", "mmlu");
    let n = args.get_usize("n", 64)?;
    // fp checkpoints (from `lota pretrain`) carry w_* tensors; quantized
    // ones carry q_* — route to the matching forward artifact.
    let fwd = if store.contains("w_wq") {
        format!("fwd_fp_{model_name}")
    } else {
        format!("fwd_merged_{model_name}")
    };
    let exe = rt.load(&fwd)?;
    match suite.as_str() {
        "mmlu" => {
            let qs = mmlu_like::generate_suite(n / 4, 0xE7A1);
            let scores = mmlu_eval(&rt, &exe, &store, &cfg, &qs, None)?;
            let mut t = Table::new(&["subject", "accuracy %"]);
            for (i, s) in mmlu_like::SUBJECTS.iter().enumerate() {
                t.row(&[s.to_string(), format!("{:.2}", scores.per_subject[i])]);
            }
            t.row(&["average".into(), format!("{:.2}", scores.average)]);
            t.print();
        }
        task => {
            let gen = tasks::task_by_name(task)?;
            let test = gen.test_set(n);
            let em = exact_match_eval(
                &rt,
                &exe,
                &store,
                &cfg,
                &test,
                lota_qaf::coordinator::experiments::max_new_for(task),
                None,
            )?;
            let ta = token_accuracy(&rt, &exe, &store, &cfg, &test, None)?;
            println!(
                "{task}: exact match {em:.2}%, token accuracy {ta:.2}% over {} examples",
                test.len()
            );
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    // serving defaults may come from an experiment TOML (--config:
    // `model`, `n_bits`, `serve_backend`); explicit flags win
    let exp = match args.opt("config") {
        Some(p) => ExperimentConfig::from_toml(&lota_qaf::config::TomlDoc::parse(
            &std::fs::read_to_string(p).with_context(|| format!("reading {p}"))?,
        )?)?,
        None => ExperimentConfig::default(),
    };
    let model_name = args.get("model", &exp.model);
    let cfg = preset(&model_name)?;
    // --synthetic true builds an in-process RTN-quantized store from
    // random weights: no checkpoint, no artifacts — enough to exercise
    // the whole serving path (the CI trace-smoke leg runs this)
    let synthetic = match args.opt("synthetic") {
        Some("true") | Some("on") => true,
        Some("false") | Some("off") | None => false,
        Some(other) => bail!("--synthetic wants true|false (got '{other}')"),
    };
    let store = if synthetic {
        let bits = args.get_usize("bits", exp.n_bits as usize)? as u32;
        let mut rng = Rng::new(args.get_usize("seed", 11)? as u64);
        let fp = model::init_fp(&cfg, &mut rng);
        model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(lota_qaf::quant::rtn_quantize(w, cfg.group_size, bits))
        })?
    } else {
        checkpoint::load(Path::new(
            args.opt("ckpt").context("--ckpt <path> required (or --synthetic true)")?,
        ))?
    };
    let backend = match args.opt("backend") {
        Some(s) => lota_qaf::config::Backend::parse(s)?,
        None => exp.backend,
    };
    // native-engine decode strategy: KV-cached (default) or the
    // full-prefix recompute reference; ignored by the pjrt backend
    let decode = match args.opt("decode") {
        Some(s) => lota_qaf::config::DecodeMode::parse(s)?,
        None => exp.decode,
    };
    // packed-GEMM kernel for the native engine: flag wins, else the
    // experiment TOML's `gemm_kernel`, else auto-detect
    let gemm_kernel = match args.opt("gemm-kernel") {
        Some(s) => lota_qaf::config::GemmKernel::parse(s)?,
        None => exp.gemm_kernel,
    };
    let path = match args.get("path", "merged").as_str() {
        "merged" => ServePath::Merged,
        "lora" => ServePath::LoraAdapter,
        other => bail!("unknown serve path '{other}'"),
    };
    // continuous-batching scheduler: --sched true routes native serving
    // through the request-level scheduler; defaults (and the opt-in when
    // the flag is absent) come from the [sched] TOML table
    let mut sched_cfg = match args.opt("sched") {
        Some("true") | Some("on") => Some(exp.sched.clone().unwrap_or_default()),
        Some("false") | Some("off") => None,
        Some(other) => bail!("--sched wants true|false (got '{other}')"),
        None => exp.sched.clone(),
    };
    if let Some(sc) = sched_cfg.as_mut() {
        sc.max_batch = args.get_usize("max-batch", sc.max_batch)?;
        sc.kv_budget_mb = args.get_usize("kv-budget-mb", sc.kv_budget_mb)?;
        sc.kv_block_size = args.get_usize("kv-block-size", sc.kv_block_size)?;
        sc.kv_paged = match args.opt("kv-paged") {
            Some("true") | Some("on") => true,
            Some("false") | Some("off") => false,
            Some(other) => bail!("--kv-paged wants true|false (got '{other}')"),
            None => sc.kv_paged,
        };
        // overload-control knobs: admission priority classes, the bounded
        // worker submit queue, and the default TTFT deadline (0 = none)
        sc.priority_classes = args.get_usize("priority-classes", sc.priority_classes)?;
        if !(1..=256).contains(&sc.priority_classes) {
            bail!("--priority-classes wants 1..=256 (got {})", sc.priority_classes);
        }
        sc.submit_queue_cap = args.get_usize("submit-queue-cap", sc.submit_queue_cap)?;
        sc.default_deadline_ms =
            args.get_usize("default-deadline-ms", sc.default_deadline_ms as usize)? as u64;
    }
    // bit width for the native engine's packed grids: flag, else the
    // checkpoint's own hint, else the experiment config
    let hint = checkpoint::n_bits_hint(&store);
    let bits = args.get_usize("bits", hint.unwrap_or(exp.n_bits) as usize)? as u32;
    let n = args.get_usize("requests", 32)?;
    let max_new = args.get_usize("max-new", 12)?;
    // the native engine serves straight from the checkpoint — only the
    // PJRT backend needs an artifacts directory
    let rt = match backend {
        lota_qaf::config::Backend::Pjrt => Some(Runtime::new(&artifacts_dir(args))?),
        lota_qaf::config::Backend::Native => None,
    };
    let mut opts = ServeOptions::new(path, max_new)
        .backend(backend)
        .bits(bits)
        .decode_mode(decode)
        .kernel(gemm_kernel);
    if let Some(sc) = &sched_cfg {
        opts = opts.scheduled(sc.clone());
    }
    // observability outputs: flags win over the experiment TOML's
    // trace_out / metrics_out keys
    let trace_out = args
        .opt("trace-out")
        .map(PathBuf::from)
        .or_else(|| exp.trace_out.as_ref().map(PathBuf::from));
    let metrics_out = args
        .opt("metrics-out")
        .map(PathBuf::from)
        .or_else(|| exp.metrics_out.as_ref().map(PathBuf::from));
    if trace_out.is_some() && sched_cfg.is_none() {
        bail!("--trace-out records scheduler span timelines: pass --sched true");
    }
    if let Some(p) = &trace_out {
        opts = opts.trace_out(p.clone());
    }
    let profile_out = args
        .opt("profile-out")
        .map(PathBuf::from)
        .or_else(|| exp.profile_out.as_ref().map(PathBuf::from));
    if profile_out.is_some() && sched_cfg.is_none() {
        bail!("--profile-out profiles the scheduled engine hot path: pass --sched true");
    }
    if let Some(p) = &profile_out {
        opts = opts.profile_out(p.clone());
    }

    // multi-adapter serving: --adapter (name=source,…) wins over the
    // experiment TOML's [adapters] table; requests spread round-robin
    // across the registered sets and mix freely per batch
    let adapters = match args.opt("adapter") {
        Some(s) => AdapterRegistry::parse_cli(s)?,
        None => AdapterRegistry::from_pairs(&exp.adapters)?,
    };
    let n_adapters = adapters.len();
    if n_adapters > 0 {
        if backend != lota_qaf::config::Backend::Native {
            bail!("--adapter serves on the native backend only");
        }
        if sched_cfg.is_none() {
            bail!("multi-adapter serving routes through the scheduler: pass --sched true");
        }
        opts = opts
            .with_adapters(adapters)
            .omega_frac(args.get_f32("omega-frac", exp.omega_frac)?);
    }

    // async front end: serve requests over HTTP/SSE until SIGTERM instead
    // of driving a fixed batch; the flag wins over the TOML `listen` key
    let listen = args.opt("listen").map(str::to_string).or_else(|| exp.listen.clone());
    if let Some(addr) = listen {
        if sched_cfg.is_none() {
            bail!("--listen serves through the scheduler: pass --sched true");
        }
        let report = serve_listen(&cfg, &store, &opts, &addr)?;
        let handoff = report.stats.handoff_ms.stats();
        println!(
            "drained after serving {} requests: queue handoff p50 {:.3}ms p95 {:.3}ms",
            report.responses.len(),
            handoff.p50,
            handoff.p95
        );
        return Ok(());
    }

    // open-loop mode: requests arrive over time (Poisson) instead of all
    // at t = 0 — the workload shape the scheduler exists for
    let rate = args.get_f32("arrival-rate", 0.0)?;
    if rate > 0.0 {
        if sched_cfg.is_none() {
            bail!("--arrival-rate needs the scheduler: pass --sched true");
        }
        let spec = LoadSpec {
            n_requests: n,
            rate_per_sec: rate as f64,
            seed: args.get_usize("load-seed", 123)? as u64,
            task: "arith".into(),
            max_new_mix: vec![max_new.max(1)],
        };
        let mut load = generate_load(&spec)?;
        spread_adapters(&mut load, n_adapters);
        let (_responses, report) = serve_open_loop(&cfg, &store, &opts, &load)?;
        println!(
            "served {} requests [native:sched gemm={}, open loop {rate} req/s] in {:.2}s: \
             {:.1} tok/s, {:.2} req/s, p50 {:.3}s p95 {:.3}s, \
             ttft p50 {:.1}ms p95 {:.1}ms, queue wait {:.1}ms",
            report.requests,
            report.gemm_kernel.unwrap_or("?"),
            report.wall_secs,
            report.tokens_per_sec,
            report.requests_per_sec,
            report.latency.p50,
            report.latency.p95,
            report.ttft_ms_p50,
            report.ttft_ms_p95,
            report.queue_wait_ms
        );
        print_adapter_usage(&report);
        if let Some(p) = &metrics_out {
            lota_qaf::obs::MetricsRegistry::from_report(&report).write(p)?;
            println!("metrics snapshot written to {}", p.display());
        }
        return Ok(());
    }

    let gen = tasks::task_by_name("arith")?;
    let mut rng = Rng::new(123);
    let prompts: Vec<String> = (0..n)
        .map(|_| gen.sample(&mut rng, tasks::Split::Test).prompt)
        .collect();

    // multi-adapter batch serving: the per-request adapter tag lives on
    // the scheduler's submit path, so route through the open-loop driver
    // with every arrival at t = 0 (identical admission behavior to the
    // plain scheduled drain)
    if n_adapters > 0 {
        let mut load: Vec<LoadRequest> = prompts
            .iter()
            .map(|p| LoadRequest {
                arrival_secs: 0.0,
                prompt: p.clone(),
                max_new,
                adapter: 0,
                priority: 0,
                deadline_ms: None,
            })
            .collect();
        spread_adapters(&mut load, n_adapters);
        let (_responses, report) = serve_open_loop(&cfg, &store, &opts, &load)?;
        println!(
            "served {} requests [native:sched gemm={}, {n_adapters} adapters] in {:.2}s: \
             {:.1} tok/s, {:.2} req/s, p50 {:.3}s p95 {:.3}s, \
             ttft p50 {:.1}ms p95 {:.1}ms, queue wait {:.1}ms",
            report.requests,
            report.gemm_kernel.unwrap_or("?"),
            report.wall_secs,
            report.tokens_per_sec,
            report.requests_per_sec,
            report.latency.p50,
            report.latency.p95,
            report.ttft_ms_p50,
            report.ttft_ms_p95,
            report.queue_wait_ms
        );
        print_adapter_usage(&report);
        if let Some(p) = &metrics_out {
            lota_qaf::obs::MetricsRegistry::from_report(&report).write(p)?;
            println!("metrics snapshot written to {}", p.display());
        }
        return Ok(());
    }

    let report = serve_batch(rt.as_ref(), &cfg, &store, &opts, &prompts)?;
    let backend_tag = match backend {
        lota_qaf::config::Backend::Native => {
            let mode = if sched_cfg.is_some() { "sched" } else { decode.as_str() };
            format!("native:{mode} gemm={}", report.gemm_kernel.unwrap_or("?"))
        }
        lota_qaf::config::Backend::Pjrt => "pjrt".to_string(),
    };
    println!(
        "served {} requests [{}] in {:.2}s: {:.1} tok/s, {:.2} req/s, p50 {:.3}s p95 {:.3}s",
        report.requests,
        backend_tag,
        report.wall_secs,
        report.tokens_per_sec,
        report.requests_per_sec,
        report.latency.p50,
        report.latency.p95
    );
    if report.sched.is_some() {
        println!(
            "  scheduler: ttft p50 {:.1}ms p95 {:.1}ms, mean queue wait {:.1}ms",
            report.ttft_ms_p50, report.ttft_ms_p95, report.queue_wait_ms
        );
    }
    if let Some(p) = &metrics_out {
        lota_qaf::obs::MetricsRegistry::from_report(&report).write(p)?;
        println!("metrics snapshot written to {}", p.display());
    }
    Ok(())
}

/// Per-adapter serving usage from a scheduled run's report (no-op for
/// untagged runs — the map only carries labels that served requests).
fn print_adapter_usage(report: &lota_qaf::serve::ThroughputReport) {
    if let Some(sched) = &report.sched {
        for (label, usage) in &sched.adapter_usage {
            println!("  adapter {label}: {} requests, {} tokens", usage.requests, usage.tokens);
        }
    }
}

/// The timing metrics every `BenchResult` carries, in report order. All
/// are durations — lower is better — so regressions are positive deltas.
const LEDGER_METRICS: [&str; 4] = ["mean_secs", "p50_secs", "p95_secs", "min_secs"];

/// One run snapshot: (bench, case) → the four metric values.
type RunSnapshot = BTreeMap<(String, String), [f64; 4]>;

/// The perf-gate decision for one gated-metric entry. Both deltas are
/// checked: vs the previous run (catches step regressions) **and** vs the
/// best run on record — prev alone lets a slow drift of just-under-gate
/// steps compound without bound (e.g. +15% per run forever), which is
/// exactly the hole a rolling CI history exists to close.
fn gate_regressed(d_prev: Option<f64>, d_best: f64, max_regress: f64) -> bool {
    d_prev.is_some_and(|d| d > max_regress) || d_best > max_regress
}

/// Load every `BENCH_*.json` under `dir` into one snapshot map.
fn load_bench_snapshot(dir: &Path) -> Result<RunSnapshot> {
    let mut snap = RunSnapshot::new();
    let mut files: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    files.sort();
    for f in files {
        let text =
            std::fs::read_to_string(&f).with_context(|| format!("reading {}", f.display()))?;
        let doc = lota_qaf::config::Json::parse(&text)
            .with_context(|| format!("parsing {}", f.display()))?;
        let bench = doc.get("bench")?.as_str()?.to_string();
        for r in doc.get("results")?.as_arr()? {
            let case = r.get("name")?.as_str()?.to_string();
            let mut vals = [0.0; 4];
            for (i, m) in LEDGER_METRICS.iter().enumerate() {
                vals[i] = r.get(m)?.as_f64()?;
            }
            snap.insert((bench.clone(), case), vals);
        }
    }
    Ok(snap)
}

/// `lota bench-report`: fold a directory of historical bench snapshots
/// (one subdirectory per run, sorted lexicographically — CI names them
/// by zero-padded run number) into a trend ledger of per-metric deltas
/// vs the previous run and vs the best run on record.
fn cmd_bench_report(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("dir", "bench-history"));
    let gate_metric = args.get("gate-metric", "min_secs");
    let gate_idx = LEDGER_METRICS
        .iter()
        .position(|m| *m == gate_metric)
        .with_context(|| format!("--gate-metric must be one of {LEDGER_METRICS:?}"))?;
    let max_regress = args.get_f32("max-regress", 0.20)? as f64;
    let fail_on_regress = match args.opt("fail-on-regress") {
        Some("true") | Some("on") => true,
        Some("false") | Some("off") | None => false,
        Some(other) => bail!("--fail-on-regress wants true|false (got '{other}')"),
    };

    // one subdirectory per run; a flat directory of BENCH_*.json is
    // accepted as a single-run history (first CI run, local smoke)
    let mut run_dirs: Vec<PathBuf> = std::fs::read_dir(&dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_dir())
        .collect();
    run_dirs.sort();
    let mut runs: Vec<(String, RunSnapshot)> = Vec::new();
    for rd in &run_dirs {
        let snap = load_bench_snapshot(rd)?;
        if !snap.is_empty() {
            let name = rd
                .file_name()
                .and_then(|n| n.to_str())
                .unwrap_or("run")
                .to_string();
            runs.push((name, snap));
        }
    }
    if runs.is_empty() {
        let snap = load_bench_snapshot(&dir)?;
        if snap.is_empty() {
            bail!("no BENCH_*.json reports under {}", dir.display());
        }
        runs.push((".".to_string(), snap));
    }

    let (latest_name, latest) = runs.last().expect("non-empty checked above");
    let history = &runs[..runs.len() - 1];
    let mut regressions: Vec<String> = Vec::new();
    let mut w = lota_qaf::config::JsonWriter::new();
    w.begin_obj();
    w.key("runs").begin_arr();
    for (name, _) in &runs {
        w.str(name);
    }
    w.end_arr();
    w.key("latest").str(latest_name);
    w.key("gate_metric").str(&gate_metric);
    w.key("max_regress_frac").num(max_regress);
    let mut table = Table::new(&["bench", "case", &gate_metric, "vs prev", "vs best"]);
    w.key("entries").begin_arr();
    for ((bench, case), vals) in latest {
        let prev = history.iter().rev().find_map(|(_, s)| s.get(&(bench.clone(), case.clone())));
        for (i, metric) in LEDGER_METRICS.iter().enumerate() {
            let value = vals[i];
            // best on record, current run included — 0.0 means "this run
            // is the best ever seen for this metric"
            let best = runs
                .iter()
                .filter_map(|(_, s)| s.get(&(bench.clone(), case.clone())).map(|v| v[i]))
                .fold(value, f64::min);
            let d_best = if best > 0.0 { value / best - 1.0 } else { 0.0 };
            w.begin_obj();
            w.key("bench").str(bench);
            w.key("case").str(case);
            w.key("metric").str(metric);
            w.key("value").num(value);
            w.key("best").num(best);
            w.key("delta_vs_best").num(d_best);
            let mut d_prev = None;
            if let Some(pv) = prev {
                let p = pv[i];
                w.key("prev").num(p);
                if p > 0.0 {
                    let d = value / p - 1.0;
                    w.key("delta_vs_prev").num(d);
                    d_prev = Some(d);
                }
            }
            let regressed = i == gate_idx && gate_regressed(d_prev, d_best, max_regress);
            w.key("regressed").bool(regressed);
            w.end_obj();
            if regressed {
                let vs_prev = d_prev
                    .map(|d| format!("{:+.1}% vs previous run", 1e2 * d))
                    .unwrap_or_else(|| "no previous run".to_string());
                regressions.push(format!(
                    "{bench}/{case} {metric}: {value:.6}s is {vs_prev}, {:+.1}% vs best on record",
                    1e2 * d_best
                ));
            }
            if i == gate_idx {
                table.row(&[
                    bench.clone(),
                    case.clone(),
                    format!("{value:.6}"),
                    d_prev.map_or("-".to_string(), |d| format!("{:+.1}%", 1e2 * d)),
                    format!("{:+.1}%", 1e2 * d_best),
                ]);
            }
        }
    }
    w.end_arr();
    w.key("regressions").num(regressions.len() as f64);
    w.end_obj();
    let ledger = w.finish();
    println!(
        "# bench trend over {} run(s), latest '{latest_name}', gate {gate_metric} @ {:.0}%",
        runs.len(),
        1e2 * max_regress
    );
    table.print();
    if let Some(out) = args.opt("out") {
        let out = PathBuf::from(out);
        if let Some(parent) = out.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&out, &ledger)?;
        println!("trend ledger written to {}", out.display());
    } else {
        println!("{ledger}");
    }
    if !regressions.is_empty() {
        for r in &regressions {
            eprintln!("regression: {r}");
        }
        if fail_on_regress {
            bail!("{} bench regression(s) past the {:.0}% gate", regressions.len(), 1e2 * max_regress);
        }
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let model_name = args.get("model", "tiny");
    let steps = args.get_usize("steps", 40)?;
    let eval_n = args.get_usize("eval-n", 32)?;
    let pre = args.get_usize("pretrain-steps", 150)?;
    println!("# Table 1 (simulator scale): model={model_name} steps={steps} eval_n={eval_n}");
    let ctx = ExperimentContext::build(&artifacts_dir(args), &model_name, pre, 20250710)?;
    let tasks_list = ["arith", "sql", "datatotext"];
    let rows = run_table1(&ctx, steps, eval_n, &[4, 3, 2], &tasks_list)?;
    print_table1(&rows, &tasks_list);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    let m = rt.manifest();
    let mut t = Table::new(&["artifact", "kind", "cfg", "ins", "outs"]);
    for spec in m.artifacts.values() {
        t.row(&[
            spec.name.clone(),
            spec.kind.clone(),
            spec.cfg.clone().unwrap_or_default(),
            spec.inputs.len().to_string(),
            spec.outputs.len().to_string(),
        ]);
    }
    t.print();
    for name in ["tiny", "small", "medium"] {
        let cfg = preset(name)?;
        println!(
            "{name}: {} params, d={} L={} T={} gs={} r={} step-batch={}",
            cfg.n_params(),
            cfg.d_model,
            cfg.n_layers,
            cfg.seq_len,
            cfg.group_size,
            cfg.rank,
            step_batch(name)
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(pairs: &[(&str, &str)]) -> Args {
        let map = pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        Args { map }
    }

    fn write_bench_run(dir: &Path, run: &str, secs: f64) {
        let rd = dir.join(run);
        std::fs::create_dir_all(&rd).unwrap();
        let body = format!(
            "{{\"bench\": \"gemm\", \"meta\": {{}}, \"results\": [{{\
             \"name\": \"pack4\", \"iters\": 10, \"mean_secs\": {secs}, \
             \"p50_secs\": {secs}, \"p95_secs\": {secs}, \"min_secs\": {secs}}}]}}"
        );
        std::fs::write(rd.join("BENCH_gemm.json"), body).unwrap();
    }

    #[test]
    fn gate_trips_on_prev_or_best() {
        // the classic step regression: prev gate fires
        assert!(gate_regressed(Some(0.25), 0.25, 0.20));
        // slow drift: each step below the gate, cumulative above it
        assert!(gate_regressed(Some(0.15), 0.32, 0.20));
        // first run after a history wipe can still trip on best
        assert!(gate_regressed(None, 0.40, 0.20));
        // healthy entries pass both
        assert!(!gate_regressed(Some(0.05), 0.10, 0.20));
        assert!(!gate_regressed(None, 0.0, 0.20));
    }

    #[test]
    fn bench_report_staircase_drift_trips_best_gate() {
        let dir = std::env::temp_dir().join("lota_bench_report_staircase_test");
        std::fs::remove_dir_all(&dir).ok();
        // +15% per run: every delta_vs_prev is below the 20% gate, but by
        // run three the drift vs best is +32.25%
        write_bench_run(&dir, "run-0000000001", 0.100);
        write_bench_run(&dir, "run-0000000002", 0.115);
        let dir_str = dir.to_str().unwrap();
        let gated = [("dir", dir_str), ("fail-on-regress", "true")];
        // two runs: +15% vs prev and vs best — passes
        cmd_bench_report(&args(&gated)).unwrap();
        write_bench_run(&dir, "run-0000000003", 0.13225);
        // three runs: +15% vs prev still passes, +32% vs best trips
        let err = cmd_bench_report(&args(&gated)).unwrap_err();
        assert!(err.to_string().contains("regression"), "unexpected error: {err}");
        // reporting without the gate flag still succeeds on the same data
        cmd_bench_report(&args(&[("dir", dir_str)])).unwrap();
        // and a looser gate tolerates the whole staircase
        cmd_bench_report(&args(&[
            ("dir", dir_str),
            ("fail-on-regress", "true"),
            ("max-regress", "0.40"),
        ]))
        .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
