//! Data substrate: tokenizer, synthetic corpora, task generators, the
//! MMLU-like evaluation suite and batch assembly. Everything is
//! deterministic from seeds (DESIGN.md §2 documents how each piece stands
//! in for the paper's datasets).

pub mod batch;
pub mod corpus;
pub mod mmlu_like;
pub mod tasks;
pub mod tokenizer;

pub use batch::{encode_example, lm_batch, prompt_batch, sft_batch, Batch, BatchStream};
pub use tasks::{task_by_name, Example, Split, TaskGen};
