//! Task-specific fine-tuning datasets — the synthetic stand-ins for the
//! paper's GSM8K / SQL-generation / ViGGO (DESIGN.md §2), each with a
//! deterministic generator, disjoint train/test splits (hash-partitioned
//! on the latent example id), and exact-match scoring of greedy decodes —
//! mirroring HALO's evaluation harness that the paper follows.
//!
//! * `arith`      — two-step arithmetic word problems → final integer
//! * `sql`        — NL requests compiled onto a fixed schema grammar
//! * `datatotext` — attribute dict → templated utterance (ViGGO-like)

use crate::data::corpus;
use crate::tensor::Rng;

use anyhow::{bail, Result};

/// One prompt/completion pair. The model is trained on
/// `BOS prompt | completion EOS` with the loss masked to the completion.
#[derive(Clone, Debug, PartialEq)]
pub struct Example {
    pub prompt: String,
    pub completion: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// Deterministic split: ~1/8 of ids land in Test.
fn split_of(id: u64) -> Split {
    // splitmix-style avalanche so consecutive ids scatter
    let mut z = id.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    if (z ^ (z >> 31)) % 8 == 0 {
        Split::Test
    } else {
        Split::Train
    }
}

pub trait TaskGen {
    fn name(&self) -> &'static str;
    /// Total latent example space.
    fn space(&self) -> u64;
    /// Render example `id`.
    fn render(&self, id: u64) -> Example;

    /// Sample an example of the requested split.
    fn sample(&self, rng: &mut Rng, split: Split) -> Example {
        loop {
            let id = rng.next_u64() % self.space();
            if split_of(id) == split {
                return self.render(id);
            }
        }
    }

    /// A deterministic test set (first `n` test-split ids in order).
    fn test_set(&self, n: usize) -> Vec<Example> {
        let mut out = Vec::with_capacity(n);
        let mut id = 0u64;
        while out.len() < n && id < self.space() {
            if split_of(id) == Split::Test {
                out.push(self.render(id));
            }
            id += 1;
        }
        out
    }
}

// ---------------------------------------------------------------------------

/// GSM8K stand-in: "x has A <obj> and gets B more then loses C . how many ?"
pub struct ArithTask;

impl TaskGen for ArithTask {
    fn name(&self) -> &'static str {
        "arith"
    }

    fn space(&self) -> u64 {
        // a in 0..30, b in 0..30, c in 0..(a+b) bounded 30, name, object
        30 * 30 * 30 * 15 * 4
    }

    fn render(&self, id: u64) -> Example {
        let a = (id % 30) as i64;
        let b = ((id / 30) % 30) as i64;
        let c_raw = ((id / 900) % 30) as i64;
        let c = c_raw.min(a + b); // keep answers non-negative
        let name = corpus::names()[((id / 27000) % 15) as usize];
        let obj = ["apples", "coins", "books", "cards"][((id / 405000) % 4) as usize];
        Example {
            prompt: format!(
                "{name} has {a} {obj} and gets {b} more then loses {c} . how many ?"
            ),
            completion: format!("{}", a + b - c),
        }
    }
}

// ---------------------------------------------------------------------------

/// SQL stand-in: NL request → query over a fixed table grammar.
pub struct SqlTask;

const SQL_COLS: &[&str] = &["name", "age", "city", "score", "team"];
const SQL_TABLES: &[&str] = &["users", "players", "staff"];
const SQL_OPS: &[(&str, &str)] = &[("over", ">"), ("under", "<"), ("exactly", "=")];

impl TaskGen for SqlTask {
    fn name(&self) -> &'static str {
        "sql"
    }

    fn space(&self) -> u64 {
        // select-col × table × filter-col × op × value(0..100)
        (SQL_COLS.len() * SQL_TABLES.len() * SQL_COLS.len() * SQL_OPS.len() * 100) as u64
    }

    fn render(&self, id: u64) -> Example {
        let ncols = SQL_COLS.len() as u64;
        let sel = SQL_COLS[(id % ncols) as usize];
        let table = SQL_TABLES[((id / ncols) % SQL_TABLES.len() as u64) as usize];
        let fcol = SQL_COLS
            [((id / (ncols * SQL_TABLES.len() as u64)) % ncols) as usize];
        let op_idx = ((id / (ncols * ncols * SQL_TABLES.len() as u64))
            % SQL_OPS.len() as u64) as usize;
        let (word, op) = SQL_OPS[op_idx];
        let val = (id / (ncols * ncols * SQL_TABLES.len() as u64 * SQL_OPS.len() as u64))
            % 100;
        Example {
            prompt: format!("get {sel} of {table} with {fcol} {word} {val}"),
            completion: format!("select {sel} from {table} where {fcol} {op} {val}"),
        }
    }
}

// ---------------------------------------------------------------------------

/// ViGGO stand-in: attribute dictionary → templated utterance.
pub struct DataToTextTask;

const GAMES: &[&str] = &[
    "pacman", "tetris", "pong", "doom", "myst", "zork", "portal", "halo",
    "mario", "sonic",
];
const GENRES: &[&str] = &["arcade", "puzzle", "shooter", "adventure"];
const RATINGS: &[&str] = &["good", "great", "poor", "mixed"];
const YEARS_BASE: u64 = 1980;

impl TaskGen for DataToTextTask {
    fn name(&self) -> &'static str {
        "datatotext"
    }

    fn space(&self) -> u64 {
        (GAMES.len() * GENRES.len() * RATINGS.len() * 40) as u64
    }

    fn render(&self, id: u64) -> Example {
        let g = GAMES[(id % GAMES.len() as u64) as usize];
        let genre =
            GENRES[((id / GAMES.len() as u64) % GENRES.len() as u64) as usize];
        let rating = RATINGS[((id / (GAMES.len() * GENRES.len()) as u64)
            % RATINGS.len() as u64) as usize];
        let year = YEARS_BASE
            + (id / (GAMES.len() * GENRES.len() * RATINGS.len()) as u64) % 40;
        Example {
            prompt: format!("name = {g} , genre = {genre} , year = {year} , rating = {rating}"),
            completion: format!(
                "{g} is a {genre} game from {year} with {rating} reviews"
            ),
        }
    }
}

// ---------------------------------------------------------------------------

/// The "recovery" pseudo-task: Alpaca-like generic instruction data (no
/// fixed latent space; splits do not apply — evaluation is the MMLU-like
/// suite instead of exact match).
pub struct RecoveryTask;

impl RecoveryTask {
    pub fn sample(&self, rng: &mut Rng) -> Example {
        let (prompt, completion) = corpus::sample_recovery_example(rng);
        Example { prompt, completion }
    }
}

/// Look up a task generator by config name.
pub fn task_by_name(name: &str) -> Result<Box<dyn TaskGen + Send + Sync>> {
    Ok(match name {
        "arith" => Box::new(ArithTask),
        "sql" => Box::new(SqlTask),
        "datatotext" => Box::new(DataToTextTask),
        _ => bail!("unknown task '{name}' (arith|sql|datatotext|recovery)"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer;

    fn check_task(t: &dyn TaskGen) {
        // renders are tokenizable and deterministic
        for id in [0u64, 1, 17, t.space() - 1] {
            let e1 = t.render(id);
            let e2 = t.render(id);
            assert_eq!(e1, e2);
            tokenizer::encode(&e1.prompt);
            tokenizer::encode(&e1.completion);
        }
    }

    #[test]
    fn all_tasks_render_and_tokenize() {
        check_task(&ArithTask);
        check_task(&SqlTask);
        check_task(&DataToTextTask);
    }

    #[test]
    fn arith_answers_are_correct() {
        let t = ArithTask;
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let e = t.sample(&mut rng, Split::Train);
            // parse "N has A obj and gets B more then loses C ..."
            let words: Vec<&str> = e.prompt.split(' ').collect();
            let a: i64 = words[2].parse().unwrap();
            let b: i64 = words[6].parse().unwrap();
            let c: i64 = words[10].parse().unwrap();
            assert_eq!(e.completion, format!("{}", a + b - c));
            assert!(a + b - c >= 0);
        }
    }

    #[test]
    fn splits_are_disjoint_and_nonempty() {
        let t = SqlTask;
        let mut train_ids = std::collections::HashSet::new();
        let mut rng = Rng::new(2);
        for _ in 0..500 {
            train_ids.insert(t.sample(&mut rng, Split::Train).prompt);
        }
        let test = t.test_set(64);
        assert_eq!(test.len(), 64);
        for e in &test {
            assert!(
                !train_ids.contains(&e.prompt),
                "test example leaked into train: {}",
                e.prompt
            );
        }
    }

    #[test]
    fn test_set_is_deterministic() {
        let a = ArithTask.test_set(32);
        let b = ArithTask.test_set(32);
        assert_eq!(a, b);
    }

    #[test]
    fn split_fraction_is_about_an_eighth() {
        let n = 10_000u64;
        let tests = (0..n).filter(|&i| split_of(i) == Split::Test).count();
        let frac = tests as f64 / n as f64;
        assert!((0.09..0.16).contains(&frac), "test frac {frac}");
    }

    #[test]
    fn task_lookup() {
        assert!(task_by_name("arith").is_ok());
        assert!(task_by_name("sql").is_ok());
        assert!(task_by_name("datatotext").is_ok());
        assert!(task_by_name("mmlu").is_err());
    }
}
