//! Char-level tokenizer over a fixed 64-symbol alphabet.
//!
//! Mirrors `python/compile/configs.py::VOCAB = 64` — the HLO artifacts bake
//! this vocabulary size into the embedding/head shapes, so the alphabet is
//! part of the cross-language contract (checked by a unit test against the
//! manifest's config block at runtime).

pub const VOCAB: usize = 64;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
/// prompt/completion separator (rendered as '|')
pub const SEP: u32 = 3;

/// symbol table for ids 4..: letters, digits, space and task punctuation.
const SYMBOLS: &[char] = &[
    'a', 'b', 'c', 'd', 'e', 'f', 'g', 'h', 'i', 'j', 'k', 'l', 'm', 'n', 'o',
    'p', 'q', 'r', 's', 't', 'u', 'v', 'w', 'x', 'y', 'z', '0', '1', '2', '3',
    '4', '5', '6', '7', '8', '9', ' ', '+', '-', '*', '=', '>', '<', '(', ')',
    ',', '.', ':', '?', '_',
];

/// Encode one char; `None` if outside the alphabet.
pub fn encode_char(c: char) -> Option<u32> {
    match c {
        '|' => Some(SEP),
        _ => SYMBOLS
            .iter()
            .position(|s| *s == c)
            .map(|i| (i + 4) as u32),
    }
}

pub fn decode_char(id: u32) -> char {
    match id {
        PAD => '\u{2400}', // visible control pictures for specials
        BOS => '\u{2402}',
        EOS => '\u{2403}',
        SEP => '|',
        _ => SYMBOLS
            .get(id as usize - 4)
            .copied()
            .unwrap_or('\u{fffd}'),
    }
}

/// Encode a string; panics on out-of-alphabet chars (all generators emit
/// only alphabet chars, so a panic here is a bug, not a data problem).
pub fn encode(s: &str) -> Vec<u32> {
    s.chars()
        .map(|c| encode_char(c).unwrap_or_else(|| panic!("char '{c}' not in alphabet")))
        .collect()
}

pub fn decode(ids: &[u32]) -> String {
    ids.iter()
        .take_while(|&&id| id != EOS)
        .filter(|&&id| id != PAD && id != BOS)
        .map(|&id| decode_char(id))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alphabet_fits_vocab() {
        assert!(SYMBOLS.len() + 4 <= VOCAB, "{} symbols", SYMBOLS.len());
    }

    #[test]
    fn roundtrip_ascii() {
        let s = "select name from t where age > 30";
        let ids = encode(s);
        assert_eq!(decode(&ids), s);
    }

    #[test]
    fn no_symbol_collisions() {
        let mut seen = std::collections::HashSet::new();
        for c in SYMBOLS {
            assert!(seen.insert(*c), "duplicate symbol '{c}'");
            let id = encode_char(*c).unwrap();
            assert_eq!(decode_char(id), *c);
        }
    }

    #[test]
    fn sep_is_pipe() {
        assert_eq!(encode("a|b"), vec![4, SEP, 5]);
    }

    #[test]
    fn decode_stops_at_eos() {
        let ids = vec![4, 5, EOS, 6, 7];
        assert_eq!(decode(&ids), "ab");
    }

    #[test]
    #[should_panic]
    fn out_of_alphabet_panics() {
        encode("é");
    }
}
