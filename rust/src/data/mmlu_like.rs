//! MMLU-like multi-subject multiple-choice benchmark (DESIGN.md §2).
//!
//! Four subjects mirror the paper's MMLU groups (Humanities / STEM /
//! Social / Other → the four corpus domains). Scoring follows lm-eval's
//! likelihood protocol: each option is appended to the question context
//! and scored by the length-normalized log-likelihood of its tokens; the
//! model answers with the argmax option. Contexts deliberately match the
//! pretraining-corpus surface forms, so the suite probes *retained
//! knowledge* — exactly what quantization destroys and recovery
//! fine-tuning restores (the Table 1 / Fig. 1 dynamic).
//!
//! Held-out discipline: suite questions draw from the same fixed world
//! model (`corpus::animal_class`, `corpus::social_fact`) the corpus
//! teaches, but the suite seed never feeds the training samplers.

use crate::data::corpus;
use crate::tensor::Rng;

pub const SUBJECTS: [&str; 4] = ["facts", "math", "social", "seq"];
pub const N_OPTIONS: usize = 4;

/// One likelihood-scored multiple-choice question.
#[derive(Clone, Debug)]
pub struct Question {
    pub subject: usize,
    /// context the options complete, e.g. `"a robin is a "`
    pub context: String,
    pub options: [String; 4],
    /// index of the correct option
    pub answer: usize,
}

fn rotate(opts: &mut [String; 4], answer: usize, rng: &mut Rng) -> usize {
    let rot = rng.below(4);
    opts.rotate_left(rot);
    (answer + 4 - rot) % 4
}

fn gen_question(subject: usize, rng: &mut Rng) -> Question {
    match subject {
        0 => {
            let a = *rng.choose(corpus::animals());
            let correct = corpus::animal_class(a).to_string();
            let mut opts = ["bird", "fish", "reptile", "mammal"].map(|s| s.to_string());
            let answer = opts.iter().position(|o| *o == correct).unwrap();
            let answer = rotate(&mut opts, answer, rng);
            Question { subject, context: format!("a {a} is a "), options: opts, answer }
        }
        1 => {
            let a = rng.below(50);
            let b = rng.below(50);
            let correct = a + b;
            let distract = [
                correct + 1 + rng.below(3),
                (correct + 7 + rng.below(5)) % 100,
                correct.saturating_sub(2 + rng.below(4)),
            ];
            let mut opts = [
                correct.to_string(),
                distract[0].to_string(),
                distract[1].to_string(),
                distract[2].to_string(),
            ];
            // dedupe collisions deterministically
            for i in 1..4 {
                while opts[..i].contains(&opts[i]) {
                    let bump: usize = opts[i].parse::<usize>().unwrap() + 11;
                    opts[i] = (bump % 113).to_string();
                }
            }
            let answer = rotate(&mut opts, 0, rng);
            Question { subject, context: format!("{a} + {b} = "), options: opts, answer }
        }
        2 => {
            let i = rng.below(corpus::names().len() * corpus::verbs().len());
            let (s, v, o) = corpus::social_fact(i);
            let names = corpus::names();
            let mut opts = [
                names[o].to_string(),
                names[(o + 1) % names.len()].to_string(),
                names[(o + 4) % names.len()].to_string(),
                names[(o + 7) % names.len()].to_string(),
            ];
            let answer = rotate(&mut opts, 0, rng);
            Question {
                subject,
                context: format!("{} {v} ", names[s]),
                options: opts,
                answer,
            }
        }
        _ => {
            let start = rng.below(22);
            let ch = |k: usize| ((b'a' + ((start + k) % 26) as u8) as char).to_string();
            let mut opts = [ch(3), ch(5), ch(9), ch(14)];
            let answer = rotate(&mut opts, 0, rng);
            Question {
                subject,
                context: format!("{} {} {} ", ch(0), ch(1), ch(2)),
                options: opts,
                answer,
            }
        }
    }
}

/// Deterministic evaluation suite: `per_subject` questions per subject.
pub fn generate_suite(per_subject: usize, seed: u64) -> Vec<Question> {
    let mut out = Vec::with_capacity(per_subject * SUBJECTS.len());
    for subject in 0..SUBJECTS.len() {
        let mut rng = Rng::new(seed ^ (subject as u64 + 1).wrapping_mul(0x9E3779B9));
        for _ in 0..per_subject {
            out.push(gen_question(subject, &mut rng));
        }
    }
    out
}

/// Accuracy aggregation per subject + average (the Table 1 row format).
#[derive(Clone, Debug, Default)]
pub struct MmluScores {
    pub per_subject: [f32; 4],
    pub average: f32,
}

pub fn aggregate(results: &[(usize, bool)]) -> MmluScores {
    let mut correct = [0usize; 4];
    let mut total = [0usize; 4];
    for (subject, ok) in results {
        total[*subject] += 1;
        if *ok {
            correct[*subject] += 1;
        }
    }
    let mut s = MmluScores::default();
    let mut sum = 0.0;
    for i in 0..4 {
        s.per_subject[i] = if total[i] > 0 {
            100.0 * correct[i] as f32 / total[i] as f32
        } else {
            0.0
        };
        sum += s.per_subject[i];
    }
    s.average = sum / 4.0;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer;

    #[test]
    fn suite_is_deterministic_and_tokenizable() {
        let a = generate_suite(8, 42);
        let b = generate_suite(8, 42);
        assert_eq!(a.len(), 32);
        for (qa, qb) in a.iter().zip(&b) {
            assert_eq!(qa.context, qb.context);
            assert_eq!(qa.answer, qb.answer);
            tokenizer::encode(&qa.context);
            for o in &qa.options {
                tokenizer::encode(o);
            }
        }
    }

    #[test]
    fn options_are_distinct_and_answer_correct() {
        for q in generate_suite(50, 7) {
            for i in 0..4 {
                for j in 0..i {
                    assert_ne!(q.options[i], q.options[j], "{q:?}");
                }
            }
            assert!(q.answer < 4);
            // spot-check subject-0 semantics: correct option matches the world
            if q.subject == 0 {
                let animal = q.context.split(' ').nth(1).unwrap();
                assert_eq!(q.options[q.answer], corpus::animal_class(animal));
            }
            if q.subject == 1 {
                let parts: Vec<&str> = q.context.split(' ').collect();
                let a: usize = parts[0].parse().unwrap();
                let b: usize = parts[2].parse().unwrap();
                assert_eq!(q.options[q.answer], (a + b).to_string());
            }
        }
    }

    #[test]
    fn answer_positions_are_balanced() {
        let qs = generate_suite(60, 3);
        let mut counts = [0usize; 4];
        for q in &qs {
            counts[q.answer] += 1;
        }
        for c in counts {
            assert!(c > 30, "answer positions skewed: {counts:?}");
        }
    }

    #[test]
    fn aggregate_computes_per_subject() {
        let results = vec![(0, true), (0, false), (1, true), (2, true), (3, false)];
        let s = aggregate(&results);
        assert_eq!(s.per_subject[0], 50.0);
        assert_eq!(s.per_subject[1], 100.0);
        assert_eq!(s.average, (50.0 + 100.0 + 100.0 + 0.0) / 4.0);
    }
}
