//! Synthetic pretraining corpus + the "Alpaca-like" recovery mix.
//!
//! Four deterministic domains play the role of the paper's data world
//! (DESIGN.md §2): the same four families structure the MMLU-like eval, so
//! "performance recovery" means the same thing here as in the paper —
//! generic fine-tuning data restores general abilities measured on held-out
//! multi-domain questions.
//!
//! * `facts`   — templated taxonomy facts           ("a robin is a bird")
//! * `math`    — arithmetic equalities               ("12 + 7 = 19")
//! * `social`  — relation triples                    ("mia likes ben")
//! * `seq`     — alphabet/counting patterns          ("a b c d e")
//!
//! Every sampler takes the RNG by value-of-state, so corpora are fully
//! reproducible from a seed.

use crate::tensor::Rng;

pub const DOMAINS: [&str; 4] = ["facts", "math", "social", "seq"];

const ANIMALS: &[&str] = &[
    "robin", "eagle", "crow", "owl", "shark", "trout", "salmon", "cobra",
    "gecko", "turtle", "wolf", "fox", "bear", "otter", "horse",
];
const CLASSES: &[&str] = &["bird", "fish", "reptile", "mammal"];
const NAMES: &[&str] = &[
    "mia", "ben", "ana", "leo", "zoe", "max", "eva", "sam", "ivy", "dan",
    "amy", "tom", "lia", "rex", "kim",
];
const VERBS: &[&str] = &["likes", "helps", "knows", "meets"];

/// class of an animal — a fixed world model shared by corpus + eval.
pub fn animal_class(animal: &str) -> &'static str {
    let idx = ANIMALS.iter().position(|a| *a == animal).unwrap_or(0);
    CLASSES[match idx {
        0..=3 => 0,  // birds
        4..=6 => 1,  // fish
        7..=9 => 2,  // reptiles
        _ => 3,      // mammals
    }]
}

pub fn animals() -> &'static [&'static str] {
    ANIMALS
}

pub fn names() -> &'static [&'static str] {
    NAMES
}

pub fn verbs() -> &'static [&'static str] {
    VERBS
}

/// deterministic "who likes whom" world: person i relates to person
/// (i*7+3) mod n with verb (i mod verbs).
pub fn social_fact(i: usize) -> (usize, &'static str, usize) {
    let n = NAMES.len();
    (i % n, VERBS[i % VERBS.len()], (i * 7 + 3) % n)
}

/// One pretraining sentence from the given domain.
pub fn sample_sentence(domain: usize, rng: &mut Rng) -> String {
    match domain % 4 {
        0 => {
            let a = rng.choose(ANIMALS);
            format!("a {a} is a {}", animal_class(a))
        }
        1 => {
            let a = rng.below(50);
            let b = rng.below(50);
            if rng.below(2) == 0 {
                format!("{a} + {b} = {}", a + b)
            } else {
                let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
                format!("{hi} - {lo} = {}", hi - lo)
            }
        }
        2 => {
            let i = rng.below(NAMES.len() * VERBS.len());
            let (s, v, o) = social_fact(i);
            format!("{} {v} {}", NAMES[s], NAMES[o])
        }
        _ => {
            // rotating alphabet window or counting run
            if rng.below(2) == 0 {
                let start = rng.below(20);
                let len = rng.range(4, 8);
                (start..start + len)
                    .map(|i| ((b'a' + (i % 26) as u8) as char).to_string())
                    .collect::<Vec<_>>()
                    .join(" ")
            } else {
                let start = rng.below(20);
                let len = rng.range(4, 8);
                (start..start + len)
                    .map(|i| i.to_string())
                    .collect::<Vec<_>>()
                    .join(" ")
            }
        }
    }
}

/// A pretraining document: a few sentences joined by periods, mixing
/// domains uniformly.
pub fn sample_document(rng: &mut Rng) -> String {
    let n = rng.range(2, 5);
    (0..n)
        .map(|_| sample_sentence(rng.below(4), rng))
        .collect::<Vec<_>>()
        .join(" . ")
}

/// The "Alpaca-like" recovery instruction: a domain sentence rendered as a
/// question/answer pair. Generic (covers all domains), which is what makes
/// it performance-recovery rather than task-specific data.
pub fn sample_recovery_example(rng: &mut Rng) -> (String, String) {
    match rng.below(4) {
        0 => {
            let a = rng.choose(ANIMALS);
            (format!("what is a {a} ?"), format!("a {a} is a {}", animal_class(a)))
        }
        1 => {
            let a = rng.below(50);
            let b = rng.below(50);
            (format!("{a} + {b} = ?"), format!("{}", a + b))
        }
        2 => {
            let i = rng.below(NAMES.len() * VERBS.len());
            let (s, v, o) = social_fact(i);
            (format!("who does {} {v} ?", NAMES[s]), NAMES[o].to_string())
        }
        _ => {
            let start = rng.below(20);
            (
                format!(
                    "continue: {} {} {}",
                    ((b'a' + (start % 26) as u8) as char),
                    ((b'a' + ((start + 1) % 26) as u8) as char),
                    ((b'a' + ((start + 2) % 26) as u8) as char)
                ),
                format!("{}", ((b'a' + ((start + 3) % 26) as u8) as char)),
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer;

    #[test]
    fn sentences_are_tokenizable() {
        let mut rng = Rng::new(1);
        for d in 0..4 {
            for _ in 0..50 {
                let s = sample_sentence(d, &mut rng);
                let ids = tokenizer::encode(&s); // panics on bad char
                assert!(!ids.is_empty());
            }
        }
    }

    #[test]
    fn documents_are_deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..20 {
            assert_eq!(sample_document(&mut a), sample_document(&mut b));
        }
    }

    #[test]
    fn world_model_is_consistent() {
        assert_eq!(animal_class("robin"), "bird");
        assert_eq!(animal_class("shark"), "fish");
        assert_eq!(animal_class("gecko"), "reptile");
        assert_eq!(animal_class("fox"), "mammal");
    }

    #[test]
    fn math_sentences_are_correct() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let s = sample_sentence(1, &mut rng);
            // parse "a op b = c" and check
            let parts: Vec<&str> = s.split(' ').collect();
            let a: i64 = parts[0].parse().unwrap();
            let b: i64 = parts[2].parse().unwrap();
            let c: i64 = parts[4].parse().unwrap();
            match parts[1] {
                "+" => assert_eq!(a + b, c),
                "-" => assert_eq!(a - b, c),
                op => panic!("unexpected op {op}"),
            }
        }
    }

    #[test]
    fn recovery_examples_cover_domains() {
        let mut rng = Rng::new(4);
        let mut qs = std::collections::HashSet::new();
        for _ in 0..200 {
            let (q, a) = sample_recovery_example(&mut rng);
            tokenizer::encode(&q);
            tokenizer::encode(&a);
            qs.insert(q);
        }
        assert!(qs.len() > 100, "should be diverse, got {}", qs.len());
    }
}
