//! Batch assembly: fixed-shape (B, T) f32 buffers for the HLO step/fwd
//! artifacts — tokens, next-token targets, and the loss mask.
//!
//! All artifact inputs are f32 by convention (the graphs cast to int32
//! internally), so batches are built directly as f32 vectors ready for
//! literal marshalling.

use crate::data::tasks::Example;
use crate::data::tokenizer::{self, BOS, EOS, PAD, SEP};
use crate::tensor::Rng;

/// A fixed-shape training/eval batch.
#[derive(Clone, Debug)]
pub struct Batch {
    pub batch: usize,
    pub seq: usize,
    /// (B, T) input token ids (f32-coded)
    pub tokens: Vec<f32>,
    /// (B, T) next-token targets
    pub targets: Vec<f32>,
    /// (B, T) loss mask (1.0 on positions that contribute to the loss)
    pub mask: Vec<f32>,
}

impl Batch {
    fn empty(batch: usize, seq: usize) -> Batch {
        Batch {
            batch,
            seq,
            tokens: vec![PAD as f32; batch * seq],
            targets: vec![PAD as f32; batch * seq],
            mask: vec![0.0; batch * seq],
        }
    }

    /// Write one sequence of ids into row `row`, computing shifted targets.
    /// `mask_from`: first position (in the *target* frame) that contributes
    /// to the loss; use 0 to train on the whole sequence (LM pretraining),
    /// or the completion start for instruction tuning.
    fn fill_row(&mut self, row: usize, ids: &[u32], mask_from: usize) {
        let t = self.seq;
        let n = ids.len().min(t + 1); // ids[t] can still serve as a target
        for p in 0..t {
            let idx = row * t + p;
            if p < n {
                self.tokens[idx] = ids[p] as f32;
            }
            if p + 1 < n {
                self.targets[idx] = ids[p + 1] as f32;
                if p + 1 >= mask_from.max(1) {
                    self.mask[idx] = 1.0;
                }
            }
        }
    }
}

/// Tokenize an instruction example as `BOS prompt | completion EOS`.
/// Returns (ids, completion_start) where completion_start is the index of
/// the first completion token (right after the separator).
pub fn encode_example(ex: &Example) -> (Vec<u32>, usize) {
    let mut ids = vec![BOS];
    ids.extend(tokenizer::encode(&ex.prompt.replace('\n', " ")));
    ids.push(SEP);
    let start = ids.len();
    ids.extend(tokenizer::encode(&ex.completion));
    ids.push(EOS);
    (ids, start)
}

/// Build a language-modelling batch from raw documents (pretraining / the
/// recovery mix trained LM-style on QA text).
pub fn lm_batch(docs: &[String], batch: usize, seq: usize) -> Batch {
    let mut b = Batch::empty(batch, seq);
    for (row, doc) in docs.iter().take(batch).enumerate() {
        let mut ids = vec![BOS];
        ids.extend(tokenizer::encode(doc));
        ids.push(EOS);
        b.fill_row(row, &ids, 0);
    }
    b
}

/// Build an instruction-tuning batch: loss restricted to completions.
pub fn sft_batch(examples: &[Example], batch: usize, seq: usize) -> Batch {
    let mut b = Batch::empty(batch, seq);
    for (row, ex) in examples.iter().take(batch).enumerate() {
        let (ids, start) = encode_example(ex);
        b.fill_row(row, &ids, start);
    }
    b
}

/// Build an inference batch of prompts only (`BOS prompt |`), returning the
/// per-row position of the last prompt token (where generation begins).
pub fn prompt_batch(prompts: &[String], batch: usize, seq: usize) -> (Batch, Vec<usize>) {
    let mut b = Batch::empty(batch, seq);
    let mut ends = Vec::with_capacity(prompts.len());
    for (row, p) in prompts.iter().take(batch).enumerate() {
        let mut ids = vec![BOS];
        ids.extend(tokenizer::encode(&p.replace('\n', " ")));
        ids.push(SEP);
        let n = ids.len().min(seq);
        for (pos, &id) in ids.iter().take(n).enumerate() {
            b.tokens[row * seq + pos] = id as f32;
        }
        ends.push(n - 1);
    }
    (b, ends)
}

/// Infinite deterministic batch stream over a sampler closure.
pub struct BatchStream<F: FnMut(&mut Rng) -> Example> {
    sampler: F,
    rng: Rng,
    batch: usize,
    seq: usize,
}

impl<F: FnMut(&mut Rng) -> Example> BatchStream<F> {
    pub fn new(sampler: F, seed: u64, batch: usize, seq: usize) -> Self {
        BatchStream { sampler, rng: Rng::new(seed), batch, seq }
    }

    pub fn next_batch(&mut self) -> Batch {
        let examples: Vec<Example> =
            (0..self.batch).map(|_| (self.sampler)(&mut self.rng)).collect();
        sft_batch(&examples, self.batch, self.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ex(p: &str, c: &str) -> Example {
        Example { prompt: p.into(), completion: c.into() }
    }

    #[test]
    fn encode_example_layout() {
        let (ids, start) = encode_example(&ex("ab", "cd"));
        assert_eq!(ids[0], BOS);
        assert_eq!(ids[3], SEP);
        assert_eq!(start, 4);
        assert_eq!(*ids.last().unwrap(), EOS);
    }

    #[test]
    fn sft_mask_covers_only_completion() {
        let b = sft_batch(&[ex("ab", "cd")], 1, 16);
        // ids: BOS a b SEP c d EOS → targets at pos p predict ids[p+1];
        // completion starts at index 4 (token 'c'), so mask fires at
        // target positions 3 (predict c), 4 (predict d), 5 (predict EOS).
        let mask: Vec<f32> = b.mask[..8].to_vec();
        assert_eq!(mask, vec![0., 0., 0., 1., 1., 1., 0., 0.]);
        // and the masked targets are c, d, EOS
        assert_eq!(b.targets[3], tokenizer::encode("c")[0] as f32);
        assert_eq!(b.targets[5], EOS as f32);
    }

    #[test]
    fn lm_batch_masks_everything_real() {
        let b = lm_batch(&["abc".to_string()], 1, 8);
        // BOS a b c EOS → 4 target positions
        assert_eq!(b.mask[..5], [1., 1., 1., 1., 0.]);
        assert_eq!(b.tokens[0], BOS as f32);
    }

    #[test]
    fn overlong_sequences_truncate() {
        let long = "a".repeat(100);
        let b = sft_batch(&[ex(&long, "b")], 1, 16);
        assert_eq!(b.tokens.len(), 16);
        // no panics, everything PAD-free up to seq
        assert!(b.tokens.iter().all(|v| *v >= 0.0));
    }

    #[test]
    fn prompt_batch_records_generation_start() {
        let (b, ends) = prompt_batch(&["abc".to_string()], 1, 16);
        // BOS a b c SEP → last prompt index 4
        assert_eq!(ends, vec![4]);
        assert_eq!(b.tokens[4], SEP as f32);
    }

    #[test]
    fn stream_is_deterministic() {
        let mk = || {
            BatchStream::new(
                |rng| ex(&format!("q{}", rng.below(10)), "a"),
                9,
                4,
                16,
            )
        };
        let mut s1 = mk();
        let mut s2 = mk();
        for _ in 0..5 {
            assert_eq!(s1.next_batch().tokens, s2.next_batch().tokens);
        }
    }
}
