//! Self-contained binary checkpoint format for [`ParamStore`]s.
//!
//! Layout (little-endian):
//! `magic "LOTA" | version u32 | count u32 |` then per tensor:
//! `name_len u32 | name bytes | ndim u32 | dims u32... | f32 data`.
//! A trailing CRC-style xor checksum guards against truncation.
//!
//! Quantized integer grids are additionally stored **bit-packed** when the
//! store carries a `__n_bits__` hint tensor, so checkpoints of quantized
//! models reflect the deployment footprint (and exercise `quant::pack`).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::ParamStore;
use crate::quant::{pack_ints, packed_len_u32, unpack_ints};
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"LOTA";
const VERSION: u32 = 2;

/// Name of the 1-element tensor recording the bit width of quantized
/// checkpoints, so a merged checkpoint is self-describing — the native
/// engine reads it back through [`n_bits_hint`] to pack the grids.
pub const N_BITS_HINT: &str = "__n_bits__";

/// Marker flag for packed integer tensors within the file.
const FLAG_DENSE: u32 = 0;
const FLAG_PACKED: u32 = 1;

fn xor_fold(bytes: &[u8]) -> u32 {
    let mut acc = 0xA5A5_5A5Au32;
    for (i, b) in bytes.iter().enumerate() {
        acc ^= (*b as u32) << ((i % 4) * 8);
        acc = acc.rotate_left(1);
    }
    acc
}

/// Save a store. Tensors whose name ends in `_int` and whose values all
/// fit `n_bits` are bit-packed on disk; a [`N_BITS_HINT`] tensor is
/// appended so the bit width survives the round trip.
pub fn save(store: &ParamStore, path: &Path, n_bits: Option<u32>) -> Result<()> {
    let f = File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    let mut checksum = 0u32;

    // a fresh `n_bits` wins over any hint already in the store, so
    // re-quantized checkpoints never carry a stale bit width
    let hint_entry =
        n_bits.map(|bits| (N_BITS_HINT.to_string(), Tensor::from_scalar(bits as f32)));
    let drop_stored_hint = hint_entry.is_some() && store.contains(N_BITS_HINT);
    let count = store.len() - drop_stored_hint as usize + hint_entry.is_some() as usize;
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&(count as u32).to_le_bytes())?;

    let entries = store
        .iter()
        .filter(|(n, _)| !(drop_stored_hint && n.as_str() == N_BITS_HINT))
        .map(|(n, t)| (n.as_str(), t))
        .chain(hint_entry.iter().map(|(n, t)| (n.as_str(), t)));
    for (name, t) in entries {
        let name_b = name.as_bytes();
        w.write_all(&(name_b.len() as u32).to_le_bytes())?;
        w.write_all(name_b)?;
        checksum ^= xor_fold(name_b);
        w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for d in t.shape() {
            w.write_all(&(*d as u32).to_le_bytes())?;
        }
        let packable = n_bits.is_some() && name.ends_with("_int");
        if packable {
            let bits = n_bits.unwrap();
            match pack_ints(t.data(), bits) {
                Ok(words) => {
                    w.write_all(&FLAG_PACKED.to_le_bytes())?;
                    w.write_all(&bits.to_le_bytes())?;
                    for word in &words {
                        w.write_all(&word.to_le_bytes())?;
                        checksum ^= *word;
                    }
                    continue;
                }
                Err(_) => { /* fall through to dense */ }
            }
        }
        w.write_all(&FLAG_DENSE.to_le_bytes())?;
        for v in t.data() {
            let b = v.to_le_bytes();
            w.write_all(&b)?;
            checksum ^= u32::from_le_bytes(b);
        }
    }
    w.write_all(&checksum.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Read back the bit width a quantized checkpoint was saved with, if the
/// store (typically one returned by [`load`]) carries the hint tensor.
pub fn n_bits_hint(store: &ParamStore) -> Option<u32> {
    let t = store.get(N_BITS_HINT).ok()?;
    let v = *t.data().first()?;
    if v.fract() == 0.0 && (1.0..=8.0).contains(&v) {
        Some(v as u32)
    } else {
        None
    }
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Load a store saved by [`save`].
pub fn load(path: &Path) -> Result<ParamStore> {
    let f = File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?}: not a LOTA checkpoint");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("{path:?}: unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut store = ParamStore::new();
    let mut checksum = 0u32;

    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name_b = vec![0u8; name_len];
        r.read_exact(&mut name_b)?;
        checksum ^= xor_fold(&name_b);
        let name = String::from_utf8(name_b)?;
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 4 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product();
        let flag = read_u32(&mut r)?;
        let data = match flag {
            FLAG_DENSE => {
                let mut data = Vec::with_capacity(n);
                for _ in 0..n {
                    let w = read_u32(&mut r)?;
                    checksum ^= w;
                    data.push(f32::from_le_bytes(w.to_le_bytes()));
                }
                data
            }
            FLAG_PACKED => {
                let bits = read_u32(&mut r)?;
                let nwords = packed_len_u32(n, bits);
                let mut words = Vec::with_capacity(nwords);
                for _ in 0..nwords {
                    let w = read_u32(&mut r)?;
                    checksum ^= w;
                    words.push(w);
                }
                unpack_ints(&words, n, bits)?
            }
            _ => bail!("corrupt checkpoint: unknown flag {flag}"),
        };
        store.insert(&name, Tensor::new(&shape, data));
    }

    let stored = read_u32(&mut r)?;
    if stored != checksum {
        bail!("{path:?}: checksum mismatch (truncated or corrupted)");
    }
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::tensor::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("lota_ckpt_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_fp_store() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(1);
        let store = super::super::init_fp(&cfg, &mut rng);
        let path = tmp("fp");
        save(&store, &path, None).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), store.len());
        for (name, t) in store.iter() {
            assert_eq!(loaded.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn packed_quant_roundtrip_and_smaller() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(2);
        let fp = super::super::init_fp(&cfg, &mut rng);
        let q = super::super::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(crate::quant::rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        let p_dense = tmp("dense");
        let p_packed = tmp("packed");
        save(&q, &p_dense, None).unwrap();
        save(&q, &p_packed, Some(4)).unwrap();
        let dense_sz = std::fs::metadata(&p_dense).unwrap().len();
        let packed_sz = std::fs::metadata(&p_packed).unwrap().len();
        assert!(packed_sz < dense_sz, "{packed_sz} !< {dense_sz}");
        let loaded = load(&p_packed).unwrap();
        for (name, t) in q.iter() {
            assert_eq!(loaded.get(name).unwrap(), t, "{name}");
        }
        std::fs::remove_file(&p_dense).ok();
        std::fs::remove_file(&p_packed).ok();
    }

    #[test]
    fn n_bits_hint_survives_roundtrip() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(6);
        let fp = super::super::init_fp(&cfg, &mut rng);
        let q = super::super::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(crate::quant::rtn_quantize(w, cfg.group_size, 3))
        })
        .unwrap();
        assert_eq!(n_bits_hint(&q), None);
        let path = tmp("hint");
        save(&q, &path, Some(3)).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(n_bits_hint(&loaded), Some(3));
        // re-saving a store that already carries the hint doesn't dup it
        let path2 = tmp("hint2");
        save(&loaded, &path2, Some(3)).unwrap();
        let again = load(&path2).unwrap();
        assert_eq!(again.len(), loaded.len());
        // and a fresh bit width replaces a stale stored hint
        let path3 = tmp("hint3");
        save(&loaded, &path3, Some(4)).unwrap();
        let requant = load(&path3).unwrap();
        assert_eq!(n_bits_hint(&requant), Some(4));
        assert_eq!(requant.len(), loaded.len());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&path2).ok();
        std::fs::remove_file(&path3).ok();
    }

    #[test]
    fn truncation_is_detected() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(3);
        let store = super::super::init_fp(&cfg, &mut rng);
        let path = tmp("trunc");
        save(&store, &path, None).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 8]).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn garbage_is_rejected() {
        let path = tmp("garbage");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
