//! Model parameter store: named tensors in the exact (sorted-name) layout
//! the HLO artifacts expect, plus initialization, quantization plumbing,
//! and a self-contained binary checkpoint format.
//!
//! Naming contract (mirrors `python/compile/model.py`):
//! * shared f32: `embed, head, ln1_b, ln1_w, ln2_b, ln2_w, lnf_b, lnf_w, pos`
//! * fp weights (pretraining): `w_{slot}` (L, Din, Dout)
//! * quantized slots: `q_{slot}_int|_s|_z`
//! * adapters: `ta_{slot}_a|_b` (LoTA), `lo_{slot}_a|_b` (LoRA),
//!   `qa_{slot}_a|_b` (QA-LoRA)
//!
//! Layer-stacked tensors carry the layer as the leading axis. The
//! flattening order used at the PJRT boundary is **sorted by name**, which
//! `BTreeMap` gives for free and `aot.py` records in the manifest.

pub mod checkpoint;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::adapter::{LoraAdapter, QaLoraAdapter, TernaryAdapter};
use crate::config::{Method, ModelConfig};
use crate::quant::QuantizedLinear;
use crate::tensor::{Rng, Tensor};

pub const SLOTS: [&str; 6] = ["wq", "wk", "wv", "wo", "w_up", "w_down"];

/// Named tensor collection with sorted iteration order.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).ok_or_else(|| anyhow!("missing param '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map.get_mut(name).ok_or_else(|| anyhow!("missing param '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.map.remove(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.map.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    /// Total f32 element count (diagnostics / Fig. 6 memory accounting).
    pub fn n_elems(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Values in the order of `names` — the PJRT argument list.
    pub fn ordered(&self, names: &[String]) -> Result<Vec<&Tensor>> {
        names.iter().map(|n| self.get(n)).collect()
    }
}

// ---------------------------------------------------------------------------
// Initialization

/// Initialize a full-precision model (pretraining start point).
pub fn init_fp(cfg: &ModelConfig, rng: &mut Rng) -> ParamStore {
    let mut p = ParamStore::new();
    let (v, d, t, l) = (cfg.vocab, cfg.d_model, cfg.seq_len, cfg.n_layers);
    p.insert("embed", Tensor::new(&[v, d], rng.normal_vec(v * d, 0.05)));
    p.insert("pos", Tensor::new(&[t, d], rng.normal_vec(t * d, 0.02)));
    p.insert("head", Tensor::new(&[d, v], rng.normal_vec(d * v, 0.05)));
    p.insert("lnf_w", Tensor::full(&[d], 1.0));
    p.insert("lnf_b", Tensor::zeros(&[d]));
    for pre in ["ln1", "ln2"] {
        p.insert(&format!("{pre}_w"), Tensor::full(&[l, d], 1.0));
        p.insert(&format!("{pre}_b"), Tensor::zeros(&[l, d]));
    }
    for (slot, din, dout) in cfg.slots() {
        // scaled-down residual-branch init
        let std = (2.0 / din as f32).sqrt() * 0.5;
        p.insert(
            &format!("w_{slot}"),
            Tensor::new(&[l, din, dout], rng.normal_vec(l * din * dout, std)),
        );
    }
    p
}

/// Replace fp slot weights with a quantized representation. `quantize` is
/// called per (layer, slot) with the 2-D weight and must return the grid.
pub fn quantize_store(
    cfg: &ModelConfig,
    fp: &ParamStore,
    mut quantize: impl FnMut(&str, usize, &Tensor) -> Result<QuantizedLinear>,
) -> Result<ParamStore> {
    let l = cfg.n_layers;
    let mut out = ParamStore::new();
    // copy shared tensors
    for name in ["embed", "pos", "head", "lnf_w", "lnf_b", "ln1_w", "ln1_b", "ln2_w", "ln2_b"] {
        out.insert(name, fp.get(name)?.clone());
    }
    for (slot, din, dout) in cfg.slots() {
        let w = fp.get(&format!("w_{slot}"))?;
        let g = din / cfg.group_size;
        let mut w_int = Tensor::zeros(&[l, din, dout]);
        let mut scales = Tensor::zeros(&[l, g, dout]);
        let mut zeros = Tensor::zeros(&[l, g, dout]);
        for li in 0..l {
            let ql = quantize(slot, li, &w.layer(li))?;
            if ql.group_size != cfg.group_size || ql.n_groups() != g {
                bail!("quantizer returned wrong grouping for {slot}");
            }
            w_int.set_layer(li, &ql.w_int);
            scales.set_layer(li, &ql.scales);
            zeros.set_layer(li, &ql.zeros);
        }
        out.insert(&format!("q_{slot}_int"), w_int);
        out.insert(&format!("q_{slot}_s"), scales);
        out.insert(&format!("q_{slot}_z"), zeros);
    }
    Ok(out)
}

/// Extract one (layer, slot) [`QuantizedLinear`] view from a store.
pub fn quant_layer(cfg: &ModelConfig, p: &ParamStore, slot: &str, layer: usize, n_bits: u32) -> Result<QuantizedLinear> {
    let ql = QuantizedLinear {
        n_bits,
        group_size: cfg.group_size,
        w_int: p.get(&format!("q_{slot}_int"))?.layer(layer),
        scales: p.get(&format!("q_{slot}_s"))?.layer(layer),
        zeros: p.get(&format!("q_{slot}_z"))?.layer(layer),
    };
    Ok(ql)
}

/// Write one (layer, slot) grid back into a store (post-merge).
pub fn set_quant_layer(p: &mut ParamStore, slot: &str, layer: usize, ql: &QuantizedLinear) -> Result<()> {
    p.get_mut(&format!("q_{slot}_int"))?.set_layer(layer, &ql.w_int);
    p.get_mut(&format!("q_{slot}_s"))?.set_layer(layer, &ql.scales);
    p.get_mut(&format!("q_{slot}_z"))?.set_layer(layer, &ql.zeros);
    Ok(())
}

/// Initialize the adapter tensors for a method (paper §3.2 init for LoTA,
/// standard LoRA init otherwise). Adds `ta_/lo_/qa_{slot}_a|_b` entries.
pub fn init_adapters(cfg: &ModelConfig, method: Method, rng: &mut Rng, p: &mut ParamStore) {
    let l = cfg.n_layers;
    let g_of = |din: usize| din / cfg.group_size;
    for (slot, din, dout) in cfg.slots() {
        match method {
            Method::LotaQaf => {
                let mut a = Tensor::zeros(&[l, din, cfg.rank]);
                for li in 0..l {
                    let ta = TernaryAdapter::init(din, dout, cfg.rank, rng);
                    a.set_layer(li, &ta.a);
                }
                p.insert(&format!("ta_{slot}_a"), a);
                p.insert(&format!("ta_{slot}_b"), Tensor::zeros(&[l, cfg.rank, dout]));
            }
            Method::Lora => {
                let mut a = Tensor::zeros(&[l, din, cfg.rank]);
                for li in 0..l {
                    let ad = LoraAdapter::init(din, dout, cfg.rank, rng);
                    a.set_layer(li, &ad.a);
                }
                p.insert(&format!("lo_{slot}_a"), a);
                p.insert(&format!("lo_{slot}_b"), Tensor::zeros(&[l, cfg.rank, dout]));
            }
            Method::QaLora => {
                let g = g_of(din);
                let mut a = Tensor::zeros(&[l, g, cfg.rank]);
                for li in 0..l {
                    let ad = QaLoraAdapter::init(din, dout, cfg.rank, cfg.group_size, rng);
                    a.set_layer(li, &ad.a);
                }
                p.insert(&format!("qa_{slot}_a"), a);
                p.insert(&format!("qa_{slot}_b"), Tensor::zeros(&[l, cfg.rank, dout]));
            }
            Method::GptqOnly => {}
        }
    }
}

/// Adapter tensor names for a method, sorted (= artifact order).
pub fn adapter_names(method: Method) -> Vec<String> {
    let prefix = match method {
        Method::LotaQaf => "ta",
        Method::Lora => "lo",
        Method::QaLora => "qa",
        Method::GptqOnly => return vec![],
    };
    let mut names: Vec<String> = SLOTS
        .iter()
        .flat_map(|s| [format!("{prefix}_{s}_a"), format!("{prefix}_{s}_b")])
        .collect();
    names.sort();
    names
}

/// Frozen (non-adapter) tensor names for the QAF graphs, sorted.
pub fn frozen_names() -> Vec<String> {
    let mut names: Vec<String> = vec![
        "embed", "head", "ln1_b", "ln1_w", "ln2_b", "ln2_w", "lnf_b", "lnf_w", "pos",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    for s in SLOTS {
        names.push(format!("q_{s}_int"));
        names.push(format!("q_{s}_s"));
        names.push(format!("q_{s}_z"));
    }
    names.sort();
    names
}

/// Full-precision tensor names (pretraining graphs), sorted.
pub fn fp_names() -> Vec<String> {
    let mut names: Vec<String> = vec![
        "embed", "head", "ln1_b", "ln1_w", "ln2_b", "ln2_w", "lnf_b", "lnf_w", "pos",
    ]
    .into_iter()
    .map(String::from)
    .collect();
    for s in SLOTS {
        names.push(format!("w_{s}"));
    }
    names.sort();
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    #[test]
    fn init_fp_has_expected_tensors() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(1);
        let p = init_fp(&cfg, &mut rng);
        for n in fp_names() {
            assert!(p.contains(&n), "missing {n}");
        }
        assert_eq!(p.get("embed").unwrap().shape(), &[64, 64]);
        assert_eq!(p.get("w_wq").unwrap().shape(), &[2, 64, 64]);
        assert_eq!(p.get("w_w_down").unwrap().shape(), &[2, 256, 64]);
    }

    #[test]
    fn quantize_store_roundtrip() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(2);
        let fp = init_fp(&cfg, &mut rng);
        let q = quantize_store(&cfg, &fp, |_, _, w| {
            Ok(crate::quant::rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        for n in frozen_names() {
            assert!(q.contains(&n), "missing {n}");
        }
        // dequantized weights approximate the originals
        let ql = quant_layer(&cfg, &q, "wq", 0, 4).unwrap();
        let orig = fp.get("w_wq").unwrap().layer(0);
        assert!(ql.max_error(&orig) < 0.05);
    }

    #[test]
    fn adapter_init_shapes_per_method() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(3);
        let fp = init_fp(&cfg, &mut rng);
        let mut q = quantize_store(&cfg, &fp, |_, _, w| {
            Ok(crate::quant::rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        init_adapters(&cfg, Method::LotaQaf, &mut rng, &mut q);
        assert_eq!(q.get("ta_wq_a").unwrap().shape(), &[2, 64, 8]);
        assert_eq!(q.get("ta_w_down_b").unwrap().shape(), &[2, 8, 64]);
        init_adapters(&cfg, Method::QaLora, &mut rng, &mut q);
        assert_eq!(q.get("qa_wq_a").unwrap().shape(), &[2, 4, 8]); // G=64/16
        init_adapters(&cfg, Method::Lora, &mut rng, &mut q);
        assert_eq!(q.get("lo_w_up_a").unwrap().shape(), &[2, 64, 8]);
    }

    #[test]
    fn lota_init_is_ternary_b_zero() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(4);
        let mut p = ParamStore::new();
        init_adapters(&cfg, Method::LotaQaf, &mut rng, &mut p);
        let a = p.get("ta_wq_a").unwrap();
        assert!(a.data().iter().all(|v| [-1.0, 0.0, 1.0].contains(v)));
        assert!(a.data().iter().any(|v| *v != 0.0));
        assert!(p.get("ta_wq_b").unwrap().data().iter().all(|v| *v == 0.0));
    }

    #[test]
    fn name_lists_are_sorted() {
        for list in [fp_names(), frozen_names(), adapter_names(Method::LotaQaf)] {
            let mut sorted = list.clone();
            sorted.sort();
            assert_eq!(list, sorted);
        }
        assert_eq!(adapter_names(Method::GptqOnly).len(), 0);
        assert_eq!(adapter_names(Method::Lora).len(), 12);
    }

    #[test]
    fn set_quant_layer_writes_back() {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(5);
        let fp = init_fp(&cfg, &mut rng);
        let mut q = quantize_store(&cfg, &fp, |_, _, w| {
            Ok(crate::quant::rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        let mut ql = quant_layer(&cfg, &q, "wk", 1, 4).unwrap();
        ql.w_int.data_mut()[0] = 7.0;
        set_quant_layer(&mut q, "wk", 1, &ql).unwrap();
        assert_eq!(quant_layer(&cfg, &q, "wk", 1, 4).unwrap().w_int.data()[0], 7.0);
    }
}
