//! Ternary adaptation (paper §3.2): trainable ternary adapters
//! `A_T ∈ {-1,0,1}^{Din×r}`, `B_T ∈ {-1,0,1}^{r×Dout}`, the auxiliary /
//! ternary / offset matrices of Eqs. 3–4, and the **lossless merge** of
//! Eq. 5 that folds the adaptation into the quantized integers and zero
//! factors with zero approximation error.

use crate::quant::affine::QuantizedLinear;
use crate::tensor::{linalg, Rng, Tensor};

use anyhow::{bail, Result};

/// A pair of ternary adapter matrices for one quantized linear slot.
#[derive(Clone, Debug)]
pub struct TernaryAdapter {
    /// (Din, r), values in {-1, 0, 1}
    pub a: Tensor,
    /// (r, Dout), values in {-1, 0, 1}
    pub b: Tensor,
    pub rank: usize,
}

impl TernaryAdapter {
    /// Paper init: Kaiming-normal A ternarized at `0.75·mean|w|`
    /// (Li et al., 2016), B = 0.
    pub fn init(din: usize, dout: usize, rank: usize, rng: &mut Rng) -> Self {
        let a = Tensor::new(&[din, rank], rng.ternary_kaiming_vec(din, din * rank));
        let b = Tensor::zeros(&[rank, dout]);
        TernaryAdapter { a, b, rank }
    }

    pub fn from_parts(a: Tensor, b: Tensor) -> Result<Self> {
        let rank = a.cols();
        if b.rows() != rank {
            bail!("adapter rank mismatch: A cols {} vs B rows {}", rank, b.rows());
        }
        let ta = TernaryAdapter { a, b, rank };
        ta.validate()?;
        Ok(ta)
    }

    /// All entries must be ternary — enforced after every optimizer step
    /// round-trip through PJRT.
    pub fn validate(&self) -> Result<()> {
        for (name, t) in [("A", &self.a), ("B", &self.b)] {
            if let Some(v) = t.data().iter().find(|v| **v != -1.0 && **v != 0.0 && **v != 1.0)
            {
                bail!("{name} contains non-ternary value {v}");
            }
        }
        Ok(())
    }

    /// Auxiliary matrix `ΔW = A_T B_T` (integer-valued, in [-r, r]).
    pub fn delta_w(&self) -> Tensor {
        linalg::matmul(&self.a, &self.b)
    }

    /// Fraction of non-zero entries (sparsity diagnostics for DESIGN §Perf).
    pub fn density(&self) -> f32 {
        let nz = self.a.data().iter().filter(|v| **v != 0.0).count()
            + self.b.data().iter().filter(|v| **v != 0.0).count();
        nz as f32 / (self.a.len() + self.b.len()) as f32
    }
}

/// Eq. 3: `Ŵ = sign(ΔW) · 1[|ΔW| > ω]`.
pub fn ternary_map(delta_w: &Tensor, omega: f32) -> Tensor {
    delta_w.clone().map(|v| {
        if v.abs() > omega {
            v.signum()
        } else {
            0.0
        }
    })
}

/// The full lossless adaptation/merge map (Eqs. 3–5).
///
/// Returns the adjusted layer: `W'_int = clip(W_int + Ŵ, 0, 2^N−1)` and
/// `z' = z + s·μ` with the per-group offset factor
/// `μ_g = Σ_{i∈g} W̃_i / (r·gs)`. The same function serves as the training
/// forward's weight map and the final merge — that identity *is* the
/// losslessness argument, and the runtime integration test checks it
/// end-to-end against the HLO graphs.
pub fn lota_merge(ql: &QuantizedLinear, adapter: &TernaryAdapter, omega: f32) -> QuantizedLinear {
    let (din, dout) = (ql.din(), ql.dout());
    assert_eq!(adapter.a.rows(), din, "adapter A rows");
    assert_eq!(adapter.b.cols(), dout, "adapter B cols");
    let gs = ql.group_size;
    let g = ql.n_groups();
    let grid_max = ql.grid_max();
    let r = adapter.rank as f32;

    let delta = adapter.delta_w();
    let mut w_int = ql.w_int.clone();
    let mut zeros = ql.zeros.clone();

    for gi in 0..g {
        let mut musum = vec![0.0f32; dout];
        for i in gi * gs..(gi + 1) * gs {
            let drow = delta.row(i);
            let wrow = w_int.row_mut(i);
            for j in 0..dout {
                let dw = drow[j];
                let what = if dw.abs() > omega { dw.signum() } else { 0.0 };
                // boundary check (paper Fig. 3): stay inside the grid
                wrow[j] = (wrow[j] + what).clamp(0.0, grid_max);
                musum[j] += dw - omega * what; // W̃ accumulation (Eq. 4)
            }
        }
        let srow = ql.scales.row(gi);
        let zrow = zeros.row_mut(gi);
        for j in 0..dout {
            zrow[j] += srow[j] * musum[j] / (r * gs as f32); // Eq. 5
        }
    }

    QuantizedLinear {
        n_bits: ql.n_bits,
        group_size: gs,
        w_int,
        scales: ql.scales.clone(),
        zeros,
    }
}

/// Count of integer-grid entries the merge would move (|Ŵ| = 1 and not
/// clipped) — the "adjustment budget" diagnostic reported by the benches.
pub fn adjustment_count(ql: &QuantizedLinear, adapter: &TernaryAdapter, omega: f32) -> usize {
    let delta = adapter.delta_w();
    let grid_max = ql.grid_max();
    let mut n = 0;
    for i in 0..ql.din() {
        let drow = delta.row(i);
        let wrow = ql.w_int.row(i);
        for j in 0..ql.dout() {
            let dw = drow[j];
            if dw.abs() > omega {
                let next = wrow[j] + dw.signum();
                if (0.0..=grid_max).contains(&next) {
                    n += 1;
                }
            }
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;

    fn setup(seed: u64, n_bits: u32) -> (QuantizedLinear, TernaryAdapter) {
        let mut rng = Rng::new(seed);
        let (din, dout, gs, r) = (32, 16, 8, 4);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, n_bits);
        let mut ta = TernaryAdapter::init(din, dout, r, &mut rng);
        // give B random ternary values so ΔW is non-trivial
        let bd: Vec<f32> = (0..r * dout).map(|_| (rng.below(3) as f32) - 1.0).collect();
        ta.b = Tensor::new(&[r, dout], bd);
        (ql, ta)
    }

    #[test]
    fn init_is_ternary_with_zero_b() {
        let mut rng = Rng::new(1);
        let ta = TernaryAdapter::init(64, 32, 8, &mut rng);
        ta.validate().unwrap();
        assert!(ta.b.data().iter().all(|v| *v == 0.0));
        assert!(ta.a.data().iter().any(|v| *v != 0.0));
    }

    #[test]
    fn zero_b_means_identity_merge() {
        let mut rng = Rng::new(2);
        let (din, dout, gs, r) = (32, 16, 8, 4);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, 4);
        let ta = TernaryAdapter::init(din, dout, r, &mut rng);
        let merged = lota_merge(&ql, &ta, 3.0);
        assert_eq!(merged.w_int, ql.w_int);
        assert_eq!(merged.zeros, ql.zeros);
    }

    #[test]
    fn delta_w_is_integer_in_rank_range() {
        let (_, ta) = setup(3, 4);
        let d = ta.delta_w();
        for &v in d.data() {
            assert_eq!(v.fract(), 0.0);
            assert!(v.abs() <= ta.rank as f32);
        }
    }

    #[test]
    fn merge_stays_in_grid_all_bits() {
        for bits in [2u32, 3, 4] {
            for seed in 0..10u64 {
                let (ql, ta) = setup(seed, bits);
                let merged = lota_merge(&ql, &ta, 0.5 * ta.rank as f32);
                merged.validate().unwrap();
                // and moved at most ±1 per entry
                assert!(merged.w_int.max_abs_diff(&ql.w_int) <= 1.0);
            }
        }
    }

    #[test]
    fn omega_monotonicity() {
        // larger ω ⇒ fewer adjustments (the paper's conservativeness knob)
        let (ql, ta) = setup(5, 4);
        let r = ta.rank as f32;
        let n_low = adjustment_count(&ql, &ta, 0.25 * r);
        let n_mid = adjustment_count(&ql, &ta, 0.5 * r);
        let n_high = adjustment_count(&ql, &ta, 0.875 * r);
        assert!(n_low >= n_mid && n_mid >= n_high, "{n_low} {n_mid} {n_high}");
        assert!(n_low > 0, "test should exercise non-trivial adjustments");
    }

    #[test]
    fn merge_is_lossless_vs_float_composition() {
        // dequant(merged) == dequant(base) + s·Ŵ + s·μ exactly (up to f32)
        let (ql, ta) = setup(6, 4);
        let omega = 0.5 * ta.rank as f32;
        let merged = lota_merge(&ql, &ta, omega);
        let delta = ta.delta_w();
        let gs = ql.group_size;
        let r = ta.rank as f32;
        let base = ql.dequantize();
        let got = merged.dequantize();
        // manual composition
        for gi in 0..ql.n_groups() {
            let mut musum = vec![0.0f32; ql.dout()];
            for i in gi * gs..(gi + 1) * gs {
                for j in 0..ql.dout() {
                    let dw = delta.at2(i, j);
                    let what = if dw.abs() > omega { dw.signum() } else { 0.0 };
                    musum[j] += dw - omega * what;
                }
            }
            for i in gi * gs..(gi + 1) * gs {
                for j in 0..ql.dout() {
                    let dw = delta.at2(i, j);
                    let what = if dw.abs() > omega { dw.signum() } else { 0.0 };
                    let clipped = (ql.w_int.at2(i, j) + what).clamp(0.0, ql.grid_max())
                        - ql.w_int.at2(i, j);
                    let s = ql.scales.at2(gi, j);
                    let want =
                        base.at2(i, j) + s * clipped + s * musum[j] / (r * gs as f32);
                    let diff = (got.at2(i, j) - want).abs();
                    assert!(diff < 1e-5, "({i},{j}): {} vs {want}", got.at2(i, j));
                }
            }
        }
    }

    #[test]
    fn validate_rejects_non_ternary() {
        let a = Tensor::new(&[2, 2], vec![1.0, 0.0, -1.0, 0.5]);
        let b = Tensor::zeros(&[2, 2]);
        assert!(TernaryAdapter::from_parts(a, b).is_err());
    }
}
