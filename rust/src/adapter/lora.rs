//! LoRA baseline (Hu et al., 2022) over GPTQ-quantized weights — the
//! QLoRA-style configuration of Table 1's "GPTQ+LoRA" rows: f32 ("16-bit")
//! adapters on a frozen quantized base.
//!
//! Its merge is the *lossy* operation the paper's intro criticises: the fp
//! update must be re-quantized onto the integer grid, reintroducing
//! quantization error at the adapter level. [`merge_requantize`] implements
//! it (and reports the error) so the benches can demonstrate the contrast
//! with LoTA's exact merge.

use crate::quant::affine::{quantize_to_grid, QuantizedLinear};
use crate::tensor::{linalg, Rng, Tensor};

/// Full-precision low-rank adapter for one quantized linear slot.
#[derive(Clone, Debug)]
pub struct LoraAdapter {
    /// (Din, r)
    pub a: Tensor,
    /// (r, Dout)
    pub b: Tensor,
    pub rank: usize,
    /// scaling coefficient α (paper setup: α = 2r)
    pub alpha: f32,
}

impl LoraAdapter {
    /// Standard LoRA init: A ~ N(0, 1/√Din)-ish Kaiming, B = 0.
    pub fn init(din: usize, dout: usize, rank: usize, rng: &mut Rng) -> Self {
        let a = Tensor::new(&[din, rank], rng.kaiming_vec(din, din * rank));
        let b = Tensor::zeros(&[rank, dout]);
        LoraAdapter { a, b, rank, alpha: 2.0 * rank as f32 }
    }

    /// The effective weight update `(α/r) · A B`.
    pub fn update_matrix(&self) -> Tensor {
        linalg::matmul(&self.a, &self.b).scale(self.alpha / self.rank as f32)
    }

    /// Adapter-path output for activations `x` (M, Din): `(α/r)·(xA)B` —
    /// the extra matmuls the unmerged serving path pays per request.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let xa = linalg::matmul(x, &self.a);
        linalg::matmul(&xa, &self.b).scale(self.alpha / self.rank as f32)
    }
}

/// Lossy merge: `requantize(dequant(W) + (α/r)AB)` onto the existing
/// per-group grid. Returns the merged layer and the max |error| the
/// requantization introduced relative to the exact fp result.
pub fn merge_requantize(ql: &QuantizedLinear, ad: &LoraAdapter) -> (QuantizedLinear, f32) {
    let upd = ad.update_matrix();
    let w_fp = ql.dequantize().add(&upd);
    let (din, dout) = (ql.din(), ql.dout());
    let gs = ql.group_size;
    let grid_max = ql.grid_max();

    let mut w_int = vec![0.0f32; din * dout];
    let mut max_err = 0.0f32;
    for i in 0..din {
        let g = i / gs;
        let srow = ql.scales.row(g);
        let zrow = ql.zeros.row(g);
        for j in 0..dout {
            let want = w_fp.at2(i, j);
            let q = quantize_to_grid(want, srow[j], zrow[j], grid_max);
            w_int[i * dout + j] = q;
            max_err = max_err.max((srow[j] * q + zrow[j] - want).abs());
        }
    }
    (
        QuantizedLinear {
            n_bits: ql.n_bits,
            group_size: gs,
            w_int: Tensor::new(&[din, dout], w_int),
            scales: ql.scales.clone(),
            zeros: ql.zeros.clone(),
        },
        max_err,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;

    fn setup(seed: u64) -> (QuantizedLinear, LoraAdapter, Tensor) {
        let mut rng = Rng::new(seed);
        let (din, dout, gs, r) = (32, 16, 8, 4);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, 4);
        let mut ad = LoraAdapter::init(din, dout, r, &mut rng);
        ad.b = Tensor::new(&[r, dout], rng.normal_vec(r * dout, 0.05));
        (ql, ad, w)
    }

    #[test]
    fn zero_b_is_identity() {
        let mut rng = Rng::new(1);
        let ql = rtn_quantize(
            &Tensor::new(&[16, 8], rng.normal_vec(128, 0.1)),
            8,
            4,
        );
        let ad = LoraAdapter::init(16, 8, 4, &mut rng);
        let x = Tensor::new(&[4, 16], rng.normal_vec(64, 1.0));
        assert!(ad.forward(&x).abs_max() == 0.0);
        let (merged, err) = merge_requantize(&ql, &ad);
        assert_eq!(merged.w_int, ql.w_int);
        assert!(err < 1e-6);
    }

    #[test]
    fn forward_matches_update_matrix() {
        let (_, ad, _) = setup(2);
        let mut rng = Rng::new(3);
        let x = Tensor::new(&[4, 32], rng.normal_vec(4 * 32, 1.0));
        let via_path = ad.forward(&x);
        let via_matrix = linalg::matmul(&x, &ad.update_matrix());
        assert!(via_path.allclose(&via_matrix, 1e-4, 1e-5));
    }

    #[test]
    fn merge_is_lossy_for_nontrivial_updates() {
        let (ql, ad, _) = setup(4);
        let (merged, err) = merge_requantize(&ql, &ad);
        merged.validate().unwrap();
        assert!(
            err > 1e-4,
            "requantization should introduce measurable error, got {err}"
        );
        // error bounded by half the largest scale step (plus clamping)
        let max_s = ql.scales.data().iter().cloned().fold(0.0f32, f32::max);
        let upd_max = ad.update_matrix().abs_max();
        assert!(err <= max_s / 2.0 + upd_max + 1e-5);
    }

    #[test]
    fn merged_output_differs_from_adapter_path() {
        // The behavioural statement of "lossy": y_merged ≠ y_base + y_adapter
        let (ql, ad, _) = setup(5);
        let mut rng = Rng::new(6);
        let x = Tensor::new(&[8, 32], rng.normal_vec(8 * 32, 1.0));
        let y_exact = linalg::matmul(&x, &ql.dequantize()).add(&ad.forward(&x));
        let (merged, _) = merge_requantize(&ql, &ad);
        let y_merged = linalg::matmul(&x, &merged.dequantize());
        assert!(y_exact.max_abs_diff(&y_merged) > 1e-3);
    }
}
