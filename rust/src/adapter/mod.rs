//! Adapter implementations: the paper's ternary adaptation (LoTA) plus the
//! two baselines it is evaluated against (LoRA, QA-LoRA).
//!
//! These are the *host-side* twins of the in-graph math in
//! `python/compile/`: the training loop updates adapters through the HLO
//! step artifacts, and this module performs initialization, the
//! **lossless merge** into the quantized grid, and the checkpoint-time
//! bookkeeping. The golden tests (`artifacts/golden/*.json`) pin both
//! sides to identical numbers.

pub mod lora;
pub mod lota;
pub mod qalora;

pub use lora::LoraAdapter;
pub use lota::{adjustment_count, lota_merge, ternary_map, TernaryAdapter};
pub use qalora::QaLoraAdapter;
