//! QA-LoRA baseline (Xu et al., 2023): the closest prior work. The adapter
//! input is group-average-pooled, so the learned update is constant within
//! each quantization group and can be absorbed **losslessly into the zero
//! factors** — but, unlike LoTA, it cannot move the integer grid itself
//! (the limitation the paper's §2 highlights).

use crate::quant::affine::QuantizedLinear;
use crate::tensor::{linalg, Rng, Tensor};

/// Group-pooled low-rank adapter for one quantized linear slot.
#[derive(Clone, Debug)]
pub struct QaLoraAdapter {
    /// (G, r) — operates on group-pooled inputs
    pub a: Tensor,
    /// (r, Dout)
    pub b: Tensor,
    pub rank: usize,
    pub group_size: usize,
    pub alpha: f32,
}

impl QaLoraAdapter {
    pub fn init(din: usize, dout: usize, rank: usize, group_size: usize, rng: &mut Rng) -> Self {
        let g = din / group_size;
        let a = Tensor::new(&[g, rank], rng.kaiming_vec(g, g * rank));
        let b = Tensor::zeros(&[rank, dout]);
        QaLoraAdapter { a, b, rank, group_size, alpha: 2.0 * rank as f32 }
    }

    /// Average-pool activations over quantization groups: (M, Din) → (M, G).
    pub fn pool(&self, x: &Tensor) -> Tensor {
        let (m, din) = (x.rows(), x.cols());
        let gs = self.group_size;
        let g = din / gs;
        let mut out = vec![0.0f32; m * g];
        for row in 0..m {
            let xrow = x.row(row);
            for gi in 0..g {
                let s: f32 = xrow[gi * gs..(gi + 1) * gs].iter().sum();
                out[row * g + gi] = s / gs as f32;
            }
        }
        Tensor::new(&[m, g], out)
    }

    /// Adapter-path output `(α/r)·(pool(x)·A)·B`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let pooled = self.pool(x);
        let pa = linalg::matmul(&pooled, &self.a);
        linalg::matmul(&pa, &self.b).scale(self.alpha / self.rank as f32)
    }

    /// Lossless merge into the zero factors:
    /// `z'[g, j] = z[g, j] + (α/r)·(AB)[g, j] / gs`.
    ///
    /// (Each pooled input contributes `x̄_g = Σ_{i∈g} x_i / gs`, so the
    /// per-element weight offset is the group value divided by gs.)
    pub fn merge_zeros(&self, ql: &QuantizedLinear) -> QuantizedLinear {
        let ab = linalg::matmul(&self.a, &self.b);
        let mut zeros = ql.zeros.clone();
        let scale = self.alpha / self.rank as f32 / self.group_size as f32;
        for (z, u) in zeros.data_mut().iter_mut().zip(ab.data()) {
            *z += scale * u;
        }
        QuantizedLinear {
            n_bits: ql.n_bits,
            group_size: ql.group_size,
            w_int: ql.w_int.clone(),
            scales: ql.scales.clone(),
            zeros,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;

    fn setup(seed: u64) -> (QuantizedLinear, QaLoraAdapter) {
        let mut rng = Rng::new(seed);
        let (din, dout, gs, r) = (32, 16, 8, 4);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = rtn_quantize(&w, gs, 4);
        let mut ad = QaLoraAdapter::init(din, dout, r, gs, &mut rng);
        ad.b = Tensor::new(&[r, dout], rng.normal_vec(r * dout, 0.1));
        (ql, ad)
    }

    #[test]
    fn pool_averages_groups() {
        let (_, ad) = setup(1);
        let x = Tensor::new(&[1, 32], (0..32).map(|i| i as f32).collect());
        let p = ad.pool(&x);
        assert_eq!(p.shape(), &[1, 4]);
        assert_eq!(p.data()[0], 3.5); // mean of 0..8
        assert_eq!(p.data()[3], 27.5);
    }

    #[test]
    fn merge_is_exactly_lossless() {
        // y via adapter path == y via merged zeros, for any x (linear in x,
        // so checking a random batch at tight tolerance is sufficient)
        let (ql, ad) = setup(2);
        let mut rng = Rng::new(3);
        let x = Tensor::new(&[8, 32], rng.normal_vec(8 * 32, 1.0));
        let y_adapter = linalg::matmul(&x, &ql.dequantize()).add(&ad.forward(&x));
        let merged = ad.merge_zeros(&ql);
        let y_merged = linalg::matmul(&x, &merged.dequantize());
        assert!(
            y_adapter.allclose(&y_merged, 1e-4, 1e-4),
            "max diff {}",
            y_adapter.max_abs_diff(&y_merged)
        );
    }

    #[test]
    fn merge_never_touches_integer_grid() {
        // the paper's point: QA-LoRA cannot modify W_int
        let (ql, ad) = setup(4);
        let merged = ad.merge_zeros(&ql);
        assert_eq!(merged.w_int, ql.w_int);
        assert_eq!(merged.scales, ql.scales);
        assert!(merged.zeros.max_abs_diff(&ql.zeros) > 0.0);
    }

    #[test]
    fn zero_b_identity() {
        let mut rng = Rng::new(5);
        let w = Tensor::new(&[16, 8], rng.normal_vec(128, 0.1));
        let ql = rtn_quantize(&w, 8, 4);
        let ad = QaLoraAdapter::init(16, 8, 4, 8, &mut rng);
        let merged = ad.merge_zeros(&ql);
        assert_eq!(merged.zeros, ql.zeros);
    }
}
