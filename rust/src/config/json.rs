//! Minimal JSON substrate (parser + writer). The offline crate set has no
//! serde, and we need JSON in three places: the artifact manifest written
//! by `aot.py`, the golden vectors written by `golden.py`, and the metric
//! logs this crate emits for EXPERIMENTS.md.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i);
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            let v = self.value()?;
            a.push(v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }
}

/// Streaming JSON writer for metric logs (avoids building trees for the
/// large float arrays the benches dump).
pub struct JsonWriter {
    out: String,
    stack: Vec<bool>, // per open container: "has at least one element"
}

impl Default for JsonWriter {
    fn default() -> Self {
        Self::new()
    }
}

impl JsonWriter {
    pub fn new() -> Self {
        JsonWriter { out: String::new(), stack: Vec::new() }
    }

    fn comma(&mut self) {
        if let Some(has) = self.stack.last_mut() {
            if *has {
                self.out.push(',');
            }
            *has = true;
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.comma();
        self.out.push('{');
        self.stack.push(false);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.stack.pop();
        self.out.push('}');
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.comma();
        self.out.push('[');
        self.stack.push(false);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.stack.pop();
        self.out.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.comma();
        write!(self.out, "\"{}\":", escape(k)).unwrap();
        // the value that follows should not get its own comma:
        if let Some(has) = self.stack.last_mut() {
            *has = false;
        }
        self
    }

    pub fn str(&mut self, v: &str) -> &mut Self {
        self.comma();
        write!(self.out, "\"{}\"", escape(v)).unwrap();
        self
    }

    pub fn num(&mut self, v: f64) -> &mut Self {
        self.comma();
        if v.is_finite() {
            write!(self.out, "{v}").unwrap();
        } else {
            self.out.push_str("null");
        }
        self
    }

    pub fn bool(&mut self, v: bool) -> &mut Self {
        self.comma();
        write!(self.out, "{v}").unwrap();
        self
    }

    pub fn finish(self) -> String {
        self.out
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            '\r' => "\\r".chars().collect(),
            c => vec![c],
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn float_vec_roundtrip() {
        let j = Json::parse("[0.5, -1, 3.25]").unwrap();
        assert_eq!(j.as_f32_vec().unwrap(), vec![0.5, -1.0, 3.25]);
    }

    #[test]
    fn writer_emits_valid_json() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("name").str("run1");
        w.key("vals").begin_arr().num(1.0).num(2.5).end_arr();
        w.key("ok").bool(true);
        w.end_obj();
        let s = w.finish();
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("name").unwrap().as_str().unwrap(), "run1");
        assert_eq!(parsed.get("vals").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.5]);
    }

    #[test]
    fn writer_parser_fuzz_roundtrip() {
        // hand-rolled property test: random nested structures survive
        // write→parse
        let mut rng = crate::tensor::Rng::new(1234);
        for _ in 0..50 {
            let mut w = JsonWriter::new();
            w.begin_obj();
            let n = rng.range(1, 6);
            for i in 0..n {
                w.key(&format!("k{i}"));
                match rng.below(3) {
                    0 => {
                        w.num((rng.normal() * 100.0) as f64);
                    }
                    1 => {
                        w.str("v\"x\\y");
                    }
                    _ => {
                        w.begin_arr();
                        for _ in 0..rng.below(4) {
                            w.num(rng.uniform() as f64);
                        }
                        w.end_arr();
                    }
                }
            }
            w.end_obj();
            let s = w.finish();
            Json::parse(&s).unwrap_or_else(|e| panic!("bad json {s}: {e}"));
        }
    }
}
