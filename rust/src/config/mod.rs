//! Configuration system: model presets (mirroring `python/compile/configs.py`),
//! experiment configs parsed from a TOML subset, and the JSON substrate used
//! for the artifact manifest / golden vectors / metric logs.

pub mod json;
pub mod toml;

pub use json::{Json, JsonWriter};
pub use toml::TomlDoc;

use anyhow::{bail, Result};

/// Model architecture preset. MUST mirror `python/compile/configs.py` —
/// the runtime cross-checks these shapes against the artifact manifest at
/// load time and refuses to run on mismatch.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub group_size: usize,
    pub rank: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The six quantized linear slots: (name, Din, Dout).
    pub fn slots(&self) -> Vec<(&'static str, usize, usize)> {
        let (d, ff) = (self.d_model, self.d_ff);
        vec![
            ("wq", d, d),
            ("wk", d, d),
            ("wv", d, d),
            ("wo", d, d),
            ("w_up", d, ff),
            ("w_down", ff, d),
        ]
    }

    pub fn n_params(&self) -> usize {
        let per_layer = 4 * self.d_model * self.d_model + 2 * self.d_model * self.d_ff;
        let embed = 2 * self.vocab * self.d_model + self.seq_len * self.d_model;
        let norms = (4 * self.n_layers + 2) * self.d_model;
        self.n_layers * per_layer + embed + norms
    }
}

pub const VOCAB: usize = 64;

pub fn preset(name: &str) -> Result<ModelConfig> {
    let c = match name {
        "tiny" => ModelConfig {
            name: "tiny".into(),
            vocab: VOCAB,
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            seq_len: 128,
            group_size: 16,
            rank: 8,
        },
        "small" => ModelConfig {
            name: "small".into(),
            vocab: VOCAB,
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_ff: 1024,
            seq_len: 128,
            group_size: 32,
            rank: 16,
        },
        "medium" => ModelConfig {
            name: "medium".into(),
            vocab: VOCAB,
            d_model: 384,
            n_layers: 8,
            n_heads: 6,
            d_ff: 1536,
            seq_len: 128,
            group_size: 64,
            rank: 16,
        },
        _ => bail!("unknown model preset '{name}' (tiny|small|medium)"),
    };
    Ok(c)
}

/// Training-step batch size per preset (baked into the step artifacts).
pub fn step_batch(cfg: &str) -> usize {
    match cfg {
        "tiny" => 8,
        "small" => 4,
        _ => 2,
    }
}

/// Serving backend selector: which executor runs the forward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Backend {
    /// fixed-shape AOT artifacts through the PJRT CPU client (the
    /// reference executor — shares lowered graphs with training)
    #[default]
    Pjrt,
    /// the pure-Rust packed-integer engine (`engine::Engine`): any batch
    /// size, no artifacts, weights held at the packed footprint
    Native,
}

impl Backend {
    pub fn as_str(&self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::Native => "native",
        }
    }

    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "pjrt" => Backend::Pjrt,
            "native" => Backend::Native,
            _ => bail!("unknown backend '{s}' (pjrt|native)"),
        })
    }

    /// Parse a backend selection as benches/examples take it from the
    /// environment: a single backend name, or `both`.
    pub fn parse_selection(s: &str) -> Result<Vec<Backend>> {
        Ok(match s {
            "both" => vec![Backend::Pjrt, Backend::Native],
            other => vec![Backend::parse(other)?],
        })
    }
}

/// How the native engine decodes: KV-cached incremental steps (the
/// default — O(T) attention work per generated token instead of the
/// recompute path's O(T²), and one GEMM row per live request) or
/// full-prefix recompute (kept alive as the reference implementation the
/// cached path is pinned against).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum DecodeMode {
    /// prefill once, then step one token at a time against per-layer
    /// K/V buffers reused across decode steps
    #[default]
    Cached,
    /// re-run the full prefix through the forward on every step — the
    /// reference path parity suites hold the cache against
    Recompute,
}

impl DecodeMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            DecodeMode::Cached => "cached",
            DecodeMode::Recompute => "recompute",
        }
    }

    pub fn parse(s: &str) -> Result<DecodeMode> {
        Ok(match s {
            "cached" => DecodeMode::Cached,
            "recompute" => DecodeMode::Recompute,
            _ => bail!("unknown decode mode '{s}' (cached|recompute)"),
        })
    }
}

/// Which packed-GEMM inner kernel the native engine runs. Every choice is
/// **bit-identical** (the kernels share one lane-ordered accumulation
/// contract — see `engine::simd`); this selects instructions, not
/// results, so it is safe to flip in production and in CI.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum GemmKernel {
    /// `LOTA_GEMM_KERNEL` env override if set, else the best detected
    /// vector path (AVX2 → portable lanes)
    #[default]
    Auto,
    /// force the vector path (AVX2 where detected, portable lanes
    /// otherwise — never the scalar reference)
    Simd,
    /// force the scalar reference kernel (the CI fallback leg, and the
    /// baseline the perf gate measures against)
    Scalar,
}

impl GemmKernel {
    pub fn as_str(&self) -> &'static str {
        match self {
            GemmKernel::Auto => "auto",
            GemmKernel::Simd => "simd",
            GemmKernel::Scalar => "scalar",
        }
    }

    pub fn parse(s: &str) -> Result<GemmKernel> {
        Ok(match s {
            "auto" => GemmKernel::Auto,
            "simd" => GemmKernel::Simd,
            "scalar" => GemmKernel::Scalar,
            _ => bail!("unknown gemm kernel '{s}' (auto|simd|scalar)"),
        })
    }
}

/// Continuous-batching scheduler knobs (the `[sched]` TOML table and the
/// `lota serve --sched` flags). Presence of the table — or `--sched true`
/// — routes native serving through `sched::Scheduler` instead of the
/// one-shot drain; the scheduler sizes its decode-slot pool as
/// `max_batch` capped by how many full-context KV rows fit the budget.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SchedConfig {
    /// concurrent decode slots ceiling (`sched.max_batch`)
    pub max_batch: usize,
    /// KV memory budget in MiB shared by all live slots
    /// (`sched.kv_budget_mb`)
    pub kv_budget_mb: usize,
    /// paged KV cache (`sched.kv_paged`, default on): slots hold per-row
    /// page tables over a shared block pool sized by the budget, so
    /// admission is bounded by tokens actually cached rather than
    /// full-context rows. `false` selects the contiguous reference
    /// layout (one full-context row per slot, PR 3 semantics) — the two
    /// decode bit-identically, only memory shape and admission change
    pub kv_paged: bool,
    /// token positions per KV block (`sched.kv_block_size`, paged only)
    pub kv_block_size: usize,
    /// admission priority classes (`sched.priority_classes`, 1..=256).
    /// 1 (default) is plain FIFO — pinned bitwise identical to the
    /// pre-priority scheduler; with more classes admission picks the
    /// most-urgent waiting class first (class 0 beats class 1, FIFO
    /// within a class, starvation bounded by aging)
    pub priority_classes: usize,
    /// bounded worker submit queue (`sched.submit_queue_cap`): submits
    /// arriving while this many requests already wait are rejected with
    /// a retry-after hint instead of queued. 0 (default) = unbounded
    pub submit_queue_cap: usize,
    /// default TTFT deadline applied to requests that don't carry one
    /// (`sched.default_deadline_ms`). 0 (default) = no deadline
    pub default_deadline_ms: u64,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            max_batch: 8,
            kv_budget_mb: 1024,
            kv_paged: true,
            kv_block_size: 16,
            priority_classes: 1,
            submit_queue_cap: 0,
            default_deadline_ms: 0,
        }
    }
}

impl SchedConfig {
    /// Parse the `[sched]` table: None when the document has no `sched.*`
    /// keys (or `sched.enabled = false`), Some(config) otherwise.
    pub fn from_toml(doc: &TomlDoc) -> Result<Option<SchedConfig>> {
        if !doc.keys().any(|k| k.starts_with("sched.")) {
            return Ok(None);
        }
        if doc.get_bool("sched.enabled") == Some(false) {
            return Ok(None);
        }
        let mut c = SchedConfig::default();
        if let Some(v) = doc.get_num("sched.max_batch") {
            c.max_batch = v as usize;
        }
        if let Some(v) = doc.get_num("sched.kv_budget_mb") {
            c.kv_budget_mb = v as usize;
        }
        if let Some(v) = doc.get_bool("sched.kv_paged") {
            c.kv_paged = v;
        }
        if let Some(v) = doc.get_num("sched.kv_block_size") {
            c.kv_block_size = v as usize;
        }
        if let Some(v) = doc.get_num("sched.priority_classes") {
            c.priority_classes = v as usize;
        }
        if let Some(v) = doc.get_num("sched.submit_queue_cap") {
            c.submit_queue_cap = v as usize;
        }
        if let Some(v) = doc.get_num("sched.default_deadline_ms") {
            c.default_deadline_ms = v as u64;
        }
        if c.max_batch == 0 {
            bail!("sched.max_batch must be at least 1");
        }
        if c.kv_budget_mb == 0 {
            bail!("sched.kv_budget_mb must be at least 1");
        }
        if c.kv_block_size == 0 {
            bail!("sched.kv_block_size must be at least 1");
        }
        // priority lives in a u8 on the request spec, so 256 classes is
        // the honest ceiling; 0 classes would admit nothing
        if !(1..=256).contains(&c.priority_classes) {
            bail!("sched.priority_classes must be in 1..=256");
        }
        Ok(Some(c))
    }
}

/// Fine-tuning method selector used across the coordinator & benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// raw GPTQ quantized model, no fine-tuning
    GptqOnly,
    /// GPTQ + 16-bit LoRA adapters (QLoRA-style baseline)
    Lora,
    /// QA-LoRA: lossless merge into zero factors only
    QaLora,
    /// the paper's method
    LotaQaf,
}

impl Method {
    pub fn as_str(&self) -> &'static str {
        match self {
            Method::GptqOnly => "gptq",
            Method::Lora => "lora",
            Method::QaLora => "qalora",
            Method::LotaQaf => "lota",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "gptq" => Method::GptqOnly,
            "lora" => Method::Lora,
            "qalora" => Method::QaLora,
            "lota" | "lota-qaf" => Method::LotaQaf,
            _ => bail!("unknown method '{s}' (gptq|lora|qalora|lota)"),
        })
    }

    pub fn trains(&self) -> bool {
        !matches!(self, Method::GptqOnly)
    }
}

/// A full experiment description (what `lota finetune` runs). Parsed from
/// TOML via [`ExperimentConfig::from_toml`] or built programmatically by
/// the benches.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: String,
    pub method: Method,
    pub n_bits: u32,
    /// ternary threshold ω expressed as a fraction of the rank (paper: 0.75r)
    pub omega_frac: f32,
    /// initial top-percentile for t-SignSGD σ_t (paper: 0.05)
    pub sigma_init: f32,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// task name from data::tasks ("recovery", "arith", "sql", "datatotext")
    pub task: String,
    pub artifacts_dir: String,
    pub checkpoint_dir: Option<String>,
    /// which executor serves the fine-tuned model (`serve_backend` in TOML)
    pub backend: Backend,
    /// how the native engine decodes (`decode_mode` in TOML): KV-cached
    /// incremental steps or full-prefix recompute
    pub decode: DecodeMode,
    /// which packed-GEMM inner kernel the native engine runs
    /// (`gemm_kernel` in TOML): auto-detected SIMD, forced SIMD, or the
    /// scalar reference — bit-identical either way
    pub gemm_kernel: GemmKernel,
    /// continuous-batching scheduler config (the `[sched]` TOML table);
    /// None serves one-shot
    pub sched: Option<SchedConfig>,
    /// write a Chrome-trace JSON span timeline of the scheduled serving
    /// run here (`trace_out` in TOML; requires the scheduler)
    pub trace_out: Option<String>,
    /// write a metrics snapshot of the final serving report here
    /// (`metrics_out` in TOML; `.json` → JSON, else Prometheus text)
    pub metrics_out: Option<String>,
    /// write the engine hot-path profile — per-(layer, kind)
    /// `lota_engine_*` phase counters — here (`profile_out` in TOML;
    /// `.json` → JSON, else Prometheus text; requires the scheduler)
    pub profile_out: Option<String>,
    /// serve over the async HTTP/SSE front end bound to this address
    /// (`listen` in TOML, e.g. `"127.0.0.1:8080"`; port 0 lets the OS
    /// pick — the server prints the resolved address. Requires the
    /// scheduler; the `lota serve --listen` flag overrides this key)
    pub listen: Option<String>,
    /// named ternary adapter sets to serve alongside the base (the
    /// `[adapters]` TOML table: `name = "source"` per entry, where source
    /// is a checkpoint path or `synthetic:<seed>`). Registration order —
    /// and therefore adapter id order — is the table's alphabetical key
    /// order, which is how the subset parser stores keys.
    pub adapters: Vec<(String, String)>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: "tiny".into(),
            method: Method::LotaQaf,
            n_bits: 4,
            omega_frac: 0.75,
            sigma_init: 0.05,
            steps: 100,
            lr: 5e-4,
            seed: 20250710,
            task: "recovery".into(),
            artifacts_dir: "artifacts".into(),
            checkpoint_dir: None,
            backend: Backend::Pjrt,
            decode: DecodeMode::Cached,
            gemm_kernel: GemmKernel::Auto,
            sched: None,
            trace_out: None,
            metrics_out: None,
            profile_out: None,
            listen: None,
            adapters: Vec::new(),
        }
    }
}

impl ExperimentConfig {
    pub fn from_toml(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        if let Some(v) = doc.get_str("model") {
            c.model = v.to_string();
        }
        if let Some(v) = doc.get_str("method") {
            c.method = Method::parse(v)?;
        }
        if let Some(v) = doc.get_num("n_bits") {
            c.n_bits = v as u32;
        }
        if let Some(v) = doc.get_num("omega_frac") {
            c.omega_frac = v as f32;
        }
        if let Some(v) = doc.get_num("sigma_init") {
            c.sigma_init = v as f32;
        }
        if let Some(v) = doc.get_num("steps") {
            c.steps = v as usize;
        }
        if let Some(v) = doc.get_num("lr") {
            c.lr = v as f32;
        }
        if let Some(v) = doc.get_num("seed") {
            c.seed = v as u64;
        }
        if let Some(v) = doc.get_str("task") {
            c.task = v.to_string();
        }
        if let Some(v) = doc.get_str("artifacts_dir") {
            c.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_str("checkpoint_dir") {
            c.checkpoint_dir = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("serve_backend") {
            c.backend = Backend::parse(v)?;
        }
        if let Some(v) = doc.get_str("decode_mode") {
            c.decode = DecodeMode::parse(v)?;
        }
        if let Some(v) = doc.get_str("gemm_kernel") {
            c.gemm_kernel = GemmKernel::parse(v)?;
        }
        if let Some(v) = doc.get_str("trace_out") {
            c.trace_out = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("metrics_out") {
            c.metrics_out = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("profile_out") {
            c.profile_out = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("listen") {
            c.listen = Some(v.to_string());
        }
        c.sched = SchedConfig::from_toml(doc)?;
        for key in doc.keys() {
            if let Some(name) = key.strip_prefix("adapters.") {
                match doc.get_str(key) {
                    Some(source) => c.adapters.push((name.to_string(), source.to_string())),
                    None => bail!(
                        "[adapters] {name} must be a string source (path or synthetic:<seed>)"
                    ),
                }
            }
        }
        if !(2..=4).contains(&c.n_bits) {
            bail!("n_bits must be 2, 3 or 4 (got {})", c.n_bits);
        }
        if !(0.0..1.0).contains(&c.omega_frac) {
            bail!("omega_frac must be in (0,1)");
        }
        Ok(c)
    }

    /// ω in absolute units for a given rank.
    pub fn omega(&self, rank: usize) -> f32 {
        self.omega_frac * rank as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_python_side() {
        // shape spot-checks mirroring python/compile/configs.py
        let t = preset("tiny").unwrap();
        assert_eq!((t.d_model, t.n_layers, t.group_size, t.rank), (64, 2, 16, 8));
        let s = preset("small").unwrap();
        assert_eq!((s.d_model, s.n_layers, s.group_size, s.rank), (256, 4, 32, 16));
        assert!(preset("huge").is_err());
        assert_eq!(t.slots().len(), 6);
        assert!(t.n_params() > 100_000 && t.n_params() < 300_000);
    }

    #[test]
    fn group_size_divides_all_slot_inputs() {
        for name in ["tiny", "small", "medium"] {
            let c = preset(name).unwrap();
            for (slot, din, _) in c.slots() {
                assert_eq!(din % c.group_size, 0, "{name}/{slot}");
            }
        }
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [Method::GptqOnly, Method::Lora, Method::QaLora, Method::LotaQaf] {
            assert_eq!(Method::parse(m.as_str()).unwrap(), m);
        }
        assert!(Method::parse("adapterx").is_err());
    }

    #[test]
    fn experiment_from_toml() {
        let doc = TomlDoc::parse(
            "model = \"small\"\nmethod = \"lota\"\nn_bits = 3\nomega_frac = 0.875\nsteps = 42\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.model, "small");
        assert_eq!(c.n_bits, 3);
        assert_eq!(c.steps, 42);
        assert!((c.omega(16) - 14.0).abs() < 1e-6);
        // observability outputs default off
        assert_eq!(c.trace_out, None);
        assert_eq!(c.metrics_out, None);
        assert_eq!(c.profile_out, None);
        assert_eq!(c.listen, None);
    }

    #[test]
    fn observability_outputs_parse() {
        let doc = TomlDoc::parse(
            "trace_out = \"out/trace.json\"\nmetrics_out = \"out/metrics.prom\"\n\
             profile_out = \"out/profile.json\"\nlisten = \"127.0.0.1:8080\"\n",
        )
        .unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.trace_out.as_deref(), Some("out/trace.json"));
        assert_eq!(c.metrics_out.as_deref(), Some("out/metrics.prom"));
        assert_eq!(c.profile_out.as_deref(), Some("out/profile.json"));
        assert_eq!(c.listen.as_deref(), Some("127.0.0.1:8080"));
    }

    #[test]
    fn adapters_table_parses_in_key_order() {
        let doc =
            TomlDoc::parse("[adapters]\nfr = \"synthetic:3\"\nde = \"ckpt/de.ckpt\"\n").unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap();
        // the subset parser stores keys sorted, so "de" registers first
        assert_eq!(
            c.adapters,
            vec![
                ("de".to_string(), "ckpt/de.ckpt".to_string()),
                ("fr".to_string(), "synthetic:3".to_string()),
            ]
        );
        // default is no adapters; non-string sources are refused
        assert!(ExperimentConfig::default().adapters.is_empty());
        let bad = TomlDoc::parse("[adapters]\nfr = 3\n").unwrap();
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn backend_parse_roundtrip() {
        for b in [Backend::Pjrt, Backend::Native] {
            assert_eq!(Backend::parse(b.as_str()).unwrap(), b);
        }
        assert!(Backend::parse("tpu").is_err());
        assert_eq!(Backend::default(), Backend::Pjrt);
        let doc = TomlDoc::parse("serve_backend = \"native\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&doc).unwrap().backend, Backend::Native);
    }

    #[test]
    fn decode_mode_parse_roundtrip() {
        for m in [DecodeMode::Cached, DecodeMode::Recompute] {
            assert_eq!(DecodeMode::parse(m.as_str()).unwrap(), m);
        }
        assert!(DecodeMode::parse("speculative").is_err());
        assert_eq!(DecodeMode::default(), DecodeMode::Cached);
        let doc = TomlDoc::parse("decode_mode = \"recompute\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&doc).unwrap().decode, DecodeMode::Recompute);
    }

    #[test]
    fn gemm_kernel_parse_roundtrip() {
        for k in [GemmKernel::Auto, GemmKernel::Simd, GemmKernel::Scalar] {
            assert_eq!(GemmKernel::parse(k.as_str()).unwrap(), k);
        }
        assert!(GemmKernel::parse("avx512").is_err());
        assert_eq!(GemmKernel::default(), GemmKernel::Auto);
        let doc = TomlDoc::parse("gemm_kernel = \"scalar\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&doc).unwrap().gemm_kernel, GemmKernel::Scalar);
        // absent key keeps the auto default
        let doc = TomlDoc::parse("model = \"tiny\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&doc).unwrap().gemm_kernel, GemmKernel::Auto);
    }

    #[test]
    fn sched_table_parses_and_validates() {
        // no table → no scheduler
        let doc = TomlDoc::parse("model = \"tiny\"\n").unwrap();
        assert_eq!(ExperimentConfig::from_toml(&doc).unwrap().sched, None);
        // bare table → defaults
        let doc = TomlDoc::parse("[sched]\nenabled = true\n").unwrap();
        assert_eq!(SchedConfig::from_toml(&doc).unwrap(), Some(SchedConfig::default()));
        // explicit knobs
        let doc =
            TomlDoc::parse("[sched]\nmax_batch = 4\nkv_budget_mb = 64\n").unwrap();
        let c = ExperimentConfig::from_toml(&doc).unwrap().sched.unwrap();
        assert_eq!(c.max_batch, 4);
        assert_eq!(c.kv_budget_mb, 64);
        // paging defaults on with 16-token blocks; both knobs parse
        assert!(c.kv_paged);
        assert_eq!(c.kv_block_size, 16);
        let doc = TomlDoc::parse("[sched]\nkv_paged = false\nkv_block_size = 8\n").unwrap();
        let c = SchedConfig::from_toml(&doc).unwrap().unwrap();
        assert!(!c.kv_paged);
        assert_eq!(c.kv_block_size, 8);
        // overload-control knobs default to the pre-priority behavior:
        // one class, unbounded submit queue, no deadline
        assert_eq!(
            (c.priority_classes, c.submit_queue_cap, c.default_deadline_ms),
            (1, 0, 0)
        );
        let doc = TomlDoc::parse(
            "[sched]\npriority_classes = 3\nsubmit_queue_cap = 64\ndefault_deadline_ms = 250\n",
        )
        .unwrap();
        let c = SchedConfig::from_toml(&doc).unwrap().unwrap();
        assert_eq!(c.priority_classes, 3);
        assert_eq!(c.submit_queue_cap, 64);
        assert_eq!(c.default_deadline_ms, 250);
        // enabled = false turns the table off
        let doc = TomlDoc::parse("[sched]\nenabled = false\nmax_batch = 4\n").unwrap();
        assert_eq!(SchedConfig::from_toml(&doc).unwrap(), None);
        // nonsense values are refused
        assert!(SchedConfig::from_toml(&TomlDoc::parse("[sched]\nmax_batch = 0\n").unwrap())
            .is_err());
        assert!(
            SchedConfig::from_toml(&TomlDoc::parse("[sched]\nkv_budget_mb = 0\n").unwrap())
                .is_err()
        );
        assert!(
            SchedConfig::from_toml(&TomlDoc::parse("[sched]\nkv_block_size = 0\n").unwrap())
                .is_err()
        );
        assert!(SchedConfig::from_toml(
            &TomlDoc::parse("[sched]\npriority_classes = 0\n").unwrap()
        )
        .is_err());
        assert!(SchedConfig::from_toml(
            &TomlDoc::parse("[sched]\npriority_classes = 257\n").unwrap()
        )
        .is_err());
    }

    #[test]
    fn experiment_validates_bits() {
        let doc = TomlDoc::parse("n_bits = 7\n").unwrap();
        assert!(ExperimentConfig::from_toml(&doc).is_err());
    }
}
