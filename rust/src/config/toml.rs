//! TOML-subset parser for experiment configs (no `toml` crate offline).
//!
//! Supported: `key = value` lines, `[section]` headers (flattened to
//! `section.key`), strings, integers, floats, booleans, inline arrays of
//! scalars, `#` comments. This covers every config the repo ships; anything
//! else is a parse error rather than a silent misread.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unclosed section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            map.insert(full_key, val);
        }
        Ok(TomlDoc { map })
    }

    pub fn load(path: &str) -> Result<TomlDoc> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        TomlDoc::parse(&src)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        match self.map.get(key) {
            Some(TomlValue::Str(s)) => Some(s),
            _ => None,
        }
    }

    pub fn get_num(&self, key: &str) -> Option<f64> {
        match self.map.get(key) {
            Some(TomlValue::Num(n)) => Some(*n),
            _ => None,
        }
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        match self.map.get(key) {
            Some(TomlValue::Bool(b)) => Some(*b),
            _ => None,
        }
    }

    pub fn get_num_arr(&self, key: &str) -> Option<Vec<f64>> {
        match self.map.get(key) {
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| match v {
                    TomlValue::Num(n) => Some(*n),
                    _ => None,
                })
                .collect(),
            _ => None,
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(rest) = s.strip_prefix('"') {
        let end = rest.rfind('"').context("unterminated string")?;
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').context("unterminated array")?;
        let mut vals = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                let p = part.trim();
                if p.is_empty() {
                    continue; // tolerate trailing comma
                }
                vals.push(parse_value(p)?);
            }
        }
        return Ok(TomlValue::Arr(vals));
    }
    let n: f64 = s
        .replace('_', "")
        .parse()
        .with_context(|| format!("not a number/string/bool: '{s}'"))?;
    Ok(TomlValue::Num(n))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_types() {
        let doc = TomlDoc::parse(
            "name = \"run\"\nsteps = 100\nlr = 5e-4\nflag = true\nbits = [4, 3, 2]\n",
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("run"));
        assert_eq!(doc.get_num("steps"), Some(100.0));
        assert_eq!(doc.get_num("lr"), Some(5e-4));
        assert_eq!(doc.get_bool("flag"), Some(true));
        assert_eq!(doc.get_num_arr("bits"), Some(vec![4.0, 3.0, 2.0]));
    }

    #[test]
    fn sections_flatten() {
        let doc = TomlDoc::parse("[train]\nsteps = 10\n[eval]\nsteps = 5\n").unwrap();
        assert_eq!(doc.get_num("train.steps"), Some(10.0));
        assert_eq!(doc.get_num("eval.steps"), Some(5.0));
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = TomlDoc::parse("a = 1 # comment\nb = \"x#y\" # more\n").unwrap();
        assert_eq!(doc.get_num("a"), Some(1.0));
        assert_eq!(doc.get_str("b"), Some("x#y"));
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("n = 1_000_000\n").unwrap();
        assert_eq!(doc.get_num("n"), Some(1e6));
    }

    #[test]
    fn errors_are_reported() {
        assert!(TomlDoc::parse("novalue\n").is_err());
        assert!(TomlDoc::parse("[open\n").is_err());
        assert!(TomlDoc::parse("x = [1, 2\n").is_err());
        assert!(TomlDoc::parse("x = zzz\n").is_err());
    }
}
