//! Request-level types of the continuous-batching scheduler: the
//! [`RequestSpec`] every submit consumes, lifecycle states, finish
//! reasons, completed-request responses, and the streaming token sink a
//! caller can attach to watch generations as they happen.

use std::sync::mpsc;
use std::time::Instant;

/// Everything one submission carries — the single argument of
/// [`crate::sched::Scheduler::submit`] and
/// [`crate::sched::WorkerClient::submit`]. The old positional variants
/// (`submit_for`, `submit_handoff`) collapsed into this: build with
/// [`RequestSpec::new`] and chain the optional fields, so plain call
/// sites stay one-liners:
///
/// ```ignore
/// sched.submit(RequestSpec::new("1 + 2 =", 8))?;                   // defaults
/// sched.submit(RequestSpec::new(p, n).adapter(2).priority(1))?;    // tagged
/// ```
///
/// Defaults are the pre-redesign FIFO path exactly: adapter 0 (bare
/// base), priority class 0, no TTFT deadline, arrival stamped inside
/// submit — a scheduler configured with one priority class, no default
/// deadline, and an unbounded submit queue is pinned bitwise identical
/// to the old behavior (`tests/sched.rs` / `tests/sched_worker.rs`).
#[derive(Clone, Debug)]
pub struct RequestSpec {
    pub prompt: String,
    /// token generation budget (0 finishes inside submit)
    pub max_new: usize,
    /// adapter id to serve with (0 = bare base)
    pub adapter: u32,
    /// priority class: 0 is most urgent, higher classes wait longer.
    /// Must be below the scheduler's `priority_classes` knob (so with
    /// the default single class, only 0 is accepted).
    pub priority: u8,
    /// TTFT SLO in milliseconds from arrival: if no first token can
    /// possibly be produced by then, the scheduler sheds the request
    /// before prefill ([`FinishReason::Shed`]). None = no deadline
    /// (the scheduler may still apply its configured default).
    pub deadline_ms: Option<u64>,
    /// when the request entered the system (e.g. the worker's command
    /// channel); None = stamped at submit. Deadlines and handoff timing
    /// are measured from this instant.
    pub enqueued_at: Option<Instant>,
}

impl RequestSpec {
    pub fn new(prompt: impl Into<String>, max_new: usize) -> RequestSpec {
        RequestSpec {
            prompt: prompt.into(),
            max_new,
            adapter: 0,
            priority: 0,
            deadline_ms: None,
            enqueued_at: None,
        }
    }

    /// Serve with this adapter id (builder style; 0 = bare base).
    pub fn adapter(mut self, adapter: u32) -> RequestSpec {
        self.adapter = adapter;
        self
    }

    /// Assign a priority class (builder style; 0 = most urgent).
    pub fn priority(mut self, class: u8) -> RequestSpec {
        self.priority = class;
        self
    }

    /// Attach a TTFT deadline in milliseconds from arrival (builder
    /// style). 0 is legal and always already blown — it sheds at submit.
    pub fn deadline_ms(mut self, ms: u64) -> RequestSpec {
        self.deadline_ms = Some(ms);
        self
    }

    /// Backdate the arrival stamp (builder style) — the cross-thread
    /// handoff path stamps channel entry here so queue-transport time
    /// counts toward handoff stats and deadlines.
    pub fn enqueued_at(mut self, at: Instant) -> RequestSpec {
        self.enqueued_at = Some(at);
        self
    }
}

/// Where a request currently is in its life. The scheduler moves every
/// request Queued → Prefilling → Decoding → Finished (or → Cancelled from
/// any live state); `Prefilling` is transient — admission and the prefill
/// forward happen within one scheduler step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// submitted, waiting for a decode slot
    Queued,
    /// admitted this step, prompt being prefilled
    Prefilling,
    /// in a decode slot, generating one token per step
    Decoding,
    /// left the batch: EOS, token budget, or context cap
    Finished,
    /// left the batch by caller request
    Cancelled,
}

/// Why a request finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// the model picked EOS
    Eos,
    /// the request's `max_new` token budget is spent
    MaxTokens,
    /// the next token would not fit in the model context
    ContextCap,
    /// cancelled by the caller (queued or mid-decode)
    Cancelled,
    /// load-shed before prefill: the request's TTFT deadline was already
    /// unmeetable (blown at submit, or while waiting in the queue), so
    /// the scheduler dropped it without ever touching the engine
    Shed,
}

impl FinishReason {
    /// Stable lowercase wire name, used verbatim by the SSE transport
    /// and pinned by its stream-parity tests.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Eos => "eos",
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::ContextCap => "context_cap",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Shed => "shed",
        }
    }
}

/// One completed (or cancelled) request, with its request-level timing.
/// Durations are measured on the scheduler's clock: `queue_wait_secs`
/// is submit → admission, `ttft_secs` submit → first generated token
/// (None when nothing was generated), `latency_secs` submit → completion.
#[derive(Clone, Debug)]
pub struct SchedResponse {
    pub id: u64,
    /// adapter id this request was served with (0 = bare base)
    pub adapter: u32,
    pub text: String,
    /// tokens actually generated (the honest tokens/s unit)
    pub tokens: usize,
    pub reason: FinishReason,
    pub queue_wait_secs: f64,
    pub ttft_secs: Option<f64>,
    pub latency_secs: f64,
}

/// Streaming observer: the scheduler calls this as tokens are picked, so
/// callers can forward partial generations (e.g. over a channel) instead
/// of waiting for completion.
pub trait TokenSink {
    /// One generated token of request `id`, in generation order. Called
    /// only for tokens that join the output — EOS and cap hits don't.
    fn on_token(&mut self, id: u64, token: u32);

    /// Request `id` left the scheduler (finished or cancelled).
    fn on_finish(&mut self, resp: &SchedResponse);
}

/// What [`ChannelSink`] emits.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    Token { id: u64, token: u32 },
    Finish(SchedResponse),
}

/// A [`TokenSink`] that forwards every event over an `mpsc` channel — the
/// decoupled producer/consumer deployment shape. Send errors (receiver
/// hung up) are ignored: a dead listener must not stall the batch the
/// request shares with others.
pub struct ChannelSink {
    tx: mpsc::Sender<StreamEvent>,
}

impl ChannelSink {
    pub fn new(tx: mpsc::Sender<StreamEvent>) -> ChannelSink {
        ChannelSink { tx }
    }
}

impl TokenSink for ChannelSink {
    fn on_token(&mut self, id: u64, token: u32) {
        let _ = self.tx.send(StreamEvent::Token { id, token });
    }

    fn on_finish(&mut self, resp: &SchedResponse) {
        let _ = self.tx.send(StreamEvent::Finish(resp.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_sink_forwards_and_survives_hangup() {
        let (tx, rx) = mpsc::channel();
        let mut sink = ChannelSink::new(tx);
        sink.on_token(3, 17);
        let resp = SchedResponse {
            id: 3,
            adapter: 0,
            text: "x".into(),
            tokens: 1,
            reason: FinishReason::Eos,
            queue_wait_secs: 0.0,
            ttft_secs: Some(0.01),
            latency_secs: 0.02,
        };
        sink.on_finish(&resp);
        match rx.recv().unwrap() {
            StreamEvent::Token { id, token } => {
                assert_eq!((id, token), (3, 17));
            }
            other => panic!("expected token event, got {other:?}"),
        }
        match rx.recv().unwrap() {
            StreamEvent::Finish(r) => {
                assert_eq!(r.id, 3);
                assert_eq!(r.reason, FinishReason::Eos);
            }
            other => panic!("expected finish event, got {other:?}"),
        }
        drop(rx);
        // receiver gone: sends are dropped, not panicking the batch
        sink.on_token(3, 18);
    }
}
