//! The continuous-batching scheduler: iteration-level admission, mixed
//! prefill/decode stepping, and slot lifecycle over one shared
//! [`KvCache`].
//!
//! One [`Scheduler::step`] is one iteration of the serving loop:
//!
//! 0. **Shed** — queued requests whose TTFT deadline is already blown
//!    are dropped *before* any prefill compute is spent on them
//!    ([`FinishReason::Shed`]); with the default no-deadline specs this
//!    phase never fires and costs nothing.
//! 1. **Admit** — waiting requests move into free decode slots, as many
//!    as are open. With one priority class (the default) admission is
//!    strictly FIFO — bitwise pinned against the pre-priority scheduler;
//!    with more classes the most-urgent effective class wins each slot
//!    (FIFO within a class, starvation bounded by step-count aging — see
//!    [`SchedOptions::aging_steps`]). With the **paged** KV cache (the default),
//!    the real resource is the shared block pool: slots are cheap
//!    (`max_batch` of them exist) and a candidate is admitted when the
//!    pool can cover its prompt plus decode horizon in blocks, *net of
//!    blocks already promised to in-flight rows* — a reservation that
//!    makes backpressure sound: when the pool runs dry the candidate
//!    simply stays queued (admission denied, counted in
//!    [`SchedStats::admission_denied`]) and nothing in flight is ever
//!    evicted or starved mid-decode. Admitting on anything less than the
//!    horizon (say, prompt + one block) could deadlock a no-eviction
//!    scheduler: every live row blocked on a dry pool, none able to
//!    finish. With the contiguous layout the slot count itself is fixed
//!    at build time by the KV memory budget (the same
//!    [`BucketPolicy::adaptive_capped`] arithmetic the one-shot native
//!    backend caps its drain batches with) — every slot a full-context
//!    row, which is exactly the over-reservation paging removes.
//! 2. **Prefill** — everything admitted this step runs one padded,
//!    batched incremental forward ([`decode::prefill_rows`]) and picks
//!    its first token.
//! 3. **Decode** — every request admitted in an *earlier* step feeds its
//!    newest token through [`decode::decode_step_rows`] — one token per
//!    live request per step.
//! 4. **Release** — finished/cancelled requests leave their slot
//!    *immediately* ([`KvCache::reset_row`], O(1), no reallocation), so
//!    the next step's admission hands the row to the next waiting
//!    request mid-generation instead of waiting for the batch to drain.
//!
//! Because the prefill and step kernels are the very ones the one-shot
//! [`crate::engine::greedy_decode`] runs, and cache rows never interact,
//! a scheduled greedy generation is **bit-identical** to the one-shot
//! cached decode of the same prompt — `tests/engine_parity.rs` pins
//! this, and `tests/sched.rs` covers the lifecycle edges (cancellation
//! mid-decode, zero-admission steps, finish-on-admission, FIFO
//! fairness).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::SchedConfig;
use crate::data::tokenizer::{self, EOS};
use crate::engine::decode::{self, DecodeStats};
use crate::engine::{Engine, KvCache};
use crate::obs::{ForwardPhase, Profiler, Tracer, Track};
use crate::serve::metrics::SchedStats;
use crate::serve::BucketPolicy;

use super::request::{FinishReason, RequestSpec, RequestState, SchedResponse, TokenSink};

/// Scheduler build knobs, in engine units. [`SchedConfig`] (the
/// TOML/CLI-facing form) converts via [`SchedOptions::from_config`].
#[derive(Clone, Copy, Debug)]
pub struct SchedOptions {
    /// desired concurrent decode slots (with the contiguous layout the
    /// KV budget may cap it lower; paged slots are bounded only by this)
    pub max_batch: usize,
    /// KV memory budget in bytes shared by all live slots
    pub kv_budget_bytes: usize,
    /// paged KV (default): the budget buys a shared block pool and
    /// admission reserves blocks per request instead of full-context rows
    pub kv_paged: bool,
    /// token positions per KV block (paged only)
    pub kv_block_size: usize,
    /// admission priority classes. 1 (the default) is plain FIFO —
    /// bitwise pinned against the pre-priority scheduler; N > 1 accepts
    /// [`RequestSpec::priority`] in `0..N` and admits the most-urgent
    /// effective class first, FIFO within a class
    pub priority_classes: usize,
    /// scheduler steps a waiting request sits before being promoted one
    /// priority class (the anti-starvation aging rule): a class-p request
    /// reaches class 0 after at most `p × aging_steps` steps. Not
    /// TOML-exposed — tests tighten it to force promotion quickly
    pub aging_steps: u64,
    /// bounded worker submit-queue cap (0 = unbounded). The in-process
    /// scheduler never rejects on depth — enforcement belongs to the
    /// worker front end, which owns the submit channel; the knob rides
    /// here so the TOML/CLI surface reaches it
    pub submit_queue_cap: usize,
    /// default TTFT deadline applied to specs that carry none
    /// (None = requests without a deadline are never shed)
    pub default_deadline_ms: Option<u64>,
}

impl Default for SchedOptions {
    fn default() -> SchedOptions {
        SchedOptions {
            max_batch: 8,
            kv_budget_bytes: 1 << 30,
            kv_paged: true,
            kv_block_size: 16,
            priority_classes: 1,
            aging_steps: 16,
            submit_queue_cap: 0,
            default_deadline_ms: None,
        }
    }
}

impl SchedOptions {
    pub fn from_config(cfg: &SchedConfig) -> SchedOptions {
        SchedOptions {
            max_batch: cfg.max_batch,
            kv_budget_bytes: cfg.kv_budget_mb << 20,
            kv_paged: cfg.kv_paged,
            kv_block_size: cfg.kv_block_size,
            priority_classes: cfg.priority_classes,
            submit_queue_cap: cfg.submit_queue_cap,
            // TOML uses 0 for "no deadline" (tables can't carry None)
            default_deadline_ms: (cfg.default_deadline_ms > 0).then_some(cfg.default_deadline_ms),
            ..SchedOptions::default()
        }
    }
}

/// A request waiting for a slot.
struct Queued {
    id: u64,
    frame: Vec<f32>,
    max_new: usize,
    /// adapter id to serve with (0 = bare base)
    adapter: u32,
    arrival: Instant,
    /// priority class (0 = most urgent); always 0 with one class
    priority: u8,
    /// absolute TTFT deadline — blown means shed before prefill
    deadline: Option<Instant>,
    /// step counter at submit — aging promotes by steps waited since
    submitted_step: u64,
}

/// A request occupying a decode slot. `slots[i]` owns cache row `i`.
struct Active {
    id: u64,
    /// adapter id this request is served with (0 = bare base)
    adapter: u32,
    /// BOS + prompt + SEP + generated-so-far, f32-coded
    frame: Vec<f32>,
    /// position whose logits pick the next token
    cursor: usize,
    generated: Vec<u32>,
    max_new: usize,
    state: RequestState,
    reason: Option<FinishReason>,
    arrival: Instant,
    admitted_at: Instant,
    /// step number this request was admitted in — a just-prefilled
    /// request must not also take a decode step in the same iteration
    admitted_step: u64,
    /// KV blocks promised to this request (paged only, 0 contiguous):
    /// enough for prompt + max_new, so its decode can never run the pool
    /// dry mid-flight. Returned to the unpromised pool on release.
    reserved_blocks: usize,
    ttft_secs: Option<f64>,
    last_token_at: Instant,
}

/// What one [`Scheduler::step`] did — the observable unit tests and the
/// serving loop key off.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// request ids admitted (and prefilled) this step, slot order
    pub admitted: Vec<u64>,
    /// rows fed by the single-token decode phase
    pub decoded_rows: usize,
    /// request ids whose slots were released at the end of this step
    pub finished: Vec<u64>,
    /// request ids shed from the queue this step — their TTFT deadline
    /// was already blown before prefill ([`FinishReason::Shed`])
    pub shed: Vec<u64>,
    /// requests still waiting after admission
    pub queue_depth: usize,
    /// busy slots / total slots during this step's compute
    pub occupancy: f64,
    /// 1 if this step stopped admitting because the KV block pool could
    /// not cover the next candidate (paged backpressure), else 0
    pub admission_denied: usize,
    /// wall time of the whole step, milliseconds (0.0 for idle no-ops)
    pub step_ms: f64,
    /// wall time of the admission phase, milliseconds
    pub admission_ms: f64,
    /// wall time of the padded prefill phase (forward + first picks),
    /// milliseconds; 0.0 when nothing was admitted
    pub prefill_ms: f64,
    /// wall time of the decode phase (forward + pick application),
    /// milliseconds; 0.0 when nothing decoded
    pub decode_ms: f64,
}

/// The request-level serving loop over one engine and one shared cache.
pub struct Scheduler<'a> {
    engine: &'a Engine,
    cache: KvCache,
    slots: Vec<Option<Active>>,
    queue: VecDeque<Queued>,
    next_id: u64,
    step_no: u64,
    finished: Vec<SchedResponse>,
    sink: Option<Box<dyn TokenSink + 'a>>,
    /// observability sink; None (the default) makes every emission site a
    /// single never-taken branch — no event is built, nothing allocates
    tracer: Option<Box<dyn Tracer + 'a>>,
    /// engine hot-path profiler; None (the default) keeps every forward
    /// on the unprofiled path — no window opens, no kernel accounting
    profiler: Option<Profiler>,
    decode_stats: DecodeStats,
    stats: SchedStats,
    /// paged layout: token positions per block (None when contiguous)
    block_size: Option<usize>,
    /// paged layout: pool size in blocks
    pool_blocks: usize,
    /// paged layout: Σ reserved_blocks over live rows — what admission
    /// checks candidates against (`pool_blocks - reserved_blocks` is the
    /// unpromised pool, regardless of how much is physically allocated)
    reserved_blocks: usize,
    /// admission priority classes (1 = plain FIFO, the pinned default)
    priority_classes: usize,
    /// steps waited per one-class aging promotion (≥ 1)
    aging_steps: u64,
    /// worker submit-queue cap carried from [`SchedOptions`] (0 = unbounded)
    submit_queue_cap: usize,
    /// default TTFT deadline for specs that carry none
    default_deadline_ms: Option<u64>,
}

fn secs(from: Instant, to: Instant) -> f64 {
    to.duration_since(from).as_secs_f64()
}

impl<'a> Scheduler<'a> {
    /// Build a scheduler. With the paged layout (the default) the KV
    /// budget buys a shared block pool and all `max_batch` slots exist —
    /// concurrency is bounded by tokens actually cached, not by
    /// full-context rows. With the contiguous layout the slot count is
    /// `max_batch` capped by how many full-context KV rows fit in the
    /// memory budget — the same `adaptive_capped` arithmetic the one-shot
    /// native backend uses, so the two modes serve under the same KV
    /// ceiling.
    pub fn new(engine: &'a Engine, opts: &SchedOptions) -> Result<Scheduler<'a>> {
        if opts.max_batch == 0 {
            bail!("scheduler needs at least one decode slot");
        }
        let (cache, n_slots, block_size, pool_blocks) = if opts.kv_paged {
            if opts.kv_block_size == 0 {
                bail!("paged scheduler needs kv_block_size of at least 1 token");
            }
            let block_bytes = engine.kv_block_bytes(opts.kv_block_size).max(1);
            let n_slots = opts.max_batch;
            // the budget buys the pool, capped at what n_slots rows can
            // ever address (slots × full-context blocks) — blocks beyond
            // that are unreachable by construction, and allocating them
            // would zero out the whole budget (1 GiB by default) for
            // nothing
            let reachable = n_slots * engine.config().seq_len.div_ceil(opts.kv_block_size);
            let pool = (opts.kv_budget_bytes / block_bytes).min(reachable).max(1);
            let cache = engine.new_cache_paged(
                n_slots,
                engine.config().seq_len,
                opts.kv_block_size,
                pool,
            )?;
            log::info!(
                "scheduler: {n_slots} paged decode slots over {pool} blocks × {} tokens \
                 ({} MiB KV budget)",
                opts.kv_block_size,
                opts.kv_budget_bytes >> 20
            );
            (cache, n_slots, Some(opts.kv_block_size), pool)
        } else {
            let budget_rows = opts.kv_budget_bytes / engine.cache_row_bytes().max(1);
            let n_slots = BucketPolicy::adaptive_capped(budget_rows)
                .pick(opts.max_batch)
                .expect("max_batch > 0 always picks");
            let cache = engine.new_cache(n_slots);
            log::info!(
                "scheduler: {n_slots} decode slots ({} requested, {budget_rows} fit the {} MiB KV budget)",
                opts.max_batch,
                opts.kv_budget_bytes >> 20
            );
            (cache, n_slots, None, 0)
        };
        Ok(Scheduler {
            engine,
            cache,
            slots: (0..n_slots).map(|_| None).collect(),
            queue: VecDeque::new(),
            next_id: 0,
            step_no: 0,
            finished: Vec::new(),
            sink: None,
            tracer: None,
            profiler: None,
            decode_stats: DecodeStats::default(),
            stats: SchedStats::default(),
            block_size,
            pool_blocks,
            reserved_blocks: 0,
            priority_classes: opts.priority_classes.max(1),
            aging_steps: opts.aging_steps.max(1),
            submit_queue_cap: opts.submit_queue_cap,
            default_deadline_ms: opts.default_deadline_ms,
        })
    }

    /// Attach a streaming observer (builder style).
    pub fn with_sink(mut self, sink: Box<dyn TokenSink + 'a>) -> Scheduler<'a> {
        self.sink = Some(sink);
        self
    }

    /// Attach a tracing sink (builder style). The tracer only observes —
    /// every span timestamp is an `Instant` the scheduler already takes
    /// for its stats, so scheduling decisions and token streams are
    /// bitwise unchanged by attaching one (`tests/obs.rs` pins this).
    pub fn with_tracer(mut self, mut tracer: Box<dyn Tracer + 'a>) -> Scheduler<'a> {
        tracer.meta("gemm_kernel", self.engine.gemm_kernel_label());
        tracer.meta("slots", &self.slots.len().to_string());
        tracer.meta(
            "kv_layout",
            if self.block_size.is_some() { "paged" } else { "contiguous" },
        );
        tracer.meta("adapters", &self.engine.adapter_count().to_string());
        self.tracer = Some(tracer);
        self
    }

    /// Attach an engine hot-path profiler (builder style). Like the
    /// tracer it only observes: profiled forwards read the same clocks
    /// the scheduler already stamps its wall-time stats with, and the
    /// profiled GEMM path runs single-threaded (bitwise-pinned against
    /// the threaded kernel), so token streams and stats are bitwise
    /// unchanged by attaching one (`tests/obs.rs` pins this). To land
    /// the engine spans inside this scheduler's `prefill_forward` /
    /// `decode_forward` trace spans, build the profiler with
    /// [`Profiler::with_sink`] over a clone of the same
    /// [`crate::obs::RecordingTracer`] passed to
    /// [`Scheduler::with_tracer`] — one shared clock, one trace.
    pub fn with_profiler(mut self, profiler: Profiler) -> Scheduler<'a> {
        self.profiler = Some(profiler);
        self
    }

    /// The attached profiler, if any — read it after a run to fold
    /// windows into a registry or inspect [`crate::obs::WindowProfile`]s.
    pub fn profiler(&self) -> Option<&Profiler> {
        self.profiler.as_ref()
    }

    /// Concurrent decode slots this scheduler runs (KV-budget capped in
    /// the contiguous layout; `max_batch` in the paged one).
    pub fn n_slots(&self) -> usize {
        self.slots.len()
    }

    /// Whether this scheduler serves over a paged KV cache.
    pub fn kv_paged(&self) -> bool {
        self.block_size.is_some()
    }

    /// `(free, total)` KV block pool state (None when contiguous).
    pub fn block_pool(&self) -> Option<(usize, usize)> {
        self.cache.free_blocks().map(|free| (free, self.pool_blocks))
    }

    /// Requests waiting for a slot.
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently holding a decode slot.
    pub fn active_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Nothing queued and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.slots.iter().all(Option::is_none)
    }

    /// Lifecycle state of request `id`: live states for queued/in-flight
    /// requests, `Finished`/`Cancelled` for completed ones not yet taken
    /// with [`Scheduler::take_finished`], None after that.
    pub fn state_of(&self, id: u64) -> Option<RequestState> {
        if self.queue.iter().any(|q| q.id == id) {
            return Some(RequestState::Queued);
        }
        for slot in self.slots.iter().flatten() {
            if slot.id == id {
                return Some(slot.state);
            }
        }
        self.finished.iter().find(|r| r.id == id).map(|r| {
            if r.reason == FinishReason::Cancelled {
                RequestState::Cancelled
            } else {
                RequestState::Finished
            }
        })
    }

    /// The id the next successful submit will return. Submission errors
    /// (framing, unknown adapter, over-pool horizon) consume no id, so a
    /// cross-thread front end can register a stream under this id
    /// *before* submitting — a zero-`max_new` request finishes inside the
    /// submit call itself, before any later registration could run.
    pub fn next_request_id(&self) -> u64 {
        self.next_id
    }

    /// Submit one [`RequestSpec`]; returns the request id. This is the
    /// whole submit surface — adapter, priority class, TTFT deadline, and
    /// the cross-thread arrival stamp all ride the spec, and a default
    /// spec ([`RequestSpec::new`]) is exactly the pre-redesign FIFO path.
    ///
    /// Framing errors (prompt + generation over the context) surface
    /// here, before the request ever queues — as do an unknown adapter
    /// id, a priority class at or above the configured count, and a paged
    /// request whose horizon exceeds the whole block pool, which no
    /// amount of waiting could ever admit. A zero-token request completes
    /// immediately without consuming any forward — the same contract as
    /// the one-shot decode. A request whose deadline is already blown on
    /// arrival completes immediately too, as [`FinishReason::Shed`],
    /// without ever queueing.
    ///
    /// For specs stamped with [`RequestSpec::enqueued_at`] (the worker's
    /// channel-entry instant), the single `Instant::now()` taken here
    /// closes the cross-thread "handoff" span *and* stamps the request's
    /// arrival — one clock, so queue-wait/TTFT include the handoff
    /// exactly once and trace spans butt against each other with no gap
    /// or overlap. Handoff time lands in [`SchedStats::handoff_ms`],
    /// which isolates channel overhead from compute in `bench_serve_load`.
    ///
    /// Adapter requests (`spec.adapter` = the 1-based id
    /// [`Engine::register_adapter`] returned; 0 = bare base) mix freely
    /// in one step — the per-row grid deltas keep every mixed batch
    /// bit-identical to serving each adapter's merged checkpoint alone
    /// (`tests/adapters.rs` pins it).
    pub fn submit(&mut self, spec: RequestSpec) -> Result<u64> {
        let RequestSpec { prompt, max_new, adapter, priority, deadline_ms, enqueued_at } = spec;
        if adapter as usize > self.engine.adapter_count() {
            bail!(
                "adapter id {adapter} is not registered (engine serves {} adapters)",
                self.engine.adapter_count()
            );
        }
        if priority as usize >= self.priority_classes {
            bail!(
                "priority class {priority} is out of range (scheduler runs {} classes)",
                self.priority_classes
            );
        }
        let (frame, _cursor) = decode::frame_prompt(self.engine.config(), &prompt, max_new)?;
        // zero-token requests complete below without ever touching the
        // cache, so only real generations are held to the pool bound
        if let (Some(bs), true) = (self.block_size, max_new > 0) {
            let need = (frame.len() + max_new).div_ceil(bs);
            if need > self.pool_blocks {
                bail!(
                    "request needs {need} KV blocks (prompt {} + {max_new} tokens) but the \
                     pool holds {} — raise the KV budget or lower kv_block_size",
                    frame.len(),
                    self.pool_blocks
                );
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        // ONE Instant for everything below: it ends the cross-thread
        // handoff (span + stat) and starts the request's own clock —
        // adding a second `now()` here would open a gap between the two
        let arrival = Instant::now();
        if let Some(from) = enqueued_at {
            self.stats.handoff_ms.record(1e3 * secs(from, arrival));
        }
        // the TTFT deadline runs from system entry — channel entry for
        // handed-off requests — so worker transport time counts against
        // the SLO, exactly like it counts in queue-wait/TTFT stats
        let deadline = deadline_ms
            .or(self.default_deadline_ms)
            .map(|ms| enqueued_at.unwrap_or(arrival) + Duration::from_millis(ms));
        if max_new == 0 {
            if let Some(tr) = self.tracer.as_mut() {
                // a zero-length span: the request existed but never queued
                tr.begin(Track::Request(id), "request", arrival);
                if adapter > 0 {
                    tr.counter(Track::Request(id), "adapter_id", adapter as f64, arrival);
                }
                if let Some(from) = enqueued_at {
                    tr.begin(Track::Request(id), "handoff", from);
                    tr.end(Track::Request(id), "handoff", arrival);
                }
                tr.end(Track::Request(id), "request", arrival);
            }
            let resp = SchedResponse {
                id,
                adapter,
                text: String::new(),
                tokens: 0,
                reason: FinishReason::MaxTokens,
                queue_wait_secs: 0.0,
                ttft_secs: None,
                latency_secs: 0.0,
            };
            self.emit_finish(resp);
            return Ok(id);
        }
        // deadline already blown on arrival (deadline_ms 0, or handoff
        // ate the whole budget): shed before the request ever queues —
        // no engine work, no cache row, no id consumed beyond this one.
        // Reuses `arrival`, the one Instant this call took.
        if deadline.is_some_and(|dl| arrival >= dl) {
            if let Some(tr) = self.tracer.as_mut() {
                tr.begin(Track::Request(id), "request", enqueued_at.unwrap_or(arrival));
                if adapter > 0 {
                    tr.counter(Track::Request(id), "adapter_id", adapter as f64, arrival);
                }
                if let Some(from) = enqueued_at {
                    tr.begin(Track::Request(id), "handoff", from);
                    tr.end(Track::Request(id), "handoff", arrival);
                }
                // a zero-length shed span marks the drop decision
                tr.begin(Track::Request(id), "shed", arrival);
                tr.end(Track::Request(id), "shed", arrival);
                tr.end(Track::Request(id), "request", arrival);
            }
            self.stats.shed_at_submit += 1;
            let wait = enqueued_at.map_or(0.0, |from| secs(from, arrival));
            let resp = SchedResponse {
                id,
                adapter,
                text: String::new(),
                tokens: 0,
                reason: FinishReason::Shed,
                queue_wait_secs: wait,
                ttft_secs: None,
                latency_secs: wait,
            };
            self.emit_finish(resp);
            return Ok(id);
        }
        if let Some(tr) = self.tracer.as_mut() {
            // the request track opens at channel-entry time for handed-off
            // requests, so the handoff span nests inside it
            tr.begin(Track::Request(id), "request", enqueued_at.unwrap_or(arrival));
            // adapter identity rides the request track as a counter —
            // base requests (id 0) emit nothing, so the golden base-only
            // trace sequence is untouched
            if adapter > 0 {
                tr.counter(Track::Request(id), "adapter_id", adapter as f64, arrival);
            }
            if let Some(from) = enqueued_at {
                tr.begin(Track::Request(id), "handoff", from);
                tr.end(Track::Request(id), "handoff", arrival);
            }
            tr.begin(Track::Request(id), "queued", arrival);
        }
        self.queue.push_back(Queued {
            id,
            frame,
            max_new,
            adapter,
            arrival,
            priority,
            deadline,
            submitted_step: self.step_no,
        });
        Ok(id)
    }

    /// Count one bounded-submit-queue rejection. The worker front end
    /// owns the cap (it rejects before the spec ever reaches this
    /// scheduler), but the count lives here so [`SchedStats`] — and
    /// everything derived from it: the metrics registry, bench reports —
    /// reconciles exactly with the transport's 503 responses.
    pub fn note_queue_rejected(&mut self) {
        self.stats.queue_rejected += 1;
    }

    /// Bounded worker submit-queue cap this scheduler was configured
    /// with (0 = unbounded). Read by the worker front end at submit time.
    pub fn submit_queue_cap(&self) -> usize {
        self.submit_queue_cap
    }

    /// Back-off hint in whole seconds for a rejected submit — the
    /// `Retry-After` value the HTTP front end returns with a queue-full
    /// 503. Estimates time-to-drain as queue depth × observed per-request
    /// service time (mean queue wait, falling back to mean handoff when
    /// nothing was admitted yet), clamped to [1, 30] so a cold scheduler
    /// still answers something sane.
    pub fn retry_after_hint_secs(&self) -> u64 {
        let (wait, hand) = (&self.stats.queue_wait_ms, &self.stats.handoff_ms);
        let per_req_ms = if !wait.is_empty() {
            wait.sum() / wait.len() as f64
        } else if !hand.is_empty() {
            hand.sum() / hand.len() as f64
        } else {
            1.0
        }
        .max(1.0);
        let est = (self.queue.len() as f64 * per_req_ms / 1e3).ceil() as u64;
        est.clamp(1, 30)
    }

    /// A queued request's class after aging: one class of promotion per
    /// [`SchedOptions::aging_steps`] scheduler steps waited, saturating
    /// at 0 — so a class-p request outranks fresh class-0 arrivals after
    /// at most `p × aging_steps` steps. That product is the starvation
    /// bound.
    fn effective_class(&self, q: &Queued) -> u8 {
        let waited = self.step_no.saturating_sub(q.submitted_step);
        let promoted = (waited / self.aging_steps).min(u8::MAX as u64) as u8;
        q.priority.saturating_sub(promoted)
    }

    /// Index of the next admission candidate: the queued request with
    /// the lowest (most urgent) effective class, FIFO within a class —
    /// the strict `<` keeps the earliest index on ties, and queue order
    /// is submission order, so equal priorities admit exactly FIFO. With
    /// one priority class every effective class is 0 and this is always
    /// index 0: the pre-priority front-of-queue scan, bitwise.
    fn pick_candidate(&self) -> usize {
        if self.priority_classes == 1 {
            return 0;
        }
        let mut best = 0;
        let mut best_class = self.effective_class(&self.queue[0]);
        for i in 1..self.queue.len() {
            let class = self.effective_class(&self.queue[i]);
            if class < best_class {
                best = i;
                best_class = class;
            }
        }
        best
    }

    /// Cancel request `id`. A queued request leaves the queue; an
    /// in-flight one releases its slot (and cache row) immediately, so
    /// the very next step can admit a waiting request into it. Returns
    /// false if the id is unknown or already finished.
    pub fn cancel(&mut self, id: u64) -> bool {
        if let Some(pos) = self.queue.iter().position(|q| q.id == id) {
            let q = self.queue.remove(pos).expect("position came from the queue");
            let now = Instant::now();
            if let Some(tr) = self.tracer.as_mut() {
                tr.end(Track::Request(id), "queued", now);
                tr.end(Track::Request(id), "request", now);
            }
            let wait = secs(q.arrival, now);
            let resp = SchedResponse {
                id,
                adapter: q.adapter,
                text: String::new(),
                tokens: 0,
                reason: FinishReason::Cancelled,
                queue_wait_secs: wait,
                ttft_secs: None,
                latency_secs: wait,
            };
            self.emit_finish(resp);
            return true;
        }
        for si in 0..self.slots.len() {
            if self.slots[si].as_ref().is_some_and(|a| a.id == id) {
                let mut a = self.slots[si].take().expect("checked is_some");
                a.reason = Some(FinishReason::Cancelled);
                self.cache.reset_row(si);
                self.reserved_blocks -= a.reserved_blocks;
                let now = Instant::now();
                if let Some(tr) = self.tracer.as_mut() {
                    // between steps the only open span on an in-flight
                    // request's track is "request" — phase spans close
                    // inside the step that opened them
                    tr.end(Track::Request(id), "request", now);
                }
                let resp = Self::respond(a, now);
                self.emit_finish(resp);
                return true;
            }
        }
        false
    }

    /// One serving iteration: admit → prefill → decode → release. A call
    /// on an idle scheduler is a no-op that runs no forwards.
    pub fn step(&mut self) -> Result<StepReport> {
        let mut report = StepReport::default();
        if self.is_idle() {
            return Ok(report);
        }
        self.step_no += 1;
        let t_step = Instant::now();
        if let Some(tr) = self.tracer.as_mut() {
            tr.begin(Track::Scheduler, "step", t_step);
            tr.begin(Track::Scheduler, "admission", t_step);
        }

        // 0. deadline shedding: drop every queued request whose TTFT
        // deadline is already behind the step clock *before* spending any
        // prefill compute on it. Reuses `t_step` — the Instant this step
        // already took — so no-deadline workloads (the default) see no
        // extra clock reads and the sweep is a single cheap scan.
        let mut qi = 0;
        while qi < self.queue.len() {
            if self.queue[qi].deadline.is_some_and(|dl| t_step >= dl) {
                let q = self.queue.remove(qi).expect("index came from the scan");
                if let Some(tr) = self.tracer.as_mut() {
                    tr.end(Track::Request(q.id), "queued", t_step);
                    // a zero-length shed span marks the drop decision
                    tr.begin(Track::Request(q.id), "shed", t_step);
                    tr.end(Track::Request(q.id), "shed", t_step);
                    tr.end(Track::Request(q.id), "request", t_step);
                }
                self.stats.shed_in_queue += 1;
                report.shed.push(q.id);
                let wait = secs(q.arrival, t_step);
                let resp = SchedResponse {
                    id: q.id,
                    adapter: q.adapter,
                    text: String::new(),
                    tokens: 0,
                    reason: FinishReason::Shed,
                    queue_wait_secs: wait,
                    ttft_secs: None,
                    latency_secs: wait,
                };
                self.emit_finish(resp);
            } else {
                qi += 1;
            }
        }

        // 1. admission: FIFO into free slots. Slots freed by last step's
        // finishes (or a cancel since) are handed out here, mid-batch.
        // Paged admission additionally requires the block pool to cover
        // the candidate net of what's promised to in-flight rows. The
        // standing reservation is the candidate's decode horizon in
        // blocks; the admission check also covers the wave's transient —
        // a padded batch prefill briefly writes every admitted row out to
        // the longest frame before `truncate_row` hands the pad-tail
        // blocks back, so each wave member transiently needs
        // max(pad, horizon). Denial stops the scan (FIFO — no skip-ahead)
        // and the candidate just waits; nothing in flight is ever
        // evicted.
        let mut admitted_rows: Vec<usize> = Vec::new();
        // (frame len, horizon blocks) of requests admitted this wave
        let mut wave: Vec<(usize, usize)> = Vec::new();
        let free_slots: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(si, _)| si)
            .collect();
        for si in free_slots {
            if self.queue.is_empty() {
                break;
            }
            // with one priority class this is always index 0 — the exact
            // front-of-queue scan the pre-priority scheduler ran, so the
            // bitwise FIFO pin holds. Denial of the *picked* candidate
            // still stops the whole scan (no skip-ahead): the wave pad
            // math couples candidates, and skipping would let a short
            // request starve a long one's reservation.
            let ci = self.pick_candidate();
            let front = &self.queue[ci];
            let reserve = if let Some(bs) = self.block_size {
                let (q_len, q_max_new) = (front.frame.len(), front.max_new);
                let q_horizon = (q_len + q_max_new).div_ceil(bs);
                // padded prefill length if this candidate joins the wave
                let t0 = wave.iter().map(|&(len, _)| len).max().unwrap_or(0).max(q_len);
                let pad = t0.div_ceil(bs);
                // total demand: every wave member (candidate included)
                // transiently needs max(pad, its horizon); live rows keep
                // their standing reservations
                let wave_need: usize =
                    wave.iter().map(|&(_, h)| h.max(pad)).sum::<usize>() + q_horizon.max(pad);
                let standing: usize = wave.iter().map(|&(_, h)| h).sum();
                if self.reserved_blocks - standing + wave_need > self.pool_blocks {
                    self.stats.admission_denied += 1;
                    report.admission_denied = 1;
                    break;
                }
                wave.push((q_len, q_horizon));
                q_horizon
            } else {
                0
            };
            let q = self.queue.remove(ci).expect("pick_candidate() is in range");
            let now = Instant::now();
            if let Some(tr) = self.tracer.as_mut() {
                // the queued→prefill handoff shares one Instant with the
                // queue-wait stat, so the trace and SchedStats agree
                tr.end(Track::Request(q.id), "queued", now);
                tr.begin(Track::Request(q.id), "prefill", now);
            }
            self.stats.queue_wait_ms.record(1e3 * secs(q.arrival, now));
            self.reserved_blocks += reserve;
            report.admitted.push(q.id);
            admitted_rows.push(si);
            self.slots[si] = Some(Active {
                id: q.id,
                adapter: q.adapter,
                cursor: q.frame.len() - 1,
                frame: q.frame,
                generated: Vec::new(),
                max_new: q.max_new,
                state: RequestState::Prefilling,
                reason: None,
                arrival: q.arrival,
                admitted_at: now,
                admitted_step: self.step_no,
                reserved_blocks: reserve,
                ttft_secs: None,
                last_token_at: now,
            });
        }
        let t_admit = Instant::now();
        report.admission_ms = 1e3 * secs(t_step, t_admit);
        if let Some(tr) = self.tracer.as_mut() {
            tr.end(Track::Scheduler, "admission", t_admit);
        }
        let busy = self.active_count();
        self.stats.steps += 1;
        self.stats.queue_depth.record(self.queue.len() as f64);
        self.stats.peak_active = self.stats.peak_active.max(busy);
        report.queue_depth = self.queue.len();
        report.occupancy = busy as f64 / self.slots.len() as f64;
        self.stats.batch_occupancy.record(report.occupancy);

        // 2. prefill everything admitted this step in one padded batch
        if !admitted_rows.is_empty() {
            let t_pre = Instant::now();
            if let Some(tr) = self.tracer.as_mut() {
                tr.begin(Track::Scheduler, "prefill_forward", t_pre);
            }
            // the profiler window opens and closes on the very Instants
            // prefill_ms is computed from, so the window's segment sum
            // reconciles with the report wall-time exactly (not within a
            // tolerance) — tests/obs.rs pins the f64 bit-equality
            if let Some(p) = self.profiler.as_ref() {
                p.begin_window(ForwardPhase::Prefill, self.step_no, t_pre);
            }
            let frames: Vec<Vec<f32>> = admitted_rows
                .iter()
                .map(|&si| self.slots[si].as_ref().expect("just admitted").frame.clone())
                .collect();
            let adapters: Vec<u32> = admitted_rows
                .iter()
                .map(|&si| self.slots[si].as_ref().expect("just admitted").adapter)
                .collect();
            let picks = decode::prefill_rows(
                self.engine,
                &mut self.cache,
                &admitted_rows,
                &frames,
                &adapters,
                &mut self.decode_stats,
                self.profiler.as_ref(),
            )?;
            for (i, &si) in admitted_rows.iter().enumerate() {
                self.apply_pick(si, picks[i]);
            }
            let t_pre_end = Instant::now();
            report.prefill_ms = 1e3 * secs(t_pre, t_pre_end);
            if let Some(p) = self.profiler.as_ref() {
                p.end_window(t_pre_end);
            }
            if let Some(tr) = self.tracer.as_mut() {
                tr.end(Track::Scheduler, "prefill_forward", t_pre_end);
            }
        }

        // 3. one decode token for every request admitted in earlier steps
        let mut rows: Vec<usize> = Vec::new();
        let mut row_ids: Vec<u64> = Vec::new();
        let mut last: Vec<f32> = Vec::new();
        let mut row_adapters: Vec<u32> = Vec::new();
        for (si, slot) in self.slots.iter().enumerate() {
            if let Some(a) = slot {
                if a.state == RequestState::Decoding && a.admitted_step < self.step_no {
                    rows.push(si);
                    row_ids.push(a.id);
                    last.push(*a.frame.last().expect("frames are never empty"));
                    row_adapters.push(a.adapter);
                }
            }
        }
        if !rows.is_empty() {
            let t_dec = Instant::now();
            if let Some(tr) = self.tracer.as_mut() {
                tr.begin(Track::Scheduler, "decode_forward", t_dec);
                for &id in &row_ids {
                    tr.begin(Track::Request(id), "decode_step", t_dec);
                }
            }
            if let Some(p) = self.profiler.as_ref() {
                p.begin_window(ForwardPhase::Decode, self.step_no, t_dec);
            }
            let picks = decode::decode_step_rows(
                self.engine,
                &mut self.cache,
                &rows,
                &last,
                &row_adapters,
                &mut self.decode_stats,
                self.profiler.as_ref(),
            )?;
            report.decoded_rows = rows.len();
            for (i, &si) in rows.iter().enumerate() {
                self.apply_pick(si, picks[i]);
            }
            let t_dec_end = Instant::now();
            report.decode_ms = 1e3 * secs(t_dec, t_dec_end);
            if let Some(p) = self.profiler.as_ref() {
                p.end_window(t_dec_end);
            }
            if let Some(tr) = self.tracer.as_mut() {
                tr.end(Track::Scheduler, "decode_forward", t_dec_end);
            }
        }

        // 4. release finished slots — their cache rows (and, paged, their
        // blocks and reservations) are reclaimed right now, so the next
        // step's admission can reuse them
        let mut released: Vec<Active> = Vec::new();
        let t_rel = Instant::now();
        for (si, slot) in self.slots.iter_mut().enumerate() {
            let done = slot.as_ref().is_some_and(|a| {
                matches!(a.state, RequestState::Finished | RequestState::Cancelled)
            });
            if done {
                released.push(slot.take().expect("checked is_some"));
                self.cache.reset_row(si);
            }
        }
        let now = Instant::now();
        if !released.is_empty() {
            if let Some(tr) = self.tracer.as_mut() {
                tr.begin(Track::Scheduler, "kv_release", t_rel);
                tr.end(Track::Scheduler, "kv_release", now);
            }
        }
        for a in released {
            self.reserved_blocks -= a.reserved_blocks;
            if let Some(tr) = self.tracer.as_mut() {
                // the request span closes on the same Instant respond()
                // stamps latency_secs with
                tr.end(Track::Request(a.id), "request", now);
            }
            let resp = Self::respond(a, now);
            report.finished.push(resp.id);
            self.emit_finish(resp);
        }
        // paged pool pressure after this step's releases — what the
        // benches chart against the admission-denied counter
        if let Some((free, total)) = self.block_pool() {
            self.stats.block_util.record((total - free) as f64 / total.max(1) as f64);
        }
        let pool = self.block_pool();
        let block_counters = self.cache.block_counters();
        let alloc_wall_ms = self.cache.alloc_wall_ms();
        let t_end = Instant::now();
        report.step_ms = 1e3 * secs(t_step, t_end);
        if let Some(tr) = self.tracer.as_mut() {
            tr.counter(Track::Scheduler, "queue_depth", report.queue_depth as f64, t_end);
            tr.counter(Track::Scheduler, "occupancy", report.occupancy, t_end);
            tr.counter(Track::Scheduler, "decoded_rows", report.decoded_rows as f64, t_end);
            tr.counter(
                Track::Scheduler,
                "admission_denied_total",
                self.stats.admission_denied as f64,
                t_end,
            );
            if let Some((free, total)) = pool {
                tr.counter(Track::Scheduler, "kv_blocks_in_use", (total - free) as f64, t_end);
            }
            if let Some(c) = block_counters {
                tr.counter(Track::Scheduler, "kv_allocs_total", c.allocs as f64, t_end);
                tr.counter(Track::Scheduler, "kv_frees_total", c.frees as f64, t_end);
                tr.counter(Track::Scheduler, "kv_alloc_ms_total", alloc_wall_ms, t_end);
            }
            tr.end(Track::Scheduler, "step", t_end);
        }
        Ok(report)
    }

    /// Drive [`Scheduler::step`] until nothing is queued or in flight.
    pub fn run_until_idle(&mut self) -> Result<()> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(())
    }

    /// Completed responses accumulated since the last take, in completion
    /// order.
    pub fn take_finished(&mut self) -> Vec<SchedResponse> {
        std::mem::take(&mut self.finished)
    }

    /// Aggregate decode-work accounting across every forward this
    /// scheduler ran (prefills + steps).
    pub fn decode_stats(&self) -> DecodeStats {
        self.decode_stats
    }

    /// Request- and step-level measurements so far.
    pub fn sched_stats(&self) -> SchedStats {
        self.stats.clone()
    }

    /// Fold one argmax pick into slot `si`'s request: append or finish,
    /// exactly the one-shot decode's `step_row` semantics plus the
    /// per-request `max_new` budget.
    fn apply_pick(&mut self, si: usize, pick: u32) {
        let t_cap = self.engine.config().seq_len;
        let now = Instant::now();
        let a = self.slots[si].as_mut().expect("apply_pick on an empty slot");
        if let Some(tr) = self.tracer.as_mut() {
            // close this row's open phase span — opened at admission
            // ("prefill") or at the decode fan-out ("decode_step") — on
            // the same Instant the ttft/inter-token stats record below,
            // so trace durations and histograms agree exactly. Closing
            // before the finish check keeps EOS/cap picks paired too.
            let span = if a.state == RequestState::Prefilling { "prefill" } else { "decode_step" };
            tr.end(Track::Request(a.id), span, now);
        }
        let done = decode::step_row(pick, t_cap, &mut a.frame, &mut a.cursor, &mut a.generated);
        if done {
            a.state = RequestState::Finished;
            a.reason = Some(if pick == EOS {
                FinishReason::Eos
            } else {
                FinishReason::ContextCap
            });
            return;
        }
        // a token was appended
        let id = a.id;
        let tok = *a.generated.last().expect("step_row appended");
        if a.ttft_secs.is_none() {
            let ttft = secs(a.arrival, now);
            a.ttft_secs = Some(ttft);
            self.stats.ttft_ms.record(1e3 * ttft);
        } else {
            self.stats.inter_token_ms.record(1e3 * secs(a.last_token_at, now));
        }
        a.last_token_at = now;
        if a.generated.len() >= a.max_new {
            a.state = RequestState::Finished;
            a.reason = Some(FinishReason::MaxTokens);
        } else {
            a.state = RequestState::Decoding;
        }
        if let Some(sink) = self.sink.as_mut() {
            sink.on_token(id, tok);
        }
    }

    fn respond(a: Active, now: Instant) -> SchedResponse {
        SchedResponse {
            id: a.id,
            adapter: a.adapter,
            text: tokenizer::decode(&a.generated),
            tokens: a.generated.len(),
            reason: a.reason.expect("released requests always carry a reason"),
            queue_wait_secs: secs(a.arrival, a.admitted_at),
            ttft_secs: a.ttft_secs,
            latency_secs: secs(a.arrival, now),
        }
    }

    fn emit_finish(&mut self, resp: SchedResponse) {
        // per-adapter usage keyed by label ("base" for id 0), recorded on
        // every completion path — finish, cancel, and zero-token alike
        let label = self.engine.adapter_label(resp.adapter).to_string();
        let usage = self.stats.adapter_usage.entry(label).or_default();
        usage.requests += 1;
        usage.tokens += resp.tokens;
        if let Some(sink) = self.sink.as_mut() {
            sink.on_finish(&resp);
        }
        self.finished.push(resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::model;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn tiny_engine(seed: u64) -> Engine {
        let cfg = preset("tiny").unwrap();
        let mut rng = Rng::new(seed);
        let fp = model::init_fp(&cfg, &mut rng);
        let store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })
        .unwrap();
        Engine::from_store(&cfg, &store, 4).unwrap()
    }

    fn opts(max_batch: usize) -> SchedOptions {
        // generous budget, paged by default — the lifecycle tests below
        // run on the default layout
        SchedOptions { max_batch, ..SchedOptions::default() }
    }

    fn contiguous(max_batch: usize, kv_budget_bytes: usize) -> SchedOptions {
        SchedOptions { max_batch, kv_budget_bytes, kv_paged: false, ..SchedOptions::default() }
    }

    #[test]
    fn slot_count_respects_kv_budget() {
        // the contiguous reference layout: the budget caps the slot pool
        // at full-context rows
        let engine = tiny_engine(1);
        let row = engine.cache_row_bytes();
        // budget for exactly 3 full-context rows
        let s = Scheduler::new(&engine, &contiguous(8, 3 * row)).unwrap();
        assert_eq!(s.n_slots(), 3);
        assert!(!s.kv_paged());
        assert_eq!(s.block_pool(), None);
        // a generous budget leaves max_batch in charge
        let s = Scheduler::new(&engine, &contiguous(8, 1 << 30)).unwrap();
        assert_eq!(s.n_slots(), 8);
        // a starved budget still yields one slot (degraded, not dead)
        let s = Scheduler::new(&engine, &contiguous(8, 0)).unwrap();
        assert_eq!(s.n_slots(), 1);
        assert!(Scheduler::new(&engine, &opts(0)).is_err());
    }

    #[test]
    fn paged_pool_sizing_and_slot_count() {
        let engine = tiny_engine(1);
        let block = engine.kv_block_bytes(16);
        // the same budget that caps contiguous at 3 rows buys a paged
        // pool of 3 × (seq_len / block_size) blocks — and all max_batch
        // slots exist, because blocks, not rows, are the resource
        let budget = 3 * engine.cache_row_bytes();
        let s = Scheduler::new(
            &engine,
            &SchedOptions { max_batch: 8, kv_budget_bytes: budget, ..SchedOptions::default() },
        )
        .unwrap();
        assert!(s.kv_paged());
        assert_eq!(s.n_slots(), 8);
        assert_eq!(s.block_pool(), Some((budget / block, budget / block)));
        // a huge budget is capped at what the slots can ever address —
        // 8 slots × (seq_len / block_size) blocks — instead of eagerly
        // zero-allocating the whole budget
        let generous = Scheduler::new(&engine, &SchedOptions::default()).unwrap();
        let reachable = 8 * engine.config().seq_len.div_ceil(16);
        assert_eq!(generous.block_pool(), Some((reachable, reachable)));
        // degenerate knobs fail loud or degrade to one block
        assert!(Scheduler::new(
            &engine,
            &SchedOptions { kv_block_size: 0, ..SchedOptions::default() }
        )
        .is_err());
        let starved = Scheduler::new(
            &engine,
            &SchedOptions { kv_budget_bytes: 0, ..SchedOptions::default() },
        )
        .unwrap();
        assert_eq!(starved.n_slots(), 8, "paged slots are not budget-capped");
        assert_eq!(starved.block_pool(), Some((1, 1)));
    }

    #[test]
    fn paged_admission_denies_and_recovers_without_eviction() {
        let engine = tiny_engine(8);
        // a pool of 2 blocks × 16 tokens: short requests need 1 block
        // each (frame + max_new ≤ 16), so at most 2 can be in flight even
        // though 4 slots exist
        let tight = SchedOptions {
            max_batch: 4,
            kv_budget_bytes: 2 * engine.kv_block_bytes(16),
            ..SchedOptions::default()
        };
        let mut s = Scheduler::new(&engine, &tight).unwrap();
        assert_eq!(s.block_pool(), Some((2, 2)));
        let mut ids = Vec::new();
        for i in 0..4 {
            ids.push(s.submit(RequestSpec::new(format!("{i} + 1 ="), 4)).unwrap());
        }
        let report = s.step().unwrap();
        assert_eq!(report.admitted.len(), 2, "pool of 2 blocks admitted {report:?}");
        assert_eq!(report.admission_denied, 1);
        s.run_until_idle().unwrap();
        let done = s.take_finished();
        assert_eq!(done.len(), 4, "denied requests were lost, not delayed");
        for r in &done {
            assert_ne!(r.reason, FinishReason::Cancelled);
        }
        let stats = s.sched_stats();
        assert!(stats.admission_denied >= 1);
        assert!(stats.peak_active <= 2, "pool bound was violated: {}", stats.peak_active);
        assert!(!stats.block_util.is_empty());
        // all blocks returned once idle
        assert_eq!(s.block_pool(), Some((2, 2)));
    }

    #[test]
    fn paged_submit_rejects_requests_larger_than_the_pool() {
        let engine = tiny_engine(9);
        let tight = SchedOptions {
            max_batch: 2,
            kv_budget_bytes: 3 * engine.kv_block_bytes(16),
            ..SchedOptions::default()
        };
        let mut s = Scheduler::new(&engine, &tight).unwrap();
        // ~9 frame tokens + 100 generated needs 7 blocks > pool of 3: no
        // amount of waiting could admit this — refuse at submit
        assert!(s.submit(RequestSpec::new("1 + 1 =", 100)).is_err());
        assert!(s.is_idle());
        // a fitting request on the same scheduler still serves
        let id = s.submit(RequestSpec::new("1 + 1 =", 4)).unwrap();
        s.run_until_idle().unwrap();
        assert_eq!(s.take_finished()[0].id, id);
    }

    #[test]
    fn runs_a_small_workload_to_completion() {
        let engine = tiny_engine(2);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        let mut ids = Vec::new();
        for i in 0..5 {
            ids.push(s.submit(RequestSpec::new(format!("{i} + 1 ="), 4)).unwrap());
        }
        assert_eq!(s.queue_depth(), 5);
        s.run_until_idle().unwrap();
        let mut done = s.take_finished();
        assert_eq!(done.len(), 5);
        done.sort_by_key(|r| r.id);
        for (resp, id) in done.iter().zip(&ids) {
            assert_eq!(resp.id, *id);
            assert!(resp.tokens <= 4);
            assert_ne!(resp.reason, FinishReason::Cancelled);
        }
        // all decode work was accounted
        assert!(s.decode_stats().forwards > 0);
        let stats = s.sched_stats();
        assert_eq!(stats.queue_wait_ms.len(), 5);
        assert!(stats.steps > 0);
    }

    #[test]
    fn zero_max_new_completes_without_forwards() {
        let engine = tiny_engine(3);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        let id = s.submit(RequestSpec::new("1 + 1 =", 0)).unwrap();
        assert!(s.is_idle(), "zero-token request should never queue");
        let done = s.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].tokens, 0);
        assert_eq!(s.decode_stats(), DecodeStats::default());
    }

    #[test]
    fn oversized_prompts_fail_at_submit() {
        let engine = tiny_engine(4);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        let long = "1 + 2 = ".repeat(32);
        assert!(s.submit(RequestSpec::new(long, 8)).is_err());
        assert!(s.is_idle());
    }

    #[test]
    fn idle_step_is_a_no_op() {
        let engine = tiny_engine(5);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        let report = s.step().unwrap();
        assert!(report.admitted.is_empty());
        assert_eq!(report.decoded_rows, 0);
        assert_eq!(s.decode_stats(), DecodeStats::default());
        assert_eq!(s.sched_stats().steps, 0);
    }

    #[test]
    fn unknown_cancel_is_refused() {
        let engine = tiny_engine(6);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        assert!(!s.cancel(99));
        let id = s.submit(RequestSpec::new("1 + 1 =", 2)).unwrap();
        assert!(s.cancel(id));
        assert!(!s.cancel(id), "double cancel must be refused");
    }

    #[test]
    fn priority_out_of_range_is_refused_at_submit() {
        let engine = tiny_engine(15);
        // default options run one class: only priority 0 is legal
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        assert!(s.submit(RequestSpec::new("1 + 1 =", 2).priority(1)).is_err());
        assert!(s.is_idle());
        assert_eq!(s.next_request_id(), 0, "refused submits consume no id");
    }

    #[test]
    fn priority_classes_admit_most_urgent_first() {
        let engine = tiny_engine(10);
        let o = SchedOptions { max_batch: 1, priority_classes: 3, ..SchedOptions::default() };
        let mut s = Scheduler::new(&engine, &o).unwrap();
        let low = s.submit(RequestSpec::new("1 + 1 =", 1).priority(2)).unwrap();
        let hi = s.submit(RequestSpec::new("2 + 1 =", 1).priority(0)).unwrap();
        // class 0 jumps the earlier class-2 submission for the one slot
        let r1 = s.step().unwrap();
        assert_eq!(r1.admitted, vec![hi]);
        let r2 = s.step().unwrap();
        assert_eq!(r2.admitted, vec![low]);
        s.run_until_idle().unwrap();
        assert_eq!(s.take_finished().len(), 2);
    }

    #[test]
    fn equal_priorities_admit_exactly_fifo() {
        let engine = tiny_engine(10);
        // multiple classes enabled, but every request lands in class 1:
        // the tiebreak must be submission order
        let o = SchedOptions { max_batch: 1, priority_classes: 3, ..SchedOptions::default() };
        let mut s = Scheduler::new(&engine, &o).unwrap();
        let mut ids = Vec::new();
        for i in 0..4 {
            ids.push(s.submit(RequestSpec::new(format!("{i} + 1 ="), 1).priority(1)).unwrap());
        }
        let mut admitted = Vec::new();
        while !s.is_idle() {
            admitted.extend(s.step().unwrap().admitted);
        }
        assert_eq!(admitted, ids, "equal-priority admission must stay FIFO");
    }

    #[test]
    fn aging_promotes_a_starved_low_priority_request() {
        let engine = tiny_engine(11);
        let o = SchedOptions {
            max_batch: 1,
            priority_classes: 2,
            aging_steps: 2,
            ..SchedOptions::default()
        };
        let mut s = Scheduler::new(&engine, &o).unwrap();
        let low = s.submit(RequestSpec::new("1 + 1 =", 1).priority(1)).unwrap();
        // a steady influx of fresh class-0 work would starve the class-1
        // request forever under pure priority order; aging promotes it
        // one class after aging_steps steps, and the FIFO tiebreak (it
        // queued first) then wins it the slot
        let hi0 = s.submit(RequestSpec::new("7 + 2 =", 1)).unwrap();
        let r1 = s.step().unwrap();
        assert_eq!(r1.admitted, vec![hi0], "fresh class 0 wins before aging");
        let hi1 = s.submit(RequestSpec::new("8 + 2 =", 1)).unwrap();
        let r2 = s.step().unwrap();
        assert_eq!(r2.admitted, vec![low], "after aging_steps the starved request is promoted");
        let r3 = s.step().unwrap();
        assert_eq!(r3.admitted, vec![hi1]);
        s.run_until_idle().unwrap();
        assert_eq!(s.take_finished().len(), 3);
    }

    #[test]
    fn blown_deadline_sheds_at_submit_without_touching_the_engine() {
        let engine = tiny_engine(12);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        // deadline_ms(0) is already blown at arrival by construction
        let id = s.submit(RequestSpec::new("1 + 1 =", 4).deadline_ms(0)).unwrap();
        assert!(s.is_idle(), "a shed request must never queue");
        let done = s.take_finished();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id);
        assert_eq!(done[0].reason, FinishReason::Shed);
        assert_eq!(done[0].tokens, 0);
        assert_eq!(s.decode_stats(), DecodeStats::default(), "no forward ran");
        assert_eq!(s.sched_stats().shed_at_submit, 1);
        assert_eq!(s.sched_stats().shed_in_queue, 0);
    }

    #[test]
    fn queued_request_past_deadline_is_shed_before_prefill() {
        let engine = tiny_engine(13);
        let o = SchedOptions { max_batch: 1, ..SchedOptions::default() };
        let mut s = Scheduler::new(&engine, &o).unwrap();
        let blocker = s.submit(RequestSpec::new("1 + 1 =", 4)).unwrap();
        let victim = s.submit(RequestSpec::new("2 + 2 =", 4).deadline_ms(1)).unwrap();
        let r1 = s.step().unwrap();
        assert_eq!(r1.admitted, vec![blocker], "the one slot goes to the blocker");
        std::thread::sleep(Duration::from_millis(5));
        let forwards_before = s.decode_stats().forwards;
        let r2 = s.step().unwrap();
        assert_eq!(r2.shed, vec![victim], "the blown deadline sheds at step start");
        s.run_until_idle().unwrap();
        let done = s.take_finished();
        let v = done.iter().find(|r| r.id == victim).unwrap();
        assert_eq!(v.reason, FinishReason::Shed);
        assert_eq!(v.tokens, 0, "shed requests never prefill");
        assert_eq!(s.sched_stats().shed_in_queue, 1);
        assert!(
            s.decode_stats().forwards > forwards_before,
            "the blocker kept decoding — shedding only touched the queue"
        );
    }

    #[test]
    fn cancel_vs_shed_race_resolves_to_whichever_ran_first() {
        let engine = tiny_engine(14);
        let o = SchedOptions { max_batch: 1, ..SchedOptions::default() };
        let mut s = Scheduler::new(&engine, &o).unwrap();
        let blocker = s.submit(RequestSpec::new("1 + 1 =", 2)).unwrap();
        let victim = s.submit(RequestSpec::new("2 + 2 =", 4).deadline_ms(1)).unwrap();
        s.step().unwrap();
        std::thread::sleep(Duration::from_millis(5));
        // cancel lands first: the blown deadline never gets a say
        assert!(s.cancel(victim));
        let r = s.step().unwrap();
        assert!(r.shed.is_empty());
        assert_eq!(s.sched_stats().shed_in_queue, 0);
        // shed lands first: the late cancel finds nothing to cancel
        let victim2 = s.submit(RequestSpec::new("3 + 3 =", 4).deadline_ms(1)).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let r = s.step().unwrap();
        assert_eq!(r.shed, vec![victim2]);
        assert!(!s.cancel(victim2), "shed already completed the request");
        s.run_until_idle().unwrap();
        let done = s.take_finished();
        assert_eq!(done.iter().find(|r| r.id == victim).unwrap().reason, FinishReason::Cancelled);
        assert_eq!(done.iter().find(|r| r.id == victim2).unwrap().reason, FinishReason::Shed);
        assert!(done.iter().any(|r| r.id == blocker));
    }

    #[test]
    fn retry_after_hint_is_clamped_and_scales_with_depth() {
        let engine = tiny_engine(16);
        let mut s = Scheduler::new(&engine, &opts(1)).unwrap();
        // cold and empty: still answers the 1-second floor
        assert_eq!(s.retry_after_hint_secs(), 1);
        for i in 0..3 {
            s.submit(RequestSpec::new(format!("{i} + 1 ="), 2)).unwrap();
        }
        let hint = s.retry_after_hint_secs();
        assert!((1..=30).contains(&hint), "hint {hint} escaped its clamp");
    }
}
