//! Continuous-batching request scheduler — the native engine as a
//! request-level server instead of a batch evaluator.
//!
//! PR 2 made single-batch decoding cheap (KV-cached, O(T) per
//! generation); this module makes the *batch itself* dynamic, which is
//! where the paper's serving-efficiency claim meets realistic load:
//! requests arrive over time, generations finish at different lengths,
//! and a fixed batch would leave decode slots idling behind the longest
//! request while new arrivals wait. The scheduler closes that gap with
//! iteration-level scheduling:
//!
//! * [`scheduler::Scheduler`] — a wait queue (FIFO by default; priority
//!   classes with starvation-bounded aging when configured, plus
//!   TTFT-deadline load shedding — every submit is one
//!   [`request::RequestSpec`]) plus a pool of decode
//!   slots (one [`crate::engine::KvCache`] row each). With the **paged**
//!   cache (the default) the KV budget buys a shared block pool: all
//!   `max_batch` slots exist and admission reserves each request's
//!   prompt + decode horizon in blocks, denying (never evicting) when
//!   the pool can't cover a candidate — so mixed-length workloads carry
//!   strictly more concurrent requests at the same budget than the
//!   contiguous reference layout, whose slot count is capped at
//!   full-context rows (the same KV arithmetic the one-shot backend caps
//!   with, kept behind `kv_paged = false`). Each
//!   [`scheduler::Scheduler::step`] admits waiting requests into free
//!   slots, prefills them in one padded batch, single-token-steps
//!   everything already in flight, and releases finished or cancelled
//!   requests immediately — their rows (and blocks) go to the next
//!   waiting request mid-generation
//!   ([`crate::engine::KvCache::reset_row`]).
//! * [`request::RequestState`] — per-request lifecycle (Queued →
//!   Prefilling → Decoding → Finished/Cancelled) with
//!   [`request::TokenSink`] streaming: tokens are observable as they are
//!   picked, not after the batch drains.
//! * [`worker::SchedWorker`] — the scheduler on a dedicated worker
//!   thread behind an MPSC command channel: submits return immediately
//!   with a request id, tokens stream per request over channels, and
//!   shutdown drains in-flight rows while rejecting new work. This is
//!   the async front end `lota serve --listen` builds its HTTP/SSE
//!   transport on ([`crate::serve::listen`]).
//! * [`loadgen`] — deterministic open-loop Poisson workloads (arrival
//!   times, prompt mix, output-length mix) shared by the
//!   `bench_serve_load` bench and the integration tests.
//!
//! The scheduler runs the *same* prefill/step kernels as the one-shot
//! [`crate::engine::greedy_decode`] ([`crate::engine::decode`]'s shared
//! primitives), and cache rows never interact, so scheduled greedy
//! output is **bit-identical** to the one-shot cached decode —
//! `tests/engine_parity.rs` pins it. One-shot serving through
//! [`crate::serve::ScheduledBackend`] is literally this scheduler with
//! every request submitted at t = 0.

pub mod loadgen;
pub mod request;
pub mod scheduler;
pub mod worker;

pub use loadgen::{generate_load, spread_adapters, stripe_priorities, LoadRequest, LoadSpec};
pub use request::{
    ChannelSink, FinishReason, RequestSpec, RequestState, SchedResponse, StreamEvent, TokenSink,
};
pub use scheduler::{SchedOptions, Scheduler, StepReport};
pub use worker::{SchedWorker, SubmitError, WorkerClient, WorkerCommand, WorkerConfig, WorkerReport};
