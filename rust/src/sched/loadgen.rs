//! Synthetic open-loop load: Poisson arrivals over the task corpora.
//!
//! "Open loop" means arrival times are fixed up front and do not react
//! to how fast the server drains — the workload a public endpoint sees,
//! and the one that separates continuous batching from static batches: a
//! static server makes late arrivals wait for the whole in-flight batch,
//! an iteration-level scheduler admits them at the next step. Both the
//! `bench_serve_load` bench target and the scheduler integration tests
//! consume this generator, so the comparison and the regression tests
//! run the exact same workload shape.
//!
//! Fully deterministic per seed: inter-arrival gaps are
//! inverse-CDF-sampled exponentials, prompts come from the named task
//! generator, and each request's token budget is drawn from the
//! configured output-length mix.

use anyhow::{bail, Result};

use crate::data::{task_by_name, Split};
use crate::tensor::Rng;

/// Workload description.
#[derive(Clone, Debug)]
pub struct LoadSpec {
    pub n_requests: usize,
    /// Poisson arrival rate λ, requests per second
    pub rate_per_sec: f64,
    pub seed: u64,
    /// prompt source (a `data::tasks` name: "arith", "sql", …)
    pub task: String,
    /// per-request `max_new` is drawn uniformly from this mix — mixed
    /// output lengths are what make slot reuse matter (short requests
    /// finish early; their slots should not idle behind long ones)
    pub max_new_mix: Vec<usize>,
}

impl Default for LoadSpec {
    fn default() -> LoadSpec {
        LoadSpec {
            n_requests: 32,
            rate_per_sec: 16.0,
            seed: 7,
            task: "arith".into(),
            max_new_mix: vec![4, 8, 24],
        }
    }
}

/// One request of the workload, arrival-stamped relative to t = 0.
#[derive(Clone, Debug)]
pub struct LoadRequest {
    pub arrival_secs: f64,
    pub prompt: String,
    pub max_new: usize,
    /// adapter id to serve with (0 = bare base). [`generate_load`] always
    /// emits 0 — the golden replay test pins its exact RNG draw order, so
    /// multi-adapter workloads re-tag requests *after* generation (see
    /// [`spread_adapters`]) instead of drawing inside the generator.
    pub adapter: u32,
    /// priority class (0 = most urgent). [`generate_load`] always emits 0
    /// for the same draw-free reason as `adapter`; overload workloads
    /// re-tag after generation (see [`stripe_priorities`]).
    pub priority: u8,
    /// per-request TTFT deadline in milliseconds; None (always what
    /// [`generate_load`] emits) means no deadline.
    pub deadline_ms: Option<u64>,
}

/// Re-tag a generated workload across `n_adapters` registered adapters,
/// round-robin in arrival order (request i gets id `i % n_adapters + 1`).
/// With `n_adapters == 0` every request keeps the bare base. Deterministic
/// and draw-free by construction, so workload shape is untouched.
pub fn spread_adapters(reqs: &mut [LoadRequest], n_adapters: usize) {
    if n_adapters == 0 {
        return;
    }
    for (i, r) in reqs.iter_mut().enumerate() {
        r.adapter = (i % n_adapters) as u32 + 1;
    }
}

/// Re-tag a generated workload across `n_classes` priority classes,
/// round-robin in arrival order (request i gets class `i % n_classes`).
/// With `n_classes` 0 or 1 every request keeps class 0. Deterministic and
/// draw-free, exactly like [`spread_adapters`], so the golden-replayed
/// workload shape is untouched — the overload bench arm uses this to mix
/// urgent and background traffic over one pinned arrival sequence.
pub fn stripe_priorities(reqs: &mut [LoadRequest], n_classes: usize) {
    if n_classes <= 1 {
        return;
    }
    for (i, r) in reqs.iter_mut().enumerate() {
        r.priority = (i % n_classes.min(256)) as u8;
    }
}

/// Generate the workload: `n_requests` arrivals with Exp(λ) gaps, sorted
/// by arrival time (cumulative sums of non-negative gaps are sorted by
/// construction).
pub fn generate_load(spec: &LoadSpec) -> Result<Vec<LoadRequest>> {
    if spec.rate_per_sec <= 0.0 || !spec.rate_per_sec.is_finite() {
        bail!("arrival rate must be a positive, finite req/s (got {})", spec.rate_per_sec);
    }
    if spec.max_new_mix.is_empty() {
        bail!("output-length mix must name at least one max_new");
    }
    let task = task_by_name(&spec.task)?;
    let mut rng = Rng::new(spec.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(spec.n_requests);
    for _ in 0..spec.n_requests {
        // inverse-CDF exponential: −ln(1−u)/λ with u ∈ [0, 1)
        let u = (rng.uniform() as f64).clamp(0.0, 1.0 - 1e-9);
        t += -(1.0 - u).ln() / spec.rate_per_sec;
        out.push(LoadRequest {
            arrival_secs: t,
            prompt: task.sample(&mut rng, Split::Test).prompt,
            max_new: *rng.choose(&spec.max_new_mix),
            adapter: 0,
            priority: 0,
            deadline_ms: None,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let spec = LoadSpec { n_requests: 16, ..LoadSpec::default() };
        let a = generate_load(&spec).unwrap();
        let b = generate_load(&spec).unwrap();
        assert_eq!(a.len(), 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_secs, y.arrival_secs);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new, y.max_new);
        }
        let c = generate_load(&LoadSpec { seed: 8, ..spec }).unwrap();
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.prompt != y.prompt
                || x.arrival_secs != y.arrival_secs),
            "different seeds produced identical workloads"
        );
    }

    #[test]
    fn arrivals_are_sorted_and_rate_scales() {
        let slow_spec =
            LoadSpec { n_requests: 64, rate_per_sec: 2.0, ..LoadSpec::default() };
        let slow = generate_load(&slow_spec).unwrap();
        for w in slow.windows(2) {
            assert!(w[0].arrival_secs <= w[1].arrival_secs);
        }
        let fast_spec =
            LoadSpec { n_requests: 64, rate_per_sec: 200.0, ..LoadSpec::default() };
        let fast = generate_load(&fast_spec).unwrap();
        // 100× the rate compresses the horizon by roughly 100× — allow
        // wide slack, the property under test is direction not precision
        let (t_slow, t_fast) =
            (slow.last().unwrap().arrival_secs, fast.last().unwrap().arrival_secs);
        assert!(t_fast < t_slow / 10.0, "rate had no effect: {t_slow} vs {t_fast}");
        // every max_new comes from the mix
        let mix = LoadSpec::default().max_new_mix;
        assert!(fast.iter().all(|r| mix.contains(&r.max_new)));
    }

    #[test]
    fn golden_replay_pins_the_sampling_order() {
        // Load-bench comparisons across PRs are only honest if a fixed
        // seed keeps producing the *exact* workload. This pin replays the
        // documented sampling sequence by hand — one uniform for the
        // arrival gap, then the prompt draw, then the max_new choice, per
        // request — so any reordering or reformulation inside
        // generate_load (extra RNG draw, changed gap formula, swapped
        // prompt/length order) fails here even though generate_load would
        // still be self-consistent.
        let spec = LoadSpec {
            n_requests: 12,
            rate_per_sec: 8.0,
            seed: 42,
            task: "arith".into(),
            max_new_mix: vec![3, 9, 27],
        };
        let got = generate_load(&spec).unwrap();
        assert_eq!(got.len(), 12);
        let task = task_by_name("arith").unwrap();
        let mut rng = Rng::new(42);
        let mut t = 0.0f64;
        for (i, req) in got.iter().enumerate() {
            let u = (rng.uniform() as f64).clamp(0.0, 1.0 - 1e-9);
            t += -(1.0 - u).ln() / spec.rate_per_sec;
            let prompt = task.sample(&mut rng, Split::Test).prompt;
            let max_new = *rng.choose(&spec.max_new_mix);
            assert_eq!(req.arrival_secs, t, "request {i}: arrival time drifted");
            assert_eq!(req.prompt, prompt, "request {i}: prompt sequence drifted");
            assert_eq!(req.max_new, max_new, "request {i}: length sequence drifted");
        }
    }

    #[test]
    fn spread_adapters_round_robins_without_touching_the_workload() {
        let spec = LoadSpec { n_requests: 7, ..LoadSpec::default() };
        let mut reqs = generate_load(&spec).unwrap();
        assert!(reqs.iter().all(|r| r.adapter == 0), "the generator never tags");
        let before: Vec<(f64, String, usize)> = reqs
            .iter()
            .map(|r| (r.arrival_secs, r.prompt.clone(), r.max_new))
            .collect();
        spread_adapters(&mut reqs, 3);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.adapter, (i % 3) as u32 + 1);
        }
        for (r, b) in reqs.iter().zip(&before) {
            assert_eq!((r.arrival_secs, r.prompt.clone(), r.max_new), *b);
        }
        // zero adapters is the identity, not a panic
        spread_adapters(&mut reqs, 0);
        assert_eq!(reqs[0].adapter, 1);
    }

    #[test]
    fn stripe_priorities_round_robins_without_touching_the_workload() {
        let spec = LoadSpec { n_requests: 7, ..LoadSpec::default() };
        let mut reqs = generate_load(&spec).unwrap();
        assert!(reqs.iter().all(|r| r.priority == 0), "the generator never tags");
        assert!(reqs.iter().all(|r| r.deadline_ms.is_none()));
        let before: Vec<(f64, String, usize)> = reqs
            .iter()
            .map(|r| (r.arrival_secs, r.prompt.clone(), r.max_new))
            .collect();
        stripe_priorities(&mut reqs, 3);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.priority, (i % 3) as u8);
        }
        for (r, b) in reqs.iter().zip(&before) {
            assert_eq!((r.arrival_secs, r.prompt.clone(), r.max_new), *b);
        }
        // one class (or zero) is the identity, not a panic
        stripe_priorities(&mut reqs, 1);
        assert_eq!(reqs[1].priority, 1);
        stripe_priorities(&mut reqs, 0);
        assert_eq!(reqs[2].priority, 2);
    }

    #[test]
    fn invalid_specs_are_rejected() {
        let d = LoadSpec::default;
        assert!(generate_load(&LoadSpec { rate_per_sec: 0.0, ..d() }).is_err());
        assert!(generate_load(&LoadSpec { rate_per_sec: -1.0, ..d() }).is_err());
        assert!(generate_load(&LoadSpec { max_new_mix: vec![], ..d() }).is_err());
        assert!(generate_load(&LoadSpec { task: "nope".into(), ..d() }).is_err());
        // zero requests is a valid empty workload
        let empty = generate_load(&LoadSpec { n_requests: 0, ..d() }).unwrap();
        assert!(empty.is_empty());
    }
}
