//! The scheduler's worker thread: an owned engine + [`Scheduler`] driven
//! by an MPSC command channel, so submission is a non-blocking message
//! send from any thread instead of a synchronous call into `step()`.
//!
//! This is the async serving front half the paper's deployment story
//! needs: the merged low-bit model decodes on one dedicated thread while
//! any number of producer threads (HTTP connections, benches, tests)
//! submit, cancel, and stream tokens through channels.
//!
//! Shape:
//!
//! * [`SchedWorker::spawn`] moves an [`Engine`] onto a new thread, builds
//!   the [`Scheduler`] there (construction errors surface synchronously
//!   through a ready-channel), and returns a handle whose
//!   [`WorkerClient`]s are cheap, cloneable, `Send` submit/cancel ports.
//! * Every submit is one [`RequestSpec`], stamped with its channel-entry
//!   `Instant` ([`RequestSpec::enqueued_at`]); the scheduler stamps
//!   arrival with the **same** `Instant::now()` that closes the
//!   cross-thread handoff — one clock, no gap, and the handoff cost
//!   lands in `SchedStats::handoff_ms` isolated from compute.
//! * Overload control: with a bounded submit queue
//!   ([`SchedOptions::submit_queue_cap`] > 0) the worker rejects a
//!   submit *before* it reaches the scheduler whenever the wait queue is
//!   at cap, replying [`SubmitError::QueueFull`] with a back-off hint —
//!   the HTTP front end turns that into `503` + `Retry-After`. Rejections
//!   are counted in `SchedStats::queue_rejected` so transport responses
//!   and scheduler stats reconcile exactly.
//! * Per-request streaming: a submit may attach an `mpsc::Sender`; the
//!   worker routes that request's [`StreamEvent`]s (every token, then
//!   the final [`SchedResponse`]) to it. The stream is registered under
//!   [`Scheduler::next_request_id`] *before* the submit runs, so even a
//!   zero-`max_new` request — which finishes inside the submit call —
//!   still sees its finish event.
//! * Graceful shutdown: [`WorkerCommand::Shutdown`] (or every client
//!   hanging up) flips the worker into draining — new submits are
//!   rejected with an error reply, cancels still work, and the step loop
//!   runs until every in-flight row has finished before the thread
//!   returns its [`WorkerReport`].
//!
//! Because the worker only ever calls the same `submit`/`cancel`/
//! `step` methods a synchronous driver would, scheduled output through
//! the channel is **bitwise identical** to the in-process step loop —
//! `tests/sched_worker.rs` pins it per request against
//! [`crate::engine::greedy_decode`]-parity workloads.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{DecodeStats, Engine};
use crate::serve::SchedStats;

use super::request::{RequestSpec, SchedResponse, StreamEvent, TokenSink};
use super::scheduler::{SchedOptions, Scheduler};

/// Why a submit was refused, as a typed value the transport can route
/// on: the two 503-worthy causes (draining vs. queue-full) need distinct
/// wire responses, and string-matching error text is how that used to be
/// told apart. Crosses the reply channel as-is and rides
/// [`anyhow::Error`] out of [`WorkerClient::submit`], so front ends
/// `downcast_ref::<SubmitError>()` instead of grepping messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// the worker is shutting down — retrying this server is pointless
    Draining,
    /// the bounded submit queue is at cap — retry after the hint
    QueueFull {
        /// the configured [`SchedOptions::submit_queue_cap`]
        cap: usize,
        /// scheduler's drain estimate, the HTTP `Retry-After` value
        retry_after_secs: u64,
    },
    /// the scheduler refused the spec itself (framing, unknown adapter,
    /// out-of-range priority, over-pool horizon) — not retriable as-is
    Rejected(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Draining => write!(f, "worker is shutting down"),
            SubmitError::QueueFull { cap, retry_after_secs } => write!(
                f,
                "submit queue is full (cap {cap}): retry after ~{retry_after_secs}s"
            ),
            SubmitError::Rejected(msg) => write!(f, "submit rejected: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Observability outputs the worker writes at drain time. Tracer and
/// profiler live on the worker thread (the recording tracer is not
/// `Send`), so the files are written there too, right before the thread
/// returns.
#[derive(Clone, Debug, Default)]
pub struct WorkerConfig {
    /// Chrome-trace JSON of the whole worker run (spans include the
    /// per-request cross-thread "handoff" intervals)
    pub trace_out: Option<PathBuf>,
    /// engine hot-path profile registry snapshot (`.json` or Prometheus
    /// text by extension)
    pub profile_out: Option<PathBuf>,
}

/// What producer threads send the worker. Most callers use the
/// [`WorkerClient`] wrappers instead of building these by hand; the raw
/// enum is public so transports can own their reply plumbing.
pub enum WorkerCommand {
    Submit {
        /// the whole request — prompt, budget, adapter, priority class,
        /// TTFT deadline, and the channel-entry stamp
        /// ([`RequestSpec::enqueued_at`], the handoff clock start; the
        /// client fills it at command build if the caller didn't)
        spec: RequestSpec,
        /// per-request stream; every token of this request and its final
        /// response are sent here (send errors ignored: a dead listener
        /// never stalls the batch)
        stream: Option<Sender<StreamEvent>>,
        /// the assigned request id, or the typed refusal
        reply: Sender<std::result::Result<u64, SubmitError>>,
    },
    Cancel {
        id: u64,
        /// same contract as [`Scheduler::cancel`]: false for unknown or
        /// already-finished ids
        reply: Sender<bool>,
    },
    /// Stop admitting, drain in-flight rows, then exit the thread.
    Shutdown,
}

/// Everything the worker measured, returned when the thread drains.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// every completed (or cancelled) request, in completion order
    pub responses: Vec<SchedResponse>,
    /// request- and step-level scheduler measurements (including
    /// `handoff_ms`, the isolated command-channel overhead)
    pub stats: SchedStats,
    /// aggregate decode-work accounting
    pub decode: DecodeStats,
}

/// Routes per-request stream events to their registered channels. Shared
/// (within the worker thread) between the scheduler's sink slot and the
/// command loop, which registers senders before each submit.
#[derive(Clone, Default)]
struct StreamRouter {
    streams: Rc<std::cell::RefCell<HashMap<u64, Sender<StreamEvent>>>>,
}

impl StreamRouter {
    fn register(&self, id: u64, tx: Sender<StreamEvent>) {
        self.streams.borrow_mut().insert(id, tx);
    }

    fn unregister(&self, id: u64) {
        self.streams.borrow_mut().remove(&id);
    }
}

impl TokenSink for StreamRouter {
    fn on_token(&mut self, id: u64, token: u32) {
        if let Some(tx) = self.streams.borrow().get(&id) {
            let _ = tx.send(StreamEvent::Token { id, token });
        }
    }

    fn on_finish(&mut self, resp: &SchedResponse) {
        // the finish event closes the stream: remove-then-send keeps the
        // router from holding dead senders for the life of the server
        if let Some(tx) = self.streams.borrow_mut().remove(&resp.id) {
            let _ = tx.send(StreamEvent::Finish(resp.clone()));
        }
    }
}

/// A cheap, cloneable, `Send` port for submitting work to a running
/// [`SchedWorker`]. Every connection/producer thread gets its own clone;
/// dropping them all (plus the owning worker handle) drains the worker.
#[derive(Clone)]
pub struct WorkerClient {
    tx: Sender<WorkerCommand>,
}

impl WorkerClient {
    /// Submit one [`RequestSpec`] and wait for the id assignment (the
    /// request itself runs asynchronously; this round-trip only covers
    /// the handoff). The spec's `enqueued_at` is stamped here, at channel
    /// entry, unless the caller already stamped an earlier instant.
    /// Refusals — draining, bounded queue at cap, or a spec the
    /// scheduler rejects — come back as a [`SubmitError`] inside the
    /// `anyhow::Error`, so transports can `downcast_ref` and route.
    pub fn submit(&self, spec: RequestSpec) -> Result<u64> {
        self.submit_cmd(spec, None)
    }

    /// [`WorkerClient::submit`] with a per-request stream: the returned
    /// receiver yields one [`StreamEvent::Token`] per generated token and
    /// ends with the [`StreamEvent::Finish`] response (already delivered
    /// for requests that complete inside the submit itself — `max_new =
    /// 0`, or a deadline blown on arrival).
    pub fn submit_streaming(&self, spec: RequestSpec) -> Result<(u64, Receiver<StreamEvent>)> {
        let (stream_tx, stream_rx) = mpsc::channel();
        let id = self.submit_cmd(spec, Some(stream_tx))?;
        Ok((id, stream_rx))
    }

    fn submit_cmd(&self, mut spec: RequestSpec, stream: Option<Sender<StreamEvent>>) -> Result<u64> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if spec.enqueued_at.is_none() {
            spec.enqueued_at = Some(Instant::now());
        }
        let cmd = WorkerCommand::Submit { spec, stream, reply: reply_tx };
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("scheduler worker is gone (already shut down)"))?;
        let assigned = reply_rx
            .recv()
            .map_err(|_| anyhow!("scheduler worker dropped the submit reply"))?;
        assigned.map_err(anyhow::Error::new)
    }

    /// Cancel request `id` (queued or in-flight). False for unknown /
    /// already-finished ids — and, unlike submit, still answered while
    /// the worker drains, so shutdown can be hurried along.
    pub fn cancel(&self, id: u64) -> Result<bool> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(WorkerCommand::Cancel { id, reply: reply_tx })
            .map_err(|_| anyhow!("scheduler worker is gone (already shut down)"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("scheduler worker dropped the cancel reply"))
    }

    /// Ask the worker to drain and exit. Fire-and-forget; join through
    /// [`SchedWorker::shutdown`] for the final report.
    pub fn request_shutdown(&self) {
        let _ = self.tx.send(WorkerCommand::Shutdown);
    }
}

/// Handle to the scheduler worker thread. Dropping it without
/// [`SchedWorker::shutdown`] still drains cleanly (the channel disconnect
/// is a shutdown signal), discarding the report.
pub struct SchedWorker {
    tx: Sender<WorkerCommand>,
    handle: Option<thread::JoinHandle<Result<WorkerReport>>>,
}

impl SchedWorker {
    /// Move `engine` onto a dedicated worker thread and start the command
    /// loop. Scheduler construction runs on the worker (it borrows the
    /// engine the thread owns); its errors are relayed back and returned
    /// here, so a bad config fails the spawn, not the first submit.
    pub fn spawn(engine: Engine, opts: SchedOptions, cfg: WorkerConfig) -> Result<SchedWorker> {
        let (tx, rx) = mpsc::channel::<WorkerCommand>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let handle = thread::Builder::new()
            .name("lota-sched-worker".to_string())
            .spawn(move || worker_main(engine, opts, cfg, rx, ready_tx))
            .context("spawning the scheduler worker thread")?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(SchedWorker { tx, handle: Some(handle) }),
            Ok(Err(msg)) => {
                let _ = handle.join();
                bail!("scheduler worker failed to start: {msg}");
            }
            Err(_) => {
                let _ = handle.join();
                bail!("scheduler worker died before signalling readiness");
            }
        }
    }

    /// A new submit/cancel port. Clones are independent and `Send` —
    /// hand one to every connection thread.
    pub fn client(&self) -> WorkerClient {
        WorkerClient { tx: self.tx.clone() }
    }

    /// Drain in-flight work, stop the thread, and return everything it
    /// measured. Submits racing this call get error replies; cancels are
    /// still honored during the drain.
    pub fn shutdown(mut self) -> Result<WorkerReport> {
        let _ = self.tx.send(WorkerCommand::Shutdown);
        let handle = self.handle.take().expect("shutdown consumes the only handle");
        match handle.join() {
            Ok(report) => report,
            Err(_) => bail!("scheduler worker thread panicked"),
        }
    }
}

impl Drop for SchedWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(WorkerCommand::Shutdown);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// The worker thread body: build scheduler (+ tracer/profiler, which are
/// thread-local by construction), then loop commands and steps until
/// shutdown + drain.
fn worker_main(
    engine: Engine,
    opts: SchedOptions,
    cfg: WorkerConfig,
    rx: Receiver<WorkerCommand>,
    ready_tx: Sender<std::result::Result<(), String>>,
) -> Result<WorkerReport> {
    // nested fn (not a closure) so the scheduler's borrow lifetime stays
    // concrete instead of higher-ranked
    fn handle_cmd(
        cmd: WorkerCommand,
        sched: &mut Scheduler<'_>,
        router: &StreamRouter,
        draining: &mut bool,
    ) {
        match cmd {
            WorkerCommand::Submit { spec, stream, reply } => {
                if *draining {
                    let _ = reply.send(Err(SubmitError::Draining));
                    return;
                }
                // bounded-queue admission control runs before the
                // scheduler ever sees the spec: at cap, the request is
                // rejected with a drain-time hint and counted, so the
                // transport's 503s reconcile with SchedStats exactly
                let cap = sched.submit_queue_cap();
                if cap > 0 && sched.queue_depth() >= cap {
                    sched.note_queue_rejected();
                    let _ = reply.send(Err(SubmitError::QueueFull {
                        cap,
                        retry_after_secs: sched.retry_after_hint_secs(),
                    }));
                    return;
                }
                // register the stream under the id the submit *will*
                // assign — zero-max_new and shed-on-arrival requests
                // finish inside the call
                let predicted = sched.next_request_id();
                if let Some(tx) = stream {
                    router.register(predicted, tx);
                }
                match sched.submit(spec) {
                    Ok(id) => {
                        debug_assert_eq!(id, predicted);
                        let _ = reply.send(Ok(id));
                    }
                    Err(e) => {
                        // failed submits consume no id: drop the
                        // registration so the next request can claim it
                        router.unregister(predicted);
                        let _ = reply.send(Err(SubmitError::Rejected(format!("{e:#}"))));
                    }
                }
            }
            WorkerCommand::Cancel { id, reply } => {
                let _ = reply.send(sched.cancel(id));
            }
            WorkerCommand::Shutdown => *draining = true,
        }
    }

    let router = StreamRouter::default();
    let mut sched = match Scheduler::new(&engine, &opts) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };
    sched = sched.with_sink(Box::new(router.clone()));
    // same observability wiring as the synchronous open-loop driver: one
    // recording buffer, profiler sharing its clock when both are on
    let trace = cfg.trace_out.as_ref().map(|_| crate::obs::RecordingTracer::new());
    if let Some(rec) = &trace {
        sched = sched.with_tracer(Box::new(rec.clone()));
    }
    let profiler = cfg.profile_out.as_ref().map(|_| {
        let p = crate::obs::Profiler::new();
        match &trace {
            Some(rec) => p.with_sink(rec.clone()),
            None => p,
        }
    });
    if let Some(p) = &profiler {
        sched = sched.with_profiler(p.clone());
    }
    let _ = ready_tx.send(Ok(()));

    let mut draining = false;
    let mut responses: Vec<SchedResponse> = Vec::new();
    loop {
        // drain every pending command first: admission this step should
        // see everything already in the channel
        loop {
            match rx.try_recv() {
                Ok(cmd) => handle_cmd(cmd, &mut sched, &router, &mut draining),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    draining = true;
                    break;
                }
            }
        }
        if sched.is_idle() {
            if draining {
                break;
            }
            // nothing to decode: block on the channel instead of spinning
            match rx.recv() {
                Ok(cmd) => handle_cmd(cmd, &mut sched, &router, &mut draining),
                Err(_) => draining = true,
            }
            continue;
        }
        sched.step()?;
        responses.extend(sched.take_finished());
    }
    responses.extend(sched.take_finished());

    // observability files are written here, on the thread that owns the
    // recording buffers — the handle side only ever sees the report
    if let (Some(path), Some(rec)) = (&cfg.trace_out, &trace) {
        crate::obs::write_chrome_trace(path, rec)?;
        log::info!("worker trace written to {}", path.display());
    }
    if let (Some(path), Some(p)) = (&cfg.profile_out, &profiler) {
        let mut reg = crate::obs::MetricsRegistry::new();
        reg.set_info("gemm_kernel", engine.gemm_kernel_label());
        p.fill_registry(&mut reg);
        reg.write(path)?;
        log::info!("worker engine profile written to {}", path.display());
    }

    Ok(WorkerReport {
        responses,
        stats: sched.sched_stats(),
        decode: sched.decode_stats(),
    })
}
