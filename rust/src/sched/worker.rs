//! The scheduler's worker thread: an owned engine + [`Scheduler`] driven
//! by an MPSC command channel, so submission is a non-blocking message
//! send from any thread instead of a synchronous call into `step()`.
//!
//! This is the async serving front half the paper's deployment story
//! needs: the merged low-bit model decodes on one dedicated thread while
//! any number of producer threads (HTTP connections, benches, tests)
//! submit, cancel, and stream tokens through channels.
//!
//! Shape:
//!
//! * [`SchedWorker::spawn`] moves an [`Engine`] onto a new thread, builds
//!   the [`Scheduler`] there (construction errors surface synchronously
//!   through a ready-channel), and returns a handle whose
//!   [`WorkerClient`]s are cheap, cloneable, `Send` submit/cancel ports.
//! * Every submit carries its channel-entry `Instant`; the scheduler
//!   stamps arrival with the **same** `Instant::now()` that closes the
//!   cross-thread handoff ([`Scheduler::submit_handoff`]) — one clock,
//!   no gap, and the handoff cost lands in `SchedStats::handoff_ms`
//!   isolated from compute.
//! * Per-request streaming: a submit may attach an `mpsc::Sender`; the
//!   worker routes that request's [`StreamEvent`]s (every token, then
//!   the final [`SchedResponse`]) to it. The stream is registered under
//!   [`Scheduler::next_request_id`] *before* the submit runs, so even a
//!   zero-`max_new` request — which finishes inside the submit call —
//!   still sees its finish event.
//! * Graceful shutdown: [`WorkerCommand::Shutdown`] (or every client
//!   hanging up) flips the worker into draining — new submits are
//!   rejected with an error reply, cancels still work, and the step loop
//!   runs until every in-flight row has finished before the thread
//!   returns its [`WorkerReport`].
//!
//! Because the worker only ever calls the same `submit_*`/`cancel`/
//! `step` methods a synchronous driver would, scheduled output through
//! the channel is **bitwise identical** to the in-process step loop —
//! `tests/sched_worker.rs` pins it per request against
//! [`crate::engine::greedy_decode`]-parity workloads.

use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::engine::{DecodeStats, Engine};
use crate::serve::SchedStats;

use super::request::{SchedResponse, StreamEvent, TokenSink};
use super::scheduler::{SchedOptions, Scheduler};

/// Observability outputs the worker writes at drain time. Tracer and
/// profiler live on the worker thread (the recording tracer is not
/// `Send`), so the files are written there too, right before the thread
/// returns.
#[derive(Clone, Debug, Default)]
pub struct WorkerConfig {
    /// Chrome-trace JSON of the whole worker run (spans include the
    /// per-request cross-thread "handoff" intervals)
    pub trace_out: Option<PathBuf>,
    /// engine hot-path profile registry snapshot (`.json` or Prometheus
    /// text by extension)
    pub profile_out: Option<PathBuf>,
}

/// What producer threads send the worker. Most callers use the
/// [`WorkerClient`] wrappers instead of building these by hand; the raw
/// enum is public so transports can own their reply plumbing.
pub enum WorkerCommand {
    Submit {
        prompt: String,
        max_new: usize,
        /// adapter id (0 = bare base)
        adapter: u32,
        /// when the command entered the channel — the handoff clock start
        enqueued_at: Instant,
        /// per-request stream; every token of this request and its final
        /// response are sent here (send errors ignored: a dead listener
        /// never stalls the batch)
        stream: Option<Sender<StreamEvent>>,
        /// the assigned request id, or the submission error rendered to a
        /// string (channel replies must be `Send`; `anyhow::Error` is,
        /// but the string keeps the protocol trivially serializable)
        reply: Sender<Result<u64, String>>,
    },
    Cancel {
        id: u64,
        /// same contract as [`Scheduler::cancel`]: false for unknown or
        /// already-finished ids
        reply: Sender<bool>,
    },
    /// Stop admitting, drain in-flight rows, then exit the thread.
    Shutdown,
}

/// Everything the worker measured, returned when the thread drains.
#[derive(Clone, Debug, Default)]
pub struct WorkerReport {
    /// every completed (or cancelled) request, in completion order
    pub responses: Vec<SchedResponse>,
    /// request- and step-level scheduler measurements (including
    /// `handoff_ms`, the isolated command-channel overhead)
    pub stats: SchedStats,
    /// aggregate decode-work accounting
    pub decode: DecodeStats,
}

/// Routes per-request stream events to their registered channels. Shared
/// (within the worker thread) between the scheduler's sink slot and the
/// command loop, which registers senders before each submit.
#[derive(Clone, Default)]
struct StreamRouter {
    streams: Rc<std::cell::RefCell<HashMap<u64, Sender<StreamEvent>>>>,
}

impl StreamRouter {
    fn register(&self, id: u64, tx: Sender<StreamEvent>) {
        self.streams.borrow_mut().insert(id, tx);
    }

    fn unregister(&self, id: u64) {
        self.streams.borrow_mut().remove(&id);
    }
}

impl TokenSink for StreamRouter {
    fn on_token(&mut self, id: u64, token: u32) {
        if let Some(tx) = self.streams.borrow().get(&id) {
            let _ = tx.send(StreamEvent::Token { id, token });
        }
    }

    fn on_finish(&mut self, resp: &SchedResponse) {
        // the finish event closes the stream: remove-then-send keeps the
        // router from holding dead senders for the life of the server
        if let Some(tx) = self.streams.borrow_mut().remove(&resp.id) {
            let _ = tx.send(StreamEvent::Finish(resp.clone()));
        }
    }
}

/// A cheap, cloneable, `Send` port for submitting work to a running
/// [`SchedWorker`]. Every connection/producer thread gets its own clone;
/// dropping them all (plus the owning worker handle) drains the worker.
#[derive(Clone)]
pub struct WorkerClient {
    tx: Sender<WorkerCommand>,
}

impl WorkerClient {
    /// Submit and wait for the id assignment (the request itself runs
    /// asynchronously; this round-trip only covers the handoff).
    pub fn submit(&self, prompt: &str, max_new: usize) -> Result<u64> {
        self.submit_for(prompt, max_new, 0)
    }

    /// [`WorkerClient::submit`] against a named adapter id.
    pub fn submit_for(&self, prompt: &str, max_new: usize, adapter: u32) -> Result<u64> {
        self.submit_inner(prompt, max_new, adapter, None)
    }

    /// Submit with a per-request stream: the returned receiver yields one
    /// [`StreamEvent::Token`] per generated token and ends with the
    /// [`StreamEvent::Finish`] response (already delivered for requests
    /// that complete inside the submit itself, e.g. `max_new = 0`).
    pub fn submit_streaming(
        &self,
        prompt: &str,
        max_new: usize,
        adapter: u32,
    ) -> Result<(u64, Receiver<StreamEvent>)> {
        let (stream_tx, stream_rx) = mpsc::channel();
        let id = self.submit_inner(prompt, max_new, adapter, Some(stream_tx))?;
        Ok((id, stream_rx))
    }

    fn submit_inner(
        &self,
        prompt: &str,
        max_new: usize,
        adapter: u32,
        stream: Option<Sender<StreamEvent>>,
    ) -> Result<u64> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let cmd = WorkerCommand::Submit {
            prompt: prompt.to_string(),
            max_new,
            adapter,
            enqueued_at: Instant::now(),
            stream,
            reply: reply_tx,
        };
        self.tx
            .send(cmd)
            .map_err(|_| anyhow!("scheduler worker is gone (already shut down)"))?;
        let assigned = reply_rx
            .recv()
            .map_err(|_| anyhow!("scheduler worker dropped the submit reply"))?;
        match assigned {
            Ok(id) => Ok(id),
            Err(msg) => bail!("submit rejected: {msg}"),
        }
    }

    /// Cancel request `id` (queued or in-flight). False for unknown /
    /// already-finished ids — and, unlike submit, still answered while
    /// the worker drains, so shutdown can be hurried along.
    pub fn cancel(&self, id: u64) -> Result<bool> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(WorkerCommand::Cancel { id, reply: reply_tx })
            .map_err(|_| anyhow!("scheduler worker is gone (already shut down)"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("scheduler worker dropped the cancel reply"))
    }

    /// Ask the worker to drain and exit. Fire-and-forget; join through
    /// [`SchedWorker::shutdown`] for the final report.
    pub fn request_shutdown(&self) {
        let _ = self.tx.send(WorkerCommand::Shutdown);
    }
}

/// Handle to the scheduler worker thread. Dropping it without
/// [`SchedWorker::shutdown`] still drains cleanly (the channel disconnect
/// is a shutdown signal), discarding the report.
pub struct SchedWorker {
    tx: Sender<WorkerCommand>,
    handle: Option<thread::JoinHandle<Result<WorkerReport>>>,
}

impl SchedWorker {
    /// Move `engine` onto a dedicated worker thread and start the command
    /// loop. Scheduler construction runs on the worker (it borrows the
    /// engine the thread owns); its errors are relayed back and returned
    /// here, so a bad config fails the spawn, not the first submit.
    pub fn spawn(engine: Engine, opts: SchedOptions, cfg: WorkerConfig) -> Result<SchedWorker> {
        let (tx, rx) = mpsc::channel::<WorkerCommand>();
        let (ready_tx, ready_rx) = mpsc::channel::<std::result::Result<(), String>>();
        let handle = thread::Builder::new()
            .name("lota-sched-worker".to_string())
            .spawn(move || worker_main(engine, opts, cfg, rx, ready_tx))
            .context("spawning the scheduler worker thread")?;
        match ready_rx.recv() {
            Ok(Ok(())) => Ok(SchedWorker { tx, handle: Some(handle) }),
            Ok(Err(msg)) => {
                let _ = handle.join();
                bail!("scheduler worker failed to start: {msg}");
            }
            Err(_) => {
                let _ = handle.join();
                bail!("scheduler worker died before signalling readiness");
            }
        }
    }

    /// A new submit/cancel port. Clones are independent and `Send` —
    /// hand one to every connection thread.
    pub fn client(&self) -> WorkerClient {
        WorkerClient { tx: self.tx.clone() }
    }

    /// Drain in-flight work, stop the thread, and return everything it
    /// measured. Submits racing this call get error replies; cancels are
    /// still honored during the drain.
    pub fn shutdown(mut self) -> Result<WorkerReport> {
        let _ = self.tx.send(WorkerCommand::Shutdown);
        let handle = self.handle.take().expect("shutdown consumes the only handle");
        match handle.join() {
            Ok(report) => report,
            Err(_) => bail!("scheduler worker thread panicked"),
        }
    }
}

impl Drop for SchedWorker {
    fn drop(&mut self) {
        let _ = self.tx.send(WorkerCommand::Shutdown);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

/// The worker thread body: build scheduler (+ tracer/profiler, which are
/// thread-local by construction), then loop commands and steps until
/// shutdown + drain.
fn worker_main(
    engine: Engine,
    opts: SchedOptions,
    cfg: WorkerConfig,
    rx: Receiver<WorkerCommand>,
    ready_tx: Sender<std::result::Result<(), String>>,
) -> Result<WorkerReport> {
    // nested fn (not a closure) so the scheduler's borrow lifetime stays
    // concrete instead of higher-ranked
    fn handle_cmd(
        cmd: WorkerCommand,
        sched: &mut Scheduler<'_>,
        router: &StreamRouter,
        draining: &mut bool,
    ) {
        match cmd {
            WorkerCommand::Submit { prompt, max_new, adapter, enqueued_at, stream, reply } => {
                if *draining {
                    let _ = reply.send(Err("worker is shutting down".to_string()));
                    return;
                }
                // register the stream under the id the submit *will*
                // assign — zero-max_new requests finish inside the call
                let predicted = sched.next_request_id();
                if let Some(tx) = stream {
                    router.register(predicted, tx);
                }
                match sched.submit_handoff(&prompt, max_new, adapter, enqueued_at) {
                    Ok(id) => {
                        debug_assert_eq!(id, predicted);
                        let _ = reply.send(Ok(id));
                    }
                    Err(e) => {
                        // failed submits consume no id: drop the
                        // registration so the next request can claim it
                        router.unregister(predicted);
                        let _ = reply.send(Err(format!("{e:#}")));
                    }
                }
            }
            WorkerCommand::Cancel { id, reply } => {
                let _ = reply.send(sched.cancel(id));
            }
            WorkerCommand::Shutdown => *draining = true,
        }
    }

    let router = StreamRouter::default();
    let mut sched = match Scheduler::new(&engine, &opts) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready_tx.send(Err(format!("{e:#}")));
            return Err(e);
        }
    };
    sched = sched.with_sink(Box::new(router.clone()));
    // same observability wiring as the synchronous open-loop driver: one
    // recording buffer, profiler sharing its clock when both are on
    let trace = cfg.trace_out.as_ref().map(|_| crate::obs::RecordingTracer::new());
    if let Some(rec) = &trace {
        sched = sched.with_tracer(Box::new(rec.clone()));
    }
    let profiler = cfg.profile_out.as_ref().map(|_| {
        let p = crate::obs::Profiler::new();
        match &trace {
            Some(rec) => p.with_sink(rec.clone()),
            None => p,
        }
    });
    if let Some(p) = &profiler {
        sched = sched.with_profiler(p.clone());
    }
    let _ = ready_tx.send(Ok(()));

    let mut draining = false;
    let mut responses: Vec<SchedResponse> = Vec::new();
    loop {
        // drain every pending command first: admission this step should
        // see everything already in the channel
        loop {
            match rx.try_recv() {
                Ok(cmd) => handle_cmd(cmd, &mut sched, &router, &mut draining),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    draining = true;
                    break;
                }
            }
        }
        if sched.is_idle() {
            if draining {
                break;
            }
            // nothing to decode: block on the channel instead of spinning
            match rx.recv() {
                Ok(cmd) => handle_cmd(cmd, &mut sched, &router, &mut draining),
                Err(_) => draining = true,
            }
            continue;
        }
        sched.step()?;
        responses.extend(sched.take_finished());
    }
    responses.extend(sched.take_finished());

    // observability files are written here, on the thread that owns the
    // recording buffers — the handle side only ever sees the report
    if let (Some(path), Some(rec)) = (&cfg.trace_out, &trace) {
        crate::obs::write_chrome_trace(path, rec)?;
        log::info!("worker trace written to {}", path.display());
    }
    if let (Some(path), Some(p)) = (&cfg.profile_out, &profiler) {
        let mut reg = crate::obs::MetricsRegistry::new();
        reg.set_info("gemm_kernel", engine.gemm_kernel_label());
        p.fill_registry(&mut reg);
        reg.write(path)?;
        log::info!("worker engine profile written to {}", path.display());
    }

    Ok(WorkerReport {
        responses,
        stats: sched.sched_stats(),
        decode: sched.decode_stats(),
    })
}
