//! Artifact manifest: the data contract written by `python/compile/aot.py`.
//!
//! Every artifact entry records the exact ordered input and output names
//! and shapes; the Rust marshaller follows the manifest rather than any
//! hand-maintained convention, and the loader cross-checks the Rust-side
//! preset shapes at startup.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::Json;

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl IoSpec {
    pub fn n_elems(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    /// path of the HLO text file, relative to the artifacts dir
    pub file: String,
    pub kind: String,
    pub cfg: Option<String>,
    pub method: Option<String>,
    pub n_bits: Option<u32>,
    pub batch: Option<usize>,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no input '{name}'", self.name))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| anyhow!("artifact {}: no output '{name}'", self.name))
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v.get("shape")?.as_usize_vec()?,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?} — run `make artifacts` first")
        })?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = BTreeMap::new();
        for entry in root.get("artifacts")?.as_arr()? {
            let spec = ArtifactSpec {
                name: entry.get("name")?.as_str()?.to_string(),
                file: entry.get("file")?.as_str()?.to_string(),
                kind: entry
                    .opt("kind")
                    .map(|k| k.as_str().unwrap_or("").to_string())
                    .unwrap_or_default(),
                cfg: entry.opt("cfg").and_then(|v| v.as_str().ok().map(String::from)),
                method: entry.opt("method").and_then(|v| v.as_str().ok().map(String::from)),
                n_bits: entry.opt("n_bits").and_then(|v| v.as_usize().ok()).map(|v| v as u32),
                batch: entry.opt("batch").and_then(|v| v.as_usize().ok()),
                inputs: entry
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
                outputs: entry
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<_>>()?,
            };
            artifacts.insert(spec.name.clone(), spec);
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow!(
                "artifact '{name}' not in manifest ({} available) — re-run `make artifacts`?",
                self.artifacts.len()
            )
        })
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }

    /// All artifacts of a given kind (e.g. every "fwd" for a config).
    pub fn of_kind<'a>(&'a self, kind: &'a str) -> impl Iterator<Item = &'a ArtifactSpec> {
        self.artifacts.values().filter(move |a| a.kind == kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp_manifest(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": [
              {"name": "fwd_x", "file": "fwd_x.hlo.txt", "kind": "fwd",
               "cfg": "tiny", "method": "merged", "batch": 8,
               "inputs": [{"name": "a", "shape": [2, 3]}],
               "outputs": [{"name": "y", "shape": [2, 4]}]}
            ]}"#,
        )
        .unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join(format!("lota_manifest_{}", std::process::id()));
        write_tmp_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        let spec = m.get("fwd_x").unwrap();
        assert_eq!(spec.inputs[0].shape, vec![2, 3]);
        assert_eq!(spec.inputs[0].n_elems(), 6);
        assert_eq!(spec.batch, Some(8));
        assert_eq!(spec.input_index("a").unwrap(), 0);
        assert!(spec.input_index("zz").is_err());
        assert_eq!(m.of_kind("fwd").count(), 1);
        assert!(m.get("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
