//! PJRT runtime: loads the AOT-lowered HLO text artifacts and executes
//! them on the CPU client. This is the only place the `xla` crate is
//! touched; everything above it deals in [`Tensor`]s.
//!
//! Pattern follows /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`, with HLO
//! **text** as the interchange format (serialized protos from jax ≥ 0.5
//! carry 64-bit ids that xla_extension 0.5.1 rejects).

pub mod manifest;

pub use manifest::{ArtifactSpec, IoSpec, Manifest};

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

/// A compiled artifact ready to run.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: the PJRT C API guarantees client/executable thread-safety
// (PJRT_Client and PJRT_LoadedExecutable may be used from multiple threads;
// the CPU plugin serializes internally). The `xla` crate just doesn't mark
// its wrappers. All mutation on the Rust side sits behind Mutexes.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

/// Runtime statistics (exposed by `lota stats` and the §Perf benches).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    pub compilations: usize,
    pub compile_secs: f64,
    pub executions: usize,
    pub execute_secs: f64,
}

/// PJRT client + executable cache keyed by artifact name.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    stats: Mutex<RuntimeStats>,
}

// SAFETY: see `Executable` above — PJRT clients are thread-safe by API
// contract; Rust-side caches/stats are Mutex-guarded.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Create a CPU runtime over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        log::info!(
            "PJRT platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.lock().unwrap().clone()
    }

    /// Load + compile an artifact (cached).
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let path_str = path
            .to_str()
            .with_context(|| format!("non-utf8 path {path:?}"))?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.lock().unwrap();
            s.compilations += 1;
            s.compile_secs += dt;
        }
        log::debug!("compiled {name} in {dt:.2}s");
        let e = std::sync::Arc::new(Executable { spec, exe });
        self.cache.lock().unwrap().insert(name.to_string(), e.clone());
        Ok(e)
    }

    /// Execute with inputs in manifest order. Shapes are checked against
    /// the manifest before anything touches PJRT.
    pub fn execute(&self, exe: &Executable, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let spec = &exe.spec;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {}: {} inputs supplied, manifest wants {}",
                spec.name,
                inputs.len(),
                spec.inputs.len()
            );
        }
        for (t, io) in inputs.iter().zip(&spec.inputs) {
            if t.len() != io.n_elems() {
                bail!(
                    "artifact {}: input '{}' has {} elems, manifest wants {:?}",
                    spec.name,
                    io.name,
                    t.len(),
                    io.shape
                );
            }
        }

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(t, io)| {
                let lit = xla::Literal::vec1(t.data());
                if io.shape.len() <= 1 {
                    Ok(lit)
                } else {
                    let dims: Vec<i64> = io.shape.iter().map(|d| *d as i64).collect();
                    lit.reshape(&dims)
                        .with_context(|| format!("reshaping input '{}'", io.name))
                }
            })
            .collect::<Result<_>>()?;

        let t0 = Instant::now();
        let result = exe
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", spec.name))?[0][0]
            .to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        {
            let mut s = self.stats.lock().unwrap();
            s.executions += 1;
            s.execute_secs += dt;
        }

        // aot.py lowers with return_tuple=True: unpack N outputs.
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs returned, manifest wants {}",
                spec.name,
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, io)| {
                let v = lit
                    .to_vec::<f32>()
                    .with_context(|| format!("reading output '{}'", io.name))?;
                if v.len() != io.n_elems() {
                    bail!(
                        "artifact {}: output '{}' has {} elems, manifest wants {:?}",
                        spec.name,
                        io.name,
                        v.len(),
                        io.shape
                    );
                }
                Ok(Tensor::new(&io.shape, v))
            })
            .collect()
    }

    /// Convenience: load-and-run by name.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let exe = self.load(name)?;
        self.execute(&exe, inputs)
    }
}
