//! GPTQ (Frantar et al., 2022): error-compensating post-training
//! quantization using approximate second-order information.
//!
//! For a linear layer `y = xᵀW` with calibration activations `X`, GPTQ
//! quantizes the weights row-by-row (along the input dimension), each time
//! distributing the rounding error onto the not-yet-quantized rows through
//! the inverse Hessian `H⁻¹ = (2XXᵀ + λI)⁻¹`, so the *layer output* error —
//! not the weight error — is minimized. This is the quantizer the paper
//! applies to all models (§4.1) and whose grid LoTA-QAF's ternary
//! adaptation later adjusts in place.
//!
//! Implementation notes:
//! * rows are processed in blocks (`block_size`, default = group size) with
//!   lazily batched trailing updates — the standard GPTQ trick that turns
//!   the O(Din²·Dout) update stream into matmuls;
//! * per-group grids are refreshed from the *error-compensated* weights
//!   when the sweep enters the group;
//! * the damped Cholesky retries with 10× damping when H is numerically
//!   indefinite, exactly like the reference implementation.

use crate::quant::affine::{grid_from_minmax, quantize_to_grid, QuantizedLinear};
use crate::tensor::{linalg, Tensor};

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct GptqConfig {
    pub n_bits: u32,
    pub group_size: usize,
    /// damping fraction λ = damp_frac · mean(diag H)
    pub damp_frac: f32,
    /// lazy-update block width (rows); defaults to the group size
    pub block_size: usize,
}

impl GptqConfig {
    pub fn new(n_bits: u32, group_size: usize) -> Self {
        GptqConfig { n_bits, group_size, damp_frac: 0.01, block_size: group_size }
    }
}

/// Accumulate `H += Xᵀ X` for one calibration batch `x` of shape (N, Din).
/// (The factor 2 of `2XXᵀ` cancels in the algorithm; we keep H symmetric.)
pub fn accumulate_hessian(h: &mut Tensor, x: &Tensor) {
    let g = linalg::matmul_tt(x);
    assert_eq!(h.shape(), g.shape(), "hessian shape mismatch");
    let hd = h.data_mut();
    for (o, v) in hd.iter_mut().zip(g.data()) {
        *o += v;
    }
}

/// Quantize `w` (Din, Dout) with the GPTQ error-compensation sweep.
///
/// `hessian` is the accumulated `XᵀX` (Din, Din) from [`accumulate_hessian`];
/// dead inputs (zero diagonal) are handled by pinning their diagonal, as in
/// the reference code.
pub fn gptq_quantize(w: &Tensor, hessian: &Tensor, cfg: &GptqConfig) -> Result<QuantizedLinear> {
    let (din, dout) = (w.rows(), w.cols());
    if din % cfg.group_size != 0 {
        bail!("group size {} must divide Din {din}", cfg.group_size);
    }
    if hessian.shape() != [din, din] {
        bail!("hessian shape {:?}, want [{din}, {din}]", hessian.shape());
    }
    let grid_max = ((1u32 << cfg.n_bits) - 1) as f32;
    let g_count = din / cfg.group_size;

    // ---- damped inverse Cholesky ----
    let mut h = hessian.clone();
    let mean_diag = (0..din).map(|i| h.at2(i, i)).sum::<f32>() / din as f32;
    let mean_diag = if mean_diag > 0.0 { mean_diag } else { 1.0 };
    for i in 0..din {
        if h.at2(i, i) == 0.0 {
            *h.at2_mut(i, i) = mean_diag; // dead input: quantize plainly
        }
    }
    let mut damp = cfg.damp_frac * mean_diag;
    let u = loop {
        let mut hd = h.clone();
        for i in 0..din {
            *hd.at2_mut(i, i) += damp;
        }
        match linalg::cholesky_inverse_upper(&hd) {
            Some(u) => break u,
            None => {
                damp *= 10.0;
                if damp > 1e6 * mean_diag {
                    bail!("hessian could not be stabilized");
                }
            }
        }
    };

    // ---- blocked error-compensating sweep ----
    let mut wq = w.clone(); // progressively overwritten with compensated weights
    let mut w_int = vec![0.0f32; din * dout];
    let mut scales = vec![0.0f32; g_count * dout];
    let mut zeros = vec![0.0f32; g_count * dout];
    let block = cfg.block_size.max(1);

    let mut b0 = 0;
    while b0 < din {
        let b1 = (b0 + block).min(din);
        let bw = b1 - b0;
        // per-row scaled errors within the block, for the trailing update
        let mut err = vec![0.0f32; bw * dout];

        for i in b0..b1 {
            let gi = i / cfg.group_size;
            if i % cfg.group_size == 0 {
                // refresh this group's grid from the compensated weights
                for j in 0..dout {
                    let mut mn = f32::INFINITY;
                    let mut mx = f32::NEG_INFINITY;
                    for r in i..i + cfg.group_size {
                        let v = wq.at2(r, j);
                        mn = mn.min(v);
                        mx = mx.max(v);
                    }
                    let (s, z) = grid_from_minmax(mn, mx, cfg.n_bits);
                    scales[gi * dout + j] = s;
                    zeros[gi * dout + j] = z;
                }
            }
            let d = u.at2(i, i);
            for j in 0..dout {
                let s = scales[gi * dout + j];
                let z = zeros[gi * dout + j];
                let wv = wq.at2(i, j);
                let q = quantize_to_grid(wv, s, z, grid_max);
                w_int[i * dout + j] = q;
                let e = (wv - (s * q + z)) / d;
                err[(i - b0) * dout + j] = e;
            }
            // propagate error inside the block immediately
            for k in (i + 1)..b1 {
                let uik = u.at2(i, k);
                if uik == 0.0 {
                    continue;
                }
                let erow_start = (i - b0) * dout;
                for j in 0..dout {
                    *wq.at2_mut(k, j) -= uik * err[erow_start + j];
                }
            }
        }

        // lazy batched update of all trailing rows: W[b1.., :] -= U[b0..b1, b1..]ᵀ · Err
        if b1 < din {
            for k in b1..din {
                let wrow = wq.row_mut(k);
                for i in b0..b1 {
                    let uik = u.at2(i, k);
                    if uik == 0.0 {
                        continue;
                    }
                    let erow = &err[(i - b0) * dout..(i - b0 + 1) * dout];
                    for j in 0..dout {
                        wrow[j] -= uik * erow[j];
                    }
                }
            }
        }
        b0 = b1;
    }

    let ql = QuantizedLinear {
        n_bits: cfg.n_bits,
        group_size: cfg.group_size,
        w_int: Tensor::new(&[din, dout], w_int),
        scales: Tensor::new(&[g_count, dout], scales),
        zeros: Tensor::new(&[g_count, dout], zeros),
    };
    ql.validate()?;
    Ok(ql)
}

/// Layer-output mean-squared error `‖X(W − Ŵ)‖² / N·Dout` — the quantity
/// GPTQ minimizes; used by tests and the quantizer ablation bench.
pub fn output_mse(w: &Tensor, ql: &QuantizedLinear, x: &Tensor) -> f32 {
    let diff = ql.dequantize().sub(w);
    let y = linalg::matmul(x, &diff);
    let n = y.len() as f32;
    y.data().iter().map(|v| v * v).sum::<f32>() / n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::rtn_quantize;
    use crate::tensor::Rng;

    fn calib(rng: &mut Rng, n: usize, din: usize) -> Tensor {
        // correlated activations (what makes GPTQ beat RTN)
        let base = Tensor::new(&[n, din], rng.normal_vec(n * din, 1.0));
        let mut data = base.into_data();
        for r in 0..n {
            for i in 1..din {
                data[r * din + i] = 0.7 * data[r * din + i - 1] + 0.3 * data[r * din + i];
            }
        }
        Tensor::new(&[n, din], data)
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let mut rng = Rng::new(42);
        let (din, dout, gs) = (64, 32, 16);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.2));
        let x = calib(&mut rng, 256, din);
        let mut h = Tensor::zeros(&[din, din]);
        accumulate_hessian(&mut h, &x);

        for bits in [2u32, 3, 4] {
            let cfg = GptqConfig::new(bits, gs);
            let gq = gptq_quantize(&w, &h, &cfg).unwrap();
            let rq = rtn_quantize(&w, gs, bits);
            let ge = output_mse(&w, &gq, &x);
            let re = output_mse(&w, &rq, &x);
            assert!(
                ge < re,
                "{bits}-bit: GPTQ {ge} should beat RTN {re} on output MSE"
            );
        }
    }

    #[test]
    fn gptq_respects_grid_invariants() {
        let mut rng = Rng::new(43);
        let (din, dout, gs) = (32, 16, 8);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let x = calib(&mut rng, 64, din);
        let mut h = Tensor::zeros(&[din, din]);
        accumulate_hessian(&mut h, &x);
        let ql = gptq_quantize(&w, &h, &GptqConfig::new(3, gs)).unwrap();
        ql.validate().unwrap();
        assert_eq!(ql.n_groups(), din / gs);
    }

    #[test]
    fn identity_hessian_reduces_to_rtn() {
        // with H = I there is no correlation to exploit: the first row of
        // each group quantizes identically to RTN (later rows absorb error)
        let mut rng = Rng::new(44);
        let (din, dout, gs) = (16, 8, 8);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let mut h = Tensor::zeros(&[din, din]);
        for i in 0..din {
            *h.at2_mut(i, i) = 1.0;
        }
        let cfg = GptqConfig { damp_frac: 1e-6, ..GptqConfig::new(4, gs) };
        let gq = gptq_quantize(&w, &h, &cfg).unwrap();
        let rq = rtn_quantize(&w, gs, 4);
        for j in 0..dout {
            assert_eq!(gq.w_int.at2(0, j), rq.w_int.at2(0, j));
        }
    }

    #[test]
    fn dead_inputs_are_handled() {
        let mut rng = Rng::new(45);
        let (din, dout, gs) = (16, 8, 8);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let mut x = calib(&mut rng, 32, din);
        for r in 0..32 {
            x.row_mut(r)[3] = 0.0; // input 3 never fires
        }
        let mut h = Tensor::zeros(&[din, din]);
        accumulate_hessian(&mut h, &x);
        let ql = gptq_quantize(&w, &h, &GptqConfig::new(4, gs)).unwrap();
        ql.validate().unwrap();
    }

    #[test]
    fn block_size_does_not_change_result() {
        let mut rng = Rng::new(46);
        let (din, dout, gs) = (32, 8, 8);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let x = calib(&mut rng, 128, din);
        let mut h = Tensor::zeros(&[din, din]);
        accumulate_hessian(&mut h, &x);
        let a = gptq_quantize(&w, &h, &GptqConfig { block_size: 8, ..GptqConfig::new(4, gs) })
            .unwrap();
        let b = gptq_quantize(&w, &h, &GptqConfig { block_size: 32, ..GptqConfig::new(4, gs) })
            .unwrap();
        // identical sweep order ⇒ identical grids, up to f32 noise in err
        assert!(a.w_int.allclose(&b.w_int, 0.0, 0.0));
        assert!(a.scales.allclose(&b.scales, 1e-6, 1e-6));
    }
}
