//! Round-to-nearest (RTN) group-wise asymmetric quantization — the paper's
//! Eq. 2 applied directly, used as (a) the ablation baseline for GPTQ and
//! (b) the grid used when re-quantizing a merged LoRA update (the lossy
//! merge the paper criticises). Matches `golden.ref_rtn_quantize` exactly.

use crate::quant::affine::{grid_from_minmax, quantize_to_grid, QuantizedLinear};
use crate::tensor::Tensor;

/// Quantize `w` (Din, Dout) onto a fresh per-(group, column) grid.
pub fn rtn_quantize(w: &Tensor, group_size: usize, n_bits: u32) -> QuantizedLinear {
    let (din, dout) = (w.rows(), w.cols());
    assert_eq!(din % group_size, 0, "group size must divide Din");
    let g = din / group_size;
    let grid_max = ((1u32 << n_bits) - 1) as f32;

    let mut w_int = vec![0.0f32; din * dout];
    let mut scales = vec![0.0f32; g * dout];
    let mut zeros = vec![0.0f32; g * dout];

    for gi in 0..g {
        let r0 = gi * group_size;
        for j in 0..dout {
            let mut mn = f32::INFINITY;
            let mut mx = f32::NEG_INFINITY;
            for i in r0..r0 + group_size {
                let v = w.at2(i, j);
                mn = mn.min(v);
                mx = mx.max(v);
            }
            let (s, z) = grid_from_minmax(mn, mx, n_bits);
            scales[gi * dout + j] = s;
            zeros[gi * dout + j] = z;
            for i in r0..r0 + group_size {
                w_int[i * dout + j] = quantize_to_grid(w.at2(i, j), s, z, grid_max);
            }
        }
    }

    QuantizedLinear {
        n_bits,
        group_size,
        w_int: Tensor::new(&[din, dout], w_int),
        scales: Tensor::new(&[g, dout], scales),
        zeros: Tensor::new(&[g, dout], zeros),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn exact_representable_weights_roundtrip() {
        // weights already on a 4-bit grid quantize losslessly — provided
        // each (group, column) actually spans the grid extremes, so pin
        // codes 0 and 15 into every group's first two rows.
        let mut rng = Rng::new(1);
        let (din, dout, gs) = (16, 8, 8);
        let mut grid = vec![0.0f32; din * dout];
        for gi in 0..din / gs {
            for j in 0..dout {
                for r in 0..gs {
                    let code = match r {
                        0 => 0,
                        1 => 15,
                        _ => rng.below(16),
                    };
                    grid[(gi * gs + r) * dout + j] = code as f32 * 0.1 - 0.5;
                }
            }
        }
        let w = Tensor::new(&[din, dout], grid);
        let ql = rtn_quantize(&w, gs, 4);
        assert!(ql.max_error(&w) < 1e-6, "err {}", ql.max_error(&w));
    }

    #[test]
    fn constant_group_gets_degenerate_grid() {
        let w = Tensor::full(&[8, 4], 0.3);
        let ql = rtn_quantize(&w, 8, 4);
        ql.validate().unwrap();
        assert!(ql.max_error(&w) < 1e-6); // z = 0.3, all codes 0
    }

    #[test]
    fn error_decreases_with_bits_property() {
        // hand-rolled property sweep over random matrices
        let mut rng = Rng::new(7);
        for case in 0..20 {
            let gs = [8usize, 16][case % 2];
            let din = gs * rng.range(1, 5);
            let dout = 8 * rng.range(1, 5);
            let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.2));
            let e4 = rtn_quantize(&w, gs, 4).frob_error(&w);
            let e3 = rtn_quantize(&w, gs, 3).frob_error(&w);
            let e2 = rtn_quantize(&w, gs, 2).frob_error(&w);
            assert!(e4 <= e3 + 1e-6 && e3 <= e2 + 1e-6, "case {case}: {e4} {e3} {e2}");
        }
    }

    #[test]
    fn smaller_groups_reduce_error() {
        let mut rng = Rng::new(8);
        let w = Tensor::new(&[64, 32], rng.normal_vec(64 * 32, 0.2));
        let e_small = rtn_quantize(&w, 8, 3).frob_error(&w);
        let e_big = rtn_quantize(&w, 64, 3).frob_error(&w);
        assert!(e_small < e_big);
    }
}
