//! Group-wise asymmetric quantization substrate: the affine grid (paper
//! Eq. 2), sub-byte bit-packing, the RTN baseline quantizer, and a full
//! GPTQ implementation (Frantar et al., 2022) driven by calibration
//! activations captured from the fp model (`acts_fp_*` artifacts).

pub mod affine;
pub mod gptq;
pub mod pack;
pub mod rtn;

pub use affine::{dequant, QuantizedLinear};
pub use gptq::{accumulate_hessian, gptq_quantize, output_mse, GptqConfig};
pub use pack::{pack_ints, unpack_ints, packed_len_u32};
pub use rtn::rtn_quantize;
