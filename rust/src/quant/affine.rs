//! The asymmetric affine grid (paper Eq. 2):
//! `W_q = s · W_int + z`, `s = (max−min)/(2^N−1)`, `z = min`, per
//! (input-group, output-column), groups along the input dimension.

use crate::tensor::Tensor;

use anyhow::{bail, Result};

/// One quantized linear layer: f32-coded integer grid + per-group affine
/// parameters. This is the exact representation the HLO graphs consume
/// (`q_{slot}_int` / `_s` / `_z` inputs) and what the ternary merge edits.
#[derive(Clone, Debug)]
pub struct QuantizedLinear {
    pub n_bits: u32,
    pub group_size: usize,
    /// (Din, Dout) integer grid stored as f32 values in `[0, 2^N−1]`
    pub w_int: Tensor,
    /// (G, Dout) scale factors
    pub scales: Tensor,
    /// (G, Dout) zero factors
    pub zeros: Tensor,
}

impl QuantizedLinear {
    pub fn din(&self) -> usize {
        self.w_int.rows()
    }

    pub fn dout(&self) -> usize {
        self.w_int.cols()
    }

    pub fn n_groups(&self) -> usize {
        self.scales.rows()
    }

    pub fn grid_max(&self) -> f32 {
        ((1u32 << self.n_bits) - 1) as f32
    }

    /// Validate structural invariants (used by proptest-style checks and
    /// after every merge).
    pub fn validate(&self) -> Result<()> {
        let (din, dout) = (self.din(), self.dout());
        if din % self.group_size != 0 {
            bail!("group size {} does not divide Din {din}", self.group_size);
        }
        let g = din / self.group_size;
        if self.scales.shape() != [g, dout] || self.zeros.shape() != [g, dout] {
            bail!(
                "scale/zero shape mismatch: {:?}/{:?}, want [{g}, {dout}]",
                self.scales.shape(),
                self.zeros.shape()
            );
        }
        let max = self.grid_max();
        for (i, &v) in self.w_int.data().iter().enumerate() {
            if v < 0.0 || v > max || v.fract() != 0.0 {
                bail!("w_int[{i}] = {v} outside {}-bit grid", self.n_bits);
            }
        }
        if self.scales.data().iter().any(|s| *s <= 0.0) {
            bail!("non-positive scale");
        }
        Ok(())
    }

    /// Dequantize to a dense f32 matrix (host-side eval / error metrics).
    pub fn dequantize(&self) -> Tensor {
        dequant(&self.w_int, &self.scales, &self.zeros, self.group_size)
    }

    /// Quantization error vs. a reference weight matrix (max abs).
    pub fn max_error(&self, w: &Tensor) -> f32 {
        self.dequantize().max_abs_diff(w)
    }

    /// Frobenius reconstruction error vs. a reference weight matrix.
    pub fn frob_error(&self, w: &Tensor) -> f32 {
        self.dequantize().sub(w).frob_norm()
    }
}

/// `s · W_int + z` with per-group broadcast.
pub fn dequant(w_int: &Tensor, scales: &Tensor, zeros: &Tensor, group_size: usize) -> Tensor {
    let (din, dout) = (w_int.rows(), w_int.cols());
    let mut out = vec![0.0f32; din * dout];
    for i in 0..din {
        let g = i / group_size;
        let srow = scales.row(g);
        let zrow = zeros.row(g);
        let wrow = w_int.row(i);
        let orow = &mut out[i * dout..(i + 1) * dout];
        for j in 0..dout {
            orow[j] = srow[j] * wrow[j] + zrow[j];
        }
    }
    Tensor::new(&[din, dout], out)
}

/// Round a single weight onto an existing (s, z) grid cell.
#[inline]
pub fn quantize_to_grid(w: f32, s: f32, z: f32, grid_max: f32) -> f32 {
    (((w - z) / s).round()).clamp(0.0, grid_max)
}

/// Compute (s, z) from min/max of a weight slice (paper Eq. 2).
#[inline]
pub fn grid_from_minmax(mn: f32, mx: f32, n_bits: u32) -> (f32, f32) {
    let levels = ((1u32 << n_bits) - 1) as f32;
    let s = ((mx - mn) / levels).max(1e-8);
    (s, mn)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn sample_ql(seed: u64, n_bits: u32) -> (QuantizedLinear, Tensor) {
        let mut rng = Rng::new(seed);
        let (din, dout, gs) = (32, 16, 8);
        let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
        let ql = crate::quant::rtn_quantize(&w, gs, n_bits);
        (ql, w)
    }

    #[test]
    fn grid_from_minmax_matches_eq2() {
        let (s, z) = grid_from_minmax(-1.0, 2.0, 4);
        assert!((s - 3.0 / 15.0).abs() < 1e-7);
        assert_eq!(z, -1.0);
    }

    #[test]
    fn quantize_to_grid_clamps() {
        assert_eq!(quantize_to_grid(100.0, 0.1, 0.0, 15.0), 15.0);
        assert_eq!(quantize_to_grid(-100.0, 0.1, 0.0, 15.0), 0.0);
        assert_eq!(quantize_to_grid(0.52, 0.1, 0.0, 15.0), 5.0);
    }

    #[test]
    fn validate_accepts_rtn_output() {
        for bits in [2, 3, 4] {
            let (ql, _) = sample_ql(1, bits);
            ql.validate().unwrap();
        }
    }

    #[test]
    fn validate_rejects_out_of_grid() {
        let (mut ql, _) = sample_ql(2, 4);
        ql.w_int.data_mut()[0] = 16.0; // > 2^4 - 1
        assert!(ql.validate().is_err());
        let (mut ql, _) = sample_ql(2, 4);
        ql.w_int.data_mut()[0] = 1.5; // non-integral
        assert!(ql.validate().is_err());
    }

    #[test]
    fn dequant_error_bounded_by_half_scale() {
        for bits in [2, 3, 4] {
            let (ql, w) = sample_ql(3, bits);
            let max_s = ql.scales.data().iter().cloned().fold(0.0f32, f32::max);
            assert!(
                ql.max_error(&w) <= max_s / 2.0 + 1e-6,
                "{bits}-bit error too large"
            );
        }
    }

    #[test]
    fn fewer_bits_more_error() {
        let (q4, w) = sample_ql(4, 4);
        let (q2, _) = sample_ql(4, 2);
        assert!(q2.frob_error(&w) > q4.frob_error(&w));
    }
}
