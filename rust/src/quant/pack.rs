//! Sub-byte bit-packing of the integer grid.
//!
//! The PJRT CPU path computes on f32-coded integers, but the *deployment*
//! representation — what the paper's memory/footprint numbers are about —
//! packs N-bit codes densely into u32 words (GPTQModel-style). This module
//! provides the pack/unpack pair used by checkpointing and by the serving
//! memory accounting, with 2/3/4-bit layouts.
//!
//! Layout: values are packed little-endian within each u32 word, column
//! after column of the (Din, Dout) grid in row-major order; 3-bit codes
//! straddle word boundaries (a code's low bits live in word k, the
//! remainder in word k+1), which keeps the stream dense at exactly
//! `ceil(n·bits / 32)` words.

use anyhow::{bail, Result};

/// Number of u32 words needed for `n` codes of `bits` width.
pub fn packed_len_u32(n: usize, bits: u32) -> usize {
    (n * bits as usize).div_ceil(32)
}

/// Pack f32-coded integers (each in `[0, 2^bits)`) into a dense u32 stream.
pub fn pack_ints(vals: &[f32], bits: u32) -> Result<Vec<u32>> {
    if !(1..=8).contains(&bits) {
        bail!("bits must be 1..=8");
    }
    let mask = (1u64 << bits) - 1;
    let mut out = vec![0u32; packed_len_u32(vals.len(), bits)];
    let mut bitpos = 0usize;
    for (i, &v) in vals.iter().enumerate() {
        if v < 0.0 || v.fract() != 0.0 || v as u64 > mask {
            bail!("value {v} at index {i} not a {bits}-bit code");
        }
        let code = (v as u64) & mask;
        let word = bitpos / 32;
        let off = bitpos % 32;
        out[word] |= (code << off) as u32;
        if off + bits as usize > 32 {
            out[word + 1] |= (code >> (32 - off)) as u32;
        }
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Inverse of [`pack_ints`].
pub fn unpack_ints(words: &[u32], n: usize, bits: u32) -> Result<Vec<f32>> {
    if !(1..=8).contains(&bits) {
        bail!("bits must be 1..=8");
    }
    if words.len() < packed_len_u32(n, bits) {
        bail!("packed stream too short: {} words for {n} codes", words.len());
    }
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(n);
    let mut bitpos = 0usize;
    for _ in 0..n {
        let word = bitpos / 32;
        let off = bitpos % 32;
        let mut code = (words[word] as u64) >> off;
        if off + bits as usize > 32 {
            code |= (words[word + 1] as u64) << (32 - off);
        }
        out.push((code & mask) as f32);
        bitpos += bits as usize;
    }
    Ok(out)
}

/// Bytes needed to store a packed (Din × Dout) grid plus its per-group f32
/// scale/zero tables — the deployment footprint used by the efficiency
/// benches (Fig. 4) and Fig. 6 memory rows.
pub fn deployed_bytes(din: usize, dout: usize, group_size: usize, bits: u32) -> usize {
    let grid = packed_len_u32(din * dout, bits) * 4;
    // a trailing partial group still carries a full scale/zero row
    let groups = din.div_ceil(group_size);
    let params = groups * dout * 4 * 2; // scales + zeros
    grid + params
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip_all_bit_widths() {
        let mut rng = Rng::new(99);
        for bits in [2u32, 3, 4] {
            for n in [1usize, 7, 32, 33, 100, 1024] {
                let vals: Vec<f32> =
                    (0..n).map(|_| rng.below(1 << bits) as f32).collect();
                let packed = pack_ints(&vals, bits).unwrap();
                assert_eq!(packed.len(), packed_len_u32(n, bits));
                let got = unpack_ints(&packed, n, bits).unwrap();
                assert_eq!(got, vals, "bits={bits} n={n}");
            }
        }
    }

    #[test]
    fn three_bit_straddles_words() {
        // 11 × 3 bits = 33 bits -> exactly 2 words, last code straddles
        let vals: Vec<f32> = (0..11).map(|i| ((i * 3) % 8) as f32).collect();
        let packed = pack_ints(&vals, 3).unwrap();
        assert_eq!(packed.len(), 2);
        assert_eq!(unpack_ints(&packed, 11, 3).unwrap(), vals);
    }

    #[test]
    fn density_is_exact() {
        assert_eq!(packed_len_u32(64, 4), 8); // 64*4/32
        assert_eq!(packed_len_u32(64, 3), 6); // 192/32
        assert_eq!(packed_len_u32(64, 2), 4);
        assert_eq!(packed_len_u32(3, 3), 1);
    }

    #[test]
    fn rejects_out_of_range() {
        assert!(pack_ints(&[16.0], 4).is_err());
        assert!(pack_ints(&[-1.0], 4).is_err());
        assert!(pack_ints(&[1.5], 4).is_err());
        assert!(unpack_ints(&[0u32], 100, 4).is_err());
    }

    #[test]
    fn deployed_bytes_ordering() {
        // fewer bits -> smaller deployment, always
        let b4 = deployed_bytes(1024, 1024, 64, 4);
        let b3 = deployed_bytes(1024, 1024, 64, 3);
        let b2 = deployed_bytes(1024, 1024, 64, 2);
        assert!(b2 < b3 && b3 < b4);
        // and all far below f32 (4 bytes/weight)
        assert!(b4 < 1024 * 1024 * 4 / 4);
    }

    #[test]
    fn deployed_bytes_counts_partial_groups() {
        // Din = 100, gs = 64: the tail rows 64..100 form a second group
        // whose scale/zero tables must be counted (was truncated to 1)
        let got = deployed_bytes(100, 8, 64, 4);
        let grid = packed_len_u32(100 * 8, 4) * 4;
        assert_eq!(got, grid + 2 * 8 * 4 * 2);
        // exact multiples are unchanged by the div_ceil
        let exact = deployed_bytes(128, 8, 64, 4);
        assert_eq!(exact, packed_len_u32(128 * 8, 4) * 4 + 2 * 8 * 4 * 2);
    }
}
