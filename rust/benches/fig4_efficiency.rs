//! Regenerates **Figure 4 (c)** — inference-efficiency analysis (§4.3):
//! token throughput by batch bucket for the merged low-bit path (LoTA
//! after its lossless merge) vs the quant + 16-bit-adapter path (LoRA),
//! at 4/3/2-bit, plus the merged-over-LoRA speedup ratio and the
//! deployed-weight footprints.
//!
//! Paper reference: LoTA 1.9×/1.7×/2.0× faster than LoRA at 4/3/2-bit on
//! an A800. The comparison now runs on **both serving backends**: the
//! fixed-shape PJRT artifacts (f32-coded compute, the portable part of
//! the claim is the extra adapter matmuls) and the native packed-integer
//! engine, which computes straight off the deployed `u32` grid — the
//! representation the paper's footprint numbers describe — and therefore
//! needs no artifacts and no batch buckets at all.
//!
//! Env knobs: LOTA_F4C_REQS (16), LOTA_F4C_MAXNEW (8),
//! LOTA_F4C_MODEL (small), LOTA_F4C_BACKEND (both|pjrt|native).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{preset, Backend, DecodeMode, Method, SchedConfig};
use lota_qaf::data::{task_by_name, Split};
use lota_qaf::model;
use lota_qaf::quant::{pack::deployed_bytes, rtn_quantize};
use lota_qaf::runtime::Runtime;
use lota_qaf::serve::{serve_batch, ServeOptions, ServePath};
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_reqs = env_usize("LOTA_F4C_REQS", 16);
    let max_new = env_usize("LOTA_F4C_MAXNEW", 8);
    let model = std::env::var("LOTA_F4C_MODEL").unwrap_or_else(|_| "small".into());
    let backend_sel = std::env::var("LOTA_F4C_BACKEND").unwrap_or_else(|_| "both".into());
    let backends = Backend::parse_selection(&backend_sel)?;
    let rt = if backends.contains(&Backend::Pjrt) {
        Some(Runtime::new(Path::new("artifacts"))?)
    } else {
        None
    };
    let cfg = preset(&model)?;
    let mut rng = Rng::new(4);
    let fp = model::init_fp(&cfg, &mut rng);

    let gen = task_by_name("arith")?;
    let mut prng = Rng::new(5);
    let prompts: Vec<String> = (0..n_reqs)
        .map(|_| gen.sample(&mut prng, Split::Test).prompt)
        .collect();

    // warm-up: compile every PJRT serving executable before timing
    // anything, so the first table row doesn't absorb compilation (the
    // native engine has no compile step — packing is part of setup)
    if let Some(rt) = rt.as_ref() {
        let warm = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })?;
        let mut warm_l = warm.clone();
        model::init_adapters(&cfg, Method::Lora, &mut rng, &mut warm_l);
        let wp = vec![prompts[0].clone()];
        serve_batch(Some(rt), &cfg, &warm, &ServeOptions::new(ServePath::Merged, 2), &wp)?;
        serve_batch(Some(rt), &cfg, &warm_l, &ServeOptions::new(ServePath::LoraAdapter, 2), &wp)?;
    }

    println!("## Figure 4c — serving throughput, merged vs LoRA path ({n_reqs} reqs × {max_new} toks)");
    let mut t = Table::new(&[
        "bits", "backend", "merged tok/s", "lora tok/s", "cpu speedup", "bw-model speedup",
        "merged KiB", "lora KiB",
    ]);
    for bits in [4u32, 3, 2] {
        let merged = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, bits))
        })?;
        let mut lora = merged.clone();
        model::init_adapters(&cfg, Method::Lora, &mut rng, &mut lora);

        let w_bytes: usize = cfg
            .slots()
            .iter()
            .map(|(_, din, dout)| deployed_bytes(*din, *dout, cfg.group_size, bits) * cfg.n_layers)
            .sum();
        let a_bytes: usize = cfg
            .slots()
            .iter()
            .map(|(_, din, dout)| (din * cfg.rank + cfg.rank * dout) * 4 * cfg.n_layers)
            .sum();
        // Real GPTQ decode is weight-bandwidth-bound, so the deployment
        // speedup tracks bytes-moved-per-token; the PJRT f32 substrate
        // computes both paths at full precision and compresses the gap
        // (DESIGN.md §2), while the native engine really moves packed
        // bytes. The bandwidth model reproduces the paper's 1.7–2.0x
        // territory at low bits.
        let bw_model = (w_bytes + a_bytes) as f64 / w_bytes as f64;
        for &backend in &backends {
            let opts = |path| ServeOptions::new(path, max_new).backend(backend).bits(bits);
            let rep_m =
                serve_batch(rt.as_ref(), &cfg, &merged, &opts(ServePath::Merged), &prompts)?;
            let rep_l =
                serve_batch(rt.as_ref(), &cfg, &lora, &opts(ServePath::LoraAdapter), &prompts)?;
            t.row(&[
                bits.to_string(),
                backend.as_str().to_string(),
                format!("{:.1}", rep_m.tokens_per_sec),
                format!("{:.1}", rep_l.tokens_per_sec),
                format!("{:.2}x", rep_m.speedup_over(&rep_l)),
                format!("{:.2}x", bw_model),
                format!("{:.1}", w_bytes as f64 / 1024.0),
                format!("{:.1}", (w_bytes + a_bytes) as f64 / 1024.0),
            ]);
        }
    }
    t.print();

    // throughput scaling over batch sizes (merged path, 4-bit): the PJRT
    // rows are bucket-shaped; the native rows include sizes no bucket
    // covers — the shape-freedom the engine buys
    println!("\n## Figure 4c inset — merged-path throughput by batch size");
    let merged =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))?;
    let mut t = Table::new(&["batch", "backend", "tok/s", "p50 latency s"]);
    let buckets: &[usize] = if model == "tiny" { &[1, 8, 32] } else { &[1, 4, 8] };
    for &backend in &backends {
        let sizes: Vec<usize> = match backend {
            Backend::Pjrt => buckets.to_vec(),
            // off-bucket sizes on purpose: nothing was compiled for these
            Backend::Native => buckets.iter().map(|b| b + 1).collect(),
        };
        for bucket in sizes {
            let prompts: Vec<String> = (0..bucket)
                .map(|_| gen.sample(&mut prng, Split::Test).prompt)
                .collect();
            let opts = ServeOptions::new(ServePath::Merged, max_new).backend(backend);
            let rep = serve_batch(rt.as_ref(), &cfg, &merged, &opts, &prompts)?;
            t.row(&[
                bucket.to_string(),
                backend.as_str().to_string(),
                format!("{:.1}", rep.tokens_per_sec),
                format!("{:.3}", rep.latency.p50),
            ]);
        }
    }
    t.print();

    // cached vs recompute vs scheduled decode on the native engine: the
    // same total generated tokens (full per-text parity is pinned by the
    // test suites), O(T) vs O(T²) work. "pos/tok" is positions fed per
    // token generated — the honest witness (near 1 + prefill
    // amortization for the cache, growing with generation length for
    // recompute). The sched row serves through the continuous-batching
    // scheduler (one-shot: all requests at t = 0), which additionally
    // observes time-to-first-token and queue wait — the request-level
    // numbers one-shot draining can't measure.
    if backends.contains(&Backend::Native) {
        println!("\n## Figure 4c addendum — native decode: KV-cached vs recompute vs scheduled");
        let mut t = Table::new(&[
            "max_new", "decode", "tok/s", "pos/tok", "speedup", "ttft p50/p95 ms", "queue ms",
        ]);
        for max_new in [8usize, 32] {
            let prompts: Vec<String> = (0..n_reqs)
                .map(|_| gen.sample(&mut prng, Split::Test).prompt)
                .collect();
            let run = |opts: ServeOptions| serve_batch(None, &cfg, &merged, &opts, &prompts);
            let native = |mode: DecodeMode| {
                ServeOptions::new(ServePath::Merged, max_new)
                    .backend(Backend::Native)
                    .decode_mode(mode)
            };
            let rep_c = run(native(DecodeMode::Cached))?;
            let rep_r = run(native(DecodeMode::Recompute))?;
            // scheduled rows in both KV layouts: paged (the default) and
            // the contiguous reference — same tokens, different memory
            // shape and admission arithmetic
            let rep_s = run(native(DecodeMode::Cached).scheduled(SchedConfig::default()))?;
            let rep_sc = run(native(DecodeMode::Cached)
                .scheduled(SchedConfig { kv_paged: false, ..SchedConfig::default() }))?;
            assert_eq!(rep_c.tokens, rep_r.tokens, "decode modes generated different tokens");
            assert_eq!(rep_c.tokens, rep_s.tokens, "scheduling changed the generations");
            assert_eq!(rep_s.tokens, rep_sc.tokens, "the KV layout changed the generations");
            for (mode, rep, speedup) in [
                ("cached", &rep_c, rep_c.speedup_over(&rep_r)),
                ("recompute", &rep_r, 1.0),
                ("sched-paged", &rep_s, rep_s.speedup_over(&rep_r)),
                ("sched-contig", &rep_sc, rep_sc.speedup_over(&rep_r)),
            ] {
                let ppt = rep.positions_per_token();
                t.row(&[
                    max_new.to_string(),
                    mode.to_string(),
                    format!("{:.1}", rep.tokens_per_sec),
                    if ppt.is_nan() { "-".to_string() } else { format!("{ppt:.1}") },
                    format!("{:.2}x", speedup),
                    if rep.sched.is_some() {
                        format!("{:.1}/{:.1}", rep.ttft_ms_p50, rep.ttft_ms_p95)
                    } else {
                        "-".to_string()
                    },
                    if rep.sched.is_some() {
                        format!("{:.1}", rep.queue_wait_ms)
                    } else {
                        "-".to_string()
                    },
                ]);
            }
        }
        t.print();
    }
    Ok(())
}
