//! Regenerates **Figure 4 (c)** — inference-efficiency analysis (§4.3):
//! token throughput by batch bucket for the merged low-bit path (LoTA
//! after its lossless merge) vs the quant + 16-bit-adapter path (LoRA),
//! at 4/3/2-bit, plus the merged-over-LoRA speedup ratio and the
//! deployed-weight footprints.
//!
//! Paper reference: LoTA 1.9×/1.7×/2.0× faster than LoRA at 4/3/2-bit on
//! an A800. Here both paths run identical fixed-shape fwd artifacts on
//! CPU PJRT, so the ratio reflects the *extra adapter matmuls* — the
//! portable part of the claim. (Sub-byte kernels are simulated with
//! f32-coded integers, so 4/3/2-bit merged paths share one artifact; the
//! footprint column shows the real deployment sizes from `quant::pack`.)
//!
//! Env knobs: LOTA_F4C_REQS (16), LOTA_F4C_MAXNEW (8).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{preset, Method};
use lota_qaf::data::{task_by_name, Split};
use lota_qaf::model;
use lota_qaf::quant::{pack::deployed_bytes, rtn_quantize};
use lota_qaf::runtime::Runtime;
use lota_qaf::serve::{serve_batch, ServePath};
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let n_reqs = env_usize("LOTA_F4C_REQS", 16);
    let max_new = env_usize("LOTA_F4C_MAXNEW", 8);
    let model = std::env::var("LOTA_F4C_MODEL").unwrap_or_else(|_| "small".into());
    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg = preset(&model)?;
    let mut rng = Rng::new(4);
    let fp = model::init_fp(&cfg, &mut rng);

    let gen = task_by_name("arith")?;
    let mut prng = Rng::new(5);
    let prompts: Vec<String> = (0..n_reqs)
        .map(|_| gen.sample(&mut prng, Split::Test).prompt)
        .collect();

    // warm-up: compile every serving executable before timing anything,
    // so the first table row doesn't absorb PJRT compilation
    {
        let warm = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })?;
        let mut warm_l = warm.clone();
        model::init_adapters(&cfg, Method::Lora, &mut rng, &mut warm_l);
        let wp = vec![prompts[0].clone()];
        serve_batch(&rt, &cfg, &warm, ServePath::Merged, &wp, 2)?;
        serve_batch(&rt, &cfg, &warm_l, ServePath::LoraAdapter, &wp, 2)?;
    }

    println!("## Figure 4c — serving throughput, merged vs LoRA path ({n_reqs} reqs × {max_new} toks)");
    let mut t = Table::new(&[
        "bits", "merged tok/s", "lora tok/s", "cpu speedup", "bw-model speedup",
        "merged KiB", "lora KiB",
    ]);
    for bits in [4u32, 3, 2] {
        let merged = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, bits))
        })?;
        let mut lora = merged.clone();
        model::init_adapters(&cfg, Method::Lora, &mut rng, &mut lora);

        let rep_m = serve_batch(&rt, &cfg, &merged, ServePath::Merged, &prompts, max_new)?;
        let rep_l = serve_batch(&rt, &cfg, &lora, ServePath::LoraAdapter, &prompts, max_new)?;

        let w_bytes: usize = cfg
            .slots()
            .iter()
            .map(|(_, din, dout)| deployed_bytes(*din, *dout, cfg.group_size, bits) * cfg.n_layers)
            .sum();
        let a_bytes: usize = cfg
            .slots()
            .iter()
            .map(|(_, din, dout)| (din * cfg.rank + cfg.rank * dout) * 4 * cfg.n_layers)
            .sum();
        // Real GPTQ decode is weight-bandwidth-bound, so the deployment
        // speedup tracks bytes-moved-per-token; the CPU-f32 substrate
        // computes both paths at full precision and compresses the gap
        // (DESIGN.md §2). The bandwidth model reproduces the paper's
        // 1.7–2.0x territory at low bits.
        let bw_model = (w_bytes + a_bytes) as f64 / w_bytes as f64;
        t.row(&[
            bits.to_string(),
            format!("{:.1}", rep_m.tokens_per_sec),
            format!("{:.1}", rep_l.tokens_per_sec),
            format!("{:.2}x", rep_m.speedup_over(&rep_l)),
            format!("{:.2}x", bw_model),
            format!("{:.1}", w_bytes as f64 / 1024.0),
            format!("{:.1}", (w_bytes + a_bytes) as f64 / 1024.0),
        ]);
    }
    t.print();

    // throughput scaling over batch buckets (merged path, 4-bit)
    println!("\n## Figure 4c inset — merged-path throughput by batch bucket");
    let merged =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))?;
    let mut t = Table::new(&["batch", "tok/s", "p50 latency s"]);
    let buckets: &[usize] = if model == "tiny" { &[1, 8, 32] } else { &[1, 4, 8] };
    for &bucket in buckets {
        let prompts: Vec<String> = (0..bucket)
            .map(|_| gen.sample(&mut prng, Split::Test).prompt)
            .collect();
        let rep = serve_batch(&rt, &cfg, &merged, ServePath::Merged, &prompts, max_new)?;
        t.row(&[
            bucket.to_string(),
            format!("{:.1}", rep.tokens_per_sec),
            format!("{:.3}", rep.latency.p50),
        ]);
    }
    t.print();
    Ok(())
}
