//! Regenerates **Figure 4 (a,b)** — hyper-parameter sensitivity of
//! LoTA-QAF on the GSM8K stand-in (`arith`) at 4/3/2-bit:
//!   (a) the ternary threshold ω as a fraction of the rank
//!       (paper sweeps ω ∈ {40..60} at r=64 ⇒ fracs 0.625..0.9375);
//!   (b) the initial σ_t percentile (top {9.5, 8.0, 6.5, 5.0, 3.5, 2.0}%).
//!
//! Expected shapes: a sweet spot near ω = 0.75r with larger ω preferred at
//! 2-bit (conservative updates on a 4-level grid); small initial σ_t
//! under-trains (the paper's "overly small σ_t limits learning").
//!
//! Env knobs: LOTA_F4_STEPS (120), LOTA_F4_EVAL (48).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::{run_cell, ExperimentContext};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = env_usize("LOTA_F4_STEPS", 120);
    let eval_n = env_usize("LOTA_F4_EVAL", 48);
    let ctx = ExperimentContext::build(Path::new("artifacts"), "tiny", 600, 20250710)?;

    let omega_fracs = [0.625, 0.6875, 0.75, 0.8125, 0.875, 0.9375];
    let sigma_inits = [0.095, 0.080, 0.065, 0.050, 0.035, 0.020];

    println!("## Figure 4a — ω sweep (arith token-acc %, LoTA-QAF, {steps} steps)");
    let mut t = Table::new(&["omega/r", "int4", "int3", "int2"]);
    for of in omega_fracs {
        let mut row = vec![format!("{of:.4}")];
        for bits in [4u32, 3, 2] {
            let exp = ExperimentConfig {
                method: Method::LotaQaf,
                n_bits: bits,
                omega_frac: of,
                sigma_init: 0.05,
                steps,
                lr: 5e-4,
                task: "arith".into(),
                ..Default::default()
            };
            let cell = run_cell(&ctx, &exp, eval_n)?;
            row.push(format!("{:.2}", cell.token_acc.unwrap_or(0.0)));
        }
        t.row(&row);
    }
    t.print();

    println!("\n## Figure 4b — initial σ_t sweep (arith token-acc %, ω=0.75r)");
    let mut t = Table::new(&["sigma_init", "int4", "int3", "int2"]);
    for si in sigma_inits {
        let mut row = vec![format!("{:.1}%", si * 100.0)];
        for bits in [4u32, 3, 2] {
            let exp = ExperimentConfig {
                method: Method::LotaQaf,
                n_bits: bits,
                omega_frac: 0.75,
                sigma_init: si,
                steps,
                lr: 5e-4,
                task: "arith".into(),
                ..Default::default()
            };
            let cell = run_cell(&ctx, &exp, eval_n)?;
            row.push(format!("{:.2}", cell.token_acc.unwrap_or(0.0)));
        }
        t.row(&row);
    }
    t.print();
    Ok(())
}
