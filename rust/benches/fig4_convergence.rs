//! Regenerates **Figure 4 (d)** — convergence analysis (§4.3): training
//! loss curves of LoRA (AdamW, 16-bit adapters) vs LoTA (t-SignSGD,
//! ternary adapters) on the SQL stand-in at 4/3/2-bit.
//!
//! Expected shapes: LoRA converges lowest everywhere (fp adapter
//! stability); the 4/3-bit LoTA gap stays small; the 2-bit gap widens
//! (paper: 0.132 vs 0.375 at 2-bit) — the 4-level grid makes ternary
//! adjustments volatile.
//!
//! Env knobs: LOTA_F4D_STEPS (150).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::ExperimentContext;
use lota_qaf::coordinator::{finetune, TrainOptions};
use lota_qaf::model;
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn curve_string(losses: &[f32], points: usize) -> String {
    let stride = (losses.len() / points).max(1);
    losses
        .iter()
        .step_by(stride)
        .map(|l| format!("{l:.3}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn tail_mean(losses: &[f32], k: usize) -> f32 {
    let n = losses.len();
    let k = k.min(n);
    losses[n - k..].iter().sum::<f32>() / k as f32
}

fn main() -> anyhow::Result<()> {
    let steps = env_usize("LOTA_F4D_STEPS", 150);
    let ctx = ExperimentContext::build(Path::new("artifacts"), "tiny", 600, 20250710)?;

    println!("## Figure 4d — convergence on sql ({steps} steps)");
    let mut summary = Table::new(&["bits", "LoRA final loss", "LoTA final loss", "gap"]);
    for bits in [4u32, 3, 2] {
        let mut finals = Vec::new();
        for method in [Method::Lora, Method::LotaQaf] {
            let mut store = ctx.quantized(bits)?;
            let mut rng = Rng::new(0xF16D ^ bits as u64);
            model::init_adapters(&ctx.cfg, method, &mut rng, &mut store);
            let exp = ExperimentConfig {
                method,
                n_bits: bits,
                steps,
                lr: 5e-4,
                task: "sql".into(),
                ..Default::default()
            };
            let report = finetune(&ctx.rt, &ctx.cfg, &exp, &mut store, &TrainOptions::default())?;
            let f = tail_mean(&report.losses, 10);
            println!(
                "int{bits} {:>5}: {}",
                method.as_str(),
                curve_string(&report.losses, 15)
            );
            finals.push(f);
        }
        summary.row(&[
            bits.to_string(),
            format!("{:.3}", finals[0]),
            format!("{:.3}", finals[1]),
            format!("{:+.3}", finals[1] - finals[0]),
        ]);
    }
    println!();
    summary.print();
    println!("(paper at 2-bit: LoRA 0.132 vs LoTA 0.375 — gap widens at 2-bit)");
    Ok(())
}
