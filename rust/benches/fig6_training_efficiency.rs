//! Regenerates **Figure 6** (appendix C) — training-efficiency comparison
//! of LoRA vs LoTA at 4-bit across the four datasets: total fine-tuning
//! wall time and peak auxiliary training state (adapters + optimizer
//! moments — the paper's "memory" axis, minus the framework's fixed
//! overheads which are identical for both methods here).
//!
//! Paper reference: LoTA costs +14.1–25.4% time vs LoRA (the ternary map
//! adds forward work), with a small memory delta. Here LoTA carries *no*
//! AdamW moments (t-SignSGD is stateless) while paying the ternary-apply
//! map per step — both effects are visible in the table.
//!
//! Env knobs: LOTA_F6_STEPS (100).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::ExperimentContext;
use lota_qaf::coordinator::{finetune, TrainOptions};
use lota_qaf::model;
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let steps = env_usize("LOTA_F6_STEPS", 100);
    let ctx = ExperimentContext::build(Path::new("artifacts"), "tiny", 600, 20250710)?;

    println!("## Figure 6 — training time & aux memory, LoRA vs LoTA (4-bit, {steps} steps)");
    let mut t = Table::new(&[
        "dataset",
        "LoRA s",
        "LoTA s",
        "time delta",
        "LoRA aux KiB",
        "LoTA aux KiB",
    ]);
    for task in ["recovery", "arith", "sql", "datatotext"] {
        let mut secs = Vec::new();
        let mut aux = Vec::new();
        for method in [Method::Lora, Method::LotaQaf] {
            let mut store = ctx.quantized(4)?;
            let mut rng = Rng::new(0xF6 ^ method as u64);
            model::init_adapters(&ctx.cfg, method, &mut rng, &mut store);
            let exp = ExperimentConfig {
                method,
                n_bits: 4,
                steps,
                lr: 5e-4,
                task: task.into(),
                ..Default::default()
            };
            let report =
                finetune(&ctx.rt, &ctx.cfg, &exp, &mut store, &TrainOptions::default())?;
            secs.push(report.wall_secs);
            aux.push(report.aux_state_elems * 4);
        }
        t.row(&[
            task.to_string(),
            format!("{:.2}", secs[0]),
            format!("{:.2}", secs[1]),
            format!("{:+.1}%", 100.0 * (secs[1] - secs[0]) / secs[0]),
            format!("{:.1}", aux[0] as f64 / 1024.0),
            format!("{:.1}", aux[1] as f64 / 1024.0),
        ]);
    }
    t.print();
    println!("(paper: LoTA +14.1–25.4% time, +2.6–6.3% memory vs LoRA on A800/bf16)");
    Ok(())
}
