//! Hot-path micro-benchmarks backing EXPERIMENTS.md §Perf: per-layer
//! timings of every operation on the training/serving critical paths —
//! GPTQ sweeps, the host ternary merge, bit-packing, t-SignSGD host
//! update, host matmul, the native engine's fused packed GEMM against its
//! unpack-then-f32-matmul baseline, the native decode step (KV-cached vs
//! full-prefix recompute at growing prefix lengths — the O(1)-vs-O(T)
//! per-token scaling), PJRT forward latency per batch bucket, and the
//! full training-step latency per method.
//!
//! Env knobs: LOTA_MICRO_ITERS (10), LOTA_BENCH_JSON_DIR (".").
//!
//! Alongside the markdown table, every timing lands in
//! `BENCH_micro_hotpaths.json` (the `bench_harness::JsonReport` schema) —
//! flushed once before the PJRT sections (which need `make artifacts` and
//! abort the run without them) and again at the end, so the host/engine
//! rows always reach the JSON even on an artifact-less machine.

use std::collections::BTreeMap;
use std::path::Path;

use lota_qaf::adapter::{lota_merge, TernaryAdapter};
use lota_qaf::bench_harness::{bench, JsonReport, Table};
use lota_qaf::config::{preset, step_batch, Method};
use lota_qaf::coordinator;
use lota_qaf::data::{corpus, lm_batch, sft_batch, Example};
use lota_qaf::engine::{self, PackedLinear};
use lota_qaf::model;
use lota_qaf::quant::{
    accumulate_hessian, gptq_quantize, pack_ints, rtn_quantize, unpack_ints, GptqConfig,
};
use lota_qaf::runtime::Runtime;
use lota_qaf::tensor::{linalg, Rng, Tensor};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let iters = env_usize("LOTA_MICRO_ITERS", 10);
    let mut results = Table::new(&["path", "mean ms", "p50 ms", "p95 ms", "throughput"]);
    let mut jr = JsonReport::new("micro_hotpaths");
    jr.meta_num("iters", iters as f64);
    jr.meta_str("gemm_kernel", lota_qaf::engine::simd::resolve(Default::default()).label());
    let json_path = JsonReport::default_path("micro_hotpaths");
    let mut rng = Rng::new(1);

    // ---- host: GPTQ sweep on a small-model slot (256×1024, gs=32) ----
    let (din, dout, gs) = (256, 1024, 32);
    let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
    let x = Tensor::new(&[512, din], rng.normal_vec(512 * din, 1.0));
    let mut h = Tensor::zeros(&[din, din]);
    accumulate_hessian(&mut h, &x);
    let cfg4 = GptqConfig::new(4, gs);
    let r = bench("gptq 256x1024", 1, iters.min(5), || {
        gptq_quantize(&w, &h, &cfg4).unwrap();
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.1} Mw/s", din as f64 * dout as f64 / r.mean_secs / 1e6),
    ]);

    // ---- host: hessian accumulation ----
    let r = bench("hessian 512x256", 1, iters, || {
        let mut h2 = Tensor::zeros(&[din, din]);
        accumulate_hessian(&mut h2, &x);
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.2} GF/s", 2.0 * 512.0 * (din * din) as f64 / r.mean_secs / 1e9),
    ]);

    // ---- host: ternary merge ----
    let ql = rtn_quantize(&w, gs, 4);
    let rank = 16;
    let ta = {
        let mut t = TernaryAdapter::init(din, dout, rank, &mut rng);
        t.b = Tensor::new(
            &[rank, dout],
            (0..rank * dout).map(|_| rng.below(3) as f32 - 1.0).collect(),
        );
        t
    };
    let r = bench("lota_merge 256x1024", 1, iters, || {
        lota_merge(&ql, &ta, 12.0);
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.1} Mw/s", din as f64 * dout as f64 / r.mean_secs / 1e6),
    ]);

    // ---- host: bit packing ----
    let codes: Vec<f32> = (0..din * dout).map(|_| rng.below(16) as f32).collect();
    let r = bench("pack+unpack 4-bit 256k", 1, iters, || {
        let p = pack_ints(&codes, 4).unwrap();
        unpack_ints(&p, codes.len(), 4).unwrap();
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.1} Mw/s", codes.len() as f64 / r.mean_secs / 1e6),
    ]);

    // ---- host: matmul (the coordinator's biggest host op) ----
    let a = Tensor::new(&[256, 256], rng.normal_vec(256 * 256, 1.0));
    let b = Tensor::new(&[256, 256], rng.normal_vec(256 * 256, 1.0));
    let r = bench("host matmul 256^3", 1, iters, || {
        linalg::matmul(&a, &b);
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.2} GF/s", 2.0 * 256f64.powi(3) / r.mean_secs / 1e9),
    ]);

    // ---- host: fused packed GEMM vs unpack-then-f32-matmul ----
    // the native engine's hot path: same (256×1024, gs=32) slot, activations
    // for a 128-row batch; the unfused baseline is what serving paid before
    // the engine existed (unpack every code, materialize f32, dense matmul)
    let xa = Tensor::new(&[128, din], rng.normal_vec(128 * din, 1.0));
    let pl = PackedLinear::from_quantized(&ql)?;
    {
        // correctness pin before timing anything
        let fused = engine::matmul_packed(&xa, &pl);
        let dense = linalg::matmul(&xa, &ql.dequantize());
        assert!(
            fused.allclose(&dense, 1e-3, 1e-3),
            "fused/unfused diverge: {}",
            fused.max_abs_diff(&dense)
        );
    }
    let flops = 2.0 * 128.0 * (din * dout) as f64;
    let r = bench("quant_matmul_packed 128x256x1024", 1, iters, || {
        engine::matmul_packed(&xa, &pl);
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.2} GF/s", flops / r.mean_secs / 1e9),
    ]);
    let packed_grid = pack_ints(ql.w_int.data(), 4)?;
    let r = bench("unpack+f32 matmul 128x256x1024", 1, iters, || {
        let grid = Tensor::new(&[din, dout], unpack_ints(&packed_grid, din * dout, 4).unwrap());
        let w_f32 = lota_qaf::quant::dequant(&grid, &ql.scales, &ql.zeros, gs);
        linalg::matmul(&xa, &w_f32);
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.2}", r.mean_secs * 1e3),
        format!("{:.2}", r.p50_secs * 1e3),
        format!("{:.2}", r.p95_secs * 1e3),
        format!("{:.2} GF/s", flops / r.mean_secs / 1e9),
    ]);

    // ---- native engine: decode-step latency, KV-cached vs recompute ----
    // the O(T)-vs-O(1) witness: one decode step at growing prefix length.
    // Recompute re-runs the whole prefix; the cached step feeds one token
    // against stored K/V, so its latency should be ~flat in T while the
    // recompute row grows linearly.
    {
        let dcfg = preset("tiny")?;
        let dfp = model::init_fp(&dcfg, &mut rng);
        let dstore = model::quantize_store(&dcfg, &dfp, |_, _, w| {
            Ok(rtn_quantize(w, dcfg.group_size, 4))
        })?;
        let eng = engine::Engine::from_store(&dcfg, &dstore, 4)?;
        for prefix in [16usize, 48, 96] {
            let toks: Vec<f32> =
                (0..prefix).map(|_| rng.below(dcfg.vocab) as f32).collect();
            let full = Tensor::new(&[1, prefix], toks.clone());
            let r = bench(&format!("decode step recompute T={prefix}"), 1, iters, || {
                eng.forward(&full).unwrap();
            });
            jr.push(&r);
            results.row(&[
                r.name.clone(),
                format!("{:.2}", r.mean_secs * 1e3),
                format!("{:.2}", r.p50_secs * 1e3),
                format!("{:.2}", r.p95_secs * 1e3),
                format!("{:.0} step/s", r.per_sec()),
            ]);
            // prefill the prefix once outside the timer, then repeatedly
            // re-step the final token against the cached prefix (rewinding
            // the cursor between iterations — truncate is O(1))
            let mut cache = eng.new_cache(1);
            let prefill = Tensor::new(&[1, prefix - 1], toks[..prefix - 1].to_vec());
            eng.forward_incremental(&prefill, &mut cache, &[0])?;
            let step_tok = Tensor::new(&[1, 1], vec![toks[prefix - 1]]);
            let r = bench(&format!("decode step cached    T={prefix}"), 1, iters, || {
                cache.truncate_row(0, prefix - 1);
                eng.forward_incremental(&step_tok, &mut cache, &[0]).unwrap();
            });
            jr.push(&r);
            results.row(&[
                r.name.clone(),
                format!("{:.2}", r.mean_secs * 1e3),
                format!("{:.2}", r.p50_secs * 1e3),
                format!("{:.2}", r.p95_secs * 1e3),
                format!("{:.0} step/s", r.per_sec()),
            ]);
        }
    }

    // flush the host/engine rows before touching artifacts —
    // Runtime::new errors out on artifact-less machines and would
    // otherwise drop everything timed so far from the JSON
    jr.write(&json_path)?;

    // ---- PJRT: forward latency per bucket ----
    let rt = Runtime::new(Path::new("artifacts"))?;
    let cfg = preset("tiny")?;
    let fp = model::init_fp(&cfg, &mut rng);
    let store = model::quantize_store(&cfg, &fp, |_, _, w| {
        Ok(rtn_quantize(w, cfg.group_size, 4))
    })?;
    for bucket in [1usize, 8, 32] {
        let name = if bucket == step_batch(&cfg.name) {
            "fwd_merged_tiny".to_string()
        } else {
            format!("fwd_merged_tiny_b{bucket}")
        };
        let exe = rt.load(&name)?;
        let tokens = Tensor::new(
            &[bucket, cfg.seq_len],
            (0..bucket * cfg.seq_len).map(|_| rng.below(cfg.vocab) as f32).collect(),
        );
        let r = bench(&format!("pjrt fwd b{bucket}"), 2, iters, || {
            coordinator::run_forward(&rt, &exe, &store, &tokens, None).unwrap();
        });
        jr.push(&r);
        results.row(&[
            r.name.clone(),
            format!("{:.2}", r.mean_secs * 1e3),
            format!("{:.2}", r.p50_secs * 1e3),
            format!("{:.2}", r.p95_secs * 1e3),
            format!(
                "{:.0} tok/s",
                bucket as f64 * cfg.seq_len as f64 / r.mean_secs
            ),
        ]);
    }

    // ---- PJRT: one full training step per method ----
    let bsz = step_batch(&cfg.name);
    let examples: Vec<Example> = {
        let mut er = Rng::new(2);
        (0..bsz)
            .map(|_| {
                let (p, c) = corpus::sample_recovery_example(&mut er);
                Example { prompt: p, completion: c }
            })
            .collect()
    };
    let batch = sft_batch(&examples, bsz, cfg.seq_len);
    for method in [Method::LotaQaf, Method::Lora, Method::QaLora] {
        let mut store = model::quantize_store(&cfg, &fp, |_, _, w| {
            Ok(rtn_quantize(w, cfg.group_size, 4))
        })?;
        let mut mrng = Rng::new(3);
        model::init_adapters(&cfg, method, &mut mrng, &mut store);
        let artifact = match method {
            Method::LotaQaf => "step_lota_tiny_w4".to_string(),
            m => format!("step_{}_tiny", m.as_str()),
        };
        let exe = rt.load(&artifact)?;
        let names = model::adapter_names(method);
        let mut m = model::ParamStore::new();
        let mut v = model::ParamStore::new();
        for n in &names {
            let shape = store.get(n)?.shape().to_vec();
            m.insert(n, Tensor::zeros(&shape));
            v.insert(n, Tensor::zeros(&shape));
        }
        let mut scalars = BTreeMap::new();
        match method {
            Method::LotaQaf => {
                scalars.insert("omega".to_string(), Tensor::from_scalar(6.0));
                scalars.insert("keep_frac".to_string(), Tensor::from_scalar(0.05));
            }
            _ => {
                scalars.insert("lr".to_string(), Tensor::from_scalar(5e-4));
                scalars.insert("step".to_string(), Tensor::from_scalar(1.0));
            }
        }
        let r = bench(&format!("train step {}", method.as_str()), 2, iters, || {
            coordinator::run_step(
                &rt,
                &exe,
                &mut store,
                Some(&mut m),
                Some(&mut v),
                &batch,
                &scalars,
            )
            .unwrap();
        });
        jr.push(&r);
        results.row(&[
            r.name.clone(),
            format!("{:.2}", r.mean_secs * 1e3),
            format!("{:.2}", r.p50_secs * 1e3),
            format!("{:.2}", r.p95_secs * 1e3),
            format!(
                "{:.0} tok/s",
                bsz as f64 * cfg.seq_len as f64 / r.mean_secs
            ),
        ]);
    }

    // ---- pretraining doc batch assembly (pure host path) ----
    let mut drng = Rng::new(4);
    let r = bench("batch assembly b8", 2, iters * 5, || {
        let docs: Vec<String> = (0..8).map(|_| corpus::sample_document(&mut drng)).collect();
        lm_batch(&docs, 8, cfg.seq_len);
    });
    jr.push(&r);
    results.row(&[
        r.name.clone(),
        format!("{:.3}", r.mean_secs * 1e3),
        format!("{:.3}", r.p50_secs * 1e3),
        format!("{:.3}", r.p95_secs * 1e3),
        format!("{:.0} batch/s", r.per_sec()),
    ]);

    println!("## §Perf micro-benchmarks (hot paths, 1 CPU core)");
    results.print();
    jr.write(&json_path)?;
    println!("wrote {}", json_path.display());
    Ok(())
}
