//! Packed-GEMM kernel micro-bench: the SIMD path against the scalar
//! reference, per bit width and group shape — the number the CI
//! `perf-gate` job pins so the speedup can't silently rot.
//!
//! Artifact-free by construction (weights are RTN-quantized in-process),
//! so it runs on every PR. Before timing anything, every case asserts the
//! two kernels produce **bit-identical** outputs (`assert_eq!`, not a
//! tolerance) — a perf number for a kernel that drifted is worthless.
//!
//! Output: a markdown table on stdout plus `BENCH_gemm.json`
//! ([`JsonReport`] schema). The JSON's `meta.speedup_min` is the
//! smallest scalar/SIMD mean-time ratio across cases — the single value
//! the perf gate compares against its 1.5× threshold.
//!
//! Env knobs:
//!   LOTA_GEMM_QUICK=1      smaller shapes/iters (what CI runs)
//!   LOTA_GEMM_ITERS=N      timed iterations per case
//!   LOTA_BENCH_JSON_DIR=d  where BENCH_gemm.json lands (default ".")

use lota_qaf::bench_harness::{bench, f, JsonReport, Table};
use lota_qaf::config::GemmKernel;
use lota_qaf::engine::{matmul_packed_opts, simd, PackedLinear};
use lota_qaf::quant::rtn_quantize;
use lota_qaf::tensor::{Rng, Tensor};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("LOTA_GEMM_QUICK").map(|v| v != "0").unwrap_or(false);
    let iters = env_usize("LOTA_GEMM_ITERS", if quick { 15 } else { 40 });
    let m = if quick { 48 } else { 128 };
    // (din, dout, gs): the small-model slot shape, plus — in full mode —
    // a gs with an 8-lane remainder tail so the masked path gets timed too
    let shapes: &[(usize, usize, usize)] =
        if quick { &[(256, 512, 32)] } else { &[(256, 1024, 32), (240, 1024, 20)] };

    let simd_label = simd::resolve(GemmKernel::Simd).label();
    let mut table =
        Table::new(&["case", "scalar ms", "simd ms", "scalar GF/s", "simd GF/s", "speedup"]);
    let mut jr = JsonReport::new("gemm");
    jr.meta_bool("quick", quick);
    jr.meta_str("simd_kernel", simd_label);
    jr.meta_num("iters", iters as f64);
    jr.meta_num("batch_rows", m as f64);

    let mut rng = Rng::new(0x6E77);
    let mut speedup_min = f64::INFINITY;
    for bits in [2u32, 3, 4] {
        for &(din, dout, gs) in shapes {
            let w = Tensor::new(&[din, dout], rng.normal_vec(din * dout, 0.1));
            let pl = PackedLinear::from_quantized(&rtn_quantize(&w, gs, bits))?;
            let x = Tensor::new(&[m, din], rng.normal_vec(m * din, 1.0));

            // the honesty pin: a timed kernel must be the *same function*
            // bit-for-bit, or the comparison measures nothing
            let scalar_y = matmul_packed_opts(&x, &pl, GemmKernel::Scalar, Some(1));
            let simd_y = matmul_packed_opts(&x, &pl, GemmKernel::Simd, Some(1));
            assert_eq!(
                simd_y, scalar_y,
                "kernel outputs diverged (bits={bits} din={din} dout={dout} gs={gs})"
            );

            let case = format!("w{bits} {m}x{din}x{dout} gs{gs}");
            let rs = bench(&format!("gemm {case} scalar"), 1, iters, || {
                matmul_packed_opts(&x, &pl, GemmKernel::Scalar, Some(1));
            });
            let rv = bench(&format!("gemm {case} simd"), 1, iters, || {
                matmul_packed_opts(&x, &pl, GemmKernel::Simd, Some(1));
            });
            jr.push(&rs);
            jr.push(&rv);
            let flops = 2.0 * m as f64 * (din * dout) as f64;
            let speedup = rs.mean_secs / rv.mean_secs;
            speedup_min = speedup_min.min(speedup);
            table.row(&[
                case,
                f(rs.mean_secs * 1e3, 3),
                f(rv.mean_secs * 1e3, 3),
                f(flops / rs.mean_secs / 1e9, 2),
                f(flops / rv.mean_secs / 1e9, 2),
                format!("{speedup:.2}x"),
            ]);
        }
    }
    jr.meta_num("speedup_min", speedup_min);

    println!("## Packed-GEMM kernel micro-bench (simd = {simd_label}, quick = {quick}, 1 thread)");
    table.print();
    let path = JsonReport::default_path("gemm");
    jr.write(&path)?;
    println!("min speedup {speedup_min:.2}x; wrote {}", path.display());
    Ok(())
}
