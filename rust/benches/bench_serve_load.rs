//! Serving under load: continuous batching vs static batches on the same
//! open-loop Poisson workload and the same KV budget.
//!
//! The workload (`sched::generate_load`) arrives over time with a mixed
//! output-length profile. Two serving disciplines consume it:
//!
//! * **continuous** — `serve::serve_open_loop`: iteration-level
//!   scheduling; a request is admitted into a free decode slot at the
//!   next step, mid-batch, the moment one frees up.
//! * **static** — the PR 2 discipline: whatever has arrived when the
//!   server is free forms a batch (capped at the same slot count), and
//!   everything that arrives while it decodes waits for the *whole*
//!   batch to finish. Generations are produced by the same scheduler
//!   kernels, so both modes emit bit-identical tokens — the only
//!   variable is the admission policy.
//!
//! Short requests finishing early is what separates them: static leaves
//! the freed slots idle behind the batch's longest generation while the
//! queue waits; continuous refills them immediately. Expect higher
//! aggregate tokens/s and much lower p95 latency for continuous at the
//! same KV budget.
//!
//! A second comparison pits the **paged** KV cache against the
//! contiguous reference at the same *tight* budget: contiguous admission
//! reserves a full-context row per request, so the budget caps its slot
//! pool hard; paged admission reserves each request's prompt + max_new
//! in blocks, so the same bytes carry strictly more concurrent requests
//! on a mixed-length workload — with bit-identical tokens (asserted).
//!
//! A final **overload** arm drives a saturating burst through the async
//! worker with a tiny bounded submit queue and a default TTFT deadline:
//! the reject/shed split and the p99 TTFT of the surviving requests land
//! in `BENCH_serve.json` as `overload_*` meta keys.
//!
//! Env knobs: LOTA_LOAD_REQS (48), LOTA_LOAD_RATE (32 req/s),
//! LOTA_LOAD_MODEL (tiny), LOTA_LOAD_SEED (7), LOTA_LOAD_MAXBATCH (4),
//! LOTA_LOAD_BUDGET_MB (1024), LOTA_LOAD_PAGED_RATE (200 req/s — the
//! paged-vs-contiguous arm saturates on purpose), LOTA_LOAD_BLOCK (16),
//! LOTA_LOAD_SUBMIT_ITERS (24), LOTA_LOAD_OVERLOAD_RATE (400 req/s),
//! LOTA_LOAD_OVERLOAD_CAP (4), LOTA_LOAD_OVERLOAD_DEADLINE_MS (150).

use std::time::{Duration, Instant};

use lota_qaf::bench_harness::{BenchResult, JsonReport, Table};
use lota_qaf::config::{preset, Backend, SchedConfig};
use lota_qaf::engine::Engine;
use lota_qaf::model;
use lota_qaf::quant::rtn_quantize;
use lota_qaf::sched::{
    generate_load, stripe_priorities, FinishReason, LoadSpec, RequestSpec, SchedOptions,
    SchedWorker, Scheduler, WorkerConfig,
};
use lota_qaf::serve::{serve_open_loop, Histogram, LatencyStats, ServeOptions, ServePath};
use lota_qaf::tensor::Rng;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Nearest-rank percentile over an ascending-sorted slice (the
/// submit-latency arm wants p90, which [`LatencyStats`] doesn't carry).
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One scheduler histogram as a `BENCH_serve.json` result row. The row
/// reuses the harness's timing-quad field names, but the values are in
/// the histogram's own unit (ms for the latency rows, a 0..1 ratio for
/// occupancy/utilization) — the row name carries the unit.
fn hist_row(name: &str, h: &Histogram) -> BenchResult {
    let s = h.stats();
    BenchResult {
        name: name.to_string(),
        iters: h.len(),
        mean_secs: s.mean,
        p50_secs: s.p50,
        p95_secs: s.p95,
        min_secs: h.min(),
    }
}

fn main() -> anyhow::Result<()> {
    let n_reqs = env_usize("LOTA_LOAD_REQS", 48);
    let rate = env_f64("LOTA_LOAD_RATE", 32.0);
    let model = std::env::var("LOTA_LOAD_MODEL").unwrap_or_else(|_| "tiny".into());
    let seed = env_usize("LOTA_LOAD_SEED", 7) as u64;
    let max_batch = env_usize("LOTA_LOAD_MAXBATCH", 4);
    let budget_mb = env_usize("LOTA_LOAD_BUDGET_MB", 1024);

    let cfg = preset(&model)?;
    let mut rng = Rng::new(4);
    let fp = model::init_fp(&cfg, &mut rng);
    let store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))?;

    let spec = LoadSpec {
        n_requests: n_reqs,
        rate_per_sec: rate,
        seed,
        task: "arith".into(),
        // mixed output lengths: early finishers free slots mid-batch
        max_new_mix: vec![4, 12, 32],
    };
    let load = generate_load(&spec)?;
    let sched_cfg = SchedConfig { max_batch, kv_budget_mb: budget_mb, ..SchedConfig::default() };
    println!(
        "## serving {n_reqs} Poisson arrivals (λ={rate}/s, seed {seed}) on {model}, \
         {max_batch} slots, {budget_mb} MiB KV budget"
    );

    // --- continuous batching: iteration-level admission ---
    let opts = ServeOptions::new(ServePath::Merged, 32)
        .backend(Backend::Native)
        .scheduled(sched_cfg.clone());
    let (cont_responses, cont) = serve_open_loop(&cfg, &store, &opts, &load)?;
    let cont_occupancy = cont
        .sched
        .as_ref()
        .map(|s| s.batch_occupancy.stats().mean)
        .unwrap_or(f64::NAN);

    // --- static batches: same kernels, same slot pool, batch-level
    // admission (arrivals during a batch wait for the whole batch) ---
    let engine = Engine::from_store(&cfg, &store, 4)?;
    let sched_opts = SchedOptions::from_config(&sched_cfg);
    // the *actual* slot pool both disciplines run under (the KV budget
    // may cap it below max_batch) — a static batch must not submit more
    // than this, or the scheduler would quietly do iteration-level
    // admission inside the "static" arm
    let n_slots = Scheduler::new(&engine, &sched_opts)?.n_slots();
    let t0 = Instant::now();
    let mut next = 0usize;
    let mut waiting: Vec<usize> = Vec::new(); // indices into `load`, FIFO
    let mut stat_tokens = 0usize;
    let mut stat_latencies: Vec<f64> = Vec::new();
    // per-request generations in load order, for the bit-identity check
    let mut stat_texts: Vec<Option<(String, usize)>> = vec![None; load.len()];
    let mut stat_occ_sum = 0.0f64;
    let mut stat_batches = 0usize;
    while next < load.len() || !waiting.is_empty() {
        let elapsed = t0.elapsed().as_secs_f64();
        while next < load.len() && load[next].arrival_secs <= elapsed {
            waiting.push(next);
            next += 1;
        }
        if waiting.is_empty() {
            if next < load.len() {
                let gap = load[next].arrival_secs - t0.elapsed().as_secs_f64();
                if gap > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(gap.min(0.02)));
                }
            }
            continue;
        }
        // one static batch: everything waiting, capped at the slot pool,
        // decoded to completion before anything else is admitted
        let batch: Vec<usize> = waiting.drain(..waiting.len().min(n_slots)).collect();
        let mut s = Scheduler::new(&engine, &sched_opts)?;
        let mut submitted = Vec::with_capacity(batch.len());
        for &li in &batch {
            submitted
                .push((s.submit(RequestSpec::new(load[li].prompt.as_str(), load[li].max_new))?, li));
        }
        stat_occ_sum += batch.len() as f64 / n_slots as f64;
        stat_batches += 1;
        s.run_until_idle()?;
        // like the PR 2 drain, a static batch ships all its responses at
        // batch completion — latency runs from arrival to that moment
        let done_at = t0.elapsed().as_secs_f64();
        for resp in s.take_finished() {
            stat_tokens += resp.tokens;
            let li = submitted
                .iter()
                .find(|(id, _)| *id == resp.id)
                .map(|(_, li)| *li)
                .expect("response for an unsubmitted request");
            stat_latencies.push(done_at - load[li].arrival_secs);
            stat_texts[li] = Some((resp.text, resp.tokens));
        }
    }
    let stat_wall = t0.elapsed().as_secs_f64();
    stat_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stat_lat = LatencyStats::from_sorted(&stat_latencies);

    // same requests through the same kernels: every individual generation
    // must be bit-identical across disciplines (the scheduler assigns ids
    // in submission order, which is `load` order for both arms)
    let cont_tokens: usize = cont_responses.iter().map(|r| r.tokens).sum();
    for r in &cont_responses {
        let li = r.id as usize;
        let (text, tokens) = stat_texts[li]
            .as_ref()
            .expect("static arm never served this request");
        assert_eq!(
            (&r.text, r.tokens),
            (text, *tokens),
            "request {li} diverged between disciplines — admission leaked into decoding"
        );
    }

    let mut t = Table::new(&[
        "discipline",
        "tok/s",
        "req/s",
        "p50 lat s",
        "p95 lat s",
        "ttft p50 ms",
        "queue wait ms",
        "occupancy",
    ]);
    t.row(&[
        "continuous".into(),
        format!("{:.1}", cont.tokens_per_sec),
        format!("{:.2}", cont.requests_per_sec),
        format!("{:.3}", cont.latency.p50),
        format!("{:.3}", cont.latency.p95),
        format!("{:.1}", cont.ttft_ms_p50),
        format!("{:.1}", cont.queue_wait_ms),
        format!("{cont_occupancy:.2}"),
    ]);
    t.row(&[
        "static".into(),
        format!("{:.1}", stat_tokens as f64 / stat_wall),
        format!("{:.2}", stat_latencies.len() as f64 / stat_wall),
        format!("{:.3}", stat_lat.p50),
        format!("{:.3}", stat_lat.p95),
        "-".into(), // a static batch streams nothing before it completes
        "-".into(),
        format!("{:.2}", stat_occ_sum / stat_batches.max(1) as f64),
    ]);
    t.print();
    let speedup = (cont.tokens_per_sec * stat_wall) / stat_tokens.max(1) as f64;
    println!(
        "continuous over static: {speedup:.2}x aggregate tokens/s \
         ({} requests, {} tokens each way)",
        n_reqs, cont_tokens
    );

    // --- paged vs contiguous KV at the same tight budget ---
    // The budget is sized so contiguous admission (full-context rows)
    // caps well below max_batch, while the arrival rate saturates both
    // arms — the concurrency gap is then purely the admission unit:
    // rows vs blocks actually needed. Both arms serve the identical
    // workload through the identical kernels; per-request outputs are
    // asserted bit-identical below, so the comparison is honest.
    let paged_rate = env_f64("LOTA_LOAD_PAGED_RATE", 200.0);
    let block_size = env_usize("LOTA_LOAD_BLOCK", 16);
    let wide_batch = 16usize;
    // budget sized from the model so contiguous admission caps at half
    // the slots whatever LOTA_LOAD_MODEL says (tiny: 1 MiB = 8
    // full-context rows = 64 blocks of 16)
    let contig_slots = wide_batch / 2;
    let tight_mb = (contig_slots * engine.cache_row_bytes()).div_ceil(1 << 20).max(1);
    let burst = generate_load(&LoadSpec { rate_per_sec: paged_rate, ..spec.clone() })?;
    println!(
        "\n## paged vs contiguous KV: {} arrivals at λ={paged_rate}/s, {tight_mb} MiB budget, \
         max_batch {wide_batch}, {block_size}-token blocks",
        burst.len()
    );
    let arm = |kv_paged: bool| {
        let cfg_arm = SchedConfig {
            max_batch: wide_batch,
            kv_budget_mb: tight_mb,
            kv_paged,
            kv_block_size: block_size,
            ..SchedConfig::default()
        };
        let opts = ServeOptions::new(ServePath::Merged, 32)
            .backend(Backend::Native)
            .scheduled(cfg_arm);
        serve_open_loop(&cfg, &store, &opts, &burst)
    };
    let (paged_resp, paged_rep) = arm(true)?;
    let (contig_resp, contig_rep) = arm(false)?;
    // responses come back in completion order, which the layouts' timing
    // may shuffle — match per request id (ids are submission order, and
    // both arms submit the same arrival-sorted workload)
    for p in &paged_resp {
        let c = contig_resp
            .iter()
            .find(|c| c.id == p.id)
            .expect("contiguous arm lost a request");
        assert_eq!(
            (&p.text, p.tokens),
            (&c.text, c.tokens),
            "request {} diverged between KV layouts — paging leaked into decoding",
            p.id
        );
    }
    let mut t = Table::new(&[
        "kv layout",
        "tok/s",
        "p95 lat s",
        "peak concurrent",
        "denied",
        "block util",
    ]);
    for (name, rep) in [("paged", &paged_rep), ("contiguous", &contig_rep)] {
        let s = rep.sched.as_ref().expect("scheduled run carries stats");
        t.row(&[
            name.into(),
            format!("{:.1}", rep.tokens_per_sec),
            format!("{:.3}", rep.latency.p95),
            s.peak_active.to_string(),
            s.admission_denied.to_string(),
            if s.block_util.is_empty() {
                "-".into()
            } else {
                format!("{:.2}", s.block_util.stats().mean)
            },
        ]);
    }
    t.print();
    let paged_peak = paged_rep.sched.as_ref().map(|s| s.peak_active).unwrap_or(0);
    let contig_peak = contig_rep.sched.as_ref().map(|s| s.peak_active).unwrap_or(0);
    // the open loop runs on wall-clock arrivals, so only hold the
    // concurrency claim when the contiguous arm demonstrably saturated
    // its slot pool — on a host fast enough to drain λ without queueing
    // there is nothing to compare, so say so instead of aborting
    if contig_peak >= contig_slots {
        assert!(
            paged_peak > contig_peak,
            "paged KV admitted no more concurrent requests than contiguous \
             ({paged_peak} vs {contig_peak}) at a saturated slot pool"
        );
        println!(
            "paged sustained {paged_peak} concurrent requests vs {contig_peak} contiguous \
             at the same {tight_mb} MiB KV budget"
        );
    } else {
        println!(
            "note: the workload never saturated the contiguous slot pool \
             ({contig_peak}/{contig_slots} peak) — raise LOTA_LOAD_PAGED_RATE or \
             LOTA_LOAD_REQS for a meaningful concurrency comparison \
             (paged peak {paged_peak})"
        );
    }

    // --- async front end: submit→first-token latency through the worker
    // command channel, per payload size, with the queue-handoff overhead
    // isolated from compute. Each request runs alone (sequential
    // submits), so the first-token latency decomposes into channel
    // handoff (measured in-scheduler on the arrival clock —
    // `SchedStats::handoff_ms`) + admission + prefill; the difference is
    // pure compute. LOTA_LOAD_SUBMIT_ITERS (24) sets the sample count.
    let submit_iters = env_usize("LOTA_LOAD_SUBMIT_ITERS", 24);
    // payload = prompt length in chars (the toy tokenizer is 1 char =
    // 1 token); prompt + specials + max_new stays inside seq_len 128
    let payloads: [(&str, usize); 3] = [("short", 8), ("medium", 32), ("long", 96)];
    println!(
        "\n## async front end: submit→first-token latency over the worker channel \
         ({submit_iters} sequential requests per payload, max_new 4)"
    );
    let mut submit_arms: Vec<(&str, usize, Histogram, Histogram)> = Vec::new();
    for (name, chars) in payloads {
        let prompt: String =
            "1 + 2 = 3 ".chars().cycle().take(chars).collect();
        let engine = Engine::from_store(&cfg, &store, 4)?;
        let worker = SchedWorker::spawn(
            engine,
            SchedOptions::from_config(&sched_cfg),
            WorkerConfig::default(),
        )?;
        let client = worker.client();
        let mut first = Histogram::default();
        for _ in 0..submit_iters {
            let t = Instant::now();
            let (_id, events) = client.submit_streaming(RequestSpec::new(prompt.as_str(), 4))?;
            events.recv()?; // first generated token crosses back
            first.record(1e3 * t.elapsed().as_secs_f64());
            for _ in events {} // drain to idle before the next submit
        }
        let report = worker.shutdown()?;
        submit_arms.push((name, chars, first, report.stats.handoff_ms));
    }
    let mut t = Table::new(&[
        "payload",
        "chars",
        "first p50 ms",
        "first p90 ms",
        "first p99 ms",
        "handoff p50 ms",
        "handoff p99 ms",
    ]);
    for (name, chars, first, handoff) in &submit_arms {
        let mut f = first.samples().to_vec();
        f.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut h = handoff.samples().to_vec();
        h.sort_by(|a, b| a.partial_cmp(b).unwrap());
        t.row(&[
            (*name).into(),
            chars.to_string(),
            format!("{:.3}", pct(&f, 0.50)),
            format!("{:.3}", pct(&f, 0.90)),
            format!("{:.3}", pct(&f, 0.99)),
            format!("{:.4}", pct(&h, 0.50)),
            format!("{:.4}", pct(&h, 0.99)),
        ]);
    }
    t.print();

    // --- overload control: bounded submit queue + TTFT deadlines under a
    // deliberately saturating burst. Requests arrive striped across two
    // priority classes and every one inherits the worker's default TTFT
    // deadline; the queue cap rejects at the front door (typed
    // `QueueFull`, the wire's 503 + Retry-After) and the deadline sweep
    // sheds whatever waited past its SLO. The ledger records the
    // reject/shed split and the TTFT tail of the survivors — the p99 a
    // deadline-respecting client actually experiences under overload.
    let over_rate = env_f64("LOTA_LOAD_OVERLOAD_RATE", 400.0);
    let over_cap = env_usize("LOTA_LOAD_OVERLOAD_CAP", 4);
    let over_deadline = env_usize("LOTA_LOAD_OVERLOAD_DEADLINE_MS", 150) as u64;
    let mut over_load = generate_load(&LoadSpec { rate_per_sec: over_rate, ..spec.clone() })?;
    stripe_priorities(&mut over_load, 2);
    println!(
        "\n## overload control: {} arrivals at λ={over_rate}/s, submit queue cap {over_cap}, \
         {over_deadline} ms TTFT deadline, 2 priority classes",
        over_load.len()
    );
    let engine = Engine::from_store(&cfg, &store, 4)?;
    let over_opts = SchedOptions {
        priority_classes: 2,
        submit_queue_cap: over_cap,
        default_deadline_ms: Some(over_deadline),
        ..SchedOptions::from_config(&sched_cfg)
    };
    let worker = SchedWorker::spawn(engine, over_opts, WorkerConfig::default())?;
    let client = worker.client();
    let t0 = Instant::now();
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    for r in &over_load {
        let gap = r.arrival_secs - t0.elapsed().as_secs_f64();
        if gap > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(gap));
        }
        let mut rs = RequestSpec::new(r.prompt.as_str(), r.max_new).priority(r.priority);
        rs.deadline_ms = r.deadline_ms; // None → the worker default applies
        match client.submit(rs) {
            Ok(_) => accepted += 1,
            Err(_) => rejected += 1, // bounded queue said 503
        }
    }
    let report = worker.shutdown()?;
    assert_eq!(
        report.stats.queue_rejected, rejected,
        "front-door rejections must reconcile with SchedStats"
    );
    assert_eq!(
        report.responses.len(),
        accepted,
        "every accepted request must resolve (served or shed)"
    );
    let shed = report.stats.shed_at_submit + report.stats.shed_in_queue;
    let served = accepted - shed;
    let mut survivor_ttft = Histogram::default();
    for resp in &report.responses {
        if resp.reason != FinishReason::Shed {
            if let Some(s) = resp.ttft_secs {
                survivor_ttft.record(1e3 * s);
            }
        }
    }
    let mut sv = survivor_ttft.samples().to_vec();
    sv.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let shed_rate = shed as f64 / accepted.max(1) as f64;
    let reject_rate = rejected as f64 / over_load.len().max(1) as f64;
    let mut t = Table::new(&[
        "offered",
        "rejected (503)",
        "accepted",
        "shed",
        "served",
        "survivor ttft p50 ms",
        "survivor ttft p99 ms",
    ]);
    t.row(&[
        over_load.len().to_string(),
        rejected.to_string(),
        accepted.to_string(),
        shed.to_string(),
        served.to_string(),
        format!("{:.1}", pct(&sv, 0.50)),
        format!("{:.1}", pct(&sv, 0.99)),
    ]);
    t.print();
    println!(
        "shed rate {shed_rate:.2} over accepted ({} at submit, {} in queue), \
         reject rate {reject_rate:.2} over offered",
        report.stats.shed_at_submit, report.stats.shed_in_queue
    );

    // machine-readable twin of the tables above: scheduler histograms as
    // result rows (TTFT, inter-token gaps, queue wait, occupancy, block
    // utilization) plus the headline throughput numbers as meta
    let mut jr = JsonReport::new("serve");
    jr.meta_str("model", &model)
        .meta_num("n_requests", n_reqs as f64)
        .meta_num("rate_per_sec", rate)
        .meta_num("max_batch", max_batch as f64)
        .meta_num("kv_budget_mb", budget_mb as f64)
        .meta_str("gemm_kernel", cont.gemm_kernel.unwrap_or("?"))
        .meta_num("tokens_per_sec", cont.tokens_per_sec)
        .meta_num("requests_per_sec", cont.requests_per_sec)
        .meta_num("static_tokens_per_sec", stat_tokens as f64 / stat_wall.max(1e-12))
        .meta_num("speedup_continuous_over_static", speedup)
        .meta_num("paged_peak_active", paged_peak as f64)
        .meta_num("contiguous_peak_active", contig_peak as f64)
        .meta_str("units", "latency rows in ms; occupancy/util rows are 0..1 ratios");
    if let Some(s) = cont.sched.as_ref() {
        jr.meta_num("peak_active", s.peak_active as f64)
            .meta_num("admission_denied", s.admission_denied as f64);
        jr.push(&hist_row("ttft_ms", &s.ttft_ms))
            .push(&hist_row("inter_token_ms", &s.inter_token_ms))
            .push(&hist_row("queue_wait_ms", &s.queue_wait_ms))
            .push(&hist_row("batch_occupancy", &s.batch_occupancy));
    }
    if let Some(s) = paged_rep.sched.as_ref() {
        if !s.block_util.is_empty() {
            jr.push(&hist_row("block_util", &s.block_util));
        }
    }
    // async-front-end arm: full timing quads as rows, the p50/p90/p99
    // surface the issue asks for as meta keys (the ledger's fixed
    // BenchResult schema has no p90/p99 slots)
    for (name, chars, first, handoff) in &submit_arms {
        let mut f = first.samples().to_vec();
        f.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut h = handoff.samples().to_vec();
        h.sort_by(|a, b| a.partial_cmp(b).unwrap());
        jr.push(&hist_row(&format!("submit_first_ms_{name}"), first))
            .push(&hist_row(&format!("handoff_ms_{name}"), handoff));
        jr.meta_num(&format!("submit_first_{name}_chars"), *chars as f64)
            .meta_num(&format!("submit_first_{name}_p50_ms"), pct(&f, 0.50))
            .meta_num(&format!("submit_first_{name}_p90_ms"), pct(&f, 0.90))
            .meta_num(&format!("submit_first_{name}_p99_ms"), pct(&f, 0.99))
            .meta_num(&format!("handoff_{name}_p50_ms"), pct(&h, 0.50))
            .meta_num(&format!("handoff_{name}_p90_ms"), pct(&h, 0.90))
            .meta_num(&format!("handoff_{name}_p99_ms"), pct(&h, 0.99));
    }
    // overload arm: the shed/reject split plus the survivors' TTFT tail
    jr.meta_num("overload_rate_per_sec", over_rate)
        .meta_num("overload_queue_cap", over_cap as f64)
        .meta_num("overload_deadline_ms", over_deadline as f64)
        .meta_num("overload_offered", over_load.len() as f64)
        .meta_num("overload_rejected", rejected as f64)
        .meta_num("overload_accepted", accepted as f64)
        .meta_num("overload_shed", shed as f64)
        .meta_num("overload_shed_rate", shed_rate)
        .meta_num("overload_reject_rate", reject_rate)
        .meta_num("overload_survivor_ttft_p50_ms", pct(&sv, 0.50))
        .meta_num("overload_survivor_ttft_p99_ms", pct(&sv, 0.99));
    if !survivor_ttft.is_empty() {
        jr.push(&hist_row("overload_survivor_ttft_ms", &survivor_ttft));
    }
    let json_path = JsonReport::default_path("serve");
    jr.write(&json_path)?;
    println!("wrote {}", json_path.display());
    Ok(())
}
