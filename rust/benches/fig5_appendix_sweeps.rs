//! Regenerates **Figure 5** (appendix) — the hyper-parameter sweeps
//! extended across datasets: ω and initial-σ_t sensitivity of LoTA-QAF on
//! (a) the MMLU-like recovery suite, (b) sql and (c) datatotext — the
//! analogues of the paper's MMLU/SQL/ViGGO panels. (Panels (d)/(e) — the
//! same sweep on bigger models — are covered by setting
//! LOTA_F5_MODEL=small; the default keeps the bench affordable on 1 CPU.)
//!
//! Env knobs: LOTA_F5_MODEL (tiny), LOTA_F5_STEPS (100), LOTA_F5_EVAL (48).

use std::path::Path;

use lota_qaf::bench_harness::Table;
use lota_qaf::config::{ExperimentConfig, Method};
use lota_qaf::coordinator::experiments::{run_cell, ExperimentContext};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn score(cell: &lota_qaf::coordinator::CellResult) -> f32 {
    cell.mmlu
        .as_ref()
        .map(|m| m.average)
        .or(cell.token_acc)
        .unwrap_or(0.0)
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LOTA_F5_MODEL").unwrap_or_else(|_| "tiny".into());
    let steps = env_usize("LOTA_F5_STEPS", 100);
    let eval_n = env_usize("LOTA_F5_EVAL", 48);
    let pretrain = if model == "tiny" { 600 } else { 300 };
    let ctx = ExperimentContext::build(Path::new("artifacts"), &model, pretrain, 20250710)?;

    let omega_fracs = [0.625, 0.75, 0.875, 0.9375];
    let sigma_inits = [0.080, 0.050, 0.020];
    let datasets = ["recovery", "sql", "datatotext"];

    for task in datasets {
        println!("## Figure 5 — ω sweep on {task} (score = MMLU-avg or token-acc %)");
        let mut t = Table::new(&["omega/r", "int4", "int3", "int2"]);
        for of in omega_fracs {
            let mut row = vec![format!("{of:.4}")];
            for bits in [4u32, 3, 2] {
                let exp = ExperimentConfig {
                    method: Method::LotaQaf,
                    n_bits: bits,
                    omega_frac: of,
                    sigma_init: 0.05,
                    steps,
                    lr: if task == "recovery" { 1e-4 } else { 5e-4 },
                    task: task.into(),
                    model: model.clone(),
                    ..Default::default()
                };
                let cell = run_cell(&ctx, &exp, eval_n)?;
                row.push(format!("{:.2}", score(&cell)));
            }
            t.row(&row);
        }
        t.print();

        println!("\n## Figure 5 — σ_t sweep on {task} (ω = 0.75r)");
        let mut t = Table::new(&["sigma_init", "int4", "int3", "int2"]);
        for si in sigma_inits {
            let mut row = vec![format!("{:.1}%", si * 100.0)];
            for bits in [4u32, 3, 2] {
                let exp = ExperimentConfig {
                    method: Method::LotaQaf,
                    n_bits: bits,
                    omega_frac: 0.75,
                    sigma_init: si,
                    steps,
                    lr: if task == "recovery" { 1e-4 } else { 5e-4 },
                    task: task.into(),
                    model: model.clone(),
                    ..Default::default()
                };
                let cell = run_cell(&ctx, &exp, eval_n)?;
                row.push(format!("{:.2}", score(&cell)));
            }
            t.row(&row);
        }
        t.print();
        println!();
    }
    Ok(())
}
