//! Regenerates **Table 1** (and the Fig. 1 series, which is Table 1's
//! MMLU-average column): accuracy of {16-bit, GPTQ, GPTQ+LoRA, QA-LoRA,
//! LoTA-QAF} × bits {4,3,2} on performance recovery (MMLU-like) and the
//! three task-specific suites (arith/sql/datatotext, the GSM8K/SQL/ViGGO
//! stand-ins) — at simulator scale (DESIGN.md §2 substitutions).
//!
//! Expected shape vs the paper: QAF beats raw GPTQ with the gap exploding
//! at 2-bit; LoTA-QAF ≥ QA-LoRA on recovery; LoRA's 16-bit adapters lead
//! task-specific; absolute values are not comparable (tiny synthetic
//! world, not Llama+MMLU).
//!
//! Env knobs: LOTA_T1_MODEL (tiny), LOTA_T1_PRETRAIN (600),
//! LOTA_T1_STEPS (200), LOTA_T1_EVAL (160).

use std::path::Path;

use lota_qaf::coordinator::experiments::{print_table1, run_table1, ExperimentContext};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("LOTA_T1_MODEL").unwrap_or_else(|_| "tiny".into());
    let pretrain = env_usize("LOTA_T1_PRETRAIN", 600);
    let steps = env_usize("LOTA_T1_STEPS", 200);
    let eval_n = env_usize("LOTA_T1_EVAL", 160);

    println!("## Table 1 / Figure 1 — model={model} pretrain={pretrain} ft-steps={steps} eval-n={eval_n}");
    let t0 = std::time::Instant::now();
    let ctx = ExperimentContext::build(Path::new("artifacts"), &model, pretrain, 20250710)?;
    let tasks = ["arith", "sql", "datatotext"];
    let rows = run_table1(&ctx, steps, eval_n, &[4, 3, 2], &tasks)?;
    print_table1(&rows, &tasks);

    // Fig. 1 series: MMLU average per method per bit-width
    println!("\n## Figure 1 series (MMLU-like avg by bits)");
    for bits in ["4", "3", "2"] {
        let line: Vec<String> = rows
            .iter()
            .filter(|r| r.bits.starts_with(bits))
            .filter_map(|r| r.mmlu.as_ref().map(|m| format!("{}={:.2}", r.method, m.average)))
            .collect();
        println!("bits {bits}: {}", line.join("  "));
    }
    println!("\n(total wall time {:.0}s)", t0.elapsed().as_secs_f64());
    Ok(())
}
