//! Multi-adapter serving parity suite — artifact-free, runs in CI as the
//! mixed-batch smoke gate alongside `sched` and `engine_parity`.
//!
//! The contract under test is the tentpole claim of the adapter registry:
//! a continuous-batching step that mixes requests for adapters A and B
//! (and the bare base) decodes each request **bit-identically** to
//! serving that adapter's individually merged checkpoint alone. The
//! references here are literal solo merges — `lota_merge` folded into a
//! cloned store per (layer, slot), a fresh engine per adapter — so any
//! leak between batch rows, any drift between the in-kernel
//! `TernaryDelta` application and the offline merge, or any adapter
//! mis-tagging fails an `assert_eq!` on the token stream.
//!
//! Arms: staggered Poisson-shaped arrivals across 3 adapters + base,
//! cancellation inside a mixed batch, and admission denial under a
//! 2-block KV pool — the lifecycle edges where slot and block reuse
//! could smear one adapter's state into another's rows.

use lota_qaf::adapter::{lota_merge, TernaryAdapter};
use lota_qaf::config::{preset, ModelConfig};
use lota_qaf::engine::{greedy_decode, Engine};
use lota_qaf::model::{self, ParamStore};
use lota_qaf::quant::rtn_quantize;
use lota_qaf::sched::{
    generate_load, FinishReason, LoadSpec, RequestSpec, RequestState, SchedOptions, Scheduler,
};
use lota_qaf::serve::synthetic_adapter_store;
use lota_qaf::tensor::Rng;

const OMEGA_FRAC: f32 = 0.75;

fn quant_tiny(seed: u64) -> (ModelConfig, ParamStore) {
    let cfg = preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    let fp = model::init_fp(&cfg, &mut rng);
    let store =
        model::quantize_store(&cfg, &fp, |_, _, w| Ok(rtn_quantize(w, cfg.group_size, 4)))
            .unwrap();
    (cfg, store)
}

/// The reference an adapter id must match: the adapter merged offline
/// into a clone of the base grids, served alone by a fresh engine.
fn solo_merged_engine(
    cfg: &ModelConfig,
    base: &ParamStore,
    adapter: &ParamStore,
    omega: f32,
) -> Engine {
    let mut store = base.clone();
    for (slot, _, _) in cfg.slots() {
        for li in 0..cfg.n_layers {
            let ql = model::quant_layer(cfg, &store, slot, li, 4).unwrap();
            let a = adapter.get(&format!("ta_{slot}_a")).unwrap().layer(li);
            let b = adapter.get(&format!("ta_{slot}_b")).unwrap().layer(li);
            let ta = TernaryAdapter::from_parts(a, b).unwrap();
            let merged = lota_merge(&ql, &ta, omega);
            model::set_quant_layer(&mut store, slot, li, &merged).unwrap();
        }
    }
    Engine::from_store(cfg, &store, 4).unwrap()
}

/// One multi-adapter serving engine plus the per-adapter solo references
/// it must reproduce. Index 0 of the returned references is the bare
/// base (adapter id 0), index i is adapter id i.
fn mixed_fixture(seed: u64, adapter_seeds: &[u64]) -> (ModelConfig, Engine, Vec<Engine>) {
    let (cfg, base) = quant_tiny(seed);
    let omega = OMEGA_FRAC * cfg.rank as f32;
    let mut engine = Engine::from_store(&cfg, &base, 4).unwrap();
    let mut refs = vec![Engine::from_store(&cfg, &base, 4).unwrap()];
    for (i, s) in adapter_seeds.iter().enumerate() {
        let ast = synthetic_adapter_store(&cfg, *s);
        let id = engine.register_adapter(&format!("ad{i}"), &ast, omega).unwrap();
        assert_eq!(id as usize, i + 1);
        refs.push(solo_merged_engine(&cfg, &base, &ast, omega));
    }
    (cfg, engine, refs)
}

fn opts(max_batch: usize) -> SchedOptions {
    SchedOptions { max_batch, ..SchedOptions::default() }
}

/// The tentpole pin: staggered arrivals round-robined across base + 3
/// adapters, mixed freely in a 3-slot batch, every per-request token
/// stream `assert_eq!`-identical to its adapter's solo-merged reference.
#[test]
fn mixed_adapter_batches_decode_bit_identically_to_solo_merges() {
    let (_cfg, engine, refs) = mixed_fixture(301, &[41, 42, 43]);
    let spec = LoadSpec {
        n_requests: 12,
        rate_per_sec: 50.0,
        seed: 77,
        task: "arith".into(),
        max_new_mix: vec![3, 7, 12],
    };
    let load = generate_load(&spec).unwrap();
    let mut s = Scheduler::new(&engine, &opts(3)).unwrap();
    let mut pending = load.iter().enumerate();
    let mut ids = Vec::new();
    // drip one arrival per step so admission waves carry a different
    // adapter mix every time, while earlier requests are mid-decode
    loop {
        if let Some((i, req)) = pending.next() {
            let adapter = (i % 4) as u32; // 0 = bare base, mixed in
            let spec = RequestSpec::new(req.prompt.as_str(), req.max_new).adapter(adapter);
            ids.push((s.submit(spec).unwrap(), req, adapter));
        } else if s.is_idle() {
            break;
        }
        s.step().unwrap();
    }
    let responses = s.take_finished();
    assert_eq!(responses.len(), 12);
    let mut diverged_from_base = false;
    for (id, req, adapter) in ids {
        let got = responses.iter().find(|r| r.id == id).unwrap();
        assert_eq!(got.adapter, adapter, "request {id} served under the wrong adapter");
        let want = greedy_decode(&refs[adapter as usize], &[req.prompt.clone()], req.max_new)
            .unwrap();
        assert_eq!(
            got.text, want[0].text,
            "request {id} (adapter {adapter}) diverged from its solo-merged reference"
        );
        assert_eq!(got.tokens, want[0].tokens);
        if adapter > 0 {
            let base = greedy_decode(&refs[0], &[req.prompt.clone()], req.max_new).unwrap();
            diverged_from_base |= base[0].text != got.text;
        }
    }
    // the parity claim is vacuous if every adapter merges to a no-op —
    // random ternary A·B shifts the group zero-points, so at least one
    // request must actually generate differently than the bare base
    assert!(diverged_from_base, "no adapter changed any generation: fixture is trivial");
    // every adapter (and the base) actually served requests this run
    let usage = s.sched_stats().adapter_usage;
    for label in ["base", "ad0", "ad1", "ad2"] {
        assert!(usage.get(label).is_some_and(|u| u.requests > 0), "{label} never served");
    }
}

/// Cancellation inside a mixed batch: the freed slot turns over to a
/// request of a *different* adapter, and nobody else's stream moves a
/// bit. Whether a random tiny model keeps the victim in flight is weight
/// luck, so scan seeds (the sched suite does the same).
#[test]
fn cancellation_in_a_mixed_batch_leaves_other_adapters_bit_exact() {
    for seed in 0..32u64 {
        let (_cfg, engine, refs) = mixed_fixture(600 + seed, &[51, 52, 53]);
        let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
        let reqs: [(&str, usize, u32); 5] = [
            ("1 + 2 =", 12, 1),
            ("3 + 4 =", 12, 2),
            ("5 + 6 =", 8, 3),
            ("7 + 8 =", 8, 0),
            ("9 + 1 =", 8, 2),
        ];
        let ids: Vec<u64> = reqs
            .iter()
            .map(|(p, m, a)| s.submit(RequestSpec::new(*p, *m).adapter(*a)).unwrap())
            .collect();
        s.step().unwrap(); // admit ids[0] (adapter 1) and ids[1] (adapter 2)
        if s.state_of(ids[0]) != Some(RequestState::Decoding)
            || s.state_of(ids[1]) != Some(RequestState::Decoding)
        {
            continue; // finished instantly — try the next seed
        }
        // cancel one in-flight (adapter 1) and one still-queued (base)
        assert!(s.cancel(ids[0]));
        assert!(s.cancel(ids[3]));
        s.run_until_idle().unwrap();
        let responses = s.take_finished();
        assert_eq!(responses.len(), 5);
        for (i, (prompt, max_new, adapter)) in reqs.iter().enumerate() {
            let got = responses.iter().find(|r| r.id == ids[i]).unwrap();
            assert_eq!(got.adapter, *adapter, "cancelled or not, the tag must survive");
            if i == 0 || i == 3 {
                assert_eq!(got.reason, FinishReason::Cancelled);
                continue;
            }
            assert_ne!(got.reason, FinishReason::Cancelled);
            let want =
                greedy_decode(&refs[*adapter as usize], &[prompt.to_string()], *max_new).unwrap();
            assert_eq!(
                got.text, want[0].text,
                "request {i} (adapter {adapter}) drifted after a mixed-batch cancellation"
            );
            assert_eq!(got.tokens, want[0].tokens);
        }
        return;
    }
    panic!("no seed kept the victim in flight past its first step");
}

/// Admission denial under a 2-block paged pool: requests across three
/// adapters are denied and re-admitted as blocks free, and every stream
/// still matches its solo reference — denial waves must not reorder or
/// contaminate per-adapter state.
#[test]
fn admission_denial_under_a_tight_kv_pool_preserves_mixed_parity() {
    let (_cfg, engine, refs) = mixed_fixture(900, &[61, 62, 63]);
    let tight = SchedOptions {
        max_batch: 4,
        kv_budget_bytes: 2 * engine.kv_block_bytes(16),
        kv_paged: true,
        kv_block_size: 16,
        ..SchedOptions::default()
    };
    let mut s = Scheduler::new(&engine, &tight).unwrap();
    let mut ids = Vec::new();
    for i in 0..6u32 {
        let prompt = format!("{} + {} =", i % 10, (i + 3) % 10);
        let max_new = [4usize, 9, 6][i as usize % 3];
        let adapter = i % 4;
        let id = s.submit(RequestSpec::new(prompt.as_str(), max_new).adapter(adapter)).unwrap();
        ids.push((id, prompt, max_new, adapter));
    }
    s.run_until_idle().unwrap();
    let stats = s.sched_stats();
    assert!(
        stats.admission_denied > 0,
        "pool never filled — the denial arm tested nothing (denied {})",
        stats.admission_denied
    );
    let responses = s.take_finished();
    assert_eq!(responses.len(), 6);
    for (id, prompt, max_new, adapter) in ids {
        let got = responses.iter().find(|r| r.id == id).unwrap();
        let want =
            greedy_decode(&refs[adapter as usize], &[prompt.clone()], max_new).unwrap();
        assert_eq!(
            got.text, want[0].text,
            "request {id} (adapter {adapter}) drifted across admission denials"
        );
        assert_eq!(got.tokens, want[0].tokens);
    }
}

/// Tag validation is a submit-time error, not a mid-batch panic: ids
/// beyond the registered count are refused, and an engine with no
/// adapters only accepts the bare base.
#[test]
fn unknown_adapter_ids_are_rejected_at_submit() {
    let (_cfg, engine, _refs) = mixed_fixture(950, &[71]);
    let mut s = Scheduler::new(&engine, &opts(2)).unwrap();
    assert!(s.submit(RequestSpec::new("1 + 1 =", 2).adapter(1)).is_ok());
    assert!(s.submit(RequestSpec::new("1 + 1 =", 2).adapter(2)).is_err());
    let (cfg, base) = quant_tiny(951);
    let bare = Engine::from_store(&cfg, &base, 4).unwrap();
    let mut s = Scheduler::new(&bare, &opts(2)).unwrap();
    assert!(s.submit(RequestSpec::new("1 + 1 =", 2)).is_ok());
    assert!(s.submit(RequestSpec::new("1 + 1 =", 2).adapter(1)).is_err());
}

/// The serving-layer plumbing end to end: `serve_open_loop` with a
/// registry of synthetic adapters registers them, spreads the workload,
/// and reports per-adapter usage that sums to the whole run.
#[test]
fn open_loop_serving_reports_per_adapter_usage() {
    use lota_qaf::config::{Backend, SchedConfig};
    use lota_qaf::sched::spread_adapters;
    use lota_qaf::serve::{serve_open_loop, AdapterRegistry, ServeOptions, ServePath};

    let (cfg, store) = quant_tiny(970);
    let spec = LoadSpec {
        n_requests: 9,
        rate_per_sec: 500.0,
        seed: 5,
        task: "arith".into(),
        max_new_mix: vec![2, 5],
    };
    let mut load = generate_load(&spec).unwrap();
    let reg = AdapterRegistry::parse_cli("fr=synthetic:81,de=synthetic:82,nl=synthetic:83")
        .unwrap();
    spread_adapters(&mut load, reg.len());
    let opts = ServeOptions::new(ServePath::Merged, 5)
        .backend(Backend::Native)
        .scheduled(SchedConfig { max_batch: 3, ..SchedConfig::default() })
        .with_adapters(reg);
    let (responses, report) = serve_open_loop(&cfg, &store, &opts, &load).unwrap();
    assert_eq!(responses.len(), 9);
    let sched = report.sched.as_ref().unwrap();
    // 9 requests round-robined over 3 adapters: 3 each, none on the base
    assert_eq!(sched.adapter_usage.len(), 3);
    for label in ["fr", "de", "nl"] {
        assert_eq!(sched.adapter_usage[label].requests, 3, "{label}");
    }
    let tokens: usize = sched.adapter_usage.values().map(|u| u.tokens).sum();
    assert_eq!(tokens, report.tokens, "per-adapter token usage must sum to the run total");
}
