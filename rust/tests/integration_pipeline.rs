//! Full-pipeline integration test: pretrain → GPTQ-calibrate → quantize →
//! fine-tune (all three methods) → merge → evaluate → serve, at sanity
//! scale. This is the system-level smoke that everything composes; the
//! statistically meaningful runs live in the benches / EXPERIMENTS.md.

use std::path::Path;
use std::sync::OnceLock;

use lota_qaf::config::{preset, ExperimentConfig, Method};
use lota_qaf::coordinator::pipeline::{calibrate_hessians, pretrain, quantize_model};
use lota_qaf::coordinator::{
    exact_match_eval, finetune, greedy_decode, merge_into_store, mmlu_eval, perplexity,
    token_accuracy, TrainOptions,
};
use lota_qaf::data::{mmlu_like, sft_batch, task_by_name, Split};
use lota_qaf::model::{self, ParamStore};
use lota_qaf::quant::output_mse;
use lota_qaf::runtime::Runtime;
use lota_qaf::serve::{serve_batch, ServeOptions, ServePath};
use lota_qaf::tensor::{Rng, Tensor};

struct Ctx {
    rt: Runtime,
    fp: ParamStore,
    hessians: lota_qaf::coordinator::pipeline::HessianMap,
}

fn ctx() -> &'static Ctx {
    static CTX: OnceLock<Ctx> = OnceLock::new();
    CTX.get_or_init(|| {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let rt = Runtime::new(&dir).expect("run `make artifacts` first");
        let cfg = preset("tiny").unwrap();
        let (fp, losses) = pretrain(&rt, &cfg, 200, 1e-3, 11).unwrap();
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "pretraining must make progress: {losses:?}"
        );
        let hessians = calibrate_hessians(&rt, &cfg, &fp, 2, 11).unwrap();
        Ctx { rt, fp, hessians }
    })
}

#[test]
fn calibration_covers_every_slot_layer() {
    let c = ctx();
    let cfg = preset("tiny").unwrap();
    assert_eq!(c.hessians.len(), 6 * cfg.n_layers);
    for ((slot, layer), h) in &c.hessians {
        let (_, din, _) = cfg
            .slots()
            .into_iter()
            .find(|(s, _, _)| s == slot)
            .unwrap_or_else(|| panic!("unknown slot {slot}"));
        assert_eq!(h.shape(), &[din, din], "{slot}/{layer}");
        // hessian diagonals are non-negative sums of squares
        for i in 0..din {
            assert!(h.at2(i, i) >= 0.0);
        }
    }
}

#[test]
fn gptq_beats_rtn_on_real_activations() {
    let c = ctx();
    let cfg = preset("tiny").unwrap();
    // compare on the wq slot of layer 0 with its true calibration hessian:
    // GPTQ minimizes the H-weighted quadratic form tr(Δᵀ H Δ)
    let w = c.fp.get("w_wq").unwrap().layer(0);
    let h = &c.hessians[&("wq".to_string(), 0)];
    let quad = |ql: &lota_qaf::quant::QuantizedLinear| {
        let delta = ql.dequantize().sub(&w);
        let hd = lota_qaf::tensor::linalg::matmul(h, &delta);
        delta
            .data()
            .iter()
            .zip(hd.data())
            .map(|(a, b)| (a * b) as f64)
            .sum::<f64>()
    };
    for bits in [2u32, 3, 4] {
        let g = lota_qaf::quant::gptq_quantize(
            &w,
            h,
            &lota_qaf::quant::GptqConfig::new(bits, cfg.group_size),
        )
        .unwrap();
        let r = lota_qaf::quant::rtn_quantize(&w, cfg.group_size, bits);
        assert!(
            quad(&g) < quad(&r),
            "{bits}-bit: GPTQ {} !< RTN {}",
            quad(&g),
            quad(&r)
        );
        // the output-MSE helper stays exercised
        let mut rng = Rng::new(bits as u64);
        let x = Tensor::new(&[64, cfg.d_model], rng.normal_vec(64 * cfg.d_model, 1.0));
        let _ = output_mse(&w, &g, &x);
    }
}

#[test]
fn finetune_merge_eval_all_methods() {
    let c = ctx();
    let cfg = preset("tiny").unwrap();
    let quant = quantize_model(&cfg, &c.fp, 4, Some(&c.hessians)).unwrap();

    let exe = c.rt.load("fwd_merged_tiny").unwrap();
    let qs = mmlu_like::generate_suite(4, 0xAB);
    let base_scores = mmlu_eval(&c.rt, &exe, &quant, &cfg, &qs, None).unwrap();
    assert!(base_scores.average >= 0.0 && base_scores.average <= 100.0);

    for method in [Method::LotaQaf, Method::QaLora, Method::Lora] {
        let mut store = quant.clone();
        let mut rng = Rng::new(0x77 ^ method as u64);
        model::init_adapters(&cfg, method, &mut rng, &mut store);
        let exp = ExperimentConfig {
            method,
            n_bits: 4,
            steps: 8,
            lr: 1e-3,
            task: "arith".into(),
            ..Default::default()
        };
        let report = finetune(
            &c.rt,
            &cfg,
            &exp,
            &mut store,
            &TrainOptions { record_losses: true, paranoid: true },
        )
        .unwrap();
        assert_eq!(report.losses.len(), 8);
        assert!(report.losses.iter().all(|l| l.is_finite()));

        let err = merge_into_store(&cfg, &exp, &mut store).unwrap();
        match method {
            Method::Lora => assert!(err > 0.0, "LoRA requant must be lossy"),
            _ => assert_eq!(err, 0.0, "{method:?} merge must be lossless"),
        }
        // merged store has no adapters left and still evaluates
        for n in model::adapter_names(method) {
            assert!(!store.contains(&n));
        }
        let gen = task_by_name("arith").unwrap();
        let test = gen.test_set(8);
        let em = exact_match_eval(&c.rt, &exe, &store, &cfg, &test, 6, None).unwrap();
        let ta = token_accuracy(&c.rt, &exe, &store, &cfg, &test, None).unwrap();
        assert!((0.0..=100.0).contains(&em));
        assert!((0.0..=100.0).contains(&ta));
    }
}

#[test]
fn quantization_to_2bit_hurts_in_distribution_perplexity() {
    let c = ctx();
    let cfg = preset("tiny").unwrap();
    // in-distribution data the base model actually fits (recovery mix)
    let mut rng = Rng::new(0xBEEF);
    let examples: Vec<lota_qaf::data::Example> = (0..8)
        .map(|_| {
            let (p, q) = lota_qaf::data::corpus::sample_recovery_example(&mut rng);
            lota_qaf::data::Example { prompt: p, completion: q }
        })
        .collect();
    let batch = sft_batch(&examples, 8, cfg.seq_len);

    let exe_fp = c.rt.load("fwd_fp_tiny").unwrap();
    let ppl_fp = perplexity(&c.rt, &exe_fp, &c.fp, &cfg, &batch, None).unwrap();

    let exe_q = c.rt.load("fwd_merged_tiny").unwrap();
    let q2 = quantize_model(&cfg, &c.fp, 2, Some(&c.hessians)).unwrap();
    let ppl_q2 = perplexity(&c.rt, &exe_q, &q2, &cfg, &batch, None).unwrap();

    assert!(ppl_fp.is_finite() && ppl_q2.is_finite());
    assert!(
        ppl_q2 > ppl_fp,
        "2-bit quantization should hurt perplexity: fp {ppl_fp} vs 2-bit {ppl_q2}"
    );
}

#[test]
fn serving_round_trip_both_paths() {
    let c = ctx();
    let cfg = preset("tiny").unwrap();
    let quant = quantize_model(&cfg, &c.fp, 4, Some(&c.hessians)).unwrap();
    let mut lora = quant.clone();
    let mut rng = Rng::new(0x5E);
    model::init_adapters(&cfg, Method::Lora, &mut rng, &mut lora);

    let gen = task_by_name("arith").unwrap();
    let mut prng = Rng::new(0x5F);
    let prompts: Vec<String> = (0..5)
        .map(|_| gen.sample(&mut prng, Split::Test).prompt)
        .collect();
    let rep_m = serve_batch(
        Some(&c.rt),
        &cfg,
        &quant,
        &ServeOptions::new(ServePath::Merged, 4),
        &prompts,
    )
    .unwrap();
    let rep_l = serve_batch(
        Some(&c.rt),
        &cfg,
        &lora,
        &ServeOptions::new(ServePath::LoraAdapter, 4),
        &prompts,
    )
    .unwrap();
    assert_eq!(rep_m.requests, 5);
    assert_eq!(rep_l.requests, 5);
    assert!(rep_m.tokens_per_sec > 0.0);
    // B=0 LoRA adapters are a no-op: both paths decode identical text
    let exe_m = c.rt.load("fwd_merged_tiny").unwrap();
    let exe_l = c.rt.load("fwd_lora_tiny").unwrap();
    let dm = greedy_decode(&c.rt, &exe_m, &quant, &cfg, &prompts, 4, None).unwrap();
    let dl = greedy_decode(&c.rt, &exe_l, &lora, &cfg, &prompts, 4, None).unwrap();
    assert_eq!(dm, dl, "zero-initialized LoRA must not change decodes");
}

#[test]
fn checkpoint_round_trip_preserves_eval() {
    let c = ctx();
    let cfg = preset("tiny").unwrap();
    let quant = quantize_model(&cfg, &c.fp, 3, Some(&c.hessians)).unwrap();
    let path = std::env::temp_dir().join(format!("lota_pipe_ckpt_{}", std::process::id()));
    model::checkpoint::save(&quant, &path, Some(3)).unwrap();
    let loaded = model::checkpoint::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    let exe = c.rt.load("fwd_merged_tiny").unwrap();
    let mut rng = Rng::new(0x9A);
    let tokens = Tensor::new(
        &[8, cfg.seq_len],
        (0..8 * cfg.seq_len).map(|_| rng.below(cfg.vocab) as f32).collect(),
    );
    let a = lota_qaf::coordinator::run_forward(&c.rt, &exe, &quant, &tokens, None).unwrap();
    let b = lota_qaf::coordinator::run_forward(&c.rt, &exe, &loaded, &tokens, None).unwrap();
    assert_eq!(a, b, "checkpoint round-trip must be bit-exact");
}
