//! Backend parity goldens: the same merged checkpoint must produce the
//! same numbers through the PJRT artifacts and the native packed-integer
//! engine — the interpreter-vs-AOT parity contract, inverted: here the
//! AOT artifact is the reference and the native engine must match it.
//!
//! Like the other integration suites, these tests need `make artifacts`.

use std::path::Path;
use std::sync::OnceLock;

use lota_qaf::config::{Backend, DecodeMode, ModelConfig};
use lota_qaf::coordinator;
use lota_qaf::engine::Engine;
use lota_qaf::runtime::Runtime;
use lota_qaf::serve::{serve_batch, ServeOptions, ServePath};
use lota_qaf::tensor::{Rng, Tensor};

mod common;
use common::merged_tiny;

fn runtime() -> &'static Runtime {
    static RT: OnceLock<Runtime> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Runtime::new(&dir).expect("artifacts missing — run `make artifacts`")
    })
}

fn rand_tokens(cfg: &ModelConfig, b: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::new(
        &[b, cfg.seq_len],
        (0..b * cfg.seq_len).map(|_| rng.below(cfg.vocab) as f32).collect(),
    )
}

/// The golden: identical logits (within f32 tolerance) and identical
/// argmax tokens at every position, through two different executors.
#[test]
fn merged_logits_agree_across_backends() {
    let rt = runtime();
    let (cfg, store) = merged_tiny(41);
    let exe = rt.load("fwd_merged_tiny_b1").unwrap();
    let engine = Engine::from_store(&cfg, &store, 4).unwrap();

    let tokens = rand_tokens(&cfg, 1, 7);
    let pjrt = coordinator::run_forward(rt, &exe, &store, &tokens, None).unwrap();
    let native = engine.forward(&tokens).unwrap();

    assert_eq!(pjrt.shape(), native.shape());
    let max_diff = pjrt.max_abs_diff(&native);
    assert!(max_diff < 1e-2, "backend logits diverge: max abs diff {max_diff}");

    let v = cfg.vocab;
    let argmax = |t: &Tensor, i: usize| -> usize {
        t.data()[i * v..(i + 1) * v]
            .iter()
            .enumerate()
            .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
            .map(|(k, _)| k)
            .unwrap()
    };
    for i in 0..cfg.seq_len {
        assert_eq!(argmax(&pjrt, i), argmax(&native, i), "argmax differs at position {i}");
    }
}

/// Serve-level parity: the same prompts through both backends produce the
/// same texts, with the native path running a batch size no bucket offers.
#[test]
fn serve_texts_agree_across_backends() {
    let rt = runtime();
    let (cfg, store) = merged_tiny(43);
    let gen = lota_qaf::data::task_by_name("arith").unwrap();
    let mut prng = Rng::new(17);
    // 5 requests: native serves them as one batch of 5; pjrt buckets them
    let prompts: Vec<String> = (0..5)
        .map(|_| gen.sample(&mut prng, lota_qaf::data::Split::Test).prompt)
        .collect();

    let mut pjrt_server =
        lota_qaf::serve::Server::new(rt, &cfg, &store, ServePath::Merged, 4).unwrap();
    let mut native_server =
        lota_qaf::serve::Server::native(&cfg, &store, ServePath::Merged, 4, DecodeMode::Cached, 4)
            .unwrap();
    for p in &prompts {
        pjrt_server.enqueue(p.clone());
        native_server.enqueue(p.clone());
    }
    let (mut pjrt_resp, pjrt_rep) = pjrt_server.drain().unwrap();
    let (mut native_resp, native_rep) = native_server.drain().unwrap();
    pjrt_resp.sort_by_key(|r| r.id);
    native_resp.sort_by_key(|r| r.id);

    assert_eq!(pjrt_resp.len(), native_resp.len());
    for (p, n) in pjrt_resp.iter().zip(&native_resp) {
        assert_eq!(p.text, n.text, "request {} decoded differently", p.id);
        assert_eq!(p.tokens_decoded, n.tokens_decoded, "request {} step count", p.id);
    }
    assert_eq!(pjrt_rep.tokens, native_rep.tokens);
}

/// The ServeOptions plumbing selects the native backend without a Runtime.
#[test]
fn serve_options_select_native_without_runtime() {
    let (cfg, store) = merged_tiny(47);
    let opts = ServeOptions::new(ServePath::Merged, 3).backend(Backend::Native);
    let prompts: Vec<String> = (0..3).map(|i| format!("{i} + 1 =")).collect();
    let report = serve_batch(None, &cfg, &store, &opts, &prompts).unwrap();
    assert_eq!(report.requests, 3);
}

/// Three-way parity on the same merged checkpoint: the PJRT artifacts,
/// the native engine's KV-cached decode, and its recompute reference all
/// serve the same texts with the same step counts.
#[test]
fn serve_texts_agree_across_backends_and_decode_modes() {
    let rt = runtime();
    let (cfg, store) = merged_tiny(53);
    let gen = lota_qaf::data::task_by_name("arith").unwrap();
    let mut prng = Rng::new(29);
    let prompts: Vec<String> = (0..4)
        .map(|_| gen.sample(&mut prng, lota_qaf::data::Split::Test).prompt)
        .collect();

    let mut pjrt_server =
        lota_qaf::serve::Server::new(rt, &cfg, &store, ServePath::Merged, 5).unwrap();
    for p in &prompts {
        pjrt_server.enqueue(p.clone());
    }
    let (mut pjrt_resp, _) = pjrt_server.drain().unwrap();
    pjrt_resp.sort_by_key(|r| r.id);

    for mode in [DecodeMode::Cached, DecodeMode::Recompute] {
        let opts = ServeOptions::new(ServePath::Merged, 5)
            .backend(Backend::Native)
            .decode_mode(mode);
        let mut native_server =
            lota_qaf::serve::Server::from_options(None, &cfg, &store, &opts).unwrap();
        for p in &prompts {
            native_server.enqueue(p.clone());
        }
        let (mut native_resp, native_rep) = native_server.drain().unwrap();
        native_resp.sort_by_key(|r| r.id);
        assert_eq!(pjrt_resp.len(), native_resp.len());
        for (p, n) in pjrt_resp.iter().zip(&native_resp) {
            assert_eq!(p.text, n.text, "request {} decoded differently ({mode:?})", p.id);
            assert_eq!(p.tokens_decoded, n.tokens_decoded, "request {} steps ({mode:?})", p.id);
        }
        assert!(native_rep.decode.forwards > 0, "{mode:?} reported no decode work");
    }
}
